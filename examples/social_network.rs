//! Power-law / interactive-analytics scenario (the paper's Arkouda
//! use-case): a data scientist issues connectivity queries against
//! several large skewed graphs through the coordinator's batch API, the
//! way Arachne serves `graph_cc(G)` calls from Python notebooks.
//!
//!     cargo run --release --offline --example social_network

use contour::coordinator::{Coordinator, Job};
use contour::graph::{gen, Csr};

fn main() {
    // Three "session datasets": follower graph, collaboration graph,
    // many-community graph.
    let graphs: Vec<(&str, Csr)> = vec![
        ("followers", gen::rmat(17, 2 << 17, gen::RmatKind::Graph500, 1).into_csr()),
        ("collab", gen::barabasi_albert(200_000, 8, 2).into_csr()),
        ("communities", gen::component_soup(300, 700, 3).into_csr()),
    ];
    for (name, g) in &graphs {
        println!("{name}: n={} m={}", g.n, g.m());
    }

    // Interactive batch: the user asks for components of every dataset,
    // with the coordinator choosing the variant per §IV-E ("auto").
    let jobs: Vec<Job> = graphs
        .iter()
        .enumerate()
        .map(|(id, (name, _))| Job { id, algorithm: "auto".into(), graph_name: name.to_string() })
        .collect();
    let coord = Coordinator { workers: 3, algorithm_threads: 0 };
    let lookup = |name: &str| graphs.iter().find(|(n, _)| *n == name).map(|(_, g)| g);
    let mut reports = coord.run_batch(jobs, lookup).expect("batch");
    reports.sort_by_key(|r| r.id);

    println!("\n{:>12} {:>10} {:>12} {:>8} {:>10}", "graph", "algorithm", "components", "iters", "ms");
    for r in &reports {
        println!(
            "{:>12} {:>10} {:>12} {:>8} {:>10.1}",
            r.graph_name, r.algorithm, r.components, r.iterations, r.millis
        );
    }

    // Power-law graphs are low-diameter: everything converges in a
    // handful of iterations (the §IV-C observation).
    assert!(reports.iter().all(|r| r.iterations <= 8));
}
