//! Quickstart: build a graph, find its components with the paper's
//! default operator (C-2), and verify against ground truth.
//!
//!     cargo run --release --offline --example quickstart

use contour::cc::{self, contour::Contour, Algorithm};
use contour::graph::gen;
use contour::util::Timer;

fn main() {
    // A power-law graph like the paper's social-network datasets.
    let g = gen::rmat(16, 1 << 20, gen::RmatKind::Graph500, 7).into_csr();
    println!("graph: n={} m={}", g.n, g.m());

    // The paper's default variant: two-order minimum mapping, async
    // updates, no atomics, early convergence check.
    let alg = Contour::c2();
    let t = Timer::start();
    let result = alg.run_with_stats(&g);
    println!(
        "C-2: {} components in {} iterations ({:.1} ms)",
        cc::num_components(&result.labels),
        result.iterations,
        t.ms()
    );

    // Compare with the two state-of-the-art baselines of the paper.
    for name in ["FastSV", "ConnectIt"] {
        let alg = contour::coordinator::algorithm_by_name(name, 0).unwrap();
        let t = Timer::start();
        let r = alg.run_with_stats(&g);
        println!(
            "{name}: {} components in {} iterations ({:.1} ms)",
            cc::num_components(&r.labels),
            r.iterations,
            t.ms()
        );
        assert!(cc::same_partition(&r.labels, &result.labels));
    }

    cc::verify::assert_valid(&g, &result.labels, "C-2");
    println!("verified: all algorithms agree with BFS ground truth");
}
