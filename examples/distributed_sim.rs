//! §IV-G scenario: distributed-memory execution simulated with an
//! explicit communication-cost model. Reproduces the paper's qualitative
//! claims: C-1's locality minimizes per-superstep communication, higher
//! orders trade messages for supersteps, and union-find pays fine-grained
//! remote traffic.
//!
//!     cargo run --release --offline --example distributed_sim

use contour::distsim::{simulate, CostModel, DistAlgorithm};
use contour::graph::gen;

fn main() {
    let g = gen::delaunay(60_000, 5).into_csr().shuffled_edges(9);
    println!("delaunay graph: n={} m={}\n", g.n, g.m());

    let cost = CostModel::default();
    println!(
        "{:>8} {:>6} {:>10} {:>12} {:>10} {:>10}",
        "alg", "nodes", "supersteps", "remote_gets", "MB", "modeled_s"
    );
    for alg in [
        DistAlgorithm::Contour { hops: 1 },
        DistAlgorithm::Contour { hops: 2 },
        DistAlgorithm::Contour { hops: 64 },
        DistAlgorithm::FastSv,
        DistAlgorithm::UnionFind,
    ] {
        for nodes in [4usize, 16, 32] {
            let r = simulate(&g, nodes, alg, cost);
            println!(
                "{:>8} {:>6} {:>10} {:>12} {:>10.2} {:>10.4}",
                alg.name(),
                nodes,
                r.supersteps,
                r.remote_reads,
                r.bytes as f64 / 1e6,
                r.modeled_total()
            );
        }
    }

    // §IV-G claim: per superstep, C-1 moves less data than C-2.
    let r1 = simulate(&g, 16, DistAlgorithm::Contour { hops: 1 }, cost);
    let r2 = simulate(&g, 16, DistAlgorithm::Contour { hops: 2 }, cost);
    let per1 = r1.remote_reads as f64 / r1.supersteps as f64;
    let per2 = r2.remote_reads as f64 / r2.supersteps as f64;
    println!("\nremote reads per superstep: C-1 {per1:.0} vs C-2 {per2:.0}");
    assert!(per1 < per2, "C-1 must be the locality-friendly operator");
    assert!(r2.supersteps <= r1.supersteps, "C-2 must take fewer supersteps");
}
