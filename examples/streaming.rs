//! Streaming connectivity scenario: a live edge feed ingested in
//! batches, epoch snapshots published by re-contour compaction, online
//! queries answered while ingestion is in flight, and WAL + snapshot
//! durability surviving a simulated crash.
//!
//!     cargo run --release --offline --example streaming

use contour::cc::{self, contour::Contour, Algorithm};
use contour::graph::gen;
use contour::stream::StreamingCc;
use contour::util::Timer;
use contour::VId;

fn main() -> anyhow::Result<()> {
    // The "feed": a power-law graph whose edges arrive in batches, as if
    // from a social-network event stream.
    let g = gen::rmat(15, 1 << 18, gen::RmatKind::Graph500, 7).into_csr().shuffled_edges(3);
    let edges: Vec<(VId, VId)> = g.edges().collect();
    println!("edge feed: n={} m={}\n", g.n, g.m());

    let dir = std::env::temp_dir().join("contour_streaming_example");
    std::fs::create_dir_all(&dir)?;
    let wal = dir.join("feed.wal");
    let snap_path = dir.join("feed.snap");
    let _ = std::fs::remove_file(&wal); // fresh run

    // Phase 1: ingest the first 60% with periodic epoch seals, querying
    // between batches like an interactive client would.
    let cut = edges.len() * 6 / 10;
    let service = StreamingCc::open(g.n, 0, Some(wal.as_path()))?;
    let t = Timer::start();
    for (i, chunk) in edges[..cut].chunks(8192).enumerate() {
        service.add_edges(chunk)?;
        if i % 4 == 3 {
            let snap = service.seal_epoch()?;
            println!(
                "epoch {:>2}: {:>7} edges ingested, {:>7} components, comp(0) has {:>7} vertices",
                snap.epoch,
                snap.edges_ingested,
                snap.num_components,
                snap.comp_size(0)?,
            );
        }
    }
    let mid = service.seal_epoch()?;
    println!(
        "ingested {} edges over {} epochs in {:.1} ms; snapshot to {}\n",
        mid.edges_ingested,
        mid.epoch,
        t.ms(),
        snap_path.display()
    );
    service.save_snapshot(&snap_path)?;

    // Phase 2: more edges arrive... and the process "crashes" (dropped
    // without a final snapshot). The WAL has everything.
    service.add_edges(&edges[cut..])?;
    drop(service);

    // Phase 3: recovery-on-open — snapshot seeds the union-find, the WAL
    // suffix replays, and a fresh epoch makes the state queryable.
    let t = Timer::start();
    let recovered = StreamingCc::recover(Some(snap_path.as_path()), Some(wal.as_path()), 0)?;
    let fin = recovered.current();
    println!(
        "recovered to epoch {} ({} edges) in {:.1} ms",
        fin.epoch,
        fin.edges_ingested,
        t.ms()
    );

    // Time-travel: the pre-crash epoch is still answerable from its
    // saved snapshot; the current epoch reflects the full feed.
    let saved = contour::stream::Snapshot::load(&snap_path)?;
    println!(
        "components: {} now vs {} at saved epoch {}",
        fin.num_components, saved.num_components, saved.epoch
    );

    // Cross-check: streamed + recovered labels are bit-identical to a
    // static C-2 run over the final graph.
    let want = Contour::c2().run(&g);
    assert_eq!(fin.labels, want, "streamed labels must match static Contour");
    println!(
        "verification: streamed == static C-2 ({} components)",
        cc::num_components(&want)
    );
    Ok(())
}
