//! End-to-end driver: exercises all three layers of the system on a real
//! workload and reports the paper's headline metric.
//!
//! 1. builds a representative slice of the Table I corpus (power-law,
//!    road, kmer, delaunay classes);
//! 2. runs every Contour variant plus FastSV and ConnectIt through the
//!    L3 coordinator (native engine);
//! 3. replays C-2 through the PJRT engine — the AOT-compiled L2 JAX
//!    graph whose hot spot is the L1 Pallas kernel — and checks parity,
//!    proving the three layers compose;
//! 4. prints the headline numbers: average speedup vs FastSV (paper:
//!    C-m 7.3x) and vs ConnectIt (paper: C-m 1.41x), plus iteration
//!    counts vs the Theorem 1 bound.
//!
//!     make artifacts && cargo run --release --offline --example end_to_end
//!
//! The run is recorded in EXPERIMENTS.md §End-to-end.

use contour::cc::{self, Algorithm};
use contour::coordinator::{algorithm_by_name, PjrtContour, PjrtMode};
use contour::graph::{gen, stats, Csr};
use contour::util::Timer;

const ALGS: &[&str] = &["FastSV", "ConnectIt", "C-1", "C-2", "C-m", "C-11mm", "C-1m1m", "C-Syn"];

fn main() {
    let workloads: Vec<(&str, Csr)> = vec![
        ("social (rmat s16)", gen::rmat(16, 1 << 20, gen::RmatKind::Graph500, 1).into_csr()),
        ("collab (ba 150k)", gen::barabasi_albert(150_000, 7, 2).into_csr()),
        ("road 500x500", gen::road(500, 500, 3).into_csr().shuffled_edges(3)),
        ("kmer chains", gen::kmer_chains(600, 400, 4).into_csr().shuffled_edges(4)),
        ("delaunay n16", gen::delaunay(1 << 16, 5).into_csr().shuffled_edges(5)),
    ];

    println!("== end-to-end: native sweep over {} workloads ==\n", workloads.len());
    let mut speed_vs_fastsv = vec![0.0f64; ALGS.len()];
    let mut speed_vs_connectit = vec![0.0f64; ALGS.len()];
    for (name, g) in &workloads {
        let s = stats::stats(g);
        println!("{name}: n={} m={} diam~{}", g.n, g.m(), s.pseudo_diameter);
        let mut times = Vec::new();
        let mut want = None;
        for &alg_name in ALGS {
            let alg = algorithm_by_name(alg_name, 0).unwrap();
            let t = Timer::start();
            let r = alg.run_with_stats(g);
            let ms = t.ms();
            times.push(ms);
            match &want {
                None => want = Some(r.labels.clone()),
                Some(w) => assert!(
                    cc::same_partition(&r.labels, w),
                    "{alg_name} disagrees on {name}"
                ),
            }
            let bound = (s.pseudo_diameter.max(2) as f64).log(1.5).ceil() as usize + 2;
            let bound_txt = if alg_name.starts_with("C-") && alg_name != "C-1" && r.iterations <= bound
            {
                format!("<= Thm1 bound {bound}")
            } else {
                String::new()
            };
            println!("  {alg_name:>9}: {:>5} iters {ms:>9.1} ms  {bound_txt}", r.iterations);
        }
        let fastsv = times[0];
        let connectit = times[1];
        for (i, &t) in times.iter().enumerate() {
            speed_vs_fastsv[i] += fastsv / t;
            speed_vs_connectit[i] += connectit / t;
        }
        println!();
    }

    let k = workloads.len() as f64;
    println!("== headline: average speedups (paper: C-m 7.3x vs FastSV, 1.41x vs ConnectIt) ==");
    for (i, &alg) in ALGS.iter().enumerate() {
        println!(
            "  {alg:>9}: {:>5.2}x vs FastSV, {:>5.2}x vs ConnectIt",
            speed_vs_fastsv[i] / k,
            speed_vs_connectit[i] / k
        );
    }

    // Layer-composition proof: C-2 through PJRT (L1 Pallas kernel inside
    // the L2 JAX iteration, AOT HLO executed by the L3 runtime).
    println!("\n== PJRT engine (L1+L2 artifacts driven from L3) ==");
    match contour::runtime::Runtime::from_env() {
        Ok(rt) => {
            let g = gen::delaunay(1 << 14, 6).into_csr();
            let want = cc::contour::Contour::c2().run(&g);
            for mode in [PjrtMode::PerIteration, PjrtMode::FusedRun] {
                let eng = PjrtContour::new(&rt, 2, mode);
                let t = Timer::start();
                let r = eng.try_run(&g).expect("pjrt");
                assert!(cc::same_partition(&r.labels, &want), "PJRT parity");
                println!(
                    "  {:>15}: {} components, {} iterations, {:.1} ms — parity OK",
                    eng.name(),
                    cc::num_components(&r.labels),
                    r.iterations,
                    t.ms()
                );
            }
            println!("\nall three layers compose: PASS");
        }
        Err(e) => println!("  skipped (run `make artifacts`): {e}"),
    }
}
