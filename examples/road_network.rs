//! Large-diameter scenario (the paper's `road_usa` / `kmer_*` regime):
//! shows why operator order matters — C-1 needs diameter-many
//! iterations while C-2/C-m converge logarithmically (§IV-C), and how
//! the §IV-E auto-selection policy picks the right variant.
//!
//!     cargo run --release --offline --example road_network

use contour::cc::{contour::Contour, Algorithm};
use contour::coordinator::auto_select;
use contour::graph::{gen, stats};
use contour::util::Timer;

fn main() {
    // A 600x600 road lattice: ~360k vertices, diameter ~1200.
    let g = gen::road(600, 600, 11).into_csr().shuffled_edges(3);
    let s = stats::stats(&g);
    println!(
        "road network: n={} m={} pseudo-diameter={} components={}",
        s.n, s.m, s.pseudo_diameter, s.num_components
    );

    let mut reference = None;
    for alg in [Contour::c1(), Contour::c2(), Contour::cm(), Contour::c11mm()] {
        let t = Timer::start();
        let r = alg.run_with_stats(&g);
        println!(
            "  {:>7}: {:>5} iterations  {:>9.1} ms",
            alg.name(),
            r.iterations,
            t.ms()
        );
        if let Some(ref want) = reference {
            assert_eq!(&r.labels, want, "{} disagrees", alg.name());
        } else {
            reference = Some(r.labels);
        }
    }

    // Theorem 1: C-2 converges within ceil(log_1.5(d)) + 1 iterations.
    let bound = (s.pseudo_diameter as f64).log(1.5).ceil() as usize + 1;
    let c2 = Contour::c2().run_with_stats(&g);
    println!("Theorem 1 bound for C-2: {} iterations (measured {})", bound, c2.iterations);
    assert!(c2.iterations <= bound + 1);

    // The §IV-E policy picks a high-order operator for this topology.
    let chosen = auto_select(&s);
    println!("auto-selected variant: {}", chosen.name());
}
