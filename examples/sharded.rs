//! Sharded connectivity end-to-end: partition a graph into vertex-range
//! shards, run shard-local Contour concurrently (one pool job per
//! shard), contract the cross-shard boundary, and cross-check against
//! the single-shard run.
//!
//!     cargo run --release --example sharded

use contour::cc::{self, contour::Contour, Algorithm};
use contour::graph::gen;
use contour::shard::{run_sharded, ShardedGraph};
use contour::util::Timer;

fn main() {
    let g = gen::rmat(16, 1 << 20, gen::RmatKind::Graph500, 1).into_csr().shuffled_edges(7);
    println!("graph: n={} m={}", g.n, g.m());

    let alg = Contour::c2();
    let t = Timer::start();
    let single = alg.run_with_stats(&g);
    let single_ms = t.ms();
    println!(
        "single-shard C-2: {} components in {} iterations, {:.1} ms\n",
        cc::num_components(&single.labels),
        single.iterations,
        single_ms
    );

    println!("{:>6} {:>9} {:>9} {:>9} {:>9}", "shards", "boundary", "part_ms", "run_ms", "same?");
    for p in [1usize, 2, 4, 8] {
        let t = Timer::start();
        let sg = ShardedGraph::partition(&g, p);
        let part_ms = t.ms();

        // Per-shard stats are computed on first use: the heaviest shard
        // tells you whether the split is balanced.
        let heaviest = sg.shards.iter().map(|s| s.graph.m()).max().unwrap_or(0);

        let t = Timer::start();
        let r = run_sharded(&sg, &alg, 0);
        let run_ms = t.ms();
        println!(
            "{:>6} {:>9} {:>9.1} {:>9.1} {:>9} (heaviest shard: {} edges)",
            sg.p(),
            r.boundary_edges,
            part_ms,
            run_ms,
            if r.labels == single.labels { "yes" } else { "NO" },
            heaviest
        );
        assert_eq!(
            r.labels, single.labels,
            "sharded labels must be identical to the single-shard run"
        );
    }
    println!("\nsharded == single-shard for every shard count");
}
