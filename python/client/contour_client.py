"""Arkouda-style Python client for the contour server.

The paper integrates Contour into Arachne/Arkouda: a Python front end
sends messages to a parallel back end, so data scientists get
``graph_cc(G)`` in a notebook while the heavy lifting happens server-side
(§III-A). This client is that front end for our Rust server
(``contour serve``): Python never computes — it ships messages, exactly
like Arkouda's ``pdarray`` front end.

Usage:

    from contour_client import ContourClient

    with ContourClient("127.0.0.1", 7021) as c:
        c.gen("g", "rmat:16:16")        # or c.upload("g", edges)
        comps, iters, ms = c.graph_cc("g", alg="C-2")
        print(c.stats("g"))
"""

from __future__ import annotations

import socket
from typing import Iterable, List, Optional, Tuple


class ContourError(RuntimeError):
    """Server-side error (an ``ERR ...`` reply)."""


class ContourClient:
    def __init__(self, host: str = "127.0.0.1", port: int = 7021, timeout: float = 120.0):
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self._sock.makefile("r", encoding="utf-8", newline="\n")

    # ------------------------------------------------------------ transport

    def _send(self, line: str) -> None:
        self._sock.sendall((line + "\n").encode("utf-8"))

    def _recv(self) -> str:
        line = self._rfile.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        return line.rstrip("\n")

    def _request(self, line: str) -> str:
        self._send(line)
        reply = self._recv()
        if reply.startswith("ERR"):
            raise ContourError(reply[4:])
        return reply

    # -------------------------------------------------------------- session

    def ping(self) -> bool:
        return self._request("PING") == "PONG"

    def close(self) -> None:
        try:
            self._send("QUIT")
            self._recv()  # BYE
        except OSError:
            pass
        finally:
            self._sock.close()

    def __enter__(self) -> "ContourClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --------------------------------------------------------------- graphs

    def gen(self, name: str, spec: str) -> Tuple[int, int]:
        """Generate a graph server-side (specs like ``rmat:16:16``,
        ``delaunay:100000``, ``road:500:500``). Returns (n, m)."""
        _, n, m = self._request(f"GEN {name} {spec}").split()
        return int(n), int(m)

    def upload(self, name: str, edges: Iterable[Tuple[int, int]]) -> Tuple[int, int]:
        """Upload an explicit edge list. Returns (n, m) after dedup."""
        edges = list(edges)
        self._send(f"UPLOAD {name} {len(edges)}")
        for u, v in edges:
            self._send(f"{u} {v}")
        reply = self._recv()
        if reply.startswith("ERR"):
            raise ContourError(reply[4:])
        _, n, m = reply.split()
        return int(n), int(m)

    def load(self, name: str, path: str) -> Tuple[int, int]:
        """Load a server-visible file (.mtx / SNAP edge list / .bin)."""
        _, n, m = self._request(f"LOAD {name} {path}").split()
        return int(n), int(m)

    def drop(self, name: str) -> None:
        self._request(f"DROP {name}")

    def list_graphs(self) -> List[Tuple[str, int, int]]:
        reply = self._request("LIST").split()[1:]
        out = []
        for item in reply:
            gname, n, m = item.split(":")
            out.append((gname, int(n), int(m)))
        return out

    # ------------------------------------------------------------- analysis

    def graph_cc(self, name: str, alg: str = "C-2") -> Tuple[int, int, float]:
        """The paper's ``graph_cc(graph)`` call: returns
        (components, iterations, server_millis)."""
        _, comps, iters, ms = self._request(f"CC {name} {alg}").split()
        return int(comps), int(iters), float(ms)

    def labels(self, name: str, alg: str = "C-2") -> List[int]:
        """Component labels (first 10k vertices)."""
        parts = self._request(f"LABELS {name} {alg}").split()[1:]
        return [int(x) for x in parts]

    def stats(self, name: str) -> dict:
        parts = self._request(f"STATS {name}").split()[1:]
        return {k: int(v) for k, v in (p.split("=") for p in parts)}

    def metrics(self) -> dict:
        parts = self._request("METRICS").split()[1:]
        return {k: int(v) for k, v in (p.split("=") for p in parts)}


def graph_cc(graph_name: str, host: str = "127.0.0.1", port: int = 7021,
             alg: str = "C-2") -> int:
    """One-shot convenience mirroring Arachne's ``graph_cc``: number of
    connected components of a graph already resident on the server."""
    with ContourClient(host, port) as c:
        comps, _, _ = c.graph_cc(graph_name, alg)
        return comps
