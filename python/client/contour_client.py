"""Arkouda-style Python client for the contour server.

The paper integrates Contour into Arachne/Arkouda: a Python front end
sends messages to a parallel back end, so data scientists get
``graph_cc(G)`` in a notebook while the heavy lifting happens server-side
(§III-A). This client is that front end for our Rust server
(``contour serve``): Python never computes — it ships messages, exactly
like Arkouda's ``pdarray`` front end.

Usage:

    from contour_client import ContourClient

    with ContourClient("127.0.0.1", 7021) as c:
        c.gen("g", "rmat:16:16")        # or c.upload("g", edges)
        comps, iters, ms = c.graph_cc("g", alg="C-2")
        print(c.stats("g"))

Streaming quickstart (live edge feed with epoch snapshots; see the
``STREAM*`` verbs in the server protocol):

    with ContourClient("127.0.0.1", 7021) as c:
        c.stream("live", n=1_000_000, wal="/tmp/live.wal")
        c.stream_add("live", [(0, 1), (1, 2), (5, 9)])   # batched ingest
        epoch, comps = c.stream_epoch("live")            # seal a snapshot
        c.same_comp("live", 0, 2)                        # -> True
        c.comp_size("live", 0)                           # -> 3
        c.num_comps("live", epoch=epoch)                 # time-travel
        c.stream_save("live", "/tmp/live.snap")          # durable snapshot
        # after a restart:
        c.stream_load("live2", "/tmp/live.snap", wal="/tmp/live.wal")

Sharded connectivity (server-side partitioning; shard-local runs execute
concurrently as independent pool jobs, then the cross-shard boundary is
contracted — labels are identical to the single-shard run):

    with ContourClient("127.0.0.1", 7021) as c:
        c.gen("g", "rmat:18:16")
        c.shard("g", 8, balance="edges")      # edge-balanced fences
        comps, iters, ms = c.pcc("g", "C-2")  # partitioned graph_cc
        c.pcc("g", "C-2")                     # repeat: served from cache
        c.shard_stats("g")                    # per-shard topology

Observability (every CC/PCC run records a span timeline server-side;
METRICS carries per-verb log₂ latency histograms):

    with ContourClient("127.0.0.1", 7021) as c:
        c.gen("g", "rmat:16:16")
        c.graph_cc("g", "C-2", frontier="exact")
        for s in c.trace("g"):                # one span per Contour pass
            print(s["name"], s["mode"], s["dur_ns"], s["args"])
        c.metrics()["lat/CC"]                 # {"count", "p50", "p95", "p99"}
        c.recent(5)                           # last 5 requests (verb, ok, ns)
        c.health()["status"]                  # ready | degraded | overloaded
        for tick in c.watch(ticks=3, interval_ms=500):
            print(tick["qps"], tick["deltas"])
        c.prom()                              # OpenMetrics exposition text

Protocol v2 (binary framing): on connect the client sends ``HELLO 2``;
a v2 server answers ``OK v2`` and the connection switches to
length-prefixed binary frames (request ids, pipelining, packed label
arrays — see README "Protocol v2"). Older servers answer ``ERR`` and
the client silently stays on the line protocol, so every method works
against either server. ``protocol="line"`` pins the text protocol;
``protocol="binary"`` makes a missing v2 an error.

    with ContourClient("127.0.0.1", 7021) as c:   # negotiates v2
        c.gen("g", "rmat:16:16")
        c.batch_query("g", [0, 17, 42])           # one snapshot, many ids
        with c.pipeline(window=16) as p:          # many requests in flight
            tickets = [p.batch_query("g", chunk) for chunk in chunks]
            labels = [p.result(t) for t in tickets]
"""

from __future__ import annotations

import random
import socket
import struct
import time
from typing import Dict, Iterable, Iterator, List, Optional, Tuple, Union

_MAGIC = b"CP"
_VERSION = 2
_STATUS_OK, _STATUS_ERR, _STATUS_BUSY, _STATUS_BYE = 0, 1, 2, 3
# Mirror of the server's opcode table (rust/src/server/protocol.rs):
# append new verbs, never renumber.
_OPCODES = {
    verb: op
    for op, verb in [
        (1, "PING"), (2, "GEN"), (3, "UPLOAD"), (4, "LOAD"), (5, "CC"),
        (6, "LABELS"), (7, "STATS"), (8, "SHARD"), (9, "PCC"),
        (10, "SHARDSTATS"), (11, "STREAM"), (12, "SADD"), (13, "SEPOCH"),
        (14, "SQUERY"), (15, "SSAVE"), (16, "SLOAD"), (17, "LIST"),
        (18, "DROP"), (19, "METRICS"), (20, "TRACE"), (21, "RECENT"),
        (22, "QUERY"), (23, "BQUERY"), (24, "HELLO"), (25, "QUIT"),
        (26, "PROM"), (27, "HEALTH"), (28, "WATCH"), (29, "FAULTS"),
        (30, "SDEL"),
    ]
}

# BUSY retry backoff: exponential from _RETRY_BASE_S, capped at
# _RETRY_CAP_S, with jitter in [0.5x, 1x] so a fleet of shed clients
# does not retry in lockstep.
_RETRY_BASE_S = 0.05
_RETRY_CAP_S = 2.0


def _backoff_delay(attempt: int) -> float:
    full = min(_RETRY_CAP_S, _RETRY_BASE_S * (2 ** attempt))
    return full * (0.5 + random.random() / 2)


class ContourError(RuntimeError):
    """Server-side error (an ``ERR ...`` reply)."""


class ContourBusy(ContourError):
    """Admission control rejected the request (``ERR busy`` on the line
    protocol, a BUSY frame on the binary one). Safe to retry after
    retiring in-flight replies."""


class ContourInternal(ContourError):
    """The verb panicked server-side (``ERR internal``). The server
    caught the panic, dropped the affected graph's cached results, and
    keeps serving — the connection stays usable, but the request did
    not complete and is not automatically safe to retry."""


class ContourDeadline(ContourError):
    """The request exceeded the server's per-request deadline
    (``ERR deadline``, from ``CONTOUR_DEADLINE_MS`` / ``--deadline-ms``).
    Partial work was abandoned; retry with a smaller request or a
    larger server-side budget."""


def _server_error(message: str) -> ContourError:
    """Classify an ERR reply body into the matching exception type."""
    if message.startswith("busy"):
        return ContourBusy(message)
    if message.startswith("internal"):
        return ContourInternal(message)
    if message.startswith("deadline"):
        return ContourDeadline(message)
    return ContourError(message)


class ContourClient:
    def __init__(self, host: str = "127.0.0.1", port: int = 7021,
                 timeout: float = 120.0, protocol: str = "auto"):
        """``protocol``: ``"auto"`` (negotiate binary v2, fall back to
        the line protocol on pre-v2 servers), ``"line"`` (never
        negotiate), or ``"binary"`` (fail if the server lacks v2)."""
        if protocol not in ("auto", "line", "binary"):
            raise ValueError(f"protocol must be auto|line|binary, got {protocol!r}")
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self._sock.makefile("r", encoding="utf-8", newline="\n")
        self._bfile = None
        self._proto = "line"
        self._next_id = 1
        if protocol != "line":
            self._negotiate(require=protocol == "binary")

    # ------------------------------------------------------------ transport

    def _send(self, line: str) -> None:
        self._sock.sendall((line + "\n").encode("utf-8"))

    def _recv(self) -> str:
        line = self._rfile.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        return line.rstrip("\n")

    def _negotiate(self, require: bool) -> None:
        """``HELLO 2``: upgrade to binary framing when the server speaks
        v2; older servers answer ``ERR unknown command`` and the
        connection simply stays on the line protocol."""
        self._send("HELLO 2")
        reply = self._recv()
        if reply == "OK v2":
            self._proto = "binary"
            self._bfile = self._sock.makefile("rb")
        elif require:
            raise ContourError(f"server does not speak protocol v2: {reply}")

    @property
    def protocol(self) -> str:
        """The negotiated transport: ``"line"`` or ``"binary"``."""
        return self._proto

    def _send_frame(self, verb: str, args: str = "",
                    extra: Optional[List[int]] = None) -> int:
        """Encode and send one request frame; returns its request id."""
        op = _OPCODES[verb.upper()]
        rid = self._next_id
        self._next_id = (self._next_id + 1) & 0xFFFFFFFF or 1
        a = args.encode("utf-8")
        payload = struct.pack("<H", len(a)) + a
        if extra:
            payload += struct.pack(f"<I{len(extra)}I", len(extra), *extra)
        self._sock.sendall(
            struct.pack("<2sBBII", _MAGIC, _VERSION, op, rid, len(payload)) + payload
        )
        return rid

    def _read_exact(self, n: int) -> bytes:
        buf = self._bfile.read(n)
        if buf is None or len(buf) < n:
            raise ConnectionError("server closed mid-frame")
        return buf

    def _recv_frame(self) -> Tuple[int, int, bytes]:
        """Read one reply frame: (request_id, status, payload)."""
        magic, ver, status, rid, plen = struct.unpack("<2sBBII", self._read_exact(12))
        if magic != _MAGIC or ver != _VERSION:
            raise ContourError(f"bad reply frame (magic={magic!r} version={ver})")
        return rid, status, self._read_exact(plen) if plen else b""

    @staticmethod
    def _decode_reply(verb: str, status: int, payload: bytes) -> str:
        """Render a binary reply as the equivalent line-protocol text,
        so both transports feed the same parsing above."""
        if status == _STATUS_BUSY:
            raise ContourBusy(payload.decode("utf-8", "replace"))
        if status == _STATUS_ERR:
            raise _server_error(payload.decode("utf-8", "replace"))
        if status == _STATUS_BYE:
            return "BYE"
        v = verb.upper()
        if v == "BQUERY":
            (count,) = struct.unpack_from("<I", payload, 0)
            labels = struct.unpack_from(f"<{count}I", payload, 4)
            return " ".join(["OK", str(count), *map(str, labels)])
        if v == "LABELS":
            (total,) = struct.unpack_from("<Q", payload, 0)
            (count,) = struct.unpack_from("<I", payload, 8)
            labels = struct.unpack_from(f"<{count}I", payload, 12)
            return " ".join(["OK", str(total), *map(str, labels)])
        text = payload.decode("utf-8")
        if v == "PING":
            return text  # "PONG"
        return f"OK {text}" if text else "OK"

    def _frame_request(self, verb: str, args: str,
                       extra: Optional[List[int]] = None) -> str:
        rid = self._send_frame(verb, args, extra)
        got, status, payload = self._recv_frame()
        if got != rid:
            raise ContourError(f"reply id {got} for request {rid} (pipelining desync)")
        return self._decode_reply(verb, status, payload)

    def _request(self, line: str) -> str:
        if self._proto == "binary":
            verb, _, args = line.partition(" ")
            return self._frame_request(verb, args)
        self._send(line)
        reply = self._recv()
        if reply.startswith("ERR"):
            raise _server_error(reply[4:])
        return reply

    def _with_busy_retry(self, fn, retry_busy: int):
        """Run ``fn``, retrying up to ``retry_busy`` times on
        :class:`ContourBusy` with capped exponential backoff + jitter.
        0 (the default everywhere) keeps load-shed replies visible."""
        attempt = 0
        while True:
            try:
                return fn()
            except ContourBusy:
                if attempt >= retry_busy:
                    raise
                time.sleep(_backoff_delay(attempt))
                attempt += 1

    # -------------------------------------------------------------- session

    def ping(self) -> bool:
        return self._request("PING") == "PONG"

    def close(self) -> None:
        try:
            if self._proto == "binary":
                self._frame_request("QUIT", "")  # BYE, after the pipeline drains
            else:
                self._send("QUIT")
                self._recv()  # BYE
        except OSError:
            pass
        finally:
            self._sock.close()

    def __enter__(self) -> "ContourClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def pipeline(self, window: int = 16, retry_busy: int = 0) -> "Pipeline":
        """Pipelined requests on the binary transport: up to ``window``
        requests in flight, replies matched by request id (the server
        may complete them out of order). Requires a v2 connection.
        ``retry_busy`` resubmits load-shed (BUSY) requests that many
        times with capped exponential backoff + jitter; results still
        land under the original ticket."""
        if self._proto != "binary":
            raise ContourError("pipelining requires the binary protocol (v2 server)")
        return Pipeline(self, window, retry_busy)

    # --------------------------------------------------------------- graphs

    def gen(self, name: str, spec: str) -> Tuple[int, int]:
        """Generate a graph server-side (specs like ``rmat:16:16``,
        ``delaunay:100000``, ``road:500:500``). Returns (n, m)."""
        _, n, m = self._request(f"GEN {name} {spec}").split()
        return int(n), int(m)

    def upload(self, name: str, edges: Iterable[Tuple[int, int]]) -> Tuple[int, int]:
        """Upload an explicit edge list. Returns (n, m) after dedup.
        On the binary transport the edges travel as one packed frame
        instead of one text line per edge."""
        edges = list(edges)
        if self._proto == "binary":
            flat = [x for uv in edges for x in uv]
            reply = self._frame_request("UPLOAD", f"{name} {len(edges)}", flat)
            _, n, m = reply.split()
            return int(n), int(m)
        self._send(f"UPLOAD {name} {len(edges)}")
        for u, v in edges:
            self._send(f"{u} {v}")
        reply = self._recv()
        if reply.startswith("ERR"):
            raise _server_error(reply[4:])
        _, n, m = reply.split()
        return int(n), int(m)

    def load(self, name: str, path: str) -> Tuple[int, int]:
        """Load a server-visible file (.mtx / SNAP edge list / .bin)."""
        _, n, m = self._request(f"LOAD {name} {path}").split()
        return int(n), int(m)

    def drop(self, name: str) -> None:
        self._request(f"DROP {name}")

    def list_graphs(self) -> List[Tuple[str, int, int]]:
        reply = self._request("LIST").split()[1:]
        out = []
        for item in reply:
            gname, n, m = item.split(":")
            out.append((gname, int(n), int(m)))
        return out

    # ------------------------------------------------------------- analysis

    def graph_cc(self, name: str, alg: str = "C-2",
                 frontier: Optional[str] = None) -> Tuple[int, int, float]:
        """The paper's ``graph_cc(graph)`` call: returns
        (components, iterations, server_millis). ``frontier`` pins the
        Contour execution engine for this request: ``"exact"`` (vertex→
        chunk activation map, no backstop sweeps), ``"chunk"`` (local
        dirty bits + periodic full sweeps) or ``"off"`` (full sweeps).
        Default: the server process's ``CONTOUR_FRONTIER`` setting.
        Labels are bit-identical across engines; only iterations/time
        differ, and each pinned engine gets its own server cache slot."""
        if frontier not in (None, "exact", "chunk", "off"):
            raise ValueError(f"frontier must be exact|chunk|off, got {frontier!r}")
        req = f"CC {name} {alg}" + (f" {frontier}" if frontier else "")
        _, comps, iters, ms = self._request(req).split()
        return int(comps), int(iters), float(ms)

    def query(self, name: str, v: int, alg: Optional[str] = None,
              retry_busy: int = 0) -> int:
        """Component label of one vertex, answered wait-free from the
        server's cached labelling. ``alg`` selects the labelling for
        static graphs (default C-2); for streams pass ``"epoch:<e>"``
        to time-travel. ``retry_busy`` retries load-shed (BUSY) replies
        that many times with capped exponential backoff + jitter."""
        sel = f" {alg}" if alg else ""
        reply = self._with_busy_retry(
            lambda: self._request(f"QUERY {name} {v}{sel}"), retry_busy
        )
        return int(reply.split()[1])

    def batch_query(self, name: str, ids: Iterable[int],
                    alg: Optional[str] = None, retry_busy: int = 0) -> List[int]:
        """Vectorized component lookup: every id is answered from one
        epoch/labelling snapshot, so the batch is internally consistent
        even while the stream moves. On the binary transport the ids
        travel packed in the frame payload; on the line protocol they
        ride the arg list. ``retry_busy`` retries load-shed (BUSY)
        replies with capped exponential backoff + jitter."""
        ids = list(ids)
        sel = f" {alg}" if alg else ""
        if self._proto == "binary":
            ask = lambda: self._frame_request("BQUERY", f"{name}{sel}", ids)
        else:
            flat = " ".join(str(v) for v in ids)
            ask = lambda: self._request(f"BQUERY {name}{sel} {flat}")
        reply = self._with_busy_retry(ask, retry_busy)
        return [int(x) for x in reply.split()[2:]]

    def labels(self, name: str, alg: str = "C-2",
               offset: int = 0, count: Optional[int] = None) -> List[int]:
        """One page of component labels (server default: 10k per page)."""
        _, page = self.labels_page(name, alg, offset, count)
        return page

    def labels_page(self, name: str, alg: str = "C-2", offset: int = 0,
                    count: Optional[int] = None) -> Tuple[int, List[int]]:
        """Page through the label array: returns (total, labels[offset:
        offset+count]). Iterate until offset reaches total."""
        req = f"LABELS {name} {alg} {offset}"
        if count is not None:
            req += f" {count}"
        parts = self._request(req).split()[1:]
        return int(parts[0]), [int(x) for x in parts[1:]]

    def all_labels(self, name: str, alg: str = "C-2",
                   page_size: int = 10_000) -> List[int]:
        """Every label, fetched page by page."""
        out: List[int] = []
        total = 1
        while len(out) < total:
            total, page = self.labels_page(name, alg, len(out), page_size)
            if not page and len(out) < total:
                raise ContourError("label paging stalled")
            out.extend(page)
        return out

    def stats(self, name: str) -> dict:
        parts = self._request(f"STATS {name}").split()[1:]
        return {k: int(v) for k, v in (p.split("=") for p in parts)}

    def metrics(self) -> dict:
        """Server counters. Most values are ints; per-graph cache
        entries (``cache/<name>``, including sharded views under
        ``cache/shard/<name>``) are ``"hits:misses"`` strings. The
        execution-engine counters ride along: ``pool_pins`` (workers
        pinned to cores), ``pool_sticky_jobs`` / ``pool_sticky_home`` /
        ``pool_sticky_away`` (sticky chunk→worker placement),
        ``frontier_passes`` / ``frontier_skipped`` (partial frontier
        passes and the chunks they skipped, both engines),
        ``frontier_activations`` (stores that re-dirtied chunks through
        the exact vertex→chunk map), ``frontier_exact`` (exact-engine
        passes), ``frontier_full_sweeps`` (the chunk engine's forced
        backstop sweeps — the exact engine never forces one) and
        ``chunk_index_built`` / ``chunk_index_reused`` (exact-engine
        vertex→chunk index builds vs. cache hits on sharded views).

        Serving counters: ``qps`` (lifetime requests/second, a float),
        ``uptime_ms``, ``busy`` (admission-control rejections),
        ``bytes_in`` / ``bytes_out``, ``hello_upgrades`` (connections
        negotiated to binary v2), ``batch_queries`` /
        ``batch_vertices`` (BQUERY traffic), and per-verb error
        counters ``err/<verb>`` (requests that answered ERR — those
        land in ``lat/<verb>`` too).

        Latency keys (``lat/<verb>`` per request verb, plus
        ``lat/pool_wait`` / ``lat/pool_run`` for the worker pool) are
        log₂-bucket histograms and decode to
        ``{"count", "p50", "p95", "p99"}`` dicts — percentiles are
        bucket midpoints in nanoseconds (clamped to the observed
        max)."""
        out: dict = {}
        for p in self._request("METRICS").split()[1:]:
            k, v = p.split("=", 1)
            if k.startswith("lat/"):
                count, p50, p95, p99 = (int(x) for x in v.split(":"))
                out[k] = {"count": count, "p50": p50, "p95": p95, "p99": p99}
                continue
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)  # e.g. qps=123.4
                except ValueError:
                    out[k] = v
        return out

    # ------------------------------------------------------------ telemetry
    #
    # Continuous telemetry on top of the snapshot verbs: PROM is the
    # OpenMetrics text exposition (what `contour serve --prom-addr`
    # serves over HTTP), HEALTH a windowed ready/degraded/overloaded
    # signal, WATCH a server-push stream of per-interval metric deltas.

    def prom(self) -> str:
        """The server's OpenMetrics/Prometheus text exposition (ends
        with ``# EOF``). Same body a scrape of ``--prom-addr`` gets."""
        if self._proto == "binary":
            reply = self._frame_request("PROM", "")
            _, _, body = reply.partition("\n")  # drop the "OK <n>" head
            return body
        self._send("PROM")
        head = self._recv()
        if head.startswith("ERR"):
            raise _server_error(head[4:])
        n = int(head.split()[1])
        return "\n".join(self._recv() for _ in range(n))

    def health(self) -> dict:
        """Windowed health signal: ``{"status": "ready"|"degraded"|
        "overloaded", "busy_frac": .., "heavy_sat": ..,
        "pool_wait_p95_ns": .., "wal_fsync_ns": .., "window_ms": ..,
        "samples": .., ...}`` (thresholds ride along)."""
        parts = self._request("HEALTH").split()
        out: dict = {"status": parts[1]}
        for tok in parts[2:]:
            k, v = tok.split("=", 1)
            try:
                out[k] = int(v)
            except ValueError:
                out[k] = float(v)
        return out

    @staticmethod
    def _parse_tick(line: str) -> dict:
        parts = line.split()
        if not parts or parts[0] != "TICK":
            raise ContourError(f"unexpected WATCH frame: {line!r}")
        out: dict = {"seq": int(parts[1]), "deltas": {}}
        for tok in parts[2:]:
            k, v = tok.split("=", 1)
            if k in ("t_ms", "dt_ms"):
                out[k] = int(v)
            elif k == "qps":
                out["qps"] = float(v)
            else:
                out["deltas"][k] = int(v)
        return out

    def watch(self, ticks: int = 5, interval_ms: int = 1000) -> Iterator[dict]:
        """Server-push metric deltas: yields one dict per tick —
        ``{"seq", "t_ms", "dt_ms", "qps", "deltas": {counter: delta}}``
        — every ``interval_ms`` until ``ticks`` frames have arrived.
        Works on both transports (OK frames keyed by the request id on
        binary; TICK lines then DONE on the line protocol)."""
        if self._proto == "binary":
            rid = self._send_frame("WATCH", f"{ticks} {interval_ms}")
            while True:
                got, status, payload = self._recv_frame()
                if got != rid:
                    raise ContourError(f"reply id {got} inside WATCH stream {rid}")
                text = payload.decode("utf-8", "replace")
                if status == _STATUS_BUSY:
                    raise ContourBusy(text)
                if status != _STATUS_OK:
                    raise _server_error(text)
                if text == "DONE":
                    return
                yield self._parse_tick(text)
        else:
            self._send(f"WATCH {ticks} {interval_ms}")
            head = self._recv()
            if head.startswith("ERR"):
                raise _server_error(head[4:])
            while True:
                line = self._recv()
                if line == "DONE":
                    return
                yield self._parse_tick(line)

    # ------------------------------------------------------------- tracing
    #
    # Every CC/PCC run records a bounded span timeline server-side (one
    # span per Contour pass, shard-local passes on per-shard tracks).
    # TRACE ships the most recent timeline for a graph; RECENT tails the
    # server's per-request ring buffer.

    def trace(self, name: str) -> List[dict]:
        """Span timeline of the most recent CC/PCC run on ``name``:
        a list of ``{"name", "cat", "mode", "tid", "start_ns",
        "dur_ns", "args"}`` dicts, start-ordered. ``mode`` is how a
        Contour pass executed ("exact"/"chunk"/"full"; "" for
        non-pass spans) and ``args`` carries per-span counters such as
        ``visited``/``skipped``/``lowered``. For Chrome-trace JSON use
        ``contour run --trace`` on the server side instead."""
        parts = self._request(f"TRACE {name}").split()[1:]
        spans: List[dict] = []
        for tok in parts[2:]:  # skip the n=/dropped= header
            fields = tok.split("|")
            sname, cat, mode, tid, start_ns, dur_ns = fields[:6]
            args = {}
            if len(fields) > 6 and fields[6]:
                args = {k: int(v) for k, v in (kv.split("=") for kv in fields[6].split(","))}
            spans.append(
                {
                    "name": sname,
                    "cat": cat,
                    "mode": mode,
                    "tid": int(tid),
                    "start_ns": int(start_ns),
                    "dur_ns": int(dur_ns),
                    "args": args,
                }
            )
        return spans

    def recent(self, n: Optional[int] = None) -> List[dict]:
        """Tail of the server's request ring buffer (most recent last):
        ``{"verb", "ok", "ns"}`` dicts for up to ``n`` requests."""
        req = "RECENT" + (f" {n}" if n is not None else "")
        out = []
        for tok in self._request(req).split()[2:]:
            verb, ok, ns = tok.split(":")
            out.append({"verb": verb, "ok": ok == "1", "ns": int(ns)})
        return out

    # ------------------------------------------------------------- sharding
    #
    # Sharded connectivity: SHARD partitions a stored graph into p
    # vertex-range shards server-side; PCC runs shard-local connectivity
    # concurrently (one pool job per shard) and contracts the cross-shard
    # boundary. Labels are identical to the single-shard run.

    def shard(self, name: str, p: int, balance: Optional[str] = None) -> Tuple[int, int]:
        """Partition graph ``name`` into ``p`` contiguous range shards.
        ``balance`` selects the fence policy: ``"vertices"`` (default —
        equal vertex counts) or ``"edges"`` (fences placed by cumulative
        edge count, evening out per-shard work on power-law graphs).
        Returns (shards, boundary_edges)."""
        req = f"SHARD {name} {p}" + (f" {balance}" if balance else "")
        _, shards, boundary = self._request(req).split()
        return int(shards), int(boundary)

    def pcc(self, name: str, alg: str = "C-2",
            frontier: Optional[str] = None) -> Tuple[int, int, float]:
        """Partitioned ``graph_cc``: shard-local runs + boundary merge.
        Returns (components, iterations, server_millis); requires a
        prior :meth:`shard` call for ``name``. ``frontier`` pins the
        Contour engine shard-locally (``"exact"``/``"chunk"``/``"off"``,
        as in :meth:`graph_cc`); exact-mode repeats on one partition
        reuse each shard's cached vertex→chunk index
        (``chunk_index_reused`` in :meth:`metrics`). Results are cached
        server-side per (name, alg, frontier, p, balance) — a repeat
        call on an unchanged partition reports 0.0 ms."""
        if frontier not in (None, "exact", "chunk", "off"):
            raise ValueError(f"frontier must be exact|chunk|off, got {frontier!r}")
        req = f"PCC {name} {alg}" + (f" {frontier}" if frontier else "")
        _, comps, iters, ms = self._request(req).split()
        return int(comps), int(iters), float(ms)

    def shard_stats(self, name: str) -> dict:
        """Per-shard topology: ``{"p": .., "n": .., "m": ..,
        "boundary": .., "balance": "vertices"|"edges", "shards":
        [{"lo", "hi", "m", "components", "max_degree"}, ...]}``."""
        parts = self._request(f"SHARDSTATS {name}").split()[1:]
        out: dict = {"shards": []}
        for part in parts:
            k, v = part.split("=", 1)
            if k.startswith("shard"):
                lo, hi, m, comps, maxdeg = (int(x) for x in v.split(":"))
                out["shards"].append(
                    {"lo": lo, "hi": hi, "m": m, "components": comps, "max_degree": maxdeg}
                )
            else:
                try:
                    out[k] = int(v)
                except ValueError:
                    out[k] = v  # e.g. balance=edges
        return out

    # ------------------------------------------------------------ streaming
    #
    # Epoch-based streaming connectivity: edges are ingested in batches,
    # SEPOCH seals an immutable min-vertex-id label snapshot (bit-equal
    # to a static C-2 run on the same graph), and queries answer from a
    # snapshot — the current epoch by default, or any retained past one.

    def stream(self, name: str, n: int, wal: Optional[str] = None,
               max_history: Optional[int] = None) -> Tuple[int, int]:
        """Create a streaming session over ``n`` vertices. ``wal`` is a
        server-side write-ahead-log path: if the file exists the stream
        is recovered from it (one live stream per WAL file).
        ``max_history`` caps retained epoch snapshots server-side.
        Returns (n, current_epoch)."""
        req = f"STREAM {name} {n}"
        if wal:
            req += f" {wal}"
        if max_history is not None:
            req += f" {max_history}"
        _, rn, epoch = self._request(req).split()
        return int(rn), int(epoch)

    def stream_add(self, name: str, edges: Iterable[Tuple[int, int]]) -> Tuple[int, int]:
        """Ingest a batch of edges. Returns (edges_added, current_epoch).
        The batch lands in the *next* sealed epoch. An empty batch is a
        no-op."""
        edges = list(edges)
        if not edges:
            _, epoch = self._squery(name, "COMPS")
            return 0, epoch
        flat = " ".join(f"{u} {v}" for u, v in edges)
        _, added, epoch = self._request(f"SADD {name} {flat}").split()
        return int(added), int(epoch)

    def stream_delete(self, name: str, edges: Iterable[Tuple[int, int]]) -> Tuple[int, int]:
        """Remove a batch of edges (multiset semantics: one delete
        retires one surviving insert of that edge; a parallel edge needs
        as many deletes as it had inserts). Deleting an edge that is not
        live is an error. Queries reflect the removal after the next
        :meth:`stream_epoch` seal. On the binary transport the id pairs
        travel packed in the frame payload like :meth:`batch_query`.
        Returns (edges_removed, current_epoch)."""
        edges = list(edges)
        if not edges:
            _, epoch = self._squery(name, "COMPS")
            return 0, epoch
        if self._proto == "binary":
            ids = [x for uv in edges for x in uv]
            reply = self._frame_request("SDEL", name, ids)
        else:
            flat = " ".join(f"{u} {v}" for u, v in edges)
            reply = self._request(f"SDEL {name} {flat}")
        _, removed, epoch = reply.split()
        return int(removed), int(epoch)

    def stream_epoch(self, name: str) -> Tuple[int, int]:
        """Seal the current epoch (re-contour compaction + snapshot
        publish). Returns (epoch, num_components)."""
        _, epoch, comps = self._request(f"SEPOCH {name}").split()
        return int(epoch), int(comps)

    def _squery(self, name: str, op: str, *args: int,
                epoch: Optional[int] = None) -> Tuple[int, int]:
        req = f"SQUERY {name} {op} " + " ".join(str(a) for a in args)
        if epoch is not None:
            req += f" {epoch}"
        _, value, at = self._request(req.rstrip()).split()
        return int(value), int(at)

    def same_comp(self, name: str, u: int, v: int,
                  epoch: Optional[int] = None) -> bool:
        """Are u and v in the same component (at ``epoch``, default
        current)? Wait-free server-side: never blocks on ingestion."""
        value, _ = self._squery(name, "SAME", u, v, epoch=epoch)
        return bool(value)

    def comp_size(self, name: str, v: int, epoch: Optional[int] = None) -> int:
        """Size of v's component at the given (default current) epoch."""
        value, _ = self._squery(name, "SIZE", v, epoch=epoch)
        return value

    def num_comps(self, name: str, epoch: Optional[int] = None) -> int:
        """Number of components at the given (default current) epoch."""
        value, _ = self._squery(name, "COMPS", epoch=epoch)
        return value

    def stream_label(self, name: str, v: int, epoch: Optional[int] = None) -> int:
        """Component label (min vertex id) of v."""
        value, _ = self._squery(name, "LABEL", v, epoch=epoch)
        return value

    def stream_labels_page(self, name: str, epoch: Optional[int] = None,
                           offset: int = 0, count: Optional[int] = None
                           ) -> Tuple[int, List[int]]:
        """Page a sealed epoch's full labelling (default: current epoch)
        through the server's labels cache — the streaming counterpart of
        :meth:`labels_page`. Returns (total, labels[offset:offset+count])."""
        req = f"LABELS {name}"
        if epoch is not None:
            req += f" epoch:{epoch}"
        req += f" {offset}"
        if count is not None:
            req += f" {count}"
        parts = self._request(req).split()[1:]
        return int(parts[0]), [int(x) for x in parts[1:]]

    def stream_save(self, name: str, path: str) -> int:
        """Write a binary snapshot server-side. Returns the epoch saved."""
        _, epoch = self._request(f"SSAVE {name} {path}").split()
        return int(epoch)

    def stream_load(self, name: str, snapshot: str,
                    wal: Optional[str] = None) -> Tuple[int, int]:
        """Recover a stream from a snapshot file (plus optional WAL to
        replay the suffix). Returns (n, current_epoch)."""
        req = f"SLOAD {name} {snapshot}" + (f" {wal}" if wal else "")
        _, n, epoch = self._request(req).split()
        return int(n), int(epoch)


class Pipeline:
    """Pipelined binary requests (from :meth:`ContourClient.pipeline`).

    Issue requests without waiting for replies; each call returns a
    ticket (the frame's request id), and :meth:`result` blocks until
    that ticket's reply has arrived — replies may come back in any
    order. The client-side ``window`` caps in-flight requests below the
    server's per-connection window, so well-behaved pipelines never see
    BUSY; if the server sheds load anyway, :meth:`result` raises
    :class:`ContourBusy` for that ticket and the request can be
    reissued.

        with client.pipeline(window=16) as p:
            tickets = [p.batch_query("g", chunk) for chunk in chunks]
            labels = [p.result(t) for t in tickets]
    """

    def __init__(self, client: ContourClient, window: int = 16, retry_busy: int = 0):
        if window < 1:
            raise ValueError("window must be >= 1")
        self._c = client
        self._window = window
        self._retry_busy = retry_busy
        # In flight, by current frame id. A BUSY resubmission gets a
        # fresh frame id but keeps its original ticket, so callers never
        # see the retries.
        self._inflight: Dict[int, Tuple[str, str, Optional[List[int]], int, int]] = {}
        self._done: Dict[int, Union[str, ContourError]] = {}  # by ticket

    def __enter__(self) -> "Pipeline":
        return self

    def __exit__(self, *exc) -> None:
        self.drain()

    def _submit(self, verb: str, args: str, extra: Optional[List[int]] = None) -> int:
        while len(self._inflight) >= self._window:
            self._pump()
        rid = self._c._send_frame(verb, args, extra)
        self._inflight[rid] = (verb, args, extra, 0, rid)  # ticket = first id
        return rid

    def _pump(self) -> None:
        """Receive one reply and file it under its ticket (or resubmit
        a BUSY request while it has retries left)."""
        rid, status, payload = self._c._recv_frame()
        rec = self._inflight.pop(rid, None)
        if rec is None:
            raise ContourError(f"reply for unknown request id {rid}")
        verb, args, extra, attempt, ticket = rec
        try:
            self._done[ticket] = ContourClient._decode_reply(verb, status, payload)
        except ContourBusy as e:
            if attempt < self._retry_busy:
                time.sleep(_backoff_delay(attempt))
                new_rid = self._c._send_frame(verb, args, extra)
                self._inflight[new_rid] = (verb, args, extra, attempt + 1, ticket)
            else:
                self._done[ticket] = e
        except ContourError as e:
            self._done[ticket] = e

    def query(self, name: str, v: int, alg: Optional[str] = None) -> int:
        """Pipelined :meth:`ContourClient.query`; returns a ticket."""
        sel = f" {alg}" if alg else ""
        return self._submit("QUERY", f"{name} {v}{sel}")

    def batch_query(self, name: str, ids: Iterable[int],
                    alg: Optional[str] = None) -> int:
        """Pipelined :meth:`ContourClient.batch_query`; returns a ticket."""
        sel = f" {alg}" if alg else ""
        return self._submit("BQUERY", f"{name}{sel}", list(ids))

    def result(self, ticket: int) -> Union[int, List[int]]:
        """The reply for ``ticket``: an ``int`` label for ``query``, a
        list of labels for ``batch_query``. Blocks until that reply
        arrives; raises the server's error (:class:`ContourBusy` for
        load shedding) if the request failed."""
        while ticket not in self._done:
            if not any(t == ticket for (_, _, _, _, t) in self._inflight.values()):
                raise ContourError(f"unknown ticket {ticket}")
            self._pump()
        reply = self._done.pop(ticket)
        if isinstance(reply, ContourError):
            raise reply
        parts = reply.split()
        if parts[0] != "OK":
            raise ContourError(reply)
        labels = [int(x) for x in parts[2:]]
        # QUERY replies carry exactly one value after OK.
        return int(parts[1]) if len(parts) == 2 else labels

    def drain(self) -> None:
        """Receive every outstanding reply (errors are filed, not
        raised — they surface when their ticket's result is read)."""
        while self._inflight:
            self._pump()


def graph_cc(graph_name: str, host: str = "127.0.0.1", port: int = 7021,
             alg: str = "C-2") -> int:
    """One-shot convenience mirroring Arachne's ``graph_cc``: number of
    connected components of a graph already resident on the server."""
    with ContourClient(host, port) as c:
        comps, _, _ = c.graph_cc(graph_name, alg)
        return comps
