"""Typed fault exceptions against in-process mock servers — no Rust
binary needed. ``ERR internal`` (a caught server-side panic) and
``ERR deadline`` (per-request budget exceeded) must surface as their
own exception types on both transports, stay distinct from BUSY (no
silent retry), and leave the connection usable for the next request."""

import pathlib
import sys

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "client"))
import contour_client as cc  # noqa: E402
from contour_client import (  # noqa: E402
    ContourBusy,
    ContourClient,
    ContourDeadline,
    ContourError,
    ContourInternal,
)

from test_telemetry_client import MockBinaryServer, MockLineServer  # noqa: E402

OP_CC = cc._OPCODES["CC"]
OP_QUERY = cc._OPCODES["QUERY"]


def test_error_classifier():
    assert isinstance(cc._server_error("busy: shed"), ContourBusy)
    assert isinstance(cc._server_error("internal: CC panicked"), ContourInternal)
    assert isinstance(
        cc._server_error("deadline exceeded after 50ms budget"), ContourDeadline
    )
    plain = cc._server_error("no such graph")
    assert isinstance(plain, ContourError)
    assert not isinstance(plain, (ContourBusy, ContourInternal, ContourDeadline))
    # Both faults are ContourError subclasses, so blanket handlers still fire.
    assert isinstance(cc._server_error("internal: x"), ContourError)
    assert isinstance(cc._server_error("deadline x"), ContourError)


def test_faults_opcode_registered():
    # The FAULTS verb rides the append-only opcode table at 29.
    assert cc._OPCODES["FAULTS"] == 29


def test_line_internal_error_is_typed_and_connection_survives():
    replies = iter(["ERR internal: CC panicked: boom", "OK 7"])
    srv = MockLineServer(lambda line: next(replies))
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        with pytest.raises(ContourInternal, match="CC panicked"):
            c.graph_cc("g")
        # Panic isolation: the same connection answers the next request.
        assert c.query("g", 3) == 7
    srv.join(2)
    assert srv.lines == ["CC g C-2", "QUERY g 3", "QUIT"]


def test_line_deadline_error_is_typed():
    srv = MockLineServer(lambda line: "ERR deadline exceeded after 50ms budget")
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        with pytest.raises(ContourDeadline, match="50ms"):
            c.graph_cc("g")
    srv.join(2)


def test_internal_is_not_retried_as_busy(monkeypatch):
    """A panicking verb must not be silently resubmitted: retry_busy
    only covers load shedding, and repeating a crashed request without
    the caller's say-so could crash the server's worker again."""
    monkeypatch.setattr(cc, "_RETRY_BASE_S", 0.001)
    srv = MockLineServer(lambda line: "ERR internal: boom")
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        with pytest.raises(ContourInternal):
            c.query("g", 3, retry_busy=5)
    srv.join(2)
    assert srv.lines == ["QUERY g 3", "QUIT"]  # exactly one attempt


def test_binary_internal_and_deadline_are_typed():
    replies = {
        1: "internal: PCC panicked: index out of bounds",
        2: "deadline exceeded after 250ms budget",
        3: "no such graph g",
    }
    state = {"n": 0}

    def handler(op, rid, args):
        state["n"] += 1
        return [(rid, cc._STATUS_ERR, replies[state["n"]])]

    srv = MockBinaryServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="binary") as c:
        with pytest.raises(ContourInternal, match="PCC panicked"):
            c.graph_cc("g", "C-2")
        with pytest.raises(ContourDeadline, match="250ms"):
            c.graph_cc("g", "C-2")
        with pytest.raises(ContourError) as ei:
            c.graph_cc("g", "C-2")
        assert not isinstance(
            ei.value, (ContourBusy, ContourInternal, ContourDeadline)
        )
    srv.join(2)


def test_pipeline_files_typed_errors_under_ticket():
    def handler(op, rid, args):
        assert op == OP_QUERY
        if args == "g 1":
            return [(rid, cc._STATUS_ERR, "internal: boom")]
        return [(rid, cc._STATUS_OK, "9")]

    srv = MockBinaryServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="binary") as c:
        with c.pipeline(window=4) as p:
            bad = p.query("g", 1)
            good = p.query("g", 2)
            with pytest.raises(ContourInternal):
                p.result(bad)
            # The panic poisoned neither the pipeline nor the connection.
            assert p.result(good) == 9
    srv.join(2)
