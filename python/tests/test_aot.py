"""AOT pipeline: artifacts must emit, be valid HLO text, and list every
(name, bucket) pair in the manifest the Rust registry parses."""

import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


def test_quick_emit(tmp_path):
    aot.emit(str(tmp_path), quick=True)
    names = sorted(os.listdir(tmp_path))
    assert "manifest.txt" in names
    hlo = [f for f in names if f.endswith(".hlo.txt")]
    # 6 edge artifacts + 2 vertex artifacts for the single quick bucket.
    assert len(hlo) == 8
    for f in hlo:
        text = (tmp_path / f).read_text()
        assert text.startswith("HloModule"), f
        assert "ENTRY" in text, f
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == len(hlo)
    for line in manifest:
        name, n, m, file = line.split()
        assert n.startswith("n=") and m.startswith("m=") and file.startswith("file=")
        assert file.removeprefix("file=") in hlo


def test_hlo_text_round_trips_through_xla_compile():
    """The emitted text must be re-parsable and executable by an XLA CPU
    client — the same path the Rust runtime takes (via xla_extension)."""
    n, m = 64, 32
    lowered = jax.jit(lambda l, s, d: model.contour_iter(l, s, d, hops=2)).lower(
        jax.ShapeDtypeStruct((n,), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.int32),
        jax.ShapeDtypeStruct((m,), jnp.int32),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # Scatter-min must have survived lowering (the combine phase).
    assert "scatter" in text


def test_buckets_are_sane():
    for n, m in aot.BUCKETS:
        assert n & (n - 1) == 0 and m & (m - 1) == 0, "power-of-two buckets"
        assert m % 2048 == 0 or m < 2048  # divisible by the edge block
    assert aot.QUICK_BUCKETS[0] == aot.BUCKETS[0]
