"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

Hypothesis sweeps shapes, dtypes, block sizes and adversarial edge
patterns; every property is also pinned by a deterministic case so plain
pytest runs are meaningful without hypothesis's database.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import minmap, ref

DTYPES = [jnp.int32, jnp.int64]


def _rand_case(rng, n, m, selfloops=False):
    labels = jnp.asarray(rng.integers(0, n, n), dtype=jnp.int32)
    src = rng.integers(0, n, m)
    dst = src.copy() if selfloops else rng.integers(0, n, m)
    return labels, jnp.asarray(src, dtype=jnp.int32), jnp.asarray(dst, dtype=jnp.int32)


# ---------------------------------------------------------------- hop_min


@pytest.mark.parametrize("hops", [1, 2, 3, 4, 8])
@pytest.mark.parametrize("n,m,block", [(16, 8, 4), (64, 128, 32), (1024, 4096, 2048)])
def test_hop_min_matches_ref(hops, n, m, block):
    rng = np.random.default_rng(n * m + hops)
    labels, src, dst = _rand_case(rng, n, m)
    got = minmap.hop_min(labels, src, dst, hops=hops, edge_block=block)
    want = ref.hop_min_ref(labels, src, dst, hops)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(2, 200),
    blocks=st.integers(1, 8),
    block=st.sampled_from([1, 2, 8, 32]),
    hops=st.integers(1, 5),
    seed=st.integers(0, 2**31),
)
def test_hop_min_property(n, blocks, block, hops, seed):
    m = blocks * block
    rng = np.random.default_rng(seed)
    labels, src, dst = _rand_case(rng, n, m)
    got = minmap.hop_min(labels, src, dst, hops=hops, edge_block=block)
    want = ref.hop_min_ref(labels, src, dst, hops)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hop_min_identity_labels():
    """With L = identity, z^h = min(src, dst) for every h."""
    n, m = 32, 64
    rng = np.random.default_rng(7)
    labels = jnp.arange(n, dtype=jnp.int32)
    src = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    for hops in (1, 2, 4):
        got = minmap.hop_min(labels, src, dst, hops=hops, edge_block=16)
        np.testing.assert_array_equal(np.asarray(got), np.minimum(src, dst))


def test_hop_min_self_loops():
    """Self-loop edges produce z = L^h[v]: pure compression, no cross-merge."""
    n, m = 64, 32
    rng = np.random.default_rng(13)
    labels, src, dst = _rand_case(rng, n, m, selfloops=True)
    got = minmap.hop_min(labels, src, dst, hops=2, edge_block=8)
    want = labels[labels[src]]
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hop_min_rejects_bad_block():
    labels = jnp.arange(8, dtype=jnp.int32)
    e = jnp.zeros(6, dtype=jnp.int32)
    with pytest.raises(ValueError):
        minmap.hop_min(labels, e, e, hops=2, edge_block=4)
    with pytest.raises(ValueError):
        minmap.hop_min(labels, e, e, hops=0)


def test_hop_min_monotone_in_hops():
    """z^{h+1} <= z^h pointwise once labels form a decreasing pointer graph
    (L[i] <= i), which holds throughout any Contour run."""
    n, m = 128, 256
    rng = np.random.default_rng(21)
    raw = rng.integers(0, n, n)
    labels = jnp.asarray(np.minimum(raw, np.arange(n)), dtype=jnp.int32)
    src = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    prev = None
    for hops in (1, 2, 3, 4):
        z = np.asarray(minmap.hop_min(labels, src, dst, hops=hops, edge_block=64))
        if prev is not None:
            assert (z <= prev).all()
        prev = z


# ------------------------------------------------------------ pointer_jump


@pytest.mark.parametrize("n,block", [(8, 4), (64, 16), (1024, 256), (1024, 1024)])
def test_pointer_jump_matches_ref(n, block):
    rng = np.random.default_rng(n)
    labels = jnp.asarray(rng.integers(0, n, n), dtype=jnp.int32)
    got = minmap.pointer_jump(labels, vertex_block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.pointer_jump_ref(labels)))


@settings(max_examples=30, deadline=None)
@given(blocks=st.integers(1, 6), block=st.sampled_from([1, 4, 16]), seed=st.integers(0, 2**31))
def test_pointer_jump_property(blocks, block, seed):
    n = blocks * block
    rng = np.random.default_rng(seed)
    labels = jnp.asarray(rng.integers(0, n, n), dtype=jnp.int32)
    got = minmap.pointer_jump(labels, vertex_block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(labels)[np.asarray(labels)])


def test_pointer_jump_fixed_point_on_stars():
    """A forest of stars (L[L] == L) is a fixed point of compression."""
    labels = jnp.asarray([0, 0, 0, 3, 3, 5, 5, 5], dtype=jnp.int32)
    got = minmap.pointer_jump(labels, vertex_block=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(labels))


# ------------------------------------------------------------- scatter_min


@pytest.mark.parametrize("n,m", [(8, 4), (64, 256), (512, 128)])
def test_scatter_min_matches_ref(n, m):
    rng = np.random.default_rng(n + m)
    idx = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    val = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    init = jnp.asarray(rng.integers(0, n, n), dtype=jnp.int32)
    got = minmap.scatter_min(idx, val, init)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.scatter_min_ref(idx, val, init))
    )


@settings(max_examples=30, deadline=None)
@given(n=st.integers(1, 100), m=st.integers(1, 200), seed=st.integers(0, 2**31))
def test_scatter_min_property(n, m, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    val = jnp.asarray(rng.integers(-5, n, m), dtype=jnp.int32)
    init = jnp.asarray(rng.integers(0, n, n), dtype=jnp.int32)
    got = np.asarray(minmap.scatter_min(idx, val, init))
    want = np.asarray(init).copy()
    for i, v in zip(np.asarray(idx), np.asarray(val)):
        want[i] = min(want[i], v)
    np.testing.assert_array_equal(got, want)


def test_scatter_min_duplicate_indices():
    """All edges target one slot: result is the global min (CAS-loop analog)."""
    idx = jnp.zeros(16, dtype=jnp.int32)
    val = jnp.asarray(np.arange(16, 0, -1), dtype=jnp.int32)
    init = jnp.full((4,), 100, dtype=jnp.int32)
    got = np.asarray(minmap.scatter_min(idx, val, init))
    assert got[0] == 1
    assert (got[1:] == 100).all()
