"""Client-side telemetry tests against in-process mock servers — no
Rust binary needed. Covers the opt-in BUSY retry (line protocol,
binary pipeline), backoff shape, and the WATCH/PROM/HEALTH parsers on
both transports."""

import pathlib
import socket
import struct
import sys
import threading

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "client"))
import contour_client as cc  # noqa: E402
from contour_client import ContourBusy, ContourClient  # noqa: E402

OP_QUIT = cc._OPCODES["QUIT"]
OP_QUERY = cc._OPCODES["QUERY"]
OP_WATCH = cc._OPCODES["WATCH"]


class MockLineServer(threading.Thread):
    """One-connection line-protocol mock. ``handler(line)`` returns the
    reply line or a list of lines; QUIT is answered here."""

    def __init__(self, handler):
        super().__init__(daemon=True)
        self.sock = socket.socket()
        self.sock.bind(("127.0.0.1", 0))
        self.sock.listen(1)
        self.port = self.sock.getsockname()[1]
        self.handler = handler
        self.lines = []
        self.start()

    def run(self):
        conn, _ = self.sock.accept()
        f = conn.makefile("r", encoding="utf-8", newline="\n")
        try:
            for line in f:
                line = line.rstrip("\n")
                self.lines.append(line)
                if line == "QUIT":
                    conn.sendall(b"BYE\n")
                    break
                out = self.handler(line)
                if isinstance(out, str):
                    out = [out]
                conn.sendall(("".join(l + "\n" for l in out)).encode("utf-8"))
        finally:
            conn.close()
            self.sock.close()


def _send_frame(conn, rid, status, text):
    b = text.encode("utf-8")
    conn.sendall(struct.pack("<2sBBII", b"CP", 2, status, rid, len(b)) + b)


class MockBinaryServer(threading.Thread):
    """One-connection protocol-v2 mock: answers the HELLO upgrade, then
    feeds each request frame to ``handler(op, rid, args)``, which
    returns a list of ``(rid, status, text)`` reply frames. QUIT is
    answered here with a BYE frame."""

    def __init__(self, handler):
        super().__init__(daemon=True)
        self.sock = socket.socket()
        self.sock.bind(("127.0.0.1", 0))
        self.sock.listen(1)
        self.port = self.sock.getsockname()[1]
        self.handler = handler
        self.frames = []
        self.start()

    def run(self):
        conn, _ = self.sock.accept()
        rf = conn.makefile("rb")
        try:
            assert rf.readline() == b"HELLO 2\n"
            conn.sendall(b"OK v2\n")
            while True:
                head = rf.read(12)
                if not head or len(head) < 12:
                    break
                magic, ver, op, rid, plen = struct.unpack("<2sBBII", head)
                payload = rf.read(plen) if plen else b""
                (alen,) = struct.unpack_from("<H", payload, 0)
                args = payload[2 : 2 + alen].decode("utf-8")
                self.frames.append((op, rid, args))
                if op == OP_QUIT:
                    _send_frame(conn, rid, cc._STATUS_BYE, "")
                    break
                for reply in self.handler(op, rid, args):
                    _send_frame(conn, *reply)
        finally:
            conn.close()
            self.sock.close()


# ----------------------------------------------------------- BUSY retry


def test_backoff_grows_and_caps():
    for attempt in range(12):
        d = cc._backoff_delay(attempt)
        full = min(cc._RETRY_CAP_S, cc._RETRY_BASE_S * 2 ** attempt)
        assert full / 2 <= d <= full, (attempt, d)
    # Far past the cap: still bounded (no overflow blowup).
    assert cc._backoff_delay(60) <= cc._RETRY_CAP_S


def test_busy_surfaces_without_optin():
    srv = MockLineServer(lambda line: "ERR busy: shed")
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        with pytest.raises(ContourBusy):
            c.query("g", 3)
    srv.join(2)
    # Exactly one attempt: no silent retries by default.
    assert srv.lines == ["QUERY g 3", "QUIT"]


def test_line_query_retries_busy_until_ok(monkeypatch):
    monkeypatch.setattr(cc, "_RETRY_BASE_S", 0.001)
    state = {"n": 0}

    def handler(line):
        state["n"] += 1
        return "ERR busy: shed" if state["n"] <= 2 else "OK 7"

    srv = MockLineServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        assert c.query("g", 3, retry_busy=5) == 7
    srv.join(2)
    assert srv.lines[:3] == ["QUERY g 3"] * 3, srv.lines


def test_line_batch_query_retries_busy(monkeypatch):
    monkeypatch.setattr(cc, "_RETRY_BASE_S", 0.001)
    state = {"n": 0, "always_busy": False}

    def handler(line):
        state["n"] += 1
        if state["always_busy"] or state["n"] == 1:
            return "ERR busy: shed"
        return "OK 2 0 0"

    srv = MockLineServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        assert c.batch_query("g", [1, 2], retry_busy=1) == [0, 0]
        # Retries exhausted: the BUSY surfaces.
        state["always_busy"] = True
        with pytest.raises(ContourBusy):
            c.batch_query("g", [1, 2], retry_busy=2)
    srv.join(2)


def test_pipeline_resubmits_busy_under_original_ticket(monkeypatch):
    monkeypatch.setattr(cc, "_RETRY_BASE_S", 0.001)
    state = {"n": 0}

    def handler(op, rid, args):
        assert op == OP_QUERY
        state["n"] += 1
        if state["n"] <= 2:
            return [(rid, cc._STATUS_BUSY, "shed")]
        return [(rid, cc._STATUS_OK, "7")]

    srv = MockBinaryServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="binary") as c:
        with c.pipeline(window=4, retry_busy=3) as p:
            ticket = p.query("g", 3)
            assert p.result(ticket) == 7
    srv.join(2)
    query_frames = [f for f in srv.frames if f[0] == OP_QUERY]
    assert len(query_frames) == 3, srv.frames
    # Each resubmission used a fresh frame id.
    assert len({rid for _, rid, _ in query_frames}) == 3
    assert {args for _, _, args in query_frames} == {"g 3"}


def test_pipeline_busy_raises_when_retries_exhausted(monkeypatch):
    monkeypatch.setattr(cc, "_RETRY_BASE_S", 0.001)

    def handler(op, rid, args):
        return [(rid, cc._STATUS_BUSY, "shed")]

    srv = MockBinaryServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="binary") as c:
        with c.pipeline(window=4, retry_busy=2) as p:
            ticket = p.query("g", 3)
            with pytest.raises(ContourBusy):
                p.result(ticket)
    srv.join(2)
    assert len([f for f in srv.frames if f[0] == OP_QUERY]) == 3  # 1 + 2 retries


# ------------------------------------------------- WATCH / PROM / HEALTH


TICKS = [
    "TICK 0 t_ms=12 dt_ms=10 requests=4 errors=0 qps=400.0",
    "TICK 1 t_ms=22 dt_ms=10 requests=0 errors=1 qps=0.0",
]


def _check_ticks(got):
    assert [t["seq"] for t in got] == [0, 1]
    assert got[0]["t_ms"] == 12 and got[0]["dt_ms"] == 10
    assert got[0]["deltas"] == {"requests": 4, "errors": 0}
    assert got[0]["qps"] == 400.0
    assert got[1]["deltas"]["errors"] == 1 and got[1]["qps"] == 0.0


def test_watch_parses_line_stream():
    def handler(line):
        assert line == "WATCH 2 10"
        return ["OK 2 10"] + TICKS + ["DONE"]

    srv = MockLineServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        _check_ticks(list(c.watch(ticks=2, interval_ms=10)))
    srv.join(2)


def test_watch_parses_binary_stream():
    def handler(op, rid, args):
        assert (op, args) == (OP_WATCH, "2 10")
        return [(rid, cc._STATUS_OK, t) for t in TICKS] + [(rid, cc._STATUS_OK, "DONE")]

    srv = MockBinaryServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="binary") as c:
        _check_ticks(list(c.watch(ticks=2, interval_ms=10)))
    srv.join(2)


PROM_BODY = ["# TYPE contour_requests_total counter", "contour_requests_total 7", "# EOF"]


def test_prom_line_transport():
    srv = MockLineServer(lambda line: [f"OK {len(PROM_BODY)}"] + PROM_BODY)
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        assert c.prom() == "\n".join(PROM_BODY)
    srv.join(2)


def test_prom_binary_transport():
    body = "\n".join(PROM_BODY)

    def handler(op, rid, args):
        return [(rid, cc._STATUS_OK, f"{len(PROM_BODY)}\n{body}")]

    srv = MockBinaryServer(handler)
    with ContourClient("127.0.0.1", srv.port, protocol="binary") as c:
        assert c.prom() == body
    srv.join(2)


def test_health_parses_status_and_signals():
    reply = (
        "OK degraded busy_frac=0.0870 heavy_sat=1.0000 pool_wait_p95_ns=12 "
        "wal_fsync_ns=0 window_ms=60000 samples=0 busy_degraded=0.05 busy_overloaded=0.5"
    )
    srv = MockLineServer(lambda line: reply)
    with ContourClient("127.0.0.1", srv.port, protocol="line") as c:
        h = c.health()
    srv.join(2)
    assert h["status"] == "degraded"
    assert h["busy_frac"] == pytest.approx(0.087)
    assert h["samples"] == 0 and h["window_ms"] == 60000
    assert h["busy_overloaded"] == pytest.approx(0.5)
