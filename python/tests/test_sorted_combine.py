"""The sort-based combine (TPU-idiomatic conflict-free alternative to
scatter-min) must be numerically identical to the scatter path."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _case(seed, n, m):
    rng = np.random.default_rng(seed)
    labels = jnp.asarray(np.minimum(rng.integers(0, n, n), np.arange(n)), dtype=jnp.int32)
    src = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    return labels, src, dst


@pytest.mark.parametrize("hops", [1, 2, 4])
@pytest.mark.parametrize("n,m", [(16, 8), (128, 256), (512, 1024)])
def test_sort_combine_matches_scatter(hops, n, m):
    labels, src, dst = _case(n * m + hops, n, m)
    a, ca = model.contour_iter(labels, src, dst, hops=hops, use_pallas=False,
                               combine="scatter")
    b, cb = model.contour_iter(labels, src, dst, hops=hops, use_pallas=False,
                               combine="sort")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(ca) == int(cb)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 100), m=st.integers(1, 200), hops=st.integers(1, 3),
       seed=st.integers(0, 2**31))
def test_sort_combine_property(n, m, hops, seed):
    labels, src, dst = _case(seed, n, m)
    a, _ = model.contour_iter(labels, src, dst, hops=hops, use_pallas=False,
                              combine="scatter")
    b, _ = model.contour_iter(labels, src, dst, hops=hops, use_pallas=False,
                              combine="sort")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sort_combine_full_run_converges():
    n = 64
    edges = [(i, i + 1) for i in range(n - 1)]
    labels = np.arange(n, dtype=np.int32)
    src = jnp.asarray([e[0] for e in edges], dtype=jnp.int32)
    dst = jnp.asarray([e[1] for e in edges], dtype=jnp.int32)
    lab = jnp.asarray(labels)
    for _ in range(64):
        lab, changed = model.contour_iter(lab, src, dst, hops=2,
                                          use_pallas=False, combine="sort")
        if int(changed) == 0:
            break
    np.testing.assert_array_equal(
        np.asarray(lab), ref.connected_components_ref(n, edges)
    )


def test_unknown_combine_rejected():
    labels, src, dst = _case(1, 8, 4)
    with pytest.raises(ValueError):
        model.contour_iter(labels, src, dst, combine="nope")
