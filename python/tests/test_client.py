"""End-to-end test of the Arkouda-style integration: start the Rust
server (`contour serve`), drive it from the Python client, and check the
answers against python-side ground truth. Skips when the release binary
has not been built yet."""

import pathlib
import socket
import subprocess
import sys
import time

import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "client"))
from contour_client import ContourClient, ContourError  # noqa: E402

from compile.kernels.ref import connected_components_ref  # noqa: E402

REPO = pathlib.Path(__file__).resolve().parents[2]
BINARY = REPO / "target" / "release" / "contour"
PORT = 39741


@pytest.fixture(scope="module")
def server():
    if not BINARY.exists():
        pytest.skip("release binary not built (cargo build --release)")
    proc = subprocess.Popen(
        [str(BINARY), "serve", "--addr", f"127.0.0.1:{PORT}"],
        cwd=REPO,
        stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL,
    )
    # Wait for the port to open.
    for _ in range(100):
        try:
            socket.create_connection(("127.0.0.1", PORT), timeout=0.2).close()
            break
        except OSError:
            if proc.poll() is not None:
                pytest.skip("server binary exited (no `serve` subcommand?)")
            time.sleep(0.1)
    else:
        proc.kill()
        pytest.skip("server did not come up")
    yield proc
    proc.terminate()
    proc.wait(timeout=10)


def test_ping_and_generate(server):
    with ContourClient(port=PORT) as c:
        assert c.ping()
        n, m = c.gen("t1", "path:100")
        assert (n, m) == (100, 99)
        comps, iters, ms = c.graph_cc("t1", "C-2")
        assert comps == 1
        assert iters >= 1
        assert ms >= 0.0


def test_frontier_modes_agree(server):
    with ContourClient(port=PORT) as c:
        c.gen("fm", "er:500:900")
        base, _, _ = c.graph_cc("fm", "C-2")
        for mode in ("exact", "chunk", "off"):
            comps, iters, _ = c.graph_cc("fm", "C-2", frontier=mode)
            assert comps == base, f"{mode} changed the component count"
            assert iters >= 1
        with pytest.raises(ValueError):
            c.graph_cc("fm", "C-2", frontier="sideways")
        m = c.metrics()
        assert "frontier_exact" in m
        assert "frontier_activations" in m
        assert "frontier_full_sweeps" in m


def test_upload_matches_ground_truth(server):
    import numpy as np

    rng = np.random.default_rng(5)
    n, m = 200, 300
    edges = [(int(rng.integers(0, n)), int(rng.integers(0, n))) for _ in range(m)]
    # Force vertex n-1 to exist so the universe size matches.
    edges.append((n - 1, n - 1))
    want = connected_components_ref(n, edges)
    with ContourClient(port=PORT) as c:
        c.upload("up", edges)
        labels = c.labels("up", "ConnectIt")
        assert labels == list(want)
        comps, _, _ = c.graph_cc("up", "C-m")
        assert comps == len(set(want))
        c.drop("up")


def test_stats_and_metrics(server):
    with ContourClient(port=PORT) as c:
        c.gen("s1", "star:50")
        st = c.stats("s1")
        assert st["n"] == 50 and st["m"] == 49
        assert st["components"] == 1
        assert st["diameter"] == 2
        metrics = c.metrics()
        assert metrics["requests"] > 0
        assert metrics["errors"] >= 0


def test_error_paths(server):
    with ContourClient(port=PORT) as c:
        with pytest.raises(ContourError):
            c.graph_cc("missing-graph")
        with pytest.raises(ContourError):
            c.gen("bad", "nosuchgen:10")


def test_streaming_session(server):
    import tempfile

    with ContourClient(port=PORT) as c:
        n, epoch = c.stream("live", 100)
        assert (n, epoch) == (100, 0)
        added, _ = c.stream_add("live", [(0, 1), (1, 2), (10, 11)])
        assert added == 3
        # Epoch 0 predates the batch; sealing publishes it.
        assert not c.same_comp("live", 0, 2, epoch=0)
        epoch, comps = c.stream_epoch("live")
        assert epoch == 1
        assert comps == 100 - 3  # three merges
        assert c.same_comp("live", 0, 2)
        assert c.comp_size("live", 1) == 3
        assert c.comp_size("live", 10) == 2
        assert c.num_comps("live") == comps
        assert c.num_comps("live", epoch=0) == 100
        assert c.stream_label("live", 2) == 0
        assert c.stream_add("live", []) == (0, 1)  # empty batch is a no-op
        # Durability round trip through SSAVE/SLOAD (the server reads
        # and writes the path, so it just needs to be shared-host).
        with tempfile.TemporaryDirectory(prefix="contour_client_") as td:
            snap = f"{td}/live.snap"
            assert c.stream_save("live", snap) == 1
            n2, epoch2 = c.stream_load("live_restored", snap)
            assert n2 == 100 and epoch2 > 1
            assert c.same_comp("live_restored", 0, 2)
        # Deletions decrement the multiset and publish at the next seal.
        removed, _ = c.stream_delete("live", [(1, 2)])
        assert removed == 1
        assert c.same_comp("live", 0, 2)  # last sealed epoch still answers
        epoch, comps = c.stream_epoch("live")
        assert epoch == 2
        assert comps == 100 - 2
        assert not c.same_comp("live", 0, 2)
        assert c.same_comp("live", 0, 1)
        assert c.stream_delete("live", []) == (0, 2)  # empty batch is a no-op
        with pytest.raises(ContourError):
            c.stream_delete("live", [(1, 2)])  # no longer live
        c.drop("live")
        c.drop("live_restored")

    with ContourClient(port=PORT) as c:
        with pytest.raises(ContourError):
            c.stream_add("nosuchstream", [(0, 1)])


def test_labels_paging(server):
    with ContourClient(port=PORT) as c:
        c.gen("pg", "path:50")
        total, page = c.labels_page("pg", "C-2", offset=10, count=5)
        assert total == 50
        assert page == [0] * 5
        assert c.all_labels("pg", page_size=7) == [0] * 50
        c.drop("pg")


def test_sharded_connectivity(server):
    with ContourClient(port=PORT) as c:
        c.gen("sg", "er:500:900")
        with pytest.raises(ContourError):
            c.pcc("sg")  # not sharded yet
        shards, boundary = c.shard("sg", 4)
        assert shards == 4 and boundary >= 0
        comps, iters, ms = c.pcc("sg", "C-2")
        want, _, _ = c.graph_cc("sg", "C-2")
        assert comps == want
        assert iters >= 1 and ms >= 0.0
        st = c.shard_stats("sg")
        assert st["p"] == 4 and st["n"] == 500
        assert len(st["shards"]) == 4
        assert st["m"] == sum(s["m"] for s in st["shards"]) + st["boundary"]
        assert any(name == "shard/sg" for name, _, _ in c.list_graphs())
        c.drop("sg")
        with pytest.raises(ContourError):
            c.shard_stats("sg")


def test_stream_labels_and_cache_metrics(server):
    with ContourClient(port=PORT) as c:
        c.stream("lcache", 6)
        c.stream_add("lcache", [(0, 1), (2, 3)])
        epoch, _ = c.stream_epoch("lcache")
        total, labels = c.stream_labels_page("lcache", epoch=epoch)
        assert total == 6
        assert labels == [0, 0, 2, 2, 4, 5]
        # Second page of the same epoch is served from the labels cache.
        assert c.stream_labels_page("lcache", epoch=epoch) == (total, labels)
        metrics = c.metrics()
        assert "cache/stream/lcache" in metrics
        hits, misses = (int(x) for x in metrics["cache/stream/lcache"].split(":"))
        assert hits >= 1 and misses >= 1
        c.drop("lcache")


def test_multiple_clients(server):
    with ContourClient(port=PORT) as a, ContourClient(port=PORT) as b:
        a.gen("shared", "soup:3:20")
        # The second client sees the first client's graph (shared store).
        comps, _, _ = b.graph_cc("shared", "auto")
        assert comps == 3
        names = [g[0] for g in b.list_graphs()]
        assert "shared" in names
