"""L2 correctness: iteration graphs vs references, convergence properties,
and the paper's theorems checked as executable properties.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _random_graph(rng, n, m):
    src = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    dst = jnp.asarray(rng.integers(0, n, m), dtype=jnp.int32)
    return src, dst


def _path_edges(n, pad_to=None):
    src = list(range(n - 1))
    dst = list(range(1, n))
    if pad_to:
        src += [0] * (pad_to - len(src))
        dst += [0] * (pad_to - len(dst))
    return jnp.asarray(src, dtype=jnp.int32), jnp.asarray(dst, dtype=jnp.int32)


# ------------------------------------------------------------ contour_iter


@pytest.mark.parametrize("hops", [1, 2, 4])
@pytest.mark.parametrize("use_pallas", [True, False])
def test_contour_iter_matches_ref(hops, use_pallas):
    rng = np.random.default_rng(42 + hops)
    n, m = 64, 128
    labels = jnp.asarray(np.minimum(rng.integers(0, n, n), np.arange(n)), dtype=jnp.int32)
    src, dst = _random_graph(rng, n, m)
    got, changed = model.contour_iter(labels, src, dst, hops=hops, use_pallas=use_pallas)
    want = ref.contour_iter_ref(labels, src, dst, hops)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert bool(changed) == bool((np.asarray(got) != np.asarray(labels)).any())


@settings(max_examples=25, deadline=None)
@given(n=st.integers(2, 128), m=st.integers(1, 256), hops=st.integers(1, 4),
       seed=st.integers(0, 2**31))
def test_contour_iter_property(n, m, hops, seed):
    rng = np.random.default_rng(seed)
    labels = jnp.asarray(np.minimum(rng.integers(0, n, n), np.arange(n)), dtype=jnp.int32)
    src, dst = _random_graph(rng, n, m)
    got, _ = model.contour_iter(labels, src, dst, hops=hops, use_pallas=False)
    want = ref.contour_iter_ref(labels, src, dst, hops)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Labels never increase (minimum-mapping is monotone).
    assert (np.asarray(got) <= np.asarray(labels)).all()


def test_contour_iter_pallas_jnp_identical():
    """The Pallas kernel path and the pure-jnp path lower to the same math."""
    rng = np.random.default_rng(3)
    n, m = 256, 512
    labels = jnp.arange(n, dtype=jnp.int32)
    src, dst = _random_graph(rng, n, m)
    a, ca = model.contour_iter(labels, src, dst, hops=2, use_pallas=True)
    b, cb = model.contour_iter(labels, src, dst, hops=2, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(ca) == int(cb)


def test_contour_iter_converged_graph_reports_no_change():
    labels = jnp.asarray([0, 0, 0, 3, 3], dtype=jnp.int32)
    src = jnp.asarray([0, 1, 3], dtype=jnp.int32)
    dst = jnp.asarray([1, 2, 4], dtype=jnp.int32)
    out, changed = model.contour_iter(labels, src, dst, hops=2, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(labels))
    assert int(changed) == 0


def test_padding_edges_are_neutral():
    """(0,0) padding self-loops must not alter any real label."""
    n = 16
    src, dst = _path_edges(8, pad_to=32)
    labels = jnp.arange(n, dtype=jnp.int32)
    lab, _ = model.contour_run(labels, src, dst, hops=2, use_pallas=False)
    lab = np.asarray(lab)
    assert (lab[:8] == 0).all()
    assert (lab[8:] == np.arange(8, 16)).all()


# ------------------------------------------------------------- contour_run


@pytest.mark.parametrize("hops", [1, 2])
@pytest.mark.parametrize("topo", ["path", "random", "two_comps"])
def test_contour_run_finds_components(hops, topo):
    n = 64
    rng = np.random.default_rng(hash(topo) % 2**31)
    if topo == "path":
        src, dst = _path_edges(n)
        edges = list(zip(np.asarray(src), np.asarray(dst)))
    elif topo == "random":
        src, dst = _random_graph(rng, n, 96)
        edges = list(zip(np.asarray(src), np.asarray(dst)))
    else:
        src = jnp.asarray(list(range(0, 31)) + list(range(32, 63)), dtype=jnp.int32)
        dst = jnp.asarray(list(range(1, 32)) + list(range(33, 64)), dtype=jnp.int32)
        edges = list(zip(np.asarray(src), np.asarray(dst)))
    labels = jnp.arange(n, dtype=jnp.int32)
    lab, iters = model.contour_run(labels, src, dst, hops=hops, use_pallas=False)
    want = ref.connected_components_ref(n, edges)
    np.testing.assert_array_equal(np.asarray(lab), want)
    assert int(iters) >= 1


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 96), m=st.integers(1, 192), seed=st.integers(0, 2**31))
def test_contour_run_property_vs_union_find(n, m, seed):
    rng = np.random.default_rng(seed)
    src, dst = _random_graph(rng, n, m)
    edges = list(zip(np.asarray(src), np.asarray(dst)))
    labels = jnp.arange(n, dtype=jnp.int32)
    lab, _ = model.contour_run(labels, src, dst, hops=2, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(lab), ref.connected_components_ref(n, edges))


def test_theorem1_iteration_bound_on_paths():
    """Theorem 1: MM^2 converges within ceil(log_1.5(d)) + 1 iterations.
    A path of n vertices has diameter n-1 — the adversarial case."""
    for n in (2, 3, 5, 17, 64, 200):
        _, iters = ref.contour_run_ref(n, [(i, i + 1) for i in range(n - 1)], hops=2)
        bound = int(np.ceil(np.log(max(n - 1, 2)) / np.log(1.5))) + 1
        # +1: our count includes the final no-change detection pass.
        assert iters <= bound + 1, (n, iters, bound)


def test_contour_run_respects_max_iters():
    n = 64
    src, dst = _path_edges(n)
    labels = jnp.arange(n, dtype=jnp.int32)
    lab, iters = model.contour_run(labels, src, dst, hops=1, max_iters=2, use_pallas=False)
    assert int(iters) == 2
    assert (np.asarray(lab) != 0).any()  # genuinely truncated


# ------------------------------------------------------------- fastsv_iter


def test_fastsv_matches_ref():
    rng = np.random.default_rng(5)
    n, m = 64, 128
    labels = jnp.asarray(np.minimum(rng.integers(0, n, n), np.arange(n)), dtype=jnp.int32)
    src, dst = _random_graph(rng, n, m)
    got, _ = model.fastsv_iter(labels, src, dst)
    want = ref.fastsv_iter_ref(labels, src, dst)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 64), m=st.integers(1, 128), seed=st.integers(0, 2**31))
def test_fastsv_converges_to_components(n, m, seed):
    rng = np.random.default_rng(seed)
    src, dst = _random_graph(rng, n, m)
    edges = list(zip(np.asarray(src), np.asarray(dst)))
    lab = jnp.arange(n, dtype=jnp.int32)
    for _ in range(4 * int(np.ceil(np.log2(n))) + 8):
        nxt, changed = model.fastsv_iter(lab, src, dst)
        if int(changed) == 0:
            break
        lab = nxt
    np.testing.assert_array_equal(np.asarray(lab), ref.connected_components_ref(n, edges))


# ----------------------------------------------------- compress + counting


def test_compress_to_stars():
    # Chain pointer graph 7->6->...->0: compression needs ceil(log2(7)) jumps.
    labels = jnp.asarray([0, 0, 1, 2, 3, 4, 5, 6], dtype=jnp.int32)
    lab, rounds = model.compress_to_stars(labels, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(lab), np.zeros(8, dtype=np.int32))
    assert 1 <= int(rounds) <= 3


def test_compress_pallas_matches_jnp():
    rng = np.random.default_rng(11)
    n = 64
    labels = jnp.asarray(np.minimum(rng.integers(0, n, n), np.arange(n)), dtype=jnp.int32)
    a, _ = model.compress_to_stars(labels, use_pallas=True)
    b, _ = model.compress_to_stars(labels, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_count_components():
    labels = jnp.asarray([0, 0, 0, 3, 3, 5], dtype=jnp.int32)
    assert int(model.count_components(labels)) == 3
    labels = jnp.arange(7, dtype=jnp.int32)
    assert int(model.count_components(labels)) == 7
