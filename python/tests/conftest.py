"""Make `compile` (and the client) importable whether pytest runs from
the repo root (`pytest python/tests/`) or from `python/` (the Makefile's
`cd python && pytest tests/`)."""

import pathlib
import sys

PKG_ROOT = pathlib.Path(__file__).resolve().parents[1]
for p in (PKG_ROOT, PKG_ROOT / "client"):
    if str(p) not in sys.path:
        sys.path.insert(0, str(p))
