"""Pure-jnp / pure-python correctness oracles for the L1 kernels and the
L2 iteration graphs.

Everything here is deliberately simple and independent of the Pallas code:
``python/tests`` asserts the kernels and models against these references.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def hop_min_ref(labels, src, dst, hops: int = 2):
    """Reference for minmap.hop_min: z[e] = min(L^h[src[e]], L^h[dst[e]])."""
    ls = labels[src]
    ld = labels[dst]
    for _ in range(hops - 1):
        ls = labels[ls]
        ld = labels[ld]
    return jnp.minimum(ls, ld)


def pointer_jump_ref(labels):
    """Reference for minmap.pointer_jump: L'[i] = L[L[i]]."""
    return labels[labels]


def scatter_min_ref(idx, val, init):
    """Reference for minmap.scatter_min (order-independent min combine)."""
    return init.at[idx].min(val)


def contour_iter_ref(labels, src, dst, hops: int = 2):
    """One synchronous Contour iteration (Alg. 1 body with MM^h).

    For each edge (w, v): z = min(L^h[w], L^h[v]) and the 2h touched
    vertices {w, v, L[w], L[v], ..., L^{h-1}[w], L^{h-1}[v]} are lowered
    to z if above it (Definition 2/3's conditional vector assignment).
    """
    z = hop_min_ref(labels, src, dst, hops)
    out = labels
    ls, ld = src, dst
    for _ in range(hops):
        out = out.at[ls].min(z).at[ld].min(z)
        ls = labels[ls]
        ld = labels[ld]
    return out


def fastsv_iter_ref(labels, src, dst):
    """Reference FastSV iteration (Zhang, Azad & Hu 2020), both edge
    directions: stochastic hooking, aggressive hooking, shortcutting."""
    f = labels
    gf = f[f]
    out = f
    # Stochastic hooking: f[f[u]] <- min(gf[v]); both directions.
    out = out.at[f[src]].min(gf[dst]).at[f[dst]].min(gf[src])
    # Aggressive hooking: f[u] <- min(gf[v]); both directions.
    out = out.at[src].min(gf[dst]).at[dst].min(gf[src])
    # Shortcutting: f[u] <- min(gf[u]).
    out = jnp.minimum(out, gf)
    return out


def connected_components_ref(n: int, edges) -> np.ndarray:
    """Ground-truth CC labels via union-find; label = min vertex id of the
    component (the fixed point the Contour algorithm converges to)."""
    parent = list(range(n))

    def find(x: int) -> int:
        root = x
        while parent[root] != root:
            root = parent[root]
        while parent[x] != root:
            parent[x], x = root, parent[x]
        return root

    for w, v in edges:
        rw, rv = find(int(w)), find(int(v))
        if rw != rv:
            parent[max(rw, rv)] = min(rw, rv)
    # Min-id canonical form: every root is already the min of its component
    # because unions always hang the larger id under the smaller one.
    return np.asarray([find(i) for i in range(n)], dtype=np.int32)


def contour_run_ref(n: int, edges, hops: int = 2, max_iters: int = 10_000):
    """Run synchronous Contour to convergence in numpy; returns (L, iters).

    ``iters`` counts the convergence-detecting iteration too, matching the
    do/while in Alg. 1 (an extra no-change pass is what terminates it).
    """
    labels = np.arange(n, dtype=np.int32)
    if len(edges) == 0:
        return labels, 1
    src = jnp.asarray([e[0] for e in edges], dtype=jnp.int32)
    dst = jnp.asarray([e[1] for e in edges], dtype=jnp.int32)
    for it in range(1, max_iters + 1):
        nxt = np.asarray(contour_iter_ref(jnp.asarray(labels), src, dst, hops))
        if np.array_equal(nxt, labels):
            return labels, it
        labels = nxt
    raise RuntimeError("contour_run_ref did not converge")
