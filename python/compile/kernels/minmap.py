"""L1 Pallas kernels for the Contour minimum-mapping operator.

The paper's per-edge hot spot is the h-order minimum-mapping operator
MM^h (Definition 3): for an edge (w, v) compute

    z^h = min(L^h[w], L^h[v]),   L^h[x] = L[L^{h-1}[x]]

and conditionally lower the labels of the 2h touched vertices to z^h.

On a TPU this splits into two phases (see DESIGN.md §Hardware-Adaptation):

1. ``hop_min``      — per-edge gather chain + elementwise min. Pure
                      gather/VPU work, tiled over edge blocks with the label
                      array resident in VMEM. This is the Pallas kernel.
2. scatter-min      — the conditional-vector-assignment combine. Left to
                      XLA's native ``scatter`` (deterministic min combiner)
                      in the L2 graph; a serial in-kernel variant
                      (``scatter_min``) exists for comparison/ablation.

All kernels run with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute. Correctness is checked
against the pure-jnp oracles in ``ref.py`` by ``python/tests``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default edge-block size: 2048 edges x 4 B x 3 vectors (src, dst, out) plus
# the resident label block keeps VMEM usage ~(n*4 + 24 KiB) per grid step.
DEFAULT_EDGE_BLOCK = 2048


def _hop_min_kernel(l_ref, src_ref, dst_ref, z_ref, *, hops: int):
    """Per-edge-block kernel: z[e] = min(L^h[src[e]], L^h[dst[e]]).

    ``l_ref`` holds the full label array (one VMEM-resident block); the edge
    arrays are streamed block by block via the grid.
    """
    labels = l_ref[...]
    ls = jnp.take(labels, src_ref[...], mode="clip")
    ld = jnp.take(labels, dst_ref[...], mode="clip")
    # Each extra hop follows one more pointer: L^k[x] = L[L^{k-1}[x]].
    for _ in range(hops - 1):
        ls = jnp.take(labels, ls, mode="clip")
        ld = jnp.take(labels, ld, mode="clip")
    z_ref[...] = jnp.minimum(ls, ld)


def hop_min(labels, src, dst, hops: int = 2, edge_block: int | None = None):
    """z[e] = min(L^hops[src[e]], L^hops[dst[e]]) for every edge, via Pallas.

    Args:
      labels: int32[n] current label array.
      src, dst: int32[m] edge endpoints (padding edges may be (0, 0)).
      hops: the operator order h >= 1.
      edge_block: edges per grid step (defaults to min(m, DEFAULT_EDGE_BLOCK)).

    Returns:
      int32[m] per-edge minimum z^h.
    """
    if hops < 1:
        raise ValueError(f"hops must be >= 1, got {hops}")
    (m,) = src.shape
    (n,) = labels.shape
    bm = edge_block or min(m, DEFAULT_EDGE_BLOCK)
    if m % bm != 0:
        raise ValueError(f"edge count {m} not divisible by block {bm}")
    return pl.pallas_call(
        functools.partial(_hop_min_kernel, hops=hops),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),  # labels: resident
            pl.BlockSpec((bm,), lambda i: (i,)),  # src: streamed
            pl.BlockSpec((bm,), lambda i: (i,)),  # dst: streamed
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), labels.dtype),
        interpret=True,
    )(labels, src, dst)


def _pointer_jump_kernel(l_ref, out_ref):
    """Vertex-block kernel: out[i] = L[L[i]] (one round of compression)."""
    labels = l_ref[...]
    blk = out_ref.shape[0]
    i = pl.program_id(0)
    mine = jax.lax.dynamic_slice(labels, (i * blk,), (blk,))
    out_ref[...] = jnp.take(labels, mine, mode="clip")


def pointer_jump(labels, vertex_block: int | None = None):
    """One pointer-jumping round: L'[i] = L[L[i]], via Pallas.

    This is the tree-compression step of §II-C effect (1), used by the
    star-compression routine that finalizes the pointer graph.
    """
    (n,) = labels.shape
    bn = vertex_block or min(n, DEFAULT_EDGE_BLOCK)
    if n % bn != 0:
        raise ValueError(f"vertex count {n} not divisible by block {bn}")
    return pl.pallas_call(
        _pointer_jump_kernel,
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((n,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), labels.dtype),
        interpret=True,
    )(labels)


def _scatter_min_kernel(idx_ref, val_ref, init_ref, out_ref):
    """Serial conditional-vector-assignment: out[idx[e]] min= val[e].

    Single-block ablation variant of the combine phase (the production path
    uses XLA's native scatter-min; see module docstring). The fori_loop is
    the in-kernel analog of the paper's CAS loop (Eq. 4), made race-free by
    serialization instead of atomics.
    """
    idx = idx_ref[...]
    val = val_ref[...]

    def body(e, acc):
        return acc.at[idx[e]].min(val[e])

    out_ref[...] = jax.lax.fori_loop(0, idx.shape[0], body, init_ref[...])


def scatter_min(idx, val, init):
    """out = init, then out[idx[e]] = min(out[idx[e]], val[e]) serially."""
    (n,) = init.shape
    (m,) = idx.shape
    return pl.pallas_call(
        _scatter_min_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), init.dtype),
        interpret=True,
    )(idx, val, init)
