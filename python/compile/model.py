"""L2: whole-graph Contour / FastSV iteration graphs in JAX.

Each public function here is a jit-able computation over fixed (n, m)
shapes; ``aot.py`` lowers them to HLO text for the Rust runtime. The hot
per-edge phase calls the L1 Pallas kernels in ``kernels.minmap``; the
conditional-vector-assignment combine uses XLA's native scatter-min
(race-free by construction — the TPU formulation of the paper's CAS loop,
see DESIGN.md §Hardware-Adaptation).

Conventions (shared with rust/src/runtime):
  * labels      int32[n]  — L array; padding vertices carry their own id.
  * src, dst    int32[m]  — edge endpoints; padding edges are (0, 0)
                            self-loops, which are correctness-neutral
                            (a self-loop only applies compression).
  * every iteration returns (labels', changed:int32) where changed != 0
    iff any label moved.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import minmap


def _scatter_targets(labels, src, dst, hops: int):
    """The 2h vertices MM^h conditionally assigns: w, v, L[w], L[v], ...,
    L^{h-1}[w], L^{h-1}[v] (Definition 3)."""
    targets = []
    ls, ld = src, dst
    for _ in range(hops):
        targets.append(ls)
        targets.append(ld)
        ls = labels[ls]
        ld = labels[ld]
    return targets


def contour_iter(labels, src, dst, *, hops: int = 2, use_pallas: bool = True,
                 combine: str = "scatter"):
    """One synchronous Contour iteration (Alg. 1 body with MM^hops).

    Returns (labels', changed). ``use_pallas=False`` swaps the L1 kernel for
    the pure-jnp gather chain (ablation; identical numerics).

    ``combine`` selects the conditional-vector-assignment implementation:

    * ``"scatter"`` — XLA scatter with a min combiner (default).
    * ``"sort"``    — the TPU-idiomatic alternative: sort the 2h·m
      (target, z) pairs by target, segmented-min via associative scan,
      then a *conflict-free* scatter of one minimum per unique target.
      Trades a sort for a collision-free memory pattern; numerics are
      identical (ablated in python/tests and `bench ablation`).
    """
    if use_pallas:
        z = minmap.hop_min(labels, src, dst, hops=hops)
    else:
        ls, ld = labels[src], labels[dst]
        for _ in range(hops - 1):
            ls, ld = labels[ls], labels[ld]
        z = jnp.minimum(ls, ld)
    targets = _scatter_targets(labels, src, dst, hops)
    if combine == "scatter":
        out = labels
        for t in targets:
            out = out.at[t].min(z)
    elif combine == "sort":
        out = _sorted_combine(labels, jnp.concatenate(targets),
                              jnp.tile(z, len(targets)))
    else:
        raise ValueError(f"unknown combine {combine!r}")
    changed = jnp.any(out != labels).astype(jnp.int32)
    return out, changed


def _sorted_combine(labels, idx, val):
    """min-combine (idx, val) pairs into ``labels`` without write
    conflicts: sort by index, segmented min-scan, keep each segment's
    last (= full-segment) minimum, scatter-min those unique slots."""
    order = jnp.argsort(idx)
    sidx = idx[order]
    sval = val[order]
    # Segmented min via associative scan: (start_flag, min) pairs.
    starts = jnp.concatenate(
        [jnp.ones((1,), jnp.bool_), sidx[1:] != sidx[:-1]]
    )

    def seg_min(a, b):
        a_flag, a_min = a
        b_flag, b_min = b
        return (
            jnp.logical_or(b_flag, a_flag),
            jnp.where(b_flag, b_min, jnp.minimum(a_min, b_min)),
        )

    _, run_min = jax.lax.associative_scan(seg_min, (starts, sval))
    # A segment's total min sits at its last element.
    ends = jnp.concatenate([sidx[1:] != sidx[:-1], jnp.ones((1,), jnp.bool_)])
    # Conflict-free: route non-end lanes to a dummy slot (their own index
    # holds a value >= the end lane's min, so a min-scatter is harmless —
    # but unique=True semantics hold because each target's end lane is
    # unique).
    out = labels.at[jnp.where(ends, sidx, sidx)].min(
        jnp.where(ends, run_min, jnp.iinfo(labels.dtype).max)
    )
    return out


def contour_run(labels, src, dst, *, hops: int = 2, max_iters: int = 64,
                use_pallas: bool = True):
    """Full on-device convergence loop: iterate MM^hops until no label
    changes (or ``max_iters``). Returns (labels, iters).

    By Theorem 1 the loop needs at most ceil(log_1.5 d_max) + 1 iterations,
    so ``max_iters=64`` covers any graph that fits in memory. The loop
    carries only (L, changed, k); XLA keeps L donated in-place.
    """

    def cond(state):
        _, changed, k = state
        return jnp.logical_and(changed != 0, k < max_iters)

    def body(state):
        lab, _, k = state
        nxt, changed = contour_iter(lab, src, dst, hops=hops, use_pallas=use_pallas)
        return nxt, changed, k + 1

    init = (labels, jnp.int32(1), jnp.int32(0))
    lab, _, iters = jax.lax.while_loop(cond, body, init)
    return lab, iters


def fastsv_iter(labels, src, dst):
    """One FastSV iteration (Zhang, Azad & Hu 2020): stochastic hooking,
    aggressive hooking, shortcutting — each a scatter-min/gather round.
    The baseline the paper's Figs. 1-3 compare against. Returns
    (labels', changed)."""
    f = labels
    gf = f[f]
    out = f
    out = out.at[f[src]].min(gf[dst]).at[f[dst]].min(gf[src])  # stochastic
    out = out.at[src].min(gf[dst]).at[dst].min(gf[src])        # aggressive
    out = jnp.minimum(out, gf)                                 # shortcut
    changed = jnp.any(out != labels).astype(jnp.int32)
    return out, changed


def compress_to_stars(labels, *, max_iters: int = 64, use_pallas: bool = True):
    """Pointer-jump L <- L[L] until the pointer graph is a forest of stars
    (L == L[L]). Used to canonicalize partial results. Returns (labels,
    rounds)."""

    def jump(lab):
        return minmap.pointer_jump(lab) if use_pallas else lab[lab]

    def cond(state):
        lab, k = state
        return jnp.logical_and(jnp.any(jump(lab) != lab), k < max_iters)

    def body(state):
        lab, k = state
        return jump(lab), k + 1

    lab, rounds = jax.lax.while_loop(cond, body, (labels, jnp.int32(0)))
    return lab, rounds


def count_components(labels):
    """Number of stars in a converged pointer graph: |{i : L[i] == i}|.
    Padding vertices count as singletons; the Rust side subtracts them."""
    n = labels.shape[0]
    return jnp.sum(labels == jnp.arange(n, dtype=labels.dtype)).astype(jnp.int32)
