"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Run once via ``make artifacts`` (never on the request path):

    python -m compile.aot --out-dir ../artifacts

Every artifact is a self-contained HLO module specialized to one
(n, m) size bucket; the Rust runtime picks the smallest bucket that fits
the live graph and pads (padding vertices are self-labelled, padding edges
are (0,0) self-loops — both correctness-neutral; see model.py).

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (n, m) size buckets. Kept in sync with rust/src/runtime/registry.rs.
BUCKETS = [
    (1_024, 4_096),
    (16_384, 65_536),
    (262_144, 1_048_576),
]
QUICK_BUCKETS = BUCKETS[:1]

MAX_ITERS = 64  # Theorem 1: ceil(log_1.5 d_max)+1; 64 covers d_max ~ 2^37.


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _lab(n):
    return jax.ShapeDtypeStruct((n,), jnp.int32)


def _edges(m):
    return jax.ShapeDtypeStruct((m,), jnp.int32)


def artifact_set(n: int, m: int):
    """All (name, fn, example_args) triples for one size bucket."""
    sets = []
    for hops in (1, 2, 4):
        sets.append(
            (
                f"contour_iter_h{hops}",
                functools.partial(model.contour_iter, hops=hops),
                (_lab(n), _edges(m), _edges(m)),
            )
        )
    # Full on-device convergence loops for the default operator orders.
    for hops in (1, 2):
        sets.append(
            (
                f"contour_run_h{hops}",
                functools.partial(model.contour_run, hops=hops, max_iters=MAX_ITERS),
                (_lab(n), _edges(m), _edges(m)),
            )
        )
    sets.append(("fastsv_iter", model.fastsv_iter, (_lab(n), _edges(m), _edges(m))))
    return sets


def vertex_artifact_set(n: int):
    """Artifacts that only depend on n."""
    return [
        ("compress", functools.partial(model.compress_to_stars, max_iters=MAX_ITERS), (_lab(n),)),
        ("count_components", model.count_components, (_lab(n),)),
    ]


def emit(out_dir: str, quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    buckets = QUICK_BUCKETS if quick else BUCKETS
    manifest = []
    for n, m in buckets:
        for name, fn, args in artifact_set(n, m):
            fname = f"{name}_n{n}_m{m}.hlo.txt"
            text = to_hlo_text(jax.jit(fn).lower(*args))
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest.append(f"{name} n={n} m={m} file={fname}")
            print(f"  wrote {fname} ({len(text)} chars)")
    for n, _ in buckets:
        for name, fn, args in vertex_artifact_set(n):
            fname = f"{name}_n{n}.hlo.txt"
            text = to_hlo_text(jax.jit(fn).lower(*args))
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest.append(f"{name} n={n} m=0 file={fname}")
            print(f"  wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {len(manifest)} artifacts to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="smallest bucket only")
    args = ap.parse_args()
    emit(args.out_dir, quick=args.quick)


if __name__ == "__main__":
    main()
