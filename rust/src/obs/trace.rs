//! Bounded span recorder with Chrome trace-event export.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Default span capacity per trace. A Contour run emits one span per
/// pass plus a handful of setup/finalize spans; a sharded run adds one
/// per shard. 8192 covers every realistic run while bounding a
/// pathological one (spans past the cap are counted, not stored).
pub const DEFAULT_SPAN_CAP: usize = 8192;

/// One completed span: a named interval on a logical track.
///
/// Times are nanoseconds relative to the owning [`RunTrace`]'s origin
/// (its creation instant), which keeps them small, monotonic, and
/// serializable without a wall-clock dependency.
#[derive(Clone, Debug)]
pub struct Span {
    /// Display name ("pass3", "shard1", "merge", ...).
    pub name: String,
    /// Category for trace viewers ("contour", "pcc", "pool", ...).
    pub cat: &'static str,
    /// One-word qualifier — for pass spans this is the executed mode
    /// ("full" / "chunk" / "exact"); empty when not applicable.
    pub detail: &'static str,
    /// Logical track id: 0 is the driver, sharded runs put shard `k`
    /// on track `k + 1`.
    pub tid: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Small numeric payload (pass index, chunks skipped, labels
    /// lowered, ...), rendered into the trace viewer's args pane.
    pub args: Vec<(&'static str, u64)>,
}

impl Span {
    /// The value of a named arg, if present.
    pub fn arg(&self, key: &str) -> Option<u64> {
        self.args.iter().find(|(k, _)| *k == key).map(|&(_, v)| v)
    }
}

/// A bounded recorder of [`Span`]s for one run.
///
/// One `RunTrace` is shared (via `Arc`) by every layer participating in
/// a run: the algorithm core pushes pass spans, the shard executor
/// pushes shard/merge spans on their own tracks, the CLI serializes the
/// result. Recording takes a short mutex — spans are pushed once per
/// pass or per shard, never per edge, so contention is nil. Callers
/// gate on `Option<&RunTrace>`, making tracing-off cost one branch.
#[derive(Debug)]
pub struct RunTrace {
    origin: Instant,
    spans: Mutex<Vec<Span>>,
    tid_names: Mutex<Vec<(u32, String)>>,
    dropped: AtomicU64,
    cap: usize,
}

impl Default for RunTrace {
    fn default() -> Self {
        Self::new()
    }
}

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // A panicking recorder thread must not take the trace down with it;
    // span data is append-only so a poisoned guard is still coherent.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl RunTrace {
    pub fn new() -> Self {
        Self::with_cap(DEFAULT_SPAN_CAP)
    }

    pub fn with_cap(cap: usize) -> Self {
        Self {
            origin: Instant::now(),
            spans: Mutex::new(Vec::new()),
            tid_names: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
            cap,
        }
    }

    /// Nanoseconds since this trace was created — the timebase every
    /// span's `start_ns` is expressed in.
    #[inline]
    pub fn now(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    /// Record a completed span. Past the capacity the span is dropped
    /// and counted, so a runaway pass loop cannot exhaust memory.
    pub fn push(&self, span: Span) {
        let mut spans = lock(&self.spans);
        if spans.len() >= self.cap {
            drop(spans);
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        spans.push(span);
    }

    /// Close out a span that began at `start_ns` (from [`Self::now`]):
    /// duration is measured here, then the span is recorded.
    pub fn close(
        &self,
        name: String,
        cat: &'static str,
        detail: &'static str,
        tid: u32,
        start_ns: u64,
        args: Vec<(&'static str, u64)>,
    ) {
        let dur_ns = self.now().saturating_sub(start_ns);
        self.push(Span { name, cat, detail, tid, start_ns, dur_ns, args });
    }

    /// Give a logical track a display name ("driver", "shard 0", ...).
    pub fn name_tid(&self, tid: u32, name: &str) {
        let mut names = lock(&self.tid_names);
        if let Some(slot) = names.iter_mut().find(|(t, _)| *t == tid) {
            slot.1 = name.to_string();
        } else {
            names.push((tid, name.to_string()));
        }
    }

    pub fn len(&self) -> usize {
        lock(&self.spans).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Spans dropped past the capacity bound.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Snapshot of all recorded spans, in recording order.
    pub fn spans(&self) -> Vec<Span> {
        lock(&self.spans).clone()
    }

    /// One-line wire form for the server's `TRACE` verb:
    /// `n=<len> dropped=<d> <span> <span> ...` where each span is
    /// `name|cat|detail|tid|start_ns|dur_ns[|k=v,k=v]`. Fields never
    /// contain spaces or `|`, so the line splits on whitespace then `|`.
    pub fn render_wire(&self) -> String {
        let spans = lock(&self.spans);
        let mut out = format!("n={} dropped={}", spans.len(), self.dropped());
        for s in spans.iter() {
            out.push(' ');
            out.push_str(&format!(
                "{}|{}|{}|{}|{}|{}",
                s.name, s.cat, s.detail, s.tid, s.start_ns, s.dur_ns
            ));
            if !s.args.is_empty() {
                let kv: Vec<String> = s.args.iter().map(|(k, v)| format!("{k}={v}")).collect();
                out.push('|');
                out.push_str(&kv.join(","));
            }
        }
        out
    }

    /// Serialize as Chrome trace-event JSON (the `traceEvents` format
    /// Perfetto and `chrome://tracing` load directly). Spans become
    /// complete (`"ph":"X"`) events with microsecond timestamps;
    /// process/track names ride along as `"M"` metadata events.
    pub fn to_chrome_json(&self, process_name: &str) -> String {
        let spans = lock(&self.spans);
        let names = lock(&self.tid_names);
        let mut events: Vec<String> = Vec::with_capacity(spans.len() + names.len() + 2);
        events.push(format!(
            "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":1,\"tid\":0,\
             \"args\":{{\"name\":{}}}}}",
            json_str(process_name)
        ));
        for (tid, name) in names.iter() {
            events.push(format!(
                "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":1,\"tid\":{tid},\
                 \"args\":{{\"name\":{}}}}}",
                json_str(name)
            ));
        }
        for s in spans.iter() {
            let mut args = String::new();
            if !s.detail.is_empty() {
                args.push_str(&format!("\"mode\":{}", json_str(s.detail)));
            }
            for (k, v) in &s.args {
                if !args.is_empty() {
                    args.push(',');
                }
                args.push_str(&format!("{}:{v}", json_str(k)));
            }
            events.push(format!(
                "{{\"ph\":\"X\",\"name\":{},\"cat\":{},\"pid\":1,\"tid\":{},\
                 \"ts\":{:.3},\"dur\":{:.3},\"args\":{{{args}}}}}",
                json_str(&s.name),
                json_str(s.cat),
                s.tid,
                s.start_ns as f64 / 1e3,
                s.dur_ns as f64 / 1e3,
            ));
        }
        let dropped = self.dropped();
        if dropped > 0 {
            events.push(format!(
                "{{\"ph\":\"M\",\"name\":\"dropped_spans\",\"pid\":1,\"tid\":0,\
                 \"args\":{{\"count\":{dropped}}}}}"
            ));
        }
        format!("{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{}]}}", events.join(","))
    }
}

/// Minimal JSON string escape — names here are ASCII identifiers, but a
/// graph name from the wire could hold anything.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunTrace {
        let t = RunTrace::new();
        t.name_tid(0, "driver");
        let s0 = t.now();
        t.close("pass0".to_string(), "contour", "full", 0, s0, vec![("pass", 0)]);
        t.close("pass1".to_string(), "contour", "exact", 0, s0, vec![("pass", 1), ("skipped", 7)]);
        t
    }

    #[test]
    fn records_and_snapshots_spans() {
        let t = sample();
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 0);
        let spans = t.spans();
        assert_eq!(spans[0].name, "pass0");
        assert_eq!(spans[1].detail, "exact");
        assert_eq!(spans[1].arg("skipped"), Some(7));
        assert_eq!(spans[1].arg("missing"), None);
        assert!(spans[1].start_ns >= spans[0].start_ns);
    }

    #[test]
    fn capacity_bound_counts_drops() {
        let t = RunTrace::with_cap(2);
        for i in 0..5 {
            t.close(format!("s{i}"), "test", "", 0, 0, vec![]);
        }
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 3);
        assert!(t.render_wire().starts_with("n=2 dropped=3 "));
    }

    #[test]
    fn wire_form_round_trips_fields() {
        let t = sample();
        let wire = t.render_wire();
        let toks: Vec<&str> = wire.split_whitespace().collect();
        assert_eq!(toks[0], "n=2");
        assert_eq!(toks[1], "dropped=0");
        let fields: Vec<&str> = toks[3].split('|').collect();
        assert_eq!(fields[0], "pass1");
        assert_eq!(fields[1], "contour");
        assert_eq!(fields[2], "exact");
        assert_eq!(fields[3], "0");
        assert_eq!(fields[6], "pass=1,skipped=7");
    }

    #[test]
    fn chrome_json_has_required_shape() {
        let t = sample();
        let json = t.to_chrome_json("contour run");
        assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(json.ends_with("]}"));
        assert!(json.contains("\"ph\":\"M\""));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"name\":\"pass1\""));
        assert!(json.contains("\"mode\":\"exact\""));
        assert!(json.contains("\"thread_name\""));
        // Balanced braces/brackets — cheap structural sanity without a
        // JSON parser in the dependency-free crate.
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes);
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn json_escape_handles_specials() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("x\ny"), "\"x\\ny\"");
        let t = RunTrace::new();
        t.close("ev\"il".to_string(), "test", "", 0, 0, vec![]);
        let json = t.to_chrome_json("p\"q");
        assert!(json.contains("\"name\":\"ev\\\"il\""));
        assert!(json.contains("{\"name\":\"p\\\"q\"}"));
    }

    #[test]
    fn tid_names_update_in_place() {
        let t = RunTrace::new();
        t.name_tid(1, "shard 0");
        t.name_tid(1, "shard zero");
        t.name_tid(2, "shard 1");
        let json = t.to_chrome_json("p");
        assert!(!json.contains("\"shard 0\""));
        assert!(json.contains("\"shard zero\""));
        assert!(json.contains("\"shard 1\""));
    }
}
