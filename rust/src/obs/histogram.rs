//! Lock-free log₂-bucketed latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: bucket 0 holds the value 0, bucket `b >= 1` holds
/// `[2^(b-1), 2^b)` nanoseconds, and the top bucket absorbs everything
/// from `2^62` up — 64 buckets cover the full `u64` range.
pub const BUCKETS: usize = 64;

/// A lock-free latency histogram with power-of-two buckets.
///
/// Values are nanoseconds by convention (everything the engine records
/// is a `Duration`), but nothing in here assumes a unit. Recording is
/// two relaxed `fetch_add`s plus a relaxed `fetch_max` — no locks, no
/// allocation — so it is safe on hot paths and from any thread.
/// Quantiles come from a bucket walk: within a bucket the reported
/// value is the bucket midpoint (exact to within 1.5× by construction,
/// which is ample for the p50/p95/p99 the `METRICS` verb renders).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; BUCKETS],
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// The bucket holding `v`: 0 for 0, else `64 - leading_zeros(v)`
    /// clamped into the array (so bucket `b` spans `[2^(b-1), 2^b)`).
    #[inline]
    fn bucket(v: u64) -> usize {
        (64 - v.leading_zeros() as usize).min(BUCKETS - 1)
    }

    /// Midpoint of bucket `b`'s range — the value a quantile landing in
    /// `b` reports.
    fn representative(b: usize) -> u64 {
        if b == 0 {
            return 0;
        }
        let low = 1u64 << (b - 1);
        low + low / 2
    }

    /// Record one value (nanoseconds by convention).
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[Self::bucket(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration as nanoseconds.
    #[inline]
    pub fn record_duration(&self, d: std::time::Duration) {
        self.record(d.as_nanos() as u64);
    }

    /// Raw per-bucket counts (relaxed reads). The telemetry ring stores
    /// these so windowed quantiles can be derived from count *deltas*
    /// via [`quantile_from_counts`] — a lifetime histogram cannot answer
    /// "p95 over the last minute", but the difference of two bucket
    /// vectors can.
    pub fn bucket_counts(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|b| self.buckets[b].load(Ordering::Relaxed))
    }

    /// A consistent-enough snapshot for rendering (buckets are read
    /// relaxed, so a concurrent recorder may be half-visible; counts
    /// only ever grow, so quantiles stay sane).
    pub fn snapshot(&self) -> HistogramSnapshot {
        let counts: [u64; BUCKETS] =
            std::array::from_fn(|b| self.buckets[b].load(Ordering::Relaxed));
        let count: u64 = counts.iter().sum();
        let max = self.max.load(Ordering::Relaxed);
        let quantile = |q: f64| -> u64 {
            if count == 0 {
                return 0;
            }
            let target = ((q * count as f64).ceil() as u64).clamp(1, count);
            let mut cum = 0u64;
            for (b, &c) in counts.iter().enumerate() {
                cum += c;
                if cum >= target {
                    // The top non-empty bucket's midpoint can overshoot
                    // the true maximum; clamp to the exact max tracked.
                    return Self::representative(b).min(max);
                }
            }
            max
        };
        HistogramSnapshot {
            count,
            sum: self.sum.load(Ordering::Relaxed),
            max,
            p50: quantile(0.50),
            p95: quantile(0.95),
            p99: quantile(0.99),
        }
    }
}

/// Quantile over a standalone bucket-count vector (same log₂ bucket
/// scheme as [`Histogram`]). Used on *deltas* of two
/// [`Histogram::bucket_counts`] snapshots to answer windowed quantiles;
/// with no exact max available, the top bucket reports its lower bound
/// rather than a midpoint that could overshoot by 1.5×.
pub fn quantile_from_counts(counts: &[u64], q: f64) -> u64 {
    let count: u64 = counts.iter().sum();
    if count == 0 {
        return 0;
    }
    let target = ((q * count as f64).ceil() as u64).clamp(1, count);
    let mut cum = 0u64;
    for (b, &c) in counts.iter().enumerate() {
        cum += c;
        if cum >= target {
            return if b + 1 == BUCKETS && b > 0 {
                1u64 << (b - 1)
            } else {
                Histogram::representative(b)
            };
        }
    }
    0
}

/// Plain-value view of a [`Histogram`] at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
}

impl HistogramSnapshot {
    /// The `METRICS` wire form: `count:p50:p95:p99` (nanoseconds).
    pub fn render(&self) -> String {
        format!("{}:{}:{}:{}", self.count, self.p50, self.p95, self.p99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        let s = h.snapshot();
        assert_eq!(s, HistogramSnapshot::default());
        assert_eq!(s.render(), "0:0:0:0");
    }

    #[test]
    fn buckets_are_log2() {
        assert_eq!(Histogram::bucket(0), 0);
        assert_eq!(Histogram::bucket(1), 1);
        assert_eq!(Histogram::bucket(2), 2);
        assert_eq!(Histogram::bucket(3), 2);
        assert_eq!(Histogram::bucket(4), 3);
        assert_eq!(Histogram::bucket(1024), 11);
        assert_eq!(Histogram::bucket(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn quantiles_track_recorded_values() {
        let h = Histogram::new();
        // 100 values around 1µs, one outlier at ~1ms.
        for _ in 0..100 {
            h.record(1_000);
        }
        h.record(1_000_000);
        let s = h.snapshot();
        assert_eq!(s.count, 101);
        assert_eq!(s.max, 1_000_000);
        // p50 must land in 1_000's bucket [512, 1024): midpoint 768.
        assert!((512..1024).contains(&s.p50), "p50={}", s.p50);
        assert!(s.p99 <= s.max);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99, "{s:?}");
    }

    #[test]
    fn single_value_quantiles_clamp_to_max() {
        let h = Histogram::new();
        h.record(700);
        let s = h.snapshot();
        assert_eq!(s.count, 1);
        // Bucket [512, 1024) midpoint is 768 > the observed max 700.
        assert_eq!(s.p50, 700);
        assert_eq!(s.p99, 700);
    }

    #[test]
    fn max_bucket_clamps_without_overflow() {
        // 2^63 and u64::MAX both land in the top bucket; the reported
        // quantile must clamp to the tracked max instead of overflowing
        // while computing a midpoint above 2^63.
        let h = Histogram::new();
        h.record(1u64 << 63);
        h.record(u64::MAX);
        assert_eq!(Histogram::bucket(1u64 << 63), BUCKETS - 1);
        let s = h.snapshot();
        assert_eq!(s.count, 2);
        assert_eq!(s.max, u64::MAX);
        assert!(s.p50 >= 1u64 << 62, "p50={}", s.p50);
        assert!(s.p99 <= s.max);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99, "{s:?}");
    }

    #[test]
    fn quantile_from_count_deltas_matches_window() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record(1_000);
        }
        let before = h.bucket_counts();
        for _ in 0..100 {
            h.record(1_000_000);
        }
        let after = h.bucket_counts();
        let delta: Vec<u64> = after.iter().zip(before.iter()).map(|(a, b)| a - b).collect();
        // The window contains only ~1ms samples even though the lifetime
        // histogram is dominated by 1µs ones.
        let p50 = quantile_from_counts(&delta, 0.50);
        assert!((524_288..1_048_576).contains(&p50), "p50={p50}");
        assert_eq!(quantile_from_counts(&[], 0.5), 0);
        assert_eq!(quantile_from_counts(&[0; BUCKETS], 0.99), 0);
        // Top-bucket mass reports the bucket's lower bound, not an
        // overflowing midpoint.
        let mut top = [0u64; BUCKETS];
        top[BUCKETS - 1] = 5;
        assert_eq!(quantile_from_counts(&top, 0.5), 1u64 << 62);
    }

    #[test]
    fn concurrent_records_all_land() {
        let h = Histogram::new();
        std::thread::scope(|s| {
            for t in 0..8 {
                let h = &h;
                s.spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                });
            }
        });
        let s = h.snapshot();
        assert_eq!(s.count, 8000);
        assert!(s.max >= 7999);
    }
}
