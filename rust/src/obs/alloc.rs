//! Per-run memory accounting via a counting global allocator.
//!
//! With the `alloc-track` feature enabled this module installs a
//! [`#[global_allocator]`](std::alloc::GlobalAlloc) wrapper around the
//! system allocator that counts bytes and calls into thread-tagged
//! atomic stripes (tagged by a hash of the calling thread's stack
//! address — no TLS, so the accounting can never recurse into the
//! allocator or touch a thread mid-teardown). On top of the raw
//! counters, [`MemScope`] brackets a region of work — one CC run — and
//! reports the scope's peak and net heap growth as [`MemStats`], which
//! `RunResult` carries and TRACE/METRICS surface.
//!
//! Without the feature every entry point compiles to a no-op returning
//! zeros/`None`, so the default build pays nothing (the allocator
//! wrapper itself is not even installed).
//!
//! Accuracy notes (feature on): the current/peak watermarks are
//! process-global, so two runs measured concurrently attribute each
//! other's allocations to whichever scope is open — fine for the
//! diagnostic this is (the serving path runs heavy verbs under an
//! admission gate anyway), not a substitute for a heap profiler.

/// Heap accounting for one bracketed region of work.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemStats {
    /// Peak bytes live above the scope's starting point.
    pub peak_bytes: u64,
    /// Net growth across the scope (bytes still live at close minus
    /// bytes live at open); negative when the scope freed more than it
    /// allocated.
    pub net_bytes: i64,
    /// Allocation calls observed process-wide during the scope.
    pub allocs: u64,
    /// Deallocation calls observed process-wide during the scope.
    pub frees: u64,
}

#[cfg(feature = "alloc-track")]
mod imp {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};

    const STRIPES: usize = 64;

    #[repr(align(128))] // one stripe per cache line pair: no false sharing
    struct Stripe {
        alloc_bytes: AtomicU64,
        alloc_calls: AtomicU64,
        free_bytes: AtomicU64,
        free_calls: AtomicU64,
    }

    #[allow(clippy::declare_interior_mutable_const)]
    const STRIPE_INIT: Stripe = Stripe {
        alloc_bytes: AtomicU64::new(0),
        alloc_calls: AtomicU64::new(0),
        free_bytes: AtomicU64::new(0),
        free_calls: AtomicU64::new(0),
    };
    static STRIPED: [Stripe; STRIPES] = [STRIPE_INIT; STRIPES];

    /// Live bytes right now (allocated minus freed, process-wide).
    static CUR: AtomicI64 = AtomicI64::new(0);
    /// High-water mark of `CUR`, resettable by an opening [`MemScope`].
    static WATERMARK: AtomicI64 = AtomicI64::new(0);

    /// Tag the calling thread without TLS: thread stacks are distinct
    /// multi-page regions, so the page number of a local variable is a
    /// stable, allocation-free per-thread discriminator.
    #[inline]
    fn stripe() -> &'static Stripe {
        let probe = 0u8;
        let tag = (&probe as *const u8 as usize) >> 13;
        &STRIPED[tag % STRIPES]
    }

    #[inline]
    fn on_alloc(n: usize) {
        let s = stripe();
        s.alloc_bytes.fetch_add(n as u64, Ordering::Relaxed);
        s.alloc_calls.fetch_add(1, Ordering::Relaxed);
        let cur = CUR.fetch_add(n as i64, Ordering::Relaxed) + n as i64;
        WATERMARK.fetch_max(cur, Ordering::Relaxed);
    }

    #[inline]
    fn on_free(n: usize) {
        let s = stripe();
        s.free_bytes.fetch_add(n as u64, Ordering::Relaxed);
        s.free_calls.fetch_add(1, Ordering::Relaxed);
        CUR.fetch_sub(n as i64, Ordering::Relaxed);
    }

    pub struct CountingAlloc;

    // SAFETY: defers every allocation to `System`; the bookkeeping is
    // atomic arithmetic on static storage and never allocates.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc(layout);
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            let p = System.alloc_zeroed(layout);
            if !p.is_null() {
                on_alloc(layout.size());
            }
            p
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout);
            on_free(layout.size());
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            let p = System.realloc(ptr, layout, new_size);
            if !p.is_null() {
                on_free(layout.size());
                on_alloc(new_size);
            }
            p
        }
    }

    #[global_allocator]
    static GLOBAL: CountingAlloc = CountingAlloc;

    pub fn current_bytes() -> u64 {
        CUR.load(Ordering::Relaxed).max(0) as u64
    }

    pub fn cur_raw() -> i64 {
        CUR.load(Ordering::Relaxed)
    }

    pub fn reset_watermark_to_current() -> i64 {
        let cur = CUR.load(Ordering::Relaxed);
        WATERMARK.store(cur, Ordering::Relaxed);
        cur
    }

    pub fn watermark() -> i64 {
        WATERMARK.load(Ordering::Relaxed)
    }

    pub fn totals() -> (u64, u64, u64, u64) {
        let mut t = (0u64, 0u64, 0u64, 0u64);
        for s in &STRIPED {
            t.0 = t.0.wrapping_add(s.alloc_bytes.load(Ordering::Relaxed));
            t.1 = t.1.wrapping_add(s.alloc_calls.load(Ordering::Relaxed));
            t.2 = t.2.wrapping_add(s.free_bytes.load(Ordering::Relaxed));
            t.3 = t.3.wrapping_add(s.free_calls.load(Ordering::Relaxed));
        }
        t
    }
}

/// Whether the counting allocator is compiled in.
pub const fn enabled() -> bool {
    cfg!(feature = "alloc-track")
}

/// Bytes currently live on the heap (0 when `alloc-track` is off).
pub fn current_bytes() -> u64 {
    #[cfg(feature = "alloc-track")]
    {
        imp::current_bytes()
    }
    #[cfg(not(feature = "alloc-track"))]
    {
        0
    }
}

/// Lifetime allocator totals `(alloc_bytes, alloc_calls, free_bytes,
/// free_calls)`, summed across thread stripes. All zeros when the
/// feature is off.
pub fn totals() -> (u64, u64, u64, u64) {
    #[cfg(feature = "alloc-track")]
    {
        imp::totals()
    }
    #[cfg(not(feature = "alloc-track"))]
    {
        (0, 0, 0, 0)
    }
}

/// Process-wide peak of live bytes since the last scope opened (0 when
/// the feature is off).
pub fn peak_bytes() -> u64 {
    #[cfg(feature = "alloc-track")]
    {
        imp::watermark().max(0) as u64
    }
    #[cfg(not(feature = "alloc-track"))]
    {
        0
    }
}

/// Brackets a region of work for heap accounting.
///
/// `start()` marks the live-byte level and resets the peak watermark;
/// `finish()` returns the scope's [`MemStats`] — or `None` when the
/// `alloc-track` feature is off, so callers store an `Option<MemStats>`
/// and pay nothing by default.
pub struct MemScope {
    #[cfg(feature = "alloc-track")]
    start_cur: i64,
    #[cfg(feature = "alloc-track")]
    start_totals: (u64, u64, u64, u64),
}

impl MemScope {
    pub fn start() -> MemScope {
        #[cfg(feature = "alloc-track")]
        {
            MemScope {
                start_cur: imp::reset_watermark_to_current(),
                start_totals: imp::totals(),
            }
        }
        #[cfg(not(feature = "alloc-track"))]
        {
            MemScope {}
        }
    }

    pub fn finish(self) -> Option<MemStats> {
        #[cfg(feature = "alloc-track")]
        {
            let end = imp::totals();
            Some(MemStats {
                peak_bytes: (imp::watermark() - self.start_cur).max(0) as u64,
                net_bytes: imp::cur_raw() - self.start_cur,
                allocs: end.1.wrapping_sub(self.start_totals.1),
                frees: end.3.wrapping_sub(self.start_totals.3),
            })
        }
        #[cfg(not(feature = "alloc-track"))]
        {
            None
        }
    }
}

#[cfg(all(test, feature = "alloc-track"))]
mod tests {
    use super::*;

    #[test]
    fn scope_sees_a_large_allocation() {
        let scope = MemScope::start();
        let buf = vec![0u8; 1 << 20];
        std::hint::black_box(&buf);
        let held = MemScope::start(); // nested mark while buf is live
        drop(buf);
        let inner = held.finish().unwrap();
        let outer = scope.finish().unwrap();
        assert!(outer.peak_bytes >= 1 << 20, "peak {outer:?}");
        assert!(outer.allocs >= 1);
        // The inner scope opened after the megabyte was allocated and
        // closed after it was freed: net must go negative.
        assert!(inner.net_bytes <= -(1 << 20) + 4096, "inner {inner:?}");
    }

    #[test]
    fn current_bytes_moves_with_live_data() {
        let before = current_bytes();
        let buf = vec![7u8; 1 << 18];
        std::hint::black_box(&buf);
        let during = current_bytes();
        assert!(during >= before + (1 << 18), "{before} -> {during}");
    }
}
