//! Run-level observability: dependency-free measurement primitives.
//!
//! The engine's only observable outputs used to be the flat `METRICS`
//! counter line and a per-run iteration count — aggregate totals with no
//! notion of *where a run's time went* or *what the latency distribution
//! looks like*. ConnectIt's evaluation (PAPERS.md) is built on per-phase
//! breakdowns (sampling vs finish phases timed separately) and Groute's
//! adaptive CC switches strategy on per-pass runtime signals; both need
//! the two primitives this module provides:
//!
//! * [`Histogram`] — a lock-free log₂-bucketed latency histogram.
//!   Recording is two relaxed `fetch_add`s plus a `fetch_max` (no locks,
//!   no allocation, safe from any thread); rendering walks the 64
//!   buckets into count/p50/p95/p99/max. The server keeps one per verb
//!   and the worker pool splits queue-wait from run-time with a pair.
//! * [`RunTrace`] — a bounded span recorder for one run (or one sharded
//!   run, or one CLI invocation). Spans are complete `X`-phase events
//!   (name, category, track, start, duration, small numeric args);
//!   recording is a short mutex push, and the whole recorder is behind
//!   an `Option` so tracing *off* costs one branch per pass, not per
//!   edge. Export is the standard Chrome trace-event JSON
//!   ([`RunTrace::to_chrome_json`]) — `contour run --trace out.json`
//!   opens directly in Perfetto / `chrome://tracing` — plus a one-line
//!   wire form ([`RunTrace::render_wire`]) for the server's `TRACE`
//!   verb.
//!
//! Neither primitive knows about graphs or algorithms; the wiring lives
//! with the layers being observed ([`crate::cc::RunContext`] threads a
//! trace through the algorithm core, [`crate::par::pool`] owns the
//! queue-wait/run-time pair, [`crate::server`] owns the per-verb set).
//!
//! Two continuous-telemetry primitives build on the same foundations:
//!
//! * [`TimeSeries`] — a bounded lock-free ring of periodic metric
//!   snapshots (seqlock per slot) with delta/rate derivation over any
//!   lookback window; the server's sampler thread feeds one and the
//!   PROM/HEALTH/WATCH verbs read it.
//! * [`alloc`] — an optional (`alloc-track` feature) counting global
//!   allocator so each run's [`MemStats`](alloc::MemStats) ride on
//!   `RunResult` and pass spans.

pub mod alloc;
mod histogram;
mod timeseries;
mod trace;

pub use alloc::{MemScope, MemStats};
pub use histogram::{quantile_from_counts, Histogram, HistogramSnapshot, BUCKETS};
pub use timeseries::{Sample, TimeSeries};
pub use trace::{DEFAULT_SPAN_CAP, RunTrace, Span};
