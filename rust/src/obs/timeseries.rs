//! Bounded lock-free ring of periodic metric snapshots.
//!
//! [`TimeSeries`] turns the server's point-in-time counters into a
//! *trajectory*: a sampler thread pushes one [`Sample`] (monotonic
//! timestamp + a fixed schema of `u64` values) per interval, and readers
//! derive deltas and rates (qps, bytes/s, busy fraction, pool
//! saturation) over any lookback window without ever taking a lock.
//!
//! Concurrency model: each slot is a seqlock. A writer claims a slot by
//! `fetch_add` on the global head (so concurrent writers never share a
//! slot), bumps the slot's sequence to odd, writes the payload, and
//! bumps it back to even. Readers snapshot the sequence, copy the
//! payload, and re-check; a torn read (odd or changed sequence) retries
//! a bounded number of times and then skips the slot. With one sampler
//! pushing every ~1s and scrapes every ~15s, retries are essentially
//! never taken — but the structure stays correct even under a hostile
//! push rate.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// How many times a reader re-tries a torn slot before skipping it.
const READ_RETRIES: usize = 64;

/// One periodic snapshot: a monotonic timestamp (milliseconds since the
/// process-local epoch, e.g. server start) plus one `u64` per key in the
/// owning ring's schema.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sample {
    pub ts_ms: u64,
    pub values: Vec<u64>,
}

struct Slot {
    /// Seqlock sequence: odd while a writer owns the slot.
    seq: AtomicU64,
    ts_ms: AtomicU64,
    values: Box<[AtomicU64]>,
}

impl Slot {
    fn new(width: usize) -> Self {
        Slot {
            seq: AtomicU64::new(0),
            ts_ms: AtomicU64::new(0),
            values: (0..width).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    fn write(&self, ts_ms: u64, values: &[u64]) {
        // Odd sequence marks the slot as mid-write; SeqCst keeps the
        // marker ordered against the payload stores on every platform.
        // This is a cold path (one write per sample interval), so the
        // strongest ordering is the simplest correct choice.
        self.seq.fetch_add(1, Ordering::SeqCst);
        self.ts_ms.store(ts_ms, Ordering::SeqCst);
        for (slot, &v) in self.values.iter().zip(values) {
            slot.store(v, Ordering::SeqCst);
        }
        self.seq.fetch_add(1, Ordering::SeqCst);
    }

    fn read(&self) -> Option<Sample> {
        for _ in 0..READ_RETRIES {
            let s1 = self.seq.load(Ordering::SeqCst);
            if s1 % 2 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let ts_ms = self.ts_ms.load(Ordering::SeqCst);
            let values: Vec<u64> = self.values.iter().map(|v| v.load(Ordering::SeqCst)).collect();
            if self.seq.load(Ordering::SeqCst) == s1 {
                return Some(Sample { ts_ms, values });
            }
        }
        None
    }
}

/// Bounded ring of [`Sample`]s with a fixed key schema.
///
/// The schema (an ordered list of key names) is fixed at construction:
/// every pushed sample carries exactly one value per key, so deltas are
/// a positional subtraction and readers never chase a mutating key set.
pub struct TimeSeries {
    keys: Vec<String>,
    slots: Vec<Slot>,
    /// Total pushes ever; the newest sample lives at `(head - 1) % cap`.
    head: AtomicUsize,
}

impl TimeSeries {
    /// A ring holding the newest `cap` samples of `keys.len()` values each.
    pub fn new(cap: usize, keys: Vec<String>) -> Self {
        let cap = cap.max(2);
        TimeSeries {
            slots: (0..cap).map(|_| Slot::new(keys.len())).collect(),
            keys,
            head: AtomicUsize::new(0),
        }
    }

    pub fn keys(&self) -> &[String] {
        &self.keys
    }

    /// Position of `key` in the schema (and in every sample's `values`).
    pub fn index_of(&self, key: &str) -> Option<usize> {
        self.keys.iter().position(|k| k == key)
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Number of samples currently readable (saturates at capacity).
    pub fn len(&self) -> usize {
        self.head.load(Ordering::Acquire).min(self.slots.len())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append one sample. `values` must match the schema width.
    pub fn push(&self, ts_ms: u64, values: &[u64]) {
        assert_eq!(
            values.len(),
            self.keys.len(),
            "TimeSeries::push value count must match the key schema"
        );
        let n = self.head.fetch_add(1, Ordering::AcqRel);
        self.slots[n % self.slots.len()].write(ts_ms, values);
    }

    /// The most recent sample, if any.
    pub fn latest(&self) -> Option<Sample> {
        let head = self.head.load(Ordering::Acquire);
        if head == 0 {
            return None;
        }
        // The newest slot may be mid-overwrite under a racing push; fall
        // back toward older slots until one reads cleanly.
        let cap = self.slots.len();
        let live = head.min(cap);
        for back in 0..live {
            let idx = (head - 1 - back) % cap;
            if let Some(s) = self.slots[idx].read() {
                return Some(s);
            }
        }
        None
    }

    /// All readable samples, oldest first. Slots torn by a concurrent
    /// writer are skipped, so the result is always internally consistent
    /// (each returned sample is a complete snapshot).
    pub fn samples(&self) -> Vec<Sample> {
        let head = self.head.load(Ordering::Acquire);
        let cap = self.slots.len();
        let live = head.min(cap);
        let mut out = Vec::with_capacity(live);
        for back in (0..live).rev() {
            let idx = (head - 1 - back) % cap;
            if let Some(s) = self.slots[idx].read() {
                out.push(s);
            }
        }
        // A wrapping writer can overwrite the oldest slots mid-walk,
        // leaving a newer sample in an "old" position; keep the suffix
        // monotone by timestamp so callers can difference blindly.
        let mut last = 0u64;
        out.retain(|s| {
            let ok = s.ts_ms >= last;
            if ok {
                last = s.ts_ms;
            }
            ok
        });
        out
    }

    /// The pair (oldest-within-window, newest) for a lookback of
    /// `lookback_ms` behind the newest sample. Returns `None` with
    /// fewer than two samples (no delta to take).
    pub fn window(&self, lookback_ms: u64) -> Option<(Sample, Sample)> {
        let all = self.samples();
        let newest = all.last()?.clone();
        let floor = newest.ts_ms.saturating_sub(lookback_ms);
        let oldest = all.iter().find(|s| s.ts_ms >= floor)?.clone();
        if oldest.ts_ms == newest.ts_ms {
            // Need an actual interval: fall back to the sample just
            // before the newest when the window is narrower than one
            // sampling period.
            let prev = all.iter().rev().nth(1)?.clone();
            return Some((prev, newest));
        }
        Some((oldest, newest))
    }

    /// Counter delta for `key` across a `(old, new)` sample pair.
    pub fn delta(old: &Sample, new: &Sample, idx: usize) -> u64 {
        new.values[idx].saturating_sub(old.values[idx])
    }

    /// Per-second rate for `key` across a `(old, new)` sample pair.
    pub fn rate_per_sec(old: &Sample, new: &Sample, idx: usize) -> f64 {
        let dt_ms = new.ts_ms.saturating_sub(old.ts_ms);
        if dt_ms == 0 {
            return 0.0;
        }
        Self::delta(old, new, idx) as f64 * 1000.0 / dt_ms as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    fn ring(cap: usize) -> TimeSeries {
        TimeSeries::new(cap, vec!["a".into(), "b".into()])
    }

    #[test]
    fn empty_ring_has_no_samples() {
        let ts = ring(8);
        assert!(ts.is_empty());
        assert!(ts.latest().is_none());
        assert!(ts.samples().is_empty());
        assert!(ts.window(1000).is_none());
    }

    #[test]
    fn push_and_read_back_in_order() {
        let ts = ring(4);
        for i in 0..3u64 {
            ts.push(i * 100, &[i, i * 2]);
        }
        let all = ts.samples();
        assert_eq!(all.len(), 3);
        assert_eq!(all[0].ts_ms, 0);
        assert_eq!(all[2].values, vec![2, 4]);
        assert_eq!(ts.latest().unwrap().ts_ms, 200);
    }

    #[test]
    fn ring_wraps_keeping_newest() {
        let ts = ring(4);
        for i in 0..10u64 {
            ts.push(i, &[i, 0]);
        }
        let all = ts.samples();
        assert_eq!(all.len(), 4);
        assert_eq!(all[0].ts_ms, 6);
        assert_eq!(all[3].ts_ms, 9);
        assert_eq!(ts.len(), 4);
    }

    #[test]
    fn window_picks_oldest_within_lookback() {
        let ts = ring(16);
        for i in 0..10u64 {
            ts.push(i * 1000, &[i * 7, 0]);
        }
        let (old, new) = ts.window(3000).unwrap();
        assert_eq!(new.ts_ms, 9000);
        assert_eq!(old.ts_ms, 6000);
        assert_eq!(TimeSeries::delta(&old, &new, 0), 21);
        let r = TimeSeries::rate_per_sec(&old, &new, 0);
        assert!((r - 7.0).abs() < 1e-9, "rate {r}");
    }

    #[test]
    fn window_wider_than_history_uses_oldest() {
        let ts = ring(16);
        ts.push(0, &[0, 0]);
        ts.push(500, &[5, 0]);
        let (old, new) = ts.window(u64::MAX).unwrap();
        assert_eq!((old.ts_ms, new.ts_ms), (0, 500));
    }

    #[test]
    fn concurrent_readers_never_see_torn_samples() {
        // Writer pushes pairs (i, 2*i); any sample where b != 2*a is a
        // torn read that escaped the seqlock.
        let ts = std::sync::Arc::new(ring(8));
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let ts = std::sync::Arc::clone(&ts);
                let stop = std::sync::Arc::clone(&stop);
                s.spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        for smp in ts.samples() {
                            assert_eq!(smp.values[1], smp.values[0] * 2);
                        }
                        if let Some(smp) = ts.latest() {
                            assert_eq!(smp.values[1], smp.values[0] * 2);
                        }
                    }
                });
            }
            for i in 0..20_000u64 {
                ts.push(i, &[i, i * 2]);
            }
            stop.store(true, Ordering::Relaxed);
        });
    }

    #[test]
    fn samples_are_monotone_in_time_under_wrap_race() {
        let ts = std::sync::Arc::new(ring(4));
        std::thread::scope(|s| {
            let w = std::sync::Arc::clone(&ts);
            s.spawn(move || {
                for i in 0..50_000u64 {
                    w.push(i, &[i, i * 2]);
                }
            });
            for _ in 0..2_000 {
                let all = ts.samples();
                for pair in all.windows(2) {
                    assert!(pair[0].ts_ms <= pair[1].ts_ms);
                }
            }
        });
    }
}
