//! # contour — Minimum-Mapping Connectivity (Contour algorithm)
//!
//! A from-scratch reproduction of *“Contour Algorithm for Connectivity”*
//! (Du, Alvarado Rodriguez, Li, Dindoost & Bader, 2023) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordination layer: graph substrate,
//!   native parallel implementations of every algorithm the paper
//!   evaluates (Contour variants C-1/C-2/C-m/C-Syn/C-11mm/C-1m1m, FastSV,
//!   Shiloach–Vishkin, ConnectIt-style union-find, BFS, label
//!   propagation, Afforest), the iteration driver, a distributed-memory
//!   simulator, and the benchmark harness that regenerates every table
//!   and figure in the paper.
//! * **L2/L1 (python/, build-time only)** — the same iteration expressed
//!   as a JAX graph whose per-edge hot spot is a Pallas kernel,
//!   AOT-lowered to HLO text and executed from Rust through the PJRT CPU
//!   client ([`runtime`]). Python is never on the request path.
//!
//! Quickstart:
//!
//! ```no_run
//! use contour::graph::gen;
//! use contour::cc::{self, Algorithm};
//!
//! let g = gen::rmat(16, 1 << 18, gen::RmatKind::Graph500, 1).into_csr();
//! let labels = cc::contour::Contour::c2().run(&g);
//! println!("{} components", cc::num_components(&labels));
//! ```

pub mod bench;
pub mod cc;
pub mod cli;
pub mod coordinator;
pub mod distsim;
pub mod graph;
pub mod obs;
pub mod par;
pub mod runtime;
pub mod server;
pub mod shard;
pub mod stream;
pub mod util;

/// Vertex id. Graphs up to 2^32 vertices; labels are vertex ids, so the
/// label array is `Vec<u32>` / `Vec<AtomicU32>`.
pub type VId = u32;
