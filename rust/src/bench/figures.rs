//! Figure/table drivers: regenerate every experiment of the paper's
//! evaluation section (§IV) on the synthetic corpus.
//!
//! * `table1`  — the dataset table (§IV-A, Table I)
//! * `fig1`    — iterations per algorithm per graph (§IV-C, Fig. 1)
//! * `fig2`    — execution time (§IV-D, Fig. 2)
//! * `fig3`    — speedup vs FastSV (§IV-E, Fig. 3)
//! * `fig4`    — speedup vs ConnectIt (§IV-F, Fig. 4)
//! * `distsim` — distributed-memory trends (§IV-G)
//! * `delaunay_scaling` — the §IV-D Delaunay growth analysis
//! * `pjrt`    — (ours) PJRT/HLO engine parity + dispatch overhead
//!
//! Every driver prints the table and writes `results/<name>.{txt,csv}`.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use super::suite::{self, Entry};
use super::{measure, Table};
use crate::cc::{self, Algorithm};
use crate::coordinator::algorithm_by_name;
use crate::distsim;
use crate::graph::{stats, Csr};
use crate::info;

/// The algorithm set of Figs. 1–4, legend order.
pub const SWEEP_ALGS: &[&str] =
    &["FastSV", "ConnectIt", "C-1", "C-2", "C-m", "C-11mm", "C-1m1m", "C-Syn"];

/// One (graph, algorithm) measurement.
#[derive(Clone, Debug)]
pub struct SweepRecord {
    pub graph_id: usize,
    pub graph: String,
    pub class: String,
    pub n: usize,
    pub m: usize,
    pub alg: String,
    pub iterations: usize,
    pub median_ms: f64,
    pub components: usize,
}

fn write_outputs(out_dir: &Path, name: &str, table: &Table) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(out_dir.join(format!("{name}.txt")), table.render())?;
    std::fs::write(out_dir.join(format!("{name}.csv")), table.csv())?;
    Ok(())
}

fn sweep_csv_path(out_dir: &Path, quick: bool) -> std::path::PathBuf {
    out_dir.join(if quick { "sweep_quick.csv" } else { "sweep.csv" })
}

/// Run (or reload) the full measurement sweep behind Figs. 1–4.
pub fn ensure_sweep(out_dir: &Path, quick: bool, threads: usize) -> Result<Vec<SweepRecord>> {
    let cache = sweep_csv_path(out_dir, quick);
    if let Ok(text) = std::fs::read_to_string(&cache) {
        let recs = parse_sweep_csv(&text)?;
        if !recs.is_empty() {
            info!("reusing sweep cache {} ({} records)", cache.display(), recs.len());
            return Ok(recs);
        }
    }
    let entries = if quick { suite::quick_corpus() } else { suite::corpus() };
    let mut records = Vec::new();
    for e in &entries {
        let g = e.build();
        info!("sweep: {} (n={} m={})", e.name, g.n, g.m());
        let mut comps_seen: Option<usize> = None;
        for &alg_name in SWEEP_ALGS {
            let alg = algorithm_by_name(alg_name, threads)?;
            // Expensive combos (huge-diameter graphs under C-1) get one
            // reliable rep; everything else gets warmup + 3.
            let heavy = g.m() > 300_000 || (alg_name == "C-1" && g.m() > 100_000);
            let (warmup, reps) = if heavy { (0, 1) } else { (1, 3) };
            let mut result = None;
            let sample = measure(warmup, reps, || result = Some(alg.run_with_stats(&g)));
            let r = result.unwrap();
            let comps = cc::num_components(&r.labels);
            if let Some(c0) = comps_seen {
                anyhow::ensure!(
                    c0 == comps,
                    "{} on {}: {} components, expected {}",
                    alg_name,
                    e.name,
                    comps,
                    c0
                );
            } else {
                comps_seen = Some(comps);
            }
            records.push(SweepRecord {
                graph_id: e.id,
                graph: e.name.to_string(),
                class: e.class.as_str().to_string(),
                n: g.n,
                m: g.m(),
                alg: alg_name.to_string(),
                iterations: r.iterations,
                median_ms: sample.median_ms,
                components: comps,
            });
        }
    }
    // Persist for the derived figures.
    let mut t = Table::new(&[
        "graph_id", "graph", "class", "n", "m", "alg", "iterations", "median_ms", "components",
    ]);
    for r in &records {
        t.row(vec![
            r.graph_id.to_string(),
            r.graph.clone(),
            r.class.clone(),
            r.n.to_string(),
            r.m.to_string(),
            r.alg.clone(),
            r.iterations.to_string(),
            format!("{:.3}", r.median_ms),
            r.components.to_string(),
        ]);
    }
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(&cache, t.csv())?;
    Ok(records)
}

fn parse_sweep_csv(text: &str) -> Result<Vec<SweepRecord>> {
    let mut out = Vec::new();
    for line in text.lines().skip(1) {
        let f: Vec<&str> = line.split(',').collect();
        if f.len() != 9 {
            continue;
        }
        out.push(SweepRecord {
            graph_id: f[0].parse()?,
            graph: f[1].into(),
            class: f[2].into(),
            n: f[3].parse()?,
            m: f[4].parse()?,
            alg: f[5].into(),
            iterations: f[6].parse()?,
            median_ms: f[7].parse()?,
            components: f[8].parse()?,
        });
    }
    Ok(out)
}

fn by_graph<'r>(records: &'r [SweepRecord]) -> BTreeMap<usize, Vec<&'r SweepRecord>> {
    let mut m: BTreeMap<usize, Vec<&SweepRecord>> = BTreeMap::new();
    for r in records {
        m.entry(r.graph_id).or_default().push(r);
    }
    m
}

fn lookup<'r>(rows: &[&'r SweepRecord], alg: &str) -> Option<&'r SweepRecord> {
    rows.iter().find(|r| r.alg == alg).copied()
}

// ------------------------------------------------------------------ Table I

pub fn table1(out_dir: &Path, quick: bool) -> Result<String> {
    let entries = if quick { suite::quick_corpus() } else { suite::corpus() };
    let mut t = Table::new(&[
        "id", "graph", "class", "edges", "vertices", "paper_edges", "paper_vertices", "scale",
        "comps", "pseudo_diam",
    ]);
    for e in &entries {
        let g = e.build();
        let s = stats::stats(&g);
        t.row(vec![
            e.id.to_string(),
            e.name.to_string(),
            e.class.as_str().to_string(),
            g.m().to_string(),
            g.n.to_string(),
            e.paper_m.to_string(),
            e.paper_n.to_string(),
            format!("{:.4}", e.scale),
            s.num_components.to_string(),
            s.pseudo_diameter.to_string(),
        ]);
    }
    write_outputs(out_dir, "table1", &t)?;
    Ok(t.render())
}

// ------------------------------------------------------------------- Fig. 1

pub fn fig1(out_dir: &Path, quick: bool, threads: usize) -> Result<String> {
    let records = ensure_sweep(out_dir, quick, threads)?;
    let mut t = Table::new(&{
        let mut h = vec!["id", "graph"];
        h.extend(SWEEP_ALGS);
        h
    });
    for (id, rows) in by_graph(&records) {
        let mut cells = vec![id.to_string(), rows[0].graph.clone()];
        for &alg in SWEEP_ALGS {
            cells.push(lookup(&rows, alg).map(|r| r.iterations.to_string()).unwrap_or_default());
        }
        t.row(cells);
    }
    // §IV-C summary: average iterations per algorithm.
    let mut summary = String::from("\naverage iterations (paper: C-m 2.19 < C-2 3.19 < C-11mm 3.89 < C-1m1m 4.31 < C-Syn 6.83 < FastSV 6.97 < C-1 83.86):\n");
    let mut avgs: Vec<(String, f64)> = SWEEP_ALGS
        .iter()
        .map(|&alg| {
            let xs: Vec<f64> =
                records.iter().filter(|r| r.alg == alg).map(|r| r.iterations as f64).collect();
            (alg.to_string(), xs.iter().sum::<f64>() / xs.len().max(1) as f64)
        })
        .collect();
    avgs.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    for (alg, avg) in &avgs {
        summary.push_str(&format!("  {alg:>9}: {avg:.2}\n"));
    }
    // Shape checks the paper asserts.
    let per_graph = by_graph(&records);
    let mut violations = Vec::new();
    for (_, rows) in &per_graph {
        let it = |a: &str| lookup(rows, a).map(|r| r.iterations).unwrap_or(0);
        if !(it("C-m") <= it("C-2") && it("C-2") <= it("C-1")) {
            violations.push(format!("{}: C-m {} C-2 {} C-1 {}", rows[0].graph, it("C-m"), it("C-2"), it("C-1")));
        }
    }
    summary.push_str(&format!(
        "ordering iterations(C-m) <= iterations(C-2) <= iterations(C-1): {}\n",
        if violations.is_empty() { "HOLDS on all graphs".into() } else { format!("violated on {violations:?}") }
    ));
    let rendered = format!("{}{}", t.render(), summary);
    write_outputs(out_dir, "fig1", &t)?;
    std::fs::write(out_dir.join("fig1_summary.txt"), &summary)?;
    Ok(rendered)
}

// ------------------------------------------------------------------- Fig. 2

pub fn fig2(out_dir: &Path, quick: bool, threads: usize) -> Result<String> {
    let records = ensure_sweep(out_dir, quick, threads)?;
    let mut t = Table::new(&{
        let mut h = vec!["id", "graph", "m"];
        h.extend(SWEEP_ALGS);
        h
    });
    for (id, rows) in by_graph(&records) {
        let mut cells = vec![id.to_string(), rows[0].graph.clone(), rows[0].m.to_string()];
        for &alg in SWEEP_ALGS {
            cells.push(
                lookup(&rows, alg).map(|r| format!("{:.2}", r.median_ms)).unwrap_or_default(),
            );
        }
        t.row(cells);
    }
    write_outputs(out_dir, "fig2", &t)?;
    Ok(t.render())
}

// ------------------------------------------------------- Figs. 3 and 4

fn speedup_fig(
    out_dir: &Path,
    quick: bool,
    threads: usize,
    name: &str,
    baseline: &str,
    paper_avgs: &[(&str, f64)],
) -> Result<String> {
    let records = ensure_sweep(out_dir, quick, threads)?;
    let algs: Vec<&str> = SWEEP_ALGS.iter().copied().filter(|&a| a != baseline).collect();
    let mut t = Table::new(&{
        let mut h = vec!["id", "graph"];
        h.extend(algs.iter().copied());
        h
    });
    let mut sums: BTreeMap<&str, (f64, usize, usize)> = BTreeMap::new(); // (sum, count, wins)
    for (id, rows) in by_graph(&records) {
        let Some(base) = lookup(&rows, baseline) else { continue };
        let mut cells = vec![id.to_string(), rows[0].graph.clone()];
        for &alg in &algs {
            match lookup(&rows, alg) {
                Some(r) if r.median_ms > 0.0 => {
                    let s = base.median_ms / r.median_ms;
                    let e = sums.entry(alg).or_default();
                    e.0 += s;
                    e.1 += 1;
                    if s > 1.0 {
                        e.2 += 1;
                    }
                    cells.push(format!("{s:.2}"));
                }
                _ => cells.push(String::new()),
            }
        }
        t.row(cells);
    }
    let mut summary = format!("\naverage speedup vs {baseline} (ours | paper):\n");
    for &alg in &algs {
        let (sum, cnt, wins) = sums.get(alg).copied().unwrap_or_default();
        let avg = sum / cnt.max(1) as f64;
        let paper = paper_avgs
            .iter()
            .find(|(a, _)| *a == alg)
            .map(|&(_, v)| format!("{v:.2}"))
            .unwrap_or_else(|| "-".into());
        summary.push_str(&format!("  {alg:>9}: {avg:5.2}x on {cnt} graphs (wins {wins}) | paper {paper}\n"));
    }
    let rendered = format!("{}{}", t.render(), summary);
    write_outputs(out_dir, name, &t)?;
    std::fs::write(out_dir.join(format!("{name}_summary.txt")), &summary)?;
    Ok(rendered)
}

pub fn fig3(out_dir: &Path, quick: bool, threads: usize) -> Result<String> {
    // Paper §IV-E average speedups vs FastSV.
    speedup_fig(
        out_dir,
        quick,
        threads,
        "fig3",
        "FastSV",
        &[
            ("C-m", 7.3),
            ("C-11mm", 6.6),
            ("ConnectIt", 6.49),
            ("C-1m1m", 6.33),
            ("C-2", 6.33),
            ("C-1", 4.62),
            ("C-Syn", 2.87),
        ],
    )
}

pub fn fig4(out_dir: &Path, quick: bool, threads: usize) -> Result<String> {
    // Paper §IV-F average speedups vs ConnectIt.
    speedup_fig(
        out_dir,
        quick,
        threads,
        "fig4",
        "ConnectIt",
        &[("C-m", 1.41), ("C-1m1m", 1.37), ("C-11mm", 1.35), ("C-2", 1.2), ("C-1", 1.11), ("C-Syn", 0.62)],
    )
}

// -------------------------------------------------------------- §IV-G

pub fn distsim_report(out_dir: &Path, quick: bool) -> Result<String> {
    use distsim::{simulate, CostModel, DistAlgorithm};
    let entries = if quick { suite::quick_corpus() } else { suite::corpus() };
    // Representative graphs: one power-law, one road, one delaunay.
    let picks: Vec<&Entry> = [3usize, 17, 23]
        .iter()
        .filter_map(|&id| entries.iter().find(|e| e.id == id))
        .collect();
    let algs = [
        DistAlgorithm::Contour { hops: 1 },
        DistAlgorithm::Contour { hops: 2 },
        DistAlgorithm::Contour { hops: 64 },
        DistAlgorithm::FastSv,
        DistAlgorithm::UnionFind,
    ];
    let mut t = Table::new(&[
        "graph", "alg", "nodes", "supersteps", "remote_reads", "remote_writes", "MB",
        "compute_s", "comm_s", "modeled_s",
    ]);
    for e in picks {
        let g: Csr = e.build();
        for alg in algs {
            for p in [2usize, 4, 8, 16, 32] {
                let r = simulate(&g, p, alg, CostModel::default());
                t.row(vec![
                    e.name.to_string(),
                    alg.name(),
                    p.to_string(),
                    r.supersteps.to_string(),
                    r.remote_reads.to_string(),
                    r.remote_writes.to_string(),
                    format!("{:.2}", r.bytes as f64 / 1e6),
                    format!("{:.4}", r.compute_secs),
                    format!("{:.4}", r.comm_secs),
                    format!("{:.4}", r.modeled_total()),
                ]);
            }
        }
    }
    write_outputs(out_dir, "distsim", &t)?;
    Ok(t.render())
}

// ------------------------------------------------- Delaunay scaling (§IV-D)

pub fn delaunay_scaling(out_dir: &Path, quick: bool, threads: usize) -> Result<String> {
    let records = ensure_sweep(out_dir, quick, threads)?;
    let mut del: Vec<&SweepRecord> =
        records.iter().filter(|r| r.class == "delaunay").collect();
    del.sort_by_key(|r| (r.n, r.alg.clone()));
    anyhow::ensure!(!del.is_empty(), "no delaunay records in sweep");
    let (n_min, n_max) = (del.first().unwrap().n, del.last().unwrap().n);
    let mut t = Table::new(&["alg", "t(min_n)_ms", "t(max_n)_ms", "growth", "size_growth"]);
    for &alg in SWEEP_ALGS {
        let lo = del.iter().find(|r| r.n == n_min && r.alg == alg);
        let hi = del.iter().find(|r| r.n == n_max && r.alg == alg);
        if let (Some(lo), Some(hi)) = (lo, hi) {
            t.row(vec![
                alg.to_string(),
                format!("{:.3}", lo.median_ms),
                format!("{:.3}", hi.median_ms),
                format!("{:.0}x", hi.median_ms / lo.median_ms.max(1e-9)),
                format!("{}x", n_max / n_min),
            ]);
        }
    }
    write_outputs(out_dir, "delaunay_scaling", &t)?;
    Ok(t.render())
}

// ---------------------------------------------------------------- PJRT path

pub fn pjrt_report(out_dir: &Path) -> Result<String> {
    use crate::coordinator::{PjrtContour, PjrtMode};
    use crate::graph::gen;
    let rt = crate::runtime::Runtime::from_env()
        .context("PJRT runtime unavailable (run `make artifacts`)")?;
    let graphs: Vec<(&str, Csr)> = vec![
        ("path_1k", gen::path(1_000).into_csr().shuffled_edges(1)),
        ("rmat_13", gen::rmat(13, 60_000, gen::RmatKind::Graph500, 9).into_csr()),
        ("delaunay_n14", gen::delaunay(1 << 14, 214).into_csr()),
    ];
    let mut t = Table::new(&["graph", "engine", "iterations", "median_ms", "parity"]);
    for (name, g) in &graphs {
        let native = cc::contour::Contour::c2();
        let want = native.run(g);
        let mut native_res = None;
        let s_native =
            measure(1, 3, || native_res = Some(native.run_with_stats(g)));
        t.row(vec![
            name.to_string(),
            "native-C2".into(),
            native_res.unwrap().iterations.to_string(),
            format!("{:.2}", s_native.median_ms),
            "ref".into(),
        ]);
        for mode in [PjrtMode::PerIteration, PjrtMode::FusedRun] {
            let eng = PjrtContour::new(&rt, 2, mode);
            let mut res = None;
            let s = measure(0, 1, || res = Some(eng.try_run(g).expect("pjrt run")));
            let r = res.unwrap();
            let parity = cc::same_partition(&r.labels, &want);
            t.row(vec![
                name.to_string(),
                eng.name(),
                r.iterations.to_string(),
                format!("{:.2}", s.median_ms),
                if parity { "OK".into() } else { "MISMATCH".into() },
            ]);
        }
    }
    write_outputs(out_dir, "pjrt", &t)?;
    Ok(t.render())
}

// ------------------------------------------------- hotpath trajectory

/// One `bench hotpath` measurement for the machine-readable report.
struct HotpathRecord {
    bench: String,
    graph: String,
    median_ms: f64,
    medges_per_s: f64,
}

/// Minimal JSON string escape (the identifiers we emit are plain ASCII,
/// but a defensive escape keeps the file well-formed whatever lands in
/// a label).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn hotpath_json_text(
    quick: bool,
    threads: usize,
    records: &[HotpathRecord],
    summary: &[(&str, f64)],
) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": 2,\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!("  \"threads\": {threads},\n"));
    out.push_str(&format!("  \"pool_workers\": {},\n", crate::par::pool::stats().workers));
    out.push_str("  \"summary\": {");
    for (i, (k, v)) in summary.iter().enumerate() {
        out.push_str(&format!(
            "{}\"{}\": {:.3}",
            if i == 0 { "" } else { ", " },
            json_escape(k),
            v
        ));
    }
    out.push_str("},\n");
    out.push_str("  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"bench\": \"{}\", \"graph\": \"{}\", \"median_ms\": {:.3}, \
             \"medges_per_s\": {:.1}}}{}\n",
            json_escape(&r.bench),
            json_escape(&r.graph),
            r.median_ms,
            r.medges_per_s,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

/// `bench hotpath` — the hot-path trajectory the ROADMAP tracks over
/// time instead of one-off runs: `exec/pool` vs `exec/spawn` (the
/// worker-pool amortization), `contour/full` vs `contour/frontier` vs
/// `contour/exact` (the three frontier engines), the `shard/p` sweep
/// (sharded C-2 against shard counts) and `balance/vertices` vs
/// `balance/edges` (fence policy at p=4). The JSON summary carries
/// `frontier_speedup_rmat` (full/frontier median ratio on the
/// low-diameter RMAT case), `exact_vs_chunk_{rmat,road}` (chunk/exact
/// median ratio — road is the high-diameter case where dropping the
/// backstop sweeps pays) and `edge_mass_ratio_p4_{vertices,edges}`
/// (max/min per-shard edge mass). Writes human-readable
/// `hotpath_trend.{txt,csv}` *and* machine-readable
/// `BENCH_hotpath.json` (CI uploads the JSON as an artifact so deltas
/// are diffable across commits; the repo-root `BENCH_hotpath.json` is
/// the committed trajectory baseline).
pub fn hotpath_json(out_dir: &Path, quick: bool, threads: usize) -> Result<String> {
    use crate::graph::gen;
    use crate::shard::Balance;

    let (scale, edges) = if quick { (13, 1 << 17) } else { (18, 1 << 22) };
    let g = gen::rmat(scale, edges, gen::RmatKind::Graph500, 1).into_csr();
    let side = if quick { 120 } else { 700 };
    let road = gen::road(side, side, 2).into_csr().shuffled_edges(3);
    let mut records: Vec<HotpathRecord> = Vec::new();
    let mut t = Table::new(&["bench", "graph", "median_ms", "medges_per_s"]);

    let mut bench = |records: &mut Vec<HotpathRecord>,
                     t: &mut Table,
                     name: &str,
                     gname: &str,
                     graph: &Csr,
                     run: &mut dyn FnMut() -> usize| {
        let mut iters = 0usize;
        let s = measure(1, 3, || iters = run());
        let medges = graph.m() as f64 * iters.max(1) as f64 / s.median_ms / 1e3;
        t.row(vec![
            name.into(),
            gname.into(),
            format!("{:.2}", s.median_ms),
            format!("{medges:.1}"),
        ]);
        records.push(HotpathRecord {
            bench: name.into(),
            graph: gname.into(),
            median_ms: s.median_ms,
            medges_per_s: medges,
        });
    };

    // Parallel substrate: persistent pool vs spawn-per-call.
    for (mode, label) in
        [(crate::par::ExecMode::SpawnPerCall, "spawn"), (crate::par::ExecMode::Pooled, "pool")]
    {
        crate::par::set_exec_mode(mode);
        for (gname, graph) in [("rmat", &g), ("road", &road)] {
            let alg = cc::contour::Contour::c2().with_threads(threads);
            bench(
                &mut records,
                &mut t,
                &format!("exec/{label}"),
                gname,
                graph,
                &mut || alg.run_with_stats(graph).iterations,
            );
        }
    }
    crate::par::set_exec_mode(crate::par::ExecMode::Pooled);

    // Contour execution engine: full-sweep vs chunk frontier vs exact
    // vertex-activation on the same sticky chunk grid. The rmat pair
    // feeds the frontier_speedup_rmat summary (the low-diameter case
    // the chunk frontier exists for); road is the high-diameter case —
    // adversarial for the chunk engine (backstop sweeps fire while
    // propagation crosses chunk borders) and exactly what the exact
    // activation map was built for, which is what the
    // exact_vs_chunk_road ratio records.
    for (label, mode) in [
        ("full", cc::contour::FrontierMode::Off),
        ("frontier", cc::contour::FrontierMode::Chunk),
        ("exact", cc::contour::FrontierMode::Exact),
    ] {
        for (gname, graph) in [("rmat", &g), ("road", &road)] {
            let alg = cc::contour::Contour::c2().with_threads(threads).with_frontier_mode(mode);
            bench(
                &mut records,
                &mut t,
                &format!("contour/{label}"),
                gname,
                graph,
                &mut || alg.run_with_stats(graph).iterations,
            );
        }
    }
    let median_of = |records: &[HotpathRecord], bench: &str, graph: &str| -> f64 {
        records
            .iter()
            .find(|r| r.bench == bench && r.graph == graph)
            .map(|r| r.median_ms)
            .unwrap_or(f64::NAN)
    };
    let frontier_speedup = median_of(&records, "contour/full", "rmat")
        / median_of(&records, "contour/frontier", "rmat");
    let exact_vs_chunk_rmat = median_of(&records, "contour/frontier", "rmat")
        / median_of(&records, "contour/exact", "rmat");
    let exact_vs_chunk_road = median_of(&records, "contour/frontier", "road")
        / median_of(&records, "contour/exact", "road");

    // Sharded connectivity: partition once per p, measure the sharded
    // run (shard-local C-2 jobs in flight + boundary contraction).
    for p in [1usize, 2, 4, 8] {
        let sg = crate::shard::ShardedGraph::partition(&g, p);
        let alg = cc::contour::Contour::c2().with_threads(threads);
        bench(&mut records, &mut t, &format!("shard/p{p}"), "rmat", &g, &mut || {
            crate::shard::run_sharded(&sg, &alg, threads).iterations
        });
    }

    // Fence policy at p=4: edge-balanced vs vertex-balanced shards,
    // with the max/min per-shard edge-mass ratio recorded alongside the
    // timing (the ratio is deterministic; the timing shows what the
    // balance buys the concurrent shard jobs).
    let mut mass_ratio = Vec::new();
    for balance in [Balance::Vertices, Balance::Edges] {
        let sg = crate::shard::ShardedGraph::partition_with(&g, 4, balance);
        let mass: Vec<usize> = sg
            .shards
            .iter()
            .map(|s| g.offsets[s.hi as usize] - g.offsets[s.lo as usize])
            .collect();
        let ratio = *mass.iter().max().unwrap() as f64
            / (*mass.iter().min().unwrap() as f64).max(1.0);
        mass_ratio.push(ratio);
        let alg = cc::contour::Contour::c2().with_threads(threads);
        bench(
            &mut records,
            &mut t,
            &format!("balance/{}", balance.as_str()),
            "rmat",
            &g,
            &mut || crate::shard::run_sharded(&sg, &alg, threads).iterations,
        );
    }
    let summary = [
        ("frontier_speedup_rmat", frontier_speedup),
        ("exact_vs_chunk_rmat", exact_vs_chunk_rmat),
        ("exact_vs_chunk_road", exact_vs_chunk_road),
        ("edge_mass_ratio_p4_vertices", mass_ratio[0]),
        ("edge_mass_ratio_p4_edges", mass_ratio[1]),
    ];

    std::fs::create_dir_all(out_dir)?;
    std::fs::write(
        out_dir.join("BENCH_hotpath.json"),
        hotpath_json_text(quick, threads, &records, &summary),
    )?;
    write_outputs(out_dir, "hotpath_trend", &t)?;
    Ok(t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hotpath_json_is_well_formed() {
        let recs = vec![
            HotpathRecord {
                bench: "exec/pool".into(),
                graph: "rmat".into(),
                median_ms: 1.5,
                medges_per_s: 100.0,
            },
            HotpathRecord {
                bench: "shard/p2".into(),
                graph: "rmat".into(),
                median_ms: 2.5,
                medges_per_s: 50.0,
            },
        ];
        let summary = [("frontier_speedup_rmat", 1.4567), ("edge_mass_ratio_p4_edges", 1.08)];
        let text = hotpath_json_text(true, 4, &recs, &summary);
        assert!(text.contains("\"schema\": 2"));
        assert!(text.contains("\"quick\": true"));
        assert!(text.contains("\"bench\": \"shard/p2\""));
        assert!(text.contains("\"frontier_speedup_rmat\": 1.457"), "{text}");
        assert!(text.contains("\"edge_mass_ratio_p4_edges\": 1.080"), "{text}");
        // One comma between the two summary keys, none trailing.
        assert!(text.contains("1.457, \""), "{text}");
        // One comma between the two records, none after the last.
        assert_eq!(text.matches("},\n").count(), 2, "{text}");
        assert_eq!(json_escape("a\"b\\c\n"), "a\\\"b\\\\c\\u000a");
    }

    #[test]
    fn sweep_csv_round_trip() {
        let rec = SweepRecord {
            graph_id: 3,
            graph: "wiki".into(),
            class: "power-law".into(),
            n: 100,
            m: 200,
            alg: "C-2".into(),
            iterations: 4,
            median_ms: 1.25,
            components: 2,
        };
        let csv = format!(
            "graph_id,graph,class,n,m,alg,iterations,median_ms,components\n3,wiki,power-law,100,200,C-2,4,1.250,2\n"
        );
        let parsed = parse_sweep_csv(&csv).unwrap();
        assert_eq!(parsed.len(), 1);
        assert_eq!(parsed[0].graph, rec.graph);
        assert_eq!(parsed[0].iterations, 4);
        assert!((parsed[0].median_ms - 1.25).abs() < 1e-9);
    }

    #[test]
    fn lookup_and_grouping() {
        let mk = |id: usize, alg: &str| SweepRecord {
            graph_id: id,
            graph: format!("g{id}"),
            class: "x".into(),
            n: 1,
            m: 1,
            alg: alg.into(),
            iterations: 1,
            median_ms: 1.0,
            components: 1,
        };
        let recs = vec![mk(0, "C-2"), mk(0, "FastSV"), mk(1, "C-2")];
        let g = by_graph(&recs);
        assert_eq!(g.len(), 2);
        assert!(lookup(&g[&0], "FastSV").is_some());
        assert!(lookup(&g[&1], "FastSV").is_none());
    }
}
