//! Benchmark harness (the image has no `criterion`): warmup + repeated
//! measurement with robust summaries, a fixed-width table printer, and
//! the experiment suite + figure drivers that regenerate every table and
//! figure of the paper (see DESIGN.md §4).

pub mod figures;
pub mod serve;
pub mod suite;

use crate::util::Timer;

/// Robust summary of repeated measurements (milliseconds).
#[derive(Clone, Copy, Debug)]
pub struct Sample {
    pub reps: usize,
    pub median_ms: f64,
    pub min_ms: f64,
    pub mean_ms: f64,
    /// Median absolute deviation — stability indicator.
    pub mad_ms: f64,
}

/// Measure `f` with `warmup` unrecorded runs then `reps` recorded runs.
pub fn measure<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        let t = Timer::start();
        f();
        times.push(t.ms());
    }
    summarize(&times)
}

pub fn summarize(times: &[f64]) -> Sample {
    let mut sorted = times.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = sorted[sorted.len() / 2];
    let mut devs: Vec<f64> = sorted.iter().map(|t| (t - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Sample {
        reps: times.len(),
        median_ms: median,
        min_ms: sorted[0],
        mean_ms: times.iter().sum::<f64>() / times.len() as f64,
        mad_ms: devs[devs.len() / 2],
    }
}

/// Fixed-width ASCII table writer used by every figure driver.
#[derive(Default)]
pub struct Table {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        debug_assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
    }

    /// Render with per-column widths.
    pub fn render(&self) -> String {
        let cols = self.header.len();
        let mut widths = vec![0usize; cols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = line(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (cols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }

    /// CSV form (for plotting outside).
    pub fn csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_reps() {
        let mut calls = 0;
        let s = measure(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(s.reps, 5);
        assert!(s.min_ms <= s.median_ms);
    }

    #[test]
    fn summarize_median_and_mad() {
        let s = summarize(&[1.0, 100.0, 3.0, 2.0, 2.5]);
        assert_eq!(s.median_ms, 2.5);
        assert!(s.mad_ms <= 1.5 + 1e-9);
        assert_eq!(s.min_ms, 1.0);
    }

    #[test]
    fn table_renders_aligned_and_csv() {
        let mut t = Table::new(&["graph", "ms"]);
        t.row(vec!["path".into(), "1.5".into()]);
        t.row(vec!["a-very-long-name".into(), "20".into()]);
        let r = t.render();
        assert!(r.contains("graph"));
        assert!(r.lines().count() == 4);
        let csv = t.csv();
        assert_eq!(csv.lines().next().unwrap(), "graph,ms");
        assert_eq!(csv.lines().count(), 3);
    }
}
