//! Serving benchmark (`contour bench serve`): a multi-connection load
//! generator against an in-process server, measuring the wire path the
//! paper cares about — many concurrent clients querying components
//! while the engine runs underneath (§III-A / Arkouda integration).
//!
//! Five scenarios. The four query shapes, {line, binary} × {single,
//! batch}, are all answered from one warmed labels-cache entry so the
//! numbers isolate protocol + dispatch overhead rather than
//! connectivity time; the fifth exercises the streaming write path:
//!
//! - `line/single`   — closed-loop `QUERY` per connection
//! - `line/batch`    — closed-loop `BQUERY` with ids in the arg list
//! - `binary/single` — framed `QUERY`, one in flight
//! - `binary/batch`  — framed `BQUERY`, pipelined (client window 16)
//! - `line/churn`    — closed-loop SADD/SQUERY/SDEL cycles against a
//!   live stream, one connection also sealing epochs (decremental path)
//!
//! Output mirrors the hotpath bench: `serving.{txt,csv}` in the out
//! directory plus machine-readable `BENCH_serving.json` (schema 1) that
//! CI validates and uploads; the repo-root copy is the committed
//! trajectory baseline (`bench serve --baseline` refreshes it).

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::server::{protocol, serve_listener, ServerState};
use crate::VId;

use super::Table;

/// Client-side pipeline window for the binary batch scenario. Below the
/// server's default per-connection window (64) on purpose: the bench
/// measures steady-state pipelining, not BUSY handling (tests cover
/// that).
const PIPELINE_WINDOW: usize = 16;

/// One scenario's measurements.
#[derive(Clone, Debug)]
pub struct ServeRecord {
    /// `protocol/mode`, e.g. `binary/batch`.
    pub scenario: String,
    pub protocol: &'static str,
    pub mode: &'static str,
    pub conns: usize,
    /// Total requests across all connections.
    pub requests: usize,
    /// Vertex ids per request (1 for single).
    pub batch: usize,
    /// Client-side in-flight window (1 = closed loop).
    pub window: usize,
    pub qps: f64,
    /// Vertex lookups per second (`qps × batch`).
    pub vps: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

fn pctl(sorted_us: &[f64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[idx.min(sorted_us.len() - 1)]
}

fn summarize_scenario(
    protocol: &'static str,
    mode: &'static str,
    conns: usize,
    batch: usize,
    window: usize,
    mut lat_us: Vec<f64>,
    wall_secs: f64,
) -> ServeRecord {
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let requests = lat_us.len();
    let qps = requests as f64 / wall_secs.max(1e-9);
    ServeRecord {
        scenario: format!("{protocol}/{mode}"),
        protocol,
        mode,
        conns,
        requests,
        batch,
        window,
        qps,
        vps: qps * batch as f64,
        p50_us: pctl(&lat_us, 0.50),
        p95_us: pctl(&lat_us, 0.95),
        p99_us: pctl(&lat_us, 0.99),
    }
}

// ------------------------------------------------------------ clients

/// A line-protocol connection (the classic text transport).
struct LineConn {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

impl LineConn {
    fn connect(addr: &str) -> Result<Self> {
        let s = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        s.set_nodelay(true)?;
        Ok(Self { r: BufReader::new(s.try_clone()?), w: BufWriter::new(s) })
    }

    fn req(&mut self, cmd: &str) -> Result<String> {
        self.w.write_all(cmd.as_bytes())?;
        self.w.write_all(b"\n")?;
        self.w.flush()?;
        let mut line = String::new();
        if self.r.read_line(&mut line)? == 0 {
            bail!("server closed the connection mid-request");
        }
        Ok(line.trim_end().to_string())
    }

    fn req_ok(&mut self, cmd: &str) -> Result<String> {
        let reply = self.req(cmd)?;
        anyhow::ensure!(reply.starts_with("OK") || reply == "PONG", "{cmd:?} -> {reply}");
        Ok(reply)
    }
}

/// A binary-protocol connection: line `HELLO 2` upgrade, then frames.
struct BinConn {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
    next_id: u32,
}

impl BinConn {
    fn connect(addr: &str) -> Result<Self> {
        let s = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        s.set_nodelay(true)?;
        let mut r = BufReader::new(s.try_clone()?);
        let mut w = BufWriter::new(s);
        w.write_all(b"HELLO 2\n")?;
        w.flush()?;
        let mut line = String::new();
        r.read_line(&mut line)?;
        anyhow::ensure!(line.trim_end() == "OK v2", "HELLO 2 -> {}", line.trim_end());
        Ok(Self { r, w, next_id: 1 })
    }

    fn send(&mut self, verb: &str, args: &str, extra: &[VId]) -> Result<u32> {
        let id = self.next_id;
        self.next_id = self.next_id.wrapping_add(1);
        self.w.write_all(&protocol::encode_request(id, verb, args, extra)?)?;
        Ok(id)
    }

    fn recv(&mut self) -> Result<protocol::ReplyFrame> {
        protocol::read_reply(&mut self.r)?.ok_or_else(|| anyhow!("server closed the connection"))
    }
}

// ---------------------------------------------------------- workloads

/// Deterministic vertex-id stream: a Weyl-ish stride walk that touches
/// ids all over the label array (no RNG dependency, same ids per run).
fn vid_at(i: usize, conn: usize, n: usize) -> VId {
    ((i.wrapping_mul(2_654_435_761).wrapping_add(conn * 97)) % n) as VId
}

fn line_single(addr: &str, graph: &str, conn: usize, n_reqs: usize, n: usize) -> Result<Vec<f64>> {
    let mut c = LineConn::connect(addr)?;
    let mut lat = Vec::with_capacity(n_reqs);
    for i in 0..n_reqs {
        let cmd = format!("QUERY {graph} {} C-2", vid_at(i, conn, n));
        let t = Instant::now();
        c.req_ok(&cmd)?;
        lat.push(t.elapsed().as_secs_f64() * 1e6);
    }
    let _ = c.req("QUIT");
    Ok(lat)
}

fn line_batch(
    addr: &str,
    graph: &str,
    conn: usize,
    n_reqs: usize,
    batch: usize,
    n: usize,
) -> Result<Vec<f64>> {
    let mut c = LineConn::connect(addr)?;
    let mut lat = Vec::with_capacity(n_reqs);
    for i in 0..n_reqs {
        let mut cmd = format!("BQUERY {graph} C-2");
        for k in 0..batch {
            cmd.push(' ');
            cmd.push_str(&vid_at(i * batch + k, conn, n).to_string());
        }
        let t = Instant::now();
        let reply = c.req_ok(&cmd)?;
        lat.push(t.elapsed().as_secs_f64() * 1e6);
        // `OK <count> l...` — the count pins reply/request pairing.
        let count: usize =
            reply.split_whitespace().nth(1).and_then(|t| t.parse().ok()).unwrap_or(0);
        anyhow::ensure!(count == batch, "BQUERY answered {count} of {batch} ids");
    }
    let _ = c.req("QUIT");
    Ok(lat)
}

fn bin_single(addr: &str, graph: &str, conn: usize, n_reqs: usize, n: usize) -> Result<Vec<f64>> {
    let mut c = BinConn::connect(addr)?;
    let mut lat = Vec::with_capacity(n_reqs);
    for i in 0..n_reqs {
        let args = format!("{graph} {} C-2", vid_at(i, conn, n));
        let t = Instant::now();
        let id = c.send("QUERY", &args, &[])?;
        c.w.flush()?;
        let f = c.recv()?;
        lat.push(t.elapsed().as_secs_f64() * 1e6);
        anyhow::ensure!(f.id == id && f.status == protocol::STATUS_OK, "QUERY -> {}", f.text());
    }
    Ok(lat)
}

/// The pipelined path: keep up to [`PIPELINE_WINDOW`] BQUERY frames in
/// flight, matching replies to send times by request id (replies may
/// arrive out of order).
fn bin_batch(
    addr: &str,
    graph: &str,
    conn: usize,
    n_reqs: usize,
    batch: usize,
    n: usize,
) -> Result<Vec<f64>> {
    let mut c = BinConn::connect(addr)?;
    let mut lat = Vec::with_capacity(n_reqs);
    let mut sent_at: std::collections::HashMap<u32, Instant> = std::collections::HashMap::new();
    let args = format!("{graph} C-2");
    let mut sent = 0usize;
    let mut done = 0usize;
    while done < n_reqs {
        while sent < n_reqs && sent_at.len() < PIPELINE_WINDOW {
            let ids: Vec<VId> = (0..batch).map(|k| vid_at(sent * batch + k, conn, n)).collect();
            let t = Instant::now();
            let id = c.send("BQUERY", &args, &ids)?;
            sent_at.insert(id, t);
            sent += 1;
        }
        c.w.flush()?;
        let f = c.recv()?;
        let t = sent_at
            .remove(&f.id)
            .ok_or_else(|| anyhow!("reply for unknown request id {}", f.id))?;
        lat.push(t.elapsed().as_secs_f64() * 1e6);
        anyhow::ensure!(f.status == protocol::STATUS_OK, "BQUERY -> {}", f.text());
        anyhow::ensure!(
            f.batch_labels()?.len() == batch,
            "BQUERY reply label count != {batch}"
        );
        done += 1;
    }
    Ok(lat)
}

/// Vertex strip each churn connection owns: deletes always target edges
/// that same connection inserted, so the server-side multiset never
/// underflows no matter how the connections interleave.
const CHURN_SPAN: usize = 512;

/// How many add/query/delete cycles pass between epoch seals on the
/// sealing connection (conn 0).
const CHURN_SEAL_EVERY: usize = 16;

/// Churn workload: closed-loop SADD / SQUERY SAME / SDEL cycles against
/// a live stream — the decremental write path under concurrent load.
/// Each cycle inserts one edge inside the connection's strip, queries
/// its endpoints, then deletes it again; connection 0 additionally seals
/// an epoch every [`CHURN_SEAL_EVERY`] cycles so queries observe the
/// churn (seals are timed like every other request — they *are* the
/// expensive part of the workload).
fn line_churn(addr: &str, stream: &str, conn: usize, cycles: usize) -> Result<Vec<f64>> {
    let mut c = LineConn::connect(addr)?;
    let base = conn * CHURN_SPAN;
    let mut lat = Vec::with_capacity(cycles * 3);
    let mut timed = |c: &mut LineConn, cmd: &str| -> Result<()> {
        let t = Instant::now();
        c.req_ok(cmd)?;
        lat.push(t.elapsed().as_secs_f64() * 1e6);
        Ok(())
    };
    for i in 0..cycles {
        let u = base + (i * 97) % (CHURN_SPAN - 1);
        let v = u + 1;
        timed(&mut c, &format!("SADD {stream} {u} {v}"))?;
        timed(&mut c, &format!("SQUERY {stream} SAME {u} {v}"))?;
        timed(&mut c, &format!("SDEL {stream} {u} {v}"))?;
        if conn == 0 && (i + 1) % CHURN_SEAL_EVERY == 0 {
            timed(&mut c, &format!("SEPOCH {stream}"))?;
        }
    }
    let _ = c.req("QUIT");
    Ok(lat)
}

/// Fan a per-connection workload across `conns` OS threads; returns all
/// latencies merged plus the wall time of the slowest connection.
fn run_conns<F>(conns: usize, f: F) -> Result<(Vec<f64>, f64)>
where
    F: Fn(usize) -> Result<Vec<f64>> + Sync,
{
    let t = Instant::now();
    let f = &f;
    let per_conn: Vec<Result<Vec<f64>>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..conns).map(|c| s.spawn(move || f(c))).collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("load-generator thread panicked"))?)
            .collect()
    });
    let wall = t.elapsed().as_secs_f64();
    let mut all = Vec::new();
    for r in per_conn {
        all.extend(r?);
    }
    Ok((all, wall))
}

// ------------------------------------------------------------- driver

/// Run the serving benchmark and write `serving.{txt,csv}` +
/// `BENCH_serving.json` under `out_dir`. Returns the rendered table.
pub fn serving_json(out_dir: &Path, quick: bool, threads: usize) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let (scale, degree) = if quick { (12u32, 8usize) } else { (16u32, 16usize) };
    let (conns, singles, batches, batch) =
        if quick { (2usize, 400usize, 40usize, 64usize) } else { (4, 4000, 200, 256) };
    let churn_cycles = if quick { 48usize } else { 240 };
    let spec = format!("rmat:{scale}:{degree}");
    let n = 1usize << scale;

    // In-process server on an OS-assigned port: the bench measures the
    // full TCP wire path but needs no external process.
    let state = Arc::new(ServerState::new(threads));
    let shutdown = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();
    let server = {
        let (state, shutdown) = (state.clone(), shutdown.clone());
        std::thread::spawn(move || serve_listener(listener, state, shutdown))
    };

    // Build + warm once so every scenario reads the same cached
    // labelling — wait-free queries, per ConnectIt's serving model.
    let mut setup = LineConn::connect(&addr)?;
    setup.req_ok(&format!("GEN serve {spec}"))?;
    setup.req_ok("CC serve C-2")?;
    setup.req_ok("QUERY serve 0 C-2")?;

    let mut records = Vec::new();
    let (lat, wall) = run_conns(conns, |c| line_single(&addr, "serve", c, singles, n))?;
    records.push(summarize_scenario("line", "single", conns, 1, 1, lat, wall));
    let (lat, wall) = run_conns(conns, |c| line_batch(&addr, "serve", c, batches, batch, n))?;
    records.push(summarize_scenario("line", "batch", conns, batch, 1, lat, wall));
    let (lat, wall) = run_conns(conns, |c| bin_single(&addr, "serve", c, singles, n))?;
    records.push(summarize_scenario("binary", "single", conns, 1, 1, lat, wall));
    let (lat, wall) = run_conns(conns, |c| bin_batch(&addr, "serve", c, batches, batch, n))?;
    records.push(summarize_scenario(
        "binary",
        "batch",
        conns,
        batch,
        PIPELINE_WINDOW,
        lat,
        wall,
    ));

    // Churn scenario: its own stream (no WAL — the bench meters the
    // in-memory decremental path, not fsync), one vertex strip per
    // connection.
    setup.req_ok(&format!("STREAM churn {}", conns * CHURN_SPAN))?;
    let (lat, wall) = run_conns(conns, |c| line_churn(&addr, "churn", c, churn_cycles))?;
    records.push(summarize_scenario("line", "churn", conns, 1, 1, lat, wall));

    let _ = setup.req("QUIT");
    drop(setup);
    shutdown.store(true, Ordering::Relaxed);
    let _ = server.join();

    let mut table = Table::new(&[
        "scenario", "conns", "requests", "batch", "window", "qps", "vps", "p50_us", "p95_us",
        "p99_us",
    ]);
    for r in &records {
        table.row(vec![
            r.scenario.clone(),
            r.conns.to_string(),
            r.requests.to_string(),
            r.batch.to_string(),
            r.window.to_string(),
            format!("{:.0}", r.qps),
            format!("{:.0}", r.vps),
            format!("{:.1}", r.p50_us),
            format!("{:.1}", r.p95_us),
            format!("{:.1}", r.p99_us),
        ]);
    }
    let text = table.render();
    std::fs::write(out_dir.join("serving.txt"), &text)?;
    std::fs::write(out_dir.join("serving.csv"), table.csv())?;
    let json = serving_json_text(quick, threads, &spec, &records);
    let json_path = out_dir.join("BENCH_serving.json");
    std::fs::write(&json_path, &json)
        .with_context(|| format!("writing {}", json_path.display()))?;
    Ok(format!("{text}json: {}\n", json_path.display()))
}

fn serving_json_text(quick: bool, threads: usize, graph: &str, records: &[ServeRecord]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": 1,\n");
    out.push_str("  \"bench\": \"serving\",\n");
    out.push_str(&format!("  \"quick\": {quick},\n"));
    out.push_str(&format!("  \"threads\": {threads},\n"));
    out.push_str(&format!("  \"graph\": \"{graph}\",\n"));
    out.push_str("  \"records\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!("      \"scenario\": \"{}\",\n", r.scenario));
        out.push_str(&format!("      \"protocol\": \"{}\",\n", r.protocol));
        out.push_str(&format!("      \"mode\": \"{}\",\n", r.mode));
        out.push_str(&format!("      \"conns\": {},\n", r.conns));
        out.push_str(&format!("      \"requests\": {},\n", r.requests));
        out.push_str(&format!("      \"batch\": {},\n", r.batch));
        out.push_str(&format!("      \"window\": {},\n", r.window));
        out.push_str(&format!("      \"qps\": {:.1},\n", r.qps));
        out.push_str(&format!("      \"vertices_per_sec\": {:.1},\n", r.vps));
        out.push_str(&format!("      \"p50_us\": {:.1},\n", r.p50_us));
        out.push_str(&format!("      \"p95_us\": {:.1},\n", r.p95_us));
        out.push_str(&format!("      \"p99_us\": {:.1}\n", r.p99_us));
        out.push_str(if i + 1 == records.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n");
    out.push_str("}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(scenario: &str, protocol: &'static str, mode: &'static str) -> ServeRecord {
        ServeRecord {
            scenario: scenario.to_string(),
            protocol,
            mode,
            conns: 2,
            requests: 800,
            batch: 64,
            window: 16,
            qps: 12345.6789,
            vps: 790123.0,
            p50_us: 81.25,
            p95_us: 190.5,
            p99_us: 402.0,
        }
    }

    #[test]
    fn serving_json_shape() {
        let records =
            [rec("line/single", "line", "single"), rec("binary/batch", "binary", "batch")];
        let text = serving_json_text(true, 4, "rmat:12:8", &records);
        assert!(text.contains("\"schema\": 1"), "{text}");
        assert!(text.contains("\"bench\": \"serving\""));
        assert!(text.contains("\"graph\": \"rmat:12:8\""));
        assert!(text.contains("\"scenario\": \"binary/batch\""));
        assert!(text.contains("\"qps\": 12345.7"));
        assert!(text.contains("\"p99_us\": 402.0"));
        // Valid JSON: no trailing comma before the closing bracket.
        assert!(!text.contains(",\n  ]"), "{text}");
    }

    #[test]
    fn percentiles_clamp() {
        assert_eq!(pctl(&[], 0.5), 0.0);
        let one = [7.0];
        assert_eq!(pctl(&one, 0.5), 7.0);
        assert_eq!(pctl(&one, 0.99), 7.0);
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(pctl(&v, 0.50), 50.0);
        assert_eq!(pctl(&v, 0.99), 99.0);
    }

    #[test]
    fn vertex_ids_stay_in_range() {
        let n = 1 << 12;
        for i in 0..1000 {
            for c in 0..4 {
                assert!((vid_at(i, c, n) as usize) < n);
            }
        }
    }
}
