//! The experiment corpus: a synthetic analog for every Table I graph.
//!
//! The sandbox cannot download SNAP/SuiteSparse datasets, so each
//! real-world graph is replaced by a seeded generator of the same
//! topology class with matched (n, m) — scaled down where the original
//! exceeds the sandbox budget (the `scale` field records the factor;
//! DESIGN.md §5 argues why class + scale preserve the evaluated
//! behaviour). Delaunay graphs are built with the *same construction* as
//! the SuiteSparse family (triangulation of random points), up to n20.
//!
//! Built graphs are cached on disk (`results/graphcache/*.bin`) so
//! repeated bench runs pay generation once.

use std::path::PathBuf;

use crate::graph::{gen, io, Csr, EdgeList};

/// Topology class of a corpus entry (drives expectations in figures).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Class {
    /// Power-law collaboration/social networks (BA or RMAT analogs).
    PowerLaw,
    /// Web-crawl-like (RMAT with milder skew).
    Web,
    /// Lattice road networks — huge diameter.
    Road,
    /// Genomic k-mer filament graphs — huge diameter, many components.
    Kmer,
    /// Delaunay triangulations — sqrt(n) diameter, uniform degree.
    Delaunay,
}

impl Class {
    pub fn as_str(&self) -> &'static str {
        match self {
            Class::PowerLaw => "power-law",
            Class::Web => "web",
            Class::Road => "road",
            Class::Kmer => "kmer",
            Class::Delaunay => "delaunay",
        }
    }
}

/// One corpus entry mirroring a Table I row.
pub struct Entry {
    /// Paper's graph id (Table I).
    pub id: usize,
    /// Paper's graph name.
    pub name: &'static str,
    pub class: Class,
    /// Vertex/edge counts from Table I (the original dataset).
    pub paper_n: usize,
    pub paper_m: usize,
    /// Size scale factor of our analog vs the paper's dataset (1 = full).
    pub scale: f64,
    build: fn() -> EdgeList,
}

impl Entry {
    /// Build (or load from cache) the canonical benchmark form: CSR with
    /// shuffled edge-list order (sequential order is unrepresentatively
    /// easy for asynchronous sweeps — see `Csr::shuffled_edges`).
    pub fn build(&self) -> Csr {
        let edges = match self.cached() {
            Some(e) => e,
            None => {
                let e = (self.build)();
                self.store_cache(&e);
                e
            }
        };
        edges.into_csr().shuffled_edges(0xC0FFEE ^ self.id as u64)
    }

    fn cache_path(&self) -> PathBuf {
        let dir = std::env::var("CONTOUR_CACHE").unwrap_or_else(|_| "results/graphcache".into());
        PathBuf::from(dir).join(format!("{:02}_{}.bin", self.id, self.name.replace('/', "_")))
    }

    fn cached(&self) -> Option<EdgeList> {
        io::read_bin(&self.cache_path()).ok()
    }

    fn store_cache(&self, e: &EdgeList) {
        let path = self.cache_path();
        if let Some(parent) = path.parent() {
            let _ = std::fs::create_dir_all(parent);
        }
        let _ = io::write_bin(&path, e);
    }
}

macro_rules! entry {
    ($id:expr, $name:expr, $class:expr, $pn:expr, $pm:expr, $scale:expr, $build:expr) => {
        Entry {
            id: $id,
            name: $name,
            class: $class,
            paper_n: $pn,
            paper_m: $pm,
            scale: $scale,
            build: $build,
        }
    };
}

/// The full corpus, one entry per Table I row (delaunay capped at n20:
/// n21..n24 exceed the sandbox generation budget; the scaling fit in
/// `delaunay-scaling` extrapolates the trend instead).
pub fn corpus() -> Vec<Entry> {
    use Class::*;
    let mut v = vec![
        entry!(0, "ca-GrQc", PowerLaw, 5_242, 28_980, 1.0, || gen::barabasi_albert(5_242, 6, 100)),
        entry!(1, "ca-HepTh", PowerLaw, 9_877, 51_971, 1.0, || gen::barabasi_albert(9_877, 5, 101)),
        entry!(2, "facebook_combined", PowerLaw, 4_039, 88_234, 1.0, || {
            gen::barabasi_albert(4_039, 22, 102)
        }),
        entry!(3, "wiki", PowerLaw, 8_277, 103_689, 1.0, || {
            gen::rmat(13, 103_689, gen::RmatKind::Graph500, 103)
        }),
        entry!(4, "as-caida20071105", PowerLaw, 26_475, 106_762, 1.0, || {
            gen::barabasi_albert(26_475, 4, 104)
        }),
        entry!(5, "ca-CondMat", PowerLaw, 23_133, 186_936, 1.0, || {
            gen::barabasi_albert(23_133, 8, 105)
        }),
        entry!(6, "ca-HepPh", PowerLaw, 12_008, 237_010, 1.0, || {
            gen::barabasi_albert(12_008, 20, 106)
        }),
        entry!(7, "email-Enron", PowerLaw, 36_692, 367_662, 1.0, || {
            gen::rmat(15, 367_662, gen::RmatKind::Graph500, 107)
        }),
        entry!(8, "ca-AstroPh", PowerLaw, 18_772, 396_160, 1.0, || {
            gen::barabasi_albert(18_772, 21, 108)
        }),
        entry!(9, "loc-brightkite_edges", PowerLaw, 58_228, 428_156, 1.0, || {
            gen::barabasi_albert(58_228, 7, 109)
        }),
        entry!(10, "soc-Epinions1", PowerLaw, 75_879, 508_837, 1.0, || {
            gen::barabasi_albert(75_879, 7, 110)
        }),
        entry!(11, "com-dblp", PowerLaw, 317_080, 1_049_866, 1.0, || {
            gen::barabasi_albert(317_080, 3, 111)
        }),
        entry!(12, "com-youtube", PowerLaw, 1_134_890, 2_987_624, 0.5, || {
            gen::barabasi_albert(567_445, 3, 112)
        }),
        entry!(13, "amazon0601", PowerLaw, 403_394, 2_443_408, 1.0, || {
            gen::barabasi_albert(403_394, 6, 113)
        }),
        entry!(14, "soc-LiveJournal1", PowerLaw, 4_847_571, 68_993_773, 1.0 / 32.0, || {
            gen::rmat(17, 2_156_055, gen::RmatKind::Graph500, 114)
        }),
        entry!(15, "higgs-social_network", PowerLaw, 456_626, 14_855_842, 1.0 / 8.0, || {
            gen::rmat(16, 1_856_980, gen::RmatKind::Graph500, 115)
        }),
        entry!(16, "com-orkut", PowerLaw, 3_072_441, 117_185_083, 1.0 / 64.0, || {
            gen::rmat(16, 1_831_017, gen::RmatKind::Graph500, 116)
        }),
        entry!(17, "road_usa", Road, 23_947_347, 28_854_312, 1.0 / 24.0, || {
            gen::road(1_000, 1_000, 117)
        }),
        entry!(18, "kmer_A2a", Kmer, 170_728_175, 180_292_586, 1.0 / 170.0, || {
            gen::kmer_chains(1_800, 560, 118)
        }),
        entry!(19, "kmer_V1r", Kmer, 214_005_017, 232_705_452, 1.0 / 180.0, || {
            gen::kmer_chains(2_100, 560, 119)
        }),
        entry!(20, "uk_2002", Web, 18_520_486, 298_113_762, 1.0 / 128.0, || {
            gen::rmat(17, 2_329_013, gen::RmatKind::Web, 120)
        }),
    ];
    // delaunay_n10 .. n20 (paper ids 21..35 reach n24; we cap at n20).
    for (i, k) in (10u32..=20).enumerate() {
        let n = 1usize << k;
        // SuiteSparse Table I: edges ≈ 3n (triangulation).
        let paper_m = [
            3_056, 6_127, 12_264, 24_547, 49_122, 98_274, 196_575, 393_176, 786_396, 1_572_823,
            3_145_686,
        ][i];
        let name: &'static str = Box::leak(format!("delaunay_n{k}").into_boxed_str());
        v.push(Entry {
            id: 21 + i,
            name,
            class: Class::Delaunay,
            paper_n: n,
            paper_m,
            scale: 1.0,
            build: match k {
                10 => || gen::delaunay(1 << 10, 210),
                11 => || gen::delaunay(1 << 11, 211),
                12 => || gen::delaunay(1 << 12, 212),
                13 => || gen::delaunay(1 << 13, 213),
                14 => || gen::delaunay(1 << 14, 214),
                15 => || gen::delaunay(1 << 15, 215),
                16 => || gen::delaunay(1 << 16, 216),
                17 => || gen::delaunay(1 << 17, 217),
                18 => || gen::delaunay(1 << 18, 218),
                19 => || gen::delaunay(1 << 19, 219),
                _ => || gen::delaunay(1 << 20, 220),
            },
        });
    }
    v
}

/// Quick subset for smoke benches: the small power-law graphs, one road,
/// one kmer and the first few delaunay sizes.
pub fn quick_corpus() -> Vec<Entry> {
    corpus()
        .into_iter()
        .filter(|e| {
            matches!(e.id, 0..=6) || e.id == 17 || e.id == 18 || (21..=25).contains(&e.id)
        })
        .map(|mut e| {
            if e.id == 17 {
                e.build = || gen::road(250, 250, 117);
                e.scale /= 16.0;
            }
            if e.id == 18 {
                e.build = || gen::kmer_chains(450, 280, 118);
                e.scale /= 16.0;
            }
            e
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_matches_table1_layout() {
        let c = corpus();
        assert_eq!(c.len(), 32, "21 real-world analogs + delaunay n10..n20");
        // Ids unique and ascending.
        for w in c.windows(2) {
            assert!(w[0].id < w[1].id);
        }
        assert_eq!(c[17].class, Class::Road);
        assert_eq!(c[21].name, "delaunay_n10");
    }

    #[test]
    fn small_entries_build_with_plausible_sizes() {
        for e in corpus().into_iter().filter(|e| e.paper_m < 120_000 && e.scale == 1.0) {
            let g = e.build();
            let ratio = g.m() as f64 / e.paper_m as f64;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{}: m {} vs paper {}",
                e.name,
                g.m(),
                e.paper_m
            );
        }
    }

    #[test]
    fn quick_corpus_is_small() {
        let q = quick_corpus();
        assert!(q.len() >= 10 && q.len() <= 16);
    }

    #[test]
    fn cache_round_trip() {
        std::env::set_var("CONTOUR_CACHE", std::env::temp_dir().join("contour_suite_cache"));
        let e = &corpus()[0];
        let a = e.build();
        let b = e.build(); // second call hits the cache
        assert_eq!(a.src, b.src);
        std::env::remove_var("CONTOUR_CACHE");
    }
}
