//! Artifact registry: discovers the AOT-compiled HLO artifacts emitted by
//! `python/compile/aot.py` and selects size buckets.
//!
//! `artifacts/manifest.txt` has one line per artifact:
//! `<name> n=<n> m=<m> file=<file>` (m=0 for vertex-only artifacts).
//! HLO modules are shape-specialized, so the runtime picks the smallest
//! bucket that fits the live graph and pads (see python/compile/model.py
//! for why padding is correctness-neutral).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One AOT artifact (a size-specialized HLO module on disk).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Artifact {
    /// Logical computation name, e.g. `contour_iter_h2`.
    pub name: String,
    /// Vertex-bucket size (label array length).
    pub n: usize,
    /// Edge-bucket size (0 for vertex-only computations).
    pub m: usize,
    pub path: PathBuf,
}

impl Artifact {
    /// Cache key unique per (name, bucket).
    pub fn key(&self) -> String {
        format!("{}_n{}_m{}", self.name, self.n, self.m)
    }
}

/// Parsed manifest over one artifact directory.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    artifacts: Vec<Artifact>,
}

impl Registry {
    /// Load `<dir>/manifest.txt`. Missing files referenced by the
    /// manifest are an error (stale manifest).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("read {} (run `make artifacts`)", manifest.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut artifacts = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields = line.split_whitespace();
            let name = fields.next().context("artifact name")?.to_string();
            let mut n = None;
            let mut m = None;
            let mut file = None;
            for f in fields {
                match f.split_once('=') {
                    Some(("n", v)) => n = Some(v.parse::<usize>()?),
                    Some(("m", v)) => m = Some(v.parse::<usize>()?),
                    Some(("file", v)) => file = Some(v.to_string()),
                    _ => bail!("manifest line {}: bad field {f:?}", lineno + 1),
                }
            }
            let (n, m, file) = match (n, m, file) {
                (Some(n), Some(m), Some(f)) => (n, m, f),
                _ => bail!("manifest line {}: missing n=/m=/file=", lineno + 1),
            };
            let path = dir.join(&file);
            if !path.exists() {
                bail!("manifest references missing artifact {}", path.display());
            }
            artifacts.push(Artifact { name, n, m, path });
        }
        // Sort so `select` finds the smallest fitting bucket first.
        artifacts.sort_by_key(|a| (a.name.clone(), a.n, a.m));
        Ok(Self { artifacts })
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.artifacts.iter().map(|a| a.name.as_str()).collect();
        names.dedup();
        names
    }

    /// Smallest bucket of `name` with capacity for `n` vertices and `m`
    /// edges. `None` if the graph exceeds every bucket.
    pub fn select(&self, name: &str, n: usize, m: usize) -> Option<&Artifact> {
        self.artifacts
            .iter()
            .filter(|a| a.name == name && a.n >= n && a.m >= m)
            .min_by_key(|a| (a.n, a.m))
    }

    pub fn iter(&self) -> impl Iterator<Item = &Artifact> {
        self.artifacts.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_dir(files: &[&str]) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("contour_registry_{:p}", &files));
        std::fs::create_dir_all(&dir).unwrap();
        for f in files {
            std::fs::write(dir.join(f), "HloModule fake").unwrap();
        }
        dir
    }

    #[test]
    fn parses_and_selects_smallest_fitting() {
        let dir = fake_dir(&["a_small.hlo.txt", "a_big.hlo.txt"]);
        let text = "contour_iter_h2 n=1024 m=4096 file=a_small.hlo.txt\n\
                    contour_iter_h2 n=16384 m=65536 file=a_big.hlo.txt\n";
        let r = Registry::parse(text, &dir).unwrap();
        assert_eq!(r.len(), 2);
        let a = r.select("contour_iter_h2", 1000, 4000).unwrap();
        assert_eq!(a.n, 1024);
        let a = r.select("contour_iter_h2", 1000, 5000).unwrap();
        assert_eq!(a.n, 16384, "edge overflow must bump the bucket");
        assert!(r.select("contour_iter_h2", 1 << 20, 1).is_none());
        assert!(r.select("nope", 1, 1).is_none());
    }

    #[test]
    fn rejects_missing_file_and_bad_lines() {
        let dir = fake_dir(&[]);
        assert!(Registry::parse("x n=1 m=1 file=gone.hlo.txt", &dir).is_err());
        let dir = fake_dir(&["ok.hlo.txt"]);
        assert!(Registry::parse("x n=1 file=ok.hlo.txt", &dir).is_err());
        assert!(Registry::parse("x n=1 m=2 file=ok.hlo.txt junk", &dir).is_err());
    }

    #[test]
    fn vertex_only_artifacts() {
        let dir = fake_dir(&["c.hlo.txt"]);
        let r = Registry::parse("compress n=1024 m=0 file=c.hlo.txt", &dir).unwrap();
        assert!(r.select("compress", 512, 0).is_some());
        assert_eq!(r.names(), vec!["compress"]);
    }
}
