//! PJRT runtime: loads the AOT HLO artifacts and executes them on the
//! CPU PJRT client from the Rust hot path (Python is never involved).
//!
//! Pipeline per artifact: HLO text → `HloModuleProto::from_text_file` →
//! `XlaComputation` → `PjRtClient::compile` (cached) → `execute`.
//! Interchange is HLO *text* because jax ≥ 0.5 emits 64-bit instruction
//! ids that xla_extension 0.5.1 rejects in serialized protos.

pub mod registry;
mod xla_stub;

// The image carries no XLA/PJRT binding crate, so the runtime compiles
// against the API-compatible stub (see xla_stub.rs). To use a real
// binding: add the dependency and replace this alias with `use xla;`.
use xla_stub as xla;

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

pub use registry::{Artifact, Registry};

use crate::VId;

/// A PJRT CPU execution context with a compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    registry: Registry,
    compiled: RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Create a CPU PJRT client over the artifact directory (default
    /// `artifacts/`, or `$CONTOUR_ARTIFACTS`).
    pub fn new(artifact_dir: &std::path::Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let registry = Registry::load(artifact_dir)?;
        Ok(Self { client, registry, compiled: RefCell::new(HashMap::new()) })
    }

    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("CONTOUR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::new(std::path::Path::new(&dir))
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) one artifact.
    fn ensure_compiled(&self, art: &Artifact) -> Result<()> {
        if self.compiled.borrow().contains_key(&art.key()) {
            return Ok(());
        }
        let path_str = art
            .path
            .to_str()
            .with_context(|| format!("non-utf8 path {}", art.path.display()))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parse HLO {}: {e:?}", art.path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e:?}", art.key()))?;
        self.compiled.borrow_mut().insert(art.key(), exe);
        Ok(())
    }

    /// Execute `art` with 1-D i32 inputs; returns the flattened tuple of
    /// i32 outputs. All our artifacts are (i32[...], ...) -> tuple.
    pub fn exec_i32(&self, art: &Artifact, inputs: &[Vec<i32>]) -> Result<Vec<Vec<i32>>> {
        self.ensure_compiled(art)?;
        let compiled = self.compiled.borrow();
        let exe = compiled.get(&art.key()).expect("just compiled");
        let literals: Vec<xla::Literal> = inputs.iter().map(|v| xla::Literal::vec1(v)).collect();
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", art.key()))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result {}: {e:?}", art.key()))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = out.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

/// Padded problem instance matching an artifact's (n, m) bucket.
#[derive(Clone, Debug)]
pub struct PaddedGraph {
    /// Real vertex count (labels beyond this are padding).
    pub n_real: usize,
    pub labels: Vec<i32>,
    pub src: Vec<i32>,
    pub dst: Vec<i32>,
}

impl PaddedGraph {
    /// Pad `g` to the bucket (n_pad, m_pad): padding vertices are
    /// self-labelled singletons, padding edges are (0, 0) self-loops —
    /// both correctness-neutral (python/compile/model.py docstring).
    pub fn new(g: &crate::graph::Csr, n_pad: usize, m_pad: usize) -> Result<Self> {
        anyhow::ensure!(g.n <= n_pad, "graph n {} exceeds bucket {}", g.n, n_pad);
        anyhow::ensure!(g.m() <= m_pad, "graph m {} exceeds bucket {}", g.m(), m_pad);
        let labels: Vec<i32> = (0..n_pad as i32).collect();
        let mut src: Vec<i32> = g.src.iter().map(|&x| x as i32).collect();
        let mut dst: Vec<i32> = g.dst.iter().map(|&x| x as i32).collect();
        src.resize(m_pad, 0);
        dst.resize(m_pad, 0);
        Ok(Self { n_real: g.n, labels, src, dst })
    }

    /// Strip padding and convert labels back to `VId`.
    pub fn unpad(&self, labels: &[i32]) -> Vec<VId> {
        labels[..self.n_real].iter().map(|&x| x as VId).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    fn runtime() -> Option<Runtime> {
        // Integration-level tests need built artifacts; skip quietly when
        // `make artifacts` has not run (pure-unit CI).
        Runtime::from_env().ok()
    }

    #[test]
    fn padded_graph_layout() {
        let g = gen::path(5).into_csr();
        let p = PaddedGraph::new(&g, 8, 16).unwrap();
        assert_eq!(p.labels, (0..8).collect::<Vec<i32>>());
        assert_eq!(&p.src[..4], &[0, 1, 2, 3]);
        assert_eq!(&p.src[4..], &[0; 12]);
        assert_eq!(p.unpad(&p.labels), vec![0, 1, 2, 3, 4]);
        assert!(PaddedGraph::new(&g, 4, 16).is_err());
        assert!(PaddedGraph::new(&g, 8, 2).is_err());
    }

    #[test]
    fn contour_iter_artifact_executes() {
        let Some(rt) = runtime() else { return };
        let g = gen::path(100).into_csr();
        let art = rt.registry().select("contour_iter_h2", g.n, g.m()).expect("bucket");
        let p = PaddedGraph::new(&g, art.n, art.m).unwrap();
        let out = rt
            .exec_i32(art, &[p.labels.clone(), p.src.clone(), p.dst.clone()])
            .expect("execute");
        assert_eq!(out.len(), 2, "(labels, changed)");
        assert_eq!(out[0].len(), art.n);
        assert_eq!(out[1], vec![1], "first iteration must report change");
        // Labels must only decrease.
        assert!(out[0].iter().zip(&p.labels).all(|(&a, &b)| a <= b));
    }

    #[test]
    fn contour_run_artifact_converges() {
        let Some(rt) = runtime() else { return };
        let g = gen::path(64).into_csr();
        let art = rt.registry().select("contour_run_h2", g.n, g.m()).expect("bucket");
        let p = PaddedGraph::new(&g, art.n, art.m).unwrap();
        let out =
            rt.exec_i32(art, &[p.labels.clone(), p.src.clone(), p.dst.clone()]).expect("execute");
        let labels = p.unpad(&out[0]);
        assert!(labels.iter().all(|&l| l == 0), "path must collapse to 0");
        let iters = out[1][0];
        assert!((1..=64).contains(&iters), "iters {iters}");
    }
}
