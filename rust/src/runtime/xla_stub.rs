//! API-compatible stand-in for the `xla` PJRT binding crate.
//!
//! The sandbox image has no XLA/PJRT Rust binding in its crate cache, so
//! the runtime compiles against this stub instead of an external `xla`
//! dependency (`runtime/mod.rs` does `use xla_stub as xla;`). The stub
//! mirrors exactly the API surface the runtime touches; every entry
//! point fails at `PjRtClient::cpu()` with a clear error, which callers
//! already treat as "PJRT unavailable" (tests skip, `contour list`
//! prints the reason). Swapping in a real binding is a two-line change
//! at the top of `runtime/mod.rs` plus a Cargo dependency.
//!
//! Types that can never be constructed here carry an
//! [`std::convert::Infallible`] field, so the methods unreachable
//! without a client are still fully type-checked (`match self.0 {}`).

use std::convert::Infallible;

/// Error type matching how the runtime consumes binding errors: opaque,
/// formatted with `{:?}`.
#[derive(Debug)]
pub struct XlaError(pub String);

fn unavailable() -> XlaError {
    XlaError(
        "PJRT runtime unavailable: built against the xla stub (no XLA binding crate in \
         this image); run the native engine instead"
            .to_string(),
    )
}

/// Stand-in for the PJRT CPU client. Never constructible.
pub struct PjRtClient(Infallible);

impl PjRtClient {
    pub fn cpu() -> Result<Self, XlaError> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        match self.0 {}
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        match self.0 {}
    }
}

/// Stand-in for a compiled executable. Never constructible.
pub struct PjRtLoadedExecutable(Infallible);

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        match self.0 {}
    }
}

/// Stand-in for a device buffer. Never constructible.
pub struct PjRtBuffer(Infallible);

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        match self.0 {}
    }
}

/// Stand-in for a parsed HLO module. Never constructible.
pub struct HloModuleProto(Infallible);

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, XlaError> {
        Err(unavailable())
    }
}

/// Stand-in for an XLA computation. Never constructible.
pub struct XlaComputation(Infallible);

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        match proto.0 {}
    }
}

/// Host literal. Constructible (it wraps host data in the real binding)
/// but inert: the stub never executes, so conversions are unreachable in
/// practice and report unavailability if ever called directly.
pub struct Literal(());

impl Literal {
    pub fn vec1(_data: &[i32]) -> Literal {
        Literal(())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, XlaError> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        Err(unavailable())
    }
}
