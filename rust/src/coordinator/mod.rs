//! L3 coordination: the engine abstraction over native and PJRT
//! execution, the §IV-E operator-selection policy, an algorithm factory,
//! and a job coordinator that drives batches of connectivity requests
//! across a worker pool with metrics.

use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::cc::{self, contour::Contour, contour::FrontierMode, Algorithm, RunResult};
use crate::graph::{stats::GraphStats, Csr};
use crate::runtime::{PaddedGraph, Runtime};
use crate::util::Timer;

// ---------------------------------------------------------------- PJRT engine

/// How the PJRT engine drives iterations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PjrtMode {
    /// One `contour_iter` dispatch per iteration; the Rust coordinator
    /// owns the convergence loop (inspectable, schedulable).
    PerIteration,
    /// One `contour_run` dispatch: the while-loop runs on-device and only
    /// the converged labels come back (minimal dispatch overhead).
    FusedRun,
}

/// Contour executed through the AOT HLO artifacts (L2+L1) on the PJRT CPU
/// client. Demonstrates the accelerator formulation; the native engine
/// remains the CPU performance path.
pub struct PjrtContour<'rt> {
    rt: &'rt Runtime,
    pub hops: usize,
    pub mode: PjrtMode,
    pub max_iters: usize,
}

impl<'rt> PjrtContour<'rt> {
    pub fn new(rt: &'rt Runtime, hops: usize, mode: PjrtMode) -> Self {
        // PerIteration loops in Rust, so it can afford C-1-style iteration
        // counts; FusedRun is bounded by the artifact's on-device
        // `max_iters` (64 — ample for h >= 2 by Theorem 1, but C-1 on a
        // large-diameter graph needs PerIteration).
        let max_iters = match mode {
            PjrtMode::PerIteration => 100_000,
            PjrtMode::FusedRun => 64,
        };
        Self { rt, hops, mode, max_iters }
    }
}

impl Algorithm for PjrtContour<'_> {
    fn name(&self) -> String {
        match self.mode {
            PjrtMode::PerIteration => format!("PJRT-C{}-step", self.hops),
            PjrtMode::FusedRun => format!("PJRT-C{}-run", self.hops),
        }
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        self.try_run(g).expect("PJRT execution failed")
    }
}

impl PjrtContour<'_> {
    pub fn try_run(&self, g: &Csr) -> Result<RunResult> {
        let (iter_name, run_name) =
            (format!("contour_iter_h{}", self.hops), format!("contour_run_h{}", self.hops));
        match self.mode {
            PjrtMode::FusedRun => {
                let art = self
                    .rt
                    .registry()
                    .select(&run_name, g.n, g.m())
                    .ok_or_else(|| anyhow!("no bucket fits n={} m={} for {run_name}", g.n, g.m()))?;
                let p = PaddedGraph::new(g, art.n, art.m)?;
                let out = self.rt.exec_i32(art, &[p.labels.clone(), p.src.clone(), p.dst.clone()])?;
                Ok(RunResult::new(p.unpad(&out[0]), out[1][0].max(1) as usize))
            }
            PjrtMode::PerIteration => {
                let art = self
                    .rt
                    .registry()
                    .select(&iter_name, g.n, g.m())
                    .ok_or_else(|| anyhow!("no bucket fits n={} m={} for {iter_name}", g.n, g.m()))?;
                let p = PaddedGraph::new(g, art.n, art.m)?;
                let mut labels = p.labels.clone();
                let mut iters = 0usize;
                loop {
                    iters += 1;
                    let out = self.rt.exec_i32(art, &[labels, p.src.clone(), p.dst.clone()])?;
                    let changed = out[1][0] != 0;
                    labels = out.into_iter().next().unwrap();
                    if !changed || iters >= self.max_iters {
                        break;
                    }
                }
                Ok(RunResult::new(p.unpad(&labels), iters))
            }
        }
    }
}

// ------------------------------------------------------------------- policy

/// §IV-E operator-selection guidance as an executable policy:
/// small low-diameter graphs → C-1; mixed-diameter component soups →
/// C-11mm; large diameter → C-m; everything else → C-2 ("a stable and
/// simple operator that fits well in most cases").
pub fn auto_select(stats: &GraphStats) -> Contour {
    let small = stats.m < 200_000;
    let low_diameter = stats.pseudo_diameter <= 16;
    let huge_diameter = stats.pseudo_diameter >= 256;
    // "Mixed": a sizable fraction of vertices lives outside the largest
    // component (not just isolated-vertex dust), alongside a big one.
    let mixed = stats.num_components > 8
        && stats.largest_component * 2 > stats.n
        && (stats.n - stats.largest_component) * 20 > stats.n;
    if small && low_diameter {
        Contour::c1()
    } else if huge_diameter {
        Contour::cm()
    } else if mixed {
        Contour::c11mm()
    } else {
        Contour::c2()
    }
}

// ------------------------------------------------------------------ factory

/// Algorithm registry by figure-legend name. `threads` = 0 for default.
pub fn algorithm_by_name(name: &str, threads: usize) -> Result<Box<dyn Algorithm + Send + Sync>> {
    algorithm_by_name_with(name, threads, None)
}

/// [`algorithm_by_name`] with an explicit Contour frontier engine:
/// `Some(mode)` pins the mode on every Contour variant (non-Contour
/// algorithms have no frontier and ignore it); `None` keeps the
/// `CONTOUR_FRONTIER` environment default. This is what the server's
/// `CC name alg [exact|chunk|off]` verb and the CLI's `--frontier`
/// option resolve through.
pub fn algorithm_by_name_with(
    name: &str,
    threads: usize,
    frontier: Option<FrontierMode>,
) -> Result<Box<dyn Algorithm + Send + Sync>> {
    let contour = |c: Contour| -> Box<dyn Algorithm + Send + Sync> {
        let c = c.with_threads(threads);
        Box::new(match frontier {
            Some(mode) => c.with_frontier_mode(mode),
            None => c,
        })
    };
    let alg: Box<dyn Algorithm + Send + Sync> = match name {
        "C-1" => contour(Contour::c1()),
        "C-2" => contour(Contour::c2()),
        "C-m" => contour(Contour::cm()),
        "C-11mm" => contour(Contour::c11mm()),
        "C-1m1m" => contour(Contour::c1m1m()),
        "C-Syn" => contour(Contour::csyn()),
        "FastSV" => Box::new(cc::fastsv::FastSv::new().with_threads(threads)),
        "SV" => Box::new(cc::sv::ShiloachVishkin::new()),
        "ConnectIt" => Box::new(cc::unionfind::RemConcurrent::new().with_threads(threads)),
        "Rem-seq" => Box::new(cc::unionfind::RemSequential),
        "UF-rank" => Box::new(cc::unionfind::RankUnionFind),
        "BFS-seq" => Box::new(cc::bfs::BfsCc::sequential()),
        "BFS-par" => Box::new(cc::bfs::BfsCc::parallel()),
        "LabelProp" => Box::new(cc::labelprop::LabelPropagation::new()),
        "Afforest" => Box::new(cc::afforest::Afforest { threads, ..Default::default() }),
        other => return Err(anyhow!("unknown algorithm {other:?} (see `contour list`)")),
    };
    Ok(alg)
}

/// Names accepted by [`algorithm_by_name`], figure-legend order first.
pub const ALGORITHM_NAMES: &[&str] = &[
    "C-1", "C-2", "C-m", "C-11mm", "C-1m1m", "C-Syn", "FastSV", "ConnectIt", "SV", "Rem-seq",
    "UF-rank", "BFS-seq", "BFS-par", "LabelProp", "Afforest",
];

// -------------------------------------------------------------- coordinator

/// One connectivity request.
#[derive(Clone, Debug)]
pub struct Job {
    pub id: usize,
    /// Algorithm name ([`ALGORITHM_NAMES`]) or "auto" for the §IV-E policy.
    pub algorithm: String,
    pub graph_name: String,
}

/// Completed job metrics.
#[derive(Clone, Debug)]
pub struct JobReport {
    pub id: usize,
    pub algorithm: String,
    pub graph_name: String,
    pub components: usize,
    pub iterations: usize,
    pub millis: f64,
}

/// Batch coordinator: drains a job queue across `workers` threads, each
/// job running its algorithm (itself parallel — worker count × algorithm
/// threads is the caller's budget to split).
pub struct Coordinator {
    pub workers: usize,
    pub algorithm_threads: usize,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self { workers: 1, algorithm_threads: 0 }
    }
}

impl Coordinator {
    /// Run all jobs against graphs resolved by `lookup`. Jobs execute in
    /// queue order per worker; reports return in completion order.
    pub fn run_batch<'g, F>(&self, jobs: Vec<Job>, lookup: F) -> Result<Vec<JobReport>>
    where
        F: Fn(&str) -> Option<&'g Csr> + Sync,
    {
        let queue = Mutex::new(jobs.into_iter().collect::<std::collections::VecDeque<_>>());
        let reports = Mutex::new(Vec::new());
        let errors = Mutex::new(Vec::<String>::new());
        std::thread::scope(|s| {
            for _ in 0..self.workers.max(1) {
                s.spawn(|| loop {
                    let job = match queue.lock().unwrap().pop_front() {
                        Some(j) => j,
                        None => break,
                    };
                    let Some(g) = lookup(&job.graph_name) else {
                        errors.lock().unwrap().push(format!("job {}: unknown graph {}", job.id, job.graph_name));
                        continue;
                    };
                    let alg: Box<dyn Algorithm + Send + Sync> = if job.algorithm == "auto" {
                        Box::new(auto_select(&crate::graph::stats::stats(g))
                            .with_threads(self.algorithm_threads))
                    } else {
                        match algorithm_by_name(&job.algorithm, self.algorithm_threads) {
                            Ok(a) => a,
                            Err(e) => {
                                errors.lock().unwrap().push(format!("job {}: {e}", job.id));
                                continue;
                            }
                        }
                    };
                    let t = Timer::start();
                    let result = alg.run_with_stats(g);
                    reports.lock().unwrap().push(JobReport {
                        id: job.id,
                        algorithm: alg.name(),
                        graph_name: job.graph_name.clone(),
                        components: cc::num_components(&result.labels),
                        iterations: result.iterations,
                        millis: t.ms(),
                    });
                });
            }
        });
        let errors = errors.into_inner().unwrap();
        if !errors.is_empty() {
            return Err(anyhow!("coordinator errors: {}", errors.join("; ")));
        }
        Ok(reports.into_inner().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{gen, stats};

    #[test]
    fn policy_follows_paper_guidance() {
        let small_low = stats::stats(&gen::star(500).into_csr());
        assert_eq!(auto_select(&small_low).name(), "C-1");
        let huge_diam = stats::stats(&gen::path(5000).into_csr());
        assert_eq!(auto_select(&huge_diam).name(), "C-m");
        let soup = stats::stats(&gen::component_soup(20, 100, 1).into_csr());
        // soup: many comps but no dominant one -> falls through to C-2/C-m
        let chosen = auto_select(&soup).name();
        assert!(chosen == "C-2" || chosen == "C-m" || chosen == "C-11mm", "{chosen}");
        let mid = stats::stats(&gen::erdos_renyi(300_000, 900_000, 2).into_csr());
        assert_eq!(auto_select(&mid).name(), "C-2");
    }

    #[test]
    fn factory_knows_every_name() {
        for name in ALGORITHM_NAMES {
            let alg = algorithm_by_name(name, 1).unwrap();
            assert_eq!(&alg.name(), name);
        }
        assert!(algorithm_by_name("nope", 1).is_err());
    }

    #[test]
    fn factory_applies_frontier_mode() {
        let g = gen::path(300).into_csr().shuffled_edges(3);
        let want = algorithm_by_name_with("C-2", 1, Some(FrontierMode::Off)).unwrap().run(&g);
        for mode in [FrontierMode::Chunk, FrontierMode::Exact] {
            let got = algorithm_by_name_with("C-2", 1, Some(mode)).unwrap().run(&g);
            assert_eq!(got, want, "C-2 diverges under {} via the factory", mode.as_str());
        }
        // Non-Contour algorithms have no frontier: the mode is ignored,
        // not an error (one verb syntax serves every algorithm).
        let uf = algorithm_by_name_with("ConnectIt", 1, Some(FrontierMode::Exact)).unwrap();
        assert_eq!(uf.run(&g), want);
    }

    #[test]
    fn batch_runs_jobs_and_reports() {
        let g1 = gen::path(200).into_csr();
        let g2 = gen::component_soup(3, 50, 2).into_csr();
        let lookup = |name: &str| match name {
            "path" => Some(&g1),
            "soup" => Some(&g2),
            _ => None,
        };
        let jobs = vec![
            Job { id: 0, algorithm: "C-2".into(), graph_name: "path".into() },
            Job { id: 1, algorithm: "ConnectIt".into(), graph_name: "soup".into() },
            Job { id: 2, algorithm: "auto".into(), graph_name: "path".into() },
        ];
        let coord = Coordinator { workers: 2, algorithm_threads: 1 };
        let mut reports = coord.run_batch(jobs, lookup).unwrap();
        reports.sort_by_key(|r| r.id);
        assert_eq!(reports.len(), 3);
        assert_eq!(reports[0].components, 1);
        assert_eq!(reports[1].components, 3);
        assert!(reports[1].iterations == 1);
    }

    #[test]
    fn batch_surfaces_errors() {
        let g = gen::path(10).into_csr();
        let jobs = vec![Job { id: 0, algorithm: "bogus".into(), graph_name: "g".into() }];
        let coord = Coordinator::default();
        assert!(coord.run_batch(jobs, |_| Some(&g)).is_err());
        let jobs = vec![Job { id: 0, algorithm: "C-2".into(), graph_name: "missing".into() }];
        assert!(coord.run_batch(jobs, |_| None).is_err());
    }
}
