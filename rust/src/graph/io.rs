//! Graph file I/O: MatrixMarket (`.mtx`, the SuiteSparse format the paper's
//! Table I graphs ship in) and SNAP whitespace edge lists.

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::EdgeList;
use crate::VId;

/// Parse a MatrixMarket coordinate file as an undirected graph.
///
/// Accepts `%%MatrixMarket matrix coordinate <field> <symmetry>`; entry
/// values (if present) are ignored — only the sparsity pattern matters for
/// connectivity. Indices are 1-based per the format.
pub fn read_mtx(path: &Path) -> Result<EdgeList> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    parse_mtx(BufReader::new(f))
}

pub fn parse_mtx<R: BufRead>(reader: R) -> Result<EdgeList> {
    let mut lines = reader.lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if l.starts_with("%%MatrixMarket") {
                    break l;
                } else if !l.trim().is_empty() {
                    bail!("missing %%MatrixMarket header");
                }
            }
            None => bail!("empty mtx file"),
        }
    };
    let lower = header.to_ascii_lowercase();
    if !lower.contains("coordinate") {
        bail!("only coordinate (sparse) MatrixMarket supported: {header}");
    }
    // Dimensions line: first non-comment line.
    let dims = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                let t = l.trim();
                if !t.is_empty() && !t.starts_with('%') {
                    break t.to_string();
                }
            }
            None => bail!("mtx file has no dimensions line"),
        }
    };
    let mut it = dims.split_whitespace();
    let rows: usize = it.next().context("rows")?.parse()?;
    let cols: usize = it.next().context("cols")?.parse()?;
    let nnz: usize = it.next().context("nnz")?.parse()?;
    let n = rows.max(cols);
    let mut edges = EdgeList::with_capacity(n, nnz);
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut fields = t.split_whitespace();
        let i: usize = fields.next().context("row index")?.parse()?;
        let j: usize = fields.next().context("col index")?.parse()?;
        if i == 0 || j == 0 || i > n || j > n {
            bail!("mtx index out of range: {i} {j} (n = {n})");
        }
        edges.push((i - 1) as VId, (j - 1) as VId);
    }
    if edges.len() != nnz {
        bail!("mtx declared {nnz} entries, found {}", edges.len());
    }
    Ok(edges)
}

/// Write a pattern symmetric MatrixMarket file.
pub fn write_mtx(path: &Path, g: &EdgeList) -> Result<()> {
    let f = File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate pattern symmetric")?;
    writeln!(w, "{} {} {}", g.n, g.n, g.len())?;
    for (u, v) in g.iter() {
        writeln!(w, "{} {}", u + 1, v + 1)?;
    }
    Ok(())
}

/// Parse a SNAP-style edge list: `#` comment lines, then one
/// whitespace-separated vertex pair per line. Vertex ids may be arbitrary
/// (non-contiguous); they are compacted to `0..n` preserving order of
/// first appearance.
pub fn read_snap(path: &Path) -> Result<EdgeList> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    parse_snap(BufReader::new(f))
}

pub fn parse_snap<R: BufRead>(reader: R) -> Result<EdgeList> {
    let mut remap = std::collections::HashMap::<u64, VId>::new();
    let mut pairs = Vec::<(VId, VId)>::new();
    let intern = |raw: u64, remap: &mut std::collections::HashMap<u64, VId>| -> VId {
        let next = remap.len() as VId;
        *remap.entry(raw).or_insert(next)
    };
    for line in reader.lines() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('#') || t.starts_with('%') {
            continue;
        }
        let mut fields = t.split_whitespace();
        let a: u64 = fields.next().context("src")?.parse()?;
        let b: u64 = match fields.next() {
            Some(x) => x.parse()?,
            None => bail!("edge line with a single field: {t}"),
        };
        let u = intern(a, &mut remap);
        let v = intern(b, &mut remap);
        pairs.push((u, v));
    }
    Ok(EdgeList::from_pairs(remap.len(), &pairs))
}

/// Write a SNAP-style edge list.
pub fn write_snap(path: &Path, g: &EdgeList) -> Result<()> {
    let f = File::create(path)?;
    let mut w = BufWriter::new(f);
    writeln!(w, "# contour edge list: n={} m={}", g.n, g.len())?;
    for (u, v) in g.iter() {
        writeln!(w, "{u}\t{v}")?;
    }
    Ok(())
}

/// Load by extension: `.mtx` => MatrixMarket, `.bin` => the fast binary
/// cache format, anything else => SNAP.
pub fn read_auto(path: &Path) -> Result<EdgeList> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("mtx") => read_mtx(path),
        Some("bin") => read_bin(path),
        _ => read_snap(path),
    }
}

const BIN_MAGIC: &[u8; 8] = b"CONTOUR1";

/// Fast binary edge-list cache (used by the bench suite so large
/// generated graphs build once): magic, n: u64, m: u64, src[u32; m],
/// dst[u32; m], little-endian.
pub fn write_bin(path: &Path, g: &EdgeList) -> Result<()> {
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(BIN_MAGIC)?;
    w.write_all(&(g.n as u64).to_le_bytes())?;
    w.write_all(&(g.len() as u64).to_le_bytes())?;
    for &x in &g.src {
        w.write_all(&x.to_le_bytes())?;
    }
    for &x in &g.dst {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

pub fn read_bin(path: &Path) -> Result<EdgeList> {
    let data = std::fs::read(path).with_context(|| format!("open {}", path.display()))?;
    if data.len() < 24 || &data[..8] != BIN_MAGIC {
        bail!("{}: not a contour binary graph", path.display());
    }
    let n = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
    let m = u64::from_le_bytes(data[16..24].try_into().unwrap()) as usize;
    if data.len() != 24 + 8 * m {
        bail!("{}: truncated binary graph", path.display());
    }
    let words = |off: usize| -> Vec<VId> {
        data[off..off + 4 * m]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    };
    let src = words(24);
    let dst = words(24 + 4 * m);
    if src.iter().chain(&dst).any(|&x| x as usize >= n) {
        bail!("{}: vertex id out of range", path.display());
    }
    Ok(EdgeList { n, src, dst })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn mtx_round_trip() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n% comment\n4 4 3\n1 2\n2 3\n4 1\n";
        let g = parse_mtx(Cursor::new(text)).unwrap();
        assert_eq!(g.n, 4);
        let pairs: Vec<_> = g.iter().collect();
        assert_eq!(pairs, vec![(0, 1), (1, 2), (3, 0)]);
    }

    #[test]
    fn mtx_with_values_field() {
        let text = "%%MatrixMarket matrix coordinate real general\n3 3 2\n1 2 0.5\n3 1 1.5\n";
        let g = parse_mtx(Cursor::new(text)).unwrap();
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn mtx_rejects_bad_header_and_indices() {
        assert!(parse_mtx(Cursor::new("garbage\n1 1 0\n")).is_err());
        let bad = "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n3 1\n";
        assert!(parse_mtx(Cursor::new(bad)).is_err());
        let short = "%%MatrixMarket matrix coordinate pattern general\n2 2 5\n1 2\n";
        assert!(parse_mtx(Cursor::new(short)).is_err());
    }

    #[test]
    fn snap_compacts_ids() {
        let text = "# a comment\n100 200\n200 300\n100\t300\n";
        let g = parse_snap(Cursor::new(text)).unwrap();
        assert_eq!(g.n, 3);
        let pairs: Vec<_> = g.iter().collect();
        assert_eq!(pairs, vec![(0, 1), (1, 2), (0, 2)]);
    }

    #[test]
    fn snap_rejects_single_field() {
        assert!(parse_snap(Cursor::new("1\n")).is_err());
    }

    #[test]
    fn file_round_trips() {
        let dir = std::env::temp_dir().join("contour_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let g = EdgeList::from_pairs(5, &[(0, 1), (2, 3), (3, 4)]);

        let mtx = dir.join("g.mtx");
        write_mtx(&mtx, &g).unwrap();
        let back = read_auto(&mtx).unwrap();
        assert_eq!(back.iter().collect::<Vec<_>>(), g.iter().collect::<Vec<_>>());

        let snap = dir.join("g.txt");
        write_snap(&snap, &g).unwrap();
        let back = read_auto(&snap).unwrap();
        assert_eq!(back.len(), g.len());
    }

    #[test]
    fn bin_round_trip_and_validation() {
        let dir = std::env::temp_dir().join("contour_io_bin");
        std::fs::create_dir_all(&dir).unwrap();
        let g = EdgeList::from_pairs(1000, &[(0, 999), (5, 7), (999, 0)]);
        let p = dir.join("g.bin");
        write_bin(&p, &g).unwrap();
        let back = read_auto(&p).unwrap();
        assert_eq!(back.n, g.n);
        assert_eq!(back.src, g.src);
        assert_eq!(back.dst, g.dst);
        // Corrupt: truncate.
        std::fs::write(dir.join("bad.bin"), b"CONTOUR1short").unwrap();
        assert!(read_bin(&dir.join("bad.bin")).is_err());
    }
}
