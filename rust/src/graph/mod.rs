//! Graph substrate: edge lists, CSR adjacency, loaders, generators and
//! statistics.
//!
//! The Contour family and FastSV iterate over an *edge list* (the paper's
//! `forall e in E`); BFS / Afforest / statistics need CSR adjacency. A
//! [`Csr`] carries both views over the same deduplicated undirected edge
//! set.

pub mod gen;
pub mod io;
pub mod stats;
pub mod transform;

use crate::VId;

/// An undirected multigraph as a raw edge list (possibly with duplicates
/// and self-loops); the mutable construction stage.
#[derive(Clone, Debug, Default)]
pub struct EdgeList {
    /// Number of vertices (ids are `0..n`).
    pub n: usize,
    pub src: Vec<VId>,
    pub dst: Vec<VId>,
}

impl EdgeList {
    pub fn new(n: usize) -> Self {
        Self { n, src: Vec::new(), dst: Vec::new() }
    }

    pub fn with_capacity(n: usize, m: usize) -> Self {
        Self { n, src: Vec::with_capacity(m), dst: Vec::with_capacity(m) }
    }

    pub fn from_pairs(n: usize, pairs: &[(VId, VId)]) -> Self {
        let mut e = Self::with_capacity(n, pairs.len());
        for &(u, v) in pairs {
            e.push(u, v);
        }
        e
    }

    #[inline]
    pub fn push(&mut self, u: VId, v: VId) {
        debug_assert!((u as usize) < self.n && (v as usize) < self.n);
        self.src.push(u);
        self.dst.push(v);
    }

    pub fn len(&self) -> usize {
        self.src.len()
    }

    pub fn is_empty(&self) -> bool {
        self.src.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (VId, VId)> + '_ {
        self.src.iter().copied().zip(self.dst.iter().copied())
    }

    /// Canonicalize: drop self-loops, orient u < v, sort, dedup.
    pub fn dedup(mut self) -> Self {
        let mut pairs: Vec<(VId, VId)> = self
            .iter()
            .filter(|&(u, v)| u != v)
            .map(|(u, v)| if u < v { (u, v) } else { (v, u) })
            .collect();
        pairs.sort_unstable();
        pairs.dedup();
        self.src.clear();
        self.dst.clear();
        for (u, v) in pairs {
            self.src.push(u);
            self.dst.push(v);
        }
        self
    }

    /// Build the CSR (symmetrized) view; implies [`EdgeList::dedup`].
    pub fn into_csr(self) -> Csr {
        Csr::from_edges(self.dedup())
    }
}

/// Deduplicated undirected graph: edge list + symmetrized CSR adjacency.
#[derive(Clone, Debug)]
pub struct Csr {
    pub n: usize,
    /// Unique undirected edges, oriented `src[i] < dst[i]`, sorted.
    pub src: Vec<VId>,
    pub dst: Vec<VId>,
    /// CSR offsets over the symmetrized adjacency, `offsets.len() == n+1`.
    pub offsets: Vec<usize>,
    /// Symmetrized neighbor array, `adj.len() == 2 * m`.
    pub adj: Vec<VId>,
}

impl Csr {
    /// Build from a canonical (deduped) edge list.
    fn from_edges(e: EdgeList) -> Self {
        let n = e.n;
        let m = e.len();
        let mut degree = vec![0usize; n];
        for (u, v) in e.iter() {
            degree[u as usize] += 1;
            degree[v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + degree[i];
        }
        let mut cursor = offsets.clone();
        let mut adj = vec![0 as VId; 2 * m];
        for (u, v) in e.iter() {
            adj[cursor[u as usize]] = v;
            cursor[u as usize] += 1;
            adj[cursor[v as usize]] = u;
            cursor[v as usize] += 1;
        }
        Csr { n, src: e.src, dst: e.dst, offsets, adj }
    }

    /// Number of unique undirected edges.
    pub fn m(&self) -> usize {
        self.src.len()
    }

    #[inline]
    pub fn neighbors(&self, v: VId) -> &[VId] {
        &self.adj[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    #[inline]
    pub fn degree(&self, v: VId) -> usize {
        self.offsets[v as usize + 1] - self.offsets[v as usize]
    }

    pub fn edges(&self) -> impl Iterator<Item = (VId, VId)> + '_ {
        self.src.iter().copied().zip(self.dst.iter().copied())
    }

    /// Deterministically shuffle the *edge-list view* (adjacency is
    /// untouched). `into_csr` sorts edges during dedup, which makes
    /// sequential-id generators (paths, grids) artificially easy for
    /// asynchronous edge-sweep algorithms; benchmarks shuffle to measure
    /// the representative case.
    pub fn shuffled_edges(mut self, seed: u64) -> Self {
        let mut rng = crate::util::Xoshiro256::new(seed);
        let mut perm: Vec<usize> = (0..self.src.len()).collect();
        rng.shuffle(&mut perm);
        self.src = perm.iter().map(|&i| self.src[i]).collect();
        self.dst = perm.iter().map(|&i| self.dst[i]).collect();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle_plus_isolate() -> Csr {
        // 0-1, 1-2, 0-2 and isolated vertex 3; duplicates + loop thrown in.
        EdgeList::from_pairs(4, &[(0, 1), (1, 0), (1, 2), (2, 0), (2, 2), (0, 1)]).into_csr()
    }

    #[test]
    fn dedup_canonicalizes() {
        let g = triangle_plus_isolate();
        assert_eq!(g.n, 4);
        assert_eq!(g.m(), 3);
        let edges: Vec<_> = g.edges().collect();
        assert_eq!(edges, vec![(0, 1), (0, 2), (1, 2)]);
    }

    #[test]
    fn csr_adjacency_symmetric() {
        let g = triangle_plus_isolate();
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(3), 0);
        let mut n1: Vec<_> = g.neighbors(1).to_vec();
        n1.sort_unstable();
        assert_eq!(n1, vec![0, 2]);
        // Sum of degrees = 2m.
        let total: usize = (0..g.n).map(|v| g.degree(v as VId)).sum();
        assert_eq!(total, 2 * g.m());
    }

    #[test]
    fn empty_graph() {
        let g = EdgeList::new(5).into_csr();
        assert_eq!(g.n, 5);
        assert_eq!(g.m(), 0);
        assert!(g.neighbors(2).is_empty());
    }

    #[test]
    fn self_loops_removed() {
        let g = EdgeList::from_pairs(3, &[(0, 0), (1, 1), (2, 2)]).into_csr();
        assert_eq!(g.m(), 0);
    }
}
