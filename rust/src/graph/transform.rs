//! Graph transformations built on connectivity results: component
//! extraction, induced subgraphs and relabelling — the utilities an
//! Arachne user chains after `graph_cc` (and what Afforest-style
//! sampling uses internally).

use std::collections::HashMap;

use super::{Csr, EdgeList};
use crate::cc::Labels;
use crate::VId;

/// Sizes of each component, keyed by root label.
pub fn component_sizes(labels: &Labels) -> HashMap<VId, usize> {
    let mut sizes = HashMap::new();
    for &l in labels {
        *sizes.entry(l).or_insert(0) += 1;
    }
    sizes
}

/// Root label of the largest component (ties broken by smaller label).
pub fn largest_component(labels: &Labels) -> Option<VId> {
    component_sizes(labels)
        .into_iter()
        .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
        .map(|(l, _)| l)
}

/// Induced subgraph on the vertices where `keep` is true; vertices are
/// compacted to `0..k` preserving order. Returns the subgraph and the
/// old→new id map (new id of dropped vertices = `VId::MAX`).
pub fn induced_subgraph(g: &Csr, keep: impl Fn(VId) -> bool) -> (EdgeList, Vec<VId>) {
    let mut remap = vec![VId::MAX; g.n];
    let mut next = 0 as VId;
    for v in 0..g.n {
        if keep(v as VId) {
            remap[v] = next;
            next += 1;
        }
    }
    let mut out = EdgeList::new(next as usize);
    for (u, v) in g.edges() {
        let (ru, rv) = (remap[u as usize], remap[v as usize]);
        if ru != VId::MAX && rv != VId::MAX {
            out.push(ru, rv);
        }
    }
    (out, remap)
}

/// Extract one component as a standalone graph (compacted ids).
pub fn extract_component(g: &Csr, labels: &Labels, root: VId) -> EdgeList {
    induced_subgraph(g, |v| labels[v as usize] == root).0
}

/// Split a graph into its components, largest first (root, subgraph).
pub fn split_components(g: &Csr, labels: &Labels) -> Vec<(VId, EdgeList)> {
    let mut sizes: Vec<(usize, VId)> =
        component_sizes(labels).into_iter().map(|(l, s)| (s, l)).collect();
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    sizes.into_iter().map(|(_, root)| (root, extract_component(g, labels, root))).collect()
}

/// Partition machinery for the sharded store ([`crate::shard`]): split
/// `g`'s canonical edge list into per-shard local edge lists plus the
/// cross-shard boundary. `bounds` are the `p + 1` range fences — shard
/// `k` owns global vertices `bounds[k]..bounds[k + 1]` — and `owner`
/// maps a vertex to its shard index. Shard-local ids are global ids
/// minus the shard's base, so every part is a standalone compact graph;
/// boundary edges keep global ids. One O(m) sweep total, versus p
/// passes of [`induced_subgraph`].
pub fn partition_edges<F>(g: &Csr, bounds: &[usize], owner: F) -> (Vec<EdgeList>, Vec<(VId, VId)>)
where
    F: Fn(VId) -> usize,
{
    assert!(bounds.len() >= 2, "need at least one shard");
    let p = bounds.len() - 1;
    let mut parts: Vec<EdgeList> =
        (0..p).map(|k| EdgeList::new(bounds[k + 1] - bounds[k])).collect();
    let mut boundary = Vec::new();
    for (u, v) in g.edges() {
        let (su, sv) = (owner(u), owner(v));
        if su == sv {
            let base = bounds[su] as VId;
            parts[su].push(u - base, v - base);
        } else {
            boundary.push((u, v));
        }
    }
    (parts, boundary)
}

/// Weighted-fence mode for [`partition_edges`]: `p + 1` range fences
/// placed by **cumulative edge count** instead of vertex count. The CSR
/// `offsets` array already is the prefix sum of degrees, so fence `k`
/// is one binary search for the first vertex whose prefix reaches
/// `k/p` of the total (2m) — shard `k` then carries ≈ 2m/p edge
/// endpoints however skewed the degree distribution is, which is what
/// evens out per-shard work on power-law graphs (vertex-count fences
/// hand whole hub neighborhoods to whichever shard owns the hub's
/// range). Fences are clamped monotone; under extreme skew (one vertex
/// heavier than 2m/p) a range may be empty, which the shard machinery
/// tolerates.
pub fn edge_balanced_fences(g: &Csr, p: usize) -> Vec<usize> {
    assert!(p >= 1, "need at least one shard");
    let total = *g.offsets.last().unwrap_or(&0);
    let mut bounds = Vec::with_capacity(p + 1);
    bounds.push(0usize);
    for k in 1..p {
        let target = k * total / p;
        let cut = g.offsets.partition_point(|&o| o < target).min(g.n);
        bounds.push(cut.max(bounds[k - 1]));
    }
    bounds.push(g.n);
    bounds
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{self, contour::Contour, Algorithm};
    use crate::graph::gen;

    fn soup() -> (Csr, Labels) {
        let g = gen::component_soup(4, 25, 9).into_csr();
        let labels = Contour::c2().run(&g);
        (g, labels)
    }

    #[test]
    fn sizes_and_largest() {
        let (_, labels) = soup();
        let sizes = component_sizes(&labels);
        assert_eq!(sizes.len(), 4);
        assert_eq!(sizes.values().sum::<usize>(), labels.len());
        let big = largest_component(&labels).unwrap();
        assert!(sizes[&big] >= *sizes.values().max().unwrap());
    }

    #[test]
    fn extract_preserves_structure() {
        let (g, labels) = soup();
        let comp = extract_component(&g, &labels, 0);
        let cg = comp.into_csr();
        // The extracted piece is connected and has 25 vertices.
        assert_eq!(cg.n, 25);
        let sub_labels = Contour::c2().run(&cg);
        assert_eq!(cc::num_components(&sub_labels), 1);
    }

    #[test]
    fn split_covers_everything() {
        let (g, labels) = soup();
        let parts = split_components(&g, &labels);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts.iter().map(|(_, e)| e.n).sum::<usize>(), g.n);
        // Largest first.
        assert!(parts.windows(2).all(|w| w[0].1.n >= w[1].1.n));
        // Edge counts add up (no cross-component edges exist).
        assert_eq!(parts.iter().map(|(_, e)| e.len()).sum::<usize>(), g.m());
    }

    #[test]
    fn partition_edges_splits_local_and_boundary() {
        // path(6) split at vertex 3: edges 0-1, 1-2 local to shard 0,
        // 3-4, 4-5 local to shard 1, 2-3 on the boundary.
        let g = gen::path(6).into_csr();
        let bounds = [0usize, 3, 6];
        let (parts, boundary) =
            partition_edges(&g, &bounds, |v| if v < 3 { 0 } else { 1 });
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].n, 3);
        assert_eq!(parts[1].n, 3);
        let p0: Vec<_> = parts[0].iter().collect();
        let p1: Vec<_> = parts[1].iter().collect();
        assert_eq!(p0, vec![(0, 1), (1, 2)]);
        // Shard 1 is compacted: global 3,4,5 -> local 0,1,2.
        assert_eq!(p1, vec![(0, 1), (1, 2)]);
        assert_eq!(boundary, vec![(2, 3)]);
        // Edge conservation: locals + boundary = m.
        assert_eq!(parts.iter().map(|e| e.len()).sum::<usize>() + boundary.len(), g.m());
    }

    #[test]
    fn edge_fences_balance_degree_mass_on_power_law() {
        // The fence guarantee: each shard's degree mass lands within
        // one max-degree of 2m/p, so even a skewed RMAT splits evenly.
        let g = gen::rmat(12, 50_000, gen::RmatKind::Graph500, 1).into_csr();
        let p = 4;
        let b = edge_balanced_fences(&g, p);
        assert_eq!(b.len(), p + 1);
        assert_eq!(b[0], 0);
        assert_eq!(b[p], g.n);
        assert!(b.windows(2).all(|w| w[0] <= w[1]), "fences not monotone: {b:?}");
        let weight = |k: usize| g.offsets[b[k + 1]] - g.offsets[b[k]];
        let max = (0..p).map(weight).max().unwrap();
        let min = (0..p).map(weight).min().unwrap();
        assert!(max as f64 <= 1.5 * min as f64, "edge mass skew: max {max} min {min}");
        // Degenerate inputs stay well-formed.
        assert_eq!(edge_balanced_fences(&g, 1), vec![0, g.n]);
        let empty = crate::graph::EdgeList::new(0).into_csr();
        assert_eq!(edge_balanced_fences(&empty, 3), vec![0, 0, 0, 0]);
    }

    #[test]
    fn induced_subgraph_remap() {
        let g = gen::path(6).into_csr();
        // Keep even vertices: 0,2,4 -> 0,1,2 with no surviving edges.
        let (sub, remap) = induced_subgraph(&g, |v| v % 2 == 0);
        assert_eq!(sub.n, 3);
        assert_eq!(sub.len(), 0);
        assert_eq!(remap[2], 1);
        assert_eq!(remap[3], VId::MAX);
    }
}
