//! Graph transformations built on connectivity results: component
//! extraction, induced subgraphs and relabelling — the utilities an
//! Arachne user chains after `graph_cc` (and what Afforest-style
//! sampling uses internally).

use std::collections::HashMap;

use super::{Csr, EdgeList};
use crate::cc::Labels;
use crate::par::Chunks;
use crate::VId;

/// Sizes of each component, keyed by root label.
pub fn component_sizes(labels: &Labels) -> HashMap<VId, usize> {
    let mut sizes = HashMap::new();
    for &l in labels {
        *sizes.entry(l).or_insert(0) += 1;
    }
    sizes
}

/// Root label of the largest component (ties broken by smaller label).
pub fn largest_component(labels: &Labels) -> Option<VId> {
    component_sizes(labels)
        .into_iter()
        .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
        .map(|(l, _)| l)
}

/// Induced subgraph on the vertices where `keep` is true; vertices are
/// compacted to `0..k` preserving order. Returns the subgraph and the
/// old→new id map (new id of dropped vertices = `VId::MAX`).
pub fn induced_subgraph(g: &Csr, keep: impl Fn(VId) -> bool) -> (EdgeList, Vec<VId>) {
    let mut remap = vec![VId::MAX; g.n];
    let mut next = 0 as VId;
    for v in 0..g.n {
        if keep(v as VId) {
            remap[v] = next;
            next += 1;
        }
    }
    let mut out = EdgeList::new(next as usize);
    for (u, v) in g.edges() {
        let (ru, rv) = (remap[u as usize], remap[v as usize]);
        if ru != VId::MAX && rv != VId::MAX {
            out.push(ru, rv);
        }
    }
    (out, remap)
}

/// Extract one component as a standalone graph (compacted ids).
pub fn extract_component(g: &Csr, labels: &Labels, root: VId) -> EdgeList {
    induced_subgraph(g, |v| labels[v as usize] == root).0
}

/// Split a graph into its components, largest first (root, subgraph).
pub fn split_components(g: &Csr, labels: &Labels) -> Vec<(VId, EdgeList)> {
    let mut sizes: Vec<(usize, VId)> =
        component_sizes(labels).into_iter().map(|(l, s)| (s, l)).collect();
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    sizes.into_iter().map(|(_, root)| (root, extract_component(g, labels, root))).collect()
}

/// Partition machinery for the sharded store ([`crate::shard`]): split
/// `g`'s canonical edge list into per-shard local edge lists plus the
/// cross-shard boundary. `bounds` are the `p + 1` range fences — shard
/// `k` owns global vertices `bounds[k]..bounds[k + 1]` — and `owner`
/// maps a vertex to its shard index. Shard-local ids are global ids
/// minus the shard's base, so every part is a standalone compact graph;
/// boundary edges keep global ids. One O(m) sweep total, versus p
/// passes of [`induced_subgraph`].
pub fn partition_edges<F>(g: &Csr, bounds: &[usize], owner: F) -> (Vec<EdgeList>, Vec<(VId, VId)>)
where
    F: Fn(VId) -> usize,
{
    assert!(bounds.len() >= 2, "need at least one shard");
    let p = bounds.len() - 1;
    let mut parts: Vec<EdgeList> =
        (0..p).map(|k| EdgeList::new(bounds[k + 1] - bounds[k])).collect();
    let mut boundary = Vec::new();
    for (u, v) in g.edges() {
        let (su, sv) = (owner(u), owner(v));
        if su == sv {
            let base = bounds[su] as VId;
            parts[su].push(u - base, v - base);
        } else {
            boundary.push((u, v));
        }
    }
    (parts, boundary)
}

/// Weighted-fence mode for [`partition_edges`]: `p + 1` range fences
/// placed by **cumulative edge count** instead of vertex count. The CSR
/// `offsets` array already is the prefix sum of degrees, so fence `k`
/// is one binary search for the first vertex whose prefix reaches
/// `k/p` of the total (2m) — shard `k` then carries ≈ 2m/p edge
/// endpoints however skewed the degree distribution is, which is what
/// evens out per-shard work on power-law graphs (vertex-count fences
/// hand whole hub neighborhoods to whichever shard owns the hub's
/// range). Fences are clamped monotone; under extreme skew (one vertex
/// heavier than 2m/p) a range may be empty, which the shard machinery
/// tolerates.
pub fn edge_balanced_fences(g: &Csr, p: usize) -> Vec<usize> {
    assert!(p >= 1, "need at least one shard");
    let total = *g.offsets.last().unwrap_or(&0);
    let mut bounds = Vec::with_capacity(p + 1);
    bounds.push(0usize);
    for k in 1..p {
        let target = k * total / p;
        let cut = g.offsets.partition_point(|&o| o < target).min(g.n);
        bounds.push(cut.max(bounds[k - 1]));
    }
    bounds.push(g.n);
    bounds
}

/// CSR-shaped vertex → edge-chunk membership index over an
/// iteration-stable [`Chunks`] grid of a graph's edge list: vertex `v`'s
/// slice names every chunk that contains at least one edge incident to
/// `v`, sorted ascending with no duplicates. This is what makes *exact*
/// frontier activation possible in the Contour engine
/// ([`crate::cc::contour`]): when a pass lowers `label[v]`, marking
/// exactly `chunks_of(v)` dirty re-schedules every edge whose operator
/// can now make progress, so convergence is concluded directly from an
/// empty dirty set — no backstop sweeps. Built once per run (the grid is
/// fixed for a run's lifetime) in two O(m) sweeps.
#[derive(Clone, Debug)]
pub struct VertexChunkIndex {
    /// `offsets.len() == n + 1`; vertex `v` owns `chunks[offsets[v]..offsets[v + 1]]`.
    offsets: Vec<usize>,
    chunks: Vec<u32>,
}

impl VertexChunkIndex {
    /// Chunk ids (of the grid the index was built from) containing an
    /// edge incident to `v`.
    #[inline]
    pub fn chunks_of(&self, v: VId) -> &[u32] {
        &self.chunks[self.offsets[v as usize]..self.offsets[v as usize + 1]]
    }

    /// Total membership entries (≤ 2m; usually far fewer after dedup).
    pub fn entries(&self) -> usize {
        self.chunks.len()
    }

    /// Number of vertices indexed.
    pub fn len(&self) -> usize {
        self.offsets.len() - 1
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Build the [`VertexChunkIndex`] for `g`'s edge list over `grid`
/// (which must tile `0..g.m()` — the same grid every pass of the run
/// iterates). Because chunk ids are `e / grain`, the id sequence seen
/// by any one vertex while sweeping edges in order is non-decreasing,
/// so consecutive-duplicate suppression per endpoint is *exact* dedup —
/// no sort pass needed: one counting sweep, a prefix sum, one fill
/// sweep.
pub fn vertex_chunk_index(g: &Csr, grid: Chunks) -> VertexChunkIndex {
    let n = g.n;
    let m = g.m();
    debug_assert_eq!(grid.len, m, "index grid must tile the edge list");
    let grain = grid.grain.max(1);
    const NONE: u32 = u32::MAX;
    // Pass 1: exact deduplicated membership counts per vertex.
    let mut last = vec![NONE; n];
    let mut cursor = vec![0usize; n];
    for (e, (u, v)) in g.edges().enumerate() {
        let c = (e / grain) as u32;
        for x in [u, v] {
            let x = x as usize;
            if last[x] != c {
                last[x] = c;
                cursor[x] += 1;
            }
        }
    }
    let mut offsets = vec![0usize; n + 1];
    for v in 0..n {
        offsets[v + 1] = offsets[v] + cursor[v];
    }
    // Pass 2: fill, reusing `cursor` as per-vertex write positions.
    let mut chunks = vec![0u32; offsets[n]];
    last.fill(NONE);
    cursor.fill(0);
    for (e, (u, v)) in g.edges().enumerate() {
        let c = (e / grain) as u32;
        for x in [u, v] {
            let x = x as usize;
            if last[x] != c {
                last[x] = c;
                chunks[offsets[x] + cursor[x]] = c;
                cursor[x] += 1;
            }
        }
    }
    VertexChunkIndex { offsets, chunks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{self, contour::Contour, Algorithm};
    use crate::graph::gen;

    fn soup() -> (Csr, Labels) {
        let g = gen::component_soup(4, 25, 9).into_csr();
        let labels = Contour::c2().run(&g);
        (g, labels)
    }

    #[test]
    fn sizes_and_largest() {
        let (_, labels) = soup();
        let sizes = component_sizes(&labels);
        assert_eq!(sizes.len(), 4);
        assert_eq!(sizes.values().sum::<usize>(), labels.len());
        let big = largest_component(&labels).unwrap();
        assert!(sizes[&big] >= *sizes.values().max().unwrap());
    }

    #[test]
    fn extract_preserves_structure() {
        let (g, labels) = soup();
        let comp = extract_component(&g, &labels, 0);
        let cg = comp.into_csr();
        // The extracted piece is connected and has 25 vertices.
        assert_eq!(cg.n, 25);
        let sub_labels = Contour::c2().run(&cg);
        assert_eq!(cc::num_components(&sub_labels), 1);
    }

    #[test]
    fn split_covers_everything() {
        let (g, labels) = soup();
        let parts = split_components(&g, &labels);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts.iter().map(|(_, e)| e.n).sum::<usize>(), g.n);
        // Largest first.
        assert!(parts.windows(2).all(|w| w[0].1.n >= w[1].1.n));
        // Edge counts add up (no cross-component edges exist).
        assert_eq!(parts.iter().map(|(_, e)| e.len()).sum::<usize>(), g.m());
    }

    #[test]
    fn partition_edges_splits_local_and_boundary() {
        // path(6) split at vertex 3: edges 0-1, 1-2 local to shard 0,
        // 3-4, 4-5 local to shard 1, 2-3 on the boundary.
        let g = gen::path(6).into_csr();
        let bounds = [0usize, 3, 6];
        let (parts, boundary) =
            partition_edges(&g, &bounds, |v| if v < 3 { 0 } else { 1 });
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].n, 3);
        assert_eq!(parts[1].n, 3);
        let p0: Vec<_> = parts[0].iter().collect();
        let p1: Vec<_> = parts[1].iter().collect();
        assert_eq!(p0, vec![(0, 1), (1, 2)]);
        // Shard 1 is compacted: global 3,4,5 -> local 0,1,2.
        assert_eq!(p1, vec![(0, 1), (1, 2)]);
        assert_eq!(boundary, vec![(2, 3)]);
        // Edge conservation: locals + boundary = m.
        assert_eq!(parts.iter().map(|e| e.len()).sum::<usize>() + boundary.len(), g.m());
    }

    #[test]
    fn edge_fences_balance_degree_mass_on_power_law() {
        // The fence guarantee: each shard's degree mass lands within
        // one max-degree of 2m/p, so even a skewed RMAT splits evenly.
        let g = gen::rmat(12, 50_000, gen::RmatKind::Graph500, 1).into_csr();
        let p = 4;
        let b = edge_balanced_fences(&g, p);
        assert_eq!(b.len(), p + 1);
        assert_eq!(b[0], 0);
        assert_eq!(b[p], g.n);
        assert!(b.windows(2).all(|w| w[0] <= w[1]), "fences not monotone: {b:?}");
        let weight = |k: usize| g.offsets[b[k + 1]] - g.offsets[b[k]];
        let max = (0..p).map(weight).max().unwrap();
        let min = (0..p).map(weight).min().unwrap();
        assert!(max as f64 <= 1.5 * min as f64, "edge mass skew: max {max} min {min}");
        // Degenerate inputs stay well-formed.
        assert_eq!(edge_balanced_fences(&g, 1), vec![0, g.n]);
        let empty = crate::graph::EdgeList::new(0).into_csr();
        assert_eq!(edge_balanced_fences(&empty, 3), vec![0, 0, 0, 0]);
    }

    /// Reference membership: brute-force set of chunks per vertex.
    fn brute_membership(g: &Csr, grid: Chunks) -> Vec<Vec<u32>> {
        let mut want: Vec<std::collections::BTreeSet<u32>> = vec![Default::default(); g.n];
        for (e, (u, v)) in g.edges().enumerate() {
            let c = (e / grid.grain) as u32;
            want[u as usize].insert(c);
            want[v as usize].insert(c);
        }
        want.into_iter().map(|s| s.into_iter().collect()).collect()
    }

    #[test]
    fn vertex_chunk_index_matches_brute_force() {
        for (g, grain) in [
            (gen::rmat(9, 3_000, gen::RmatKind::Graph500, 4).into_csr().shuffled_edges(1), 64),
            (gen::path(500).into_csr().shuffled_edges(2), 37),
            (gen::star(200).into_csr(), 16),
            (gen::component_soup(5, 20, 3).into_csr().shuffled_edges(4), 8),
        ] {
            let grid = Chunks::new(g.m(), grain);
            let idx = vertex_chunk_index(&g, grid);
            assert_eq!(idx.len(), g.n);
            let want = brute_membership(&g, grid);
            for v in 0..g.n {
                assert_eq!(
                    idx.chunks_of(v as VId),
                    &want[v][..],
                    "vertex {v} membership wrong (n={} m={} grain={grain})",
                    g.n,
                    g.m()
                );
            }
            // Sorted + deduplicated by construction.
            for v in 0..g.n {
                let s = idx.chunks_of(v as VId);
                assert!(s.windows(2).all(|w| w[0] < w[1]), "vertex {v} slice not strict-sorted");
            }
            assert!(idx.entries() <= 2 * g.m());
        }
    }

    #[test]
    fn vertex_chunk_index_degenerate() {
        // No edges: every vertex has an empty slice.
        let g = crate::graph::EdgeList::new(5).into_csr();
        let idx = vertex_chunk_index(&g, Chunks::new(0, 16));
        assert_eq!(idx.len(), 5);
        assert_eq!(idx.entries(), 0);
        for v in 0..5 {
            assert!(idx.chunks_of(v).is_empty());
        }
        // Single chunk covering everything: each touched vertex maps to
        // exactly chunk 0.
        let g = gen::complete(6).into_csr();
        let idx = vertex_chunk_index(&g, Chunks::new(g.m(), g.m()));
        for v in 0..6 {
            assert_eq!(idx.chunks_of(v), [0]);
        }
    }

    #[test]
    fn induced_subgraph_remap() {
        let g = gen::path(6).into_csr();
        // Keep even vertices: 0,2,4 -> 0,1,2 with no surviving edges.
        let (sub, remap) = induced_subgraph(&g, |v| v % 2 == 0);
        assert_eq!(sub.n, 3);
        assert_eq!(sub.len(), 0);
        assert_eq!(remap[2], 1);
        assert_eq!(remap[3], VId::MAX);
    }
}
