//! Delaunay triangulation graphs (the paper's `delaunay_n10..n24` family,
//! SuiteSparse DIMACS10 construction: Delaunay triangulation of n random
//! points in the unit square).
//!
//! Implementation: incremental Bowyer–Watson with triangle adjacency,
//! point location by straight walk, and Morton-order insertion so the
//! walk from the previous insertion is O(1) amortized — overall
//! ~O(n log n), comfortably building n = 2^18 in seconds.
//!
//! Predicates are plain f64 determinants (not exact arithmetic): inputs
//! are seeded uniform random points, which keeps configurations far from
//! degeneracy; a tiny deterministic jitter breaks exact duplicates/ties.

use crate::graph::EdgeList;
use crate::util::Xoshiro256;
use crate::VId;

#[derive(Clone, Copy, Debug)]
struct Tri {
    /// Vertex ids (CCW). Super-triangle vertices are `n..n+3`.
    v: [u32; 3],
    /// `nb[i]` = triangle sharing the edge opposite `v[i]` (-1 = hull).
    nb: [i32; 3],
    alive: bool,
}

#[inline]
fn orient(a: (f64, f64), b: (f64, f64), c: (f64, f64)) -> f64 {
    (b.0 - a.0) * (c.1 - a.1) - (b.1 - a.1) * (c.0 - a.0)
}

/// > 0 iff `d` lies inside the circumcircle of CCW triangle (a, b, c).
#[inline]
fn in_circle(a: (f64, f64), b: (f64, f64), c: (f64, f64), d: (f64, f64)) -> f64 {
    let (adx, ady) = (a.0 - d.0, a.1 - d.1);
    let (bdx, bdy) = (b.0 - d.0, b.1 - d.1);
    let (cdx, cdy) = (c.0 - d.0, c.1 - d.1);
    let ad = adx * adx + ady * ady;
    let bd = bdx * bdx + bdy * bdy;
    let cd = cdx * cdx + cdy * cdy;
    adx * (bdy * cd - bd * cdy) - ady * (bdx * cd - bd * cdx) + ad * (bdx * cdy - bdy * cdx)
}

/// Interleave 16-bit x/y into a Morton code for insertion locality.
fn morton(x: f64, y: f64) -> u32 {
    let spread = |mut v: u32| {
        v &= 0xFFFF;
        v = (v | (v << 8)) & 0x00FF00FF;
        v = (v | (v << 4)) & 0x0F0F0F0F;
        v = (v | (v << 2)) & 0x33333333;
        v = (v | (v << 1)) & 0x55555555;
        v
    };
    let xi = (x * 65535.0) as u32;
    let yi = (y * 65535.0) as u32;
    spread(xi) | (spread(yi) << 1)
}

struct Triangulator {
    pts: Vec<(f64, f64)>,
    tris: Vec<Tri>,
    /// Hint triangle for the next locate walk.
    last: usize,
}

impl Triangulator {
    fn new(pts: Vec<(f64, f64)>) -> Self {
        let n = pts.len();
        let mut pts = pts;
        // Super-triangle comfortably containing the unit square.
        pts.push((-10.0, -10.0));
        pts.push((30.0, -10.0));
        pts.push((-10.0, 30.0));
        let tris = vec![Tri { v: [n as u32, n as u32 + 1, n as u32 + 2], nb: [-1, -1, -1], alive: true }];
        Self { pts, tris, last: 0 }
    }

    #[inline]
    fn p(&self, v: u32) -> (f64, f64) {
        self.pts[v as usize]
    }

    /// Straight walk from `self.last` to a triangle containing `q`.
    fn locate(&self, q: (f64, f64)) -> usize {
        let mut t = self.last;
        if !self.tris[t].alive {
            t = self.tris.iter().rposition(|x| x.alive).expect("no live triangle");
        }
        let mut steps = 0usize;
        'walk: loop {
            steps += 1;
            debug_assert!(steps <= self.tris.len() + 16, "locate walk did not terminate");
            let tri = &self.tris[t];
            for i in 0..3 {
                let a = tri.v[(i + 1) % 3];
                let b = tri.v[(i + 2) % 3];
                // q strictly outside edge (a,b) => move to that neighbor.
                if orient(self.p(a), self.p(b), q) < 0.0 {
                    let nb = tri.nb[i];
                    debug_assert!(nb >= 0, "walked off the super-triangle hull");
                    t = nb as usize;
                    continue 'walk;
                }
            }
            return t;
        }
    }

    /// Insert point with id `pid` at `q` (Bowyer–Watson cavity step).
    fn insert(&mut self, pid: u32, q: (f64, f64)) {
        let seed = self.locate(q);
        // Grow the cavity: BFS over triangles whose circumcircle holds q.
        let mut bad = vec![seed];
        let mut in_bad = std::collections::HashSet::from([seed]);
        let mut stack = vec![seed];
        while let Some(t) = stack.pop() {
            for i in 0..3 {
                let nb = self.tris[t].nb[i];
                if nb < 0 {
                    continue;
                }
                let nb = nb as usize;
                if in_bad.contains(&nb) {
                    continue;
                }
                let tv = self.tris[nb].v;
                if in_circle(self.p(tv[0]), self.p(tv[1]), self.p(tv[2]), q) > 0.0 {
                    in_bad.insert(nb);
                    bad.push(nb);
                    stack.push(nb);
                }
            }
        }
        // Cavity boundary: edges of bad triangles whose neighbor is good.
        // Each entry: (a, b, outer neighbor) with (a, b) CCW on the cavity.
        let mut boundary = Vec::new();
        for &t in &bad {
            let tri = self.tris[t];
            for i in 0..3 {
                let nb = tri.nb[i];
                if nb < 0 || !in_bad.contains(&(nb as usize)) {
                    boundary.push((tri.v[(i + 1) % 3], tri.v[(i + 2) % 3], nb));
                }
            }
        }
        for &t in &bad {
            self.tris[t].alive = false;
        }
        // Fan of new triangles (pid, a, b); link via the shared-edge map.
        let base = self.tris.len();
        let mut edge_owner = std::collections::HashMap::new();
        for (k, &(a, b, outer)) in boundary.iter().enumerate() {
            let idx = base + k;
            self.tris.push(Tri { v: [pid, a, b], nb: [outer, -1, -1], alive: true });
            if outer >= 0 {
                // Fix the outer triangle's back-pointer: its edge (b, a)
                // (reversed orientation) now borders the new triangle.
                let o = &mut self.tris[outer as usize];
                for i in 0..3 {
                    if (o.v[(i + 1) % 3], o.v[(i + 2) % 3]) == (b, a) {
                        o.nb[i] = idx as i32;
                    }
                }
            }
            // Spoke edges (pid,a) and (b,pid) pair up between new triangles.
            for (key, slot) in [((pid.min(a), pid.max(a)), 2usize), ((pid.min(b), pid.max(b)), 1usize)] {
                if let Some((other_idx, other_slot)) = edge_owner.insert(key, (idx, slot)) {
                    self.tris[idx].nb[slot] = other_idx as i32;
                    self.tris[other_idx].nb[other_slot] = idx as i32;
                }
            }
        }
        self.last = base;
    }
}

/// Delaunay triangulation of `n` seeded uniform points; the graph's edges
/// are the triangulation edges (SuiteSparse `delaunay_n*` construction).
pub fn delaunay(n: usize, seed: u64) -> EdgeList {
    assert!(n >= 3, "need at least 3 points");
    let mut rng = Xoshiro256::new(seed);
    let mut pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64(), rng.f64())).collect();
    // Deterministic sub-ulp-ish jitter to break duplicates / cocircularity.
    for p in pts.iter_mut() {
        p.0 += (rng.f64() - 0.5) * 1e-9;
        p.1 += (rng.f64() - 0.5) * 1e-9;
    }
    // Morton-order insertion for O(1) locate walks.
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by_key(|&i| morton(pts[i as usize].0, pts[i as usize].1));

    let mut tr = Triangulator::new(pts);
    for &pid in &order {
        let q = tr.p(pid);
        tr.insert(pid, q);
    }
    // Emit unique edges between real vertices.
    let mut e = EdgeList::with_capacity(n, 3 * n);
    for tri in tr.tris.iter().filter(|t| t.alive) {
        for i in 0..3 {
            let a = tri.v[i];
            let b = tri.v[(i + 1) % 3];
            if a < b && (b as usize) < n {
                e.push(a as VId, b as VId);
            }
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn predicates() {
        // CCW unit right triangle; (0.25, 0.25) inside its circumcircle.
        let a = (0.0, 0.0);
        let b = (1.0, 0.0);
        let c = (0.0, 1.0);
        assert!(orient(a, b, c) > 0.0);
        assert!(in_circle(a, b, c, (0.25, 0.25)) > 0.0);
        assert!(in_circle(a, b, c, (5.0, 5.0)) < 0.0);
    }

    #[test]
    fn tiny_triangulations() {
        let g = delaunay(3, 1).into_csr();
        assert_eq!(g.m(), 3); // a single triangle
        let g = delaunay(4, 1).into_csr();
        assert!(g.m() == 5 || g.m() == 6, "4 points: 5 (convex) or 6 edges, got {}", g.m());
    }

    /// Euler's formula for Delaunay: m = 3n - 3 - h where h = hull size.
    #[test]
    fn euler_bound_holds() {
        for (n, seed) in [(64usize, 2u64), (256, 3), (1024, 4)] {
            let g = delaunay(n, seed).into_csr();
            assert!(g.m() <= 3 * n - 6, "n={n}: m={} > 3n-6", g.m());
            assert!(g.m() >= 2 * n - 3, "n={n}: m={} too small", g.m());
            let s = stats::stats(&g);
            assert_eq!(s.num_components, 1, "triangulation must be connected");
        }
    }

    /// Empty-circumcircle property, checked exhaustively on a small case.
    #[test]
    fn delaunay_property_small() {
        let n = 48;
        let seed = 9;
        let mut rng = Xoshiro256::new(seed);
        let mut pts: Vec<(f64, f64)> = (0..n).map(|_| (rng.f64(), rng.f64())).collect();
        for p in pts.iter_mut() {
            p.0 += (rng.f64() - 0.5) * 1e-9;
            p.1 += (rng.f64() - 0.5) * 1e-9;
        }
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by_key(|&i| morton(pts[i as usize].0, pts[i as usize].1));
        let mut tr = Triangulator::new(pts.clone());
        for &pid in &order {
            let q = tr.p(pid);
            tr.insert(pid, q);
        }
        for tri in tr.tris.iter().filter(|t| t.alive) {
            if tri.v.iter().any(|&v| v as usize >= n) {
                continue; // super-triangle fans are not Delaunay-constrained
            }
            let (a, b, c) = (tr.p(tri.v[0]), tr.p(tri.v[1]), tr.p(tri.v[2]));
            for (i, &p) in pts.iter().enumerate() {
                if tri.v.contains(&(i as u32)) {
                    continue;
                }
                assert!(
                    in_circle(a, b, c, p) <= 1e-12,
                    "point {i} inside circumcircle of {:?}",
                    tri.v
                );
            }
        }
    }

    #[test]
    fn deterministic_and_mid_scale() {
        let a = delaunay(4096, 7).into_csr();
        let b = delaunay(4096, 7).into_csr();
        assert_eq!(a.src, b.src);
        let s = stats::stats(&a);
        assert_eq!(s.num_components, 1);
        // Planar: average degree < 6.
        assert!(s.avg_degree < 6.0);
        assert!(s.pseudo_diameter > 20, "delaunay diameter grows like sqrt(n)");
    }
}
