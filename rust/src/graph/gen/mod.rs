//! Synthetic graph generators.
//!
//! The sandbox has no network access to SNAP/SuiteSparse, so the paper's
//! Table I corpus is substituted with seeded synthetic analogs (DESIGN.md
//! §5): power-law families (RMAT / Barabási–Albert) for the social and
//! collaboration networks, lattice road graphs for `road_usa`, chain
//! "k-mer" filaments for `kmer_*`, and true Delaunay triangulations for
//! the `delaunay_n*` family.

mod basic;
mod delaunay;
mod random;
mod rmat;

pub use basic::{
    binary_tree, comb, complete, component_soup, cycle, grid, kmer_chains, path, road, star,
};
pub use delaunay::delaunay;
pub use random::{barabasi_albert, erdos_renyi};
pub use rmat::{kronecker, rmat, RmatKind};
