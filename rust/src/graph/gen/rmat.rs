//! RMAT / stochastic-Kronecker generator (Chakrabarti et al.; the
//! Graph500 parameterization). This is the standard stand-in for scale-free
//! web/social graphs (`soc-*`, `com-*`, `uk_2002` analogs in our suite).

use crate::graph::EdgeList;
use crate::util::Xoshiro256;
use crate::VId;

/// Quadrant probability presets.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RmatKind {
    /// Graph500 reference: (a,b,c,d) = (0.57, 0.19, 0.19, 0.05); heavy skew.
    Graph500,
    /// Milder skew (0.45, 0.22, 0.22, 0.11) — web-graph-like.
    Web,
    /// Uniform (0.25, 0.25, 0.25, 0.25) — degenerates to Erdős–Rényi.
    Uniform,
    /// Custom quadrant probabilities (a, b, c); d = 1 - a - b - c.
    Custom(f64, f64, f64),
}

impl RmatKind {
    fn probs(self) -> (f64, f64, f64) {
        match self {
            RmatKind::Graph500 => (0.57, 0.19, 0.19),
            RmatKind::Web => (0.45, 0.22, 0.22),
            RmatKind::Uniform => (0.25, 0.25, 0.25),
            RmatKind::Custom(a, b, c) => {
                assert!(a + b + c <= 1.0 + 1e-9, "quadrant probs exceed 1");
                (a, b, c)
            }
        }
    }
}

/// RMAT graph over n = 2^scale vertices with `m` sampled edges. Quadrant
/// probabilities are perturbed ±10% per level (standard noise to avoid
/// grid artifacts), seeded deterministically.
pub fn rmat(scale: u32, m: usize, kind: RmatKind, seed: u64) -> EdgeList {
    let n = 1usize << scale;
    let (a, b, c) = kind.probs();
    let mut rng = Xoshiro256::new(seed);
    let mut e = EdgeList::with_capacity(n, m);
    for _ in 0..m {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            u <<= 1;
            v <<= 1;
            // Per-level ±10% noise, renormalized implicitly by branching.
            let noise = 0.9 + 0.2 * rng.f64();
            let (aa, bb, cc) = (a * noise, b * noise, c * noise);
            let r = rng.f64();
            if r < aa {
                // top-left
            } else if r < aa + bb {
                v |= 1;
            } else if r < aa + bb + cc {
                u |= 1;
            } else {
                u |= 1;
                v |= 1;
            }
        }
        e.push(u as VId, v as VId);
    }
    e
}

/// Stochastic Kronecker with the Graph500 edge factor convention:
/// n = 2^scale, m = edge_factor * n.
pub fn kronecker(scale: u32, edge_factor: usize, seed: u64) -> EdgeList {
    rmat(scale, edge_factor << scale, RmatKind::Graph500, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn rmat_shape() {
        let g = rmat(10, 8192, RmatKind::Graph500, 42);
        assert_eq!(g.n, 1024);
        assert_eq!(g.len(), 8192);
    }

    #[test]
    fn rmat_deterministic() {
        let a = rmat(8, 1000, RmatKind::Web, 7);
        let b = rmat(8, 1000, RmatKind::Web, 7);
        assert_eq!(a.src, b.src);
        let c = rmat(8, 1000, RmatKind::Web, 8);
        assert_ne!(a.src, c.src);
    }

    #[test]
    fn graph500_is_skewed_uniform_is_not() {
        let skew = rmat(12, 1 << 15, RmatKind::Graph500, 3).into_csr();
        let flat = rmat(12, 1 << 15, RmatKind::Uniform, 3).into_csr();
        let ss = stats::stats(&skew);
        let sf = stats::stats(&flat);
        assert!(
            ss.max_degree > 3 * sf.max_degree,
            "graph500 max {} vs uniform max {}",
            ss.max_degree,
            sf.max_degree
        );
    }

    #[test]
    fn kronecker_edge_factor() {
        let g = kronecker(8, 16, 1);
        assert_eq!(g.len(), 16 * 256);
    }

    #[test]
    #[should_panic(expected = "exceed 1")]
    fn custom_probs_validated() {
        rmat(4, 10, RmatKind::Custom(0.6, 0.3, 0.2), 0);
    }
}
