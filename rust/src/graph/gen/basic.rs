//! Deterministic structured generators: paths, cycles, stars, grids,
//! trees and filament ("k-mer") graphs — the extreme-topology cases the
//! paper's iteration-count analysis (§IV-C) turns on.

use crate::graph::EdgeList;
use crate::util::Xoshiro256;
use crate::VId;

/// Path 0-1-2-...-(n-1): diameter n-1, the adversarial case for C-1 and
/// the construction of Lemmas 1-2.
pub fn path(n: usize) -> EdgeList {
    let mut e = EdgeList::with_capacity(n, n.saturating_sub(1));
    for i in 1..n {
        e.push((i - 1) as VId, i as VId);
    }
    e
}

/// Cycle of n vertices (diameter ~ n/2).
pub fn cycle(n: usize) -> EdgeList {
    let mut e = path(n);
    if n > 2 {
        e.push((n - 1) as VId, 0);
    }
    e
}

/// Star with vertex 0 at the center: diameter 2, one iteration for all
/// Contour variants — the best case.
pub fn star(n: usize) -> EdgeList {
    let mut e = EdgeList::with_capacity(n, n.saturating_sub(1));
    for i in 1..n {
        e.push(0, i as VId);
    }
    e
}

/// Complete graph K_n (dense small graphs for correctness checks).
pub fn complete(n: usize) -> EdgeList {
    let mut e = EdgeList::with_capacity(n, n * (n - 1) / 2);
    for u in 0..n {
        for v in (u + 1)..n {
            e.push(u as VId, v as VId);
        }
    }
    e
}

/// Perfect binary tree with `depth` levels (n = 2^depth - 1).
pub fn binary_tree(depth: u32) -> EdgeList {
    let n = (1usize << depth) - 1;
    let mut e = EdgeList::with_capacity(n, n - 1);
    for i in 1..n {
        e.push(((i - 1) / 2) as VId, i as VId);
    }
    e
}

/// rows x cols 4-neighbor lattice: the high-diameter, constant-degree
/// regime of `road_usa`.
pub fn grid(rows: usize, cols: usize) -> EdgeList {
    let n = rows * cols;
    let mut e = EdgeList::with_capacity(n, 2 * n);
    let id = |r: usize, c: usize| (r * cols + c) as VId;
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                e.push(id(r, c), id(r, c + 1));
            }
            if r + 1 < rows {
                e.push(id(r, c), id(r + 1, c));
            }
        }
    }
    e
}

/// Road-network analog: lattice with a fraction of edges removed and a
/// few random diagonal shortcuts, keeping the giant component and the
/// large diameter (matches `road_usa`'s m/n ~ 1.2 regime).
pub fn road(rows: usize, cols: usize, seed: u64) -> EdgeList {
    let mut rng = Xoshiro256::new(seed);
    let base = grid(rows, cols);
    let n = base.n;
    let mut e = EdgeList::with_capacity(n, base.len());
    for (u, v) in base.iter() {
        // Drop 15% of lattice edges (dead ends, rivers).
        if rng.f64() >= 0.15 {
            e.push(u, v);
        }
    }
    // Sparse diagonal shortcuts (~2% of n): highway links.
    let id = |r: usize, c: usize| (r * cols + c) as VId;
    for _ in 0..n / 50 {
        let r = rng.below(rows.saturating_sub(1).max(1) as u64) as usize;
        let c = rng.below(cols.saturating_sub(1).max(1) as u64) as usize;
        e.push(id(r, c), id(r + 1, (c + 1).min(cols - 1)));
    }
    e
}

/// Comb graph: a spine of length `spine` with a tooth path of length
/// `tooth` at every spine vertex. High diameter with branching.
pub fn comb(spine: usize, tooth: usize) -> EdgeList {
    let n = spine * (tooth + 1);
    let mut e = EdgeList::with_capacity(n, n);
    for s in 1..spine {
        e.push((s - 1) as VId, s as VId);
    }
    let mut next = spine;
    for s in 0..spine {
        let mut prev = s;
        for _ in 0..tooth {
            e.push(prev as VId, next as VId);
            prev = next;
            next += 1;
        }
    }
    e
}

/// k-mer-graph analog (`kmer_A2a`, `kmer_V1r`): a soup of long filaments
/// (paths) with occasional branches — near-degree-2, huge vertex count,
/// many components, large component diameters.
pub fn kmer_chains(chains: usize, chain_len: usize, seed: u64) -> EdgeList {
    let mut rng = Xoshiro256::new(seed);
    let n = chains * chain_len;
    let mut e = EdgeList::with_capacity(n, n);
    for c in 0..chains {
        let base = c * chain_len;
        for i in 1..chain_len {
            e.push((base + i - 1) as VId, (base + i) as VId);
        }
        // 10% of chains get one branch point linking into a random offset
        // of the same chain (a bubble, as in assembly graphs).
        if chain_len > 4 && rng.f64() < 0.10 {
            let a = base + rng.below(chain_len as u64 / 2) as usize;
            let b = base + chain_len / 2 + rng.below(chain_len as u64 / 2) as usize;
            e.push(a as VId, b as VId);
        }
    }
    e
}

/// Union of disjoint pieces with mixed topologies — exercises the
/// "many components, mixed diameters" case that motivates C-11mm.
pub fn component_soup(pieces: usize, piece_size: usize, seed: u64) -> EdgeList {
    let mut rng = Xoshiro256::new(seed);
    let n = pieces * piece_size;
    let mut e = EdgeList::with_capacity(n, 2 * n);
    for p in 0..pieces {
        let base = (p * piece_size) as VId;
        match rng.below(3) {
            0 => {
                // path piece
                for i in 1..piece_size {
                    e.push(base + (i - 1) as VId, base + i as VId);
                }
            }
            1 => {
                // star piece
                for i in 1..piece_size {
                    e.push(base, base + i as VId);
                }
            }
            _ => {
                // sparse random connected piece: random spanning chain + extras
                for i in 1..piece_size {
                    let j = rng.below(i as u64) as usize;
                    e.push(base + j as VId, base + i as VId);
                }
                for _ in 0..piece_size / 2 {
                    let a = rng.below(piece_size as u64) as VId;
                    let b = rng.below(piece_size as u64) as VId;
                    e.push(base + a, base + b);
                }
            }
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn basic_shapes() {
        assert_eq!(path(5).len(), 4);
        assert_eq!(cycle(5).len(), 5);
        assert_eq!(star(5).len(), 4);
        assert_eq!(complete(5).len(), 10);
        assert_eq!(binary_tree(4).len(), 14);
        assert_eq!(grid(3, 4).len(), 3 * 3 + 2 * 4);
    }

    #[test]
    fn grid_is_connected_with_right_diameter() {
        let g = grid(5, 7).into_csr();
        let s = stats::stats(&g);
        assert_eq!(s.num_components, 1);
        assert_eq!(s.pseudo_diameter, 4 + 6);
    }

    #[test]
    fn comb_structure() {
        let g = comb(10, 5).into_csr();
        let s = stats::stats(&g);
        assert_eq!(g.n, 60);
        assert_eq!(s.num_components, 1);
        assert!(s.pseudo_diameter >= 9 + 2 * 5);
    }

    #[test]
    fn kmer_chains_are_many_long_components() {
        let g = kmer_chains(20, 50, 7).into_csr();
        let s = stats::stats(&g);
        assert_eq!(s.num_components, 20);
        assert!(s.pseudo_diameter >= 40);
    }

    #[test]
    fn component_soup_has_exactly_pieces_components() {
        let g = component_soup(13, 17, 3).into_csr();
        let s = stats::stats(&g);
        assert_eq!(s.num_components, 13);
    }

    #[test]
    fn road_keeps_big_component() {
        let g = road(40, 40, 11).into_csr();
        let s = stats::stats(&g);
        assert!(s.largest_component as f64 > 0.8 * g.n as f64);
        assert!(s.pseudo_diameter >= 40);
    }
}
