//! Random graph families: Erdős–Rényi G(n, m) and Barabási–Albert
//! preferential attachment (the power-law degree regime of the paper's
//! social / collaboration graphs).

use crate::graph::EdgeList;
use crate::util::Xoshiro256;
use crate::VId;

/// G(n, m): m edges sampled uniformly (with replacement; dedup happens in
/// `into_csr`). Low diameter once m ≳ n ln n / 2.
pub fn erdos_renyi(n: usize, m: usize, seed: u64) -> EdgeList {
    let mut rng = Xoshiro256::new(seed);
    let mut e = EdgeList::with_capacity(n, m);
    for _ in 0..m {
        let u = rng.below(n as u64) as VId;
        let v = rng.below(n as u64) as VId;
        e.push(u, v);
    }
    e
}

/// Barabási–Albert: each new vertex attaches `k` edges preferentially to
/// high-degree targets (implemented with the repeated-endpoint trick: the
/// target list holds every edge endpoint, so sampling from it is
/// degree-proportional). Produces the power-law degree distribution of
/// real-world social graphs.
pub fn barabasi_albert(n: usize, k: usize, seed: u64) -> EdgeList {
    assert!(k >= 1, "attachment degree must be >= 1");
    let n0 = (k + 1).min(n);
    let mut rng = Xoshiro256::new(seed);
    let mut e = EdgeList::with_capacity(n, n * k);
    // Seed clique among the first n0 vertices.
    let mut endpoints: Vec<VId> = Vec::with_capacity(2 * n * k);
    for u in 0..n0 {
        for v in (u + 1)..n0 {
            e.push(u as VId, v as VId);
            endpoints.push(u as VId);
            endpoints.push(v as VId);
        }
    }
    for v in n0..n {
        for _ in 0..k {
            let t = if endpoints.is_empty() {
                rng.below(v as u64) as VId
            } else {
                endpoints[rng.below(endpoints.len() as u64) as usize]
            };
            e.push(v as VId, t);
            endpoints.push(v as VId);
            endpoints.push(t);
        }
    }
    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn er_sizes() {
        let g = erdos_renyi(100, 300, 1);
        assert_eq!(g.n, 100);
        assert_eq!(g.len(), 300);
        let c = g.into_csr();
        assert!(c.m() <= 300);
        assert!(c.m() > 200); // few dups at this density
    }

    #[test]
    fn er_deterministic_per_seed() {
        let a = erdos_renyi(50, 100, 9).into_csr();
        let b = erdos_renyi(50, 100, 9).into_csr();
        assert_eq!(a.src, b.src);
        assert_eq!(a.dst, b.dst);
    }

    #[test]
    fn ba_connected_and_skewed() {
        let g = barabasi_albert(2000, 3, 5).into_csr();
        let s = stats::stats(&g);
        assert_eq!(s.num_components, 1, "BA is connected by construction");
        // Power-law: max degree far above average.
        assert!(s.max_degree as f64 > 8.0 * s.avg_degree, "max {} avg {}", s.max_degree, s.avg_degree);
        // Low diameter.
        assert!(s.pseudo_diameter <= 12);
    }

    #[test]
    fn ba_small_n_edge_cases() {
        assert_eq!(barabasi_albert(1, 2, 0).len(), 0);
        let g = barabasi_albert(5, 2, 0).into_csr();
        assert_eq!(stats::stats(&g).num_components, 1);
    }
}
