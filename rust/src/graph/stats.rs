//! Graph statistics: degree distribution, component census, and the
//! pseudo-diameter estimate used to check the paper's iteration bounds
//! (Theorem 1 needs d_max, the largest component diameter).

use std::collections::VecDeque;

use super::Csr;
use crate::VId;

/// Summary statistics for one graph (regenerates Table I rows + the
/// topology columns the paper discusses in §IV-A).
#[derive(Clone, Debug)]
pub struct GraphStats {
    pub n: usize,
    pub m: usize,
    pub max_degree: usize,
    pub avg_degree: f64,
    pub num_components: usize,
    pub largest_component: usize,
    /// Lower bound on the largest component diameter (double-sweep BFS).
    pub pseudo_diameter: usize,
    pub isolated_vertices: usize,
}

/// BFS from `start` over `g`; returns (visited set as component ids
/// written into `comp`, farthest vertex, eccentricity estimate).
fn bfs_far(g: &Csr, start: VId, comp: &mut [u32], id: u32) -> (VId, usize, usize) {
    let mut q = VecDeque::new();
    let mut dist = 0usize;
    let mut far = start;
    let mut size = 1usize;
    comp[start as usize] = id;
    q.push_back((start, 0usize));
    while let Some((v, d)) = q.pop_front() {
        if d > dist {
            dist = d;
            far = v;
        }
        for &w in g.neighbors(v) {
            if comp[w as usize] != id {
                comp[w as usize] = id;
                size += 1;
                q.push_back((w, d + 1));
            }
        }
    }
    (far, dist, size)
}

/// Double-sweep BFS pseudo-diameter of the component containing `start`.
/// Returns (component size, diameter lower bound). `comp` must carry the
/// component-id scratch from previous sweeps.
fn component_pseudo_diameter(g: &Csr, start: VId, comp: &mut [u32], id: u32) -> (usize, usize) {
    let (far, d1, size) = bfs_far(g, start, comp, id);
    // Second sweep from the farthest vertex, marking with a fresh id so
    // the component can be re-traversed without clearing the scratch.
    let id2 = id ^ 0x8000_0000;
    let (_, d2, _) = bfs_far(g, far, comp, id2);
    (size, d1.max(d2))
}

/// Compute full statistics. O(n + m); the diameter estimate double-sweeps
/// only the largest few components.
pub fn stats(g: &Csr) -> GraphStats {
    let n = g.n;
    let mut comp = vec![u32::MAX; n];
    let mut sizes: Vec<(usize, VId)> = Vec::new(); // (size, representative)
    let mut id = 0u32;
    for v in 0..n {
        if comp[v] == u32::MAX {
            let (_, _, size) = bfs_far(g, v as VId, &mut comp, id);
            sizes.push((size, v as VId));
            id += 1;
        }
    }
    sizes.sort_unstable_by(|a, b| b.cmp(a));
    // Pseudo-diameter over the largest 3 components (d_max in practice
    // lives in a big component; tiny ones cannot beat them).
    let mut pseudo = 0usize;
    let mut scratch = vec![u32::MAX; n];
    for (k, &(_, rep)) in sizes.iter().take(3).enumerate() {
        let (_, d) = component_pseudo_diameter(g, rep, &mut scratch, u32::MAX - 1 - k as u32);
        pseudo = pseudo.max(d);
    }
    let max_degree = (0..n).map(|v| g.degree(v as VId)).max().unwrap_or(0);
    let isolated = (0..n).filter(|&v| g.degree(v as VId) == 0).count();
    GraphStats {
        n,
        m: g.m(),
        max_degree,
        avg_degree: if n == 0 { 0.0 } else { 2.0 * g.m() as f64 / n as f64 },
        num_components: sizes.len(),
        largest_component: sizes.first().map(|&(s, _)| s).unwrap_or(0),
        pseudo_diameter: pseudo,
        isolated_vertices: isolated,
    }
}

/// Log-binned degree histogram: `hist[k]` = #vertices with degree in
/// `[2^k, 2^{k+1})`; `hist[0]` counts degree 0 and 1 together at index 0/1.
pub fn degree_histogram(g: &Csr) -> Vec<usize> {
    let mut hist = vec![0usize; 34];
    for v in 0..g.n {
        let d = g.degree(v as VId);
        let bin = if d == 0 { 0 } else { 64 - (d as u64).leading_zeros() as usize };
        hist[bin.min(33)] += 1;
    }
    while hist.len() > 1 && *hist.last().unwrap() == 0 {
        hist.pop();
    }
    hist
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn path_stats() {
        let g = gen::path(10).into_csr();
        let s = stats(&g);
        assert_eq!(s.n, 10);
        assert_eq!(s.m, 9);
        assert_eq!(s.num_components, 1);
        assert_eq!(s.largest_component, 10);
        assert_eq!(s.pseudo_diameter, 9);
        assert_eq!(s.max_degree, 2);
        assert_eq!(s.isolated_vertices, 0);
    }

    #[test]
    fn two_components_and_isolate() {
        // path(4): 0-1-2-3, separate edge 4-5, isolated 6.
        let mut e = gen::path(4);
        e.n = 7;
        e.push(4, 5);
        let g = e.into_csr();
        let s = stats(&g);
        assert_eq!(s.num_components, 3);
        assert_eq!(s.largest_component, 4);
        assert_eq!(s.pseudo_diameter, 3);
        assert_eq!(s.isolated_vertices, 1);
    }

    #[test]
    fn star_diameter_two() {
        let g = gen::star(50).into_csr();
        let s = stats(&g);
        assert_eq!(s.pseudo_diameter, 2);
        assert_eq!(s.max_degree, 49);
    }

    #[test]
    fn cycle_pseudo_diameter_at_least_half() {
        let g = gen::cycle(32).into_csr();
        let s = stats(&g);
        assert!(s.pseudo_diameter >= 16, "pseudo {}", s.pseudo_diameter);
    }

    #[test]
    fn histogram_bins() {
        let g = gen::star(9).into_csr(); // center degree 8, leaves degree 1
        let h = degree_histogram(&g);
        assert_eq!(h[1], 8); // 8 leaves with degree 1 -> bin [1,2)
        assert_eq!(h[4], 1); // center degree 8 -> bin [8,16)
    }
}
