//! Sharded connectivity subsystem.
//!
//! The Contour operator is embarrassingly parallel per iteration, but a
//! single monolithic graph store still funnels every request through
//! one label array and (before the multi-job pool) one job at a time.
//! Distributed-memory connectivity work — FastSV (Zhang, Azad & Hu) and
//! the near-optimal MPC algorithms (Behnezhad et al.) — shows the
//! winning shape: run connectivity **locally on shards**, then contract
//! the small cross-shard boundary. This module is that shape for the
//! in-process store, in three layers:
//!
//! * [`partition`] — split a [`crate::graph::Csr`] into `p` contiguous
//!   range shards (reusing [`crate::graph::transform::partition_edges`])
//!   plus an explicit boundary edge list, with per-shard
//!   [`crate::graph::stats::GraphStats`]. Fences follow a [`Balance`]
//!   policy: equal vertex counts, or equal edge mass
//!   ([`crate::graph::transform::edge_balanced_fences`]) so power-law
//!   graphs split into equal-work shards.
//! * [`exec`] — run any [`crate::cc::Algorithm`] shard-locally and
//!   concurrently (one pool job per shard; C-1/C-2/C-m hop schedules
//!   honored unchanged), then union representative labels over the
//!   boundary with the Rem-CAS structure from [`crate::cc::unionfind`]
//!   and broadcast final roots back into every shard's label range.
//! * The **shard router** lives in [`crate::server`]: `SHARD name p`
//!   partitions a stored graph, `PCC name [alg]` runs partitioned
//!   connectivity, `SHARDSTATS name` reports per-shard topology — and
//!   the multi-job pool lets two clients' requests overlap instead of
//!   serializing on a submit lock.
//!
//! The sharded result is not merely component-equivalent to a
//! single-shard run: it is the *identical* canonical min-vertex-id
//! labelling (`tests/shard_equiv.rs` pins both properties).

pub mod exec;
pub mod partition;

pub use exec::{run_sharded, run_sharded_ctx, ShardedRun};
pub use partition::{Balance, Shard, ShardedGraph};
