//! Range graph partitioner with selectable fence placement.
//!
//! Splits a [`Csr`] into `p` shard-local subgraphs by contiguous vertex
//! range plus an explicit cross-shard boundary edge list — the
//! decomposition the distributed-memory connectivity literature (FastSV,
//! Behnezhad et al.) runs local connectivity on before contracting the
//! (small) boundary. Shard `k` owns global vertices `[lo, hi)` compacted
//! to local ids `0..hi - lo`, so every shard is a standalone graph any
//! [`crate::cc::Algorithm`] can run on unchanged; the boundary keeps
//! global ids for the merge pass ([`super::exec`]).
//!
//! Fences are placed by a [`Balance`] policy: equal vertex counts (the
//! original behavior) or equal cumulative edge counts
//! ([`crate::graph::transform::edge_balanced_fences`] — one binary
//! search per fence over the CSR offsets), which evens out per-shard
//! work on power-law graphs where a vertex split hands one shard most
//! of the edges.
//!
//! Each shard also carries its own [`GraphStats`] — computed lazily on
//! first use, so the server's `SHARDSTATS` verb (and the §IV-E auto
//! policy, per shard) can reason about per-shard topology while
//! `SHARD`/`PCC` never pay the stats BFS sweeps.

use std::sync::OnceLock;

use crate::cc::contour::ChunkIndexCache;
use crate::graph::stats::{self, GraphStats};
use crate::graph::{transform, Csr};
use crate::VId;

/// One shard: a contiguous global vertex range `[lo, hi)` compacted to
/// local ids `0..hi - lo`, its local subgraph, and its statistics.
#[derive(Clone, Debug)]
pub struct Shard {
    pub lo: VId,
    pub hi: VId,
    /// Local subgraph over local ids (`global - lo`).
    pub graph: Csr,
    /// Lazily computed: see [`Shard::stats`].
    stats: OnceLock<GraphStats>,
    /// Exact-frontier membership indexes for `graph`, living as long as
    /// the shard — the server's cached PCC path re-runs Contour on each
    /// shard per request, and the index depends only on the (immutable)
    /// shard edge list and the grid grain. See
    /// [`crate::cc::contour::ChunkIndexCache`].
    pub index_cache: ChunkIndexCache,
}

impl Shard {
    /// Vertices owned by this shard.
    pub fn len(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.hi == self.lo
    }

    /// This shard's [`GraphStats`], computed on first use: the stats
    /// BFS sweeps (component census + pseudo-diameter) cost several
    /// O(n + m) passes, and the PCC hot path never reads them — only
    /// `SHARDSTATS` and the `auto` policy do, so `SHARD` itself stays
    /// one O(m) partition sweep.
    pub fn stats(&self) -> &GraphStats {
        self.stats.get_or_init(|| stats::stats(&self.graph))
    }
}

/// Fence-placement policy for [`ShardedGraph::partition_with`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Balance {
    /// Equal vertex counts per shard (the original policy).
    #[default]
    Vertices,
    /// Fences placed by cumulative edge count — each shard carries
    /// ≈ 2m/p edge endpoints, fixing the power-law imbalance of vertex
    /// fences. See [`transform::edge_balanced_fences`].
    Edges,
}

impl Balance {
    /// Parse the wire/CLI spelling (`vertices` | `edges`).
    pub fn parse(s: &str) -> Option<Balance> {
        match s {
            "vertices" => Some(Balance::Vertices),
            "edges" => Some(Balance::Edges),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            Balance::Vertices => "vertices",
            Balance::Edges => "edges",
        }
    }
}

/// A graph split into range shards plus the boundary edges.
#[derive(Clone, Debug)]
pub struct ShardedGraph {
    /// Global vertex count of the source graph.
    pub n: usize,
    /// Unique undirected edges of the source graph (locals + boundary).
    pub m: usize,
    /// Shards in ascending range order; ranges tile `0..n` exactly.
    pub shards: Vec<Shard>,
    /// Cross-shard edges, global ids.
    pub boundary: Vec<(VId, VId)>,
    /// Fence policy this partition was built with.
    pub balance: Balance,
}

impl ShardedGraph {
    /// Partition `g` into (up to) `p` balanced vertex ranges. `p` is
    /// clamped to `[1, n]` so no shard is empty (except the degenerate
    /// empty graph, which yields one empty shard).
    pub fn partition(g: &Csr, p: usize) -> Self {
        Self::partition_with(g, p, Balance::Vertices)
    }

    /// Partition `g` into (up to) `p` contiguous ranges under the given
    /// fence policy. `p` is clamped to `[1, n]`; with [`Balance::Edges`]
    /// an individual range can still be empty under extreme skew (one
    /// vertex heavier than 2m/p), which the executor tolerates.
    pub fn partition_with(g: &Csr, p: usize, balance: Balance) -> Self {
        let p = p.max(1).min(g.n.max(1));
        let bounds: Vec<usize> = match balance {
            Balance::Vertices => (0..=p).map(|k| k * g.n / p).collect(),
            Balance::Edges => transform::edge_balanced_fences(g, p),
        };
        let owner = |v: VId| bounds.partition_point(|&b| b <= v as usize) - 1;
        let (parts, boundary) = transform::partition_edges(g, &bounds, owner);
        let shards = parts
            .into_iter()
            .enumerate()
            .map(|(k, e)| Shard {
                lo: bounds[k] as VId,
                hi: bounds[k + 1] as VId,
                graph: e.into_csr(),
                stats: OnceLock::new(),
                index_cache: ChunkIndexCache::default(),
            })
            .collect();
        Self { n: g.n, m: g.m(), shards, boundary, balance }
    }

    /// Number of shards.
    pub fn p(&self) -> usize {
        self.shards.len()
    }

    /// Shard index owning global vertex `v` (`v < n`).
    pub fn owner(&self, v: VId) -> usize {
        debug_assert!((v as usize) < self.n);
        self.shards.partition_point(|s| s.hi <= v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn ranges_tile_and_edges_are_conserved() {
        let g = gen::erdos_renyi(500, 900, 3).into_csr();
        for p in [1usize, 2, 3, 7, 16] {
            let sg = ShardedGraph::partition(&g, p);
            assert_eq!(sg.p(), p);
            assert_eq!(sg.shards[0].lo, 0);
            assert_eq!(sg.shards.last().unwrap().hi as usize, g.n);
            for w in sg.shards.windows(2) {
                assert_eq!(w[0].hi, w[1].lo, "ranges must tile");
            }
            let local_m: usize = sg.shards.iter().map(|s| s.graph.m()).sum();
            assert_eq!(local_m + sg.boundary.len(), g.m(), "p={p}");
            // Boundary edges genuinely cross shards.
            for &(u, v) in &sg.boundary {
                assert_ne!(sg.owner(u), sg.owner(v));
            }
        }
    }

    #[test]
    fn owner_matches_ranges() {
        let g = gen::path(10).into_csr();
        let sg = ShardedGraph::partition(&g, 3);
        for (k, sh) in sg.shards.iter().enumerate() {
            for v in sh.lo..sh.hi {
                assert_eq!(sg.owner(v), k);
            }
        }
    }

    #[test]
    fn shard_count_clamps_to_n() {
        let g = gen::path(3).into_csr();
        let sg = ShardedGraph::partition(&g, 100);
        assert_eq!(sg.p(), 3);
        assert!(sg.shards.iter().all(|s| s.len() == 1));
        assert_eq!(sg.boundary.len(), 2);
        let sg1 = ShardedGraph::partition(&g, 0);
        assert_eq!(sg1.p(), 1);
        assert!(sg1.boundary.is_empty());
    }

    #[test]
    fn per_shard_stats_describe_local_subgraphs() {
        // path(6) at p=2: each shard is a 3-path with 1 component.
        let g = gen::path(6).into_csr();
        let sg = ShardedGraph::partition(&g, 2);
        for sh in &sg.shards {
            assert_eq!(sh.stats().n, 3);
            assert_eq!(sh.stats().m, 2);
            assert_eq!(sh.stats().num_components, 1);
        }
        assert_eq!(sg.boundary, vec![(2, 3)]);
    }

    #[test]
    fn edge_balanced_fences_fix_power_law_skew() {
        // Acceptance: on RMAT at p=4 the edge-balanced policy brings the
        // max/min per-shard edge-mass ratio to <= 1.5, and improves on
        // the vertex policy (which hands the low-id hub range most of
        // the edges on this generator).
        let g = gen::rmat(12, 50_000, gen::RmatKind::Graph500, 7).into_csr();
        let p = 4;
        // A shard's edge mass = edge endpoints it owns (degree sum of
        // its range): the per-shard work an O(m) sweep actually does.
        let mass = |sg: &ShardedGraph| -> Vec<usize> {
            sg.shards
                .iter()
                .map(|s| g.offsets[s.hi as usize] - g.offsets[s.lo as usize])
                .collect()
        };
        let ratio = |w: &[usize]| -> f64 {
            let max = *w.iter().max().unwrap() as f64;
            let min = *w.iter().min().unwrap() as f64;
            max / min.max(1.0)
        };
        let by_edges = ShardedGraph::partition_with(&g, p, Balance::Edges);
        let by_vertices = ShardedGraph::partition_with(&g, p, Balance::Vertices);
        assert_eq!(by_edges.balance, Balance::Edges);
        assert_eq!(by_edges.p(), p);
        // Both policies still tile 0..n and conserve edges.
        for sg in [&by_edges, &by_vertices] {
            assert_eq!(sg.shards[0].lo, 0);
            assert_eq!(sg.shards.last().unwrap().hi as usize, g.n);
            for w in sg.shards.windows(2) {
                assert_eq!(w[0].hi, w[1].lo);
            }
            let local_m: usize = sg.shards.iter().map(|s| s.graph.m()).sum();
            assert_eq!(local_m + sg.boundary.len(), g.m());
        }
        let re = ratio(&mass(&by_edges));
        let rv = ratio(&mass(&by_vertices));
        assert!(re <= 1.5, "edge-balanced ratio {re:.2} > 1.5");
        assert!(re < rv, "edge fences ({re:.2}) did not improve on vertex fences ({rv:.2})");
    }

    #[test]
    fn balance_parses_wire_spelling() {
        assert_eq!(Balance::parse("edges"), Some(Balance::Edges));
        assert_eq!(Balance::parse("vertices"), Some(Balance::Vertices));
        assert_eq!(Balance::parse("hubs"), None);
        assert_eq!(Balance::Edges.as_str(), "edges");
        assert_eq!(Balance::default(), Balance::Vertices);
    }

    #[test]
    fn empty_graph_partitions() {
        let g = crate::graph::EdgeList::new(0).into_csr();
        let sg = ShardedGraph::partition(&g, 4);
        assert_eq!(sg.p(), 1);
        assert_eq!(sg.shards[0].len(), 0);
        assert!(sg.boundary.is_empty());
    }
}
