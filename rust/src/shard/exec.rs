//! Sharded connectivity executor.
//!
//! Runs any [`Algorithm`] **shard-locally and concurrently** — shard
//! runs execute as concurrent pool jobs ([`crate::par::par_tasks`]),
//! one per shard up to the thread cap, so with the multi-job worker
//! pool all shards execute at once — then merges via a
//! boundary-contraction pass:
//!
//! 1. Shard-local labels are mapped to global ids. A local label is the
//!    minimum *local* vertex id of its piece, so `lo + label` is the
//!    minimum *global* id — the global label array becomes a two-level
//!    forest (every vertex points at its shard-local representative;
//!    representatives point at themselves).
//! 2. That forest is exactly the shape Rem's splicing union-find
//!    operates on, so the cross-shard boundary edges are contracted
//!    with the lock-free Rem-CAS `unite` from [`crate::cc::unionfind`]
//!    (one parallel sweep over the boundary — O(boundary), not O(m)).
//! 3. Final roots are broadcast back into every shard's label range by
//!    parallel pointer jumping. Rem links toward smaller ids and the
//!    representatives are minima, so each root is its component's
//!    global minimum: the result is the canonical min-vertex-id
//!    labelling, **identical** (not merely component-equivalent) to a
//!    single-shard run — `tests/shard_equiv.rs` pins this cross-check
//!    across generators × shard counts × operator hops.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
use std::sync::Arc;

use super::partition::{Balance, ShardedGraph};
use crate::cc::unionfind::RemConcurrent;
use crate::cc::{Algorithm, Labels, RunContext};
use crate::obs::RunTrace;
use crate::par;

/// Outcome of one sharded connectivity run.
#[derive(Clone, Debug)]
pub struct ShardedRun {
    /// Canonical min-vertex-id labels over the global vertex set.
    pub labels: Labels,
    /// Max shard-local iteration count, plus 1 when a boundary merge
    /// pass ran.
    pub iterations: usize,
    pub shards: usize,
    pub boundary_edges: usize,
    /// Fence policy of the partition this run executed on
    /// ([`Balance::Edges`] evens the per-shard edge mass, so the
    /// shard-job seating below stays busy instead of idling behind one
    /// heavy shard).
    pub balance: Balance,
    /// Span timeline, present iff the caller passed a trace to
    /// [`run_sharded_ctx`]: one "pcc" span on the driver track, each
    /// shard's passes on track `k + 1`, and the boundary merge.
    pub trace: Option<Arc<RunTrace>>,
}

/// Run `alg` on every shard concurrently, then contract the boundary.
/// `threads` caps the whole run (0 = all): at most `threads` shard
/// jobs are in flight at once (each runs single-threaded — its inner
/// passes inline on its pool job), and the merge passes pass the same
/// cap to `par_for`.
pub fn run_sharded(sg: &ShardedGraph, alg: &(dyn Algorithm + Sync), threads: usize) -> ShardedRun {
    run_sharded_ctx(sg, alg, threads, None)
}

/// [`run_sharded`] with an optional shared trace: the whole run becomes
/// one "pcc" span on the driver track (tid 0), each shard's passes land
/// on their own track (tid `k + 1`, named "shard k"), and the boundary
/// merge + root broadcast trace as a "merge" span. Shard runs also pick
/// up each shard's [`ChunkIndexCache`](crate::cc::contour::ChunkIndexCache),
/// so repeated exact-frontier runs over one partition reuse the
/// vertex→chunk index instead of rebuilding it.
pub fn run_sharded_ctx(
    sg: &ShardedGraph,
    alg: &(dyn Algorithm + Sync),
    threads: usize,
    trace: Option<&Arc<RunTrace>>,
) -> ShardedRun {
    let n = sg.n;
    let p = sg.shards.len();
    let run_start = trace.map(|t| {
        t.name_tid(0, "driver");
        t.now()
    });
    // 1 + 2. Shard-local connectivity, one pool job per shard, each
    //    writing its labels straight into the shared (atomic) parent
    //    array the merge operates on — globalization rides inside the
    //    shard's own job (shard ranges are disjoint), so there is no
    //    intermediate label vector, no post-hoc copy passes, and no
    //    per-shard result scaffolding. A local label is the minimum
    //    *local* vertex id of its piece, so `lo + label` is the
    //    minimum *global* id.
    // Zero-init is the one sequential O(n) touch left on this path;
    // AtomicU32 is a transparent wrapper, so this lowers to a memset.
    // (par_tabulate cannot build it: AtomicU32 is not Copy.) Every slot
    // is overwritten by the shard jobs — ranges tile 0..n exactly.
    let parents: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(0)).collect();
    let pr = &parents;
    let iters_max = AtomicUsize::new(1);
    let im = &iters_max;
    // Honor the caller's thread cap (which par_tasks itself has no
    // notion of) with `width` worker tasks draining a shard cursor —
    // at most `width` shard runs in flight, no inter-batch barrier for
    // stragglers to stall behind.
    let width = if threads == 0 { p.max(1) } else { threads.clamp(1, p.max(1)) };
    let next = AtomicUsize::new(0);
    par::par_tasks(width, |_| loop {
        let k = next.fetch_add(1, Ordering::Relaxed);
        if k >= p {
            break;
        }
        let sh = &sg.shards[k];
        let tid = k as u32 + 1;
        let shard_start = trace.map(|t| {
            t.name_tid(tid, &format!("shard {k}"));
            t.now()
        });
        let ctx = RunContext {
            trace: trace.cloned(),
            tid,
            chunk_index_cache: Some(&sh.index_cache),
        };
        let r = alg.run_ctx(&sh.graph, &ctx);
        if let (Some(t), Some(start)) = (trace, shard_start) {
            let args = vec![
                ("n", sh.graph.n as u64),
                ("m", sh.graph.m() as u64),
                ("iterations", r.iterations as u64),
            ];
            t.close(format!("shard{k}"), "pcc", "", tid, start, args);
        }
        im.fetch_max(r.iterations, Ordering::Relaxed);
        let base = sh.lo;
        for (i, &l) in r.labels.iter().enumerate() {
            pr[base as usize + i].store(base + l, Ordering::Relaxed);
        }
    });
    let iterations = iters_max.load(Ordering::Relaxed);
    let boundary_edges = sg.boundary.len();
    let merge_start = trace.map(|t| t.now());
    if boundary_edges > 0 {
        // 3. Boundary contraction on the representative forest.
        let boundary = &sg.boundary;
        par::par_for(boundary_edges, threads, par::AUTO_GRAIN, |range| {
            for e in range {
                RemConcurrent::unite(pr, boundary[e].0, boundary[e].1);
            }
        });
        // 4. Broadcast final roots back into every shard's label range.
        par::par_for(n, threads, par::AUTO_GRAIN, |range| {
            for v in range {
                let mut r = pr[v].load(Ordering::Relaxed);
                loop {
                    let rr = pr[r as usize].load(Ordering::Relaxed);
                    if rr == r {
                        break;
                    }
                    r = rr;
                }
                pr[v].store(r, Ordering::Relaxed);
            }
        });
    }
    if let (Some(t), Some(start)) = (trace, merge_start) {
        if boundary_edges > 0 {
            let args = vec![("boundary", boundary_edges as u64)];
            t.close("merge".to_string(), "pcc", "", 0, start, args);
        }
    }
    let iterations = if boundary_edges > 0 { iterations + 1 } else { iterations };
    if let (Some(t), Some(start)) = (trace, run_start) {
        let args = vec![
            ("shards", p as u64),
            ("boundary", boundary_edges as u64),
            ("iterations", iterations as u64),
        ];
        t.close("pcc".to_string(), "pcc", "", 0, start, args);
    }
    let labels: Labels = parents.into_iter().map(|x| x.into_inner()).collect();
    ShardedRun {
        labels,
        iterations,
        shards: p,
        boundary_edges,
        balance: sg.balance,
        trace: trace.cloned(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{self, contour::Contour};
    use crate::graph::gen;

    // `Algorithm` reaches here through `use super::*`; the explicit
    // trait methods below (`run`, `run_with_stats`) rely on it.

    #[test]
    fn sharded_labels_match_single_shard_contour() {
        let g = gen::erdos_renyi(800, 1400, 11).into_csr();
        let want = Contour::c2().run(&g);
        for p in [1usize, 2, 5] {
            let sg = ShardedGraph::partition(&g, p);
            let r = run_sharded(&sg, &Contour::c2(), 0);
            assert_eq!(r.labels, want, "p={p}");
            assert_eq!(r.shards, p);
        }
    }

    #[test]
    fn boundary_free_partition_skips_the_merge() {
        // Component soup whose pieces are range-aligned: with p=1 there
        // is no boundary and iterations carry no merge pass.
        let g = gen::path(400).into_csr();
        let sg = ShardedGraph::partition(&g, 1);
        assert!(sg.boundary.is_empty());
        let r = run_sharded(&sg, &Contour::c2(), 0);
        assert_eq!(r.boundary_edges, 0);
        assert_eq!(cc::num_components(&r.labels), 1);
    }

    #[test]
    fn merge_reports_one_extra_iteration() {
        let g = gen::path(100).into_csr();
        let sg = ShardedGraph::partition(&g, 4);
        assert!(!sg.boundary.is_empty());
        let single = Contour::c2().run_with_stats(&sg.shards[0].graph);
        let r = run_sharded(&sg, &Contour::c2(), 0);
        assert!(r.iterations >= 2, "merge pass must be counted");
        assert!(r.iterations >= single.iterations);
    }

    #[test]
    fn edge_balanced_partition_produces_identical_labels() {
        let g = gen::rmat(11, 8_000, gen::RmatKind::Graph500, 4).into_csr();
        let want = Contour::c2().run(&g);
        for p in [2usize, 4] {
            let sg = ShardedGraph::partition_with(&g, p, Balance::Edges);
            let r = run_sharded(&sg, &Contour::c2(), 0);
            assert_eq!(r.labels, want, "p={p}");
            assert_eq!(r.balance, Balance::Edges);
        }
    }

    #[test]
    fn works_with_union_find_algorithms_too() {
        // "any cc::Algorithm": ConnectIt-style Rem-CAS as the local alg.
        let g = gen::component_soup(6, 40, 9).into_csr();
        let want = cc::ground_truth(&g);
        let sg = ShardedGraph::partition(&g, 3);
        let r = run_sharded(&sg, &crate::cc::unionfind::RemConcurrent::new(), 0);
        assert_eq!(r.labels, want);
    }
}
