//! Parallel-for substrate.
//!
//! The image has no `rayon`, so this module provides the crate's parallel
//! loops: dynamically-scheduled chunked iteration (the analog of Chapel's
//! `forall` the paper's implementation uses) plus a map-reduce
//! combinator. Workers pull chunks off an atomic cursor, so skewed
//! per-edge work (power-law graphs) load-balances.
//!
//! Passes run on the persistent worker [`pool`] by default: workers are
//! spawned once, park between jobs, and are woken per pass — a Contour
//! run issues O(log d_max) passes and the server issues them per
//! request, so per-call `std::thread::scope` spawning (the previous
//! substrate, kept as [`ExecMode::SpawnPerCall`] for comparison and as
//! an escape hatch via `CONTOUR_EXEC=spawn`) paid thread churn on the
//! hottest path in the crate. [`par_for`] still degrades to a plain
//! sequential loop for small inputs so tiny graphs pay nothing.
//!
//! On top of the dynamic substrate sits the locality layer:
//! [`Chunks`] names an iteration-stable chunk grid, and
//! [`par_for_sticky`] schedules it so the same chunk block lands on the
//! same (core-pinned) pool worker on every pass of a hot loop — see
//! [`pool::Pool::run_sticky`].

pub mod pool;

use std::ops::Range;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Below this many items a parallel loop runs inline on the caller.
pub const SEQ_CUTOFF: usize = 1 << 14;

/// Default chunk size pulled by each worker per cursor bump: large enough
/// to amortize the atomic, small enough to balance skew.
pub const DEFAULT_GRAIN: usize = 1 << 12;

/// Grain sentinel: pick the chunk size adaptively from `(len, threads)`
/// via [`adaptive_grain`]. This is what the algorithm hot loops pass, so
/// short late-stage passes (a few surviving edges) are split finely
/// enough to keep every worker busy while long passes keep big chunks.
pub const AUTO_GRAIN: usize = 0;

/// How parallel passes execute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Persistent worker pool (default): workers park between passes.
    Pooled,
    /// Spawn and join scoped threads on every pass (the pre-pool
    /// substrate; kept for the `hotpath` bench and as an escape hatch).
    SpawnPerCall,
}

/// 0 = unresolved, 1 = pooled, 2 = spawn-per-call.
static EXEC_MODE: AtomicU8 = AtomicU8::new(0);

/// Current execution mode; first call consults `CONTOUR_EXEC`
/// (`spawn` selects [`ExecMode::SpawnPerCall`], anything else pools).
pub fn exec_mode() -> ExecMode {
    match EXEC_MODE.load(Ordering::Relaxed) {
        1 => ExecMode::Pooled,
        2 => ExecMode::SpawnPerCall,
        _ => {
            let m = match std::env::var("CONTOUR_EXEC").as_deref() {
                Ok("spawn") => ExecMode::SpawnPerCall,
                _ => ExecMode::Pooled,
            };
            set_exec_mode(m);
            m
        }
    }
}

/// Force an execution mode (used by benches to compare substrates).
pub fn set_exec_mode(m: ExecMode) {
    let v = match m {
        ExecMode::Pooled => 1,
        ExecMode::SpawnPerCall => 2,
    };
    EXEC_MODE.store(v, Ordering::Relaxed);
}

/// Number of worker threads: `CONTOUR_THREADS` env override, else the
/// machine's available parallelism. Resolved **once** — the pool sizes
/// itself from this value, and later env mutations must not change how
/// many workers a pass believes it has (they used to, which made
/// concurrent tests racy).
pub fn num_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        threads_from_env()
            .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// Parse the `CONTOUR_THREADS` override from the environment (split out
/// so tests can exercise the parse without poking the cached value).
pub(crate) fn threads_from_env() -> Option<usize> {
    std::env::var("CONTOUR_THREADS").ok()?.parse::<usize>().ok().map(|t| t.max(1))
}

/// Chunk size targeting ~8 pulls per worker — enough slack for the
/// dynamic cursor to rebalance skew — clamped so chunks stay big enough
/// to amortize the cursor atomic and small enough to share.
pub fn adaptive_grain(len: usize, threads: usize) -> usize {
    (len / (threads.max(1) * 8)).clamp(1 << 10, 1 << 14)
}

/// An **iteration-stable** chunking of `0..len`: chunk `c` covers
/// `[c*grain, min((c+1)*grain, len))`, so as long as `(len, grain)` are
/// held fixed the chunk ids name the same index ranges on every pass.
/// This is the one chunk abstraction the locality layers share: sticky
/// scheduling assigns contiguous chunk blocks to fixed workers
/// ([`par_for_sticky`]), and the Contour frontier keeps one dirty bit
/// per chunk of this grid across iterations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Chunks {
    pub len: usize,
    pub grain: usize,
}

impl Chunks {
    pub fn new(len: usize, grain: usize) -> Self {
        Self { len, grain: grain.max(1) }
    }

    /// Number of chunks (0 for an empty range).
    pub fn count(&self) -> usize {
        (self.len + self.grain - 1) / self.grain
    }

    /// Index range of chunk `c` (`c < count()`).
    pub fn range(&self, c: usize) -> Range<usize> {
        let lo = c * self.grain;
        lo..(lo + self.grain).min(self.len)
    }
}

#[inline]
fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        num_threads()
    } else {
        threads
    }
}

#[inline]
fn resolve_grain(grain: usize, len: usize, threads: usize) -> usize {
    if grain == AUTO_GRAIN {
        adaptive_grain(len, threads)
    } else {
        grain.max(1)
    }
}

/// Run this pass inline on the caller? Yes when parallelism is off,
/// when the caller is already inside a pool job (nested pass), or when
/// the pass is small. For adaptive passes the smallness threshold stays
/// at [`DEFAULT_GRAIN`] — the pre-pool behavior — even though the
/// adaptive bottom clamp is finer: waking workers for a few thousand
/// cheap items costs more than the loop itself.
#[inline]
fn run_inline(len: usize, threads: usize, grain_arg: usize, grain: usize) -> bool {
    let small = if grain_arg == AUTO_GRAIN { DEFAULT_GRAIN } else { grain };
    threads <= 1 || len <= SEQ_CUTOFF.min(small) || pool::in_job()
}

/// Dynamically-scheduled parallel for over `0..len` with `threads` workers
/// (0 = [`num_threads`]). `f` receives disjoint subranges covering `0..len`
/// exactly once. Nested calls (from inside another parallel pass) run
/// inline sequentially: the outer pass already owns the workers.
pub fn par_for<F>(len: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let threads = resolve_threads(threads);
    let resolved = resolve_grain(grain, len, threads);
    if run_inline(len, threads, grain, resolved) {
        if len > 0 {
            f(0..len);
        }
        return;
    }
    let grain = resolved;
    match exec_mode() {
        ExecMode::SpawnPerCall => par_for_spawn(len, threads, grain, &f),
        ExecMode::Pooled => {
            let p = pool::global();
            if threads > p.max_threads() {
                // The pool cannot grow: honor explicit requests beyond
                // its size (e.g. oversubscription sweeps in benches)
                // with the spawn-per-call substrate.
                return par_for_spawn(len, threads, grain, &f);
            }
            let metrics = p.metrics();
            let cursor = AtomicUsize::new(0);
            p.run(threads - 1, &|| loop {
                let start = cursor.fetch_add(grain, Ordering::Relaxed);
                if start >= len {
                    break;
                }
                metrics.pulls.fetch_add(1, Ordering::Relaxed);
                f(start..(start + grain).min(len));
            });
        }
    }
}

/// Sticky parallel for over an iteration-stable chunk grid: `f(c,
/// range)` runs exactly once per chunk, and on the pooled substrate the
/// grid is split into `slots` contiguous chunk blocks with block `s`
/// always executing on the same pool worker ([`pool::Pool::run_sticky`]
/// — slot jobs live on their home worker's queue and are excluded from
/// stealing). A hot loop issuing the same grid every iteration (Contour:
/// ~log d_max passes) therefore re-touches each block's label/edge
/// cache lines on one pinned core instead of scattering them.
///
/// Degrades gracefully everywhere stickiness is unavailable: nested or
/// single-threaded or small passes run inline, and the spawn-per-call
/// substrate (plus explicit thread counts beyond the pool size) runs a
/// dynamic chunk cursor — correct, just not sticky.
pub fn par_for_sticky<F>(chunks: Chunks, threads: usize, f: F)
where
    F: Fn(usize, Range<usize>) + Sync,
{
    let nchunks = chunks.count();
    if nchunks == 0 {
        return;
    }
    let threads = resolve_threads(threads);
    let inline = threads <= 1 || chunks.len <= SEQ_CUTOFF.min(DEFAULT_GRAIN) || pool::in_job();
    let spawn = !inline
        && (exec_mode() == ExecMode::SpawnPerCall || threads > pool::global().max_threads());
    if inline {
        for c in 0..nchunks {
            f(c, chunks.range(c));
        }
    } else if spawn {
        // Dynamic cursor over the same stable grid: no persistent
        // workers to be sticky to (or the caller asked for more threads
        // than the pool owns — the oversubscription escape hatch).
        let cursor = AtomicUsize::new(0);
        let worker = || loop {
            let c = cursor.fetch_add(1, Ordering::Relaxed);
            if c >= nchunks {
                break;
            }
            f(c, chunks.range(c));
        };
        std::thread::scope(|s| {
            for _ in 1..threads.min(nchunks) {
                let worker = &worker;
                s.spawn(move || worker());
            }
            worker();
        });
    } else {
        let p = pool::global();
        let slots = threads.min(p.max_threads()).min(nchunks);
        if slots <= 1 {
            for c in 0..nchunks {
                f(c, chunks.range(c));
            }
            return;
        }
        p.run_sticky(slots, &|slot| {
            // Slot `s` owns the `s`-th contiguous block of chunks —
            // stable across passes, contiguous for locality.
            let lo = slot * nchunks / slots;
            let hi = (slot + 1) * nchunks / slots;
            for c in lo..hi {
                f(c, chunks.range(c));
            }
        });
    }
}

/// The pre-pool `par_for` body: scoped threads spawned per call.
fn par_for_spawn<F>(len: usize, threads: usize, grain: usize, f: &F)
where
    F: Fn(Range<usize>) + Sync,
{
    let cursor = AtomicUsize::new(0);
    let worker = |_wid: usize| loop {
        let start = cursor.fetch_add(grain, Ordering::Relaxed);
        if start >= len {
            break;
        }
        f(start..(start + grain).min(len));
    };
    std::thread::scope(|s| {
        for w in 1..threads {
            let worker = &worker;
            s.spawn(move || worker(w));
        }
        worker(0);
    });
}

/// Parallel map-reduce: each worker folds its chunks into a local
/// accumulator (`init`/`fold`), then accumulators are combined on the
/// caller with `combine`.
pub fn par_map_reduce<R, I, F, C>(
    len: usize,
    threads: usize,
    grain: usize,
    init: I,
    fold: F,
    combine: C,
) -> R
where
    R: Send,
    I: Fn() -> R + Sync,
    F: Fn(&mut R, Range<usize>) + Sync,
    C: Fn(R, R) -> R,
{
    let threads = resolve_threads(threads);
    let resolved = resolve_grain(grain, len, threads);
    if run_inline(len, threads, grain, resolved) {
        let mut acc = init();
        if len > 0 {
            fold(&mut acc, 0..len);
        }
        return acc;
    }
    let grain = resolved;
    match exec_mode() {
        ExecMode::SpawnPerCall => par_map_reduce_spawn(len, threads, grain, &init, &fold, &combine),
        ExecMode::Pooled => {
            let p = pool::global();
            if threads > p.max_threads() {
                // See par_for: over-pool-size requests keep the old
                // spawn-per-call semantics.
                return par_map_reduce_spawn(len, threads, grain, &init, &fold, &combine);
            }
            let metrics = p.metrics();
            let cursor = AtomicUsize::new(0);
            // Each participant parks its local accumulator here; the
            // caller combines after the pass (so `combine` needs no
            // `Sync` bound, matching the old signature).
            let accs: std::sync::Mutex<Vec<R>> = std::sync::Mutex::new(Vec::new());
            p.run(threads - 1, &|| {
                let mut acc = init();
                loop {
                    let start = cursor.fetch_add(grain, Ordering::Relaxed);
                    if start >= len {
                        break;
                    }
                    metrics.pulls.fetch_add(1, Ordering::Relaxed);
                    fold(&mut acc, start..(start + grain).min(len));
                }
                accs.lock().unwrap().push(acc);
            });
            let mut parts = accs.into_inner().unwrap().into_iter();
            // The submitting thread always participates, so there is at
            // least one accumulator.
            let first = parts.next().unwrap_or_else(&init);
            parts.fold(first, &combine)
        }
    }
}

/// The pre-pool `par_map_reduce` body: scoped threads per call.
fn par_map_reduce_spawn<R, I, F, C>(
    len: usize,
    threads: usize,
    grain: usize,
    init: &I,
    fold: &F,
    combine: &C,
) -> R
where
    R: Send,
    I: Fn() -> R + Sync,
    F: Fn(&mut R, Range<usize>) + Sync,
    C: Fn(R, R) -> R,
{
    let cursor = AtomicUsize::new(0);
    let worker = || {
        let mut acc = init();
        loop {
            let start = cursor.fetch_add(grain, Ordering::Relaxed);
            if start >= len {
                break;
            }
            fold(&mut acc, start..(start + grain).min(len));
        }
        acc
    };
    std::thread::scope(|s| {
        let handles: Vec<_> = (1..threads)
            .map(|_| {
                let worker = &worker;
                s.spawn(move || worker())
            })
            .collect();
        let mut acc = worker();
        for h in handles {
            acc = combine(acc, h.join().expect("worker panicked"));
        }
        acc
    })
}

/// Run `count` independent one-shot tasks — `f(i)` invoked **exactly
/// once** per `i in 0..count` — concurrently. Unlike [`par_for`], each
/// task becomes its own pool job, so the whole set is in flight at once
/// (visible in the pool's `inflight` metrics) and overlaps with other
/// sessions' jobs; this is how the sharded executor runs shard-local
/// connectivity. Nested calls (from inside a pool job) run inline
/// sequentially; panics propagate after every task has settled.
pub fn par_tasks<F>(count: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    if count == 0 {
        return;
    }
    if count == 1 || pool::in_job() {
        for i in 0..count {
            f(i);
        }
        return;
    }
    match exec_mode() {
        ExecMode::SpawnPerCall => {
            // Clamp the spawn width: `count` can be client-controlled
            // (SHARD p), and one OS thread per task would let a single
            // request reserve gigabytes of stacks. Workers drain an
            // index cursor instead, preserving exactly-once.
            let width = num_threads().min(count);
            let cursor = AtomicUsize::new(0);
            let worker = || {
                // Spawn-mode task workers are not pool workers, but the
                // same nesting rule must hold: passes inside a task run
                // inline, or a p-shard run would spawn ~threads² OS
                // threads (each task's inner par_for spawning its own
                // thread set).
                let _in_job = pool::JobScope::enter();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= count {
                        break;
                    }
                    f(i);
                }
            };
            std::thread::scope(|s| {
                for _ in 1..width {
                    let worker = &worker;
                    s.spawn(move || worker());
                }
                worker();
            });
        }
        ExecMode::Pooled => pool::global().run_many(count, &f),
    }
}

/// Parallel initialization of a `Vec<T>` by index (used for label arrays).
pub fn par_tabulate<T, F>(len: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Sync + Copy + Default,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); len];
    {
        let slots = SyncSlice::new(&mut out);
        par_for(len, threads, AUTO_GRAIN, |r| {
            for i in r {
                // SAFETY: ranges from par_for are disjoint.
                unsafe { slots.write(i, f(i)) };
            }
        });
    }
    out
}

/// Shared mutable slice wrapper for writes to *disjoint* indices from
/// multiple workers (the standard trick rayon hides behind chunks_mut).
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `val` at `i`. Caller must guarantee no concurrent access to
    /// the same index (disjoint ranges).
    ///
    /// # Safety
    /// `i < len` and no other thread reads or writes index `i` concurrently.
    #[inline]
    pub unsafe fn write(&self, i: usize, val: T) {
        debug_assert!(i < self.len);
        unsafe { self.ptr.add(i).write(val) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Mutex;

    /// Serializes tests that mutate process-wide environment variables
    /// (`CONTOUR_THREADS`): unsynchronized set/remove while other tests
    /// read the environment is a race.
    static ENV_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn par_for_covers_each_index_once() {
        let n = 100_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for(n, 4, 1000, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_for_zero_len_and_one_thread() {
        par_for(0, 4, 16, |_| panic!("must not run"));
        let mut seen = 0usize;
        let cell = std::sync::Mutex::new(&mut seen);
        par_for(10, 1, 16, |r| **cell.lock().unwrap() += r.len());
        assert_eq!(seen, 10);
    }

    #[test]
    fn map_reduce_sums() {
        let n = 1 << 18;
        let total = par_map_reduce(
            n,
            8,
            1 << 10,
            || 0u64,
            |acc, r| *acc += r.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn map_reduce_sums_via_spawn_substrate() {
        // The legacy spawn-per-call body stays correct (the hotpath
        // bench flips to it for comparison).
        let n = 1 << 18;
        let total = par_map_reduce_spawn(
            n,
            8,
            1 << 10,
            &|| 0u64,
            &|acc: &mut u64, r: Range<usize>| *acc += r.map(|i| i as u64).sum::<u64>(),
            &|a, b| a + b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
        let hits: Vec<AtomicU64> = (0..50_000).map(|_| AtomicU64::new(0)).collect();
        par_for_spawn(hits.len(), 4, 1000, &|r: Range<usize>| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn tabulate_matches_sequential() {
        let v = par_tabulate(50_000, 4, |i| (i * 3) as u64);
        assert!(v.iter().enumerate().all(|(i, &x)| x == (i * 3) as u64));
    }

    #[test]
    fn adaptive_grain_clamps() {
        assert_eq!(adaptive_grain(1 << 30, 8), 1 << 14); // huge: top clamp
        assert_eq!(adaptive_grain(4096, 8), 1 << 10); // small: bottom clamp
        assert_eq!(adaptive_grain(0, 0), 1 << 10); // degenerate inputs
        let mid = 1 << 20;
        assert_eq!(adaptive_grain(mid, 16), mid / (16 * 8));
    }

    #[test]
    fn chunk_grid_tiles_exactly() {
        let c = Chunks::new(10_000, 1 << 10);
        assert_eq!(c.count(), 10);
        let mut covered = 0usize;
        for i in 0..c.count() {
            let r = c.range(i);
            assert_eq!(r.start, covered);
            covered = r.end;
        }
        assert_eq!(covered, 10_000);
        assert_eq!(Chunks::new(0, 64).count(), 0);
        assert_eq!(Chunks::new(5, 0).grain, 1, "grain clamps to 1");
        assert_eq!(Chunks::new(4096, 4096).count(), 1);
    }

    #[test]
    fn sticky_pass_covers_each_chunk_once() {
        // Big enough to leave the inline path; every (chunk, index) must
        // be visited exactly once and chunk ids must match the grid.
        let grid = Chunks::new(1 << 17, 1 << 12);
        let hits: Vec<AtomicU64> = (0..grid.len).map(|_| AtomicU64::new(0)).collect();
        par_for_sticky(grid, 0, |c, r| {
            assert_eq!(r, grid.range(c));
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sticky_pass_inlines_when_nested_or_small() {
        // Small grid: runs inline on the caller.
        let small = Chunks::new(100, 10);
        let mut seen = 0usize;
        let cell = std::sync::Mutex::new(&mut seen);
        par_for_sticky(small, 8, |_, r| **cell.lock().unwrap() += r.len());
        assert_eq!(seen, 100);
        // Nested inside a pooled pass: must not resubmit to the pool.
        let grid = Chunks::new(1 << 16, 1 << 10);
        let hits: Vec<AtomicU64> = (0..grid.len).map(|_| AtomicU64::new(0)).collect();
        par_for(grid.len, 4, 1 << 12, |outer| {
            let sub = Chunks::new(outer.len(), 1 << 10);
            let base = outer.start;
            par_for_sticky(sub, 4, |_, inner| {
                for i in inner {
                    hits[base + i].fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn nested_parallel_calls_run_inline() {
        // A nested par_for inside a pooled pass must not resubmit to the
        // pool (single job slot); it runs inline and stays correct.
        let n = 1 << 16;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for(n, 4, 1 << 12, |outer| {
            let base = outer.start;
            let len = outer.len();
            par_for(len, 4, 16, |inner| {
                for i in inner {
                    hits[base + i].fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_tasks_runs_each_exactly_once() {
        let count = 23;
        let hits: Vec<AtomicU64> = (0..count).map(|_| AtomicU64::new(0)).collect();
        par_tasks(count, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        par_tasks(0, |_| panic!("must not run"));
    }

    #[test]
    fn par_tasks_nested_inside_a_pass_runs_inline() {
        let n = 1 << 16;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for(n, 4, 1 << 12, |outer| {
            let base = outer.start;
            let len = outer.len();
            par_tasks(4, |k| {
                for i in (k..len).step_by(4) {
                    hits[base + i].fetch_add(1, Ordering::Relaxed);
                }
            });
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn num_threads_env_override() {
        // Serialized: mutating CONTOUR_THREADS is process-wide. The
        // cached num_threads() value is intentionally immune to this
        // (the pool reads it once at init); we test the parser.
        let _env = ENV_LOCK.lock().unwrap();
        // Force the once-cache to fill from the *clean* environment
        // before mutating it: otherwise a concurrent test triggering
        // first-time pool init mid-mutation could capture a transient
        // value for the rest of the process.
        let cached = num_threads();
        std::env::set_var("CONTOUR_THREADS", "3");
        assert_eq!(num_threads(), cached, "cached value must ignore later env changes");
        assert_eq!(threads_from_env(), Some(3));
        std::env::set_var("CONTOUR_THREADS", "0");
        assert_eq!(threads_from_env(), Some(1), "0 clamps to 1");
        std::env::set_var("CONTOUR_THREADS", "lots");
        assert_eq!(threads_from_env(), None, "non-numeric ignored");
        std::env::remove_var("CONTOUR_THREADS");
        assert_eq!(threads_from_env(), None);
        assert!(num_threads() >= 1);
    }
}
