//! Parallel-for substrate.
//!
//! The image has no `rayon`, so this module provides the crate's parallel
//! loops on top of `std::thread::scope`: dynamically-scheduled chunked
//! iteration (the analog of Chapel's `forall` the paper's implementation
//! uses) plus a map-reduce combinator. Workers pull chunks off an atomic
//! cursor, so skewed per-edge work (power-law graphs) load-balances.
//!
//! Threads are spawned per call; for the edge-loop sizes the algorithms
//! run on (>= tens of thousands of edges) the spawn cost is noise, and
//! [`par_for`] degrades to a plain sequential loop below
//! [`SEQ_CUTOFF`] items so small graphs pay nothing.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Below this many items a parallel loop runs inline on the caller.
pub const SEQ_CUTOFF: usize = 1 << 14;

/// Default chunk size pulled by each worker per cursor bump: large enough
/// to amortize the atomic, small enough to balance skew.
pub const DEFAULT_GRAIN: usize = 1 << 12;

/// Number of worker threads: `CONTOUR_THREADS` env override, else the
/// machine's available parallelism.
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("CONTOUR_THREADS") {
        if let Ok(t) = v.parse::<usize>() {
            return t.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Dynamically-scheduled parallel for over `0..len` with `threads` workers
/// (0 = [`num_threads`]). `f` receives disjoint subranges covering `0..len`
/// exactly once.
pub fn par_for<F>(len: usize, threads: usize, grain: usize, f: F)
where
    F: Fn(Range<usize>) + Sync,
{
    let threads = if threads == 0 { num_threads() } else { threads };
    let grain = grain.max(1);
    if threads <= 1 || len <= SEQ_CUTOFF.min(grain) {
        if len > 0 {
            f(0..len);
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    let worker = |_wid: usize| loop {
        let start = cursor.fetch_add(grain, Ordering::Relaxed);
        if start >= len {
            break;
        }
        f(start..(start + grain).min(len));
    };
    std::thread::scope(|s| {
        for w in 1..threads {
            let worker = &worker;
            s.spawn(move || worker(w));
        }
        worker(0);
    });
}

/// Parallel map-reduce: each worker folds its chunks into a local
/// accumulator (`init`/`fold`), then accumulators are combined on the
/// caller with `combine`.
pub fn par_map_reduce<R, I, F, C>(
    len: usize,
    threads: usize,
    grain: usize,
    init: I,
    fold: F,
    combine: C,
) -> R
where
    R: Send,
    I: Fn() -> R + Sync,
    F: Fn(&mut R, Range<usize>) + Sync,
    C: Fn(R, R) -> R,
{
    let threads = if threads == 0 { num_threads() } else { threads };
    let grain = grain.max(1);
    if threads <= 1 || len <= SEQ_CUTOFF.min(grain) {
        let mut acc = init();
        if len > 0 {
            fold(&mut acc, 0..len);
        }
        return acc;
    }
    let cursor = AtomicUsize::new(0);
    let worker = || {
        let mut acc = init();
        loop {
            let start = cursor.fetch_add(grain, Ordering::Relaxed);
            if start >= len {
                break;
            }
            fold(&mut acc, start..(start + grain).min(len));
        }
        acc
    };
    std::thread::scope(|s| {
        let handles: Vec<_> = (1..threads)
            .map(|_| {
                let worker = &worker;
                s.spawn(move || worker())
            })
            .collect();
        let mut acc = worker();
        for h in handles {
            acc = combine(acc, h.join().expect("worker panicked"));
        }
        acc
    })
}

/// Parallel initialization of a `Vec<T>` by index (used for label arrays).
pub fn par_tabulate<T, F>(len: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Sync + Copy + Default,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); len];
    {
        let slots = SyncSlice::new(&mut out);
        par_for(len, threads, DEFAULT_GRAIN, |r| {
            for i in r {
                // SAFETY: ranges from par_for are disjoint.
                unsafe { slots.write(i, f(i)) };
            }
        });
    }
    out
}

/// Shared mutable slice wrapper for writes to *disjoint* indices from
/// multiple workers (the standard trick rayon hides behind chunks_mut).
pub struct SyncSlice<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: std::marker::PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Sync for SyncSlice<'_, T> {}
unsafe impl<T: Send> Send for SyncSlice<'_, T> {}

impl<'a, T> SyncSlice<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Self {
        Self { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: std::marker::PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write `val` at `i`. Caller must guarantee no concurrent access to
    /// the same index (disjoint ranges).
    ///
    /// # Safety
    /// `i < len` and no other thread reads or writes index `i` concurrently.
    #[inline]
    pub unsafe fn write(&self, i: usize, val: T) {
        debug_assert!(i < self.len);
        unsafe { self.ptr.add(i).write(val) };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_for_covers_each_index_once() {
        let n = 100_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        par_for(n, 4, 1000, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_for_zero_len_and_one_thread() {
        par_for(0, 4, 16, |_| panic!("must not run"));
        let mut seen = 0usize;
        let cell = std::sync::Mutex::new(&mut seen);
        par_for(10, 1, 16, |r| **cell.lock().unwrap() += r.len());
        assert_eq!(seen, 10);
    }

    #[test]
    fn map_reduce_sums() {
        let n = 1 << 18;
        let total = par_map_reduce(
            n,
            8,
            1 << 10,
            || 0u64,
            |acc, r| *acc += r.map(|i| i as u64).sum::<u64>(),
            |a, b| a + b,
        );
        assert_eq!(total, (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn tabulate_matches_sequential() {
        let v = par_tabulate(50_000, 4, |i| (i * 3) as u64);
        assert!(v.iter().enumerate().all(|(i, &x)| x == (i * 3) as u64));
    }

    #[test]
    fn num_threads_env_override() {
        // Note: mutates process env; fine inside the test binary.
        std::env::set_var("CONTOUR_THREADS", "3");
        assert_eq!(num_threads(), 3);
        std::env::remove_var("CONTOUR_THREADS");
        assert!(num_threads() >= 1);
    }
}
