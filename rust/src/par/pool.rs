//! Persistent worker pool behind the [`crate::par`] substrate.
//!
//! The paper's cost story (§III) is that each Contour iteration is one
//! cheap O(m) sweep of a highly parallel operator, and its Chapel
//! implementation rides a tasking runtime whose workers live for the
//! whole program. This module amortizes thread startup the way Chapel
//! (and ConnectIt's scheduler) do: a process-wide set of workers that
//! park on a condvar between jobs.
//!
//! Since the sharded-connectivity PR the pool runs **multiple jobs in
//! flight**: the old single epoch-stamped job slot (every submitter
//! queued on one submit lock, serializing concurrent server requests)
//! is replaced by **per-worker job queues with stealing**. Two sessions'
//! `CC`/`PCC` requests now overlap instead of serializing, and the
//! sharded executor runs one job per shard concurrently.
//!
//! Design:
//!
//! * Workers are spawned lazily on the first parallel pass, sized from
//!   `CONTOUR_THREADS` (read **once**, see [`crate::par::num_threads`])
//!   or the machine's available parallelism. Worker `w` owns queue `w`;
//!   an idle worker pops its own queue front, then steals from the
//!   back of the others.
//! * A chunked job ([`Pool::run`]) is a lifetime-erased closure every
//!   participating worker runs to exhaustion; the closure pulls chunks
//!   off the caller's atomic cursor, so scheduling stays dynamic. The
//!   submitting thread always participates, so `threads = 1` or a busy
//!   pool still makes progress.
//! * A one-shot job set ([`Pool::run_many`]) runs `task(i)` exactly once
//!   per index as independent jobs — the sharded executor's shard-local
//!   runs — with the submitter claiming whatever no worker has taken.
//! * Each job tracks `(open seats, active participants)` in one packed
//!   atomic; a claim is a seat decrement + active increment in a single
//!   CAS, so the submitter's "close seats and wait for quiescence"
//!   epilogue can never race a late joiner.
//! * Nested parallel calls (a pass inside a pool job) run inline
//!   sequentially — the outer pass already owns the workers.
//! * **Locality** (the execution-engine PR): workers pin themselves to
//!   cores on Linux (`sched_setaffinity`, `CONTOUR_PIN=0` disables, a
//!   graceful no-op elsewhere), and [`Pool::run_sticky`] runs a pass as
//!   one single-seat job per *slot*, each enqueued on a fixed worker's
//!   own queue and never stealable — so across a Contour run's
//!   ~log(d_max) passes the same chunk block always executes on the
//!   same (pinned) worker, whose cache keeps that block's label/edge
//!   lines warm.
//! * [`PoolMetrics`] counts jobs, chunk pulls, steals, park/wake
//!   transitions, jobs in flight, core pins and sticky-job placement;
//!   the server `METRICS` verb reports them.

use std::collections::VecDeque;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use crate::obs::{Histogram, HistogramSnapshot};

/// Lock ignoring poisoning: a panic inside a pool job unwinds through
/// guards and would otherwise poison them, bricking the pool for the
/// rest of the process. All pool invariants are restored before any
/// unwinding can happen, so the poison flag carries no information.
fn lock_pool<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Whether workers pin themselves to cores. `CONTOUR_PIN=0` (or `off`/
/// `no`) disables; resolved once so all workers agree for the process
/// lifetime.
fn pin_enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| {
        !matches!(std::env::var("CONTOUR_PIN").as_deref(), Ok("0") | Ok("off") | Ok("no"))
    })
}

/// Core pinning for pool workers (ROADMAP: queue→core affinity). With
/// per-worker queues and sticky chunk blocks, pinning worker `w` to a
/// fixed core keeps one queue's label/edge working set in one core's
/// private cache across a whole run's passes. Linux-only — a direct
/// glibc/musl `sched_setaffinity` call so no external crate is needed —
/// and a graceful no-op elsewhere.
mod affinity {
    /// Pin the calling thread to the `worker`-th CPU of the process's
    /// **currently allowed** set (so `taskset`/cgroup cpusets are
    /// respected — pinning to absolute CPU 0..n would escape an
    /// operator's reservation and stack every contour process on the
    /// same low-numbered cores). Returns false, leaving the thread
    /// unpinned, when the allowed set cannot be read.
    #[cfg(target_os = "linux")]
    pub fn pin_current_thread(worker: usize) -> bool {
        // Mirrors glibc's `cpu_set_t`: a 1024-bit mask.
        const WORDS: usize = 1024 / 64;
        extern "C" {
            fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut u64) -> i32;
            fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
        }
        let mut allowed = [0u64; WORDS];
        // SAFETY: pid 0 targets the calling thread; the kernel writes
        // at most `cpusetsize` bytes into `allowed`, which the array
        // provides.
        let ok =
            unsafe { sched_getaffinity(0, std::mem::size_of_val(&allowed), allowed.as_mut_ptr()) };
        if ok != 0 {
            return false;
        }
        let cpus: Vec<usize> = (0..WORDS * 64)
            .filter(|&c| allowed[c / 64] & (1u64 << (c % 64)) != 0)
            .collect();
        if cpus.is_empty() {
            return false;
        }
        let core = cpus[worker % cpus.len()];
        let mut mask = [0u64; WORDS];
        mask[core / 64] |= 1u64 << (core % 64);
        // SAFETY: pid 0 targets the calling thread; the kernel only
        // reads `cpusetsize` bytes from `mask`, which the array
        // provides.
        unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
    }

    #[cfg(not(target_os = "linux"))]
    pub fn pin_current_thread(_worker: usize) -> bool {
        false
    }
}

/// Counters describing pool activity since process start.
#[derive(Default, Debug)]
pub struct PoolMetrics {
    /// Jobs submitted (one per parallel pass or one-shot task that
    /// reached the pool).
    pub jobs: AtomicU64,
    /// Chunks claimed off job cursors (the dynamic-scheduling analog of
    /// steal counts: one pull = one grain-sized unit of work).
    pub pulls: AtomicU64,
    /// Times a worker blocked waiting for work.
    pub parks: AtomicU64,
    /// Times a blocked worker resumed.
    pub wakes: AtomicU64,
    /// Queue entries taken from another worker's queue.
    pub steals: AtomicU64,
    /// Jobs currently submitted but not yet drained.
    pub inflight: AtomicU64,
    /// High-water mark of `inflight` — ≥ 2 demonstrates jobs overlapping
    /// (concurrent sessions, or one sharded run's per-shard jobs).
    pub max_inflight: AtomicU64,
    /// Participants currently *executing* a task closure.
    pub exec_active: AtomicU64,
    /// High-water mark of `exec_active`: unlike `max_inflight` (which
    /// counts submitted batches), ≥ 2 here proves task bodies actually
    /// ran concurrently.
    pub max_exec_active: AtomicU64,
    /// Workers successfully pinned to a core (0 when pinning is
    /// disabled via `CONTOUR_PIN=0` or unsupported on this OS).
    pub pins: AtomicU64,
    /// Sticky passes submitted through [`Pool::run_sticky`].
    pub sticky_jobs: AtomicU64,
    /// Sticky slot jobs executed by their home worker. With sticky
    /// entries excluded from stealing this is every slot job — the
    /// stable chunk→worker mapping the stress test asserts.
    pub sticky_home: AtomicU64,
    /// Sticky slot jobs executed away from their home worker. Kept as a
    /// counter (rather than assumed impossible) so any future
    /// scheduling change that breaks the placement invariant shows up
    /// in METRICS and fails the stress test.
    pub sticky_away: AtomicU64,
    /// Enqueue→claim latency per job execution: time a queue entry sat
    /// before a participant claimed it. Queue-wait growing while
    /// run-time stays flat means the pool is saturated, not slow.
    pub queue_wait: Histogram,
    /// Claim→finish latency per job execution (the task body itself).
    pub run_time: Histogram,
}

/// Plain-value snapshot of [`PoolMetrics`] for rendering.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Total worker count including the submitting thread.
    pub workers: usize,
    pub jobs: u64,
    pub pulls: u64,
    pub parks: u64,
    pub wakes: u64,
    pub steals: u64,
    pub inflight: u64,
    pub max_inflight: u64,
    /// Peak count of concurrently executing task bodies.
    pub exec_peak: u64,
    /// Workers pinned to a core.
    pub pins: u64,
    pub sticky_jobs: u64,
    pub sticky_home: u64,
    pub sticky_away: u64,
    /// Enqueue→claim latency distribution (ns).
    pub queue_wait: HistogramSnapshot,
    /// Claim→finish latency distribution (ns).
    pub run_time: HistogramSnapshot,
}

/// Lifetime-erased pointer to a submitter's task closure. Raw (not a
/// reference) on purpose: stale queue entries may outlive the closure,
/// and a raw pointer held without being dereferenced carries no
/// validity obligation. It is dereferenced only between a successful
/// seat claim and the submitting frame's return, during which the
/// borrow is alive.
type TaskPtr = *const (dyn Fn() + Sync + 'static);

fn erase(task: &(dyn Fn() + Sync)) -> TaskPtr {
    // Lifetime erasure only (ref-to-ptr casts may change the trait
    // object lifetime); validity of later dereferences is argued at the
    // claim sites.
    task as TaskPtr
}

/// Seats live in the low 32 bits of a job's packed state, active
/// participants in the high 32.
const ACTIVE_ONE: u64 = 1 << 32;
const SEATS_MASK: u64 = (1 << 32) - 1;

struct Job {
    task: TaskPtr,
    /// When this job was created (≈ enqueued: creation and queue push
    /// are adjacent in every submitter). `execute` turns it into the
    /// queue-wait sample at claim time.
    enqueued: Instant,
    /// `(active << 32) | seats`: open seats grant entry, active counts
    /// participants currently inside the closure. The job is drained
    /// exactly when both halves are zero.
    state: AtomicU64,
    /// A participant's task invocation panicked (re-raised by the
    /// submitter once the job is drained).
    panicked: AtomicBool,
    /// Sticky placement: `Some(w)` means this job belongs on worker
    /// `w`'s queue and must not be stolen by other workers — the
    /// chunk→worker stability [`Pool::run_sticky`] promises.
    home: Option<usize>,
}

// SAFETY: `task` is only dereferenced under the claim protocol (see
// `TaskPtr`); everything else is atomics.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    fn new(task: TaskPtr, seats: usize, submitter_active: bool) -> Arc<Self> {
        let init = (if submitter_active { ACTIVE_ONE } else { 0 }) | seats as u64;
        Arc::new(Self {
            task,
            enqueued: Instant::now(),
            state: AtomicU64::new(init),
            panicked: AtomicBool::new(false),
            home: None,
        })
    }

    /// A single-seat sticky job homed on worker `home`'s queue.
    fn new_homed(task: TaskPtr, home: usize) -> Arc<Self> {
        Arc::new(Self {
            task,
            enqueued: Instant::now(),
            state: AtomicU64::new(1),
            panicked: AtomicBool::new(false),
            home: Some(home),
        })
    }

    /// Claim one seat (seats -= 1, active += 1) if any seat is open.
    fn claim(&self) -> bool {
        self.state
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |s| {
                if s & SEATS_MASK == 0 {
                    None
                } else {
                    Some(s - 1 + ACTIVE_ONE)
                }
            })
            .is_ok()
    }

    /// Leave after running the task; true when the job is now drained
    /// (no active participants, no open seats).
    fn finish(&self) -> bool {
        self.state.fetch_sub(ACTIVE_ONE, Ordering::AcqRel) - ACTIVE_ONE == 0
    }

    /// Chunked-job submitter epilogue: close the remaining seats and
    /// drop the submitter's own participation in one atomic step, so no
    /// late claim can slip in between. True when the job is drained.
    fn retire_submitter(&self) -> bool {
        let prev = self
            .state
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |s| {
                Some((s & !SEATS_MASK) - ACTIVE_ONE)
            })
            .expect("retire never bails");
        (prev & !SEATS_MASK) - ACTIVE_ONE == 0
    }

    fn is_done(&self) -> bool {
        self.state.load(Ordering::Acquire) == 0
    }
}

struct Inner {
    /// One deque per worker; worker `w` pops queue `w` from the front
    /// and steals from the others' backs.
    queues: Vec<Mutex<VecDeque<Arc<Job>>>>,
    /// Bumped after every enqueue batch so a parked worker can tell new
    /// work from a spurious wakeup without rescanning under the lock.
    gen: AtomicU64,
    park: Mutex<()>,
    work: Condvar,
    /// Submitters wait here for their jobs to drain.
    idle: Mutex<()>,
    done: Condvar,
    metrics: PoolMetrics,
}

/// The process-wide pool. Obtain via [`global`].
pub struct Pool {
    inner: Arc<Inner>,
    /// Total worker count including the submitting thread.
    threads: usize,
    /// Round-robin cursor over worker queues for enqueues.
    next_queue: AtomicUsize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// True while this thread executes inside a pool job (worker or
    /// submitter); nested parallel calls check it and run inline.
    static IN_JOB: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

/// The global pool, spawning its workers on first use.
pub fn global() -> &'static Pool {
    POOL.get_or_init(Pool::start)
}

/// Pool metrics without forcing pool startup (all-zero before first use).
pub fn stats() -> PoolStats {
    match POOL.get() {
        Some(p) => p.stats(),
        None => PoolStats::default(),
    }
}

/// Raw per-bucket counts of the queue-wait histogram (all-zero before
/// first use). The telemetry ring samples these so HEALTH can derive a
/// *windowed* queue-wait p95 from count deltas — the lifetime snapshot
/// in [`PoolStats`] cannot answer "p95 over the last minute".
pub fn queue_wait_buckets() -> [u64; crate::obs::BUCKETS] {
    match POOL.get() {
        Some(p) => p.inner.metrics.queue_wait.bucket_counts(),
        None => [0; crate::obs::BUCKETS],
    }
}

/// True while the current thread is executing inside a pool job.
pub fn in_job() -> bool {
    IN_JOB.with(|f| f.get())
}

/// RAII guard marking the current thread as inside a job so nested
/// parallel calls run inline. Pool workers set the flag directly; the
/// spawn-per-call substrate's task workers ([`crate::par::par_tasks`])
/// are plain scoped threads and use this guard for the same nesting
/// rule (Drop restores the flag even on unwind).
pub(crate) struct JobScope {
    was: bool,
}

impl JobScope {
    pub(crate) fn enter() -> Self {
        Self { was: IN_JOB.with(|c| c.replace(true)) }
    }
}

impl Drop for JobScope {
    fn drop(&mut self) {
        let was = self.was;
        IN_JOB.with(|c| c.set(was));
    }
}

impl Pool {
    fn start() -> Self {
        let threads = super::num_threads();
        let workers = threads.saturating_sub(1);
        let inner = Arc::new(Inner {
            queues: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            gen: AtomicU64::new(0),
            park: Mutex::new(()),
            work: Condvar::new(),
            idle: Mutex::new(()),
            done: Condvar::new(),
            metrics: PoolMetrics::default(),
        });
        for wid in 0..workers {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name(format!("contour-pool-{wid}"))
                .spawn(move || worker_loop(&inner, wid))
                .expect("spawning pool worker");
        }
        Self { inner, threads, next_queue: AtomicUsize::new(0) }
    }

    /// Total worker count including the submitting thread.
    pub fn max_threads(&self) -> usize {
        self.threads
    }

    pub fn metrics(&self) -> &PoolMetrics {
        &self.inner.metrics
    }

    pub fn stats(&self) -> PoolStats {
        let m = &self.inner.metrics;
        PoolStats {
            workers: self.threads,
            jobs: m.jobs.load(Ordering::Relaxed),
            pulls: m.pulls.load(Ordering::Relaxed),
            parks: m.parks.load(Ordering::Relaxed),
            wakes: m.wakes.load(Ordering::Relaxed),
            steals: m.steals.load(Ordering::Relaxed),
            inflight: m.inflight.load(Ordering::Relaxed),
            max_inflight: m.max_inflight.load(Ordering::Relaxed),
            exec_peak: m.max_exec_active.load(Ordering::Relaxed),
            pins: m.pins.load(Ordering::Relaxed),
            sticky_jobs: m.sticky_jobs.load(Ordering::Relaxed),
            sticky_home: m.sticky_home.load(Ordering::Relaxed),
            sticky_away: m.sticky_away.load(Ordering::Relaxed),
            queue_wait: m.queue_wait.snapshot(),
            run_time: m.run_time.snapshot(),
        }
    }

    /// Push `entries` references to `job` onto distinct worker queues
    /// (round-robin) and wake the workers.
    fn enqueue(&self, job: &Arc<Job>, entries: usize) {
        let n = self.inner.queues.len();
        if n == 0 || entries == 0 {
            return;
        }
        for _ in 0..entries.min(n) {
            let q = self.next_queue.fetch_add(1, Ordering::Relaxed) % n;
            lock_pool(&self.inner.queues[q]).push_back(Arc::clone(job));
        }
        self.notify_work();
    }

    fn notify_work(&self) {
        self.inner.gen.fetch_add(1, Ordering::Release);
        // Take the park lock (empty critical section) so the bump
        // cannot land between a worker's failed scan and its wait.
        drop(lock_pool(&self.inner.park));
        self.inner.work.notify_all();
    }

    /// Block until `job` is drained (every participant left, no open
    /// seat remains).
    fn wait_done(&self, job: &Job) {
        let mut guard = lock_pool(&self.inner.idle);
        while !job.is_done() {
            guard = self.inner.done.wait(guard).unwrap_or_else(PoisonError::into_inner);
        }
    }

    fn job_submitted(&self, count: u64) {
        let m = &self.inner.metrics;
        m.jobs.fetch_add(count, Ordering::Relaxed);
        let now = m.inflight.fetch_add(count, Ordering::Relaxed) + count;
        m.max_inflight.fetch_max(now, Ordering::Relaxed);
    }

    /// Run `task` on up to `extra` pool workers plus the calling thread,
    /// returning once every participant has finished. `task` must be
    /// safe to invoke from several threads at once (each invocation
    /// pulls disjoint chunks from a shared cursor until it is drained).
    pub fn run(&self, extra: usize, task: &(dyn Fn() + Sync)) {
        let seats = extra.min(self.threads.saturating_sub(1));
        self.job_submitted(1);
        // SAFETY: the erased borrow never outlives this frame — we do
        // not return until seats are closed and `active == 0`, i.e. no
        // worker holds or will take the task pointer.
        let job = Job::new(erase(task), seats, true);
        self.enqueue(&job, seats);
        // The submitter always participates; catch a panic so workers
        // still borrowing `task` are waited for before unwinding.
        let mine = {
            let _in_job = JobScope::enter();
            count_exec(&self.inner.metrics, || catch_unwind(AssertUnwindSafe(task)))
        };
        if !job.retire_submitter() {
            self.wait_done(&job);
        }
        self.inner.metrics.inflight.fetch_sub(1, Ordering::Relaxed);
        if let Err(p) = mine {
            resume_unwind(p);
        }
        if job.panicked.load(Ordering::Acquire) {
            panic!("pool worker panicked during parallel pass");
        }
    }

    /// Run `count` one-shot tasks — `task(i)` invoked **exactly once**
    /// per index — as independent jobs all in flight at once. Pool
    /// workers and the submitting thread claim and run them
    /// concurrently; the call returns when every task has finished.
    /// This is the sharded executor's substrate: one job per shard.
    /// Panics propagate (as one panic) after all tasks settle.
    pub fn run_many(&self, count: usize, task: &(dyn Fn(usize) + Sync)) {
        if count == 0 {
            return;
        }
        // One wrapper closure per index, kept alive by this frame until
        // every job is drained below.
        let wrappers: Vec<Box<dyn Fn() + Sync + '_>> =
            (0..count).map(|i| Box::new(move || task(i)) as Box<dyn Fn() + Sync + '_>).collect();
        self.job_submitted(count as u64);
        // SAFETY: see `run` — no claim can start after a job's single
        // seat is taken, and we wait for every job before returning.
        let jobs: Vec<Arc<Job>> =
            wrappers.iter().map(|w| Job::new(erase(w.as_ref()), 1, false)).collect();
        let n = self.inner.queues.len();
        if n > 0 {
            for job in &jobs {
                let q = self.next_queue.fetch_add(1, Ordering::Relaxed) % n;
                lock_pool(&self.inner.queues[q]).push_back(Arc::clone(job));
            }
            self.notify_work();
        }
        // The submitter claims whatever no worker has taken yet, so the
        // set completes even on a single-threaded pool.
        for job in &jobs {
            execute(&self.inner, job, None);
        }
        let mut panicked = false;
        for job in &jobs {
            self.wait_done(job);
            panicked |= job.panicked.load(Ordering::Acquire);
        }
        self.inner.metrics.inflight.fetch_sub(count as u64, Ordering::Relaxed);
        if panicked {
            panic!("pool task panicked");
        }
    }

    /// Run `task(slot)` exactly once per slot in `0..slots` with a
    /// **stable slot→worker mapping**: slot 0 runs on the submitting
    /// thread; slot `s >= 1` becomes a single-seat job enqueued
    /// directly on worker `s - 1`'s own queue, excluded from stealing.
    /// Repeated sticky passes over the same slot layout (a Contour
    /// run's ~log d_max iterations) therefore land each slot — and the
    /// chunk block it owns — on the same (pinned) worker every time,
    /// keeping that block's cache lines resident. The price is that a
    /// slot whose home worker is busy waits for it instead of migrating;
    /// callers balance slots by work (edge-balanced chunks) for exactly
    /// this reason. Requires `2 <= slots <= max_threads()`; panics
    /// propagate (as one panic) after every slot settles.
    pub fn run_sticky(&self, slots: usize, task: &(dyn Fn(usize) + Sync)) {
        debug_assert!(
            (2..=self.threads).contains(&slots),
            "run_sticky wants 2..=threads slots, got {slots}"
        );
        self.job_submitted(1);
        self.inner.metrics.sticky_jobs.fetch_add(1, Ordering::Relaxed);
        // One wrapper closure per non-submitter slot, kept alive by this
        // frame until every job is drained below.
        let wrappers: Vec<Box<dyn Fn() + Sync + '_>> = (1..slots)
            .map(|s| Box::new(move || task(s)) as Box<dyn Fn() + Sync + '_>)
            .collect();
        // SAFETY: see `run` — each job has a single seat and this frame
        // waits for every job to drain before returning, so the erased
        // borrows never outlive it.
        let jobs: Vec<Arc<Job>> = wrappers
            .iter()
            .enumerate()
            .map(|(w, t)| Job::new_homed(erase(t.as_ref()), w))
            .collect();
        for (w, job) in jobs.iter().enumerate() {
            lock_pool(&self.inner.queues[w]).push_back(Arc::clone(job));
        }
        self.notify_work();
        let mine = {
            let _in_job = JobScope::enter();
            count_exec(&self.inner.metrics, || catch_unwind(AssertUnwindSafe(|| task(0))))
        };
        let mut panicked = false;
        for job in &jobs {
            self.wait_done(job);
            panicked |= job.panicked.load(Ordering::Acquire);
        }
        self.inner.metrics.inflight.fetch_sub(1, Ordering::Relaxed);
        if let Err(p) = mine {
            resume_unwind(p);
        }
        if panicked {
            panic!("pool worker panicked during sticky pass");
        }
    }
}

/// Run `f` counted as an executing participant (drives `exec_active`
/// and its high-water mark). `f` must not unwind — both callers wrap
/// the task in `catch_unwind` first.
fn count_exec<R>(metrics: &PoolMetrics, f: impl FnOnce() -> R) -> R {
    let now = metrics.exec_active.fetch_add(1, Ordering::Relaxed) + 1;
    metrics.max_exec_active.fetch_max(now, Ordering::Relaxed);
    let r = f();
    metrics.exec_active.fetch_sub(1, Ordering::Relaxed);
    r
}

/// Pop work: own queue front first, then steal from the others' backs.
/// Sticky jobs are only ever taken by their home worker — stealing one
/// would break the chunk→worker stability `run_sticky` exists for — so
/// the steal scan skips them.
fn find_work(inner: &Inner, wid: usize) -> Option<Arc<Job>> {
    let n = inner.queues.len();
    if n == 0 {
        return None;
    }
    if let Some(j) = lock_pool(&inner.queues[wid]).pop_front() {
        return Some(j);
    }
    for off in 1..n {
        let idx = (wid + off) % n;
        let mut q = lock_pool(&inner.queues[idx]);
        if let Some(pos) = q.iter().rposition(|j| j.home.is_none()) {
            let j = q.remove(pos).expect("rposition index is in bounds");
            drop(q);
            inner.metrics.steals.fetch_add(1, Ordering::Relaxed);
            return Some(j);
        }
    }
    None
}

/// Claim a seat on `job` and, on success, run its task once. A failed
/// claim means the entry is stale (job already full or retired).
/// `wid` is the executing pool worker (`None` for a submitting thread),
/// checked against sticky jobs' home placement for the metrics.
fn execute(inner: &Inner, job: &Job, wid: Option<usize>) {
    if !job.claim() {
        return;
    }
    inner.metrics.queue_wait.record_duration(job.enqueued.elapsed());
    if let Some(home) = job.home {
        let c = if wid == Some(home) {
            &inner.metrics.sticky_home
        } else {
            &inner.metrics.sticky_away
        };
        c.fetch_add(1, Ordering::Relaxed);
    }
    // SAFETY: a successful claim pins the job open (`active > 0`), and
    // the submitter does not return — so the closure outlives this call
    // — until every claimed participant has finished.
    let task: &(dyn Fn() + Sync) = unsafe { &*job.task };
    let started = Instant::now();
    let r = {
        let _in_job = JobScope::enter();
        count_exec(&inner.metrics, || {
            catch_unwind(AssertUnwindSafe(|| {
                // Failpoint `pool.job`: any armed action panics the task
                // in place — the interesting behavior to exercise is the
                // panic funnel (mark job panicked, re-raise on the
                // submitter, isolate at dispatch), not the action kind.
                if crate::util::faults::fire("pool.job").is_some() {
                    panic!("injected fault at pool.job");
                }
                task()
            }))
        })
    };
    inner.metrics.run_time.record_duration(started.elapsed());
    if r.is_err() {
        job.panicked.store(true, Ordering::Release);
    }
    if job.finish() {
        // Serialize with a submitter between its is_done check and its
        // wait, so the notification cannot be lost.
        drop(lock_pool(&inner.idle));
        inner.done.notify_all();
    }
}

fn worker_loop(inner: &Inner, wid: usize) {
    if pin_enabled() && affinity::pin_current_thread(wid) {
        inner.metrics.pins.fetch_add(1, Ordering::Relaxed);
    }
    loop {
        let gen = inner.gen.load(Ordering::Acquire);
        if let Some(job) = find_work(inner, wid) {
            execute(inner, &job, Some(wid));
            continue;
        }
        let guard = lock_pool(&inner.park);
        if inner.gen.load(Ordering::Acquire) == gen {
            inner.metrics.parks.fetch_add(1, Ordering::Relaxed);
            drop(inner.work.wait(guard).unwrap_or_else(PoisonError::into_inner));
            inner.metrics.wakes.fetch_add(1, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_executes_on_caller_even_alone() {
        // Independent of pool size: extra = 0 means the caller does all
        // the work, and the call still returns.
        let hits = AtomicUsize::new(0);
        global().run(0, &|| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn jobs_counter_advances() {
        let before = stats().jobs;
        global().run(0, &|| {});
        global().run(0, &|| {});
        assert!(stats().jobs >= before + 2);
    }

    #[test]
    fn cursor_drained_exactly_once_with_workers() {
        // A realistic job: every participant pulls chunks off one cursor.
        let n = 1 << 20;
        let grain = 1 << 10;
        let cursor = AtomicUsize::new(0);
        let sum = AtomicU64::new(0);
        global().run(usize::MAX, &|| loop {
            let start = cursor.fetch_add(grain, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + grain).min(n);
            let mut local = 0u64;
            for i in start..end {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn submitter_panic_propagates_after_quiescence() {
        let caught = std::panic::catch_unwind(|| {
            global().run(0, &|| panic!("boom"));
        });
        assert!(caught.is_err());
        // The pool remains usable afterwards.
        let ok = AtomicUsize::new(0);
        global().run(0, &|| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn run_many_invokes_each_task_exactly_once() {
        let count = 37;
        let hits: Vec<AtomicUsize> = (0..count).map(|_| AtomicUsize::new(0)).collect();
        global().run_many(count, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn run_many_registers_tasks_in_flight() {
        // The whole set is submitted before any completion is awaited,
        // so the high-water mark must reach the set size even on a
        // single-threaded pool.
        let before = stats().jobs;
        global().run_many(5, &|_| {});
        let s = stats();
        assert!(s.jobs >= before + 5, "jobs {} -> {}", before, s.jobs);
        assert!(s.max_inflight >= 5, "max_inflight {}", s.max_inflight);
    }

    #[test]
    fn run_many_panic_propagates_and_pool_survives() {
        let caught = std::panic::catch_unwind(|| {
            global().run_many(4, &|i| {
                if i == 2 {
                    panic!("task boom");
                }
            });
        });
        assert!(caught.is_err());
        let ok = AtomicUsize::new(0);
        global().run_many(3, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn run_sticky_covers_every_slot_once() {
        let p = global();
        if p.max_threads() < 2 {
            return; // single-thread pool: the par layer inlines sticky passes
        }
        let slots = p.max_threads().min(4);
        let hits: Vec<AtomicUsize> = (0..slots).map(|_| AtomicUsize::new(0)).collect();
        p.run_sticky(slots, &|s| {
            hits[s].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sticky_jobs_never_leave_their_home_worker() {
        let p = global();
        if p.max_threads() < 2 {
            return;
        }
        let slots = p.max_threads().min(3);
        for _ in 0..50 {
            p.run_sticky(slots, &|_| {});
        }
        let s = stats();
        assert_eq!(s.sticky_away, 0, "sticky jobs migrated off their home worker");
        assert!(s.sticky_home >= 50 * (slots as u64 - 1), "home runs {}", s.sticky_home);
    }

    #[test]
    fn run_sticky_panic_propagates_and_pool_survives() {
        let p = global();
        if p.max_threads() < 2 {
            return;
        }
        let caught = std::panic::catch_unwind(|| {
            p.run_sticky(2, &|s| {
                if s == 1 {
                    panic!("sticky boom");
                }
            });
        });
        assert!(caught.is_err());
        let ok = AtomicUsize::new(0);
        p.run_sticky(2, &|_| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn concurrent_submitters_overlap() {
        // Two threads submitting chunked jobs at once: both finish and
        // both are correct (the old pool serialized these on a submit
        // lock; the multi-job pool runs them in flight together).
        let n = 1 << 18;
        let want = (n as u64 - 1) * n as u64 / 2;
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let cursor = AtomicUsize::new(0);
                    let sum = AtomicU64::new(0);
                    global().run(usize::MAX, &|| loop {
                        let start = cursor.fetch_add(1 << 10, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + (1 << 10)).min(n);
                        let mut local = 0u64;
                        for i in start..end {
                            local += i as u64;
                        }
                        sum.fetch_add(local, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), want);
                });
            }
        });
    }
}
