//! Persistent worker pool behind the [`crate::par`] substrate.
//!
//! The paper's cost story (§III) is that each Contour iteration is one
//! cheap O(m) sweep of a highly parallel operator, and its Chapel
//! implementation rides a tasking runtime whose workers live for the
//! whole program. Our old substrate instead spawned and joined OS
//! threads on *every* `edge_pass`, `check_converged`, and
//! `finalize_stars` call — O(log d_max) spawn/join rounds per run, paid
//! again per server request. This module amortizes that cost the way
//! Chapel (and ConnectIt's scheduler) do: a process-wide set of workers
//! that park on a condvar between jobs and are woken by an epoch bump on
//! a single shared job slot.
//!
//! Design:
//!
//! * Workers are spawned lazily on the first parallel pass, sized from
//!   `CONTOUR_THREADS` (read **once**, see [`crate::par::num_threads`])
//!   or the machine's available parallelism.
//! * A job is a lifetime-erased `&dyn Fn()` every participating worker
//!   runs to exhaustion; the closure pulls chunks off the caller's
//!   atomic cursor, so scheduling stays dynamic exactly as before.
//! * One job runs at a time; concurrent submitters (server sessions)
//!   queue on a submit lock. The submitting thread always participates,
//!   so `threads = 1` or a busy pool still makes progress.
//! * Nested parallel calls (a `par_for` inside a pool job) run inline
//!   sequentially — the single job slot cannot be re-entered, and the
//!   outer pass already owns every worker.
//! * [`PoolMetrics`] counts jobs, chunk pulls, and park/wake
//!   transitions; the server `METRICS` verb reports them.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};

/// Lock ignoring poisoning: a panic inside a pool job unwinds through
/// the submit guard and would otherwise poison it, bricking the pool
/// for the rest of the process. All pool invariants are restored before
/// any unwinding can happen, so the poison flag carries no information.
fn lock_pool<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Counters describing pool activity since process start.
#[derive(Default, Debug)]
pub struct PoolMetrics {
    /// Jobs submitted (one per parallel pass that reached the pool).
    pub jobs: AtomicU64,
    /// Chunks claimed off job cursors (the dynamic-scheduling analog of
    /// steal counts: one pull = one grain-sized unit of work).
    pub pulls: AtomicU64,
    /// Times a worker blocked waiting for work.
    pub parks: AtomicU64,
    /// Times a blocked worker resumed.
    pub wakes: AtomicU64,
}

/// Plain-value snapshot of [`PoolMetrics`] for rendering.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Total worker count including the submitting thread.
    pub workers: usize,
    pub jobs: u64,
    pub pulls: u64,
    pub parks: u64,
    pub wakes: u64,
}

#[derive(Clone, Copy)]
struct Job {
    /// Lifetime-erased task; valid because [`Pool::run`] does not return
    /// until every worker that entered it has left.
    task: &'static (dyn Fn() + Sync),
    /// Pool workers that may still join this job (the submitter is not
    /// counted — it always participates).
    seats: usize,
}

struct Slot {
    /// Bumped once per submitted job so workers can tell a fresh job
    /// from a spurious wakeup or one they already served.
    epoch: u64,
    /// Current job; cleared by the submitter before it waits for
    /// stragglers, so late-waking workers skip it.
    job: Option<Job>,
    /// Workers currently inside the job's closure.
    running: usize,
    /// A worker's task invocation panicked (re-raised by the submitter).
    panicked: bool,
}

struct Inner {
    slot: Mutex<Slot>,
    work: Condvar,
    done: Condvar,
    metrics: PoolMetrics,
}

/// The process-wide pool. Obtain via [`global`].
pub struct Pool {
    inner: Arc<Inner>,
    /// Serializes jobs: the slot holds one job at a time.
    submit: Mutex<()>,
    /// Total worker count including the submitting thread.
    threads: usize,
}

static POOL: OnceLock<Pool> = OnceLock::new();

thread_local! {
    /// True while this thread executes inside a pool job (worker or
    /// submitter); nested parallel calls check it and run inline.
    static IN_JOB: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

/// The global pool, spawning its workers on first use.
pub fn global() -> &'static Pool {
    POOL.get_or_init(Pool::start)
}

/// Pool metrics without forcing pool startup (all-zero before first use).
pub fn stats() -> PoolStats {
    match POOL.get() {
        Some(p) => p.stats(),
        None => PoolStats::default(),
    }
}

/// True while the current thread is executing inside a pool job.
pub fn in_job() -> bool {
    IN_JOB.with(|f| f.get())
}

impl Pool {
    fn start() -> Self {
        let threads = super::num_threads();
        let inner = Arc::new(Inner {
            slot: Mutex::new(Slot { epoch: 0, job: None, running: 0, panicked: false }),
            work: Condvar::new(),
            done: Condvar::new(),
            metrics: PoolMetrics::default(),
        });
        for i in 1..threads {
            let inner = Arc::clone(&inner);
            std::thread::Builder::new()
                .name(format!("contour-pool-{i}"))
                .spawn(move || worker_loop(&inner))
                .expect("spawning pool worker");
        }
        Self { inner, submit: Mutex::new(()), threads }
    }

    /// Total worker count including the submitting thread.
    pub fn max_threads(&self) -> usize {
        self.threads
    }

    pub fn metrics(&self) -> &PoolMetrics {
        &self.inner.metrics
    }

    pub fn stats(&self) -> PoolStats {
        let m = &self.inner.metrics;
        PoolStats {
            workers: self.threads,
            jobs: m.jobs.load(Ordering::Relaxed),
            pulls: m.pulls.load(Ordering::Relaxed),
            parks: m.parks.load(Ordering::Relaxed),
            wakes: m.wakes.load(Ordering::Relaxed),
        }
    }

    /// Run `task` on up to `extra` pool workers plus the calling thread,
    /// returning once every participant has finished. `task` must be
    /// safe to invoke from several threads at once (each invocation
    /// pulls disjoint chunks from a shared cursor until it is drained).
    pub fn run(&self, extra: usize, task: &(dyn Fn() + Sync)) {
        // SAFETY: the erased borrow never outlives this frame — we do
        // not return until the slot is cleared and `running == 0`, i.e.
        // no worker holds or will take the task reference.
        let task: &'static (dyn Fn() + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(task) };
        let _turn = lock_pool(&self.submit);
        self.inner.metrics.jobs.fetch_add(1, Ordering::Relaxed);
        {
            let mut slot = lock_pool(&self.inner.slot);
            debug_assert_eq!(slot.running, 0, "job slot reused while busy");
            slot.epoch = slot.epoch.wrapping_add(1);
            slot.job = Some(Job { task, seats: extra.min(self.threads.saturating_sub(1)) });
            self.inner.work.notify_all();
        }
        // The submitter always participates; catch a panic so workers
        // still borrowing `task` are waited for before unwinding.
        let was = IN_JOB.with(|f| f.replace(true));
        let mine = catch_unwind(AssertUnwindSafe(task));
        IN_JOB.with(|f| f.set(was));
        let worker_panicked = {
            let mut slot = lock_pool(&self.inner.slot);
            slot.job = None; // no further joins
            while slot.running > 0 {
                slot = self.inner.done.wait(slot).unwrap_or_else(PoisonError::into_inner);
            }
            std::mem::take(&mut slot.panicked)
        };
        if let Err(p) = mine {
            resume_unwind(p);
        }
        if worker_panicked {
            panic!("pool worker panicked during parallel pass");
        }
    }
}

fn worker_loop(inner: &Inner) {
    let mut seen = 0u64;
    loop {
        let task = {
            let mut slot = lock_pool(&inner.slot);
            loop {
                if slot.epoch != seen {
                    seen = slot.epoch;
                    match &mut slot.job {
                        Some(job) if job.seats > 0 => {
                            job.seats -= 1;
                            slot.running += 1;
                            break Some(job.task);
                        }
                        // Full (or already-drained) job: sit this one out.
                        _ => break None,
                    }
                }
                inner.metrics.parks.fetch_add(1, Ordering::Relaxed);
                slot = inner.work.wait(slot).unwrap_or_else(PoisonError::into_inner);
                inner.metrics.wakes.fetch_add(1, Ordering::Relaxed);
            }
        };
        if let Some(task) = task {
            IN_JOB.with(|f| f.set(true));
            let r = catch_unwind(AssertUnwindSafe(task));
            IN_JOB.with(|f| f.set(false));
            let mut slot = lock_pool(&inner.slot);
            if r.is_err() {
                slot.panicked = true;
            }
            slot.running -= 1;
            if slot.running == 0 {
                inner.done.notify_all();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn run_executes_on_caller_even_alone() {
        // Independent of pool size: extra = 0 means the caller does all
        // the work, and the call still returns.
        let hits = AtomicUsize::new(0);
        global().run(0, &|| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn jobs_counter_advances() {
        let before = stats().jobs;
        global().run(0, &|| {});
        global().run(0, &|| {});
        assert!(stats().jobs >= before + 2);
    }

    #[test]
    fn cursor_drained_exactly_once_with_workers() {
        // A realistic job: every participant pulls chunks off one cursor.
        let n = 1 << 20;
        let grain = 1 << 10;
        let cursor = AtomicUsize::new(0);
        let sum = AtomicU64::new(0);
        global().run(usize::MAX, &|| loop {
            let start = cursor.fetch_add(grain, Ordering::Relaxed);
            if start >= n {
                break;
            }
            let end = (start + grain).min(n);
            let mut local = 0u64;
            for i in start..end {
                local += i as u64;
            }
            sum.fetch_add(local, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), (n as u64 - 1) * n as u64 / 2);
    }

    #[test]
    fn submitter_panic_propagates_after_quiescence() {
        let caught = std::panic::catch_unwind(|| {
            global().run(0, &|| panic!("boom"));
        });
        assert!(caught.is_err());
        // The pool remains usable afterwards.
        let ok = AtomicUsize::new(0);
        global().run(0, &|| {
            ok.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ok.load(Ordering::Relaxed), 1);
    }
}
