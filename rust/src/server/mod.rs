//! Interactive analytics server — the Arkouda/Arachne integration analog.
//!
//! The paper's system is not a batch binary: Arachne extends Arkouda, an
//! *interactive* server where a Python client sends messages (over ZMQ)
//! to a parallel Chapel back end that holds graphs in memory and answers
//! `graph_cc(G)` queries (§III-A). This module reproduces that
//! architecture with the Rust coordinator as the back end, layered so
//! the protocol surface cannot drift between transports:
//!
//! * [`dispatch`] — the transport-agnostic verb interpreter: one
//!   `dispatch(state, verb, args, body) -> Reply` core that both wire
//!   adapters share (and that unit tests drive directly, no TCP);
//! * the line-oriented TCP protocol below (ZMQ stand-in; one request
//!   per line, one response per line — trivially scriptable from any
//!   language) as a thin adapter over the core;
//! * [`protocol`] — binary framing v2 (`HELLO 2` upgrades a line
//!   connection): length-prefixed frames with request ids, pipelining
//!   with out-of-order completion, vectorized `BQUERY`, zero-copy
//!   `LABELS` pages;
//! * an in-memory session store of named graphs, with admission
//!   control: at most [`ServerState::heavy_cap`] heavy verbs run
//!   concurrently server-wide (excess requests get `ERR busy: ...` /
//!   a BUSY frame instead of queueing unboundedly), while cache hits
//!   and point queries stay wait-free.
//!
//! `python/client/contour_client.py` is the Arkouda-style Python client.
//! Python remains off the compute path — it only ships messages, exactly
//! like Arkouda's front end.
//!
//! Protocol (request → response, all single lines). Static graphs:
//!   GEN name SPEC                  → OK n m
//!   UPLOAD name m                  → then m lines "u v", → OK n m
//!   LOAD name PATH                 → OK n m
//!   CC name [ALG] [FRONTIER]       → OK components iterations millis
//!                                    (FRONTIER pins the Contour engine:
//!                                    exact | chunk | off; default = the
//!                                    server's CONTOUR_FRONTIER; pinned
//!                                    modes cache per (name, alg, mode))
//!   LABELS name [ALG] [off [cnt]]  → OK total l_off .. l_{off+cnt-1}
//!                                    (cnt defaults to 10000; page with
//!                                    off/cnt, total = label count)
//!   QUERY name v [ALG]             → OK label   (one vertex's component
//!                                    label; streams take `epoch:<e>` in
//!                                    the alg slot)
//!   BQUERY name [ALG] v [v ...]    → OK count l l ...  (batch labels,
//!                                    all answered from one snapshot;
//!                                    binary frames carry the ids in the
//!                                    payload instead of the arg list)
//!   STATS name                     → OK n=.. m=.. components=.. ...
//!   LIST                           → OK name:n:m ... shard/name:n:m ...
//!                                    stream/name:n:m ...
//!   DROP name                      → OK       (graph, shards or stream)
//!   METRICS                        → OK requests=.. cc_runs=.. ...
//!                                    uptime_ms=.. qps=.. bytes_in=..
//!                                    cache/<name>=hits:misses ...
//!                                    lat/<verb>=count:p50:p95:p99
//!                                    err/<verb>=count
//!                                    (per-verb request latency, ns, from
//!                                    log₂ histograms — error paths are
//!                                    metered too; lat/pool_wait and
//!                                    lat/pool_run meter the worker pool)
//!   TRACE name                     → OK n=.. dropped=.. span span ...
//!                                    (the most recent CC/PCC run's span
//!                                    timeline for that graph; each span
//!                                    is name|cat|mode|tid|start|dur[|k=v,..])
//!   RECENT [n]                     → OK count verb:ok:dur_ns ...
//!                                    (ring buffer of the last requests,
//!                                    oldest first)
//!   PROM                           → OK nlines + nlines of OpenMetrics
//!                                    text (the only multi-line reply:
//!                                    the first line carries the body's
//!                                    line count so line clients stay
//!                                    framed; binary frames carry the
//!                                    same payload whole; also served
//!                                    over plain HTTP via `contour serve
//!                                    --prom-addr`)
//!   HEALTH                         → OK ready|degraded|overloaded
//!                                    busy_frac=.. heavy_sat=..
//!                                    pool_wait_p95_ns=.. wal_fsync_ns=..
//!                                    (windowed rates vs env thresholds;
//!                                    see [`telemetry::render_health`])
//!   WATCH [ticks] [interval_ms]    → OK ticks interval, then one
//!                                    `TICK seq t_ms=.. dt_ms=.. k=Δv ..
//!                                    qps=..` line per interval, then
//!                                    DONE (binary: one OK frame per
//!                                    tick, same request id, then a
//!                                    DONE frame)
//!   HELLO 2                        → OK v2  (then the connection speaks
//!                                    binary frames; see [`protocol`])
//!   FAULTS [SET spec|CLEAR]        → OK ...  (test-gated fault-injection
//!                                    control — list, arm or clear the
//!                                    failpoint registry; only served
//!                                    when `CONTOUR_FAULTS` or
//!                                    `CONTOUR_FAULTS_VERB=1` is set,
//!                                    ERR otherwise; see
//!                                    [`crate::util::faults`])
//!   PING                           → PONG
//!   QUIT                           → BYE (closes connection)
//!
//! Robustness knobs (all per-process env, read at [`ServerState::new`]):
//! `CONTOUR_IDLE_MS` closes a connection that sends no complete request
//! for that long (BYE first; 0/unset = never — WATCH pushes are
//! write-driven and unaffected); `CONTOUR_WRITE_MS` bounds blocking
//! writes to a stalled client; `CONTOUR_DEADLINE_MS` gives every heavy
//! verb a compute budget, answered with `ERR deadline ...` when
//! exceeded. A panicking verb is caught at dispatch and answered with
//! `ERR internal ...` (counted in `panics`); the connection, the server
//! and every other request survive. On shutdown the server drains:
//! stops accepting, finishes in-flight requests, then BYEs each idle
//! connection.
//!
//! Sharded store (see [`crate::shard`]; SHARD partitions a stored graph
//! into p range shards — fences by vertex count or, with `edges`, by
//! cumulative edge count — PCC runs shard-local connectivity
//! concurrently — one pool job per shard — and contracts the boundary;
//! PCC results are cached per (name, alg, p, balance) like CC results,
//! with hits reporting 0.000 ms):
//!   SHARD name p [vertices|edges]  → OK p boundary_edges
//!   PCC name [ALG] [FRONTIER]      → OK components iterations millis
//!                                    (FRONTIER as in CC; with `exact`,
//!                                    repeat runs on one partition reuse
//!                                    each shard's vertex→chunk index)
//!   SHARDSTATS name                → OK p=.. n=.. m=.. boundary=..
//!                                    balance=.. shardK=lo:hi:m:...
//!
//! Streaming connectivity (see [`crate::stream`]; epochs are sealed
//! label snapshots, `e` defaults to the current epoch):
//!   STREAM name N [WALPATH] [HIST] → OK n epoch   (create; recover-on-open
//!                                    if WALPATH already exists; a WAL may
//!                                    back only one live stream; numeric
//!                                    HIST caps retained epoch snapshots)
//!   SADD name u v [u v ...]        → OK added epoch
//!   SDEL name u v [u v ...]        → OK removed epoch  (multiset delete;
//!                                    queries reflect it after the next
//!                                    SEPOCH; binary frames may carry the
//!                                    id pairs in the payload like BQUERY)
//!   SEPOCH name                    → OK epoch components  (seal epoch)
//!   SQUERY name SAME u v [e]       → OK 0|1 epoch
//!   SQUERY name SIZE v [e]         → OK size epoch
//!   SQUERY name COMPS [e]          → OK components epoch
//!   SQUERY name LABEL v [e]        → OK label epoch
//!   SSAVE name PATH                → OK epoch    (write binary snapshot)
//!   SLOAD name SNAPPATH [WALPATH]  → OK n epoch  (recover from disk)
//!
//! Sealed epochs are admitted into the CC labels cache, so `LABELS`
//! also pages streaming labellings (`epoch:<e>` in the alg slot picks a
//! retained epoch; default = current):
//!   LABELS streamname [epoch:E] [off [cnt]] → OK total l.. l..

pub mod dispatch;
pub mod metrics;
pub mod protocol;
pub mod telemetry;

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};

use anyhow::{anyhow, bail, Result};

use crate::cc;
use crate::graph::{gen, Csr, EdgeList};
use crate::obs::{Histogram, RunTrace};
use crate::shard::{self, ShardedGraph};
use crate::stream::{Snapshot, StreamingCc};
use crate::util::{mlock, rlock, wlock, Timer};
use crate::VId;

use metrics::Metrics;

/// Cap on cached connectivity results: label arrays are O(n) each, so
/// an unbounded cache grows with every (graph, alg) pair ever queried.
/// Beyond the cap the least recently touched entry is evicted.
pub const CC_CACHE_CAP: usize = 16;

/// Requests retained by the `RECENT` ring buffer.
pub const RECENT_CAP: usize = 64;

/// Default per-connection in-flight window for pipelined binary
/// requests (see [`protocol`]): beyond this many unanswered heavy
/// frames the connection gets BUSY replies instead of queueing.
pub const DEFAULT_WINDOW: usize = 64;

/// Every verb the dispatcher knows. `note_verb` interns the request's
/// verb against this table so the latency map and the recent-request
/// ring hold `&'static str`s and stay bounded even under a stream of
/// garbage commands (which are counted in `errors`, not interned).
const VERBS: &[&str] = &[
    "PING", "GEN", "UPLOAD", "LOAD", "CC", "LABELS", "STATS", "SHARD", "PCC", "SHARDSTATS",
    "STREAM", "SADD", "SEPOCH", "SQUERY", "SSAVE", "SLOAD", "LIST", "DROP", "METRICS", "TRACE",
    "RECENT", "QUERY", "BQUERY", "HELLO", "PROM", "HEALTH", "WATCH", "FAULTS",
];

/// Backing storage for a cached labelling: static entries own their
/// vector; stream entries share the sealed snapshot's allocation
/// instead of duplicating an O(n) copy.
enum CachedLabels {
    Owned(cc::Labels),
    Epoch(Arc<Snapshot>),
}

/// A memoized connectivity run for one (graph, algorithm) pair: what
/// `CC` reports and what `LABELS` pages through.
pub struct CcEntry {
    labels: CachedLabels,
    pub iterations: usize,
    pub components: usize,
    /// The exact graph this result was computed on, for static graphs.
    /// Hits verify it by pointer identity against the request's graph:
    /// replacing a name purges the cache, but purge and graph-map
    /// insert are separate critical sections, so a key match alone can
    /// be stale. `None` for streaming-epoch entries.
    graph: Option<Arc<Csr>>,
    /// The exact stream a streaming-epoch entry was read from, for the
    /// same identity check (a DROP + recreate reuses both the name and
    /// the epoch numbers, and the DROP purge races in-flight lookups).
    /// Weak so cached entries never keep a dropped stream — and its
    /// WAL claim — alive. `None` for static entries.
    stream: Option<Weak<StreamingCc>>,
    /// The exact partition a sharded (`PCC`) entry was computed on, for
    /// the same identity check (re-`SHARD` swaps the Arc even when
    /// `(p, balance)` — and therefore the cache key — repeat). Weak so
    /// a cached entry never keeps a replaced partition's O(n + m) copy
    /// alive. `None` for static and stream entries.
    sharded: Option<Weak<ShardedGraph>>,
    /// Last-touch stamp from [`ServerState::cache_clock`] (LRU order).
    stamp: AtomicU64,
}

impl CcEntry {
    /// The cached label array (min-vertex-id canonical).
    pub fn labels(&self) -> &[VId] {
        match &self.labels {
            CachedLabels::Owned(l) => l,
            CachedLabels::Epoch(s) => &s.labels,
        }
    }
}

/// A slot in the global heavy-verb semaphore, returned to the pool on
/// drop. Held across a heavy verb's compute (never across a cache
/// hit), so admission control bounds concurrent *work*, not requests.
pub struct HeavyPermit<'a>(&'a ServerState);

impl Drop for HeavyPermit<'_> {
    fn drop(&mut self) {
        self.0.heavy_avail.fetch_add(1, Ordering::AcqRel);
    }
}

/// Shared server state: the graph, shard and stream stores plus
/// counters.
pub struct ServerState {
    graphs: RwLock<HashMap<String, Arc<Csr>>>,
    /// Sharded views keyed by the source graph's name (SHARD/PCC).
    /// Replacing or dropping the source graph drops its view too — a
    /// partition of a graph that no longer exists must not serve.
    sharded: RwLock<HashMap<String, Arc<ShardedGraph>>>,
    streams: RwLock<HashMap<String, Arc<StreamingCc>>>,
    /// Connectivity results already computed for (graph, alg) — both
    /// `CC` reruns and LABELS paging would otherwise rerun connectivity
    /// per request. Bounded by [`CC_CACHE_CAP`] with LRU eviction;
    /// purged when the graph is replaced or dropped.
    labels_cache: RwLock<HashMap<(String, String), Arc<CcEntry>>>,
    /// Monotonic clock for LRU stamps in the labels cache.
    cache_clock: AtomicU64,
    /// Per-graph labels-cache accounting: name → (hits, misses), where
    /// a "miss" is a computed-and-admitted entry. Stream entries count
    /// under `stream/<name>`. Counts survive graph *replacement* (they
    /// describe the name) but are dropped with DROP, so the map stays
    /// bounded by the store's own lifecycle. RwLock + atomic counters:
    /// the hit path (every cached CC/LABELS) takes only the read side.
    cache_stats: RwLock<HashMap<String, (AtomicU64, AtomicU64)>>,
    /// WAL files claimed by streams that may still be alive — the map
    /// entry or an in-flight verb holding the Arc. A claim dies with
    /// its last Arc, so DROP + recreate on the same WAL is refused
    /// until in-flight operations on the dropped stream finish (a
    /// second appender would interleave frames, and recovery's
    /// torn-tail repair could truncate a frame mid-write).
    wal_claims: Mutex<HashMap<std::path::PathBuf, Weak<StreamingCc>>>,
    /// Most recent CC/PCC run trace per graph name (the `TRACE` verb).
    /// One entry per live name — replace and DROP purge it with the
    /// graph — so the map is bounded by the graph store's own
    /// lifecycle. No identity check: "most recent run under this name"
    /// is the verb's contract, and a stale timeline can mislead a human
    /// at worst, never serve wrong labels.
    traces: RwLock<HashMap<String, Arc<RunTrace>>>,
    /// Per-verb request-latency histograms (`lat/<verb>` in METRICS).
    /// Keys are interned against [`VERBS`], so the map stays bounded.
    verb_lat: RwLock<HashMap<&'static str, Histogram>>,
    /// Per-verb error counters (`err/<verb>` in METRICS), interned like
    /// `verb_lat`. Errors also land in the latency histograms: a
    /// failing verb's cost is as real as a succeeding one's.
    verb_err: RwLock<HashMap<&'static str, AtomicU64>>,
    /// Ring buffer of the last [`RECENT_CAP`] handled requests as
    /// (verb, ok, duration ns), oldest first (the `RECENT` verb).
    recent: Mutex<VecDeque<(&'static str, bool, u64)>>,
    /// Remaining slots in the global heavy-verb semaphore (admission
    /// control): decremented by [`Self::try_heavy`], restored when the
    /// [`HeavyPermit`] drops.
    heavy_avail: AtomicUsize,
    /// Total heavy-verb slots (the semaphore's capacity).
    heavy_cap: usize,
    /// Per-connection in-flight window for pipelined binary requests.
    window: usize,
    pub metrics: Metrics,
    /// Telemetry ring: periodic metric snapshots pushed by the sampler
    /// thread in [`serve_listener`] (tests push directly). PROM rate
    /// gauges, HEALTH's windowed signals and WATCH deltas all read it.
    pub ring: crate::obs::TimeSeries,
    /// Sampler interval override in ms (0 = `CONTOUR_SAMPLE_MS` or the
    /// default; see [`telemetry::sample_interval`]).
    sample_ms: u64,
    /// Worker threads each algorithm run may use (0 = all).
    pub threads: usize,
    /// Idle budget per connection (`CONTOUR_IDLE_MS`): close — BYE
    /// first — when no complete request arrives for this long. `None`
    /// = never.
    idle: Option<std::time::Duration>,
    /// Socket write timeout (`CONTOUR_WRITE_MS`): bound blocking writes
    /// to a stalled client. `None` = OS default (unbounded).
    write_timeout: Option<std::time::Duration>,
    /// Per-request compute budget for heavy verbs
    /// (`CONTOUR_DEADLINE_MS`): exceeded runs abandon at the next safe
    /// point and answer `ERR deadline ...`. `None` = unbounded.
    deadline: Option<std::time::Duration>,
}

impl ServerState {
    pub fn new(threads: usize) -> Self {
        // Clamp to the worker pool's size: a `--threads` above it would
        // silently push every pass onto the spawn-per-call fallback,
        // losing the pool amortization the server exists to exploit.
        // (0 = "all" already resolves to the pool size.)
        let threads = if threads == 0 { 0 } else { threads.min(crate::par::num_threads()) };
        // Heavy verbs saturate the worker pool; admitting many more
        // than the pool has threads only buys queueing and memory
        // pressure. The floor keeps small machines (and tests) from
        // serializing everything.
        let heavy_cap = crate::par::num_threads().max(4);
        Self {
            graphs: RwLock::new(HashMap::new()),
            sharded: RwLock::new(HashMap::new()),
            streams: RwLock::new(HashMap::new()),
            labels_cache: RwLock::new(HashMap::new()),
            cache_clock: AtomicU64::new(0),
            cache_stats: RwLock::new(HashMap::new()),
            wal_claims: Mutex::new(HashMap::new()),
            traces: RwLock::new(HashMap::new()),
            verb_lat: RwLock::new(HashMap::new()),
            verb_err: RwLock::new(HashMap::new()),
            recent: Mutex::new(VecDeque::with_capacity(RECENT_CAP)),
            heavy_avail: AtomicUsize::new(heavy_cap),
            heavy_cap,
            window: DEFAULT_WINDOW,
            metrics: Metrics::default(),
            ring: crate::obs::TimeSeries::new(telemetry::RING_CAP, telemetry::sample_keys()),
            sample_ms: 0,
            threads,
            idle: env_ms("CONTOUR_IDLE_MS"),
            write_timeout: env_ms("CONTOUR_WRITE_MS"),
            deadline: env_ms("CONTOUR_DEADLINE_MS"),
        }
    }

    /// Override the idle / write / heavy-verb-deadline budgets (ms;
    /// 0 disables), shadowing the `CONTOUR_*_MS` env defaults — tests
    /// and the CLI flags use this.
    pub fn with_timeouts(mut self, idle_ms: u64, write_ms: u64, deadline_ms: u64) -> Self {
        let ms = |v: u64| (v > 0).then(|| std::time::Duration::from_millis(v));
        self.idle = ms(idle_ms);
        self.write_timeout = ms(write_ms);
        self.deadline = ms(deadline_ms);
        self
    }

    /// Per-connection idle budget, if bounded (`CONTOUR_IDLE_MS`).
    pub fn idle(&self) -> Option<std::time::Duration> {
        self.idle
    }

    /// Socket write timeout, if bounded (`CONTOUR_WRITE_MS`).
    pub fn write_timeout(&self) -> Option<std::time::Duration> {
        self.write_timeout
    }

    /// Heavy-verb compute budget, if bounded (`CONTOUR_DEADLINE_MS`).
    pub fn deadline(&self) -> Option<std::time::Duration> {
        self.deadline
    }

    /// Evict every cached labelling associated with `name` — the static
    /// entries plus the `shard/` and `stream/` namespaces. Called when
    /// a verb touching `name` panics: a task that died mid-update may
    /// have been computing *into* state these entries describe, so the
    /// cheap safe move is to recompute on next touch rather than trust
    /// anything cached under the name.
    pub(crate) fn purge_labels_cache(&self, name: &str) {
        let skey = Self::shard_cache_name(name);
        let stkey = format!("stream/{name}");
        crate::util::wlock(&self.labels_cache)
            .retain(|k, _| k.0 != name && k.0 != skey && k.0 != stkey);
    }

    /// Override the telemetry sampler interval (ms; clamped to
    /// [`telemetry::MIN_SAMPLE_MS`]). 0 keeps the `CONTOUR_SAMPLE_MS` /
    /// default resolution.
    pub fn with_sample_interval(mut self, ms: u64) -> Self {
        self.sample_ms = ms;
        self
    }

    /// Override admission-control limits: the per-connection pipeline
    /// window (clamped to ≥ 1 — a window of 0 could never admit any
    /// request) and the global heavy-verb cap (0 = reject every heavy
    /// verb, useful for drain mode and tests).
    pub fn with_admission(mut self, window: usize, heavy: usize) -> Self {
        self.window = window.max(1);
        self.heavy_cap = heavy;
        self.heavy_avail = AtomicUsize::new(heavy);
        self
    }

    /// Per-connection in-flight window for pipelined binary requests.
    pub fn window(&self) -> usize {
        self.window
    }

    /// Capacity of the global heavy-verb semaphore.
    pub fn heavy_cap(&self) -> usize {
        self.heavy_cap
    }

    /// Try to claim a heavy-verb slot; `None` means the server is at
    /// capacity and the request should be answered busy, not queued.
    /// Wait-free (one CAS loop over contending claimers).
    pub fn try_heavy(&self) -> Option<HeavyPermit<'_>> {
        let mut cur = self.heavy_avail.load(Ordering::Acquire);
        loop {
            if cur == 0 {
                return None;
            }
            match self.heavy_avail.compare_exchange_weak(
                cur,
                cur - 1,
                Ordering::AcqRel,
                Ordering::Acquire,
            ) {
                Ok(_) => return Some(HeavyPermit(self)),
                Err(seen) => cur = seen,
            }
        }
    }

    fn touch(&self, e: &CcEntry) {
        let now = self.cache_clock.fetch_add(1, Ordering::Relaxed) + 1;
        e.stamp.store(now, Ordering::Relaxed);
    }

    /// Cache/stat namespace for a graph's sharded (PCC) results — the
    /// one definition every purge and lookup site shares, so the
    /// spelling cannot drift. (Like the `stream/<name>` namespace this
    /// mirrors, it is a string prefix: a graph literally *named*
    /// `shard/x` would share the namespace of graph `x`'s sharded
    /// view — a pre-existing quirk of the wire protocol's flat name
    /// space, costing at worst a spurious eviction or a conflated
    /// METRICS line, never wrong labels.)
    fn shard_cache_name(name: &str) -> String {
        format!("shard/{name}")
    }

    /// Record a per-graph labels-cache hit or miss (and the matching
    /// global counter). Hot path (the name already has counters, i.e.
    /// every request after the first) is a read lock plus one relaxed
    /// increment — no allocation, no exclusive lock.
    fn note_cache(&self, name: &str, hit: bool) {
        if hit {
            self.metrics.cc_cache_hits.inc();
        } else {
            self.metrics.cc_cache_misses.inc();
        }
        {
            let m = self.cache_stats.read().unwrap_or_else(|e| e.into_inner());
            if let Some(e) = m.get(name) {
                let c = if hit { &e.0 } else { &e.1 };
                c.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        let mut m = self.cache_stats.write().unwrap_or_else(|e| e.into_inner());
        let e = m.entry(name.to_string()).or_default();
        let c = if hit { &e.0 } else { &e.1 };
        c.fetch_add(1, Ordering::Relaxed);
    }

    /// Per-graph cache counters as ` cache/<name>=hits:misses ...`
    /// (leading space; empty when nothing was ever looked up), appended
    /// to the METRICS reply.
    pub fn render_cache_stats(&self) -> String {
        let m = self.cache_stats.read().unwrap_or_else(|e| e.into_inner());
        let mut pairs: Vec<String> = m
            .iter()
            .map(|(k, (h, mi))| {
                format!(
                    "cache/{k}={}:{}",
                    h.load(Ordering::Relaxed),
                    mi.load(Ordering::Relaxed)
                )
            })
            .collect();
        pairs.sort();
        if pairs.is_empty() {
            String::new()
        } else {
            format!(" {}", pairs.join(" "))
        }
    }

    /// Publish `name`'s most recent run trace (served by the `TRACE`
    /// verb). CC and PCC overwrite the same slot, so the verb always
    /// answers with the latest run on that graph.
    fn store_trace(&self, name: &str, t: Arc<RunTrace>) {
        self.traces.write().unwrap_or_else(|e| e.into_inner()).insert(name.to_string(), t);
    }

    /// The most recent run trace stored under `name`, if any.
    pub fn trace_of(&self, name: &str) -> Option<Arc<RunTrace>> {
        self.traces.read().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
    }

    /// Record one handled request into the per-verb latency histogram
    /// and the recent-request ring. Unknown commands are not interned
    /// (so the maps stay bounded); the steady-state path is a read lock
    /// plus the histogram's relaxed fetch-adds.
    fn note_verb(&self, verb: &str, ok: bool, dur: std::time::Duration) {
        let Some(&v) = VERBS.iter().find(|&&v| v == verb) else {
            return;
        };
        let ns = dur.as_nanos().min(u64::MAX as u128) as u64;
        let recorded = match self.verb_lat.read().unwrap_or_else(|e| e.into_inner()).get(v) {
            Some(h) => {
                h.record(ns);
                true
            }
            None => false,
        };
        if !recorded {
            wlock(&self.verb_lat).entry(v).or_default().record(ns);
        }
        let mut r = self.recent.lock().unwrap_or_else(|e| e.into_inner());
        if r.len() == RECENT_CAP {
            r.pop_front();
        }
        r.push_back((v, ok, ns));
    }

    /// Count one ERR (or BUSY) reply against its verb — `err/<verb>` in
    /// METRICS. Interned like `note_verb`, so garbage commands are not
    /// interned and the map stays bounded.
    fn note_err(&self, verb: &str) {
        let Some(&v) = VERBS.iter().find(|&&v| v == verb) else {
            return;
        };
        {
            let m = self.verb_err.read().unwrap_or_else(|e| e.into_inner());
            if let Some(c) = m.get(v) {
                c.fetch_add(1, Ordering::Relaxed);
                return;
            }
        }
        self.verb_err
            .write().unwrap_or_else(|e| e.into_inner())
            .entry(v)
            .or_default()
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Per-verb latency histograms as ` lat/<verb>=count:p50:p95:p99`
    /// (leading space; empty before the first request; values in ns,
    /// sorted by verb), appended to the METRICS reply alongside the
    /// per-graph cache counters.
    pub fn render_verb_lat(&self) -> String {
        let m = self.verb_lat.read().unwrap_or_else(|e| e.into_inner());
        let mut pairs: Vec<String> =
            m.iter().map(|(v, h)| format!("lat/{v}={}", h.snapshot().render())).collect();
        pairs.sort();
        if pairs.is_empty() {
            String::new()
        } else {
            format!(" {}", pairs.join(" "))
        }
    }

    /// Per-verb error counters as ` err/<verb>=count ...` (leading
    /// space; empty until the first error; sorted by verb), appended to
    /// the METRICS reply after the latency histograms.
    pub fn render_verb_err(&self) -> String {
        let m = self.verb_err.read().unwrap_or_else(|e| e.into_inner());
        let mut pairs: Vec<String> =
            m.iter().map(|(v, c)| format!("err/{v}={}", c.load(Ordering::Relaxed))).collect();
        pairs.sort();
        if pairs.is_empty() {
            String::new()
        } else {
            format!(" {}", pairs.join(" "))
        }
    }

    /// Evict the least recently touched entry when the cache is full
    /// and `key` is not already resident. Caller holds the write lock.
    fn evict_if_full(map: &mut HashMap<(String, String), Arc<CcEntry>>, key: &(String, String)) {
        if map.len() >= CC_CACHE_CAP && !map.contains_key(key) {
            let victim = map
                .iter()
                .min_by_key(|(_, e)| e.stamp.load(Ordering::Relaxed))
                .map(|(k, _)| k.clone());
            if let Some(v) = victim {
                map.remove(&v);
            }
        }
    }

    /// The connectivity result for `(graph, alg)`, served from the
    /// labels cache or computed by `compute` and admitted (evicting the
    /// least recently touched entry when the cache is full). Returns
    /// the entry plus `Some(millis)` when a run actually happened
    /// (`None` = cache hit); the run is timed and accounted to
    /// `cc_runs`/`cc_millis` here so CC and LABELS misses are metered
    /// identically. Two sessions missing concurrently may both compute;
    /// the results are identical and the last insert wins.
    pub fn cc_cached<F>(
        &self,
        name: &str,
        alg: &str,
        g: &Arc<Csr>,
        compute: F,
    ) -> Result<(Arc<CcEntry>, Option<f64>)>
    where
        F: FnOnce() -> Result<cc::RunResult>,
    {
        let key = (name.to_string(), alg.to_string());
        if let Some(e) = rlock(&self.labels_cache).get(&key).cloned() {
            // Pointer identity, not just key match: a racing replace of
            // this name may not have purged the old entry yet.
            if e.graph.as_ref().map_or(false, |eg| Arc::ptr_eq(eg, g)) {
                self.touch(&e);
                self.note_cache(name, true);
                return Ok((e, None));
            }
        }
        let t = Timer::start();
        let r = compute()?;
        let ms = t.ms();
        self.metrics.cc_runs.inc();
        self.metrics.cc_millis.add(ms as u64);
        let entry = Arc::new(CcEntry {
            components: cc::num_components(&r.labels),
            labels: CachedLabels::Owned(r.labels),
            iterations: r.iterations,
            graph: Some(Arc::clone(g)),
            stream: None,
            sharded: None,
            stamp: AtomicU64::new(0),
        });
        self.touch(&entry);
        let mut map = self.labels_cache.write().unwrap_or_else(|e| e.into_inner());
        // Admit only if `name` still maps to the graph we computed on:
        // a concurrent GEN/UPLOAD/LOAD may have replaced it (purging
        // these keys) while we computed, and inserting then would
        // resurrect labels for a graph that no longer exists.
        let still_current =
            rlock(&self.graphs).get(name).is_some_and(|cur| Arc::ptr_eq(cur, g));
        if still_current {
            // Count the miss only on admission: a racing DROP must not
            // have its cache_stats cleanup resurrected by this lookup.
            self.note_cache(name, false);
            Self::evict_if_full(&mut map, &key);
            map.insert(key, Arc::clone(&entry));
        }
        Ok((entry, Some(ms)))
    }

    /// Cached labels for a sealed stream epoch (ROADMAP item: admit
    /// streaming epoch labellings into the CC labels cache). Admitted
    /// lazily on first LABELS touch — never on SEPOCH itself, so a
    /// stream sealing epochs nobody pages cannot evict the static CC
    /// entries from the bounded cache. Epochs are immutable, so a key
    /// hit stays valid as long as the stream exists (DROP purges every
    /// `stream/<name>` key). Returns the entry plus whether it was a
    /// hit.
    pub fn stream_cached(
        &self,
        name: &str,
        s: &Arc<StreamingCc>,
        epoch: u64,
    ) -> Result<(Arc<CcEntry>, bool)> {
        let cache_name = format!("stream/{name}");
        let key = (cache_name.clone(), format!("epoch:{epoch}"));
        // Bind the lookup first: an `if let` on the locked expression
        // would hold the read guard through the body (temporary
        // lifetime extension), deadlocking the dead-entry removal's
        // write lock below.
        let cached = self.labels_cache.read().unwrap_or_else(|e| e.into_inner()).get(&key).cloned();
        if let Some(e) = cached {
            // Pointer identity against the *current* stream, like the
            // static path: a DROP + recreate reuses name and epoch
            // numbers, and the DROP purge can race an in-flight lookup.
            let same_stream = e
                .stream
                .as_ref()
                .map_or(false, |w| w.upgrade().map_or(false, |cur| Arc::ptr_eq(&cur, s)));
            // Serve only epochs the stream still retains: otherwise
            // LABELS for an evicted epoch would answer from the cache
            // while SQUERY for the same epoch errors, and flip to an
            // error whenever the cache entry happens to be LRU-evicted.
            let retained = s.at_epoch(epoch).is_some();
            if same_stream && retained {
                self.touch(&e);
                self.note_cache(&cache_name, true);
                return Ok((e, true));
            }
            if same_stream && !retained {
                // Dead entry: the epoch left the stream's history, so
                // it can never hit again — free its cache slot (and
                // the snapshot it pins) instead of waiting for LRU.
                self.labels_cache.write().unwrap_or_else(|e| e.into_inner()).remove(&key);
            }
        }
        let snap = s.snapshot_at(Some(epoch))?;
        let entry = Arc::new(CcEntry {
            components: snap.num_components,
            labels: CachedLabels::Epoch(snap),
            iterations: 0,
            graph: None,
            stream: Some(Arc::downgrade(s)),
            sharded: None,
            stamp: AtomicU64::new(0),
        });
        self.touch(&entry);
        let mut map = self.labels_cache.write().unwrap_or_else(|e| e.into_inner());
        // Admit only while `name` still maps to this stream: a racing
        // DROP (or DROP + recreate) must not have its purge undone —
        // neither in the cache nor in cache_stats (miss counted only on
        // admission).
        let still_current =
            rlock(&self.streams).get(name).is_some_and(|cur| Arc::ptr_eq(cur, s));
        if still_current {
            self.note_cache(&cache_name, false);
            Self::evict_if_full(&mut map, &key);
            map.insert(key, Arc::clone(&entry));
        }
        Ok((entry, false))
    }

    /// The partitioned-connectivity result for a sharded view, served
    /// from the labels cache or computed by `compute` and admitted
    /// (ROADMAP item: PCC recomputed every time). Keyed
    /// `(shard/<name>, <alg>:p<p>:<balance>)` and — like the static
    /// cache — verified by pointer identity against the *current*
    /// sharded view, so a re-`SHARD` (same or different parameters) or
    /// a racing graph replace can never serve a dead partition's
    /// labels. Returns the entry plus `Some(millis)` when a sharded run
    /// actually happened (`None` = cache hit); runs are accounted to
    /// `pcc_runs`/`pcc_millis` here, and per-view hits/misses appear in
    /// METRICS as `cache/shard/<name>`.
    pub fn pcc_cached<F>(
        &self,
        name: &str,
        alg: &str,
        sg: &Arc<ShardedGraph>,
        compute: F,
    ) -> Result<(Arc<CcEntry>, Option<f64>)>
    where
        F: FnOnce() -> Result<shard::ShardedRun>,
    {
        let cache_name = Self::shard_cache_name(name);
        let key = (cache_name.clone(), format!("{alg}:p{}:{}", sg.p(), sg.balance.as_str()));
        if let Some(e) = rlock(&self.labels_cache).get(&key).cloned() {
            let same = e
                .sharded
                .as_ref()
                .map_or(false, |w| w.upgrade().map_or(false, |cur| Arc::ptr_eq(&cur, sg)));
            if same {
                self.touch(&e);
                self.note_cache(&cache_name, true);
                return Ok((e, None));
            }
        }
        let t = Timer::start();
        let r = compute()?;
        let ms = t.ms();
        self.metrics.pcc_runs.inc();
        self.metrics.pcc_millis.add(ms as u64);
        let entry = Arc::new(CcEntry {
            components: cc::num_components(&r.labels),
            labels: CachedLabels::Owned(r.labels),
            iterations: r.iterations,
            graph: None,
            stream: None,
            sharded: Some(Arc::downgrade(sg)),
            stamp: AtomicU64::new(0),
        });
        self.touch(&entry);
        let mut map = self.labels_cache.write().unwrap_or_else(|e| e.into_inner());
        // Admit only while `name`'s sharded view is still the exact
        // partition we computed on: a concurrent SHARD/GEN/DROP must
        // not have its purge undone (miss counted only on admission,
        // mirroring the static path).
        let still_current =
            rlock(&self.sharded).get(name).is_some_and(|cur| Arc::ptr_eq(cur, sg));
        if still_current {
            self.note_cache(&cache_name, false);
            Self::evict_if_full(&mut map, &key);
            map.insert(key, Arc::clone(&entry));
        }
        Ok((entry, Some(ms)))
    }

    #[cfg(test)]
    fn cache_len(&self) -> usize {
        self.labels_cache.read().unwrap_or_else(|e| e.into_inner()).len()
    }

    pub fn insert(&self, name: &str, g: Csr) {
        wlock(&self.graphs).insert(name.to_string(), Arc::new(g));
        let skey = Self::shard_cache_name(name);
        // Purge both the static entries and any cached PCC labellings:
        // a sharded view partitions the *replaced* graph, so its cached
        // results are as dead as the view itself (dropped below).
        wlock(&self.labels_cache).retain(|k, _| k.0 != name && k.0 != skey);
        self.sharded.write().unwrap_or_else(|e| e.into_inner()).remove(name);
        // A replaced graph's timeline describes a dead graph.
        self.traces.write().unwrap_or_else(|e| e.into_inner()).remove(name);
    }

    pub fn get(&self, name: &str) -> Option<Arc<Csr>> {
        self.graphs.read().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
    }

    /// Register a sharded view of graph `name`, guarding against a
    /// racing replace: the view is admitted only while `name` still
    /// maps to the exact graph that was partitioned (the same
    /// pointer-identity rule the labels cache uses) — otherwise
    /// PCC/SHARDSTATS would serve a partition of a dead graph. Returns
    /// `None` when the graph was replaced or dropped mid-partition.
    /// (Holding the sharded write lock across the identity check
    /// serializes with `insert`'s purge: either the purge runs after
    /// this insert and removes it, or the check sees the new graph.)
    pub fn insert_sharded(
        &self,
        name: &str,
        src: &Arc<Csr>,
        sg: ShardedGraph,
    ) -> Option<Arc<ShardedGraph>> {
        let sg = Arc::new(sg);
        let mut map = self.sharded.write().unwrap_or_else(|e| e.into_inner());
        let still_current =
            rlock(&self.graphs).get(name).is_some_and(|cur| Arc::ptr_eq(cur, src));
        if !still_current {
            return None;
        }
        map.insert(name.to_string(), Arc::clone(&sg));
        self.metrics.shards_created.inc();
        Some(sg)
    }

    pub fn get_sharded(&self, name: &str) -> Option<Arc<ShardedGraph>> {
        self.sharded.read().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
    }

    /// Create (or recover) a stream and register it under `name`,
    /// holding the stream-store lock across the uniqueness checks AND
    /// the build: check-then-insert outside one critical section would
    /// let two racing creations double-claim a name or — worse — attach
    /// two WAL appenders to the same file, corrupting the log. Building
    /// under the lock stalls other stream verbs during a long recovery;
    /// that is the price of the invariant.
    pub fn create_stream<F>(
        &self,
        name: &str,
        wal: Option<&Path>,
        build: F,
    ) -> Result<Arc<StreamingCc>>
    where
        F: FnOnce() -> Result<StreamingCc>,
    {
        let mut map = self.streams.write().unwrap_or_else(|e| e.into_inner());
        anyhow::ensure!(
            !map.contains_key(name),
            "stream {name:?} already exists (DROP it first)"
        );
        if let Some(w) = wal {
            let cand = canonical_wal(w);
            let mut claims = self.wal_claims.lock().unwrap_or_else(|e| e.into_inner());
            claims.retain(|_, s| s.strong_count() > 0);
            if claims.contains_key(&cand) {
                bail!(
                    "WAL {w:?} already backs a live stream (DROP it and let in-flight \
                     operations finish)"
                );
            }
        }
        let s = Arc::new(build()?);
        if let Some(p) = s.wal_path() {
            mlock(&self.wal_claims).insert(canonical_wal(p), Arc::downgrade(&s));
        }
        map.insert(name.to_string(), Arc::clone(&s));
        self.metrics.streams_created.inc();
        Ok(s)
    }

    pub fn get_stream(&self, name: &str) -> Option<Arc<StreamingCc>> {
        self.streams.read().unwrap_or_else(|e| e.into_inner()).get(name).cloned()
    }

    /// Drop a graph (with its sharded view) or stream by name (graphs
    /// take precedence).
    pub fn drop_graph(&self, name: &str) -> bool {
        if self.graphs.write().unwrap_or_else(|e| e.into_inner()).remove(name).is_some() {
            let skey = ServerState::shard_cache_name(name);
            wlock(&self.labels_cache).retain(|k, _| k.0 != name && k.0 != skey);
            self.sharded.write().unwrap_or_else(|e| e.into_inner()).remove(name);
            let mut stats = self.cache_stats.write().unwrap_or_else(|e| e.into_inner());
            stats.remove(name);
            stats.remove(&skey);
            self.traces.write().unwrap_or_else(|e| e.into_inner()).remove(name);
            return true;
        }
        if self.streams.write().unwrap_or_else(|e| e.into_inner()).remove(name).is_some() {
            // Streaming graphs cache sealed-epoch labellings under
            // `stream/<name>`; dropping the stream must evict them or a
            // recreated stream reusing the name (and its epoch numbers)
            // would serve the dead stream's labels.
            let skey = format!("stream/{name}");
            self.labels_cache.write().unwrap_or_else(|e| e.into_inner()).retain(|k, _| k.0 != skey);
            self.cache_stats.write().unwrap_or_else(|e| e.into_inner()).remove(&skey);
            return true;
        }
        false
    }

    pub fn list(&self) -> Vec<(String, usize, usize)> {
        let mut v: Vec<_> = self
            .graphs
            .read().unwrap_or_else(|e| e.into_inner())
            .iter()
            .map(|(k, g)| (k.clone(), g.n, g.m()))
            .collect();
        v.extend(
            self.sharded
                .read().unwrap_or_else(|e| e.into_inner())
                .iter()
                .map(|(k, s)| (format!("shard/{k}"), s.n, s.m)),
        );
        v.extend(
            self.streams
                .read().unwrap_or_else(|e| e.into_inner())
                .iter()
                .map(|(k, s)| (format!("stream/{k}"), s.n(), s.edges_ingested())),
        );
        v.sort();
        v
    }
}

/// A `CONTOUR_*_MS` env knob as a duration: a positive integer is
/// milliseconds, 0/unset/garbage disables the budget.
fn env_ms(name: &str) -> Option<std::time::Duration> {
    std::env::var(name)
        .ok()
        .and_then(|v| v.trim().parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(std::time::Duration::from_millis)
}

/// Best-effort canonical form of a WAL path for the one-appender check:
/// resolves symlinks/relative segments when the file (or its directory)
/// exists, falls back to the textual path otherwise.
fn canonical_wal(p: &Path) -> std::path::PathBuf {
    if let Ok(c) = p.canonicalize() {
        return c;
    }
    match (p.parent(), p.file_name()) {
        (Some(dir), Some(f)) if !dir.as_os_str().is_empty() => {
            dir.canonicalize().map(|d| d.join(f)).unwrap_or_else(|_| p.to_path_buf())
        }
        _ => p.to_path_buf(),
    }
}

/// Parse one `u v` UPLOAD payload line (ids must fit [`VId`]).
fn parse_edge_line(line: &str) -> Result<(u64, u64)> {
    let mut f = line.split_whitespace();
    let mut next = || -> Result<u64> {
        let tok = f.next().ok_or_else(|| anyhow!("expected `u v`, got {line:?}"))?;
        let x: u64 = tok.parse().map_err(|e| anyhow!("bad vertex id {tok:?}: {e}"))?;
        anyhow::ensure!(u64::from(VId::MAX) >= x, "vertex id {x} out of range");
        Ok(x)
    };
    let u = next()?;
    let v = next()?;
    Ok((u, v))
}

/// Parse a generator SPEC (same grammar as the CLI: `rmat:14:16`, ...).
pub fn graph_from_spec(spec: &str) -> Result<EdgeList> {
    let parts: Vec<&str> = spec.split(':').collect();
    let num = |i: usize| -> Result<usize> {
        parts
            .get(i)
            .ok_or_else(|| anyhow!("spec {spec:?}: missing field {i}"))?
            .parse::<usize>()
            .map_err(|e| anyhow!("spec {spec:?} field {i}: {e}"))
    };
    let seed = 42u64;
    Ok(match parts[0] {
        "path" => gen::path(num(1)?),
        "cycle" => gen::cycle(num(1)?),
        "star" => gen::star(num(1)?),
        "complete" => gen::complete(num(1)?),
        "grid" => gen::grid(num(1)?, num(2)?),
        "road" => gen::road(num(1)?, num(2)?, seed),
        "tree" => gen::binary_tree(num(1)? as u32),
        "comb" => gen::comb(num(1)?, num(2)?),
        "kmer" => gen::kmer_chains(num(1)?, num(2)?, seed),
        "er" => gen::erdos_renyi(num(1)?, num(2)?, seed),
        "ba" => gen::barabasi_albert(num(1)?, num(2)?, seed),
        "rmat" => gen::rmat(num(1)? as u32, num(2)? << num(1)?, gen::RmatKind::Graph500, seed),
        "delaunay" => gen::delaunay(num(1)?, seed),
        "soup" => gen::component_soup(num(1)?, num(2)?, seed),
        other => bail!("unknown generator {other:?}"),
    })
}

/// One client session over any line-based transport — a thin adapter
/// over [`dispatch`]: parse the line, run the shared core, render the
/// [`dispatch::Reply`] back to classic `OK ...`/`ERR ...` text. All
/// verb logic lives in the core; this type exists so in-process callers
/// (tests, tools) keep a line-level entry point.
pub struct Session<'s> {
    state: &'s ServerState,
}

impl<'s> Session<'s> {
    pub fn new(state: &'s ServerState) -> Self {
        Self { state }
    }

    /// Handle one request line; `read_extra` supplies follow-up lines for
    /// multi-line commands (UPLOAD). Returns the response line, or None
    /// for QUIT.
    pub fn handle<R: FnMut() -> Result<String>>(
        &mut self,
        line: &str,
        mut read_extra: R,
    ) -> Option<String> {
        dispatch::render_line(&dispatch::handle_line(self.state, line, &mut read_extra))
    }
}

/// Serve on `addr` until `shutdown` flips true. Each connection gets a
/// thread (interactive clients are few; algorithm runs parallelize
/// internally). For binds on port 0 use [`serve_listener`] with a
/// pre-bound listener so the caller can learn the real port first.
pub fn serve(addr: &str, state: Arc<ServerState>, shutdown: Arc<AtomicBool>) -> Result<()> {
    serve_listener(TcpListener::bind(addr)?, state, shutdown)
}

/// [`serve`] on an already-bound listener. Binding is the caller's job
/// so "bind port 0, read `local_addr`, then connect" is race-free —
/// hardcoded test ports collide under parallel test runs.
pub fn serve_listener(
    listener: TcpListener,
    state: Arc<ServerState>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    let addr = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    crate::info!("contour server listening on {addr}");
    std::thread::scope(|scope| {
        // Telemetry sampler: one ring sample per interval for as long as
        // the server runs. Sleeps in short slices so shutdown is prompt.
        {
            let state = Arc::clone(&state);
            let shutdown = Arc::clone(&shutdown);
            scope.spawn(move || {
                let interval = telemetry::sample_interval(&state);
                let slice = interval.min(std::time::Duration::from_millis(50));
                telemetry::sample_into_ring(&state);
                let mut last = std::time::Instant::now();
                while !shutdown.load(Ordering::Relaxed) {
                    std::thread::sleep(slice);
                    if last.elapsed() >= interval {
                        telemetry::sample_into_ring(&state);
                        last = std::time::Instant::now();
                    }
                }
            });
        }
        loop {
            if shutdown.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let state = Arc::clone(&state);
                    let shutdown = Arc::clone(&shutdown);
                    scope.spawn(move || {
                        let _ = handle_conn(stream, &state, &shutdown);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => {
                    crate::info!("accept error: {e}");
                    break;
                }
            }
        }
        // Whatever ended the accept loop, release the sampler thread so
        // the scope can join. Connection threads see the same flag at
        // their next command boundary (within [`POLL_MS`]) and drain:
        // finish the in-flight request, write BYE, close — so the scope
        // join below is the graceful-shutdown barrier.
        shutdown.store(true, Ordering::Relaxed);
    });
    Ok(())
}

/// Minimal plain-HTTP scrape endpoint (`contour serve --prom-addr`):
/// every request — path ignored, Prometheus sends `GET /metrics` — gets
/// a `200` with the current OpenMetrics exposition and the connection
/// closes. Deliberately not a web server: no keep-alive, no routing,
/// one short-lived thread per scrape (scrapes arrive every ~15s).
pub fn serve_prom_listener(
    listener: TcpListener,
    state: Arc<ServerState>,
    shutdown: Arc<AtomicBool>,
) -> Result<()> {
    listener.set_nonblocking(true)?;
    crate::info!("prometheus scrape endpoint on {}", listener.local_addr()?);
    std::thread::scope(|scope| {
        loop {
            if shutdown.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let state = Arc::clone(&state);
                    scope.spawn(move || {
                        let _ = answer_scrape(stream, &state);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => {
                    crate::info!("prom accept error: {e}");
                    break;
                }
            }
        }
    });
    Ok(())
}

/// One scrape: drain the request head, answer, close. The read budget
/// is the server's idle budget (`CONTOUR_IDLE_MS`), defaulting to 5 s —
/// a scraper that opens the socket and never finishes its request head
/// must not pin a thread forever.
fn answer_scrape(stream: TcpStream, state: &ServerState) -> Result<()> {
    stream.set_nonblocking(false)?;
    let budget = state.idle().unwrap_or(std::time::Duration::from_secs(5));
    stream.set_read_timeout(Some(budget))?;
    stream.set_write_timeout(state.write_timeout())?;
    let mut reader = BufReader::new(stream.try_clone()?);
    // Read request line + headers up to the blank line; tolerate
    // clients that just open the socket and wait.
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 || line == "\r\n" || line == "\n" {
            break;
        }
    }
    let mut body = telemetry::render_prom(state);
    body.push('\n');
    let mut writer = BufWriter::new(stream);
    write!(
        writer,
        "HTTP/1.1 200 OK\r\nContent-Type: application/openmetrics-text; version=1.0.0; \
         charset=utf-8\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    writer.write_all(body.as_bytes())?;
    writer.flush()?;
    state.metrics.bytes_out.add(body.len() as u64);
    Ok(())
}

/// Socket read-poll interval for line connections: reads wake this
/// often to check the idle budget and the drain flag, so neither knob
/// needs a kernel timeout equal to the (possibly unbounded) budget.
const POLL_MS: u64 = 200;

/// How long a draining server waits for the *rest* of a half-received
/// request line before abandoning the connection anyway — bounds the
/// shutdown barrier even against a client that stalls mid-command with
/// no idle budget configured.
const DRAIN_GRACE_MS: u64 = 2000;

/// What one polled line read produced.
enum LineRead {
    /// A complete line is in the buffer.
    Line,
    /// Clean EOF — the client hung up.
    Eof,
    /// Idle budget exhausted with no complete request.
    Idle,
    /// Drain requested at a command boundary (or mid-line past the
    /// grace period): stop serving this connection.
    Drain,
}

/// `read_line` under the [`POLL_MS`] socket timeout: keep polling —
/// partial bytes accumulate in `line` across timeouts — until a full
/// line, EOF, the idle budget, or (between commands) a drain request.
/// `shutdown: None` means "mid-command": a drain must not abandon a
/// half-consumed payload, or the tail would desync the next session's
/// framing.
fn poll_read_line(
    reader: &mut BufReader<TcpStream>,
    line: &mut String,
    idle: Option<std::time::Duration>,
    shutdown: Option<&AtomicBool>,
) -> std::io::Result<LineRead> {
    let start = std::time::Instant::now();
    let mut drain_since: Option<std::time::Instant> = None;
    loop {
        match reader.read_line(line) {
            Ok(0) => return Ok(LineRead::Eof),
            Ok(_) => return Ok(LineRead::Line),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                if let Some(sd) = shutdown {
                    if sd.load(Ordering::Relaxed) {
                        // At a command boundary (no bytes of a next
                        // request yet) drain immediately; mid-line,
                        // give the client a bounded grace to finish.
                        if line.is_empty() {
                            return Ok(LineRead::Drain);
                        }
                        let since = *drain_since.get_or_insert_with(std::time::Instant::now);
                        if since.elapsed() >= std::time::Duration::from_millis(DRAIN_GRACE_MS) {
                            return Ok(LineRead::Drain);
                        }
                    }
                }
                if let Some(budget) = idle {
                    if start.elapsed() >= budget {
                        return Ok(LineRead::Idle);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

/// One TCP connection: pure transport. Reads lines, feeds them to the
/// shared dispatch core, writes the rendered reply — no verb ever
/// parsed or interpreted here. `HELLO 2` hands the connection (with the
/// reader's buffered bytes — a pipelining client may already have sent
/// frames) to [`protocol::serve_binary`]. Reads poll every [`POLL_MS`]
/// so the idle budget (`CONTOUR_IDLE_MS`) and the drain flag apply at
/// command boundaries; both closes are graceful (BYE first).
fn handle_conn(stream: TcpStream, state: &ServerState, shutdown: &AtomicBool) -> Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(std::time::Duration::from_millis(POLL_MS)))?;
    stream.set_write_timeout(state.write_timeout())?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match poll_read_line(&mut reader, &mut line, state.idle(), Some(shutdown))? {
            LineRead::Line => {}
            LineRead::Eof => return Ok(()), // client hung up
            LineRead::Idle | LineRead::Drain => {
                // Deliberate close (idle timeout or server drain), not
                // a crash: tell the client before hanging up. Best
                // effort — the peer may already be gone.
                if writer.write_all(b"BYE\n").and_then(|()| writer.flush()).is_ok() {
                    state.metrics.bytes_out.add(4);
                }
                return Ok(());
            }
        }
        state.metrics.bytes_in.add(line.len() as u64);
        let trimmed = line.trim().to_string();
        if trimmed.is_empty() {
            continue;
        }
        let reply = dispatch::handle_line(state, &trimmed, &mut || {
            let mut extra = String::new();
            // Mid-command: the idle budget still applies but a drain
            // never abandons a half-consumed payload (shutdown: None).
            match poll_read_line(&mut reader, &mut extra, state.idle(), None)? {
                // EOF mid-payload surfaces as an empty line; the verb's
                // own parser rejects it and the outer loop then sees
                // the EOF — same shape as before the poll reads.
                LineRead::Line | LineRead::Eof => {}
                LineRead::Idle | LineRead::Drain => bail!("idle timeout mid-payload"),
            }
            state.metrics.bytes_in.add(extra.len() as u64);
            Ok(extra.trim().to_string())
        });
        if let dispatch::Reply::Upgrade = reply {
            writer.write_all(b"OK v2\n")?;
            writer.flush()?;
            state.metrics.bytes_out.add(6);
            state.metrics.hello_upgrades.inc();
            // Binary framing blocks in read_exact (a retry after a
            // partial header read would lose bytes), so the poll
            // timeout is replaced by the idle budget itself: a timeout
            // at a frame boundary is an idle close. No budget = block.
            reader.get_ref().set_read_timeout(state.idle())?;
            return protocol::serve_binary(reader, writer, state);
        }
        if let dispatch::Reply::Watch { ticks, interval_ms } = reply {
            // Streaming verb: this connection's reader thread becomes
            // the push loop — header, one TICK line per interval,
            // DONE. A write error means the client went away.
            let header = format!("OK {ticks} {interval_ms}\n");
            writer.write_all(header.as_bytes())?;
            writer.flush()?;
            state.metrics.bytes_out.add(header.len() as u64);
            telemetry::watch_stream(state, ticks, interval_ms, |tick| {
                let ok = writer
                    .write_all(tick.as_bytes())
                    .and_then(|()| writer.write_all(b"\n"))
                    .and_then(|()| writer.flush())
                    .is_ok();
                if ok {
                    state.metrics.bytes_out.add(tick.len() as u64 + 1);
                }
                ok
            });
            writer.write_all(b"DONE\n")?;
            writer.flush()?;
            state.metrics.bytes_out.add(5);
            continue;
        }
        match dispatch::render_line(&reply) {
            Some(r) => {
                // Failpoint `conn.write`: any armed action drops the
                // connection without a reply — the client sees a close
                // mid-pipeline, exactly the failure a flaky network
                // produces between request and response.
                if crate::util::faults::fire("conn.write").is_some() {
                    return Ok(());
                }
                writer.write_all(r.as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
                state.metrics.bytes_out.add(r.len() as u64 + 1);
            }
            None => {
                writer.write_all(b"BYE\n")?;
                writer.flush()?;
                state.metrics.bytes_out.add(4);
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session_roundtrip(lines: &[(&str, Vec<&str>)]) -> Vec<String> {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut out = Vec::new();
        for (line, extra) in lines {
            let mut extra_iter = extra.iter();
            let reply = s.handle(line, || {
                Ok(extra_iter.next().expect("ran out of extra lines").to_string())
            });
            out.push(reply.unwrap_or_else(|| "BYE".into()));
        }
        out
    }

    #[test]
    fn ping_and_unknown() {
        let r = session_roundtrip(&[("PING", vec![]), ("NOPE", vec![])]);
        assert_eq!(r[0], "PONG");
        assert!(r[1].starts_with("ERR"));
    }

    #[test]
    fn gen_cc_stats_flow() {
        let r = session_roundtrip(&[
            ("GEN g soup:4:25", vec![]),
            ("CC g C-2", vec![]),
            ("CC g auto", vec![]),
            ("STATS g", vec![]),
            ("LIST", vec![]),
            ("DROP g", vec![]),
            ("CC g C-2", vec![]),
        ]);
        assert!(r[0].starts_with("OK 100 "), "{}", r[0]);
        let m: usize = r[0].split_whitespace().nth(2).unwrap().parse().unwrap();
        assert!(r[1].starts_with("OK 4 "), "{}", r[1]);
        assert!(r[2].starts_with("OK 4 "), "{}", r[2]);
        assert!(r[3].contains("components=4"), "{}", r[3]);
        assert!(r[4].contains(&format!("g:100:{m}")), "{}", r[4]);
        assert_eq!(r[5], "OK");
        assert!(r[6].starts_with("ERR"), "{}", r[6]);
    }

    #[test]
    fn upload_flow() {
        let r = session_roundtrip(&[
            ("UPLOAD u 3", vec!["0 1", "1 2", "5 6"]),
            ("CC u ConnectIt", vec![]),
            ("LABELS u C-2", vec![]),
        ]);
        assert_eq!(r[0], "OK 7 3");
        // Components: {0,1,2}, {3}, {4}, {5,6} = 4.
        assert!(r[1].starts_with("OK 4 1 "), "{}", r[1]);
        // Reply leads with the total, then the requested page.
        assert_eq!(r[2], "OK 7 0 0 0 3 4 5 5");
    }

    #[test]
    fn labels_paging() {
        let r = session_roundtrip(&[
            ("UPLOAD p 3", vec!["0 1", "1 2", "5 6"]),
            ("LABELS p C-2 2 3", vec![]),
            ("LABELS p 5", vec![]),
            ("LABELS p C-2 100 5", vec![]),
            ("LABELS p C-2 1 2 3", vec![]),
            ("LABELS p C-2 FastSV", vec![]),
        ]);
        assert_eq!(r[1], "OK 7 0 3 4", "offset 2, count 3");
        assert_eq!(r[2], "OK 7 5 5", "offset 5 with default count, default alg");
        assert_eq!(r[3], "OK 7", "offset past the end pages empty");
        assert!(r[4].starts_with("ERR"), "three numeric args rejected: {}", r[4]);
        assert!(r[5].starts_with("ERR"), "two algorithm args rejected: {}", r[5]);
    }

    #[test]
    fn quit_ends_session() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        assert!(s.handle("QUIT", || unreachable!()).is_none());
    }

    /// Feed every line — commands and payload alike — through one
    /// queue, exactly as a TCP connection buffer delivers them. This is
    /// the shape that exposes protocol desyncs: a command that fails to
    /// consume its announced payload leaves the tail to be misread as
    /// commands.
    fn run_wire(lines: &[&str]) -> Vec<String> {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut out = Vec::new();
        let mut pos = 0usize;
        while pos < lines.len() {
            let line = lines[pos];
            pos += 1;
            let next = std::cell::Cell::new(pos);
            let reply = s.handle(line, || {
                let i = next.get();
                anyhow::ensure!(i < lines.len(), "connection exhausted mid-payload");
                next.set(i + 1);
                Ok(lines[i].to_string())
            });
            pos = next.get();
            out.push(reply.unwrap_or_else(|| "BYE".into()));
        }
        out
    }

    #[test]
    fn failed_upload_does_not_desync_the_connection() {
        let r = run_wire(&[
            "UPLOAD g 4",
            "0 1",
            "1 bogus", // bad edge: ERR, but the payload must be drained
            "2 3",
            "3 4",
            "PING", // ...so this parses as a command, not as an edge
            "UPLOAD g 2",
            "0 1",
            "1 2",
            "CC g C-2",
        ]);
        assert_eq!(r.len(), 4, "replies: {r:?}");
        assert!(r[0].starts_with("ERR"), "{}", r[0]);
        assert!(r[0].contains("edge line 1"), "{}", r[0]);
        assert_eq!(r[1], "PONG", "next command after failed UPLOAD must parse");
        assert_eq!(r[2], "OK 3 2", "connection stays usable for a retry");
        assert!(r[3].starts_with("OK 1 "), "{}", r[3]);
    }

    #[test]
    fn upload_rejects_out_of_range_ids_without_desync() {
        let too_big = format!("0 {}", u64::from(crate::VId::MAX) + 1);
        let r = run_wire(&["UPLOAD g 2", &too_big, "1 2", "PING"]);
        assert!(r[0].starts_with("ERR"), "{}", r[0]);
        assert!(r[0].contains("out of range"), "{}", r[0]);
        assert_eq!(r[1], "PONG");
    }

    #[test]
    fn cc_reuses_cached_result() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("GEN g soup:4:25").starts_with("OK"));
        let first = ask("CC g C-2");
        assert!(first.starts_with("OK 4 "), "{}", first);
        let again = ask("CC g C-2");
        assert!(again.starts_with("OK 4 "), "{}", again);
        // One actual connectivity run; the repeat and the LABELS page
        // below are all served from the cache.
        assert!(ask("LABELS g C-2 0 3").starts_with("OK 100 "));
        let m = ask("METRICS");
        assert!(m.contains("cc_runs=1"), "{m}");
        assert!(m.contains("cc_cache_hits=2"), "{m}");
        // Components and iterations agree between run and cache hit.
        let f: Vec<&str> = first.split_whitespace().take(3).collect();
        let a: Vec<&str> = again.split_whitespace().take(3).collect();
        assert_eq!(f, a);
        // Replacing the graph invalidates its entries.
        assert!(ask("GEN g path:10").starts_with("OK"));
        assert!(ask("CC g C-2").starts_with("OK 1 "), "stale cache served after replace");
        let m = ask("METRICS");
        assert!(m.contains("cc_runs=2"), "{m}");
    }

    #[test]
    fn cc_accepts_frontier_mode_argument() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("GEN g er:400:700").starts_with("OK"));
        let base = ask("CC g C-2");
        let comps = base.split_whitespace().nth(1).unwrap().to_string();
        for mode in ["exact", "chunk", "off"] {
            let r = ask(&format!("CC g C-2 {mode}"));
            assert!(r.starts_with("OK"), "{mode}: {r}");
            assert_eq!(r.split_whitespace().nth(1).unwrap(), comps, "{mode}: {r}");
        }
        // Pinned modes get their own cache slot: the repeat is a hit.
        let again = ask("CC g C-2 exact");
        assert!(again.ends_with("0.000"), "{again}");
        // The §IV-E auto policy composes with a pinned engine.
        assert!(ask("CC g auto exact").starts_with("OK"));
        assert!(ask("CC g C-2 sideways").starts_with("ERR"));
        // The exact engine's passes surface in METRICS.
        let m = ask("METRICS");
        let exact = m
            .split_whitespace()
            .find_map(|p| p.strip_prefix("frontier_exact="))
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap();
        assert!(exact > 0, "{m}");
    }

    #[test]
    fn labels_cache_is_bounded_with_lru_eviction() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("GEN keep path:6").starts_with("OK"));
        assert!(ask("CC keep C-2").starts_with("OK"));
        for i in 0..CC_CACHE_CAP + 4 {
            assert!(ask(&format!("GEN g{i} path:5")).starts_with("OK"));
            assert!(ask(&format!("CC g{i} C-2")).starts_with("OK"));
            // Keep the pinned entry hot so eviction takes the idle ones.
            assert!(ask("CC keep C-2").starts_with("OK"));
        }
        assert!(state.cache_len() <= CC_CACHE_CAP, "cache grew to {}", state.cache_len());
        let hot = ("keep".to_string(), "C-2".to_string());
        assert!(
            state.labels_cache.read().unwrap_or_else(|e| e.into_inner()).contains_key(&hot),
            "recently-touched entry was evicted"
        );
    }

    #[test]
    fn shard_pcc_flow() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("GEN g er:300:500").starts_with("OK"));
        // Partitioned CC before SHARD is an error.
        assert!(ask("PCC g C-2").starts_with("ERR"));
        let sh = ask("SHARD g 3");
        assert!(sh.starts_with("OK 3 "), "{sh}");
        let cc = ask("CC g C-2");
        let pcc = ask("PCC g C-2");
        assert!(pcc.starts_with("OK"), "{pcc}");
        // Same component count as the single-shard run.
        assert_eq!(
            cc.split_whitespace().nth(1).unwrap(),
            pcc.split_whitespace().nth(1).unwrap(),
            "cc={cc} pcc={pcc}"
        );
        let st = ask("SHARDSTATS g");
        assert!(st.contains("p=3"), "{st}");
        assert!(st.contains("shard2="), "{st}");
        assert!(ask("LIST").contains("shard/g:300:"));
        assert!(ask("PCC g auto").starts_with("OK"));
        let m = ask("METRICS");
        assert!(m.contains("shards=1"), "{m}");
        assert!(m.contains("pcc_runs=2"), "{m}");
        // Replacing the graph drops the stale sharded view.
        assert!(ask("GEN g path:10").starts_with("OK"));
        assert!(ask("PCC g C-2").starts_with("ERR"), "stale sharded view served");
        assert!(ask("SHARD g 2").starts_with("OK 2 "));
        assert!(ask("DROP g").starts_with("OK"));
        assert!(ask("SHARDSTATS g").starts_with("ERR"));
    }

    #[test]
    fn pcc_results_are_cached_per_partition() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("GEN g er:400:700").starts_with("OK"));
        assert!(ask("SHARD g 3").starts_with("OK 3 "));
        let first = ask("PCC g C-2");
        assert!(first.starts_with("OK"), "{first}");
        let again = ask("PCC g C-2");
        // Served from the cache: one actual sharded run, same report.
        assert_eq!(
            first.split_whitespace().take(3).collect::<Vec<_>>(),
            again.split_whitespace().take(3).collect::<Vec<_>>(),
            "cached PCC disagrees: {first} vs {again}"
        );
        let m = ask("METRICS");
        assert!(m.contains("pcc_runs=1"), "{m}");
        assert!(m.contains("cache/shard/g=1:1"), "{m}");
        // Re-SHARD (even with identical parameters) is a new partition:
        // the stale entry must not serve.
        assert!(ask("SHARD g 3").starts_with("OK 3 "));
        assert!(ask("PCC g C-2").starts_with("OK"));
        let m = ask("METRICS");
        assert!(m.contains("pcc_runs=2"), "{m}");
        // Edge-balanced fences through the verb: distinct cache key,
        // surfaced in SHARDSTATS, same components as CC.
        assert!(ask("SHARD g 3 edges").starts_with("OK 3 "));
        assert!(ask("SHARDSTATS g").contains("balance=edges"));
        let cc = ask("CC g C-2");
        let pcc = ask("PCC g C-2");
        assert_eq!(
            cc.split_whitespace().nth(1).unwrap(),
            pcc.split_whitespace().nth(1).unwrap(),
            "cc={cc} pcc={pcc}"
        );
        assert!(ask("PCC g C-2").starts_with("OK"));
        let m = ask("METRICS");
        assert!(m.contains("pcc_runs=3"), "{m}");
        assert!(ask("SHARD g 3 hubs").starts_with("ERR"), "bad balance accepted");
        // DROP clears the per-view cache accounting with the view.
        assert!(ask("DROP g").starts_with("OK"));
        let m = ask("METRICS");
        assert!(!m.contains("cache/shard/g="), "{m}");
    }

    /// Pull a `key=<u64>` counter out of a METRICS reply.
    fn metric_u64(m: &str, key: &str) -> u64 {
        m.split_whitespace()
            .find_map(|t| t.strip_prefix(key))
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("{key} missing in {m}"))
    }

    #[test]
    fn trace_verb_reports_the_last_run() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("TRACE g").starts_with("ERR"), "trace before any graph");
        assert!(ask("GEN g er:300:500").starts_with("OK"));
        assert!(ask("TRACE g").starts_with("ERR"), "trace before any run");
        assert!(ask("CC g C-2").starts_with("OK"));
        let t = ask("TRACE g");
        assert!(t.starts_with("OK n="), "{t}");
        assert!(t.contains("pass0|contour|"), "per-pass span missing: {t}");
        assert!(t.contains("finalize|contour|"), "epilogue span missing: {t}");
        // Non-Contour algorithms trace as one whole-run span.
        assert!(ask("CC g ConnectIt").starts_with("OK"));
        assert!(ask("TRACE g").contains("ConnectIt|cc|"));
        // PCC overwrites the slot with the sharded timeline: the run
        // span on the driver track plus one track per shard.
        assert!(ask("SHARD g 2").starts_with("OK"));
        assert!(ask("PCC g C-2").starts_with("OK"));
        let t = ask("TRACE g");
        assert!(t.contains("pcc|pcc|"), "driver span missing: {t}");
        assert!(t.contains("shard0|pcc|"), "{t}");
        assert!(t.contains("shard1|pcc|"), "{t}");
        // DROP purges the timeline with the graph.
        assert!(ask("DROP g").starts_with("OK"));
        assert!(ask("TRACE g").starts_with("ERR"));
    }

    #[test]
    fn metrics_report_per_verb_latency() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("GEN g er:300:500").starts_with("OK"));
        assert!(ask("CC g C-2").starts_with("OK"));
        let m = ask("METRICS");
        let cc = m
            .split_whitespace()
            .find_map(|t| t.strip_prefix("lat/CC="))
            .unwrap_or_else(|| panic!("lat/CC missing: {m}"));
        let parts: Vec<u64> = cc.split(':').map(|x| x.parse().unwrap()).collect();
        assert_eq!(parts.len(), 4, "{cc}");
        assert_eq!(parts[0], 1, "one CC request: {cc}");
        assert!(parts[1] > 0 && parts[2] > 0 && parts[3] > 0, "zero percentiles: {cc}");
        assert!(parts[1] <= parts[2] && parts[2] <= parts[3], "{cc}");
        assert!(m.contains("lat/GEN="), "{m}");
        // The ring buffer lists the session's requests oldest-first;
        // a reply never includes its own (still in-flight) request.
        let r = ask("RECENT");
        assert!(r.starts_with("OK 3 "), "{r}");
        assert!(r.contains(" GEN:1:"), "{r}");
        assert!(r.contains(" CC:1:"), "{r}");
        assert!(r.contains(" METRICS:1:"), "{r}");
        let r2 = ask("RECENT 2");
        assert!(r2.starts_with("OK 2 "), "{r2}");
        assert!(r2.contains(" METRICS:1:") && r2.contains(" RECENT:1:"), "{r2}");
        assert!(ask("RECENT x").starts_with("ERR"));
        // Failed requests are recorded with ok=0.
        assert!(ask("CC nope C-2").starts_with("ERR"));
        assert!(ask("RECENT 2").contains(" CC:0:"));
    }

    #[test]
    fn pcc_accepts_frontier_mode_and_reuses_chunk_indexes() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("GEN g er:400:700").starts_with("OK"));
        assert!(ask("SHARD g 2").starts_with("OK 2 "));
        // The chunk-index counters are process-global (other tests bump
        // them concurrently), so assert on deltas with >=.
        let reused0 = metric_u64(&ask("METRICS"), "chunk_index_reused=");
        let cc = ask("CC g C-2");
        let p1 = ask("PCC g C-2 exact");
        assert!(p1.starts_with("OK"), "{p1}");
        assert_eq!(
            cc.split_whitespace().nth(1),
            p1.split_whitespace().nth(1),
            "cc={cc} pcc={p1}"
        );
        // A pinned mode gets its own cache slot: the repeat is a hit.
        assert!(ask("PCC g C-2 exact").ends_with("0.000"));
        // A different algorithm re-runs on the same partition and picks
        // up each shard's cached vertex→chunk index (2 shards).
        assert!(ask("PCC g C-1 exact").starts_with("OK"));
        let reused1 = metric_u64(&ask("METRICS"), "chunk_index_reused=");
        assert!(reused1 >= reused0 + 2, "indexes not reused: {reused0} -> {reused1}");
        assert!(ask("PCC g C-2 sideways").starts_with("ERR"));
    }

    #[test]
    fn stream_labels_page_through_cache() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("STREAM s 6").starts_with("OK"));
        assert!(ask("SADD s 0 1 2 3").starts_with("OK"));
        assert_eq!(ask("SEPOCH s"), "OK 1 4");
        // Current epoch pages like a static graph (total first).
        assert_eq!(ask("LABELS s"), "OK 6 0 0 2 2 4 5");
        assert_eq!(ask("LABELS s 2 3"), "OK 6 2 2 4");
        // Sealed epochs stay addressable after later seals.
        assert!(ask("SADD s 1 2").starts_with("OK"));
        assert_eq!(ask("SEPOCH s"), "OK 2 3");
        assert_eq!(ask("LABELS s epoch:1 0 6"), "OK 6 0 0 2 2 4 5");
        assert_eq!(ask("LABELS s epoch:2 0 6"), "OK 6 0 0 0 0 4 5");
        assert!(ask("LABELS s epoch:9").starts_with("ERR"));
        assert!(ask("LABELS s FastSV").starts_with("ERR"), "algs rejected for streams");
        // Lazy admissions count as misses (one per epoch first touched);
        // repeat pages of an admitted epoch are hits.
        let m = ask("METRICS");
        assert!(m.contains("cache/stream/s="), "{m}");
        let kv = m
            .split_whitespace()
            .find(|t| t.starts_with("cache/stream/s="))
            .unwrap()
            .split_once('=')
            .unwrap()
            .1
            .to_string();
        let (hits, misses) = kv.split_once(':').unwrap();
        assert!(hits.parse::<u64>().unwrap() >= 2, "hits {kv}");
        assert!(misses.parse::<u64>().unwrap() >= 2, "misses {kv}");
    }

    /// Regression: DROP on a streaming graph must evict its cached
    /// epoch labellings — a recreated stream reuses the name *and* the
    /// epoch numbers, so a stale entry would serve the dead stream's
    /// labels.
    #[test]
    fn drop_stream_evicts_cached_epoch_labels() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut ask = |line: &str| s.handle(line, || unreachable!()).unwrap();
        assert!(ask("STREAM s 4").starts_with("OK"));
        assert!(ask("SADD s 0 1").starts_with("OK"));
        assert_eq!(ask("SEPOCH s"), "OK 1 3");
        assert_eq!(ask("LABELS s epoch:1"), "OK 4 0 0 2 3");
        assert_eq!(ask("DROP s"), "OK");
        // Recreate under the same name with different edges; epoch 1 of
        // the new stream must reflect the new stream, not the old one.
        assert!(ask("STREAM s 4").starts_with("OK"));
        assert!(ask("SADD s 2 3").starts_with("OK"));
        assert_eq!(ask("SEPOCH s"), "OK 1 3");
        assert_eq!(ask("LABELS s epoch:1"), "OK 4 0 1 2 2", "stale cached labels served");
    }

    #[test]
    fn tcp_server_end_to_end() {
        let state = Arc::new(ServerState::new(1));
        let shutdown = Arc::new(AtomicBool::new(false));
        // Port 0: the OS picks a free port, so parallel test runs (or
        // anything else on the machine) cannot collide with us.
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
        let addr = listener.local_addr().expect("local_addr");
        let s2 = Arc::clone(&state);
        let sd2 = Arc::clone(&shutdown);
        let handle = std::thread::spawn(move || serve_listener(listener, s2, sd2));

        // The listener is bound before the thread starts: connecting
        // immediately is race-free (the backlog holds us until accept).
        let stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let mut ask = |msg: &str| -> String {
            writer.write_all(msg.as_bytes()).unwrap();
            writer.write_all(b"\n").unwrap();
            writer.flush().unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            reply.trim().to_string()
        };
        assert_eq!(ask("PING"), "PONG");
        assert_eq!(ask("GEN t path:50"), "OK 50 49");
        assert!(ask("CC t C-m").starts_with("OK 1 "));
        assert!(ask("METRICS").contains("cc_runs=1"));
        assert_eq!(ask("QUIT"), "BYE");

        shutdown.store(true, Ordering::Relaxed);
        handle.join().unwrap().unwrap();
    }
}
