//! Interactive analytics server — the Arkouda/Arachne integration analog.
//!
//! The paper's system is not a batch binary: Arachne extends Arkouda, an
//! *interactive* server where a Python client sends messages (over ZMQ)
//! to a parallel Chapel back end that holds graphs in memory and answers
//! `graph_cc(G)` queries (§III-A). This module reproduces that
//! architecture with the Rust coordinator as the back end:
//!
//! * line-oriented TCP protocol (ZMQ stand-in; one request per line,
//!   one response per line — trivially scriptable from any language);
//! * an in-memory session store of named graphs;
//! * commands: upload/generate/load graphs, run connectivity with any
//!   algorithm (or the §IV-E auto policy), stats, metrics, listing.
//!
//! `python/client/contour_client.py` is the Arkouda-style Python client.
//! Python remains off the compute path — it only ships messages, exactly
//! like Arkouda's front end.
//!
//! Protocol (request → response, all single lines):
//!   GEN name SPEC              → OK n m
//!   UPLOAD name m              → READY, then m lines "u v", → OK n m
//!   LOAD name PATH             → OK n m
//!   CC name ALG                → OK components iterations millis
//!   LABELS name ALG            → OK l0 l1 l2 ... (first 10k labels)
//!   STATS name                 → OK n m comps diam maxdeg
//!   LIST                       → OK name:n:m ...
//!   DROP name                  → OK
//!   METRICS                    → OK requests=.. cc_runs=.. ...
//!   PING                       → PONG
//!   QUIT                       → BYE (closes connection)

pub mod metrics;

use std::collections::HashMap;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Result};

use crate::cc::{self, Algorithm};
use crate::coordinator::{algorithm_by_name, auto_select};
use crate::graph::{gen, io, stats, Csr, EdgeList};
use crate::util::Timer;
use crate::VId;

use metrics::Metrics;

/// Shared server state: the graph store plus counters.
pub struct ServerState {
    graphs: RwLock<HashMap<String, Arc<Csr>>>,
    pub metrics: Metrics,
    /// Worker threads each algorithm run may use (0 = all).
    pub threads: usize,
}

impl ServerState {
    pub fn new(threads: usize) -> Self {
        Self { graphs: RwLock::new(HashMap::new()), metrics: Metrics::default(), threads }
    }

    pub fn insert(&self, name: &str, g: Csr) {
        self.graphs.write().unwrap().insert(name.to_string(), Arc::new(g));
    }

    pub fn get(&self, name: &str) -> Option<Arc<Csr>> {
        self.graphs.read().unwrap().get(name).cloned()
    }

    pub fn drop_graph(&self, name: &str) -> bool {
        self.graphs.write().unwrap().remove(name).is_some()
    }

    pub fn list(&self) -> Vec<(String, usize, usize)> {
        let mut v: Vec<_> = self
            .graphs
            .read()
            .unwrap()
            .iter()
            .map(|(k, g)| (k.clone(), g.n, g.m()))
            .collect();
        v.sort();
        v
    }
}

/// Parse a generator SPEC (same grammar as the CLI: `rmat:14:16`, ...).
pub fn graph_from_spec(spec: &str) -> Result<EdgeList> {
    let parts: Vec<&str> = spec.split(':').collect();
    let num = |i: usize| -> Result<usize> {
        parts
            .get(i)
            .ok_or_else(|| anyhow!("spec {spec:?}: missing field {i}"))?
            .parse::<usize>()
            .map_err(|e| anyhow!("spec {spec:?} field {i}: {e}"))
    };
    let seed = 42u64;
    Ok(match parts[0] {
        "path" => gen::path(num(1)?),
        "cycle" => gen::cycle(num(1)?),
        "star" => gen::star(num(1)?),
        "complete" => gen::complete(num(1)?),
        "grid" => gen::grid(num(1)?, num(2)?),
        "road" => gen::road(num(1)?, num(2)?, seed),
        "tree" => gen::binary_tree(num(1)? as u32),
        "comb" => gen::comb(num(1)?, num(2)?),
        "kmer" => gen::kmer_chains(num(1)?, num(2)?, seed),
        "er" => gen::erdos_renyi(num(1)?, num(2)?, seed),
        "ba" => gen::barabasi_albert(num(1)?, num(2)?, seed),
        "rmat" => gen::rmat(num(1)? as u32, num(2)? << num(1)?, gen::RmatKind::Graph500, seed),
        "delaunay" => gen::delaunay(num(1)?, seed),
        "soup" => gen::component_soup(num(1)?, num(2)?, seed),
        other => bail!("unknown generator {other:?}"),
    })
}

/// One client session over any line-based transport.
pub struct Session<'s> {
    state: &'s ServerState,
}

impl<'s> Session<'s> {
    pub fn new(state: &'s ServerState) -> Self {
        Self { state }
    }

    /// Handle one request line; `read_extra` supplies follow-up lines for
    /// multi-line commands (UPLOAD). Returns the response line, or None
    /// for QUIT.
    pub fn handle<R: FnMut() -> Result<String>>(
        &mut self,
        line: &str,
        mut read_extra: R,
    ) -> Option<String> {
        self.state.metrics.requests.inc();
        let mut fields = line.split_whitespace();
        let cmd = fields.next().unwrap_or("").to_ascii_uppercase();
        let rest: Vec<&str> = fields.collect();
        let reply = match cmd.as_str() {
            "PING" => Ok("PONG".to_string()),
            "QUIT" => return None,
            "GEN" => self.cmd_gen(&rest),
            "UPLOAD" => self.cmd_upload(&rest, &mut read_extra),
            "LOAD" => self.cmd_load(&rest),
            "CC" => self.cmd_cc(&rest),
            "LABELS" => self.cmd_labels(&rest),
            "STATS" => self.cmd_stats(&rest),
            "LIST" => Ok(format!(
                "OK {}",
                self.state
                    .list()
                    .iter()
                    .map(|(n, v, m)| format!("{n}:{v}:{m}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            )),
            "DROP" => match rest.first() {
                Some(name) if self.state.drop_graph(name) => Ok("OK".into()),
                Some(name) => Err(anyhow!("no graph {name:?}")),
                None => Err(anyhow!("DROP needs a name")),
            },
            "METRICS" => Ok(format!("OK {}", self.state.metrics.render())),
            other => Err(anyhow!("unknown command {other:?}")),
        };
        Some(match reply {
            Ok(r) => r,
            Err(e) => {
                self.state.metrics.errors.inc();
                format!("ERR {e}")
            }
        })
    }

    fn cmd_gen(&self, rest: &[&str]) -> Result<String> {
        let (name, spec) = match rest {
            [name, spec] => (*name, *spec),
            _ => bail!("usage: GEN name SPEC"),
        };
        let g = graph_from_spec(spec)?.into_csr().shuffled_edges(7);
        let (n, m) = (g.n, g.m());
        self.state.insert(name, g);
        self.state.metrics.graphs_loaded.inc();
        Ok(format!("OK {n} {m}"))
    }

    fn cmd_upload<R: FnMut() -> Result<String>>(
        &self,
        rest: &[&str],
        read_extra: &mut R,
    ) -> Result<String> {
        let (name, m) = match rest {
            [name, m] => (*name, m.parse::<usize>()?),
            _ => bail!("usage: UPLOAD name edge_count"),
        };
        anyhow::ensure!(m <= 50_000_000, "refusing upload of {m} edges");
        let mut pairs = Vec::with_capacity(m);
        let mut max_v = 0u64;
        for _ in 0..m {
            let line = read_extra()?;
            let mut f = line.split_whitespace();
            let u: u64 = f.next().ok_or_else(|| anyhow!("bad edge line"))?.parse()?;
            let v: u64 = f.next().ok_or_else(|| anyhow!("bad edge line"))?.parse()?;
            max_v = max_v.max(u).max(v);
            pairs.push((u as VId, v as VId));
        }
        let g = EdgeList::from_pairs(max_v as usize + 1, &pairs).into_csr();
        let (n, mm) = (g.n, g.m());
        self.state.insert(name, g);
        self.state.metrics.graphs_loaded.inc();
        Ok(format!("OK {n} {mm}"))
    }

    fn cmd_load(&self, rest: &[&str]) -> Result<String> {
        let (name, path) = match rest {
            [name, path] => (*name, *path),
            _ => bail!("usage: LOAD name PATH"),
        };
        let g = io::read_auto(std::path::Path::new(path))?.into_csr();
        let (n, m) = (g.n, g.m());
        self.state.insert(name, g);
        self.state.metrics.graphs_loaded.inc();
        Ok(format!("OK {n} {m}"))
    }

    fn resolve_alg(&self, g: &Csr, alg: &str) -> Result<Box<dyn Algorithm + Send + Sync>> {
        if alg == "auto" {
            Ok(Box::new(auto_select(&stats::stats(g)).with_threads(self.state.threads)))
        } else {
            algorithm_by_name(alg, self.state.threads)
        }
    }

    fn cmd_cc(&self, rest: &[&str]) -> Result<String> {
        let (name, alg_name) = match rest {
            [name] => (*name, "C-2"),
            [name, alg] => (*name, *alg),
            _ => bail!("usage: CC name [alg]"),
        };
        let g = self.state.get(name).ok_or_else(|| anyhow!("no graph {name:?}"))?;
        let alg = self.resolve_alg(&g, alg_name)?;
        let t = Timer::start();
        let r = alg.run_with_stats(&g);
        let ms = t.ms();
        self.state.metrics.cc_runs.inc();
        self.state.metrics.cc_millis.add(ms as u64);
        Ok(format!("OK {} {} {:.3}", cc::num_components(&r.labels), r.iterations, ms))
    }

    fn cmd_labels(&self, rest: &[&str]) -> Result<String> {
        let (name, alg_name) = match rest {
            [name] => (*name, "C-2"),
            [name, alg] => (*name, *alg),
            _ => bail!("usage: LABELS name [alg]"),
        };
        let g = self.state.get(name).ok_or_else(|| anyhow!("no graph {name:?}"))?;
        let alg = self.resolve_alg(&g, alg_name)?;
        let labels = alg.run(&g);
        self.state.metrics.cc_runs.inc();
        let shown = labels.len().min(10_000);
        let body: Vec<String> = labels[..shown].iter().map(|l| l.to_string()).collect();
        Ok(format!("OK {}", body.join(" ")))
    }

    fn cmd_stats(&self, rest: &[&str]) -> Result<String> {
        let name = rest.first().ok_or_else(|| anyhow!("usage: STATS name"))?;
        let g = self.state.get(name).ok_or_else(|| anyhow!("no graph {name:?}"))?;
        let s = stats::stats(&g);
        Ok(format!(
            "OK n={} m={} components={} diameter={} max_degree={}",
            s.n, s.m, s.num_components, s.pseudo_diameter, s.max_degree
        ))
    }
}

/// Serve on `addr` until `shutdown` flips true. Each connection gets a
/// thread (interactive clients are few; algorithm runs parallelize
/// internally).
pub fn serve(addr: &str, state: Arc<ServerState>, shutdown: Arc<AtomicBool>) -> Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    crate::info!("contour server listening on {addr}");
    std::thread::scope(|scope| {
        loop {
            if shutdown.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let state = Arc::clone(&state);
                    scope.spawn(move || {
                        let _ = handle_conn(stream, &state);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                Err(e) => {
                    crate::info!("accept error: {e}");
                    break;
                }
            }
        }
    });
    Ok(())
}

fn handle_conn(stream: TcpStream, state: &ServerState) -> Result<()> {
    stream.set_nonblocking(false)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut session = Session::new(state);
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            return Ok(()); // client hung up
        }
        let trimmed = line.trim().to_string();
        if trimmed.is_empty() {
            continue;
        }
        let reply = session.handle(&trimmed, || {
            let mut extra = String::new();
            reader.read_line(&mut extra)?;
            Ok(extra.trim().to_string())
        });
        match reply {
            Some(r) => {
                writer.write_all(r.as_bytes())?;
                writer.write_all(b"\n")?;
                writer.flush()?;
            }
            None => {
                writer.write_all(b"BYE\n")?;
                writer.flush()?;
                return Ok(());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session_roundtrip(lines: &[(&str, Vec<&str>)]) -> Vec<String> {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        let mut out = Vec::new();
        for (line, extra) in lines {
            let mut extra_iter = extra.iter();
            let reply = s.handle(line, || {
                Ok(extra_iter.next().expect("ran out of extra lines").to_string())
            });
            out.push(reply.unwrap_or_else(|| "BYE".into()));
        }
        out
    }

    #[test]
    fn ping_and_unknown() {
        let r = session_roundtrip(&[("PING", vec![]), ("NOPE", vec![])]);
        assert_eq!(r[0], "PONG");
        assert!(r[1].starts_with("ERR"));
    }

    #[test]
    fn gen_cc_stats_flow() {
        let r = session_roundtrip(&[
            ("GEN g soup:4:25", vec![]),
            ("CC g C-2", vec![]),
            ("CC g auto", vec![]),
            ("STATS g", vec![]),
            ("LIST", vec![]),
            ("DROP g", vec![]),
            ("CC g C-2", vec![]),
        ]);
        assert!(r[0].starts_with("OK 100 "), "{}", r[0]);
        let m: usize = r[0].split_whitespace().nth(2).unwrap().parse().unwrap();
        assert!(r[1].starts_with("OK 4 "), "{}", r[1]);
        assert!(r[2].starts_with("OK 4 "), "{}", r[2]);
        assert!(r[3].contains("components=4"), "{}", r[3]);
        assert!(r[4].contains(&format!("g:100:{m}")), "{}", r[4]);
        assert_eq!(r[5], "OK");
        assert!(r[6].starts_with("ERR"), "{}", r[6]);
    }

    #[test]
    fn upload_flow() {
        let r = session_roundtrip(&[
            ("UPLOAD u 3", vec!["0 1", "1 2", "5 6"]),
            ("CC u ConnectIt", vec![]),
            ("LABELS u C-2", vec![]),
        ]);
        assert_eq!(r[0], "OK 7 3");
        // Components: {0,1,2}, {3}, {4}, {5,6} = 4.
        assert!(r[1].starts_with("OK 4 1 "), "{}", r[1]);
        assert_eq!(r[2], "OK 0 0 0 3 4 5 5");
    }

    #[test]
    fn quit_ends_session() {
        let state = ServerState::new(1);
        let mut s = Session::new(&state);
        assert!(s.handle("QUIT", || unreachable!()).is_none());
    }

    #[test]
    fn tcp_server_end_to_end() {
        let state = Arc::new(ServerState::new(1));
        let shutdown = Arc::new(AtomicBool::new(false));
        let addr = "127.0.0.1:39183";
        let s2 = Arc::clone(&state);
        let sd2 = Arc::clone(&shutdown);
        let handle = std::thread::spawn(move || serve(addr, s2, sd2));
        std::thread::sleep(std::time::Duration::from_millis(120));

        let stream = TcpStream::connect(addr).expect("connect");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = BufWriter::new(stream);
        let mut ask = |msg: &str| -> String {
            writer.write_all(msg.as_bytes()).unwrap();
            writer.write_all(b"\n").unwrap();
            writer.flush().unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            reply.trim().to_string()
        };
        assert_eq!(ask("PING"), "PONG");
        assert_eq!(ask("GEN t path:50"), "OK 50 49");
        assert!(ask("CC t C-m").starts_with("OK 1 "));
        assert!(ask("METRICS").contains("cc_runs=1"));
        assert_eq!(ask("QUIT"), "BYE");

        shutdown.store(true, Ordering::Relaxed);
        handle.join().unwrap().unwrap();
    }
}
