//! Transport-agnostic request dispatch — the one place every verb is
//! interpreted.
//!
//! Both wire adapters feed this core: the legacy line protocol
//! ([`super::Session`] / `handle_conn`) and the framed binary protocol
//! v2 ([`super::protocol`]). A request is (verb, args, [`Body`]); the
//! reply is a [`Reply`] value each adapter renders in its own framing.
//! Keeping parsing and rendering out of here is what guarantees the two
//! protocols cannot drift: there is exactly one behavior to test, and
//! the adapters are thin serializers.
//!
//! Admission control also lives here so both protocols share it: verbs
//! that always do heavy work (graph builds, partitioning, snapshot IO)
//! take a global heavy-verb permit up front, and the CC/PCC/LABELS/
//! QUERY/BQUERY compute closures take one only on a cache miss — cache
//! hits and snapshot queries stay wait-free, the ConnectIt property the
//! serving path is built around. With no permit free the reply is busy
//! (line: `ERR busy: ...`; binary: a BUSY frame) instead of unbounded
//! queueing.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::cc::contour::FrontierMode;
use crate::cc::Algorithm;
use crate::coordinator::{algorithm_by_name_with, auto_select};
use crate::graph::{io, stats, Csr, EdgeList};
use crate::obs::RunTrace;
use crate::shard::{self, ShardedGraph};
use crate::stream::StreamingCc;
use crate::util::deadline::{self, DeadlineExceeded};
use crate::util::{faults, mlock};
use crate::VId;

use super::telemetry;
use super::{graph_from_spec, parse_edge_line, CcEntry, HeavyPermit, ServerState, RECENT_CAP};

/// Marker error for admission-control rejections, so adapters can tell
/// "server at capacity, retry" (BUSY) apart from real errors (ERR).
#[derive(Debug)]
pub struct Busy(pub String);

impl std::fmt::Display for Busy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Busy {}

/// Take a heavy-verb permit or fail with [`Busy`].
fn heavy_permit(state: &ServerState) -> Result<HeavyPermit<'_>> {
    state.try_heavy().ok_or_else(|| {
        anyhow::Error::new(Busy(format!(
            "{} heavy requests in flight (cap {0})",
            state.heavy_cap()
        )))
    })
}

/// A request's out-of-band payload.
pub enum Body<'a> {
    /// No payload (most verbs).
    None,
    /// Line-protocol UPLOAD: the announced edge lines, pulled one at a
    /// time from the transport.
    Lines(&'a mut dyn FnMut() -> Result<String>),
    /// Binary UPLOAD: the decoded edge list.
    Edges(&'a [(VId, VId)]),
    /// Binary BQUERY: the decoded vertex ids.
    Ids(&'a [VId]),
}

/// A transport-agnostic reply. `Page` and `Batch` keep label data in
/// structured form so the binary adapter can serialize them compactly
/// (`Page` zero-copy from the cached label slice) while the line
/// adapter renders the classic text.
pub enum Reply {
    /// Success; the text after `OK` (may be empty).
    Ok(String),
    /// A LABELS page backed by a cached labelling.
    Page { total: usize, entry: Arc<CcEntry>, lo: usize, hi: usize },
    /// BQUERY: one label per requested vertex, in request order.
    Batch(Vec<VId>),
    Err(String),
    /// Admission control rejected the request; retry later.
    Busy(String),
    Pong,
    /// QUIT: close the connection.
    Bye,
    /// HELLO accepted: switch the connection to binary framing v2.
    Upgrade,
    /// WATCH accepted: the transport streams `ticks` metric-delta
    /// frames, one every `interval_ms`, then a terminal `DONE`.
    Watch { ticks: u64, interval_ms: u64 },
}

/// Render a reply in the line protocol. `None` means QUIT (the caller
/// writes `BYE` and closes).
pub fn render_line(reply: &Reply) -> Option<String> {
    Some(match reply {
        Reply::Ok(s) if s.is_empty() => "OK".to_string(),
        Reply::Ok(s) => format!("OK {s}"),
        Reply::Page { total, entry, lo, hi } => {
            let labels = &entry.labels()[*lo..*hi];
            let mut out = String::with_capacity(8 + 8 * labels.len());
            out.push_str(&format!("OK {total}"));
            for l in labels {
                out.push(' ');
                out.push_str(&l.to_string());
            }
            out
        }
        Reply::Batch(labels) => {
            let mut out = format!("OK {}", labels.len());
            for l in labels {
                out.push(' ');
                out.push_str(&l.to_string());
            }
            out
        }
        Reply::Err(e) => format!("ERR {e}"),
        Reply::Busy(m) => format!("ERR busy: {m}"),
        Reply::Pong => "PONG".to_string(),
        Reply::Upgrade => "OK v2".to_string(),
        // The header only; the transport streams the ticks after it.
        Reply::Watch { ticks, interval_ms } => format!("OK {ticks} {interval_ms}"),
        Reply::Bye => return None,
    })
}

/// Parse and dispatch one line-protocol request; UPLOAD payload lines
/// are pulled through `read_extra`.
pub fn handle_line(
    state: &ServerState,
    line: &str,
    read_extra: &mut dyn FnMut() -> Result<String>,
) -> Reply {
    let mut fields = line.split_whitespace();
    let verb = fields.next().unwrap_or("");
    let rest: Vec<&str> = fields.collect();
    if verb.eq_ignore_ascii_case("UPLOAD") {
        dispatch(state, verb, &rest, Body::Lines(read_extra))
    } else {
        dispatch(state, verb, &rest, Body::None)
    }
}

/// Verbs whose compute can run long enough for `CONTOUR_DEADLINE_MS` to
/// matter; the deadline is armed only for these so admin verbs and
/// WATCH streams never trip it.
fn deadline_applies(cmd: &str) -> bool {
    matches!(
        cmd,
        "GEN" | "UPLOAD" | "LOAD" | "CC" | "LABELS" | "QUERY" | "BQUERY" | "SHARD" | "PCC"
            | "STREAM" | "SADD" | "SDEL" | "SEPOCH" | "SSAVE" | "SLOAD"
    )
}

/// Extract something printable from a caught panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<&str>()
        .copied()
        .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("verb handler panicked")
}

/// Dispatch one request. This is the single verb interpreter both wire
/// adapters share; it also meters the request (`requests`,
/// `lat/<verb>`, `err/<verb>`, the RECENT ring) so line and binary
/// traffic land in the same counters.
///
/// Panic isolation lives here: a panicking verb handler (a bug, or an
/// injected `pool.job` fault re-raised by the pool onto this thread) is
/// caught and mapped to `ERR internal: ...` — the connection and the
/// server survive, `panics_total` counts it, and any cached labellings
/// for the graph named by the request are purged (a panic mid-run may
/// have left that graph's derived state suspect). An expired cooperative
/// deadline unwinds with a typed payload and maps to `ERR deadline ...`
/// instead.
pub fn dispatch(state: &ServerState, verb: &str, args: &[&str], body: Body<'_>) -> Reply {
    state.metrics.requests.inc();
    let started = Instant::now();
    let cmd = verb.to_ascii_uppercase();
    if cmd == "QUIT" {
        return Reply::Bye;
    }
    let outcome = {
        let budget = if deadline_applies(&cmd) { state.deadline() } else { None };
        let _armed = deadline::arm(budget);
        catch_unwind(AssertUnwindSafe(|| run_verb(state, &cmd, args, body)))
    };
    let result = match outcome {
        Ok(r) => r,
        Err(payload) => {
            if let Some(d) = payload.downcast_ref::<DeadlineExceeded>() {
                state.metrics.deadlines.inc();
                Err(anyhow!("deadline exceeded after {}ms budget", d.budget.as_millis()))
            } else {
                state.metrics.panics.inc();
                // A panic mid-run can leave derived (cached) state for
                // the named graph suspect — purge it; the graph itself
                // is immutable and stays.
                if let Some(name) = args.first() {
                    state.purge_labels_cache(name);
                }
                Err(anyhow!("internal: {}", panic_message(payload.as_ref())))
            }
        }
    };
    let (reply, ok) = match result {
        Ok(r) => (r, true),
        Err(e) => {
            // Error paths are metered like successes: the latency
            // histogram below plus a per-verb error counter here.
            state.note_err(&cmd);
            if let Some(b) = e.downcast_ref::<Busy>() {
                state.metrics.busy.inc();
                (Reply::Busy(b.0.clone()), false)
            } else {
                state.metrics.errors.inc();
                (Reply::Err(format!("{e}")), false)
            }
        }
    };
    // Latency is recorded before the reply is even serialized, so
    // `lat/<verb>` meters request handling, not socket writes.
    state.note_verb(&cmd, ok, started.elapsed());
    reply
}

fn run_verb(state: &ServerState, cmd: &str, rest: &[&str], body: Body<'_>) -> Result<Reply> {
    // Verbs that always do heavy work are admission-controlled up
    // front. CC/PCC/LABELS/QUERY/BQUERY take a permit inside their
    // compute closures instead: a cache hit must stay wait-free.
    let _gate = match cmd {
        "GEN" | "UPLOAD" | "LOAD" | "SHARD" | "STREAM" | "SEPOCH" | "SSAVE" | "SLOAD" => {
            Some(heavy_permit(state)?)
        }
        _ => None,
    };
    Ok(match cmd {
        "PING" => Reply::Pong,
        "HELLO" => cmd_hello(rest)?,
        "GEN" => Reply::Ok(cmd_gen(state, rest)?),
        "UPLOAD" => Reply::Ok(cmd_upload(state, rest, body)?),
        "LOAD" => Reply::Ok(cmd_load(state, rest)?),
        "CC" => Reply::Ok(cmd_cc(state, rest)?),
        "LABELS" => cmd_labels(state, rest)?,
        "QUERY" => Reply::Ok(cmd_query(state, rest)?),
        "BQUERY" => cmd_bquery(state, rest, body)?,
        "STATS" => Reply::Ok(cmd_stats(state, rest)?),
        "SHARD" => Reply::Ok(cmd_shard(state, rest)?),
        "PCC" => Reply::Ok(cmd_pcc(state, rest)?),
        "SHARDSTATS" => Reply::Ok(cmd_shardstats(state, rest)?),
        "STREAM" => Reply::Ok(cmd_stream(state, rest)?),
        "SADD" => Reply::Ok(cmd_sadd(state, rest)?),
        "SDEL" => Reply::Ok(cmd_sdel(state, rest, body)?),
        "SEPOCH" => Reply::Ok(cmd_sepoch(state, rest)?),
        "SQUERY" => Reply::Ok(cmd_squery(state, rest)?),
        "SSAVE" => Reply::Ok(cmd_ssave(state, rest)?),
        "SLOAD" => Reply::Ok(cmd_sload(state, rest)?),
        "LIST" => Reply::Ok(
            state
                .list()
                .iter()
                .map(|(n, v, m)| format!("{n}:{v}:{m}"))
                .collect::<Vec<_>>()
                .join(" "),
        ),
        "DROP" => match rest.first() {
            Some(name) if state.drop_graph(name) => Reply::Ok(String::new()),
            Some(name) => bail!("no graph or stream {name:?}"),
            None => bail!("DROP needs a name"),
        },
        // Rendered from the telemetry registry so METRICS and PROM
        // expose the same key set, in the same (sorted) order.
        "METRICS" => Reply::Ok(telemetry::render_metrics(state)),
        "PROM" => {
            // The line transport needs a length prefix to frame the
            // multi-line body: `OK <nlines>` then that many lines.
            let body = telemetry::render_prom(state);
            Reply::Ok(format!("{}\n{}", body.lines().count(), body))
        }
        "HEALTH" => Reply::Ok(telemetry::render_health(state)),
        "FAULTS" => Reply::Ok(cmd_faults(rest)?),
        "WATCH" => cmd_watch(rest)?,
        "TRACE" => match rest.first() {
            Some(name) => match state.trace_of(name) {
                Some(t) => Reply::Ok(t.render_wire()),
                None => bail!("no trace for {name:?} (run CC or PCC first)"),
            },
            None => bail!("usage: TRACE name"),
        },
        "RECENT" => Reply::Ok(cmd_recent(state, rest)?),
        other => bail!("unknown command {other:?}"),
    })
}

/// `HELLO v` — protocol negotiation. Accepting v2 upgrades the
/// connection to binary framing (the transport reacts to
/// [`Reply::Upgrade`]; over a non-upgradable transport it is a no-op
/// acknowledgment). Servers predating v2 answer `ERR unknown command`,
/// which clients take as "line protocol only" — negotiation never
/// desyncs either side.
fn cmd_hello(rest: &[&str]) -> Result<Reply> {
    let v = match rest {
        [v] => v.parse::<u32>().map_err(|e| anyhow!("bad protocol version {v:?}: {e}"))?,
        _ => bail!("usage: HELLO version"),
    };
    anyhow::ensure!(v == 2, "unsupported protocol version {v} (server speaks v2)");
    Ok(Reply::Upgrade)
}

/// `WATCH [ticks] [interval_ms]` — stream `ticks` metric-delta frames,
/// one per interval, then `DONE`. Parse + validation only; the actual
/// streaming happens in the transports (the dispatch core is
/// one-request-one-reply by design).
fn cmd_watch(rest: &[&str]) -> Result<Reply> {
    let (ticks, interval_ms) = match rest {
        [] => (5, 1000),
        [t] => (t.parse::<u64>().map_err(|e| anyhow!("bad tick count {t:?}: {e}"))?, 1000),
        [t, i] => (
            t.parse::<u64>().map_err(|e| anyhow!("bad tick count {t:?}: {e}"))?,
            i.parse::<u64>().map_err(|e| anyhow!("bad interval {i:?}: {e}"))?,
        ),
        _ => bail!("usage: WATCH [ticks] [interval_ms]"),
    };
    anyhow::ensure!(ticks >= 1, "WATCH needs at least one tick");
    anyhow::ensure!(
        ticks <= telemetry::WATCH_MAX_TICKS,
        "tick count {ticks} over cap {}",
        telemetry::WATCH_MAX_TICKS
    );
    Ok(Reply::Watch { ticks, interval_ms })
}

/// `FAULTS [SET spec | CLEAR]` — inspect or swap the fault-injection
/// schedule at runtime (see [`crate::util::faults`] for the spec
/// syntax). Test-gated: refused unless a schedule was armed at boot via
/// `CONTOUR_FAULTS` or `CONTOUR_FAULTS_VERB=1` opts in — a production
/// server never exposes a verb that makes it fail on purpose.
fn cmd_faults(rest: &[&str]) -> Result<String> {
    anyhow::ensure!(
        faults::verb_enabled(),
        "FAULTS is disabled (set CONTOUR_FAULTS or CONTOUR_FAULTS_VERB=1 at boot)"
    );
    match rest {
        [] => {
            let lines = faults::describe();
            Ok(format!("{} {}", lines.len(), lines.join("; ")).trim_end().to_string())
        }
        [set, spec] if set.eq_ignore_ascii_case("SET") => {
            faults::configure(spec)?;
            Ok(format!("armed {}", faults::describe().len()))
        }
        [clear] if clear.eq_ignore_ascii_case("CLEAR") => {
            faults::clear();
            Ok("cleared".to_string())
        }
        _ => bail!("usage: FAULTS [SET point=action[@trigger][;...] | CLEAR]"),
    }
}

/// `RECENT [n]` — the last (up to `n`) handled requests as
/// `verb:ok:dur_ns`, oldest first; the reply leads with the count.
fn cmd_recent(state: &ServerState, rest: &[&str]) -> Result<String> {
    let n = match rest {
        [] => RECENT_CAP,
        [n] => n.parse::<usize>().map_err(|e| anyhow!("bad count: {e}"))?,
        _ => bail!("usage: RECENT [n]"),
    };
    let r = mlock(&state.recent);
    let skip = r.len().saturating_sub(n);
    let mut out = format!("{}", r.len() - skip);
    for (verb, ok, ns) in r.iter().skip(skip) {
        out.push_str(&format!(" {verb}:{}:{ns}", *ok as u8));
    }
    Ok(out)
}

fn cmd_gen(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, spec) = match rest {
        [name, spec] => (*name, *spec),
        _ => bail!("usage: GEN name SPEC"),
    };
    let g = graph_from_spec(spec)?.into_csr().shuffled_edges(7);
    let (n, m) = (g.n, g.m());
    state.insert(name, g);
    state.metrics.graphs_loaded.inc();
    Ok(format!("{n} {m}"))
}

fn cmd_upload(state: &ServerState, rest: &[&str], body: Body<'_>) -> Result<String> {
    match body {
        Body::Lines(read_extra) => {
            let (name, m) = match rest {
                [name, m] => (*name, m.parse::<usize>()?),
                _ => bail!("usage: UPLOAD name edge_count"),
            };
            anyhow::ensure!(m <= 50_000_000, "refusing upload of {m} edges");
            let mut pairs = Vec::with_capacity(m);
            let mut max_v = 0u64;
            // The client has already committed to sending `m` lines: on
            // a bad line we must still drain the remainder before
            // replying ERR, or the leftover edge lines get parsed as
            // commands and the whole connection desynchronizes.
            // Transport errors (`?` on read_extra) abort outright — the
            // connection is gone anyway.
            let mut bad: Option<anyhow::Error> = None;
            for i in 0..m {
                let line = read_extra()?;
                if bad.is_some() {
                    continue; // draining the announced payload
                }
                match parse_edge_line(&line) {
                    Ok((u, v)) => {
                        max_v = max_v.max(u).max(v);
                        pairs.push((u as VId, v as VId));
                    }
                    Err(e) => bad = Some(anyhow!("edge line {i}: {e}")),
                }
            }
            if let Some(e) = bad {
                return Err(e);
            }
            admit_upload(state, name, max_v, pairs)
        }
        // The binary frame carries the decoded edges; an edge count in
        // the args (line-protocol habit) is tolerated but the payload
        // is authoritative.
        Body::Edges(edges) => {
            let name = match rest {
                [name] | [name, _] => *name,
                _ => bail!("usage: UPLOAD name edge_count"),
            };
            anyhow::ensure!(edges.len() <= 50_000_000, "refusing upload of {} edges", edges.len());
            let max_v = edges.iter().map(|&(u, v)| u.max(v)).max().unwrap_or(0);
            admit_upload(state, name, u64::from(max_v), edges.to_vec())
        }
        _ => bail!("UPLOAD needs an edge payload"),
    }
}

fn admit_upload(
    state: &ServerState,
    name: &str,
    max_v: u64,
    pairs: Vec<(VId, VId)>,
) -> Result<String> {
    let g = EdgeList::from_pairs(max_v as usize + 1, &pairs).into_csr();
    let (n, mm) = (g.n, g.m());
    state.insert(name, g);
    state.metrics.graphs_loaded.inc();
    Ok(format!("{n} {mm}"))
}

fn cmd_load(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, path) = match rest {
        [name, path] => (*name, *path),
        _ => bail!("usage: LOAD name PATH"),
    };
    let g = io::read_auto(std::path::Path::new(path))?.into_csr();
    let (n, m) = (g.n, g.m());
    state.insert(name, g);
    state.metrics.graphs_loaded.inc();
    Ok(format!("{n} {m}"))
}

fn resolve_alg(
    state: &ServerState,
    g: &Csr,
    alg: &str,
) -> Result<Box<dyn Algorithm + Send + Sync>> {
    resolve_alg_with(state, g, alg, None)
}

/// Resolve an algorithm name with an optional Contour frontier engine
/// pinned (`Some(mode)`; `None` keeps the process default).
fn resolve_alg_with(
    state: &ServerState,
    g: &Csr,
    alg: &str,
    frontier: Option<FrontierMode>,
) -> Result<Box<dyn Algorithm + Send + Sync>> {
    if alg == "auto" {
        let mut c = auto_select(&stats::stats(g)).with_threads(state.threads);
        if let Some(mode) = frontier {
            c = c.with_frontier_mode(mode);
        }
        Ok(Box::new(c))
    } else {
        algorithm_by_name_with(alg, state.threads, frontier)
    }
}

fn cmd_cc(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, alg_name, fmode) = match rest {
        [name] => (*name, "C-2", None),
        [name, alg] => (*name, *alg, None),
        [name, alg, mode] => (
            *name,
            *alg,
            Some(FrontierMode::parse(mode).ok_or_else(|| {
                anyhow!("frontier mode must be exact|chunk|off, got {mode:?}")
            })?),
        ),
        _ => bail!("usage: CC name [alg] [exact|chunk|off]"),
    };
    let g = state.get(name).ok_or_else(|| anyhow!("no graph {name:?}"))?;
    // Serve repeat CC requests for an unchanged (graph, alg) pair from
    // the labels cache: graphs are immutable once inserted, and
    // replacing/dropping a name purges its entries. Labels are
    // bit-identical across frontier engines, but iterations/millis are
    // not — an explicitly pinned mode gets its own cache slot so the
    // reply reflects the engine that was asked for (DROP and replace
    // purge by name, covering these slots too).
    let key = match fmode {
        None => alg_name.to_string(),
        Some(m) => format!("{alg_name}#{}", m.as_str()),
    };
    let (entry, ran_ms) = state.cc_cached(name, &key, &g, || {
        // Misses do heavy work: admission-controlled. Hits above stay
        // wait-free.
        let _permit = heavy_permit(state)?;
        let alg = resolve_alg_with(state, &g, alg_name, fmode)?;
        // Every computed run records a span timeline for the TRACE
        // verb — the recorder costs two clock reads per pass, noise
        // next to the pass itself, so it is always on here.
        let r = alg.run_traced(&g);
        if let Some(t) = &r.trace {
            state.store_trace(name, Arc::clone(t));
        }
        Ok(r)
    })?;
    // A cache hit reports 0.000 ms: no connectivity work was done.
    Ok(format!("{} {} {:.3}", entry.components, entry.iterations, ran_ms.unwrap_or(0.0)))
}

/// The labelling a read verb (LABELS/QUERY/BQUERY) answers from, as a
/// cached entry: static graphs key on the algorithm (default C-2; one
/// run serves every page and query), streams key on a sealed epoch
/// (`epoch:<e>` in the selector slot, default = current). One entry
/// resolution = one snapshot, so a batch never straddles epochs.
fn resolve_entry(state: &ServerState, name: &str, selector: Option<&str>) -> Result<Arc<CcEntry>> {
    if let Some(g) = state.get(name) {
        let alg_name = selector.unwrap_or("C-2");
        let (entry, _) = state.cc_cached(name, alg_name, &g, || {
            let _permit = heavy_permit(state)?;
            let alg = resolve_alg(state, &g, alg_name)?;
            Ok(alg.run_with_stats(&g))
        })?;
        Ok(entry)
    } else if let Some(s) = state.get_stream(name) {
        let epoch = match selector {
            None => s.epoch(),
            Some(tok) => tok
                .strip_prefix("epoch:")
                .ok_or_else(|| {
                    anyhow!("streams take `epoch:<e>`, not an algorithm ({tok:?})")
                })?
                .parse::<u64>()
                .map_err(|e| anyhow!("bad epoch in {tok:?}: {e}"))?,
        };
        Ok(state.stream_cached(name, &s, epoch)?.0)
    } else {
        bail!("no graph or stream {name:?}")
    }
}

/// `LABELS name [alg|epoch:<e>] [offset [count]]` — pages through the
/// label array instead of silently truncating. The reply leads with
/// the total label count so clients know when they have everything.
fn cmd_labels(state: &ServerState, rest: &[&str]) -> Result<Reply> {
    let mut it = rest.iter();
    let name = *it.next().ok_or_else(|| anyhow!("usage: LABELS name [alg] [off [cnt]]"))?;
    let mut selector: Option<&str> = None;
    let mut nums: Vec<usize> = Vec::new();
    for &tok in it {
        if !tok.is_empty() && tok.bytes().all(|b| b.is_ascii_digit()) {
            // All-digit tokens are positional offset/count. Parsing can
            // still fail past usize::MAX — that must be a clean ERR,
            // never a wrap and not a confusing fall-through into the
            // algorithm slot.
            nums.push(
                tok.parse::<usize>().map_err(|_| anyhow!("offset/count {tok:?} out of range"))?,
            );
        } else if nums.is_empty() && selector.is_none() {
            selector = Some(tok);
        } else {
            bail!("usage: LABELS name [alg] [offset [count]], got {tok:?}");
        }
    }
    anyhow::ensure!(nums.len() <= 2, "usage: LABELS name [alg] [offset [count]]");
    let offset = nums.first().copied().unwrap_or(0);
    let count = nums.get(1).copied().unwrap_or(10_000);
    let entry = resolve_entry(state, name, selector)?;
    Ok(page_reply(entry, offset, count))
}

/// Clamp a page request against the label array: any offset/count,
/// including usize::MAX, resolves to a valid (possibly empty) range.
pub(crate) fn page_reply(entry: Arc<CcEntry>, offset: usize, count: usize) -> Reply {
    let total = entry.labels().len();
    let lo = offset.min(total);
    let hi = lo.saturating_add(count).min(total);
    Reply::Page { total, entry, lo, hi }
}

/// `QUERY name v [alg|epoch:<e>]` — one vertex's component label,
/// answered from the same cached labelling LABELS pages (wait-free on
/// a hit). The sequential cross-check for BQUERY.
fn cmd_query(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, v, sel) = match rest {
        [name, v] => (*name, *v, None),
        [name, v, sel] => (*name, *v, Some(*sel)),
        _ => bail!("usage: QUERY name v [alg|epoch:<e>]"),
    };
    let v = v.parse::<u64>().map_err(|e| anyhow!("bad vertex id {v:?}: {e}"))?;
    let entry = resolve_entry(state, name, sel)?;
    let labels = entry.labels();
    let i = usize::try_from(v)
        .ok()
        .filter(|&i| i < labels.len())
        .ok_or_else(|| anyhow!("vertex id {v} out of range (n = {})", labels.len()))?;
    Ok(labels[i].to_string())
}

/// `BQUERY name [alg|epoch:<e>] v1 v2 ...` (line) or a binary frame
/// carrying a packed id array — the vectorized read path. Every id is
/// answered from one entry resolution, so the batch is consistent (one
/// epoch/labelling) and wait-free on a cache hit.
fn cmd_bquery(state: &ServerState, rest: &[&str], body: Body<'_>) -> Result<Reply> {
    let name =
        *rest.first().ok_or_else(|| anyhow!("usage: BQUERY name [alg|epoch:<e>] v1 v2 ..."))?;
    let mut selector: Option<&str> = None;
    let mut parsed: Vec<VId> = Vec::new();
    for &tok in &rest[1..] {
        if let Ok(v) = tok.parse::<VId>() {
            parsed.push(v);
        } else if parsed.is_empty() && selector.is_none() {
            selector = Some(tok);
        } else {
            bail!("bad vertex id {tok:?}");
        }
    }
    let ids: &[VId] = match body {
        Body::Ids(ids) => {
            anyhow::ensure!(
                parsed.is_empty(),
                "BQUERY takes ids in the frame payload or the arg list, not both"
            );
            ids
        }
        _ => &parsed,
    };
    anyhow::ensure!(!ids.is_empty(), "BQUERY needs at least one vertex id");
    let entry = resolve_entry(state, name, selector)?;
    let labels = entry.labels();
    let mut out = Vec::with_capacity(ids.len());
    for &v in ids {
        let i = v as usize;
        anyhow::ensure!(i < labels.len(), "vertex id {v} out of range (n = {})", labels.len());
        out.push(labels[i]);
    }
    state.metrics.batch_queries.inc();
    state.metrics.batch_vertices.add(out.len() as u64);
    Ok(Reply::Batch(out))
}

fn cmd_stats(state: &ServerState, rest: &[&str]) -> Result<String> {
    let name = rest.first().ok_or_else(|| anyhow!("usage: STATS name"))?;
    let g = state.get(name).ok_or_else(|| anyhow!("no graph {name:?}"))?;
    let s = stats::stats(&g);
    Ok(format!(
        "n={} m={} components={} diameter={} max_degree={}",
        s.n, s.m, s.num_components, s.pseudo_diameter, s.max_degree
    ))
}

// ------------------------------------------------------- sharded verbs

/// `SHARD name p [vertices|edges]` — partition a stored graph into `p`
/// range shards (see [`crate::shard`]); the optional balance policy
/// places fences by vertex count (default) or by cumulative edge
/// count. Replaces any previous view and purges its cached PCC
/// results.
fn cmd_shard(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, p, balance) = match rest {
        [name, p] => (*name, *p, shard::Balance::Vertices),
        [name, p, b] => (
            *name,
            *p,
            shard::Balance::parse(b)
                .ok_or_else(|| anyhow!("balance must be `vertices` or `edges`, got {b:?}"))?,
        ),
        _ => bail!("usage: SHARD name p [vertices|edges]"),
    };
    let p = p.parse::<usize>().map_err(|e| anyhow!("bad shard count: {e}"))?;
    anyhow::ensure!(p >= 1, "shard count must be >= 1");
    anyhow::ensure!(p <= 65_536, "shard count {p} unreasonably large");
    let g = state.get(name).ok_or_else(|| anyhow!("no graph {name:?}"))?;
    // Hygiene: purge entries cached for the partition this SHARD
    // replaces *before* publishing the new one — purging after could
    // race a concurrent PCC and delete an entry freshly computed on
    // the new partition. (A PCC racing into this window can still
    // re-admit an old-partition entry; its weak identity is dead, so
    // it can never serve and only waits for LRU.) Outside
    // insert_sharded so the labels-cache lock is never nested inside
    // the sharded lock.
    let skey = ServerState::shard_cache_name(name);
    crate::util::wlock(&state.labels_cache).retain(|k, _| k.0 != skey);
    let sg = state
        .insert_sharded(name, &g, ShardedGraph::partition_with(&g, p, balance))
        .ok_or_else(|| anyhow!("graph {name:?} was replaced during SHARD; retry"))?;
    Ok(format!("{} {}", sg.p(), sg.boundary.len()))
}

/// `PCC name [alg] [exact|chunk|off]` — partitioned connectivity:
/// shard-local runs concurrently (one pool job per shard), then
/// boundary merge. The optional frontier mode pins the Contour engine
/// like CC's — with `exact`, repeated runs on one partition reuse each
/// shard's cached vertex→chunk index (`chunk_index_reused` in METRICS)
/// instead of rebuilding it. Results are cached per
/// `(name, alg, mode, p, balance)` with the same identity rules as
/// `CC` (a cache hit reports 0.000 ms).
fn cmd_pcc(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, alg_name, fmode) = match rest {
        [name] => (*name, "C-2", None),
        [name, alg] => (*name, *alg, None),
        [name, alg, mode] => (
            *name,
            *alg,
            Some(FrontierMode::parse(mode).ok_or_else(|| {
                anyhow!("frontier mode must be exact|chunk|off, got {mode:?}")
            })?),
        ),
        _ => bail!("usage: PCC name [alg] [exact|chunk|off]"),
    };
    let sg = state
        .get_sharded(name)
        .ok_or_else(|| anyhow!("no sharded graph {name:?} (run SHARD first)"))?;
    let threads = state.threads;
    let key = match fmode {
        None => alg_name.to_string(),
        Some(m) => format!("{alg_name}#{}", m.as_str()),
    };
    let (entry, ran_ms) = state.pcc_cached(name, &key, &sg, || {
        let _permit = heavy_permit(state)?;
        let alg: Box<dyn Algorithm + Send + Sync> = if alg_name == "auto" {
            // Drive the §IV-E policy from the heaviest shard's topology
            // (range partitioning, so shards inherit the source graph's
            // shape).
            let big = sg
                .shards
                .iter()
                .max_by_key(|s| s.graph.m())
                .expect("a partition has at least one shard");
            let mut c = auto_select(big.stats()).with_threads(threads);
            if let Some(mode) = fmode {
                c = c.with_frontier_mode(mode);
            }
            Box::new(c)
        } else {
            algorithm_by_name_with(alg_name, threads, fmode)?
        };
        // Computed runs share one timeline: driver track (the pcc +
        // merge spans) plus one track per shard.
        let tr = Arc::new(RunTrace::new());
        let r = shard::run_sharded_ctx(&sg, alg.as_ref(), threads, Some(&tr));
        state.store_trace(name, tr);
        Ok(r)
    })?;
    Ok(format!("{} {} {:.3}", entry.components, entry.iterations, ran_ms.unwrap_or(0.0)))
}

/// `SHARDSTATS name` — per-shard topology of a sharded view.
fn cmd_shardstats(state: &ServerState, rest: &[&str]) -> Result<String> {
    let name = rest.first().ok_or_else(|| anyhow!("usage: SHARDSTATS name"))?;
    let sg = state
        .get_sharded(name)
        .ok_or_else(|| anyhow!("no sharded graph {name:?} (run SHARD first)"))?;
    let mut out = format!(
        "p={} n={} m={} boundary={} balance={}",
        sg.p(),
        sg.n,
        sg.m,
        sg.boundary.len(),
        sg.balance.as_str()
    );
    for (k, sh) in sg.shards.iter().enumerate() {
        let st = sh.stats();
        out.push_str(&format!(
            " shard{k}={}:{}:{}:{}:{}",
            sh.lo, sh.hi, st.m, st.num_components, st.max_degree
        ));
    }
    Ok(out)
}

// ----------------------------------------------------- streaming verbs

fn stream_of(state: &ServerState, name: &str) -> Result<Arc<StreamingCc>> {
    state.get_stream(name).ok_or_else(|| anyhow!("no stream {name:?}"))
}

fn cmd_stream(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, n, extra) = match rest {
        [name, n, extra @ ..] if extra.len() <= 2 => (*name, n.parse::<usize>()?, extra),
        _ => bail!("usage: STREAM name n [walpath] [maxhist]"),
    };
    // Extras in either order: a number is the history cap, anything
    // else is the WAL path.
    let mut wal: Option<&str> = None;
    let mut hist: Option<usize> = None;
    for tok in extra {
        if let Ok(h) = tok.parse::<usize>() {
            anyhow::ensure!(hist.is_none(), "duplicate maxhist argument");
            hist = Some(h);
        } else {
            anyhow::ensure!(wal.is_none(), "duplicate WAL path argument");
            wal = Some(*tok);
        }
    }
    let threads = state.threads;
    let s = state.create_stream(name, wal.map(Path::new), || {
        let mut s = StreamingCc::open(n, threads, wal.map(Path::new))?;
        if let Some(h) = hist {
            s = s.with_max_history(h);
        }
        Ok(s)
    })?;
    if s.epoch() > 0 {
        // Recovery-on-open sealed an implicit epoch, same as SLOAD.
        state.metrics.stream_epochs.inc();
    }
    // Recovery-on-open surfaces its stats, same as SLOAD.
    Ok(match s.recovery() {
        Some(info) => format!("{n} {} {}", s.epoch(), info.summary()),
        None => format!("{n} {}", s.epoch()),
    })
}

fn cmd_sadd(state: &ServerState, rest: &[&str]) -> Result<String> {
    let name = rest.first().ok_or_else(|| anyhow!("usage: SADD name u v [u v ...]"))?;
    let ids: Vec<VId> = rest[1..]
        .iter()
        .map(|t| t.parse::<VId>().map_err(|e| anyhow!("bad vertex id {t:?}: {e}")))
        .collect::<Result<_>>()?;
    anyhow::ensure!(!ids.is_empty() && ids.len() % 2 == 0, "SADD needs one or more u v pairs");
    let edges: Vec<(VId, VId)> = ids.chunks_exact(2).map(|p| (p[0], p[1])).collect();
    let s = stream_of(state, name)?;
    let added = s.add_edges(&edges)?;
    state.metrics.stream_edges.add(added as u64);
    Ok(format!("{added} {}", s.epoch()))
}

fn cmd_sdel(state: &ServerState, rest: &[&str], body: Body<'_>) -> Result<String> {
    let name = rest.first().ok_or_else(|| anyhow!("usage: SDEL name u v [u v ...]"))?;
    let parsed: Vec<VId> = rest[1..]
        .iter()
        .map(|t| t.parse::<VId>().map_err(|e| anyhow!("bad vertex id {t:?}: {e}")))
        .collect::<Result<_>>()?;
    // Like BQUERY, the binary transport may carry the ids as a packed
    // frame payload instead of arg-list text.
    let ids: &[VId] = match body {
        Body::Ids(ids) => {
            anyhow::ensure!(
                parsed.is_empty(),
                "SDEL takes ids in the frame payload or the arg list, not both"
            );
            ids
        }
        _ => &parsed,
    };
    anyhow::ensure!(!ids.is_empty() && ids.len() % 2 == 0, "SDEL needs one or more u v pairs");
    let edges: Vec<(VId, VId)> = ids.chunks_exact(2).map(|p| (p[0], p[1])).collect();
    let s = stream_of(state, name)?;
    let removed = s.delete_edges(&edges)?;
    state.metrics.stream_deletes.add(removed as u64);
    Ok(format!("{removed} {}", s.epoch()))
}

fn cmd_sepoch(state: &ServerState, rest: &[&str]) -> Result<String> {
    let name = rest.first().ok_or_else(|| anyhow!("usage: SEPOCH name"))?;
    let snap = stream_of(state, name)?.seal_epoch()?;
    state.metrics.stream_epochs.inc();
    Ok(format!("{} {}", snap.epoch, snap.num_components))
}

/// One usage string for every SQUERY error path — the arity check and
/// the per-op match used to disagree about whether `[epoch]` existed.
const SQUERY_USAGE: &str =
    "usage: SQUERY name SAME u v [epoch] | SIZE v [epoch] | COMPS [epoch] | LABEL v [epoch]";

fn cmd_squery(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, op, args) = match rest {
        [name, op, args @ ..] => (*name, op.to_ascii_uppercase(), args),
        _ => bail!("{SQUERY_USAGE}"),
    };
    let nums: Vec<u64> = args
        .iter()
        .map(|t| t.parse::<u64>().map_err(|e| anyhow!("bad number {t:?}: {e}")))
        .collect::<Result<_>>()?;
    let s = stream_of(state, name)?;
    state.metrics.stream_queries.inc();
    let vid =
        |x: u64| -> Result<VId> { VId::try_from(x).map_err(|_| anyhow!("vertex id {x} out of range")) };
    match (op.as_str(), nums.as_slice()) {
        ("SAME", [u, v]) | ("SAME", [u, v, _]) => {
            let snap = s.snapshot_at(nums.get(2).copied())?;
            let same = snap.same_comp(vid(*u)?, vid(*v)?)?;
            Ok(format!("{} {}", same as u8, snap.epoch))
        }
        ("SIZE", [v]) | ("SIZE", [v, _]) => {
            let snap = s.snapshot_at(nums.get(1).copied())?;
            Ok(format!("{} {}", snap.comp_size(vid(*v)?)?, snap.epoch))
        }
        ("COMPS", []) | ("COMPS", [_]) => {
            let snap = s.snapshot_at(nums.first().copied())?;
            Ok(format!("{} {}", snap.num_components, snap.epoch))
        }
        ("LABEL", [v]) | ("LABEL", [v, _]) => {
            let snap = s.snapshot_at(nums.get(1).copied())?;
            Ok(format!("{} {}", snap.label(vid(*v)?)?, snap.epoch))
        }
        _ => bail!("{SQUERY_USAGE}"),
    }
}

fn cmd_ssave(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, path) = match rest {
        [name, path] => (*name, *path),
        _ => bail!("usage: SSAVE name PATH"),
    };
    let epoch = stream_of(state, name)?.save_snapshot(Path::new(path))?;
    Ok(format!("{epoch}"))
}

fn cmd_sload(state: &ServerState, rest: &[&str]) -> Result<String> {
    let (name, snap, wal) = match rest {
        [name, snap] => (*name, *snap, None),
        [name, snap, wal] => (*name, *snap, Some(*wal)),
        _ => bail!("usage: SLOAD name SNAPPATH [WALPATH]"),
    };
    let threads = state.threads;
    let s = state.create_stream(name, wal.map(Path::new), || {
        StreamingCc::recover(Some(Path::new(snap)), wal.map(Path::new), threads)
    })?;
    state.metrics.stream_epochs.inc();
    // Lead with the classic `n epoch` so old clients keep parsing, then
    // the recovery stats: frames replayed past the snapshot's cut and
    // any torn tail dropped.
    Ok(match s.recovery() {
        Some(info) => format!("{} {} {}", s.n(), s.epoch(), info.summary()),
        None => format!("{} {}", s.n(), s.epoch()),
    })
}
