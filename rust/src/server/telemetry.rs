//! Continuous telemetry: the typed metric registry and everything
//! rendered from it.
//!
//! PR 7 left the server with a hand-formatted `METRICS` line assembled
//! from four independent renderers — fine for one snapshot verb,
//! useless as a foundation for time series, scrape exposition and
//! health signals that must all agree on what a "metric" is. This
//! module centralizes the answer:
//!
//! * [`registry`] — every live metric (counters, gauges, latency
//!   summaries, per-graph cache pairs) as one typed, key-sorted list.
//!   `METRICS` ([`render_metrics`]) and the OpenMetrics exposition
//!   ([`render_prom`]) are both projections of this list, so a PROM
//!   family exists for every METRICS counter *by construction*, and
//!   successive scrapes diff cleanly (stable sorted key order).
//! * [`sample_keys`] / [`live_sample`] — the fixed schema the sampler
//!   thread pushes into the server's [`TimeSeries`] ring each interval:
//!   all counters, per-verb histogram percentiles (the verb table is
//!   static, so the schema is too), and the pool queue-wait bucket
//!   counts (so *windowed* quantiles come from count deltas).
//! * [`render_health`] — ready/degraded/overloaded from windowed rates
//!   (busy fraction, heavy-gate saturation, pool queue-wait p95, WAL
//!   fsync lag) with env-configurable thresholds.
//! * [`watch_stream`] / [`render_tick`] — the `WATCH` verb's push loop:
//!   per-interval counter deltas + instantaneous qps on any transport.
//!
//! Wire-key spellings are owned by [`Metrics::counter_pairs`] and this
//! module; they are frozen (clients parse them), which is why the
//! registry reuses them verbatim instead of inventing a second naming
//! scheme. The PROM names are derived mechanically (`contour_` prefix,
//! non-alphanumerics → `_`, `_total` on counters).

use std::sync::atomic::Ordering;
use std::time::Duration;

use crate::obs::{self, quantile_from_counts, HistogramSnapshot, Sample};

use super::ServerState;

/// Samples retained by the server's telemetry ring. At the default 1s
/// interval this is 12 minutes of history — enough for any sane health
/// window — in ~1.3 MB (227 u64 values per sample).
pub const RING_CAP: usize = 720;

/// Default sampler interval (override: `CONTOUR_SAMPLE_MS` or
/// `contour serve --sample-ms`).
pub const DEFAULT_SAMPLE_MS: u64 = 1000;

/// Floor on the sampler interval — below this the sampler itself
/// becomes measurable load.
pub const MIN_SAMPLE_MS: u64 = 10;

/// Lookback window for windowed rates (HEALTH, PROM rate gauges),
/// override `CONTOUR_HEALTH_WINDOW_MS`.
pub const DEFAULT_WINDOW_MS: u64 = 60_000;

/// Counters whose deltas a WATCH tick reports (a curated subset — the
/// full 200+-key schema would make tick lines unreadable).
pub const WATCH_KEYS: &[&str] = &[
    "requests",
    "errors",
    "busy",
    "bytes_in",
    "bytes_out",
    "cc_runs",
    "pcc_runs",
    "batch_queries",
    "stream_queries",
    "stream_deletes",
    "pool_jobs",
];

/// One registry entry's value. The variant decides both the METRICS
/// text form and the OpenMetrics family type.
pub enum Value {
    /// Monotone counter → OpenMetrics `counter` (`_total` suffix).
    Count(u64),
    /// Point-in-time gauge.
    Gauge(u64),
    /// Floating gauge (qps), rendered `{:.1}`.
    GaugeF(f64),
    /// Latency summary (`count:p50:p95:p99` on the wire).
    Hist(HistogramSnapshot),
    /// Per-graph cache `hits:misses`.
    Pair(u64, u64),
}

/// One live metric under its frozen METRICS wire key.
pub struct Metric {
    pub key: String,
    pub val: Value,
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The sampler interval for a server: explicit override first (the
/// `--sample-ms` flag lands in [`ServerState`]), then the env, clamped
/// to [`MIN_SAMPLE_MS`].
pub fn sample_interval(state: &ServerState) -> Duration {
    let ms = match state.sample_ms {
        0 => env_u64("CONTOUR_SAMPLE_MS", DEFAULT_SAMPLE_MS),
        ms => ms,
    };
    Duration::from_millis(ms.max(MIN_SAMPLE_MS))
}

/// Highest `last_fsync_ns` across live streams — the WAL fsync lag
/// signal HEALTH checks (0 with no streams or no WAL).
fn wal_fsync_ns(state: &ServerState) -> u64 {
    crate::util::rlock(&state.streams).values().map(|s| s.last_fsync_ns()).max().unwrap_or(0)
}

/// Heavy-verb slots currently held.
fn heavy_used(state: &ServerState) -> u64 {
    state.heavy_cap.saturating_sub(state.heavy_avail.load(Ordering::Acquire)) as u64
}

/// Allocator gauges (all zero unless built with `alloc-track`):
/// `(mem_cur_bytes, alloc_bytes, alloc_calls, free_calls)`.
fn mem_gauges() -> (u64, u64, u64, u64) {
    let (alloc_bytes, alloc_calls, _free_bytes, free_calls) = obs::alloc::totals();
    (obs::alloc::current_bytes(), alloc_bytes, alloc_calls, free_calls)
}

/// Every live metric, sorted by wire key. The one list METRICS and
/// PROM render from.
pub fn registry(state: &ServerState) -> Vec<Metric> {
    let m = |key: &str, val: Value| Metric { key: key.to_string(), val };
    let mut out = Vec::with_capacity(96);
    for (k, v) in state.metrics.counter_pairs() {
        out.push(m(k, Value::Count(v)));
    }
    // Per-failpoint injection counts (the flat `faults_injected` total
    // is a counter_pair above). Keys only exist while faults have been
    // configured, like the per-graph cache pairs.
    for (point, n) in crate::util::faults::injected_counts() {
        out.push(m(&format!("faults_injected/{point}"), Value::Count(n)));
    }
    out.push(m("uptime_ms", Value::Gauge(state.metrics.uptime_ms())));
    out.push(m("qps", Value::GaugeF(state.metrics.qps())));

    let pool = crate::par::pool::stats();
    out.push(m("pool_workers", Value::Gauge(pool.workers as u64)));
    out.push(m("pool_jobs", Value::Count(pool.jobs)));
    out.push(m("pool_pulls", Value::Count(pool.pulls)));
    out.push(m("pool_steals", Value::Count(pool.steals)));
    out.push(m("pool_parks", Value::Count(pool.parks)));
    out.push(m("pool_wakes", Value::Count(pool.wakes)));
    out.push(m("pool_inflight", Value::Gauge(pool.inflight)));
    out.push(m("pool_max_inflight", Value::Gauge(pool.max_inflight)));
    out.push(m("pool_exec_peak", Value::Gauge(pool.exec_peak)));
    out.push(m("pool_pins", Value::Count(pool.pins)));
    out.push(m("pool_sticky_jobs", Value::Count(pool.sticky_jobs)));
    out.push(m("pool_sticky_home", Value::Count(pool.sticky_home)));
    out.push(m("pool_sticky_away", Value::Count(pool.sticky_away)));
    out.push(m("lat/pool_wait", Value::Hist(pool.queue_wait)));
    out.push(m("lat/pool_run", Value::Hist(pool.run_time)));

    let fr = crate::cc::contour::frontier_totals();
    out.push(m("frontier_passes", Value::Count(fr.passes)));
    out.push(m("frontier_skipped", Value::Count(fr.skipped_chunks)));
    out.push(m("frontier_activations", Value::Count(fr.activations)));
    out.push(m("frontier_exact", Value::Count(fr.exact_passes)));
    out.push(m("frontier_full_sweeps", Value::Count(fr.full_sweeps)));
    let (idx_built, idx_reused) = crate::cc::contour::chunk_index_counters();
    out.push(m("chunk_index_built", Value::Count(idx_built)));
    out.push(m("chunk_index_reused", Value::Count(idx_reused)));

    out.push(m("heavy_cap", Value::Gauge(state.heavy_cap as u64)));
    out.push(m("heavy_used", Value::Gauge(heavy_used(state))));
    out.push(m("wal_fsync_ns", Value::Gauge(wal_fsync_ns(state))));
    let (mem_cur, alloc_bytes, alloc_calls, free_calls) = mem_gauges();
    out.push(m("mem_cur_bytes", Value::Gauge(mem_cur)));
    out.push(m("alloc_bytes", Value::Count(alloc_bytes)));
    out.push(m("alloc_calls", Value::Count(alloc_calls)));
    out.push(m("free_calls", Value::Count(free_calls)));

    {
        let lat = state.verb_lat.read().unwrap_or_else(|e| e.into_inner());
        for (v, h) in lat.iter() {
            out.push(m(&format!("lat/{v}"), Value::Hist(h.snapshot())));
        }
    }
    {
        let err = state.verb_err.read().unwrap_or_else(|e| e.into_inner());
        for (v, c) in err.iter() {
            out.push(m(&format!("err/{v}"), Value::Count(c.load(Ordering::Relaxed))));
        }
    }
    {
        let cache = state.cache_stats.read().unwrap_or_else(|e| e.into_inner());
        for (name, (h, mi)) in cache.iter() {
            out.push(m(
                &format!("cache/{name}"),
                Value::Pair(h.load(Ordering::Relaxed), mi.load(Ordering::Relaxed)),
            ));
        }
    }
    out.sort_by(|a, b| a.key.cmp(&b.key));
    out
}

/// The `METRICS` reply body: every registry entry as `key=value`,
/// key-sorted, space-joined. Same key spellings and value forms as the
/// PR 7 renderer (clients parse them); only the ordering changed — to
/// globally sorted, so successive scrapes diff cleanly.
pub fn render_metrics(state: &ServerState) -> String {
    let parts: Vec<String> = registry(state)
        .iter()
        .map(|mt| match &mt.val {
            Value::Count(v) | Value::Gauge(v) => format!("{}={v}", mt.key),
            Value::GaugeF(v) => format!("{}={v:.1}", mt.key),
            Value::Hist(h) => format!("{}={}", mt.key, h.render()),
            Value::Pair(h, m) => format!("{}={h}:{m}", mt.key),
        })
        .collect();
    parts.join(" ")
}

/// `contour_`-prefixed OpenMetrics name for a wire key.
fn prom_name(key: &str) -> String {
    let mut s = String::with_capacity(key.len() + 8);
    s.push_str("contour_");
    for c in key.chars() {
        s.push(if c.is_ascii_alphanumeric() { c } else { '_' });
    }
    s
}

/// Escape a label value per the OpenMetrics text format.
fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// One exposition family: `# TYPE` header plus its sample lines.
struct Family {
    name: String,
    kind: &'static str,
    lines: Vec<String>,
}

fn summary_lines(fam: &str, label: &str, verb: &str, h: &HistogramSnapshot) -> Vec<String> {
    let l = escape_label(verb);
    vec![
        format!("{fam}{{{label}=\"{l}\",quantile=\"0.5\"}} {}", h.p50),
        format!("{fam}{{{label}=\"{l}\",quantile=\"0.95\"}} {}", h.p95),
        format!("{fam}{{{label}=\"{l}\",quantile=\"0.99\"}} {}", h.p99),
        format!("{fam}_sum{{{label}=\"{l}\"}} {}", h.sum),
        format!("{fam}_count{{{label}=\"{l}\"}} {}", h.count),
    ]
}

/// The OpenMetrics/Prometheus text exposition: one family per registry
/// entry (labelled families for the per-verb/per-graph groups), plus
/// windowed rate gauges derived from the telemetry ring's newest
/// samples, ending in `# EOF`. No trailing newline — the PROM verb
/// prefixes a line count so the line transport stays line-framed.
pub fn render_prom(state: &ServerState) -> String {
    let mut fams: Vec<Family> = Vec::new();
    // Grouped (labelled) families are collected across registry entries.
    let fam = |name: &str, kind: &'static str| Family {
        name: name.to_string(),
        kind,
        lines: Vec::new(),
    };
    let mut lat = fam("contour_verb_latency_ns", "summary");
    let mut errs = fam("contour_verb_errors_total", "counter");
    let mut cache_h = fam("contour_cache_hits", "gauge");
    let mut cache_m = fam("contour_cache_misses", "gauge");
    for mt in registry(state) {
        match &mt.val {
            Value::Count(v) => {
                if let Some(verb) = mt.key.strip_prefix("err/") {
                    errs.lines.push(format!("{}{{verb=\"{}\"}} {v}", errs.name, escape_label(verb)));
                } else {
                    let name = format!("{}_total", prom_name(&mt.key));
                    fams.push(Family {
                        lines: vec![format!("{name} {v}")],
                        name,
                        kind: "counter",
                    });
                }
            }
            Value::Gauge(v) => {
                let name = prom_name(&mt.key);
                fams.push(Family { lines: vec![format!("{name} {v}")], name, kind: "gauge" });
            }
            Value::GaugeF(v) => {
                let name = prom_name(&mt.key);
                fams.push(Family { lines: vec![format!("{name} {v:.3}")], name, kind: "gauge" });
            }
            Value::Hist(h) => {
                let verb = mt.key.strip_prefix("lat/").unwrap_or(&mt.key);
                lat.lines.extend(summary_lines(&lat.name, "verb", verb, h));
            }
            Value::Pair(h, mi) => {
                let name = escape_label(mt.key.strip_prefix("cache/").unwrap_or(&mt.key));
                cache_h.lines.push(format!("{}{{name=\"{name}\"}} {h}", cache_h.name));
                cache_m.lines.push(format!("{}{{name=\"{name}\"}} {mi}", cache_m.name));
            }
        }
    }
    for f in [lat, errs, cache_h, cache_m] {
        if !f.lines.is_empty() {
            fams.push(f);
        }
    }

    // Windowed rates from the ring: live registry + newest samples.
    let window_ms = env_u64("CONTOUR_HEALTH_WINDOW_MS", DEFAULT_WINDOW_MS);
    let gauge = |name: &str, line: String| Family {
        name: name.to_string(),
        kind: "gauge",
        lines: vec![line],
    };
    fams.push(gauge(
        "contour_ring_samples",
        format!("contour_ring_samples {}", state.ring.len()),
    ));
    if let Some((old, new)) = state.ring.window(window_ms) {
        let rate = |key: &str| -> f64 {
            state
                .ring
                .index_of(key)
                .map_or(0.0, |i| obs::TimeSeries::rate_per_sec(&old, &new, i))
        };
        fams.push(gauge("contour_rate_qps", format!("contour_rate_qps {:.3}", rate("requests"))));
        fams.push(gauge(
            "contour_rate_bytes_in_per_s",
            format!("contour_rate_bytes_in_per_s {:.3}", rate("bytes_in")),
        ));
        fams.push(gauge(
            "contour_rate_bytes_out_per_s",
            format!("contour_rate_bytes_out_per_s {:.3}", rate("bytes_out")),
        ));
        let h = health_signals(state);
        fams.push(gauge(
            "contour_busy_fraction",
            format!("contour_busy_fraction {:.6}", h.busy_frac),
        ));
        fams.push(gauge(
            "contour_pool_saturation",
            format!("contour_pool_saturation {:.6}", h.heavy_sat),
        ));
    }

    fams.sort_by(|a, b| a.name.cmp(&b.name));
    let mut out = String::with_capacity(4096);
    for f in &fams {
        out.push_str(&format!("# TYPE {} {}\n", f.name, f.kind));
        for l in &f.lines {
            out.push_str(l);
            out.push('\n');
        }
    }
    out.push_str("# EOF");
    out
}

// ---------------------------------------------------------------------
// Ring sample schema
// ---------------------------------------------------------------------

// The schema is FIXED: sample_keys() and sample_values() must walk the
// exact same sections in the exact same order (the push asserts the
// lengths agree, and tests/telemetry.rs pins key↔value alignment).

const POOL_KEYS: &[&str] = &[
    "pool_jobs",
    "pool_pulls",
    "pool_steals",
    "pool_parks",
    "pool_wakes",
    "pool_pins",
    "pool_sticky_jobs",
    "pool_sticky_home",
    "pool_sticky_away",
    "pool_workers",
    "pool_inflight",
    "pool_max_inflight",
    "pool_exec_peak",
];

const ENGINE_KEYS: &[&str] = &[
    "frontier_passes",
    "frontier_skipped",
    "frontier_activations",
    "frontier_exact",
    "frontier_full_sweeps",
    "chunk_index_built",
    "chunk_index_reused",
    "heavy_used",
    "heavy_cap",
    "wal_fsync_ns",
    "mem_cur_bytes",
    "alloc_bytes",
    "alloc_calls",
    "free_calls",
];

/// Histogram families sampled per tick: every verb (the table is
/// static) plus the pool pair.
fn hist_names() -> Vec<&'static str> {
    let mut v: Vec<&'static str> = super::VERBS.to_vec();
    v.push("pool_wait");
    v.push("pool_run");
    v
}

/// The ring's key schema, fixed at server construction.
pub fn sample_keys() -> Vec<String> {
    let mut keys: Vec<String> = Vec::with_capacity(256);
    // Counter keys come from the same source the registry uses; the
    // values of a default Metrics are irrelevant here.
    for (k, _) in super::metrics::Metrics::default().counter_pairs() {
        keys.push(k.to_string());
    }
    keys.extend(POOL_KEYS.iter().map(|k| k.to_string()));
    keys.extend(ENGINE_KEYS.iter().map(|k| k.to_string()));
    for h in hist_names() {
        for q in ["count", "p50", "p95", "p99"] {
            keys.push(format!("lat/{h}/{q}"));
        }
    }
    for b in 0..obs::BUCKETS {
        keys.push(format!("pool_wait_bkt/{b}"));
    }
    keys
}

/// The schema's values right now, in [`sample_keys`] order.
pub fn sample_values(state: &ServerState) -> Vec<u64> {
    let mut v: Vec<u64> = Vec::with_capacity(256);
    for (_, x) in state.metrics.counter_pairs() {
        v.push(x);
    }
    let pool = crate::par::pool::stats();
    v.extend([
        pool.jobs,
        pool.pulls,
        pool.steals,
        pool.parks,
        pool.wakes,
        pool.pins,
        pool.sticky_jobs,
        pool.sticky_home,
        pool.sticky_away,
        pool.workers as u64,
        pool.inflight,
        pool.max_inflight,
        pool.exec_peak,
    ]);
    let fr = crate::cc::contour::frontier_totals();
    let (idx_built, idx_reused) = crate::cc::contour::chunk_index_counters();
    let (mem_cur, alloc_bytes, alloc_calls, free_calls) = mem_gauges();
    v.extend([
        fr.passes,
        fr.skipped_chunks,
        fr.activations,
        fr.exact_passes,
        fr.full_sweeps,
        idx_built,
        idx_reused,
        heavy_used(state),
        state.heavy_cap as u64,
        wal_fsync_ns(state),
        mem_cur,
        alloc_bytes,
        alloc_calls,
        free_calls,
    ]);
    {
        let lat = state.verb_lat.read().unwrap_or_else(|e| e.into_inner());
        for name in hist_names() {
            let h = match name {
                "pool_wait" => pool.queue_wait,
                "pool_run" => pool.run_time,
                verb => lat.get(verb).map(|h| h.snapshot()).unwrap_or_default(),
            };
            v.extend([h.count, h.p50, h.p95, h.p99]);
        }
    }
    v.extend(crate::par::pool::queue_wait_buckets());
    v
}

/// Capture one live sample (timestamped against server start).
pub fn live_sample(state: &ServerState) -> Sample {
    Sample { ts_ms: state.metrics.uptime_ms(), values: sample_values(state) }
}

/// Capture and push one sample into the server's ring.
pub fn sample_into_ring(state: &ServerState) {
    let s = live_sample(state);
    state.ring.push(s.ts_ms, &s.values);
}

// ---------------------------------------------------------------------
// HEALTH
// ---------------------------------------------------------------------

/// The windowed signals HEALTH judges.
pub struct HealthSignals {
    /// BUSY replies over requests in the window (0 with no traffic).
    pub busy_frac: f64,
    /// Heavy-verb slots held / capacity (1.0 when the cap is 0 — drain
    /// mode rejects every heavy verb, which *is* saturation).
    pub heavy_sat: f64,
    /// Windowed pool queue-wait p95 (ns) from ring bucket deltas, or
    /// the lifetime p95 when the ring has no window yet.
    pub pool_wait_p95_ns: u64,
    /// Duration of the most recent WAL fsync (ns), max across streams.
    pub fsync_ns: u64,
    /// Caught verb panics in the window (lifetime total as fallback).
    pub panics: u64,
    /// Injected faults in the window (lifetime total as fallback) — a
    /// storm means someone armed the failpoint registry against this
    /// server, which an operator should see as degraded.
    pub faults: u64,
    /// Ring samples backing the windowed values (0 = lifetime
    /// fallback).
    pub samples: usize,
    pub window_ms: u64,
}

/// Compute the health signals over the configured lookback window,
/// falling back to lifetime totals while the ring has fewer than two
/// samples (e.g. dispatch-only use with no sampler thread).
pub fn health_signals(state: &ServerState) -> HealthSignals {
    let window_ms = env_u64("CONTOUR_HEALTH_WINDOW_MS", DEFAULT_WINDOW_MS);
    let heavy_sat = if state.heavy_cap == 0 {
        1.0
    } else {
        heavy_used(state) as f64 / state.heavy_cap as f64
    };
    let fsync_ns = wal_fsync_ns(state);
    if let Some((old, new)) = state.ring.window(window_ms) {
        let d = |key: &str| -> u64 {
            state.ring.index_of(key).map_or(0, |i| obs::TimeSeries::delta(&old, &new, i))
        };
        let d_req = d("requests");
        let busy_frac = if d_req == 0 { 0.0 } else { d("busy") as f64 / d_req as f64 };
        let bkt: Vec<u64> = (0..obs::BUCKETS)
            .map(|b| {
                state
                    .ring
                    .index_of(&format!("pool_wait_bkt/{b}"))
                    .map_or(0, |i| obs::TimeSeries::delta(&old, &new, i))
            })
            .collect();
        HealthSignals {
            busy_frac,
            heavy_sat,
            pool_wait_p95_ns: quantile_from_counts(&bkt, 0.95),
            fsync_ns,
            panics: d("panics"),
            faults: d("faults_injected"),
            samples: state.ring.len(),
            window_ms,
        }
    } else {
        let req = state.metrics.requests.get();
        let busy_frac = if req == 0 { 0.0 } else { state.metrics.busy.get() as f64 / req as f64 };
        HealthSignals {
            busy_frac,
            heavy_sat,
            pool_wait_p95_ns: crate::par::pool::stats().queue_wait.p95,
            fsync_ns,
            panics: state.metrics.panics.get(),
            faults: crate::util::faults::injected_total(),
            samples: 0,
            window_ms,
        }
    }
}

/// The `HEALTH` reply body: a status word first (`ready` | `degraded` |
/// `overloaded`), then the signals and thresholds as `k=v` pairs.
///
/// Thresholds (env-overridable, read per request so operators can tune
/// a live server):
/// * `CONTOUR_HEALTH_BUSY_DEGRADED`   — busy fraction, default 0.05
/// * `CONTOUR_HEALTH_BUSY_OVERLOADED` — busy fraction, default 0.5
/// * `CONTOUR_HEALTH_POOL_WAIT_MS`    — queue-wait p95, default 100
/// * `CONTOUR_HEALTH_FSYNC_MS`        — WAL fsync lag, default 1000
/// * `CONTOUR_HEALTH_PANICS`          — caught verb panics in the
///   window, default 1 (any recent panic degrades)
/// * `CONTOUR_HEALTH_FAULTS`          — injected faults in the window,
///   default 100 (a fault storm means someone armed the failpoint
///   registry against this server)
pub fn render_health(state: &ServerState) -> String {
    let s = health_signals(state);
    let busy_deg = env_f64("CONTOUR_HEALTH_BUSY_DEGRADED", 0.05);
    let busy_over = env_f64("CONTOUR_HEALTH_BUSY_OVERLOADED", 0.5);
    let wait_ns = env_f64("CONTOUR_HEALTH_POOL_WAIT_MS", 100.0) * 1e6;
    let fsync_ns = env_f64("CONTOUR_HEALTH_FSYNC_MS", 1000.0) * 1e6;
    let panics_max = env_u64("CONTOUR_HEALTH_PANICS", 1);
    let faults_max = env_u64("CONTOUR_HEALTH_FAULTS", 100);
    let status = if s.busy_frac >= busy_over {
        "overloaded"
    } else if s.busy_frac >= busy_deg
        || s.heavy_sat >= 1.0
        || s.pool_wait_p95_ns as f64 > wait_ns
        || s.fsync_ns as f64 > fsync_ns
        || s.panics >= panics_max
        || s.faults >= faults_max
    {
        "degraded"
    } else {
        "ready"
    };
    format!(
        "{status} busy_frac={:.4} heavy_sat={:.4} pool_wait_p95_ns={} wal_fsync_ns={} \
         panics={} faults_injected={} window_ms={} samples={} busy_degraded={busy_deg} \
         busy_overloaded={busy_over}",
        s.busy_frac, s.heavy_sat, s.pool_wait_p95_ns, s.fsync_ns, s.panics, s.faults, s.window_ms,
        s.samples
    )
}

// ---------------------------------------------------------------------
// WATCH
// ---------------------------------------------------------------------

/// Bounds on WATCH arguments (a stuck client cannot pin a server
/// thread forever, and a zero interval cannot spin).
pub const WATCH_MAX_TICKS: u64 = 100_000;
pub const WATCH_MIN_INTERVAL_MS: u64 = 10;
pub const WATCH_MAX_INTERVAL_MS: u64 = 60_000;

/// One WATCH tick line: counter deltas between two samples plus the
/// instantaneous qps over the tick interval.
pub fn render_tick(seq: u64, prev: &Sample, cur: &Sample, keys: &[String]) -> String {
    let dt_ms = cur.ts_ms.saturating_sub(prev.ts_ms);
    let mut out = format!("TICK {seq} t_ms={} dt_ms={dt_ms}", cur.ts_ms);
    for &k in WATCH_KEYS {
        if let Some(i) = keys.iter().position(|key| key == k) {
            out.push_str(&format!(" {k}={}", cur.values[i].saturating_sub(prev.values[i])));
        }
    }
    let qps = if dt_ms == 0 {
        0.0
    } else {
        let d = keys
            .iter()
            .position(|k| k == "requests")
            .map_or(0, |i| cur.values[i].saturating_sub(prev.values[i]));
        d as f64 * 1000.0 / dt_ms as f64
    };
    out.push_str(&format!(" qps={qps:.1}"));
    out
}

/// Drive one WATCH subscription: sample, sleep an interval, emit a tick
/// line, `ticks` times. `emit` returns false to stop early (client went
/// away). Both transports share this loop; only the framing differs.
pub fn watch_stream(
    state: &ServerState,
    ticks: u64,
    interval_ms: u64,
    mut emit: impl FnMut(&str) -> bool,
) {
    let keys = sample_keys();
    let interval =
        Duration::from_millis(interval_ms.clamp(WATCH_MIN_INTERVAL_MS, WATCH_MAX_INTERVAL_MS));
    let mut prev = live_sample(state);
    for seq in 0..ticks.min(WATCH_MAX_TICKS) {
        std::thread::sleep(interval);
        let cur = live_sample(state);
        if !emit(&render_tick(seq, &prev, &cur, &keys)) {
            return;
        }
        prev = cur;
    }
}
