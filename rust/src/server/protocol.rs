//! Binary protocol v2: length-prefixed frames with request ids, so one
//! connection can pipeline many requests and receive replies out of
//! order — the serving path for programs, next to the line protocol
//! for humans. A connection starts in the line protocol and upgrades
//! with `HELLO 2` (see [`super::dispatch`]); both protocols run the
//! same dispatch core, so behavior cannot drift.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! offset  size  field
//!      0     1  magic 'C'
//!      1     1  magic 'P'
//!      2     1  version (2)
//!      3     1  request: verb opcode · reply: status (OK/ERR/BUSY/BYE)
//!      4     4  request id (u32, echoed verbatim in the reply)
//!      8     4  payload length (u32, capped at MAX_FRAME)
//!     12     …  payload
//! ```
//!
//! Request payload: `u16 args_len | args (UTF-8, space-separated) |
//! [u32 count | count × u32]` — the optional trailing block carries
//! vertex ids for BQUERY and flattened `(u, v)` pairs for UPLOAD.
//!
//! Reply payload: OK → UTF-8 text (exactly what the line protocol puts
//! after `OK `), except BQUERY (`u32 count | count × u32 labels`) and
//! LABELS (`u64 total | u32 count | count × u32 labels`, written
//! zero-copy from the cached label slice); ERR/BUSY → UTF-8 message;
//! BYE → empty.
//!
//! Pipelining and backpressure: light verbs run inline on the reader
//! thread; heavy verbs ([`is_pipelined`]) each get a scoped thread and
//! complete out of order through a per-connection writer queue. At
//! most [`super::ServerState::window`] heavy requests may be in flight
//! per connection — beyond that the server answers a BUSY frame
//! immediately instead of queueing unboundedly (the global heavy-verb
//! semaphore in the dispatch core guards total load the same way).
//!
//! WATCH is the one multi-frame verb: the server pushes one OK frame
//! per tick (each echoing the request id) and a final OK frame whose
//! payload is `DONE`; a pipelining client keys the stream off the id
//! and interleaves other traffic freely.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::VId;

use super::dispatch::{self, Body, Reply};
use super::{CcEntry, ServerState};

pub const MAGIC: [u8; 2] = *b"CP";
pub const VERSION: u8 = 2;

pub const STATUS_OK: u8 = 0;
pub const STATUS_ERR: u8 = 1;
pub const STATUS_BUSY: u8 = 2;
pub const STATUS_BYE: u8 = 3;

/// Frame payload cap: a malformed or hostile length field cannot make
/// the server allocate unboundedly.
pub const MAX_FRAME: u32 = 64 << 20;

/// Verb opcodes (request header byte 3). A stable wire contract:
/// append new verbs, never renumber.
pub const OPCODES: &[(u8, &str)] = &[
    (1, "PING"),
    (2, "GEN"),
    (3, "UPLOAD"),
    (4, "LOAD"),
    (5, "CC"),
    (6, "LABELS"),
    (7, "STATS"),
    (8, "SHARD"),
    (9, "PCC"),
    (10, "SHARDSTATS"),
    (11, "STREAM"),
    (12, "SADD"),
    (13, "SEPOCH"),
    (14, "SQUERY"),
    (15, "SSAVE"),
    (16, "SLOAD"),
    (17, "LIST"),
    (18, "DROP"),
    (19, "METRICS"),
    (20, "TRACE"),
    (21, "RECENT"),
    (22, "QUERY"),
    (23, "BQUERY"),
    (24, "HELLO"),
    (25, "QUIT"),
    (26, "PROM"),
    (27, "HEALTH"),
    (28, "WATCH"),
    (29, "FAULTS"),
    (30, "SDEL"),
];

pub fn opcode_of(verb: &str) -> Option<u8> {
    OPCODES.iter().find(|(_, v)| *v == verb).map(|(o, _)| *o)
}

pub fn verb_of(op: u8) -> Option<&'static str> {
    OPCODES.iter().find(|(o, _)| *o == op).map(|(_, v)| *v)
}

fn header(kind: u8, id: u32, payload_len: u32) -> [u8; 12] {
    let mut h = [0u8; 12];
    h[0] = MAGIC[0];
    h[1] = MAGIC[1];
    h[2] = VERSION;
    h[3] = kind;
    h[4..8].copy_from_slice(&id.to_le_bytes());
    h[8..12].copy_from_slice(&payload_len.to_le_bytes());
    h
}

/// Read and validate one frame header; `None` on clean EOF at a frame
/// boundary. A torn header (EOF mid-frame) is an error.
fn read_header<R: Read>(r: &mut R) -> Result<Option<(u8, u32, usize)>> {
    let mut h = [0u8; 12];
    loop {
        match r.read(&mut h[..1]) {
            Ok(0) => return Ok(None),
            Ok(_) => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    r.read_exact(&mut h[1..])?;
    anyhow::ensure!(
        h[0] == MAGIC[0] && h[1] == MAGIC[1],
        "bad frame magic {:02x}{:02x}",
        h[0],
        h[1]
    );
    anyhow::ensure!(h[2] == VERSION, "unsupported frame version {}", h[2]);
    let id = u32::from_le_bytes(h[4..8].try_into().unwrap());
    let len = u32::from_le_bytes(h[8..12].try_into().unwrap());
    anyhow::ensure!(len <= MAX_FRAME, "frame of {len} bytes exceeds cap {MAX_FRAME}");
    Ok(Some((h[3], id, len as usize)))
}

// ------------------------------------------------------- request side

/// One decoded request frame.
pub(crate) struct Request {
    pub id: u32,
    pub verb: &'static str,
    pub args: String,
    /// The packed u32 block: BQUERY ids or UPLOAD edge pairs.
    pub extra: Vec<VId>,
    /// Bytes this frame occupied on the wire (header + payload).
    pub wire_len: usize,
}

pub(crate) fn read_request<R: Read>(r: &mut R) -> Result<Option<Request>> {
    let Some((op, id, len)) = read_header(r)? else { return Ok(None) };
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let verb = verb_of(op).ok_or_else(|| anyhow!("unknown opcode {op}"))?;
    let (args, extra) = decode_request_payload(&payload)?;
    Ok(Some(Request { id, verb, args, extra, wire_len: 12 + len }))
}

fn decode_request_payload(p: &[u8]) -> Result<(String, Vec<VId>)> {
    anyhow::ensure!(p.len() >= 2, "truncated frame: missing args length");
    let alen = u16::from_le_bytes([p[0], p[1]]) as usize;
    let rest = &p[2..];
    anyhow::ensure!(rest.len() >= alen, "truncated frame: args length {alen} exceeds payload");
    let args =
        std::str::from_utf8(&rest[..alen]).map_err(|_| anyhow!("args not UTF-8"))?.to_string();
    let tail = &rest[alen..];
    if tail.is_empty() {
        return Ok((args, Vec::new()));
    }
    anyhow::ensure!(tail.len() >= 4, "truncated frame: missing id count");
    let count = u32::from_le_bytes(tail[..4].try_into().unwrap()) as usize;
    let data = &tail[4..];
    let want = count.checked_mul(4).ok_or_else(|| anyhow!("id count overflow"))?;
    anyhow::ensure!(data.len() == want, "frame id block: {} bytes for {count} ids", data.len());
    let mut ids = Vec::with_capacity(count);
    for c in data.chunks_exact(4) {
        ids.push(u32::from_le_bytes(c.try_into().unwrap()));
    }
    Ok((args, ids))
}

/// Encode one request frame (the client side: the Rust load generator
/// and wire tests; `python/client/contour_client.py` mirrors this).
/// `extra` packs BQUERY vertex ids or UPLOAD flattened edge pairs.
pub fn encode_request(id: u32, verb: &str, args: &str, extra: &[VId]) -> Result<Vec<u8>> {
    let cmd = verb.to_ascii_uppercase();
    let op = opcode_of(&cmd).ok_or_else(|| anyhow!("no opcode for verb {verb:?}"))?;
    anyhow::ensure!(args.len() <= u16::MAX as usize, "args too long ({} bytes)", args.len());
    let extra_len = if extra.is_empty() { 0 } else { 4 + 4 * extra.len() };
    let payload_len = 2 + args.len() + extra_len;
    anyhow::ensure!(payload_len as u64 <= u64::from(MAX_FRAME), "frame too large");
    let mut b = Vec::with_capacity(12 + payload_len);
    b.extend_from_slice(&header(op, id, payload_len as u32));
    b.extend_from_slice(&(args.len() as u16).to_le_bytes());
    b.extend_from_slice(args.as_bytes());
    if !extra.is_empty() {
        b.extend_from_slice(&(extra.len() as u32).to_le_bytes());
        for v in extra {
            b.extend_from_slice(&v.to_le_bytes());
        }
    }
    Ok(b)
}

// --------------------------------------------------------- reply side

/// One decoded reply frame (client side).
pub struct ReplyFrame {
    pub id: u32,
    pub status: u8,
    pub payload: Vec<u8>,
}

impl ReplyFrame {
    /// The payload as text (OK/ERR/BUSY bodies).
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.payload).into_owned()
    }

    /// Decode a BQUERY reply payload: `u32 count | labels`.
    pub fn batch_labels(&self) -> Result<Vec<VId>> {
        decode_u32_block(&self.payload, 0)
    }

    /// Decode a LABELS page payload: `(total, labels)`.
    pub fn page(&self) -> Result<(u64, Vec<VId>)> {
        anyhow::ensure!(self.payload.len() >= 8, "short LABELS payload");
        let total = u64::from_le_bytes(self.payload[..8].try_into().unwrap());
        Ok((total, decode_u32_block(&self.payload, 8)?))
    }
}

fn decode_u32_block(p: &[u8], at: usize) -> Result<Vec<VId>> {
    anyhow::ensure!(p.len() >= at + 4, "short label block");
    let count = u32::from_le_bytes(p[at..at + 4].try_into().unwrap()) as usize;
    let data = &p[at + 4..];
    anyhow::ensure!(data.len() == 4 * count, "label block: {} bytes for {count} labels", data.len());
    Ok(data.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
}

/// Read one reply frame; `None` on clean EOF.
pub fn read_reply<R: Read>(r: &mut R) -> Result<Option<ReplyFrame>> {
    let Some((status, id, len)) = read_header(r)? else { return Ok(None) };
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(Some(ReplyFrame { id, status, payload }))
}

fn encode_reply(id: u32, status: u8, text: &str) -> Vec<u8> {
    let mut b = Vec::with_capacity(12 + text.len());
    b.extend_from_slice(&header(status, id, text.len() as u32));
    b.extend_from_slice(text.as_bytes());
    b
}

fn encode_batch(id: u32, labels: &[VId]) -> Vec<u8> {
    let mut b = Vec::with_capacity(12 + 4 + 4 * labels.len());
    b.extend_from_slice(&header(STATUS_OK, id, (4 + 4 * labels.len()) as u32));
    b.extend_from_slice(&(labels.len() as u32).to_le_bytes());
    for l in labels {
        b.extend_from_slice(&l.to_le_bytes());
    }
    b
}

fn page_head(id: u32, total: usize, count: usize) -> Vec<u8> {
    let mut b = Vec::with_capacity(12 + 12);
    b.extend_from_slice(&header(STATUS_OK, id, (12 + 4 * count) as u32));
    b.extend_from_slice(&(total as u64).to_le_bytes());
    b.extend_from_slice(&(count as u32).to_le_bytes());
    b
}

/// A reply queued for the writer thread. `Page` defers the label bytes
/// so they are written zero-copy from the cached slice, never staged
/// through an intermediate buffer.
enum WireReply {
    Buf(Vec<u8>),
    Page { head: Vec<u8>, entry: Arc<CcEntry>, lo: usize, hi: usize },
}

fn encode_wire(id: u32, reply: Reply) -> WireReply {
    match reply {
        Reply::Ok(s) => WireReply::Buf(encode_reply(id, STATUS_OK, &s)),
        Reply::Pong => WireReply::Buf(encode_reply(id, STATUS_OK, "PONG")),
        Reply::Upgrade => WireReply::Buf(encode_reply(id, STATUS_OK, "v2")),
        Reply::Err(e) => WireReply::Buf(encode_reply(id, STATUS_ERR, &e)),
        Reply::Busy(m) => WireReply::Buf(encode_reply(id, STATUS_BUSY, &m)),
        Reply::Bye => WireReply::Buf(encode_reply(id, STATUS_BYE, "")),
        Reply::Batch(labels) => WireReply::Buf(encode_batch(id, &labels)),
        Reply::Page { total, entry, lo, hi } => {
            WireReply::Page { head: page_head(id, total, hi - lo), entry, lo, hi }
        }
        // Only reachable if WATCH ever runs un-pipelined; the header
        // alone is still a well-formed (if tick-less) reply.
        Reply::Watch { ticks, interval_ms } => {
            WireReply::Buf(encode_reply(id, STATUS_OK, &format!("{ticks} {interval_ms}")))
        }
    }
}

/// Verbs dispatched on their own thread so replies can complete out of
/// order behind the per-connection window. Cheap point lookups run
/// inline on the reader thread — a spawn would cost more than the
/// lookup itself.
fn is_pipelined(verb: &str) -> bool {
    matches!(
        verb,
        "GEN"
            | "UPLOAD"
            | "LOAD"
            | "CC"
            | "PCC"
            | "SHARD"
            | "STREAM"
            | "SADD"
            | "SDEL"
            | "SEPOCH"
            | "SSAVE"
            | "SLOAD"
            | "LABELS"
            | "BQUERY"
            | "WATCH"
    )
}

fn dispatch_request(state: &ServerState, req: &Request) -> Reply {
    let args: Vec<&str> = req.args.split_whitespace().collect();
    if req.verb == "UPLOAD" {
        if req.extra.len() % 2 != 0 {
            return Reply::Err("UPLOAD payload needs an even number of ids (u v pairs)".into());
        }
        let edges: Vec<(VId, VId)> = req.extra.chunks_exact(2).map(|p| (p[0], p[1])).collect();
        dispatch::dispatch(state, req.verb, &args, Body::Edges(&edges))
    } else if req.extra.is_empty() {
        dispatch::dispatch(state, req.verb, &args, Body::None)
    } else {
        dispatch::dispatch(state, req.verb, &args, Body::Ids(&req.extra))
    }
}

fn write_msg(
    w: &mut BufWriter<TcpStream>,
    msg: &WireReply,
    state: &ServerState,
) -> std::io::Result<()> {
    match msg {
        WireReply::Buf(b) => {
            w.write_all(b)?;
            state.metrics.bytes_out.add(b.len() as u64);
        }
        WireReply::Page { head, entry, lo, hi } => {
            w.write_all(head)?;
            let labels = &entry.labels()[*lo..*hi];
            write_label_slice(w, labels)?;
            state.metrics.bytes_out.add((head.len() + 4 * labels.len()) as u64);
        }
    }
    Ok(())
}

/// The zero-copy LABELS body: on little-endian targets the cached
/// label slice *is* the wire encoding, so it goes to the socket
/// without per-element formatting or an intermediate buffer.
fn write_label_slice<W: Write>(w: &mut W, labels: &[VId]) -> std::io::Result<()> {
    #[cfg(target_endian = "little")]
    {
        // SAFETY: u32 has no padding bytes and u8 has no alignment
        // requirement; the view covers exactly `4 * len` initialized
        // bytes of the slice.
        let bytes =
            unsafe { std::slice::from_raw_parts(labels.as_ptr().cast::<u8>(), labels.len() * 4) };
        w.write_all(bytes)
    }
    #[cfg(not(target_endian = "little"))]
    {
        for l in labels {
            w.write_all(&l.to_le_bytes())?;
        }
        Ok(())
    }
}

/// The writer half of a pipelined connection: a queue drained by one
/// thread, so replies from concurrently dispatched requests are
/// serialized onto the socket whole (never interleaved) and in
/// completion order. Flushes only when the queue runs dry, batching
/// back-to-back replies into one syscall.
fn write_loop(mut w: BufWriter<TcpStream>, rx: mpsc::Receiver<WireReply>, state: &ServerState) {
    while let Ok(msg) = rx.recv() {
        if write_msg(&mut w, &msg, state).is_err() {
            return;
        }
        loop {
            match rx.try_recv() {
                Ok(m) => {
                    if write_msg(&mut w, &m, state).is_err() {
                        return;
                    }
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => {
                    let _ = w.flush();
                    return;
                }
            }
        }
        if w.flush().is_err() {
            return;
        }
    }
    let _ = w.flush();
}

/// Serve one upgraded connection until QUIT, EOF or a protocol error.
/// Called by `handle_conn` after the `HELLO 2` upgrade, inheriting the
/// line reader's buffer (a pipelining client may have sent binary
/// frames right behind its HELLO).
pub(crate) fn serve_binary(
    mut reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    state: &ServerState,
) -> Result<()> {
    let window = state.window();
    // In-flight pipelined requests on this connection. Incremented by
    // the reader, decremented by each worker *after* queueing its
    // reply, so "window full" and "QUIT drain" are both exact.
    let inflight = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<WireReply>();
    std::thread::scope(|scope| {
        scope.spawn(|| write_loop(writer, rx, state));
        loop {
            let req = match read_request(&mut reader) {
                Ok(Some(r)) => r,
                // Clean EOF between frames or a protocol error: either
                // way the framing is unrecoverable, drop the connection.
                _ => break,
            };
            state.metrics.bytes_in.add(req.wire_len as u64);
            if is_pipelined(req.verb) {
                if inflight.load(Ordering::Acquire) >= window {
                    // Backpressure: over the per-connection window the
                    // request is rejected immediately — the client
                    // retires replies and resubmits — instead of
                    // queueing without bound.
                    state.metrics.busy.inc();
                    let msg = format!("pipeline window full ({window} in flight)");
                    if tx.send(WireReply::Buf(encode_reply(req.id, STATUS_BUSY, &msg))).is_err() {
                        break;
                    }
                    continue;
                }
                inflight.fetch_add(1, Ordering::AcqRel);
                let tx2 = tx.clone();
                let inflight = &inflight;
                scope.spawn(move || {
                    match dispatch_request(state, &req) {
                        // WATCH streams: one OK frame per tick (all
                        // carrying the request id, so a pipelining
                        // client can interleave other traffic), then a
                        // terminal DONE frame.
                        Reply::Watch { ticks, interval_ms } => {
                            super::telemetry::watch_stream(state, ticks, interval_ms, |tick| {
                                tx2.send(WireReply::Buf(encode_reply(req.id, STATUS_OK, tick)))
                                    .is_ok()
                            });
                            let _ =
                                tx2.send(WireReply::Buf(encode_reply(req.id, STATUS_OK, "DONE")));
                        }
                        reply => {
                            let _ = tx2.send(encode_wire(req.id, reply));
                        }
                    }
                    inflight.fetch_sub(1, Ordering::AcqRel);
                });
            } else {
                let reply = dispatch_request(state, &req);
                let bye = matches!(reply, Reply::Bye);
                if bye {
                    // Retire the window first so BYE is the last frame
                    // on the wire.
                    while inflight.load(Ordering::Acquire) > 0 {
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                }
                if tx.send(encode_wire(req.id, reply)).is_err() || bye {
                    break;
                }
            }
        }
        drop(tx);
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_frames_roundtrip() {
        let b = encode_request(7, "bquery", "g epoch:3", &[1, 2, 99]).unwrap();
        let req = read_request(&mut &b[..]).unwrap().unwrap();
        assert_eq!(req.id, 7);
        assert_eq!(req.verb, "BQUERY");
        assert_eq!(req.args, "g epoch:3");
        assert_eq!(req.extra, vec![1, 2, 99]);
        assert_eq!(req.wire_len, b.len());
        // No extra block when there are no ids.
        let b = encode_request(1, "PING", "", &[]).unwrap();
        let req = read_request(&mut &b[..]).unwrap().unwrap();
        assert_eq!(req.verb, "PING");
        assert!(req.args.is_empty() && req.extra.is_empty());
        // Clean EOF at a frame boundary is None, not an error.
        assert!(read_request(&mut &[][..]).unwrap().is_none());
        assert!(encode_request(0, "NOPE", "", &[]).is_err());
    }

    #[test]
    fn reply_frames_roundtrip() {
        let b = encode_reply(42, STATUS_ERR, "no graph \"g\"");
        let f = read_reply(&mut &b[..]).unwrap().unwrap();
        assert_eq!((f.id, f.status), (42, STATUS_ERR));
        assert_eq!(f.text(), "no graph \"g\"");

        let b = encode_batch(3, &[5, 5, 0]);
        let f = read_reply(&mut &b[..]).unwrap().unwrap();
        assert_eq!(f.status, STATUS_OK);
        assert_eq!(f.batch_labels().unwrap(), vec![5, 5, 0]);

        // A page frame: head + the raw label bytes the writer appends.
        let mut b = page_head(9, 100, 3);
        let mut cursor = Vec::new();
        write_label_slice(&mut cursor, &[7, 8, 9]).unwrap();
        b.extend_from_slice(&cursor);
        let f = read_reply(&mut &b[..]).unwrap().unwrap();
        let (total, labels) = f.page().unwrap();
        assert_eq!(total, 100);
        assert_eq!(labels, vec![7, 8, 9]);
    }

    #[test]
    fn malformed_frames_are_clean_errors() {
        // Bad magic.
        let mut b = encode_request(1, "PING", "", &[]).unwrap();
        b[0] = b'X';
        assert!(read_request(&mut &b[..]).is_err());
        // Wrong version.
        let mut b = encode_request(1, "PING", "", &[]).unwrap();
        b[2] = 9;
        assert!(read_request(&mut &b[..]).is_err());
        // Oversized payload length.
        let mut b = header(1, 1, MAX_FRAME + 1).to_vec();
        b.extend_from_slice(&[0u8; 16]);
        assert!(read_request(&mut &b[..]).is_err());
        // Args length pointing past the payload.
        let mut b = header(1, 1, 2).to_vec();
        b.extend_from_slice(&u16::MAX.to_le_bytes());
        assert!(read_request(&mut &b[..]).is_err());
        // Id count not matching the block size.
        let mut payload = Vec::new();
        payload.extend_from_slice(&0u16.to_le_bytes());
        payload.extend_from_slice(&9u32.to_le_bytes()); // claims 9 ids
        payload.extend_from_slice(&[0u8; 4]); // provides 1
        let mut b = header(23, 1, payload.len() as u32).to_vec();
        b.extend_from_slice(&payload);
        assert!(read_request(&mut &b[..]).is_err());
    }
}
