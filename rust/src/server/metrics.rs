//! Server/coordinator metrics: lock-free counters rendered in a
//! `key=value` line (scrape-friendly, no external deps).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// A monotonically increasing counter.
#[derive(Default, Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The server's counter set.
#[derive(Debug)]
pub struct Metrics {
    pub requests: Counter,
    pub errors: Counter,
    pub graphs_loaded: Counter,
    pub cc_runs: Counter,
    /// Total milliseconds spent inside connectivity runs.
    pub cc_millis: Counter,
    /// CC/LABELS requests answered from the labels cache.
    pub cc_cache_hits: Counter,
    /// CC/LABELS requests that computed (and admitted) a fresh entry.
    pub cc_cache_misses: Counter,
    /// Sharded views created (SHARD).
    pub shards_created: Counter,
    /// Partitioned connectivity runs (PCC).
    pub pcc_runs: Counter,
    /// Total milliseconds spent inside partitioned connectivity runs.
    pub pcc_millis: Counter,
    /// Streaming sessions created (STREAM + SLOAD).
    pub streams_created: Counter,
    /// Edges ingested through SADD across all streams.
    pub stream_edges: Counter,
    /// Edges removed through SDEL across all streams.
    pub stream_deletes: Counter,
    /// Epochs sealed (SEPOCH, plus implicit seals on recovery).
    pub stream_epochs: Counter,
    /// SQUERY requests served.
    pub stream_queries: Counter,
    /// Wire bytes read from clients (line *and* binary transports).
    pub bytes_in: Counter,
    /// Wire bytes written to clients.
    pub bytes_out: Counter,
    /// Requests rejected by admission control: the global heavy-verb
    /// semaphore (`ERR busy` / BUSY frames) plus per-connection
    /// pipeline-window overflows.
    pub busy: Counter,
    /// Connections upgraded to binary framing via `HELLO 2`.
    pub hello_upgrades: Counter,
    /// BQUERY requests served.
    pub batch_queries: Counter,
    /// Total vertex ids answered across all BQUERY requests.
    pub batch_vertices: Counter,
    /// Verb handlers that panicked and were isolated by the dispatch
    /// `catch_unwind` (each also counts toward `errors` and the verb's
    /// `err/<verb>`). Any nonzero rate degrades HEALTH.
    pub panics: Counter,
    /// Requests that exceeded `CONTOUR_DEADLINE_MS` and were abandoned
    /// at a safe point (`ERR deadline`).
    pub deadlines: Counter,
    /// Process start, for `uptime_ms` and the `qps` gauge.
    started: Instant,
}

// Manual impl: `Instant` has no `Default`, and "now" is the only
// sensible start-of-life value anyway.
impl Default for Metrics {
    fn default() -> Self {
        Self {
            requests: Counter::default(),
            errors: Counter::default(),
            graphs_loaded: Counter::default(),
            cc_runs: Counter::default(),
            cc_millis: Counter::default(),
            cc_cache_hits: Counter::default(),
            cc_cache_misses: Counter::default(),
            shards_created: Counter::default(),
            pcc_runs: Counter::default(),
            pcc_millis: Counter::default(),
            streams_created: Counter::default(),
            stream_edges: Counter::default(),
            stream_deletes: Counter::default(),
            stream_epochs: Counter::default(),
            stream_queries: Counter::default(),
            bytes_in: Counter::default(),
            bytes_out: Counter::default(),
            busy: Counter::default(),
            hello_upgrades: Counter::default(),
            batch_queries: Counter::default(),
            batch_vertices: Counter::default(),
            panics: Counter::default(),
            deadlines: Counter::default(),
            started: Instant::now(),
        }
    }
}

impl Metrics {
    /// Milliseconds since the server process came up.
    pub fn uptime_ms(&self) -> u64 {
        self.started.elapsed().as_millis().min(u64::MAX as u128) as u64
    }

    /// Lifetime-average requests per second (see `render` for why this
    /// stays a coarse gauge; the telemetry ring owns windowed rates).
    pub fn qps(&self) -> f64 {
        self.requests.get() as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }

    /// Every counter under its METRICS wire key, in wire-render order.
    /// The single source the telemetry registry (METRICS/PROM/ring
    /// schema) consumes, so a counter added here is automatically
    /// scraped, sampled and exposed everywhere.
    pub fn counter_pairs(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("requests", self.requests.get()),
            ("errors", self.errors.get()),
            ("busy", self.busy.get()),
            ("bytes_in", self.bytes_in.get()),
            ("bytes_out", self.bytes_out.get()),
            ("hello_upgrades", self.hello_upgrades.get()),
            ("batch_queries", self.batch_queries.get()),
            ("batch_vertices", self.batch_vertices.get()),
            ("graphs_loaded", self.graphs_loaded.get()),
            ("cc_runs", self.cc_runs.get()),
            ("cc_millis", self.cc_millis.get()),
            ("cc_cache_hits", self.cc_cache_hits.get()),
            ("cc_cache_misses", self.cc_cache_misses.get()),
            ("shards", self.shards_created.get()),
            ("pcc_runs", self.pcc_runs.get()),
            ("pcc_millis", self.pcc_millis.get()),
            ("streams", self.streams_created.get()),
            ("stream_edges", self.stream_edges.get()),
            ("stream_deletes", self.stream_deletes.get()),
            ("stream_epochs", self.stream_epochs.get()),
            ("stream_queries", self.stream_queries.get()),
            ("panics", self.panics.get()),
            ("deadlines", self.deadlines.get()),
            ("faults_injected", crate::util::faults::injected_total()),
        ]
    }

    pub fn render(&self) -> String {
        // Worker-pool and frontier counters ride along so one METRICS
        // scrape covers the request layer, the parallel substrate and
        // the Contour execution engine under it. `frontier_passes` /
        // `frontier_skipped` cover both frontier engines;
        // `frontier_activations` / `frontier_exact` /
        // `frontier_full_sweeps` split out the exact engine's
        // store-site activations, its passes, and the chunk engine's
        // forced backstop sweeps (the exact engine never forces one).
        // `chunk_index_built` / `chunk_index_reused` meter the exact
        // engine's vertex→chunk index: reuse counts O(m) rebuilds a
        // shard's ChunkIndexCache avoided. `lat/pool_wait` /
        // `lat/pool_run` are log₂ histograms (count:p50:p95:p99, ns) of
        // job queue-wait and run time.
        let pool = crate::par::pool::stats();
        let frontier = crate::cc::contour::frontier_totals();
        let (idx_built, idx_reused) = crate::cc::contour::chunk_index_counters();
        // Lifetime-average QPS: requests over uptime. Coarse on purpose
        // (a gauge a scraper can sanity-check against its own rate
        // computation), not a windowed rate.
        let uptime = self.started.elapsed();
        let qps = self.requests.get() as f64 / uptime.as_secs_f64().max(1e-9);
        format!(
            "requests={} errors={} busy={} uptime_ms={} qps={qps:.1} bytes_in={} bytes_out={} \
             hello_upgrades={} batch_queries={} batch_vertices={} \
             graphs_loaded={} cc_runs={} cc_millis={} cc_cache_hits={} \
             cc_cache_misses={} shards={} pcc_runs={} pcc_millis={} \
             streams={} stream_edges={} stream_deletes={} stream_epochs={} stream_queries={} \
             panics={} deadlines={} faults_injected={} pool_workers={} \
             pool_jobs={} pool_pulls={} pool_steals={} pool_parks={} pool_wakes={} \
             pool_inflight={} pool_max_inflight={} pool_exec_peak={} pool_pins={} \
             pool_sticky_jobs={} pool_sticky_home={} pool_sticky_away={} \
             frontier_passes={} frontier_skipped={} frontier_activations={} \
             frontier_exact={} frontier_full_sweeps={} \
             chunk_index_built={idx_built} chunk_index_reused={idx_reused} \
             lat/pool_wait={} lat/pool_run={}",
            self.requests.get(),
            self.errors.get(),
            self.busy.get(),
            uptime.as_millis(),
            self.bytes_in.get(),
            self.bytes_out.get(),
            self.hello_upgrades.get(),
            self.batch_queries.get(),
            self.batch_vertices.get(),
            self.graphs_loaded.get(),
            self.cc_runs.get(),
            self.cc_millis.get(),
            self.cc_cache_hits.get(),
            self.cc_cache_misses.get(),
            self.shards_created.get(),
            self.pcc_runs.get(),
            self.pcc_millis.get(),
            self.streams_created.get(),
            self.stream_edges.get(),
            self.stream_deletes.get(),
            self.stream_epochs.get(),
            self.stream_queries.get(),
            self.panics.get(),
            self.deadlines.get(),
            crate::util::faults::injected_total(),
            pool.workers,
            pool.jobs,
            pool.pulls,
            pool.steals,
            pool.parks,
            pool.wakes,
            pool.inflight,
            pool.max_inflight,
            pool.exec_peak,
            pool.pins,
            pool.sticky_jobs,
            pool.sticky_home,
            pool.sticky_away,
            frontier.passes,
            frontier.skipped_chunks,
            frontier.activations,
            frontier.exact_passes,
            frontier.full_sweeps,
            pool.queue_wait.render(),
            pool.run_time.render()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_count() {
        let m = Metrics::default();
        m.requests.inc();
        m.requests.inc();
        m.cc_millis.add(120);
        assert_eq!(m.requests.get(), 2);
        assert!(m.render().contains("requests=2"));
        assert!(m.render().contains("cc_millis=120"));
        // Execution-engine counters are part of the scrape surface.
        assert!(m.render().contains("pool_pins="));
        assert!(m.render().contains("pool_sticky_jobs="));
        assert!(m.render().contains("frontier_passes="));
        assert!(m.render().contains("frontier_skipped="));
        assert!(m.render().contains("frontier_activations="));
        assert!(m.render().contains("frontier_exact="));
        assert!(m.render().contains("frontier_full_sweeps="));
        assert!(m.render().contains("chunk_index_built="));
        assert!(m.render().contains("chunk_index_reused="));
        // Serving-path counters are part of the scrape surface.
        assert!(m.render().contains("uptime_ms="));
        assert!(m.render().contains("qps="));
        assert!(m.render().contains("bytes_in=0"));
        assert!(m.render().contains("busy=0"));
        assert!(m.render().contains("batch_queries=0"));
        // Robustness counters are part of the scrape surface.
        assert!(m.render().contains("panics=0"));
        assert!(m.render().contains("deadlines=0"));
        assert!(m.render().contains("faults_injected="));
        // Pool latency histograms render as count:p50:p95:p99.
        let r = m.render();
        let wait = r
            .split_whitespace()
            .find_map(|t| t.strip_prefix("lat/pool_wait="))
            .expect("lat/pool_wait missing");
        assert_eq!(wait.split(':').count(), 4, "{wait}");
        assert!(r.contains("lat/pool_run="), "{r}");
    }

    #[test]
    fn counters_are_thread_safe() {
        let c = Counter::default();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }
}
