//! Small substrates the sandbox image lacks crates for: a deterministic
//! PRNG family (no `rand`), wall-clock timing helpers, and a leveled
//! stderr logger.

pub mod rng;
pub mod timer;

pub use rng::{SplitMix64, Xoshiro256};
pub use timer::Timer;

/// Log level, controlled by `CONTOUR_LOG` (error|warn|info|debug).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

fn log_level() -> Level {
    match std::env::var("CONTOUR_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    }
}

/// Leveled log to stderr; cheap enough for the coordinator, never used
/// inside per-edge hot loops.
pub fn log(level: Level, msg: std::fmt::Arguments) {
    if level <= log_level() {
        eprintln!("[contour:{:?}] {}", level, msg);
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Debug, format_args!($($t)*)) };
}

/// Human-readable engineering notation for counts (1.2K, 3.4M, ...).
pub fn human_count(x: u64) -> String {
    match x {
        0..=999 => format!("{x}"),
        1_000..=999_999 => format!("{:.1}K", x as f64 / 1e3),
        1_000_000..=999_999_999 => format!("{:.1}M", x as f64 / 1e6),
        _ => format!("{:.1}G", x as f64 / 1e9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(12_300), "12.3K");
        assert_eq!(human_count(2_500_000), "2.5M");
        assert_eq!(human_count(30_000_000_000), "30.0G");
    }

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Debug);
    }
}
