//! Small substrates the sandbox image lacks crates for: a deterministic
//! PRNG family (no `rand`), wall-clock timing helpers, a leveled stderr
//! logger, deterministic fault injection ([`faults`]), and cooperative
//! request deadlines ([`deadline`]).

pub mod crc;
pub mod deadline;
pub mod faults;
pub mod rng;
pub mod timer;

pub use rng::{SplitMix64, Xoshiro256};
pub use timer::Timer;

/// Log level, controlled by `CONTOUR_LOG` (error|warn|info|debug).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

fn log_level() -> Level {
    match std::env::var("CONTOUR_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    }
}

/// Leveled log to stderr; cheap enough for the coordinator, never used
/// inside per-edge hot loops.
pub fn log(level: Level, msg: std::fmt::Arguments) {
    if level <= log_level() {
        eprintln!("[contour:{:?}] {}", level, msg);
    }
}

#[macro_export]
macro_rules! info {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Info, format_args!($($t)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => { $crate::util::log($crate::util::Level::Debug, format_args!($($t)*)) };
}

/// Poison-tolerant read lock. With panic isolation (`catch_unwind`
/// around verb dispatch) a panicking request may poison shared locks;
/// state mutations under them are single-step map edits, so the data is
/// still coherent and the server must keep serving rather than cascade
/// the panic into every later `.unwrap()`.
pub fn rlock<T: ?Sized>(l: &std::sync::RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant write lock (see [`rlock`]).
pub fn wlock<T: ?Sized>(l: &std::sync::RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Poison-tolerant mutex lock (see [`rlock`]).
pub fn mlock<T: ?Sized>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Human-readable engineering notation for counts (1.2K, 3.4M, ...).
pub fn human_count(x: u64) -> String {
    match x {
        0..=999 => format!("{x}"),
        1_000..=999_999 => format!("{:.1}K", x as f64 / 1e3),
        1_000_000..=999_999_999 => format!("{:.1}M", x as f64 / 1e6),
        _ => format!("{:.1}G", x as f64 / 1e9),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_counts() {
        assert_eq!(human_count(999), "999");
        assert_eq!(human_count(12_300), "12.3K");
        assert_eq!(human_count(2_500_000), "2.5M");
        assert_eq!(human_count(30_000_000_000), "30.0G");
    }

    #[test]
    fn levels_order() {
        assert!(Level::Error < Level::Debug);
    }
}
