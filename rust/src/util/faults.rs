//! Deterministic fault injection: named failpoints with a cheap
//! always-compiled check.
//!
//! Production code marks interesting failure sites with a named point —
//! `faults::hit("wal.append")?` — which is a single relaxed atomic load
//! when no schedule is armed. A schedule arms points with an action and a
//! trigger:
//!
//! ```text
//! CONTOUR_FAULTS="wal.append=err@3;pool.job=panic@p0.01;conn.write=drop@5"
//! ```
//!
//! * action — `err` (site returns an error), `panic` (site panics; the
//!   dispatch layer is expected to isolate it), `drop` (site silently
//!   abandons the operation, e.g. closes the connection without a reply).
//! * trigger — `@N` fires exactly once, on the Nth hit of the point;
//!   `@pX` fires each hit with probability `X` from a per-point
//!   [`SplitMix64`] stream seeded by `CONTOUR_FAULTS_SEED` (so a schedule
//!   replays identically); no trigger fires on every hit.
//!
//! The schedule can also be swapped at runtime through the test-gated
//! `FAULTS` server verb (see `server::dispatch`). Injection counts are
//! kept per point for the lifetime of the process and surfaced as
//! `faults_injected/<point>` in the metrics registry.

use crate::util::SplitMix64;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, Once, OnceLock};

/// Environment variable holding the boot-time schedule.
pub const ENV_SPEC: &str = "CONTOUR_FAULTS";
/// Environment variable seeding probabilistic triggers (default `0x5EED`).
pub const ENV_SEED: &str = "CONTOUR_FAULTS_SEED";

/// What an armed failpoint does when its trigger fires.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Action {
    /// The call site returns an injected error.
    Err,
    /// The call site panics (exercises the panic-isolation layer).
    Panic,
    /// The call site abandons the operation without reporting failure.
    Drop,
}

impl Action {
    fn as_str(self) -> &'static str {
        match self {
            Action::Err => "err",
            Action::Panic => "panic",
            Action::Drop => "drop",
        }
    }
}

#[derive(Clone, Copy, Debug)]
enum Trigger {
    /// Fire exactly once, on the Nth hit (1-based).
    Nth(u64),
    /// Fire each hit with this probability, from a seeded per-point stream.
    Prob(f64),
    /// Fire on every hit.
    Always,
}

struct Point {
    action: Action,
    trigger: Trigger,
    hits: u64,
    rng: SplitMix64,
}

#[derive(Default)]
struct State {
    points: BTreeMap<String, Point>,
    /// Lifetime injection counts; survive `clear()` so metrics stay monotone.
    injected: BTreeMap<String, u64>,
}

/// Fast path: false ⇒ `fire()` is one relaxed load, no lock.
static ACTIVE: AtomicBool = AtomicBool::new(false);
/// Lifetime total across all points, for the telemetry ring and HEALTH.
static INJECTED_TOTAL: AtomicU64 = AtomicU64::new(0);
static ENV_INIT: Once = Once::new();

fn state() -> &'static Mutex<State> {
    static STATE: OnceLock<Mutex<State>> = OnceLock::new();
    STATE.get_or_init(|| Mutex::new(State::default()))
}

fn lock_state() -> std::sync::MutexGuard<'static, State> {
    // A panic action unwinds while the lock is *not* held (we release it
    // before panicking), but stay poison-tolerant anyway.
    state().lock().unwrap_or_else(|e| e.into_inner())
}

/// Load `CONTOUR_FAULTS` once, the first time any failpoint is evaluated.
fn ensure_env_loaded() {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var(ENV_SPEC) {
            if let Err(e) = configure(&spec) {
                eprintln!("[contour:Warn] ignoring bad {ENV_SPEC}: {e}");
            }
        }
    });
}

fn seed() -> u64 {
    std::env::var(ENV_SEED)
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x5EED)
}

fn point_seed(base: u64, name: &str) -> u64 {
    // Distinct deterministic stream per point: fold the name into the seed.
    name.bytes()
        .fold(base ^ 0x9E37_79B9_7F4A_7C15, |a, b| {
            a.wrapping_mul(0x100_0000_01B3) ^ b as u64
        })
}

fn parse_trigger(s: &str) -> Result<Trigger> {
    if s.is_empty() {
        return Ok(Trigger::Always);
    }
    if let Some(p) = s.strip_prefix('p') {
        let q: f64 = p.parse().with_context(|| format!("bad probability {s:?}"))?;
        if !(0.0..=1.0).contains(&q) {
            bail!("probability {q} outside [0,1]");
        }
        return Ok(Trigger::Prob(q));
    }
    let n: u64 = s.parse().with_context(|| format!("bad trigger {s:?}"))?;
    if n == 0 {
        bail!("trigger @0 never fires; use @1 for the first hit");
    }
    Ok(Trigger::Nth(n))
}

/// Install a schedule, replacing any previous one. Syntax:
/// `point=action[@trigger][;point=action[@trigger]]...` with `;` or `,`
/// separators; an empty spec clears the schedule.
pub fn configure(spec: &str) -> Result<()> {
    let base = seed();
    let mut points = BTreeMap::new();
    for part in spec.split([';', ',']) {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (name, rhs) = part
            .split_once('=')
            .with_context(|| format!("failpoint {part:?} missing '=action'"))?;
        let (action, trig) = match rhs.split_once('@') {
            Some((a, t)) => (a, t),
            None => (rhs, ""),
        };
        let action = match action {
            "err" => Action::Err,
            "panic" => Action::Panic,
            "drop" => Action::Drop,
            other => bail!("unknown fault action {other:?} (err|panic|drop)"),
        };
        let trigger = parse_trigger(trig)?;
        points.insert(
            name.to_string(),
            Point { action, trigger, hits: 0, rng: SplitMix64::new(point_seed(base, name)) },
        );
    }
    let active = !points.is_empty();
    let mut st = lock_state();
    st.points = points;
    drop(st);
    ACTIVE.store(active, Ordering::Relaxed);
    Ok(())
}

/// Disarm every failpoint (lifetime injection counts are kept).
pub fn clear() {
    let mut st = lock_state();
    st.points.clear();
    drop(st);
    ACTIVE.store(false, Ordering::Relaxed);
}

/// True if any failpoint is currently armed.
pub fn active() -> bool {
    ensure_env_loaded();
    ACTIVE.load(Ordering::Relaxed)
}

/// Evaluate a failpoint: count the hit and return the action to take if
/// the trigger fired. The common disarmed case is one relaxed load.
pub fn fire(point: &str) -> Option<Action> {
    ensure_env_loaded();
    if !ACTIVE.load(Ordering::Relaxed) {
        return None;
    }
    let mut st = lock_state();
    let p = st.points.get_mut(point)?;
    p.hits += 1;
    let fired = match p.trigger {
        Trigger::Always => true,
        Trigger::Nth(n) => p.hits == n,
        Trigger::Prob(q) => ((p.rng.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)) < q,
    };
    if !fired {
        return None;
    }
    let action = p.action;
    *st.injected.entry(point.to_string()).or_insert(0) += 1;
    drop(st);
    INJECTED_TOTAL.fetch_add(1, Ordering::Relaxed);
    Some(action)
}

/// Honor a failpoint at a fallible call site. `Err` becomes an error,
/// `Panic` panics in place, and `Drop` returns `Ok(true)` for the caller
/// to interpret (skip the write, close the connection, ...).
pub fn hit(point: &str) -> Result<bool> {
    match fire(point) {
        None => Ok(false),
        Some(Action::Err) => bail!("injected fault at {point}"),
        Some(Action::Panic) => panic!("injected fault at {point}"),
        Some(Action::Drop) => Ok(true),
    }
}

/// Same as [`hit`] but typed for `std::io` call sites.
pub fn hit_io(point: &str) -> std::io::Result<bool> {
    match fire(point) {
        None => Ok(false),
        Some(Action::Err) => Err(std::io::Error::other(format!("injected fault at {point}"))),
        Some(Action::Panic) => panic!("injected fault at {point}"),
        Some(Action::Drop) => Ok(true),
    }
}

/// Lifetime injection counts per point (points fired at least once).
pub fn injected_counts() -> Vec<(String, u64)> {
    ensure_env_loaded();
    lock_state().injected.iter().map(|(k, v)| (k.clone(), *v)).collect()
}

/// Lifetime total injections across all points.
pub fn injected_total() -> u64 {
    INJECTED_TOTAL.load(Ordering::Relaxed)
}

/// One line per armed point: `point action[@trigger] hits=H injected=I`.
pub fn describe() -> Vec<String> {
    ensure_env_loaded();
    let st = lock_state();
    st.points
        .iter()
        .map(|(name, p)| {
            let trig = match p.trigger {
                Trigger::Always => String::new(),
                Trigger::Nth(n) => format!("@{n}"),
                Trigger::Prob(q) => format!("@p{q}"),
            };
            let injected = st.injected.get(name).copied().unwrap_or(0);
            format!("{name} {}{trig} hits={} injected={injected}", p.action.as_str(), p.hits)
        })
        .collect()
}

/// The `FAULTS` server verb is test-gated: it only works when a schedule
/// was armed at boot or `CONTOUR_FAULTS_VERB=1` opts in explicitly.
pub fn verb_enabled() -> bool {
    std::env::var("CONTOUR_FAULTS_VERB").map(|v| v == "1").unwrap_or(false)
        || std::env::var(ENV_SPEC).is_ok()
}

/// Serialize tests that mutate the process-global schedule. Not part of
/// the public API; tests across modules share this one lock so parallel
/// test threads don't trample each other's schedules.
#[doc(hidden)]
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(())).lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The registry is process-global; serialize tests that mutate it.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        test_lock()
    }

    #[test]
    fn disarmed_is_noop() {
        let _g = guard();
        clear();
        assert_eq!(fire("nope"), None);
        assert!(!hit("nope").unwrap());
    }

    #[test]
    fn nth_trigger_fires_exactly_once() {
        let _g = guard();
        configure("w=err@3").unwrap();
        assert_eq!(fire("w"), None);
        assert_eq!(fire("w"), None);
        assert_eq!(fire("w"), Some(Action::Err));
        assert_eq!(fire("w"), None);
        clear();
    }

    #[test]
    fn always_trigger_and_unknown_point() {
        let _g = guard();
        configure("x=drop").unwrap();
        assert_eq!(fire("x"), Some(Action::Drop));
        assert_eq!(fire("x"), Some(Action::Drop));
        assert_eq!(fire("y"), None);
        clear();
    }

    #[test]
    fn probability_is_seed_deterministic() {
        let _g = guard();
        let run = || -> Vec<bool> {
            configure("p=err@p0.5").unwrap();
            let v = (0..64).map(|_| fire("p").is_some()).collect();
            clear();
            v
        };
        let a = run();
        let b = run();
        assert_eq!(a, b);
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "p0.5 over 64 draws: {a:?}");
    }

    #[test]
    fn hit_maps_actions() {
        let _g = guard();
        configure("e=err@1;d=drop@1").unwrap();
        let err = hit("e").unwrap_err().to_string();
        assert!(err.contains("injected fault at e"), "{err}");
        assert!(hit("d").unwrap());
        assert!(!hit("d").unwrap());
        clear();
    }

    #[test]
    fn bad_specs_rejected() {
        let _g = guard();
        assert!(configure("nope").is_err());
        assert!(configure("a=explode").is_err());
        assert!(configure("a=err@p2").is_err());
        assert!(configure("a=err@0").is_err());
        // A bad spec must not leave a half-armed schedule.
        assert_eq!(fire("a"), None);
    }

    #[test]
    fn counts_survive_clear() {
        let _g = guard();
        configure("c=err@1").unwrap();
        let before = injected_total();
        fire("c");
        clear();
        assert_eq!(injected_total(), before + 1);
        assert!(injected_counts().iter().any(|(k, n)| k == "c" && *n >= 1));
    }

    #[test]
    fn describe_lists_armed_points() {
        let _g = guard();
        configure("wal.append=err@3;pool.job=panic@p0.25").unwrap();
        let d = describe();
        assert_eq!(d.len(), 2);
        assert!(d.iter().any(|l| l.starts_with("wal.append err@3 ")), "{d:?}");
        assert!(d.iter().any(|l| l.starts_with("pool.job panic@p0.25 ")), "{d:?}");
        clear();
    }
}
