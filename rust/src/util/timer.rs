//! Wall-clock timing helper used by the bench harness and the coordinator.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Debug)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.ms() >= 1.0);
        let e = t.restart();
        assert!(e.as_millis() >= 1);
        assert!(t.ms() < e.as_secs_f64() * 1e3 + 100.0);
    }
}
