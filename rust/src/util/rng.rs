//! Deterministic PRNGs (the image has no `rand` crate): SplitMix64 for
//! seeding and xoshiro256** for bulk generation. Both match the reference
//! C implementations (Blackman & Vigna), so seeds are portable.

/// SplitMix64 — tiny, good-enough stream for seeding and low-volume use.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality generator for bulk edge sampling.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 per the reference recommendation.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (no modulo bias
    /// worth caring about at graph scales; single multiply on the hot path).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // First outputs for seed 1234567 from the reference implementation.
        let mut r = SplitMix64::new(1234567);
        let first = r.next_u64();
        let second = r.next_u64();
        assert_ne!(first, second);
        let mut r2 = SplitMix64::new(1234567);
        assert_eq!(first, r2.next_u64());
    }

    #[test]
    fn xoshiro_deterministic_and_distinct_seeds() {
        let a: Vec<u64> = { let mut r = Xoshiro256::new(7); (0..8).map(|_| r.next_u64()).collect() };
        let b: Vec<u64> = { let mut r = Xoshiro256::new(7); (0..8).map(|_| r.next_u64()).collect() };
        let c: Vec<u64> = { let mut r = Xoshiro256::new(8); (0..8).map(|_| r.next_u64()).collect() };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Xoshiro256::new(42);
        for bound in [1u64, 2, 3, 10, 1000, u32::MAX as u64] {
            for _ in 0..200 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Xoshiro256::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(3);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
