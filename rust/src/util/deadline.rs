//! Cooperative per-request deadlines.
//!
//! The dispatch layer arms a thread-local deadline before running a heavy
//! verb (`CONTOUR_DEADLINE_MS`); long-running loops call [`check`] at safe
//! points — between connectivity passes, between payload lines — where no
//! borrowed work is in flight on pool workers. An expired deadline panics
//! with a typed [`DeadlineExceeded`] payload that the dispatch
//! `catch_unwind` recognizes and turns into `ERR deadline ...` rather than
//! counting it as an internal panic.

use std::cell::Cell;
use std::time::{Duration, Instant};

/// Typed panic payload for an expired deadline; carries the configured
/// budget so the error message can report it.
#[derive(Clone, Copy, Debug)]
pub struct DeadlineExceeded {
    pub budget: Duration,
}

thread_local! {
    static DEADLINE: Cell<Option<(Instant, Duration)>> = const { Cell::new(None) };
}

/// Arm a deadline on this thread for the duration of the returned guard;
/// `None` disarms (the guard restores whatever was armed before).
pub fn arm(budget: Option<Duration>) -> Guard {
    let prev = DEADLINE.with(|d| d.replace(budget.map(|b| (Instant::now() + b, b))));
    Guard { prev }
}

/// Restores the previously armed deadline on drop.
pub struct Guard {
    prev: Option<(Instant, Duration)>,
}

impl Drop for Guard {
    fn drop(&mut self) {
        let prev = self.prev;
        DEADLINE.with(|d| d.set(prev));
    }
}

/// Panic with [`DeadlineExceeded`] if this thread's armed deadline has
/// passed. Call only at points where no borrowed work is in flight.
#[inline]
pub fn check() {
    if let Some((at, budget)) = DEADLINE.with(|d| d.get()) {
        if Instant::now() > at {
            std::panic::panic_any(DeadlineExceeded { budget });
        }
    }
}

/// True if a deadline is armed on this thread (cheap; for tests).
pub fn armed() -> bool {
    DEADLINE.with(|d| d.get().is_some())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unarmed_check_is_noop() {
        assert!(!armed());
        check();
    }

    #[test]
    fn guard_restores_previous() {
        let g1 = arm(Some(Duration::from_secs(60)));
        assert!(armed());
        {
            let g2 = arm(None);
            assert!(!armed());
            drop(g2);
        }
        assert!(armed());
        drop(g1);
        assert!(!armed());
    }

    #[test]
    fn expired_deadline_panics_with_typed_payload() {
        let g = arm(Some(Duration::ZERO));
        std::thread::sleep(Duration::from_millis(2));
        let caught = std::panic::catch_unwind(check).unwrap_err();
        let payload = caught.downcast_ref::<DeadlineExceeded>().expect("typed payload");
        assert_eq!(payload.budget, Duration::ZERO);
        drop(g);
    }
}
