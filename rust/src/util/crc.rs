//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven.
//!
//! Hand-rolled because the image has no checksum crates. Used by the WAL
//! v2 frame format and snapshot v2 trailer; matches zlib's `crc32()` so
//! files are checkable with standard tooling.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// One-shot CRC-32 of `data`.
pub fn crc32(data: &[u8]) -> u32 {
    update(!0u32, data) ^ !0u32
}

/// Streaming form: feed `state = update(state, chunk)` starting from
/// `!0u32`, finish with `state ^ !0u32`.
pub fn update(mut state: u32, data: &[u8]) -> u32 {
    for &b in data {
        state = TABLE[((state ^ b as u32) & 0xFF) as usize] ^ (state >> 8);
    }
    state
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_vectors() {
        // The canonical check value for CRC-32/ISO-HDLC.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn streaming_matches_one_shot() {
        let data = b"split across several update calls";
        let mut state = !0u32;
        for chunk in data.chunks(7) {
            state = update(state, chunk);
        }
        assert_eq!(state ^ !0u32, crc32(data));
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut data = vec![0u8; 64];
        let clean = crc32(&data);
        data[33] ^= 0x10;
        assert_ne!(crc32(&data), clean);
    }
}
