//! `contour` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   run       — one connectivity run on a file or generated graph
//!   batch     — drive a job batch through the coordinator
//!   bench     — regenerate the paper's tables/figures (table1, fig1..4,
//!               distsim, delaunay-scaling, pjrt, all)
//!   stats     — graph statistics (Table I row for one graph)
//!   list      — algorithms and artifacts available
//!
//! Examples:
//!   contour run --gen rmat:18:16 --alg C-2
//!   contour run --graph data/road.mtx --alg auto
//!   contour bench fig1 --out results
//!   contour bench all --quick --out results

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use contour::bench::{figures, serve};
use contour::cc::{self, Algorithm, RunContext};
use contour::cli::Args;
use contour::coordinator::{self, algorithm_by_name, Coordinator, Job};
use contour::graph::{gen, io, stats, Csr, EdgeList};
use contour::obs::RunTrace;
use contour::util::Timer;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("argument error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand() {
        Some("run") => cmd_run(args),
        Some("batch") => cmd_batch(args),
        Some("bench") => cmd_bench(args),
        Some("stats") => cmd_stats(args),
        Some("serve") => cmd_serve(args),
        Some("stream") => cmd_stream(args),
        Some("shard") => cmd_shard(args),
        Some("list") => cmd_list(),
        _ => {
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "contour — minimum-mapping connectivity (Contour algorithm reproduction)\n\n\
         usage:\n\
         \x20 contour run   [--graph FILE | --gen SPEC] [--alg NAME|auto] [--threads T] [--engine native|pjrt-step|pjrt-run]\n\
         \x20        [--frontier exact|chunk|off]  (default: CONTOUR_FRONTIER)\n\
         \x20        [--trace FILE]  (write the run's span timeline as Chrome trace JSON)\n\
         \x20 contour batch [--graph FILE | --gen SPEC] --algs A,B,C [--workers W]\n\
         \x20 contour bench TARGET [--quick] [--out DIR] [--threads T] [--baseline] [--trace FILE]\n\
         \x20        TARGET: table1 fig1 fig2 fig3 fig4 distsim delaunay-scaling pjrt hotpath serve all\n\
         \x20        (--baseline: hotpath/serve — rewrite ./BENCH_{{hotpath,serving}}.json; run from the repo root)\n\
         \x20        (--trace: afterwards run one traced RMAT pass and export its timeline)\n\
         \x20 contour stats [--graph FILE | --gen SPEC]\n\
         \x20 contour serve [--addr HOST:PORT] [--threads T] [--sample-ms MS] [--prom-addr HOST:PORT]\n\
         \x20        [--idle-ms MS] [--write-ms MS] [--deadline-ms MS]\n\
         \x20        (idle/write: per-connection socket budgets; deadline: heavy-verb compute\n\
         \x20        budget -> ERR deadline; defaults from CONTOUR_IDLE_MS/_WRITE_MS/_DEADLINE_MS)\n\
         \x20 contour stream [--graph FILE | --gen SPEC] [--batch B] [--epochs K]\n\
         \x20        [--wal PATH] [--snapshot PATH] [--threads T] [--verify]\n\
         \x20 contour shard [--graph FILE | --gen SPEC] [--alg NAME] [--shards 1,2,4,8]\n\
         \x20        [--balance vertices|edges] [--threads T] [--verify] [--trace FILE]\n\
         \x20 contour list\n\n\
         graph SPECs: path:N cycle:N star:N grid:R:C road:R:C tree:D comb:S:T\n\
         \x20            kmer:CHAINS:LEN er:N:M ba:N:K rmat:SCALE:EDGEFACTOR delaunay:N soup:P:S"
    );
}

/// Build a graph from `--graph FILE` or `--gen SPEC`.
fn load_graph(args: &Args) -> Result<(String, Csr)> {
    if let Some(file) = args.get("graph") {
        let e = io::read_auto(Path::new(file))?;
        return Ok((file.to_string(), e.into_csr()));
    }
    let spec = args.get("gen").unwrap_or("rmat:14:16");
    let parts: Vec<&str> = spec.split(':').collect();
    let num = |i: usize| -> Result<usize> {
        parts
            .get(i)
            .ok_or_else(|| anyhow!("spec {spec:?}: missing field {i}"))?
            .parse::<usize>()
            .with_context(|| format!("spec {spec:?} field {i}"))
    };
    let seed = 42u64;
    let e: EdgeList = match parts[0] {
        "path" => gen::path(num(1)?),
        "cycle" => gen::cycle(num(1)?),
        "star" => gen::star(num(1)?),
        "complete" => gen::complete(num(1)?),
        "grid" => gen::grid(num(1)?, num(2)?),
        "road" => gen::road(num(1)?, num(2)?, seed),
        "tree" => gen::binary_tree(num(1)? as u32),
        "comb" => gen::comb(num(1)?, num(2)?),
        "kmer" => gen::kmer_chains(num(1)?, num(2)?, seed),
        "er" => gen::erdos_renyi(num(1)?, num(2)?, seed),
        "ba" => gen::barabasi_albert(num(1)?, num(2)?, seed),
        "rmat" => gen::rmat(num(1)? as u32, num(2)? << num(1)?, gen::RmatKind::Graph500, seed),
        "delaunay" => gen::delaunay(num(1)?, seed),
        "soup" => gen::component_soup(num(1)?, num(2)?, seed),
        other => bail!("unknown generator {other:?} (see `contour` usage)"),
    };
    Ok((spec.to_string(), e.into_csr().shuffled_edges(seed)))
}

fn cmd_run(args: &Args) -> Result<()> {
    let threads = args.get_usize("threads", 0)?;
    let (name, g) = load_graph(args)?;
    let alg_name = args.get_or("alg", "C-2");
    let engine = args.get_or("engine", "native");
    // Only the canonical mode names here: FrontierMode::parse also
    // accepts the legacy boolean spellings ("true"/"1"/...), and a bare
    // `--frontier` flag reaches us as the value "true" — which must be
    // an error, not a silent fallback to the chunk engine.
    let frontier = match args.get("frontier") {
        None => None,
        Some(s) if matches!(s, "exact" | "chunk" | "off") => {
            contour::cc::contour::FrontierMode::parse(s)
        }
        Some(s) => bail!("--frontier expects exact|chunk|off, got {s:?}"),
    };
    println!("graph {name}: n={} m={}", g.n, g.m());
    // `--trace FILE`: record the run's span timeline and export it as
    // Chrome trace-event JSON (Perfetto / chrome://tracing).
    let trace_out = args.get("trace");
    let tr: Option<Arc<RunTrace>> = trace_out.map(|_| Arc::new(RunTrace::new()));
    let t = Timer::start();
    let result = match engine {
        "native" => {
            let alg: Box<dyn Algorithm + Send + Sync> = if alg_name == "auto" {
                let s = stats::stats(&g);
                let mut chosen = coordinator::auto_select(&s);
                if let Some(mode) = frontier {
                    chosen = chosen.with_frontier_mode(mode);
                }
                println!(
                    "auto-selected {} (diam~{} comps={})",
                    chosen.name(),
                    s.pseudo_diameter,
                    s.num_components
                );
                Box::new(chosen.with_threads(threads))
            } else {
                coordinator::algorithm_by_name_with(alg_name, threads, frontier)?
            };
            match &tr {
                Some(t) => {
                    let ctx = RunContext { trace: Some(Arc::clone(t)), ..Default::default() };
                    alg.run_ctx(&g, &ctx)
                }
                None => alg.run_with_stats(&g),
            }
        }
        "pjrt-step" | "pjrt-run" => {
            anyhow::ensure!(
                frontier.is_none(),
                "--frontier applies to the native engine only (the HLO loop is a full sweep)"
            );
            let rt = contour::runtime::Runtime::from_env()?;
            let mode = if engine == "pjrt-step" {
                coordinator::PjrtMode::PerIteration
            } else {
                coordinator::PjrtMode::FusedRun
            };
            let hops = args.get_usize("hops", 2)?;
            // The HLO loop has no per-pass hook; trace the device run
            // as one whole-run span so the export still has a timeline.
            let start = tr.as_ref().map(|t| t.now());
            let r = coordinator::PjrtContour::new(&rt, hops, mode).try_run(&g)?;
            if let (Some(t), Some(s)) = (tr.as_ref(), start) {
                let spargs = vec![("iterations", r.iterations as u64)];
                t.close(engine.to_string(), "cc", "", 0, s, spargs);
            }
            r
        }
        other => bail!("unknown engine {other:?}"),
    };
    let ms = t.ms();
    println!(
        "{}: {} components in {} iterations, {:.2} ms ({:.1} Medges/s)",
        alg_name,
        cc::num_components(&result.labels),
        result.iterations,
        ms,
        g.m() as f64 * result.iterations as f64 / ms / 1e3
    );
    if args.flag("verify") {
        cc::verify::assert_valid(&g, &result.labels, alg_name);
        println!("verification: OK");
    }
    if let (Some(path), Some(t)) = (trace_out, tr.as_ref()) {
        std::fs::write(path, t.to_chrome_json("contour run"))
            .with_context(|| format!("writing trace {path}"))?;
        println!("trace: {} spans -> {path} (load in Perfetto / chrome://tracing)", t.len());
    }
    Ok(())
}

fn cmd_batch(args: &Args) -> Result<()> {
    let (name, g) = load_graph(args)?;
    let algs = args.get_or("algs", "C-2,FastSV,ConnectIt");
    let jobs: Vec<Job> = algs
        .split(',')
        .enumerate()
        .map(|(id, a)| Job { id, algorithm: a.trim().to_string(), graph_name: name.clone() })
        .collect();
    let coord = Coordinator {
        workers: args.get_usize("workers", 1)?,
        algorithm_threads: args.get_usize("threads", 0)?,
    };
    let mut reports = coord.run_batch(jobs, |_| Some(&g))?;
    reports.sort_by_key(|r| r.id);
    println!("{:>10} {:>12} {:>10} {:>12}", "algorithm", "components", "iters", "ms");
    for r in reports {
        println!(
            "{:>10} {:>12} {:>10} {:>12.2}",
            r.algorithm, r.components, r.iterations, r.millis
        );
    }
    Ok(())
}

fn cmd_bench(args: &Args) -> Result<()> {
    let out = Path::new(args.get_or("out", "results")).to_path_buf();
    let quick = args.flag("quick");
    let threads = args.get_usize("threads", 0)?;
    let target = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let t = Timer::start();
    let mut run = |name: &str| -> Result<()> {
        println!("=== {name} ===");
        let text = match name {
            "table1" => figures::table1(&out, quick)?,
            "fig1" => figures::fig1(&out, quick, threads)?,
            "fig2" => figures::fig2(&out, quick, threads)?,
            "fig3" => figures::fig3(&out, quick, threads)?,
            "fig4" => figures::fig4(&out, quick, threads)?,
            "distsim" => figures::distsim_report(&out, quick)?,
            "delaunay-scaling" => figures::delaunay_scaling(&out, quick, threads)?,
            "pjrt" => figures::pjrt_report(&out)?,
            "hotpath" => figures::hotpath_json(&out, quick, threads)?,
            "serve" => serve::serving_json(&out, quick, threads)?,
            other => bail!("unknown bench target {other:?}"),
        };
        println!("{text}");
        Ok(())
    };
    if target == "all" {
        for name in
            ["table1", "fig1", "fig2", "fig3", "fig4", "delaunay-scaling", "distsim", "pjrt"]
        {
            run(name)?;
        }
    } else {
        run(target)?;
    }
    // `bench hotpath --baseline` refreshes the committed trajectory
    // baseline at ./BENCH_hotpath.json (run from the repo root; the
    // ROADMAP refresh item as one command instead of a manual copy).
    // Read-then-write instead of fs::copy: with `--out .` source and
    // destination are the same file, and copy's open-with-truncate
    // would zero the baseline before reading it.
    if matches!(target, "hotpath" | "serve") && args.flag("baseline") {
        let file = match target {
            "hotpath" => "BENCH_hotpath.json",
            _ => "BENCH_serving.json",
        };
        let src = out.join(file);
        let dst = Path::new(file);
        let bytes = std::fs::read(&src)
            .with_context(|| format!("reading bench output {}", src.display()))?;
        std::fs::write(dst, bytes)
            .with_context(|| format!("writing {}", dst.display()))?;
        println!("baseline refreshed: ./{file} <- {}", src.display());
    }
    // `--trace FILE`: after the targets, run one traced RMAT pass with
    // the exact frontier and export its timeline as Chrome trace-event
    // JSON — the artifact CI validates and uploads.
    if let Some(path) = args.get("trace") {
        let scale: u32 = if quick { 14 } else { 16 };
        let g = gen::rmat(scale, 16usize << scale, gen::RmatKind::Graph500, 42)
            .into_csr()
            .shuffled_edges(42);
        let alg = coordinator::algorithm_by_name_with(
            "C-2",
            threads,
            Some(contour::cc::contour::FrontierMode::Exact),
        )?;
        let r = alg.run_traced(&g);
        let trace = r.trace.as_ref().expect("run_traced always attaches a trace");
        std::fs::write(path, trace.to_chrome_json("contour bench"))
            .with_context(|| format!("writing trace {path}"))?;
        println!("trace: rmat:{scale} C-2/exact, {} spans -> {path}", trace.len());
    }
    println!("bench done in {:.1}s; outputs in {}", t.secs(), out.display());
    Ok(())
}

fn cmd_stats(args: &Args) -> Result<()> {
    let (name, g) = load_graph(args)?;
    let s = stats::stats(&g);
    println!("graph {name}");
    println!("  vertices          {}", s.n);
    println!("  edges             {}", s.m);
    println!("  max degree        {}", s.max_degree);
    println!("  avg degree        {:.2}", s.avg_degree);
    println!("  components        {}", s.num_components);
    println!("  largest component {}", s.largest_component);
    println!("  pseudo-diameter   {}", s.pseudo_diameter);
    println!("  isolated vertices {}", s.isolated_vertices);
    Ok(())
}

/// The Arkouda/Arachne-style interactive server (§III-A): Python (or any
/// line-protocol client) sends graph + `graph_cc` requests, the Rust back
/// end computes. See python/client/contour_client.py.
fn cmd_serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7021").to_string();
    let threads = args.get_usize("threads", 0)?;
    let sample_ms = args.get_usize("sample-ms", 0)? as u64;
    // Robustness budgets (0 = keep the CONTOUR_*_MS env default, which
    // itself defaults to unbounded).
    let idle_ms = args.get_usize("idle-ms", 0)? as u64;
    let write_ms = args.get_usize("write-ms", 0)? as u64;
    let deadline_ms = args.get_usize("deadline-ms", 0)? as u64;
    let mut state = contour::server::ServerState::new(threads).with_sample_interval(sample_ms);
    if idle_ms > 0 || write_ms > 0 || deadline_ms > 0 {
        let pick = |flag: u64, cur: Option<std::time::Duration>| {
            if flag > 0 { flag } else { cur.map_or(0, |d| d.as_millis() as u64) }
        };
        state = state.with_timeouts(
            pick(idle_ms, state.idle()),
            pick(write_ms, state.write_timeout()),
            pick(deadline_ms, state.deadline()),
        );
    }
    let state = std::sync::Arc::new(state);
    let shutdown = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    // Bind before announcing: with `--addr host:0` the OS assigns the
    // port, and the printed address is the one clients can reach.
    let listener = std::net::TcpListener::bind(&addr)?;
    println!("contour server on {} (Ctrl-C to stop)", listener.local_addr()?);
    // Optional plain-HTTP Prometheus scrape endpoint, on its own
    // listener so scrapers never mix with the verb protocol.
    if let Some(prom) = args.get("prom-addr") {
        let prom_listener = std::net::TcpListener::bind(prom)?;
        println!("prometheus scrape endpoint on {}", prom_listener.local_addr()?);
        let state = std::sync::Arc::clone(&state);
        let shutdown = std::sync::Arc::clone(&shutdown);
        std::thread::spawn(move || {
            if let Err(e) = contour::server::serve_prom_listener(prom_listener, state, shutdown) {
                eprintln!("prom endpoint error: {e}");
            }
        });
    }
    contour::server::serve_listener(listener, state, shutdown)
}

/// Streaming-connectivity driver: replays a graph's edges as a live
/// batched stream through [`contour::stream::StreamingCc`], sealing
/// epochs (re-contour compaction + snapshot publish) along the way,
/// optionally WAL-backed, and finally cross-checks the streamed labels
/// against a static C-2 run on the same graph.
fn cmd_stream(args: &Args) -> Result<()> {
    let threads = args.get_usize("threads", 0)?;
    let batch = args.get_usize("batch", 4096)?.max(1);
    let epochs = args.get_usize("epochs", 8)?.max(1);
    let (name, g) = load_graph(args)?;
    println!("streaming {name}: n={} m={} (batch={batch}, {epochs} epochs)", g.n, g.m());
    let wal = args.get("wal").map(std::path::PathBuf::from);
    let s = contour::stream::StreamingCc::open(g.n, threads, wal.as_deref())?;
    if s.epoch() > 0 {
        println!("recovered from WAL: epoch {} with {} edges", s.epoch(), s.edges_ingested());
    }
    let edges: Vec<_> = g.edges().collect();
    let per_epoch = (edges.len() / epochs).max(1);
    let total = Timer::start();
    let mut t = Timer::start();
    let mut since_seal = 0usize;
    for chunk in edges.chunks(batch) {
        s.add_edges(chunk)?;
        since_seal += chunk.len();
        if since_seal >= per_epoch {
            since_seal = 0;
            let snap = s.seal_epoch()?;
            println!(
                "  epoch {:>3}: {:>10} edges in, {:>9} components  ({:>8.1} ms)",
                snap.epoch,
                snap.edges_ingested,
                snap.num_components,
                t.restart().as_secs_f64() * 1e3,
            );
        }
    }
    let fin = s.seal_epoch()?;
    println!(
        "final epoch {}: {} components over {} streamed edges in {:.1} ms total",
        fin.epoch,
        fin.num_components,
        fin.edges_ingested,
        total.ms()
    );
    if let Some(p) = args.get("snapshot") {
        let e = s.save_snapshot(Path::new(p))?;
        println!("snapshot of epoch {e} saved to {p}");
    }
    if args.flag("verify") {
        let want = contour::cc::contour::Contour::c2().with_threads(threads).run(&g);
        anyhow::ensure!(
            fin.labels == want,
            "streamed labels diverge from static Contour C-2"
        );
        println!("verification: streamed labels == static C-2 labels");
    }
    Ok(())
}

/// Sharded-connectivity driver: partition the graph across a sweep of
/// shard counts, run shard-local connectivity concurrently (one pool
/// job per shard) plus the boundary-contraction merge, and optionally
/// cross-check every result against the single-shard run.
fn cmd_shard(args: &Args) -> Result<()> {
    let threads = args.get_usize("threads", 0)?;
    let (name, g) = load_graph(args)?;
    let alg_name = args.get_or("alg", "C-2");
    let alg = algorithm_by_name(alg_name, threads)?;
    let balance_name = args.get_or("balance", "vertices");
    let balance = contour::shard::Balance::parse(balance_name)
        .ok_or_else(|| anyhow!("--balance expects `vertices` or `edges`, got {balance_name:?}"))?;
    println!(
        "graph {name}: n={} m={} (alg {alg_name}, {} fences)",
        g.n,
        g.m(),
        balance.as_str()
    );
    // `--trace FILE`: one shared timeline across the whole shard-count
    // sweep — each run's pcc/merge spans land on the driver track, each
    // shard's passes on its own track.
    let trace_out = args.get("trace");
    let tr: Option<Arc<RunTrace>> = trace_out.map(|_| Arc::new(RunTrace::new()));
    let t = Timer::start();
    let single = alg.run_with_stats(&g);
    let single_ms = t.ms();
    println!(
        "single-shard: {} components in {} iterations, {:.2} ms",
        cc::num_components(&single.labels),
        single.iterations,
        single_ms
    );
    println!(
        "{:>6} {:>10} {:>10} {:>8} {:>10} {:>10} {:>8}",
        "shards", "boundary", "comps", "iters", "part_ms", "run_ms", "speedup"
    );
    for tok in args.get_or("shards", "1,2,4,8").split(',') {
        let p: usize = tok
            .trim()
            .parse()
            .map_err(|_| anyhow!("--shards expects a comma list of integers, got {tok:?}"))?;
        let t = Timer::start();
        let sg = contour::shard::ShardedGraph::partition_with(&g, p, balance);
        let part_ms = t.ms();
        let t = Timer::start();
        let r = contour::shard::run_sharded_ctx(&sg, alg.as_ref(), threads, tr.as_ref());
        let run_ms = t.ms();
        println!(
            "{:>6} {:>10} {:>10} {:>8} {:>10.2} {:>10.2} {:>7.2}x",
            sg.p(),
            r.boundary_edges,
            cc::num_components(&r.labels),
            r.iterations,
            part_ms,
            run_ms,
            single_ms / run_ms.max(1e-9)
        );
        if args.flag("verify") {
            anyhow::ensure!(
                r.labels == single.labels,
                "sharded labels diverge from single-shard {alg_name} at p={p}"
            );
        }
    }
    if args.flag("verify") {
        println!("verification: sharded labels identical to single-shard for every shard count");
    }
    if let (Some(path), Some(t)) = (trace_out, tr.as_ref()) {
        std::fs::write(path, t.to_chrome_json("contour shard"))
            .with_context(|| format!("writing trace {path}"))?;
        println!("trace: {} spans -> {path} (load in Perfetto / chrome://tracing)", t.len());
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("algorithms:");
    for name in coordinator::ALGORITHM_NAMES {
        println!("  {name}");
    }
    match contour::runtime::Runtime::from_env() {
        Ok(rt) => {
            println!("\nPJRT platform: {}", rt.platform());
            println!("artifacts:");
            for a in rt.registry().iter() {
                println!("  {} (n={}, m={})", a.name, a.n, a.m);
            }
        }
        Err(e) => println!("\nPJRT runtime unavailable: {e}"),
    }
    Ok(())
}
