//! FastSV (Zhang, Azad & Hu, SIAM PP 2020) — the state-of-the-art
//! large-scale parallel baseline the paper compares against in Figs. 1–3.
//!
//! Per iteration, with parent array `f` and grandparent `gf = f[f]`:
//!   1. *stochastic hooking*:  f_next[f[u]] min= gf[v]  (both directions)
//!   2. *aggressive hooking*:  f_next[u]    min= gf[v]  (both directions)
//!   3. *shortcutting*:        f_next[u]    min= gf[u]
//! then `f = f_next`, repeating until no label changes. The explicit
//! synchronization between phases and the `f = f_next` copy are exactly
//! the costs §III-C argues Contour's minimum-mapping operator avoids.

use super::{Algorithm, AtomicLabels, RunResult};
use crate::graph::Csr;
use crate::par;
use crate::VId;

#[derive(Clone, Debug, Default)]
pub struct FastSv {
    /// Worker threads (0 = default).
    pub threads: usize,
}

impl FastSv {
    pub fn new() -> Self {
        Self { threads: 0 }
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }
}

impl Algorithm for FastSv {
    fn name(&self) -> String {
        "FastSV".into()
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let n = g.n;
        let t = self.threads;
        let f = AtomicLabels::identity(n);
        let fnext = AtomicLabels::identity(n);
        let mut gf: Vec<VId> = (0..n as VId).collect();
        let mut iters = 0usize;
        loop {
            iters += 1;
            // gf = f[f] (parallel gather).
            {
                let fr = &f;
                let slots = par::SyncSlice::new(&mut gf);
                par::par_for(n, t, par::AUTO_GRAIN, |range| {
                    for v in range {
                        // SAFETY: disjoint ranges.
                        unsafe { slots.write(v, fr.load(fr.load(v as VId))) };
                    }
                });
            }
            let gf_ref = &gf;
            // Phases 1+2 fused over the edge list (all are min-scatters
            // into f_next; fusing them keeps one edge sweep per iteration).
            let src = &g.src;
            let dst = &g.dst;
            let fr = &f;
            let fx = &fnext;
            par::par_for(g.m(), t, par::AUTO_GRAIN, |range| {
                for e in range {
                    let (u, v) = (src[e], dst[e]);
                    let gfu = gf_ref[u as usize];
                    let gfv = gf_ref[v as usize];
                    // stochastic hooking
                    fx.store_min_cas(fr.load(u), gfv);
                    fx.store_min_cas(fr.load(v), gfu);
                    // aggressive hooking
                    fx.store_min_cas(u, gfv);
                    fx.store_min_cas(v, gfu);
                }
            });
            // Phase 3: shortcutting + change detection + f = f_next.
            let changed = par::par_map_reduce(
                n,
                t,
                par::AUTO_GRAIN,
                || false,
                |acc, range| {
                    for v in range {
                        let v = v as VId;
                        fx.store_min_cas(v, gf_ref[v as usize]);
                        let nv = fx.load(v);
                        if nv != fr.load(v) {
                            *acc = true;
                        }
                    }
                },
                |a, b| a || b,
            );
            f.copy_from(&fnext);
            if !changed {
                break;
            }
        }
        RunResult::new(f.to_vec(), iters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{ground_truth, Algorithm};
    use crate::graph::gen;

    #[test]
    fn correct_on_suite() {
        for e in [
            gen::path(100),
            gen::star(64),
            gen::grid(8, 8),
            gen::component_soup(6, 20, 1),
            gen::erdos_renyi(300, 500, 2),
            gen::rmat(10, 4000, gen::RmatKind::Graph500, 3),
        ] {
            let g = e.into_csr();
            let got = FastSv::new().run(&g);
            assert_eq!(got, ground_truth(&g));
        }
    }

    #[test]
    fn logarithmic_iterations_on_path() {
        // SV-family convergence is O(log n) on a path, not O(n).
        let g = gen::path(4096).into_csr();
        let r = FastSv::new().run_with_stats(&g);
        assert!(r.iterations <= 30, "iters {}", r.iterations);
        assert!(r.iterations >= 5);
    }

    #[test]
    fn single_iteration_on_trivial() {
        let g = crate::graph::EdgeList::new(8).into_csr();
        let r = FastSv::new().run_with_stats(&g);
        assert_eq!(r.iterations, 1);
        assert_eq!(r.labels, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_across_thread_counts() {
        let g = gen::barabasi_albert(2000, 3, 4).into_csr();
        let a = FastSv::new().with_threads(1).run(&g);
        let b = FastSv::new().with_threads(8).run(&g);
        assert_eq!(a, b);
    }
}
