//! A ConnectIt-style composable connectivity framework.
//!
//! Dhulipala, Hong & Shun's ConnectIt (the comparator of §IV-F) is not a
//! single algorithm but a *design space*: `sampling strategy × find
//! variant × unite variant`, yielding hundreds of combinations, of which
//! Rem's-with-splicing was the winner the paper benchmarks. This module
//! reproduces that framework shape so the ablation benches can sweep the
//! space like ConnectIt does:
//!
//! * **Sampling** (first phase, cheap, discovers the giant component):
//!   none / k-out (first k neighbors, as in Afforest) / BFS seed.
//! * **Find** (compression inside unite): naive root-chasing /
//!   path-halving / full path-splitting.
//! * **Unite**: Rem-CAS splicing / atomic hook-to-min.
//!
//! Every combination links toward smaller ids, so labels are min-id
//! canonical and directly comparable to the other algorithms.

use std::sync::atomic::{AtomicU32, Ordering};

use super::{Algorithm, RunResult};
use crate::graph::Csr;
use crate::par;
use crate::util::Xoshiro256;
use crate::VId;

/// First-phase sampling strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sampling {
    /// Process every edge in the unite phase (no sampling).
    None,
    /// Afforest-style: unite each vertex with its first k neighbors,
    /// then skip the discovered giant component's internal edges.
    KOut(usize),
    /// BFS from a few random seeds marks a candidate giant component.
    BfsSeed { seeds: usize },
}

/// Find/compression variant used inside unite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Find {
    /// Chase parents without writing.
    Naive,
    /// Path halving: every other node repointed to its grandparent.
    Halve,
    /// Path splitting: every node on the path repointed.
    Split,
}

/// Unite variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unite {
    /// Rem's algorithm with CAS splicing (ConnectIt's overall winner).
    RemCas,
    /// Find both roots, CAS-hook the larger root under the smaller.
    HookMin,
}

/// A point in the ConnectIt design space.
#[derive(Clone, Debug)]
pub struct ConnectItVariant {
    pub sampling: Sampling,
    pub find: Find,
    pub unite: Unite,
    pub threads: usize,
    pub seed: u64,
}

impl Default for ConnectItVariant {
    fn default() -> Self {
        // The configuration the paper benchmarks as "ConnectIt".
        Self { sampling: Sampling::None, find: Find::Split, unite: Unite::RemCas, threads: 0, seed: 0xC011 }
    }
}

impl ConnectItVariant {
    /// All combinations for the ablation sweep.
    pub fn design_space() -> Vec<ConnectItVariant> {
        let mut v = Vec::new();
        for sampling in [Sampling::None, Sampling::KOut(2), Sampling::BfsSeed { seeds: 4 }] {
            for find in [Find::Naive, Find::Halve, Find::Split] {
                for unite in [Unite::RemCas, Unite::HookMin] {
                    v.push(ConnectItVariant { sampling, find, unite, ..Default::default() });
                }
            }
        }
        v
    }

    pub fn short_name(&self) -> String {
        let s = match self.sampling {
            Sampling::None => "none",
            Sampling::KOut(k) => return format!("kout{k}-{:?}-{:?}", self.find, self.unite).to_lowercase(),
            Sampling::BfsSeed { .. } => "bfs",
        };
        format!("{s}-{:?}-{:?}", self.find, self.unite).to_lowercase()
    }

    #[inline]
    fn find_root(&self, p: &[AtomicU32], mut x: VId) -> VId {
        match self.find {
            Find::Naive => loop {
                let px = p[x as usize].load(Ordering::Relaxed);
                if px == x {
                    return x;
                }
                x = px;
            },
            Find::Halve => loop {
                let px = p[x as usize].load(Ordering::Relaxed);
                if px == x {
                    return x;
                }
                let ppx = p[px as usize].load(Ordering::Relaxed);
                let _ =
                    p[x as usize].compare_exchange(px, ppx, Ordering::Relaxed, Ordering::Relaxed);
                x = px;
            },
            Find::Split => {
                // First pass: find the root; second: repoint the path.
                let mut r = x;
                loop {
                    let pr = p[r as usize].load(Ordering::Relaxed);
                    if pr == r {
                        break;
                    }
                    r = pr;
                }
                while x != r {
                    let px = p[x as usize].load(Ordering::Relaxed);
                    if px == x {
                        break;
                    }
                    // Only lower pointers (keeps the decreasing invariant
                    // under races).
                    if r < px {
                        let _ = p[x as usize].compare_exchange(
                            px,
                            r,
                            Ordering::Relaxed,
                            Ordering::Relaxed,
                        );
                    }
                    x = px;
                }
                r
            }
        }
    }

    #[inline]
    fn unite(&self, p: &[AtomicU32], u: VId, v: VId) {
        match self.unite {
            Unite::RemCas => super::unionfind::RemConcurrent::unite(p, u, v),
            Unite::HookMin => loop {
                let ru = self.find_root(p, u);
                let rv = self.find_root(p, v);
                if ru == rv {
                    return;
                }
                let (hi, lo) = if ru > rv { (ru, rv) } else { (rv, ru) };
                if p[hi as usize]
                    .compare_exchange(hi, lo, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
                {
                    return;
                }
                // Root moved under us; retry with fresh roots.
            },
        }
    }
}

impl Algorithm for ConnectItVariant {
    fn name(&self) -> String {
        format!("ConnectIt[{}]", self.short_name())
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let n = g.n;
        let t = self.threads;
        let p: Vec<AtomicU32> = (0..n as u32).map(AtomicU32::new).collect();
        let pr = &p;
        // ---- Sampling phase: cheaply connect most of the giant component.
        let giant = match self.sampling {
            Sampling::None => None,
            Sampling::KOut(k) => {
                for round in 0..k {
                    par::par_for(n, t, par::AUTO_GRAIN, |range| {
                        for v in range {
                            if let Some(&w) = g.neighbors(v as VId).get(round) {
                                self.unite(pr, v as VId, w);
                            }
                        }
                    });
                }
                self.sample_giant(pr, n)
            }
            Sampling::BfsSeed { seeds } => {
                let mut rng = Xoshiro256::new(self.seed);
                for _ in 0..seeds {
                    let root = rng.below(n.max(1) as u64) as VId;
                    // Bounded BFS: unite a frontier neighborhood.
                    let mut frontier = vec![root];
                    for _ in 0..3 {
                        let mut next = Vec::new();
                        for &v in &frontier {
                            for &w in g.neighbors(v) {
                                self.unite(pr, v, w);
                                next.push(w);
                            }
                        }
                        frontier = next;
                        if frontier.len() > n / 4 {
                            break;
                        }
                    }
                }
                self.sample_giant(pr, n)
            }
        };
        // ---- Finish phase: remaining edges (skipping the giant's own).
        let src = &g.src;
        let dst = &g.dst;
        par::par_for(g.m(), t, par::AUTO_GRAIN, |range| {
            for e in range {
                let (u, v) = (src[e], dst[e]);
                if let Some(c) = giant {
                    if self.find_root(pr, u) == c && self.find_root(pr, v) == c {
                        continue;
                    }
                }
                self.unite(pr, u, v);
            }
        });
        // ---- Flatten to stars.
        par::par_for(n, t, par::AUTO_GRAIN, |range| {
            for v in range {
                let r = self.find_root(pr, v as VId);
                pr[v].store(r, Ordering::Relaxed);
            }
        });
        RunResult::new(p.into_iter().map(|x| x.into_inner()).collect(), 1)
    }
}

impl ConnectItVariant {
    /// Sample vertices to guess the most frequent (giant) root.
    fn sample_giant(&self, p: &[AtomicU32], n: usize) -> Option<VId> {
        if n == 0 {
            return None;
        }
        let mut rng = Xoshiro256::new(self.seed ^ 0x5A);
        let mut counts = std::collections::HashMap::<VId, usize>::new();
        for _ in 0..512.min(n) {
            let v = rng.below(n as u64) as VId;
            *counts.entry(self.find_root(p, v)).or_insert(0) += 1;
        }
        counts.into_iter().max_by_key(|&(_, c)| c).map(|(r, _)| r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{ground_truth, Algorithm};
    use crate::graph::gen;

    fn suite() -> Vec<Csr> {
        vec![
            gen::path(400).into_csr().shuffled_edges(1),
            gen::star(300).into_csr(),
            gen::component_soup(8, 40, 2).into_csr(),
            gen::rmat(11, 8_000, gen::RmatKind::Graph500, 3).into_csr(),
            gen::delaunay(700, 4).into_csr(),
        ]
    }

    /// Sweep the entire design space (18 combinations) on every family.
    #[test]
    fn whole_design_space_is_correct() {
        for g in suite() {
            let want = ground_truth(&g);
            for variant in ConnectItVariant::design_space() {
                let got = variant.run(&g);
                assert_eq!(got, want, "{} on n={} m={}", variant.name(), g.n, g.m());
            }
        }
    }

    #[test]
    fn default_is_rem_splicing() {
        let v = ConnectItVariant::default();
        assert_eq!(v.unite, Unite::RemCas);
        assert_eq!(v.run_with_stats(&gen::path(50).into_csr()).iterations, 1);
    }

    #[test]
    fn design_space_has_expected_size() {
        assert_eq!(ConnectItVariant::design_space().len(), 3 * 3 * 2);
        // Names must be unique.
        let names: std::collections::HashSet<String> =
            ConnectItVariant::design_space().iter().map(|v| v.short_name()).collect();
        assert_eq!(names.len(), 18);
    }

    #[test]
    fn sampling_skips_giant_but_stays_correct() {
        // One giant component plus satellites — the case sampling helps.
        let mut e = gen::barabasi_albert(3_000, 3, 7);
        let base = e.n;
        e.n += 100;
        for i in 0..99u32 {
            e.push(base as VId + i, base as VId + i + 1);
        }
        let g = e.into_csr();
        let want = ground_truth(&g);
        for sampling in [Sampling::KOut(2), Sampling::BfsSeed { seeds: 4 }] {
            let v = ConnectItVariant { sampling, ..Default::default() };
            assert_eq!(v.run(&g), want, "{:?}", sampling);
        }
    }

    #[test]
    fn concurrent_correctness_under_threads() {
        let g = gen::erdos_renyi(5_000, 9_000, 5).into_csr();
        let want = ground_truth(&g);
        for t in [2usize, 8] {
            let v = ConnectItVariant { threads: t, ..Default::default() };
            assert_eq!(v.run(&g), want, "threads {t}");
        }
    }
}
