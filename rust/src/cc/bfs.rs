//! BFS-based connected components — the graph-traversal baseline of §I,
//! in sequential and frontier-parallel forms.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, Ordering};

use super::{Algorithm, RunResult};
use crate::graph::Csr;
use crate::par;
use crate::VId;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BfsMode {
    Sequential,
    /// Level-synchronous frontier parallelism within each component.
    Parallel,
}

#[derive(Clone, Debug)]
pub struct BfsCc {
    pub mode: BfsMode,
    pub threads: usize,
}

impl BfsCc {
    pub fn sequential() -> Self {
        Self { mode: BfsMode::Sequential, threads: 0 }
    }

    pub fn parallel() -> Self {
        Self { mode: BfsMode::Parallel, threads: 0 }
    }

    fn run_sequential(&self, g: &Csr) -> (Vec<VId>, usize) {
        let n = g.n;
        let mut labels = vec![VId::MAX; n];
        let mut q = VecDeque::new();
        let mut rounds = 0usize;
        for v in 0..n {
            if labels[v] != VId::MAX {
                continue;
            }
            // v is the smallest unvisited vertex => component minimum.
            labels[v] = v as VId;
            q.push_back(v as VId);
            while let Some(u) = q.pop_front() {
                rounds += 1;
                for &w in g.neighbors(u) {
                    if labels[w as usize] == VId::MAX {
                        labels[w as usize] = v as VId;
                        q.push_back(w);
                    }
                }
            }
        }
        (labels, rounds)
    }

    fn run_parallel(&self, g: &Csr) -> (Vec<VId>, usize) {
        let n = g.n;
        let t = self.threads;
        let labels: Vec<AtomicU32> = (0..n).map(|_| AtomicU32::new(VId::MAX)).collect();
        let mut max_depth = 0usize;
        for v in 0..n {
            if labels[v].load(Ordering::Relaxed) != VId::MAX {
                continue;
            }
            let root = v as VId;
            labels[v].store(root, Ordering::Relaxed);
            let mut frontier = vec![root];
            let mut depth = 0usize;
            while !frontier.is_empty() {
                depth += 1;
                let lr = &labels;
                let fr = &frontier;
                // Expand the frontier in parallel; claim via CAS so each
                // vertex joins the next frontier exactly once.
                let next = par::par_map_reduce(
                    fr.len(),
                    t,
                    64,
                    Vec::new,
                    |acc: &mut Vec<VId>, range| {
                        for i in range {
                            for &w in g.neighbors(fr[i]) {
                                if lr[w as usize]
                                    .compare_exchange(
                                        VId::MAX,
                                        root,
                                        Ordering::Relaxed,
                                        Ordering::Relaxed,
                                    )
                                    .is_ok()
                                {
                                    acc.push(w);
                                }
                            }
                        }
                    },
                    |mut a, mut b| {
                        a.append(&mut b);
                        a
                    },
                );
                frontier = next;
            }
            max_depth = max_depth.max(depth);
        }
        (labels.into_iter().map(|x| x.into_inner()).collect(), max_depth)
    }
}

impl Algorithm for BfsCc {
    fn name(&self) -> String {
        match self.mode {
            BfsMode::Sequential => "BFS-seq".into(),
            BfsMode::Parallel => "BFS-par".into(),
        }
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let (labels, rounds) = match self.mode {
            BfsMode::Sequential => self.run_sequential(g),
            BfsMode::Parallel => self.run_parallel(g),
        };
        RunResult::new(labels, rounds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::same_partition;
    use crate::graph::gen;

    #[test]
    fn sequential_labels_are_component_minima() {
        let g = gen::component_soup(4, 10, 2).into_csr();
        let labels = BfsCc::sequential().run(&g);
        for (v, &l) in labels.iter().enumerate() {
            assert!(l <= v as VId);
            assert_eq!(labels[l as usize], l, "label must be its own root");
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        for e in [
            gen::path(300),
            gen::grid(20, 20),
            gen::erdos_renyi(500, 800, 4),
            gen::rmat(10, 3000, gen::RmatKind::Graph500, 5),
        ] {
            let g = e.into_csr();
            let a = BfsCc::sequential().run(&g);
            let b = BfsCc::parallel().run(&g);
            assert_eq!(a, b);
            assert!(same_partition(&a, &b));
        }
    }

    #[test]
    fn isolated_vertices_self_labelled() {
        let g = crate::graph::EdgeList::new(5).into_csr();
        assert_eq!(BfsCc::sequential().run(&g), vec![0, 1, 2, 3, 4]);
        assert_eq!(BfsCc::parallel().run(&g), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn parallel_depth_close_to_diameter() {
        let g = gen::path(100).into_csr();
        let r = BfsCc::parallel().run_with_stats(&g);
        assert!(r.iterations >= 99, "depth {} < diameter", r.iterations);
    }
}
