//! Result verification: every algorithm's output is checked against a
//! BFS ground truth and against the structural invariants a min-id
//! component labelling must satisfy.

use super::{ground_truth, Labels};
use crate::graph::Csr;
use crate::VId;

/// A violation found by [`check_labels`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Violation {
    /// `labels.len() != g.n`.
    WrongLength { expected: usize, got: usize },
    /// `labels[v] > v` for the component minimum, or label out of range.
    NotMinId { vertex: VId, label: VId },
    /// A label that is not itself a root (`labels[l] != l`).
    DanglingLabel { vertex: VId, label: VId },
    /// Edge endpoints with different labels.
    EdgeSplit { u: VId, v: VId, lu: VId, lv: VId },
    /// Two vertices labelled together that BFS says are separate.
    OverMerged { u: VId, v: VId },
}

/// Full structural + ground-truth check. Returns all violations (empty =
/// valid). O(n + m) plus one BFS sweep.
pub fn check_labels(g: &Csr, labels: &Labels) -> Vec<Violation> {
    let mut out = Vec::new();
    if labels.len() != g.n {
        out.push(Violation::WrongLength { expected: g.n, got: labels.len() });
        return out;
    }
    for (v, &l) in labels.iter().enumerate() {
        if (l as usize) >= g.n {
            out.push(Violation::NotMinId { vertex: v as VId, label: l });
        } else if labels[l as usize] != l {
            out.push(Violation::DanglingLabel { vertex: v as VId, label: l });
        }
        if out.len() > 16 {
            return out; // enough evidence
        }
    }
    // No edge may cross label classes (under-merge check).
    for (u, v) in g.edges() {
        if labels[u as usize] != labels[v as usize] {
            out.push(Violation::EdgeSplit {
                u,
                v,
                lu: labels[u as usize],
                lv: labels[v as usize],
            });
            if out.len() > 16 {
                return out;
            }
        }
    }
    // Exact match with BFS ground truth (catches over-merge + non-min ids).
    let truth = ground_truth(g);
    for v in 0..g.n {
        if labels[v] != truth[v] {
            // Distinguish over-merge from a non-canonical representative.
            if truth[labels[v] as usize] != truth[v] {
                out.push(Violation::OverMerged { u: v as VId, v: labels[v] });
            } else {
                out.push(Violation::NotMinId { vertex: v as VId, label: labels[v] });
            }
            if out.len() > 16 {
                return out;
            }
        }
    }
    out
}

/// Panic with diagnostics unless `labels` is a valid min-id labelling.
pub fn assert_valid(g: &Csr, labels: &Labels, who: &str) {
    let violations = check_labels(g, labels);
    assert!(
        violations.is_empty(),
        "{who}: invalid labelling, first violations: {:?}",
        &violations[..violations.len().min(5)]
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn accepts_ground_truth() {
        let g = gen::component_soup(5, 20, 1).into_csr();
        let labels = ground_truth(&g);
        assert!(check_labels(&g, &labels).is_empty());
    }

    #[test]
    fn catches_wrong_length() {
        let g = gen::path(5).into_csr();
        let v = check_labels(&g, &vec![0, 0, 0]);
        assert!(matches!(v[0], Violation::WrongLength { .. }));
    }

    #[test]
    fn catches_under_merge() {
        let g = gen::path(4).into_csr();
        // Splitting the path in half leaves edge (1,2) crossing classes.
        let v = check_labels(&g, &vec![0, 0, 2, 2]);
        assert!(v.iter().any(|x| matches!(x, Violation::EdgeSplit { u: 1, v: 2, .. })));
    }

    #[test]
    fn catches_over_merge() {
        // Two separate edges labelled as one component.
        let g = crate::graph::EdgeList::from_pairs(4, &[(0, 1), (2, 3)]).into_csr();
        let v = check_labels(&g, &vec![0, 0, 0, 0]);
        assert!(v.iter().any(|x| matches!(x, Violation::OverMerged { .. })), "{v:?}");
    }

    #[test]
    fn catches_dangling_label() {
        let g = gen::path(3).into_csr();
        // 2 -> 1 but 1 -> 0: label 1 is not a root.
        let v = check_labels(&g, &vec![0, 0, 1]);
        assert!(v.iter().any(|x| matches!(x, Violation::DanglingLabel { .. })));
    }
}
