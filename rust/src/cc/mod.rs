//! Connected-components algorithms.
//!
//! Everything the paper evaluates, behind one [`Algorithm`] trait:
//!
//! * [`contour`] — the paper's contribution: minimum-mapping Contour with
//!   the six variants of §III-B.4 (C-1, C-2, C-m, C-Syn, C-11mm, C-1m1m)
//!   and the §III-B optimizations (async updates, early convergence
//!   check, atomic-free writes) as independent switches.
//! * [`fastsv`] — FastSV (Zhang, Azad & Hu 2020), the large-scale
//!   parallel baseline of Figs. 1–3.
//! * [`sv`] — classic Shiloach–Vishkin hooking + shortcutting.
//! * [`unionfind`] — Rem's algorithm with splicing, sequential and
//!   concurrent (the ConnectIt winner the paper compares against).
//! * [`bfs`], [`labelprop`] — the traversal-based baselines of §I.
//! * [`afforest`] — Afforest subgraph sampling (related-work extension).
//!
//! Labels converge to the **minimum vertex id** of each component for
//! every algorithm here, so outputs are directly comparable.

pub mod afforest;
pub mod bfs;
pub mod connectit;
pub mod contour;
pub mod fastsv;
pub mod incremental;
pub mod labelprop;
pub mod sv;
pub mod unionfind;
pub mod verify;

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Arc;

use crate::graph::Csr;
use crate::obs::RunTrace;
use crate::VId;

/// Component labels: `labels[v]` = min vertex id in v's component.
pub type Labels = Vec<VId>;

/// Per-run accounting of the Contour execution engine's frontier
/// (zeroed for algorithms and modes that never consult dirty bits).
/// Carried on [`RunResult`] so tests and callers can assert on one
/// run's behavior without racing the process-wide `METRICS` counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FrontierStats {
    /// Partial (dirty-chunks-only) passes, chunk and exact mode alike.
    pub passes: u64,
    /// Chunks those passes skipped as clean.
    pub skipped_chunks: u64,
    /// Stores that marked chunks dirty through the vertex→chunk
    /// activation map (exact mode).
    pub activations: u64,
    /// Exact-activation passes (a subset of `passes`).
    pub exact_passes: u64,
    /// Forced full sweeps — the chunk engine's periodic correctness
    /// backstop. The exact engine concludes convergence from an empty
    /// dirty set and never forces one, so this stays 0 there.
    pub full_sweeps: u64,
}

/// Outcome of one connectivity run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub labels: Labels,
    /// Iterations to convergence, counted the way the paper's Fig. 1
    /// counts (union-find algorithms report 1).
    pub iterations: usize,
    /// Execution-engine accounting for this run (see [`FrontierStats`]).
    pub frontier: FrontierStats,
    /// Span timeline for this run, present iff the caller asked for one
    /// (see [`Algorithm::run_ctx`]). Shared so the shard executor can
    /// merge many runs onto one timeline.
    pub trace: Option<Arc<RunTrace>>,
    /// Heap accounting for this run — peak/net bytes and alloc/free
    /// counts — present iff the crate was built with the `alloc-track`
    /// feature (see [`crate::obs::MemScope`]). Approximate under
    /// concurrent runs: the peak watermark is process-global.
    pub mem: Option<crate::obs::MemStats>,
}

impl RunResult {
    /// Result with no frontier accounting (every non-Contour algorithm,
    /// and Contour runs with the frontier off).
    pub fn new(labels: Labels, iterations: usize) -> Self {
        Self { labels, iterations, frontier: FrontierStats::default(), trace: None, mem: None }
    }
}

/// Per-run execution context: observability and cache hooks that ride
/// alongside the graph without widening every algorithm signature.
/// `RunContext::default()` means "no tracing, no caches" and is what
/// [`Algorithm::run_with_stats`] uses.
#[derive(Clone, Default)]
pub struct RunContext<'a> {
    /// Span recorder shared by every layer of this run; `None` disables
    /// tracing (the hot path then pays one branch per pass, not more).
    pub trace: Option<Arc<RunTrace>>,
    /// Logical track the run's spans land on (0 = driver; the shard
    /// executor gives each shard its own track).
    pub tid: u32,
    /// Reusable vertex→chunk index for the exact frontier (see
    /// [`contour::ChunkIndexCache`]); `None` builds per run.
    pub chunk_index_cache: Option<&'a contour::ChunkIndexCache>,
}

impl RunContext<'_> {
    /// A context with a fresh trace attached and nothing else.
    pub fn traced() -> Self {
        Self { trace: Some(Arc::new(RunTrace::new())), ..Self::default() }
    }
}

/// A connectivity algorithm. `run_ctx` is the canonical entry;
/// `run_with_stats` and `run` are convenience wrappers.
pub trait Algorithm {
    /// Display name matching the paper's figure legends (e.g. "C-2").
    fn name(&self) -> String;

    fn run_with_stats(&self, g: &Csr) -> RunResult;

    /// Run with an execution context. The default implementation wraps
    /// [`Self::run_with_stats`] in a single whole-run span, so every
    /// algorithm is traceable; engines with finer structure (Contour's
    /// pass loop) override this to emit per-pass spans.
    fn run_ctx(&self, g: &Csr, ctx: &RunContext<'_>) -> RunResult {
        let mem = crate::obs::MemScope::start();
        let Some(tr) = ctx.trace.as_deref() else {
            let mut r = self.run_with_stats(g);
            r.mem = mem.finish();
            return r;
        };
        let start = tr.now();
        let mut r = self.run_with_stats(g);
        r.mem = mem.finish();
        let mut args = vec![("iterations", r.iterations as u64)];
        if let Some(m) = &r.mem {
            args.push(("peak_bytes", m.peak_bytes));
        }
        tr.close(self.name(), "cc", "", ctx.tid, start, args);
        r.trace = ctx.trace.clone();
        r
    }

    /// Run with a fresh trace; the returned `RunResult::trace` holds
    /// the recorded timeline.
    fn run_traced(&self, g: &Csr) -> RunResult {
        self.run_ctx(g, &RunContext::traced())
    }

    fn run(&self, g: &Csr) -> Labels {
        self.run_with_stats(g).labels
    }
}

/// Number of components = number of self-labelled roots.
pub fn num_components(labels: &Labels) -> usize {
    labels.iter().enumerate().filter(|&(i, &l)| i as VId == l).count()
}

/// Canonicalize an arbitrary component labelling to min-vertex-id form
/// (used to compare algorithms whose raw labels differ).
pub fn canonicalize(labels: &Labels) -> Labels {
    let n = labels.len();
    let mut min_of = vec![VId::MAX; n];
    for (v, &l) in labels.iter().enumerate() {
        let slot = &mut min_of[l as usize];
        *slot = (*slot).min(v as VId);
    }
    labels.iter().map(|&l| min_of[l as usize]).collect()
}

/// True iff two labellings induce the same partition of vertices.
pub fn same_partition(a: &Labels, b: &Labels) -> bool {
    a.len() == b.len() && canonicalize(a) == canonicalize(b)
}

/// Label array shared across workers. Relaxed atomics: the paper's
/// Chapel implementation races plain writes on purpose (§III-B.3 —
/// affects iteration count, never correctness); in Rust the same
/// "don't-care race" is expressed as relaxed load/store, and the
/// guaranteed-minimum path as `fetch_min`.
pub struct AtomicLabels(Vec<AtomicU32>);

impl AtomicLabels {
    pub fn identity(n: usize) -> Self {
        Self((0..n as VId).map(AtomicU32::new).collect())
    }

    pub fn from_labels(labels: &[VId]) -> Self {
        Self(labels.iter().map(|&l| AtomicU32::new(l)).collect())
    }

    pub fn len(&self) -> usize {
        self.0.len()
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }

    #[inline]
    pub fn load(&self, i: VId) -> VId {
        self.0[i as usize].load(Ordering::Relaxed)
    }

    /// Plain (racy-by-design) conditional store: the paper's
    /// "eliminating atomic operations" optimization.
    #[inline]
    pub fn store_min_plain(&self, i: VId, val: VId) -> bool {
        if self.load(i) > val {
            self.0[i as usize].store(val, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// Guaranteed minimum via hardware atomic (the CAS loop of Eq. 4).
    #[inline]
    pub fn store_min_cas(&self, i: VId, val: VId) -> bool {
        self.0[i as usize].fetch_min(val, Ordering::Relaxed) > val
    }

    pub fn copy_from(&self, other: &AtomicLabels) {
        for (dst, src) in self.0.iter().zip(other.0.iter()) {
            dst.store(src.load(Ordering::Relaxed), Ordering::Relaxed);
        }
    }

    pub fn to_vec(&self) -> Labels {
        self.0.iter().map(|x| x.load(Ordering::Relaxed)).collect()
    }
}

/// Ground truth for tests: sequential BFS labelling (min-id form).
pub fn ground_truth(g: &Csr) -> Labels {
    bfs::BfsCc::sequential().run(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonicalize_remaps_to_min() {
        // Partition {0,2}, {1,3} labelled by arbitrary representatives.
        let raw = vec![2, 3, 2, 3];
        assert_eq!(canonicalize(&raw), vec![0, 1, 0, 1]);
    }

    #[test]
    fn same_partition_ignores_representative_choice() {
        let a = vec![0, 0, 2, 2];
        let b = vec![1, 1, 3, 3];
        let c = vec![0, 0, 0, 2];
        assert!(same_partition(&a, &b));
        assert!(!same_partition(&a, &c));
        assert!(!same_partition(&a, &vec![0, 0, 2]));
    }

    #[test]
    fn num_components_counts_roots() {
        assert_eq!(num_components(&vec![0, 0, 2, 2, 4]), 3);
        assert_eq!(num_components(&vec![0, 1, 2]), 3);
    }

    #[test]
    fn atomic_labels_min_ops() {
        let l = AtomicLabels::identity(4);
        assert!(l.store_min_plain(3, 1));
        assert!(!l.store_min_plain(3, 2)); // already 1
        assert!(l.store_min_cas(2, 0));
        assert!(!l.store_min_cas(2, 0));
        assert_eq!(l.to_vec(), vec![0, 1, 0, 1]);
    }
}
