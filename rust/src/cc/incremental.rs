//! Incremental connectivity — ConnectIt's second mode ("a framework for
//! static and *incremental* parallel graph connectivity", §III-C): edges
//! arrive online, connectivity queries interleave with insertions.
//!
//! Backed by the same lock-free Rem-CAS union-find as the static path,
//! so concurrent `add_edge` calls from the coordinator's workers are
//! safe, and queries are wait-free root comparisons.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

use super::unionfind::RemConcurrent;
use crate::graph::Csr;
use crate::par;
use crate::VId;

/// An online connectivity index over a fixed vertex universe.
pub struct IncrementalCc {
    parent: Vec<AtomicU32>,
    edges_added: AtomicUsize,
}

impl IncrementalCc {
    pub fn new(n: usize) -> Self {
        Self {
            parent: (0..n as u32).map(AtomicU32::new).collect(),
            edges_added: AtomicUsize::new(0),
        }
    }

    /// Seed from an existing graph (bulk static phase, parallel).
    pub fn from_graph(g: &Csr, threads: usize) -> Self {
        let idx = Self::new(g.n);
        let src = &g.src;
        let dst = &g.dst;
        let p = &idx.parent;
        par::par_for(g.m(), threads, par::AUTO_GRAIN, |range| {
            for e in range {
                RemConcurrent::unite(p, src[e], dst[e]);
            }
        });
        idx.edges_added.store(g.m(), Ordering::Relaxed);
        idx
    }

    /// Rebuild an index from a canonical min-id labelling (streaming
    /// snapshot recovery): every vertex is parented directly on its
    /// component minimum, which respects Rem's link-to-smaller invariant.
    pub fn from_labels(labels: &[VId]) -> Self {
        let parent: Vec<AtomicU32> = labels
            .iter()
            .enumerate()
            .map(|(v, &l)| {
                assert!(
                    (l as usize) <= v && labels[l as usize] == l,
                    "labels not canonical at vertex {v}"
                );
                AtomicU32::new(l)
            })
            .collect();
        Self { parent, edges_added: AtomicUsize::new(0) }
    }

    pub fn n(&self) -> usize {
        self.parent.len()
    }

    /// Snapshot the union-find forest as `(child, parent)` edges — the
    /// input the streaming layer's re-contour compaction runs the
    /// Contour operator over. Concurrent `add_edge` calls may or may not
    /// be captured (parent pointers only ever move toward smaller roots
    /// within a component, so any interleaving yields a valid forest of
    /// the edges inserted so far).
    pub fn forest_edges(&self, threads: usize) -> Vec<(VId, VId)> {
        let p = &self.parent;
        par::par_map_reduce(
            self.n(),
            threads,
            par::AUTO_GRAIN,
            Vec::new,
            |acc: &mut Vec<(VId, VId)>, range| {
                for v in range {
                    let pv = p[v].load(Ordering::Relaxed);
                    if pv != v as VId {
                        acc.push((v as VId, pv));
                    }
                }
            },
            |mut a, b| {
                a.extend(b);
                a
            },
        )
    }

    pub fn edges_added(&self) -> usize {
        self.edges_added.load(Ordering::Relaxed)
    }

    /// Overwrite the forest in place with a canonical min-id labelling —
    /// the decremental fixup: a union-find can only merge, so after a
    /// delete epoch recomputes the true partition, the streaming layer
    /// stores the new labels straight into the parent array. Parenting
    /// every vertex on its component minimum respects Rem's
    /// link-to-smaller invariant, so subsequent concurrent `add_edge`
    /// calls behave exactly as on a freshly built index.
    ///
    /// Callers must hold off concurrent mutators (the streaming layer
    /// does this under its ingestion gate's write side); concurrent
    /// readers would observe a torn mix of old and new partitions.
    pub fn store_labels(&self, labels: &[VId], threads: usize) {
        assert_eq!(labels.len(), self.n(), "labelling must cover the universe");
        let p = &self.parent;
        par::par_for(self.n(), threads, par::AUTO_GRAIN, |range| {
            for v in range {
                let l = labels[v];
                assert!(
                    (l as usize) <= v && labels[l as usize] == l,
                    "labels not canonical at vertex {v}"
                );
                p[v].store(l, Ordering::Relaxed);
            }
        });
    }

    /// Insert an edge (thread-safe; concurrent calls race benignly).
    pub fn add_edge(&self, u: VId, v: VId) {
        assert!((u as usize) < self.n() && (v as usize) < self.n());
        RemConcurrent::unite(&self.parent, u, v);
        self.edges_added.fetch_add(1, Ordering::Relaxed);
    }

    /// Root of `v` with path halving (wait-free progress under races).
    pub fn find(&self, mut v: VId) -> VId {
        loop {
            let p = self.parent[v as usize].load(Ordering::Relaxed);
            if p == v {
                return v;
            }
            let gp = self.parent[p as usize].load(Ordering::Relaxed);
            let _ = self.parent[v as usize].compare_exchange(
                p,
                gp,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            v = p;
        }
    }

    /// Are `u` and `v` currently connected?
    pub fn connected(&self, u: VId, v: VId) -> bool {
        // Standard concurrent-UF query loop: re-check when roots move.
        loop {
            let ru = self.find(u);
            let rv = self.find(v);
            if ru == rv {
                return true;
            }
            // Roots are stable if still self-parented.
            if self.parent[ru as usize].load(Ordering::Relaxed) == ru {
                return false;
            }
        }
    }

    /// Snapshot the current min-id labelling (parallel flatten + relabel).
    pub fn labels(&self, threads: usize) -> Vec<VId> {
        let n = self.n();
        let mut out = vec![0 as VId; n];
        {
            let slots = par::SyncSlice::new(&mut out);
            par::par_for(n, threads, par::AUTO_GRAIN, |range| {
                for v in range {
                    // SAFETY: disjoint ranges.
                    unsafe { slots.write(v, self.find(v as VId)) };
                }
            });
        }
        // Rem links toward smaller ids, so roots are component minima.
        out
    }

    pub fn num_components(&self) -> usize {
        (0..self.n() as VId).filter(|&v| self.find(v) == v).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc;
    use crate::graph::gen;

    #[test]
    fn online_insertions_and_queries() {
        let idx = IncrementalCc::new(6);
        assert_eq!(idx.num_components(), 6);
        assert!(!idx.connected(0, 1));
        idx.add_edge(0, 1);
        idx.add_edge(2, 3);
        assert!(idx.connected(0, 1));
        assert!(!idx.connected(1, 2));
        idx.add_edge(1, 2);
        assert!(idx.connected(0, 3));
        assert_eq!(idx.num_components(), 3); // {0..3}, {4}, {5}
        assert_eq!(idx.labels(1), vec![0, 0, 0, 0, 4, 5]);
        assert_eq!(idx.edges_added(), 3);
    }

    #[test]
    fn bulk_seed_matches_static_algorithms() {
        let g = gen::rmat(11, 6_000, gen::RmatKind::Graph500, 3).into_csr();
        let idx = IncrementalCc::from_graph(&g, 0);
        assert_eq!(idx.labels(0), cc::ground_truth(&g));
    }

    #[test]
    fn incremental_equals_batch_at_every_prefix() {
        let g = gen::erdos_renyi(300, 450, 7).into_csr();
        let idx = IncrementalCc::new(g.n);
        let edges: Vec<_> = g.edges().collect();
        for (k, &(u, v)) in edges.iter().enumerate() {
            idx.add_edge(u, v);
            if k % 90 == 0 || k + 1 == edges.len() {
                // Rebuild a static baseline from the prefix.
                let prefix =
                    crate::graph::EdgeList::from_pairs(g.n, &edges[..=k]).into_csr();
                assert_eq!(idx.labels(1), cc::ground_truth(&prefix), "prefix {k}");
            }
        }
    }

    #[test]
    fn concurrent_insertions() {
        let n = 10_000usize;
        let idx = IncrementalCc::new(n);
        // 8 threads insert interleaved path edges: the final structure is
        // one path => one component.
        std::thread::scope(|s| {
            for t in 0..8usize {
                let idx = &idx;
                s.spawn(move || {
                    let mut i = t;
                    while i + 1 < n {
                        idx.add_edge(i as VId, (i + 1) as VId);
                        i += 8;
                    }
                });
            }
        });
        assert_eq!(idx.num_components(), 1);
        assert!(idx.connected(0, (n - 1) as VId));
    }

    #[test]
    #[should_panic]
    fn out_of_range_panics() {
        IncrementalCc::new(3).add_edge(0, 9);
    }

    #[test]
    fn from_labels_round_trips_through_forest() {
        let g = gen::component_soup(7, 25, 4).into_csr();
        let idx = IncrementalCc::from_graph(&g, 1);
        let labels = idx.labels(1);
        // Rebuild from the labelling: same partition, flat forest.
        let rebuilt = IncrementalCc::from_labels(&labels);
        assert_eq!(rebuilt.labels(1), labels);
        // forest_edges links every non-root to its parent: one edge per
        // non-root vertex, and re-uniting them reproduces the partition.
        let forest = rebuilt.forest_edges(1);
        assert_eq!(forest.len(), g.n - cc::num_components(&labels));
        let again = IncrementalCc::new(g.n);
        for (u, v) in forest {
            again.add_edge(u, v);
        }
        assert_eq!(again.labels(1), labels);
    }

    #[test]
    #[should_panic]
    fn from_labels_rejects_non_canonical() {
        // 1 is not a root (labels[1] = 2 > 1 violates min-id form).
        IncrementalCc::from_labels(&[0, 2, 2]);
    }

    #[test]
    fn store_labels_rebuilds_the_partition_in_place() {
        let idx = IncrementalCc::new(6);
        idx.add_edge(0, 1);
        idx.add_edge(1, 2);
        idx.add_edge(3, 4);
        assert_eq!(idx.labels(1), vec![0, 0, 0, 3, 3, 5]);
        // Simulate a delete epoch splitting {0,1,2} into {0,1} and {2}:
        // the recomputed canonical labelling is stored straight in.
        idx.store_labels(&[0, 0, 2, 3, 3, 5], 1);
        assert_eq!(idx.labels(1), vec![0, 0, 2, 3, 3, 5]);
        assert_eq!(idx.num_components(), 4);
        assert!(!idx.connected(0, 2));
        // The flattened forest stays a valid Rem structure: new unions
        // keep working, and forest_edges reflects the stored partition.
        assert_eq!(idx.forest_edges(1).len(), 2);
        idx.add_edge(2, 5);
        assert!(idx.connected(2, 5));
        assert_eq!(idx.labels(1), vec![0, 0, 2, 3, 3, 2]);
    }

    #[test]
    #[should_panic]
    fn store_labels_rejects_non_canonical() {
        IncrementalCc::new(3).store_labels(&[0, 2, 2], 1);
    }

    /// Concurrent `add_edge` from multiple writer threads interleaved
    /// with `connected` queries from reader threads. Two checks: (a) the
    /// final structure matches a static union-find ground truth, and
    /// (b) connectivity is monotone — any pair a reader observed as
    /// connected mid-stream must be connected in the final graph.
    #[test]
    fn concurrent_insertions_interleaved_with_queries() {
        use crate::cc::unionfind::RemSequential;
        use crate::cc::Algorithm;
        use crate::util::SplitMix64;

        let g = gen::erdos_renyi(4_000, 8_000, 11).into_csr();
        let edges: Vec<(VId, VId)> = g.edges().collect();
        let idx = IncrementalCc::new(g.n);
        let done = std::sync::atomic::AtomicBool::new(false);
        let mut observed = Vec::new();
        std::thread::scope(|s| {
            let readers: Vec<_> = (0..3u64)
                .map(|r| {
                    let idx = &idx;
                    let done = &done;
                    let n = g.n as u64;
                    s.spawn(move || {
                        let mut rng = SplitMix64::new(100 + r);
                        let mut positives = Vec::new();
                        while !done.load(Ordering::Relaxed) {
                            let u = (rng.next_u64() % n) as VId;
                            let v = (rng.next_u64() % n) as VId;
                            if idx.connected(u, v) {
                                positives.push((u, v));
                            }
                        }
                        positives
                    })
                })
                .collect();
            std::thread::scope(|w| {
                for t in 0..4usize {
                    let idx = &idx;
                    let edges = &edges;
                    w.spawn(move || {
                        for (u, v) in edges.iter().skip(t).step_by(4) {
                            idx.add_edge(*u, *v);
                        }
                    });
                }
            });
            done.store(true, Ordering::Relaxed);
            for h in readers {
                observed.extend(h.join().unwrap());
            }
        });
        // (a) final structure == static union-find ground truth.
        let want = RemSequential.run(&g);
        assert_eq!(idx.labels(1), want);
        assert_eq!(idx.edges_added(), edges.len());
        // (b) mid-stream positives still hold in the final graph.
        for (u, v) in observed {
            assert_eq!(
                want[u as usize], want[v as usize],
                "reader saw {u}~{v} connected but the final graph disagrees"
            );
        }
    }
}
