//! Incremental connectivity — ConnectIt's second mode ("a framework for
//! static and *incremental* parallel graph connectivity", §III-C): edges
//! arrive online, connectivity queries interleave with insertions.
//!
//! Backed by the same lock-free Rem-CAS union-find as the static path,
//! so concurrent `add_edge` calls from the coordinator's workers are
//! safe, and queries are wait-free root comparisons.

use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};

use super::unionfind::RemConcurrent;
use crate::graph::Csr;
use crate::par;
use crate::VId;

/// An online connectivity index over a fixed vertex universe.
pub struct IncrementalCc {
    parent: Vec<AtomicU32>,
    edges_added: AtomicUsize,
}

impl IncrementalCc {
    pub fn new(n: usize) -> Self {
        Self {
            parent: (0..n as u32).map(AtomicU32::new).collect(),
            edges_added: AtomicUsize::new(0),
        }
    }

    /// Seed from an existing graph (bulk static phase, parallel).
    pub fn from_graph(g: &Csr, threads: usize) -> Self {
        let idx = Self::new(g.n);
        let src = &g.src;
        let dst = &g.dst;
        let p = &idx.parent;
        par::par_for(g.m(), threads, par::DEFAULT_GRAIN, |range| {
            for e in range {
                RemConcurrent::unite(p, src[e], dst[e]);
            }
        });
        idx.edges_added.store(g.m(), Ordering::Relaxed);
        idx
    }

    pub fn n(&self) -> usize {
        self.parent.len()
    }

    pub fn edges_added(&self) -> usize {
        self.edges_added.load(Ordering::Relaxed)
    }

    /// Insert an edge (thread-safe; concurrent calls race benignly).
    pub fn add_edge(&self, u: VId, v: VId) {
        assert!((u as usize) < self.n() && (v as usize) < self.n());
        RemConcurrent::unite(&self.parent, u, v);
        self.edges_added.fetch_add(1, Ordering::Relaxed);
    }

    /// Root of `v` with path halving (wait-free progress under races).
    pub fn find(&self, mut v: VId) -> VId {
        loop {
            let p = self.parent[v as usize].load(Ordering::Relaxed);
            if p == v {
                return v;
            }
            let gp = self.parent[p as usize].load(Ordering::Relaxed);
            let _ = self.parent[v as usize].compare_exchange(
                p,
                gp,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            v = p;
        }
    }

    /// Are `u` and `v` currently connected?
    pub fn connected(&self, u: VId, v: VId) -> bool {
        // Standard concurrent-UF query loop: re-check when roots move.
        loop {
            let ru = self.find(u);
            let rv = self.find(v);
            if ru == rv {
                return true;
            }
            // Roots are stable if still self-parented.
            if self.parent[ru as usize].load(Ordering::Relaxed) == ru {
                return false;
            }
        }
    }

    /// Snapshot the current min-id labelling (parallel flatten + relabel).
    pub fn labels(&self, threads: usize) -> Vec<VId> {
        let n = self.n();
        let mut out = vec![0 as VId; n];
        {
            let slots = par::SyncSlice::new(&mut out);
            par::par_for(n, threads, par::DEFAULT_GRAIN, |range| {
                for v in range {
                    // SAFETY: disjoint ranges.
                    unsafe { slots.write(v, self.find(v as VId)) };
                }
            });
        }
        // Rem links toward smaller ids, so roots are component minima.
        out
    }

    pub fn num_components(&self) -> usize {
        (0..self.n() as VId).filter(|&v| self.find(v) == v).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc;
    use crate::graph::gen;

    #[test]
    fn online_insertions_and_queries() {
        let idx = IncrementalCc::new(6);
        assert_eq!(idx.num_components(), 6);
        assert!(!idx.connected(0, 1));
        idx.add_edge(0, 1);
        idx.add_edge(2, 3);
        assert!(idx.connected(0, 1));
        assert!(!idx.connected(1, 2));
        idx.add_edge(1, 2);
        assert!(idx.connected(0, 3));
        assert_eq!(idx.num_components(), 3); // {0..3}, {4}, {5}
        assert_eq!(idx.labels(1), vec![0, 0, 0, 0, 4, 5]);
        assert_eq!(idx.edges_added(), 3);
    }

    #[test]
    fn bulk_seed_matches_static_algorithms() {
        let g = gen::rmat(11, 6_000, gen::RmatKind::Graph500, 3).into_csr();
        let idx = IncrementalCc::from_graph(&g, 0);
        assert_eq!(idx.labels(0), cc::ground_truth(&g));
    }

    #[test]
    fn incremental_equals_batch_at_every_prefix() {
        let g = gen::erdos_renyi(300, 450, 7).into_csr();
        let idx = IncrementalCc::new(g.n);
        let edges: Vec<_> = g.edges().collect();
        for (k, &(u, v)) in edges.iter().enumerate() {
            idx.add_edge(u, v);
            if k % 90 == 0 || k + 1 == edges.len() {
                // Rebuild a static baseline from the prefix.
                let prefix =
                    crate::graph::EdgeList::from_pairs(g.n, &edges[..=k]).into_csr();
                assert_eq!(idx.labels(1), cc::ground_truth(&prefix), "prefix {k}");
            }
        }
    }

    #[test]
    fn concurrent_insertions() {
        let n = 10_000usize;
        let idx = IncrementalCc::new(n);
        // 8 threads insert interleaved path edges: the final structure is
        // one path => one component.
        std::thread::scope(|s| {
            for t in 0..8usize {
                let idx = &idx;
                s.spawn(move || {
                    let mut i = t;
                    while i + 1 < n {
                        idx.add_edge(i as VId, (i + 1) as VId);
                        i += 8;
                    }
                });
            }
        });
        assert_eq!(idx.num_components(), 1);
        assert!(idx.connected(0, (n - 1) as VId));
    }

    #[test]
    #[should_panic]
    fn out_of_range_panics() {
        IncrementalCc::new(3).add_edge(0, 9);
    }
}
