//! The Contour algorithm (Alg. 1) and its six variants (§III-B.4).
//!
//! The per-edge operator is MM^h (Definition 3): compute
//! `z = min(L^h[w], L^h[v])` by chasing up to `h` pointer hops from each
//! endpoint, then conditionally lower the labels of the up-to-2h touched
//! vertices to `z`. Because labels only ever decrease and `L[x] <= x` is
//! an invariant, pointer chains strictly descend — chases terminate and
//! racy (asynchronous) execution stays correct, exactly the argument the
//! paper makes for its Chapel implementation.
//!
//! Every §III-B optimization is an independent switch on [`Contour`]:
//! update mode (sync = Alg. 1 with the `L_u` array / async = in-place),
//! write mode (CAS per Eq. 4 / plain racy store), and the early
//! convergence check of §III-B.2.

use std::ops::Range;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::{Algorithm, AtomicLabels, FrontierStats, RunContext, RunResult};
use crate::graph::transform::{vertex_chunk_index, VertexChunkIndex};
use crate::graph::Csr;
use crate::par;
use crate::VId;

/// Operator schedule across iterations (which MM order each iteration
/// uses). `C-2` is `Fixed(2)`, `C-m` is `Fixed(M_ORDER)`, etc.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// The same MM^h every iteration.
    Fixed(usize),
    /// C-11mm: `ones` iterations of MM^1, then MM^m until convergence.
    OnesThenM { ones: usize, m: usize },
    /// C-1m1m: alternate MM^1 and MM^m.
    Alternate { m: usize },
}

impl Schedule {
    /// The operator order for iteration `k` (0-based).
    #[inline]
    pub fn order_at(self, k: usize) -> usize {
        match self {
            Schedule::Fixed(h) => h,
            Schedule::OnesThenM { ones, m } => {
                if k < ones {
                    1
                } else {
                    m
                }
            }
            Schedule::Alternate { m } => {
                if k % 2 == 0 {
                    1
                } else {
                    m
                }
            }
        }
    }
}

/// Label-update visibility (§III-B.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateMode {
    /// Alg. 1 as written: read L, write L_u, swap at iteration end.
    Sync,
    /// In-place updates, immediately visible to other edges/workers.
    Async,
}

/// How conditional assignments are written (§III-B.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WriteMode {
    /// Plain racy store (lost updates cost iterations, not correctness).
    Plain,
    /// Hardware fetch-min — the CAS loop of Eq. 4.
    Cas,
}

/// Default "m" for the high-order variants, following §IV-C (m = 1024).
pub const M_ORDER: usize = 1024;

/// In **chunk** frontier mode, force a full sweep after this many
/// consecutive frontier (dirty-chunks-only) passes. Chunk mode's
/// per-chunk dirty bits are a *local* signal — a chunk that changed
/// nothing goes clean even though a label one of its edges reads may
/// later be lowered by another chunk — so periodic full sweeps (plus
/// one whenever a frontier pass changes nothing) are the correctness
/// backstop, and chunk mode concludes convergence only from a full
/// sweep. **Exact** mode has no such constant: its vertex→chunk
/// activation map re-dirties precisely the chunks a lowered label can
/// affect, so an empty dirty set *is* the convergence proof.
pub const FULL_SWEEP_EVERY: usize = 4;

/// How the Contour execution engine selects edge chunks per iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrontierMode {
    /// Full sweep every pass (the paper's engine, no dirty bits).
    Off,
    /// Per-chunk dirty bits, rewritten each visit, with the
    /// [`FULL_SWEEP_EVERY`] full-sweep backstop (PR 4's engine).
    Chunk,
    /// Exact vertex-level activation: lowering `label[v]` marks every
    /// chunk containing an edge incident to `v` dirty (via a
    /// per-run [`VertexChunkIndex`]), a pass claims exactly the dirty
    /// chunks, and convergence is concluded from an empty dirty set —
    /// no forced sweeps.
    Exact,
}

impl FrontierMode {
    /// Parse a mode name: `exact`, `chunk`, `off` (plus the PR-4 era
    /// boolean spellings `1`/`on`/`true` → chunk, `0`/`false`/`none` →
    /// off, case-insensitively).
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "exact" => Some(Self::Exact),
            "chunk" | "1" | "on" | "true" => Some(Self::Chunk),
            "off" | "0" | "false" | "none" => Some(Self::Off),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Self::Off => "off",
            Self::Chunk => "chunk",
            Self::Exact => "exact",
        }
    }
}

/// Frontier accounting across all runs in this process (surfaced by the
/// server's METRICS verb). Runs accumulate privately and flush once at
/// the end, so these only ever move forward and a reader never sees a
/// half-counted run.
static FRONTIER_PASSES: AtomicU64 = AtomicU64::new(0);
static FRONTIER_SKIPPED: AtomicU64 = AtomicU64::new(0);
static FRONTIER_ACTIVATIONS: AtomicU64 = AtomicU64::new(0);
static FRONTIER_EXACT_PASSES: AtomicU64 = AtomicU64::new(0);
static FRONTIER_FULL_SWEEPS: AtomicU64 = AtomicU64::new(0);

/// `(frontier_passes, frontier_skipped_chunks)` since process start.
/// (Kept for callers that predate [`frontier_totals`].)
pub fn frontier_counters() -> (u64, u64) {
    (FRONTIER_PASSES.load(Ordering::Relaxed), FRONTIER_SKIPPED.load(Ordering::Relaxed))
}

/// All process-wide frontier counters since start, in the same shape a
/// single run reports ([`FrontierStats`]).
pub fn frontier_totals() -> FrontierStats {
    FrontierStats {
        passes: FRONTIER_PASSES.load(Ordering::Relaxed),
        skipped_chunks: FRONTIER_SKIPPED.load(Ordering::Relaxed),
        activations: FRONTIER_ACTIVATIONS.load(Ordering::Relaxed),
        exact_passes: FRONTIER_EXACT_PASSES.load(Ordering::Relaxed),
        full_sweeps: FRONTIER_FULL_SWEEPS.load(Ordering::Relaxed),
    }
}

fn flush_frontier_totals(s: &FrontierStats) {
    FRONTIER_PASSES.fetch_add(s.passes, Ordering::Relaxed);
    FRONTIER_SKIPPED.fetch_add(s.skipped_chunks, Ordering::Relaxed);
    FRONTIER_ACTIVATIONS.fetch_add(s.activations, Ordering::Relaxed);
    FRONTIER_EXACT_PASSES.fetch_add(s.exact_passes, Ordering::Relaxed);
    FRONTIER_FULL_SWEEPS.fetch_add(s.full_sweeps, Ordering::Relaxed);
}

/// Vertex→chunk indexes built / reused from a [`ChunkIndexCache`]
/// across all runs in this process (surfaced by the server's METRICS
/// verb as `chunk_index_built` / `chunk_index_reused`).
static CHUNK_INDEX_BUILT: AtomicU64 = AtomicU64::new(0);
static CHUNK_INDEX_REUSED: AtomicU64 = AtomicU64::new(0);

/// `(built, reused)` exact-frontier membership indexes since process
/// start. `reused` counts the O(m) rebuilds a [`ChunkIndexCache`]
/// avoided.
pub fn chunk_index_counters() -> (u64, u64) {
    (
        CHUNK_INDEX_BUILT.load(Ordering::Relaxed),
        CHUNK_INDEX_REUSED.load(Ordering::Relaxed),
    )
}

/// Cache of exact-frontier membership indexes for **one graph**, keyed
/// by grid grain (the only grid parameter — every grid tiles `0..m`).
///
/// The index is a pure function of the edge list and the grain, and the
/// grain is a pure function of `(m, threads)` — so repeated runs over
/// the same graph (the server's cached PCC path re-running Contour on
/// each shard per request) rebuild an identical index every time. One
/// cache per shard, living as long as the shard's `Csr`, turns those
/// two O(m) sweeps per run into a lookup. Stored `Arc`s keep hits
/// allocation-free; the build holds the lock so concurrent requests
/// cannot duplicate work.
#[derive(Debug, Default)]
pub struct ChunkIndexCache {
    by_grain: Mutex<IndexEntries>,
    reuses: AtomicU64,
}

type IndexEntries = Vec<(usize, Arc<VertexChunkIndex>)>;

impl Clone for ChunkIndexCache {
    /// Clones share the built indexes (cheap `Arc` copies) but start
    /// their own reuse count.
    fn clone(&self) -> Self {
        let entries = lock_cache(&self.by_grain).clone();
        Self { by_grain: Mutex::new(entries), reuses: AtomicU64::new(0) }
    }
}

fn lock_cache(m: &Mutex<IndexEntries>) -> std::sync::MutexGuard<'_, IndexEntries> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl ChunkIndexCache {
    /// The index for `g` over `grid`, building and memoizing on first
    /// use. The caller owns the invariant that this cache only ever
    /// sees the one graph it was created next to.
    pub fn get_or_build(&self, g: &Csr, grid: par::Chunks) -> Arc<VertexChunkIndex> {
        debug_assert_eq!(grid.len, g.m(), "cache consulted with a foreign grid");
        let mut entries = lock_cache(&self.by_grain);
        if let Some((_, ix)) = entries.iter().find(|&&(grain, _)| grain == grid.grain) {
            self.reuses.fetch_add(1, Ordering::Relaxed);
            CHUNK_INDEX_REUSED.fetch_add(1, Ordering::Relaxed);
            return ix.clone();
        }
        let ix = Arc::new(vertex_chunk_index(g, grid));
        CHUNK_INDEX_BUILT.fetch_add(1, Ordering::Relaxed);
        entries.push((grid.grain, ix.clone()));
        ix
    }

    /// Rebuilds this cache avoided (its hit count).
    pub fn reuses(&self) -> u64 {
        self.reuses.load(Ordering::Relaxed)
    }
}

/// Process-wide frontier default: `CONTOUR_FRONTIER=exact|chunk|off`
/// selects the engine for every [`Contour`] that does not set a mode
/// explicitly. Resolved once; unset or unparseable means off.
fn frontier_from_env() -> FrontierMode {
    static MODE: OnceLock<FrontierMode> = OnceLock::new();
    *MODE.get_or_init(|| {
        std::env::var("CONTOUR_FRONTIER")
            .ok()
            .and_then(|v| FrontierMode::parse(&v))
            .unwrap_or(FrontierMode::Off)
    })
}

/// Configurable Contour runner; use the constructors for the paper's
/// named variants.
#[derive(Clone, Debug)]
pub struct Contour {
    pub schedule: Schedule,
    pub update: UpdateMode,
    pub write: WriteMode,
    /// Early convergence check (§III-B.2).
    pub early_check: bool,
    /// Active-edge frontier engine ([`FrontierMode`]): skip settled
    /// chunks of the edge grid, either with PR 4's local dirty bits +
    /// backstop sweeps (`Chunk`) or the exact vertex→chunk activation
    /// map (`Exact`). `None` defers to the `CONTOUR_FRONTIER`
    /// environment default. Final labels are bit-identical to the
    /// full-sweep engine for every variant and mode — all converge to
    /// the canonical min-id labelling — only the work per iteration
    /// differs.
    pub frontier: Option<FrontierMode>,
    /// Worker threads (0 = [`par::num_threads`]).
    pub threads: usize,
    pub max_iters: usize,
    name: String,
}

impl Contour {
    fn new(name: &str, schedule: Schedule, update: UpdateMode, write: WriteMode) -> Self {
        Self {
            schedule,
            update,
            write,
            early_check: true,
            frontier: None,
            threads: 0,
            max_iters: 100_000,
            name: name.to_string(),
        }
    }

    /// C-1: one-order operator (≈ label propagation over edges).
    pub fn c1() -> Self {
        Self::new("C-1", Schedule::Fixed(1), UpdateMode::Async, WriteMode::Plain)
    }

    /// C-2: the paper's default (fast convergence, cheap operator).
    pub fn c2() -> Self {
        Self::new("C-2", Schedule::Fixed(2), UpdateMode::Async, WriteMode::Plain)
    }

    /// C-m: high-order operator for large-diameter graphs.
    pub fn cm() -> Self {
        Self::cm_order(M_ORDER)
    }

    pub fn cm_order(m: usize) -> Self {
        Self::new("C-m", Schedule::Fixed(m), UpdateMode::Async, WriteMode::Plain)
    }

    /// C-Syn: Alg. 1 verbatim — synchronous, atomic, no early check.
    pub fn csyn() -> Self {
        let mut c = Self::new("C-Syn", Schedule::Fixed(2), UpdateMode::Sync, WriteMode::Cas);
        c.early_check = false;
        c
    }

    /// C-11mm: MM^1 warmup then MM^m until convergence.
    pub fn c11mm() -> Self {
        Self::new(
            "C-11mm",
            Schedule::OnesThenM { ones: 2, m: M_ORDER },
            UpdateMode::Async,
            WriteMode::Plain,
        )
    }

    /// C-1m1m: alternate MM^1 / MM^m.
    pub fn c1m1m() -> Self {
        Self::new("C-1m1m", Schedule::Alternate { m: M_ORDER }, UpdateMode::Async, WriteMode::Plain)
    }

    /// All six paper variants, in the figures' legend order.
    pub fn all_variants() -> Vec<Contour> {
        vec![Self::c1(), Self::c2(), Self::cm(), Self::c11mm(), Self::c1m1m(), Self::csyn()]
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    pub fn with_early_check(mut self, on: bool) -> Self {
        self.early_check = on;
        self
    }

    pub fn with_write(mut self, w: WriteMode) -> Self {
        self.write = w;
        self
    }

    pub fn with_update(mut self, u: UpdateMode) -> Self {
        self.update = u;
        self
    }

    /// Boolean convenience kept from PR 4: `true` selects the chunk
    /// frontier, `false` the full-sweep engine (overriding the
    /// `CONTOUR_FRONTIER` environment default). Prefer
    /// [`Contour::with_frontier_mode`].
    pub fn with_frontier(self, on: bool) -> Self {
        self.with_frontier_mode(if on { FrontierMode::Chunk } else { FrontierMode::Off })
    }

    /// Pin this run's frontier engine (overriding the
    /// `CONTOUR_FRONTIER` environment default).
    pub fn with_frontier_mode(mut self, mode: FrontierMode) -> Self {
        self.frontier = Some(mode);
        self
    }

    pub fn renamed(mut self, name: &str) -> Self {
        name.clone_into(&mut self.name);
        self
    }

    /// The frontier engine this run will use. Sync updates demote
    /// `Chunk` to `Off`: every sync pass pays two O(n) shadow-array
    /// copies regardless of how many chunks the dirty bits skip, and
    /// chunk mode adds passes between the full sweeps that conclude its
    /// convergence — a net loss for C-Syn. `Exact` *does* apply to sync
    /// variants: with activation exact there are no extra passes — the
    /// shadow pass simply skips clean chunks — and labels stay
    /// identical (every engine converges to the canonical min-id
    /// labelling).
    fn frontier_mode(&self) -> FrontierMode {
        match self.frontier.unwrap_or_else(frontier_from_env) {
            FrontierMode::Chunk if self.update == UpdateMode::Sync => FrontierMode::Off,
            mode => mode,
        }
    }
}

/// Chase up to `h` pointer hops from `x` on `labels`, stopping early at a
/// fixpoint. Returns `L^h[x]` (with early stop, the same value).
#[inline]
fn chase(labels: &AtomicLabels, x: VId, h: usize) -> VId {
    let mut cur = labels.load(x);
    for _ in 1..h {
        let nxt = labels.load(cur);
        if nxt == cur {
            break;
        }
        cur = nxt;
    }
    cur
}

/// Chunk-selection policy for one [`Contour::edge_pass`] iteration.
enum PassMode<'a> {
    /// Process every chunk (full sweep).
    Full,
    /// PR 4's chunk frontier: honor/rewrite local dirty bits, with
    /// `full` forcing a backstop sweep that still refreshes the bits.
    Chunk { bits: &'a [AtomicBool], full: bool },
    /// Exact vertex-level activation over the per-run membership index.
    Exact { bits: &'a [AtomicBool], index: &'a VertexChunkIndex, activations: &'a AtomicU64 },
}

/// What one [`Contour::edge_pass`] observed.
struct PassOutcome {
    /// Did any processed chunk perform a store?
    changed: bool,
    /// Chunks skipped as clean.
    skipped: u64,
}

impl Contour {
    /// MM^h over one chunk of the edge grid: runs the operator on every
    /// edge in `range` and reports whether any label changed. The
    /// Plain-store fast paths (h = 1, h = 2, recorded-chain h > 2) and
    /// the generic CAS/sync body all share this per-range shape so the
    /// chunked engine in [`Contour::edge_pass`] can schedule any
    /// variant — full sweep, chunk frontier or exact frontier, sticky
    /// or inline — through one driver.
    ///
    /// `on_lower(x)` fires after **every performed store** to `x`
    /// (monomorphized to a no-op outside exact mode). Exact activation
    /// leans on this being complete: a plain racy store can even *raise*
    /// a label it believed it was lowering (the §III-B.3 lost-update
    /// race), and the only way an edge's endpoints can become unequal is
    /// some performed store — so "every performed store activates its
    /// target's chunks" is exactly the invariant that keeps every
    /// actionable edge inside the dirty set.
    ///
    /// Fast path rationale for the paper's default operator: MM^2 with
    /// plain stores reuses the labels loaded during the chase instead
    /// of re-walking the chain (≈ halves loads per edge; EXPERIMENTS.md
    /// §Perf step 8). Semantics match Definition 2/3 exactly: the
    /// target set {w, v, L[w], L[v]} is evaluated at operator entry.
    #[inline]
    fn pass_range<A: Fn(VId)>(
        &self,
        g: &Csr,
        read: &AtomicLabels,
        write_to: &AtomicLabels,
        h: usize,
        range: Range<usize>,
        on_lower: &A,
    ) -> bool {
        match (self.write, h) {
            (WriteMode::Plain, 1) => self.pass_range_h1(g, read, write_to, range, on_lower),
            (WriteMode::Plain, 2) => self.pass_range_h2(g, read, write_to, range, on_lower),
            (WriteMode::Plain, _) => self.pass_range_hm(g, read, write_to, h, range, on_lower),
            _ => self.pass_range_generic(g, read, write_to, h, range, on_lower),
        }
    }

    /// Generic MM^h body (CAS writes, and the sync engine's shadow
    /// array): chase both endpoints, then conditionally assign along
    /// both chains — targets w, L[w], ..., L^{h-1}[w] and the v side.
    fn pass_range_generic<A: Fn(VId)>(
        &self,
        g: &Csr,
        read: &AtomicLabels,
        write_to: &AtomicLabels,
        h: usize,
        range: Range<usize>,
        on_lower: &A,
    ) -> bool {
        let store = |arr: &AtomicLabels, i: VId, z: VId| -> bool {
            match self.write {
                WriteMode::Plain => arr.store_min_plain(i, z),
                WriteMode::Cas => arr.store_min_cas(i, z),
            }
        };
        let src = &g.src;
        let dst = &g.dst;
        let mut changed = false;
        for e in range {
            let (w, v) = (src[e], dst[e]);
            let zw = chase(read, w, h);
            let zv = chase(read, v, h);
            let z = zw.min(zv);
            for mut x in [w, v] {
                for _ in 0..h {
                    let nxt = read.load(x);
                    if store(write_to, x, z) {
                        changed = true;
                        on_lower(x);
                    }
                    if nxt == x {
                        break;
                    }
                    x = nxt;
                }
            }
        }
        changed
    }

    /// MM^1 fast path (plain stores): z = min(L[w], L[v]); lower the
    /// larger side. 2 loads + at most 1 store per edge.
    fn pass_range_h1<A: Fn(VId)>(
        &self,
        g: &Csr,
        read: &AtomicLabels,
        write_to: &AtomicLabels,
        range: Range<usize>,
        on_lower: &A,
    ) -> bool {
        let src = &g.src;
        let dst = &g.dst;
        let mut changed = false;
        for e in range {
            let (w, v) = (src[e], dst[e]);
            let lw = read.load(w);
            let lv = read.load(v);
            if lw == lv {
                continue;
            }
            let (tgt, z) = if lw > lv { (w, lv) } else { (v, lw) };
            if write_to.store_min_plain(tgt, z) {
                changed = true;
                on_lower(tgt);
            }
        }
        changed
    }

    /// MM^2 fast path (plain stores): 4 loads + up to 4 conditional
    /// stores per edge, everything reused from registers.
    fn pass_range_h2<A: Fn(VId)>(
        &self,
        g: &Csr,
        read: &AtomicLabels,
        write_to: &AtomicLabels,
        range: Range<usize>,
        on_lower: &A,
    ) -> bool {
        let src = &g.src;
        let dst = &g.dst;
        let mut changed = false;
        for e in range {
            let (w, v) = (src[e], dst[e]);
            let lw = read.load(w);
            let lv = read.load(v);
            let llw = read.load(lw);
            let llv = read.load(lv);
            let z = llw.min(llv);
            // Conditional vector assignment over {w, v, L[w], L[v]}
            // with the comparison values already in registers. The
            // pre-check keeps the common no-op case load-free; whether
            // the store was *performed* comes from store_min itself
            // (a racing worker may have gotten there first).
            if lw > z && write_to.store_min_plain(w, z) {
                changed = true;
                on_lower(w);
            }
            if lv > z && write_to.store_min_plain(v, z) {
                changed = true;
                on_lower(v);
            }
            if llw > z && write_to.store_min_plain(lw, z) {
                changed = true;
                on_lower(lw);
            }
            if llv > z && write_to.store_min_plain(lv, z) {
                changed = true;
                on_lower(lv);
            }
        }
        changed
    }

    /// MM^h fast path for h > 2 (plain stores): records the pointer chain
    /// during the chase so the conditional-assignment phase needs no
    /// re-loads. Chains longer than the record buffer (rare: the
    /// compression effect keeps chains near-flat after the first
    /// iteration) fall back to re-walking with loads.
    fn pass_range_hm<A: Fn(VId)>(
        &self,
        g: &Csr,
        read: &AtomicLabels,
        write_to: &AtomicLabels,
        h: usize,
        range: Range<usize>,
        on_lower: &A,
    ) -> bool {
        const CAP: usize = 32;
        let src = &g.src;
        let dst = &g.dst;
        let mut changed = false;
        // (chain nodes, current label of the last node, length)
        let mut chains = [[0 as VId; CAP]; 2];
        let mut vals = [0 as VId; 2];
        let mut lens = [0usize; 2];
        for e in range {
            let ends = [src[e], dst[e]];
            for side in 0..2 {
                let mut cur = ends[side];
                let chain = &mut chains[side];
                let mut len = 0usize;
                let val = loop {
                    if len < CAP {
                        chain[len] = cur;
                    }
                    len += 1;
                    let nxt = read.load(cur);
                    if nxt == cur || len >= h {
                        break nxt;
                    }
                    cur = nxt;
                };
                vals[side] = val;
                lens[side] = len;
            }
            let z = vals[0].min(vals[1]);
            for side in 0..2 {
                let len = lens[side];
                let recorded = len.min(CAP);
                if len > CAP {
                    // Rare long chain: re-walk the unrecorded tail
                    // *before* the stores below can clobber the
                    // pointers the walk follows.
                    let mut x = chains[side][CAP - 1];
                    for _ in CAP - 1..len {
                        let nxt = read.load(x);
                        if write_to.store_min_plain(x, z) {
                            changed = true;
                            on_lower(x);
                        }
                        if nxt == x {
                            break;
                        }
                        x = nxt;
                    }
                }
                for i in 0..recorded {
                    // Current label of chain[i] is chain[i+1]
                    // (or the chased value for the last node).
                    let label = if i + 1 < recorded { chains[side][i + 1] } else { vals[side] };
                    if label > z && write_to.store_min_plain(chains[side][i], z) {
                        changed = true;
                        on_lower(chains[side][i]);
                    }
                }
            }
        }
        changed
    }

    /// One iteration of MM^h over the stable edge-chunk grid, scheduled
    /// sticky so each contiguous chunk block lands on the same worker
    /// every pass. The `mode` selects which chunks run:
    ///
    /// * [`PassMode::Full`] — every chunk.
    /// * [`PassMode::Chunk`] — skip clear-bit chunks unless `full`;
    ///   every processed chunk's bit is rewritten to whether it changed
    ///   any label (PR 4's local signal).
    /// * [`PassMode::Exact`] — *claim* each dirty chunk by clearing its
    ///   bit **before** processing (`swap(false, Acquire)`), and let
    ///   every performed store re-dirty the chunks of its target vertex
    ///   through the [`VertexChunkIndex`] with a `Release` store.
    ///   Clear-before-process plus release/acquire pairing closes the
    ///   lost-wakeup window: if a claimer's acquire-swap observes a
    ///   writer's release-set it also observes the label store that
    ///   preceded it, and a set that lands after the claim simply
    ///   leaves the chunk dirty for the next pass.
    fn edge_pass(
        &self,
        g: &Csr,
        read: &AtomicLabels,
        write_to: &AtomicLabels,
        h: usize,
        grid: par::Chunks,
        mode: &PassMode<'_>,
    ) -> PassOutcome {
        let changed = AtomicBool::new(false);
        let skipped = AtomicU64::new(0);
        match *mode {
            PassMode::Full => {
                par::par_for_sticky(grid, self.threads, |_, range| {
                    if self.pass_range(g, read, write_to, h, range, &|_| {}) {
                        changed.store(true, Ordering::Relaxed);
                    }
                });
            }
            PassMode::Chunk { bits, full } => {
                par::par_for_sticky(grid, self.threads, |c, range| {
                    if !full && !bits[c].load(Ordering::Relaxed) {
                        skipped.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    let ch = self.pass_range(g, read, write_to, h, range, &|_| {});
                    bits[c].store(ch, Ordering::Relaxed);
                    if ch {
                        changed.store(true, Ordering::Relaxed);
                    }
                });
            }
            PassMode::Exact { bits, index, activations } => {
                par::par_for_sticky(grid, self.threads, |c, range| {
                    if !bits[c].swap(false, Ordering::Acquire) {
                        skipped.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                    // Count activations chunk-locally and flush once:
                    // a shared fetch_add per performed store would put
                    // cross-core counter contention inside the hottest
                    // loop the engine exists to speed up.
                    let local = std::cell::Cell::new(0u64);
                    let on_lower = |x: VId| {
                        local.set(local.get() + 1);
                        for &ci in index.chunks_of(x) {
                            // Unconditional release store: a
                            // load-then-set "optimization" could
                            // observe a stale `true`, skip the set, and
                            // let a concurrent claimer clear the bit
                            // without seeing our label write.
                            bits[ci as usize].store(true, Ordering::Release);
                        }
                    };
                    if self.pass_range(g, read, write_to, h, range, &on_lower) {
                        changed.store(true, Ordering::Relaxed);
                    }
                    if local.get() > 0 {
                        activations.fetch_add(local.get(), Ordering::Relaxed);
                    }
                });
            }
        }
        PassOutcome {
            changed: changed.load(Ordering::Relaxed),
            skipped: skipped.load(Ordering::Relaxed),
        }
    }

    /// §III-B.2 early convergence check, evaluated on the *settled* label
    /// array after a pass: converged iff for every edge (w, v)
    /// `L[w] == L²[w] && L[v] == L²[v] && L[w] == L[v]`.
    ///
    /// (The check must run post-pass: evaluating it per edge while other
    /// edges still update labels can report convergence for a state that
    /// a later update then invalidates — under-merging the result.)
    fn check_converged(&self, g: &Csr, labels: &AtomicLabels) -> bool {
        let src = &g.src;
        let dst = &g.dst;
        par::par_map_reduce(
            g.m(),
            self.threads,
            par::AUTO_GRAIN,
            || true,
            |acc, range| {
                if !*acc {
                    return;
                }
                for e in range {
                    let lw = labels.load(src[e]);
                    let lv = labels.load(dst[e]);
                    if lw != lv || labels.load(lw) != lw || labels.load(lv) != lv {
                        *acc = false;
                        return;
                    }
                }
            },
            |a, b| a && b,
        )
    }
}

impl Algorithm for Contour {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        self.run_ctx(g, &RunContext::default())
    }

    /// The pass loop with observability wired in: one span per pass
    /// (mode, chunks visited/skipped, labels lowered), plus spans for
    /// the index build and the star-finalize epilogue — all keyed to
    /// `ctx.tid` so sharded runs land on their own tracks. With
    /// `ctx.trace` unset the extra cost is one branch per pass.
    fn run_ctx(&self, g: &Csr, ctx: &RunContext<'_>) -> RunResult {
        let mem = crate::obs::MemScope::start();
        let tr = ctx.trace.as_deref();
        let n = g.n;
        let labels = AtomicLabels::identity(n);
        // Sync mode keeps the L_u array of Alg. 1.
        let shadow = match self.update {
            UpdateMode::Sync => Some(AtomicLabels::identity(n)),
            UpdateMode::Async => None,
        };
        // The stable chunk grid every pass of this run reuses: stable
        // ids are what let sticky scheduling keep chunk→worker fixed
        // across iterations, what the frontier's dirty bits index, and
        // what the exact activation map is built against.
        // Frontier grids are capped finer than the scheduling-optimal
        // grain: a chunk is dirty if *any* of its edges changed, so on
        // late passes with scattered updates halving the chunk size
        // roughly doubles the skippable fraction, at a per-chunk cost
        // (one closure call + one bit) that is noise next to the edges
        // saved. Sticky slots own contiguous chunk *blocks*, so finer
        // chunks do not fragment worker locality.
        let threads = if self.threads == 0 { par::num_threads() } else { self.threads };
        let mode = if g.m() == 0 { FrontierMode::Off } else { self.frontier_mode() };
        let scheduling_grain = par::adaptive_grain(g.m(), threads);
        let grain = match mode {
            FrontierMode::Off => scheduling_grain,
            _ => scheduling_grain.min(1 << 10),
        };
        let grid = par::Chunks::new(g.m(), grain);
        let dirty: Option<Vec<AtomicBool>> = (mode != FrontierMode::Off)
            .then(|| (0..grid.count()).map(|_| AtomicBool::new(true)).collect());
        // The exact engine's vertex→chunk membership index: two O(m)
        // sweeps, amortized over the run's passes — or over *many* runs
        // when the caller supplies a [`ChunkIndexCache`] (the sharded
        // PCC path re-runs Contour on the same shard per request).
        let index_start = tr.map(|t| t.now());
        let index: Option<Arc<VertexChunkIndex>> =
            (mode == FrontierMode::Exact).then(|| match ctx.chunk_index_cache {
                Some(cache) => cache.get_or_build(g, grid),
                None => {
                    CHUNK_INDEX_BUILT.fetch_add(1, Ordering::Relaxed);
                    Arc::new(vertex_chunk_index(g, grid))
                }
            });
        if let (Some(t), Some(start), Some(ix)) = (tr, index_start, index.as_deref()) {
            let args = vec![("entries", ix.entries() as u64)];
            t.close("index".to_string(), "contour", "", ctx.tid, start, args);
        }
        let activations = AtomicU64::new(0);
        let mut stats = FrontierStats::default();
        let mut iters = 0usize;
        // Chunk-mode bookkeeping: the first pass, every pass after
        // FULL_SWEEP_EVERY consecutive frontier passes, and any pass
        // after a frontier pass that changed nothing run as full
        // sweeps; chunk mode concludes convergence only from full
        // sweeps (its partial passes see a subset of the edges, so
        // their quiescence proves nothing globally). The exact engine
        // needs none of this: every performed store re-dirties exactly
        // the chunks it can affect, so a pass with no store means the
        // dirty set is drained and every edge has equal endpoint
        // labels — which, with labels always component-internal and
        // L[μ] = μ pinned at each component minimum, is full
        // convergence to the canonical labelling.
        let mut force_full = true;
        let mut since_full = 0usize;
        loop {
            // Cooperative deadline: between passes nothing is borrowed by
            // pool workers, so an armed `CONTOUR_DEADLINE_MS` can safely
            // abandon the run here (dispatch maps it to `ERR deadline`).
            crate::util::deadline::check();
            let pass_idx = iters;
            let h = self.schedule.order_at(iters).max(1);
            iters += 1;
            let full = match mode {
                FrontierMode::Off => true,
                FrontierMode::Chunk => force_full || since_full >= FULL_SWEEP_EVERY,
                FrontierMode::Exact => false,
            };
            let pass_mode = match mode {
                FrontierMode::Off => PassMode::Full,
                FrontierMode::Chunk => PassMode::Chunk { bits: dirty.as_deref().unwrap(), full },
                FrontierMode::Exact => PassMode::Exact {
                    bits: dirty.as_deref().unwrap(),
                    index: index.as_deref().unwrap(),
                    activations: &activations,
                },
            };
            let span_start = tr.map(|t| t.now());
            let act_before = activations.load(Ordering::Relaxed);
            let out = match &shadow {
                None => self.edge_pass(g, &labels, &labels, h, grid, &pass_mode),
                Some(lu) => {
                    lu.copy_from(&labels);
                    let o = self.edge_pass(g, &labels, lu, h, grid, &pass_mode);
                    labels.copy_from(lu); // L = L_u (line 9 of Alg. 1)
                    o
                }
            };
            if let (Some(t), Some(start)) = (tr, span_start) {
                // `detail` is the mode this pass *executed* — a chunk
                // engine's backstop sweep traces as "full", so summing
                // spans by detail reconciles exactly with FrontierStats.
                let detail = if full { "full" } else { mode.as_str() };
                let mut args = vec![
                    ("pass", pass_idx as u64),
                    ("h", h as u64),
                    ("visited", grid.count() as u64 - out.skipped),
                    ("skipped", out.skipped),
                ];
                if mode == FrontierMode::Exact {
                    let lowered = activations.load(Ordering::Relaxed) - act_before;
                    args.push(("lowered", lowered));
                } else {
                    args.push(("changed", out.changed as u64));
                }
                if crate::obs::alloc::enabled() {
                    args.push(("mem_bytes", crate::obs::alloc::current_bytes()));
                }
                t.close(format!("pass{pass_idx}"), "contour", detail, ctx.tid, start, args);
            }
            match mode {
                FrontierMode::Exact => {
                    stats.passes += 1;
                    stats.exact_passes += 1;
                    stats.skipped_chunks += out.skipped;
                    if !out.changed || iters >= self.max_iters {
                        break;
                    }
                }
                _ if full => {
                    if mode == FrontierMode::Chunk {
                        stats.full_sweeps += 1;
                    }
                    since_full = 0;
                    force_full = false;
                    let converged =
                        !out.changed || (self.early_check && self.check_converged(g, &labels));
                    if converged || iters >= self.max_iters {
                        break;
                    }
                }
                _ => {
                    stats.passes += 1;
                    stats.skipped_chunks += out.skipped;
                    since_full += 1;
                    // A frontier pass that changed nothing has drained
                    // the local dirty set; only a full sweep can tell
                    // settled from stalled.
                    force_full = !out.changed;
                    if iters >= self.max_iters {
                        break;
                    }
                }
            }
        }
        // The early check can exit with star-compression still pending
        // (labels point at roots transitively); finish with pointer
        // jumping so labels are the canonical min-id form. (The exact
        // engine's quiescence exit needs no compression — equal labels
        // along every edge already *are* the canonical stars — but the
        // jump is a cheap no-op then and keeps one epilogue.)
        let fin_start = tr.map(|t| t.now());
        finalize_stars(&labels, self.threads);
        if let (Some(t), Some(start)) = (tr, fin_start) {
            t.close("finalize".to_string(), "contour", "", ctx.tid, start, vec![]);
        }
        stats.activations = activations.load(Ordering::Relaxed);
        flush_frontier_totals(&stats);
        RunResult {
            labels: labels.to_vec(),
            iterations: iters,
            frontier: stats,
            trace: ctx.trace.clone(),
            mem: mem.finish(),
        }
    }
}

/// Pointer-jump until the forest is stars: L[v] = root(v). O(n log h).
fn finalize_stars(labels: &AtomicLabels, threads: usize) {
    loop {
        let changed = par::par_map_reduce(
            labels.len(),
            threads,
            par::AUTO_GRAIN,
            || false,
            |acc, range| {
                for v in range {
                    let l = labels.load(v as VId);
                    let ll = labels.load(l);
                    if ll < l {
                        labels.store_min_cas(v as VId, ll);
                        *acc = true;
                    }
                }
            },
            |a, b| a || b,
        );
        if !changed {
            break;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{ground_truth, same_partition};
    use crate::graph::gen;

    fn check_all_variants(g: &crate::graph::Csr) {
        let want = ground_truth(g);
        for alg in Contour::all_variants() {
            let got = alg.run(g);
            assert!(
                same_partition(&got, &want),
                "{} wrong on n={} m={}",
                alg.name(),
                g.n,
                g.m()
            );
            // Labels must be exactly min-id form after finalize.
            assert_eq!(got, want, "{} labels not canonical", alg.name());
        }
    }

    #[test]
    fn variants_on_structured_graphs() {
        for e in [
            gen::path(50),
            gen::cycle(33),
            gen::star(40),
            gen::complete(12),
            gen::grid(7, 9),
            gen::binary_tree(6),
            gen::comb(10, 6),
            gen::component_soup(8, 12, 3),
        ] {
            check_all_variants(&e.into_csr());
        }
    }

    #[test]
    fn variants_on_random_graphs() {
        for seed in 0..5 {
            check_all_variants(&gen::erdos_renyi(200, 300, seed).into_csr());
            check_all_variants(&gen::rmat(9, 2000, gen::RmatKind::Graph500, seed).into_csr());
        }
    }

    #[test]
    fn empty_and_singleton() {
        let g = gen::path(1).into_csr();
        let r = Contour::c2().run_with_stats(&g);
        assert_eq!(r.labels, vec![0]);
        let g = crate::graph::EdgeList::new(4).into_csr();
        let r = Contour::c2().run_with_stats(&g);
        assert_eq!(r.labels, vec![0, 1, 2, 3]);
        assert_eq!(r.iterations, 1);
    }

    #[test]
    fn schedule_orders() {
        assert_eq!(Schedule::Fixed(2).order_at(7), 2);
        let s = Schedule::OnesThenM { ones: 2, m: 64 };
        assert_eq!(s.order_at(0), 1);
        assert_eq!(s.order_at(1), 1);
        assert_eq!(s.order_at(2), 64);
        let a = Schedule::Alternate { m: 8 };
        assert_eq!(a.order_at(0), 1);
        assert_eq!(a.order_at(1), 8);
        assert_eq!(a.order_at(2), 1);
    }

    #[test]
    fn iteration_counts_ordered_on_long_path() {
        // §IV-C: iterations(C-m) <= iterations(C-2) <= iterations(C-1).
        // Shuffled edge order: sequential order lets an async sweep carry
        // label 0 down the whole path in one pass, hiding the contrast.
        // Pinned to the full-sweep engine: the paper's counts are about
        // full sweeps, and this test must assert the same thing whatever
        // CONTOUR_FRONTIER the suite runs under.
        let g = gen::path(2000).into_csr().shuffled_edges(17);
        let full = |c: Contour| {
            c.with_frontier_mode(FrontierMode::Off).run_with_stats(&g).iterations
        };
        let i1 = full(Contour::c1());
        let i2 = full(Contour::c2());
        let im = full(Contour::cm());
        assert!(im <= i2, "C-m {im} > C-2 {i2}");
        assert!(i2 <= i1, "C-2 {i2} > C-1 {i1}");
        assert!(i1 > i2, "C-1 ({i1}) should need more iterations than C-2 ({i2})");
    }

    #[test]
    fn theorem1_bound_for_sync_c2() {
        // Synchronous MM^2 must converge within ceil(log_1.5 d) + 1
        // iterations (+1 for the final no-change detection pass).
        // Full-sweep engine pinned: Theorem 1's contraction argument
        // needs every edge processed every iteration.
        for n in [10usize, 100, 500] {
            let g = gen::path(n).into_csr();
            let alg = Contour::csyn()
                .with_early_check(false)
                .with_frontier_mode(FrontierMode::Off);
            let r = alg.run_with_stats(&g);
            let d = (n - 1) as f64;
            let bound = d.log(1.5).ceil() as usize + 1;
            assert!(
                r.iterations <= bound + 1,
                "n={n}: {} iters > bound {bound}+1",
                r.iterations
            );
        }
    }

    #[test]
    fn async_not_slower_than_sync_in_iterations() {
        let g = gen::path(1000).into_csr();
        let full = |c: Contour| {
            c.with_frontier_mode(FrontierMode::Off).run_with_stats(&g).iterations
        };
        let sync = full(Contour::csyn());
        let asy = full(Contour::c2());
        assert!(asy <= sync + 1, "async {asy} vs sync {sync}");
    }

    #[test]
    fn cas_and_plain_both_correct() {
        let g = gen::rmat(10, 4000, gen::RmatKind::Graph500, 5).into_csr();
        let want = ground_truth(&g);
        for w in [WriteMode::Plain, WriteMode::Cas] {
            let got = Contour::c2().with_write(w).run(&g);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn early_check_does_not_change_result() {
        let g = gen::delaunay(512, 3).into_csr();
        let a = Contour::c2().with_early_check(true).run(&g);
        let b = Contour::c2().with_early_check(false).run(&g);
        assert_eq!(a, b);
    }

    #[test]
    fn single_thread_matches_parallel() {
        let g = gen::barabasi_albert(3000, 3, 9).into_csr();
        let seq = Contour::c2().with_threads(1).run(&g);
        let par = Contour::c2().with_threads(8).run(&g);
        assert_eq!(seq, par);
    }

    #[test]
    fn frontier_mode_matches_full_sweep_for_all_variants() {
        let g = gen::rmat(11, 10_000, gen::RmatKind::Graph500, 3).into_csr().shuffled_edges(5);
        for alg in Contour::all_variants() {
            let full = alg.clone().with_frontier_mode(FrontierMode::Off).run(&g);
            for mode in [FrontierMode::Chunk, FrontierMode::Exact] {
                let got = alg.clone().with_frontier_mode(mode).run(&g);
                assert_eq!(got, full, "{} diverges in {} mode", alg.name(), mode.as_str());
            }
        }
    }

    #[test]
    fn frontier_mode_parses_all_spellings() {
        assert_eq!(FrontierMode::parse("exact"), Some(FrontierMode::Exact));
        assert_eq!(FrontierMode::parse("EXACT"), Some(FrontierMode::Exact));
        assert_eq!(FrontierMode::parse("chunk"), Some(FrontierMode::Chunk));
        assert_eq!(FrontierMode::parse("1"), Some(FrontierMode::Chunk));
        assert_eq!(FrontierMode::parse("on"), Some(FrontierMode::Chunk));
        assert_eq!(FrontierMode::parse("true"), Some(FrontierMode::Chunk));
        assert_eq!(FrontierMode::parse("off"), Some(FrontierMode::Off));
        assert_eq!(FrontierMode::parse("0"), Some(FrontierMode::Off));
        assert_eq!(FrontierMode::parse("none"), Some(FrontierMode::Off));
        assert_eq!(FrontierMode::parse("sideways"), None);
        for m in [FrontierMode::Off, FrontierMode::Chunk, FrontierMode::Exact] {
            assert_eq!(FrontierMode::parse(m.as_str()), Some(m));
        }
    }

    #[test]
    fn with_frontier_bool_maps_to_modes() {
        assert_eq!(Contour::c2().with_frontier(true).frontier, Some(FrontierMode::Chunk));
        assert_eq!(Contour::c2().with_frontier(false).frontier, Some(FrontierMode::Off));
    }

    #[test]
    fn exact_mode_reports_no_forced_sweeps() {
        // Per-run stats (carried on RunResult, so concurrent tests in
        // this process can't perturb them): the exact engine must run
        // exact passes only, force zero backstop sweeps, record its
        // store-site activations, and still skip settled chunks.
        let g = gen::rmat(12, 60_000, gen::RmatKind::Graph500, 21).into_csr().shuffled_edges(9);
        let want = Contour::c2().with_frontier_mode(FrontierMode::Off).run(&g);
        let r = Contour::c2().with_frontier_mode(FrontierMode::Exact).run_with_stats(&g);
        assert_eq!(r.labels, want);
        assert_eq!(r.frontier.full_sweeps, 0, "exact mode forced a sweep");
        assert_eq!(r.frontier.exact_passes as usize, r.iterations);
        assert_eq!(r.frontier.passes, r.frontier.exact_passes);
        assert!(r.frontier.activations > 0, "no activation ever recorded");
        // (Skipping is asserted deterministically in
        // tests/frontier_exact.rs — on a homogeneous low-diameter graph
        // the dirty set can legitimately stay full until quiescence.)
        // Chunk mode on the same graph *does* force backstop sweeps.
        let c = Contour::c2().with_frontier_mode(FrontierMode::Chunk).run_with_stats(&g);
        assert_eq!(c.labels, want);
        assert!(c.frontier.full_sweeps >= 1, "chunk mode must full-sweep at least once");
        assert_eq!(c.frontier.exact_passes, 0);
        assert_eq!(c.frontier.activations, 0);
        // Full-sweep engine reports no frontier accounting at all.
        let f = Contour::c2().with_frontier_mode(FrontierMode::Off).run_with_stats(&g);
        assert_eq!(f.frontier, crate::cc::FrontierStats::default());
    }

    #[test]
    fn exact_mode_applies_to_sync_variants() {
        // Chunk mode demotes to Off for sync updates; exact does not —
        // the shadow pass skips clean chunks and labels stay identical.
        let g = gen::road(60, 60, 13).into_csr().shuffled_edges(2);
        let want = Contour::csyn().with_frontier_mode(FrontierMode::Off).run(&g);
        let r = Contour::csyn().with_frontier_mode(FrontierMode::Exact).run_with_stats(&g);
        assert_eq!(r.labels, want);
        assert!(r.frontier.exact_passes > 0, "sync run never took an exact pass");
        assert_eq!(r.frontier.full_sweeps, 0);
        // The chunk demotion still holds.
        let c = Contour::csyn().with_frontier_mode(FrontierMode::Chunk).run_with_stats(&g);
        assert_eq!(c.labels, want);
        assert_eq!(c.frontier.passes, 0, "chunk mode must demote to Off for sync");
    }

    #[test]
    fn exact_mode_handles_degenerate_graphs() {
        let g = crate::graph::EdgeList::new(4).into_csr();
        let r = Contour::c2().with_frontier_mode(FrontierMode::Exact).run_with_stats(&g);
        assert_eq!(r.labels, vec![0, 1, 2, 3]);
        assert_eq!(r.iterations, 1);
        let g = gen::path(1).into_csr();
        assert_eq!(Contour::c2().with_frontier_mode(FrontierMode::Exact).run(&g), vec![0]);
        let g = gen::path(2).into_csr();
        assert_eq!(Contour::c2().with_frontier_mode(FrontierMode::Exact).run(&g), vec![0, 0]);
    }

    #[test]
    fn frontier_skips_settled_chunks() {
        // Low diameter: most chunks settle after the first couple of
        // passes, so the frontier counters must record skipped chunks
        // while the labels stay bit-identical.
        let g = gen::rmat(13, 120_000, gen::RmatKind::Graph500, 9).into_csr().shuffled_edges(2);
        let (p0, s0) = frontier_counters();
        let want = Contour::c2().with_frontier(false).run(&g);
        let got = Contour::c2().with_frontier(true).run(&g);
        assert_eq!(got, want);
        let (p1, s1) = frontier_counters();
        assert!(p1 > p0, "no frontier pass ran");
        assert!(s1 > s0, "frontier never skipped a chunk");
    }

    #[test]
    fn frontier_handles_degenerate_graphs() {
        let g = crate::graph::EdgeList::new(4).into_csr();
        let r = Contour::c2().with_frontier(true).run_with_stats(&g);
        assert_eq!(r.labels, vec![0, 1, 2, 3]);
        assert_eq!(r.iterations, 1);
        let g = gen::path(1).into_csr();
        assert_eq!(Contour::c2().with_frontier(true).run(&g), vec![0]);
    }

    #[test]
    fn renamed_sets_the_display_name() {
        let alg = Contour::c2().renamed("C-2/custom");
        assert_eq!(alg.name(), "C-2/custom");
    }
}
