//! Classic Shiloach–Vishkin (1982): the seminal hooking + shortcutting
//! algorithm FastSV descends from (§V). Kept as a second baseline and as
//! the reference point for the ablation benches.

use super::{Algorithm, AtomicLabels, RunResult};
use crate::graph::Csr;
use crate::par;

#[derive(Clone, Debug, Default)]
pub struct ShiloachVishkin {
    pub threads: usize,
}

impl ShiloachVishkin {
    pub fn new() -> Self {
        Self { threads: 0 }
    }
}

impl Algorithm for ShiloachVishkin {
    fn name(&self) -> String {
        "SV".into()
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let n = g.n;
        let t = self.threads;
        let p = AtomicLabels::identity(n);
        let mut iters = 0usize;
        loop {
            iters += 1;
            // Hook: for each edge (u, v), roots hook onto smaller labels.
            let src = &g.src;
            let dst = &g.dst;
            let pr = &p;
            let hooked = par::par_map_reduce(
                g.m(),
                t,
                par::AUTO_GRAIN,
                || false,
                |acc, range| {
                    for e in range {
                        let (u, v) = (src[e], dst[e]);
                        let pu = pr.load(u);
                        let pv = pr.load(v);
                        // Hook the root of the larger onto the smaller.
                        if pu < pv && pv == pr.load(pv) {
                            *acc |= pr.store_min_cas(pv, pu);
                        } else if pv < pu && pu == pr.load(pu) {
                            *acc |= pr.store_min_cas(pu, pv);
                        }
                    }
                },
                |a, b| a || b,
            );
            // Shortcut: p[v] = p[p[v]] until the forest is stars.
            let mut shortcutted = true;
            while shortcutted {
                shortcutted = par::par_map_reduce(
                    n,
                    t,
                    par::AUTO_GRAIN,
                    || false,
                    |acc, range| {
                        for v in range {
                            let v = v as crate::VId;
                            let pv = pr.load(v);
                            let ppv = pr.load(pv);
                            if ppv < pv {
                                *acc |= pr.store_min_cas(v, ppv);
                            }
                        }
                    },
                    |a, b| a || b,
                );
            }
            if !hooked {
                break;
            }
        }
        RunResult::new(p.to_vec(), iters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{ground_truth, Algorithm};
    use crate::graph::gen;

    #[test]
    fn correct_on_suite() {
        for e in [
            gen::path(200),
            gen::cycle(99),
            gen::component_soup(5, 25, 7),
            gen::rmat(10, 3000, gen::RmatKind::Web, 1),
            gen::delaunay(400, 2),
        ] {
            let g = e.into_csr();
            assert_eq!(ShiloachVishkin::new().run(&g), ground_truth(&g));
        }
    }

    #[test]
    fn logarithmic_iterations() {
        let g = gen::path(4096).into_csr();
        let r = ShiloachVishkin::new().run_with_stats(&g);
        assert!(r.iterations <= 32, "iters {}", r.iterations);
    }
}
