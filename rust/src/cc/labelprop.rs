//! Synchronous label propagation over CSR adjacency — the classic
//! traversal-family baseline (§I). The paper observes it is the
//! mapping-order-one special case of Contour; we keep the CSR
//! formulation separate because its access pattern (per-vertex neighbor
//! scans) differs from Contour's edge-list sweeps.

use super::{Algorithm, AtomicLabels, RunResult};
use crate::graph::Csr;
use crate::par;
use crate::VId;

#[derive(Clone, Debug, Default)]
pub struct LabelPropagation {
    pub threads: usize,
}

impl LabelPropagation {
    pub fn new() -> Self {
        Self { threads: 0 }
    }
}

impl Algorithm for LabelPropagation {
    fn name(&self) -> String {
        "LabelProp".into()
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let n = g.n;
        // Classic *synchronous* label propagation: every vertex reads its
        // neighborhood from the previous round's labels (the behaviour the
        // paper contrasts Contour against; its iteration count tracks the
        // graph diameter exactly).
        let cur = AtomicLabels::identity(n);
        let next = AtomicLabels::identity(n);
        let mut iters = 0usize;
        loop {
            iters += 1;
            let (lr, lw) = (&cur, &next);
            let changed = par::par_map_reduce(
                n,
                self.threads,
                1 << 8,
                || false,
                |acc, range| {
                    for v in range {
                        let v = v as VId;
                        let mut m = lr.load(v);
                        for &w in g.neighbors(v) {
                            m = m.min(lr.load(w));
                        }
                        *acc |= m < lr.load(v);
                        lw.store_min_cas(v, m);
                    }
                },
                |a, b| a || b,
            );
            cur.copy_from(&next);
            if !changed {
                break;
            }
        }
        RunResult::new(cur.to_vec(), iters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{ground_truth, Algorithm};
    use crate::graph::gen;

    #[test]
    fn correct_on_suite() {
        for e in [
            gen::path(100),
            gen::star(64),
            gen::component_soup(6, 15, 9),
            gen::erdos_renyi(400, 700, 1),
        ] {
            let g = e.into_csr();
            assert_eq!(LabelPropagation::new().run(&g), ground_truth(&g));
        }
    }

    #[test]
    fn needs_many_iterations_on_long_paths() {
        // The §I observation motivating Contour: label propagation's
        // iteration count grows with the diameter.
        let short = gen::star(512).into_csr();
        let long = gen::path(512).into_csr();
        let i_short = LabelPropagation::new().run_with_stats(&short).iterations;
        let i_long = LabelPropagation::new().run_with_stats(&long).iterations;
        assert!(i_short <= 3);
        assert!(i_long >= 20, "path iters {}", i_long);
    }
}
