//! Union-find connectivity — the ConnectIt comparator.
//!
//! The paper benchmarks "the optimal union-find algorithm from the
//! ConnectIt framework", which Dhulipala et al. identify as **Rem's
//! algorithm with splicing** (after Patwary, Blair & Manne 2010). We
//! implement it three ways:
//!
//! * [`RemSequential`] — the plain sequential splicing loop.
//! * [`RemConcurrent`] — the lock-free CAS variant ConnectIt runs on
//!   shared-memory machines (what "ConnectIt" labels in our figures).
//! * [`RankUnionFind`] — textbook union-by-rank + path halving, as a
//!   sanity baseline.
//!
//! All three link toward *smaller* vertex ids, so the final root of each
//! component is its minimum vertex and labels match the other algorithms
//! without renaming. Iteration count is reported as 1 (§IV-C: "we assign
//! the iteration count for ConnectIt as 1").

use std::sync::atomic::{AtomicU32, Ordering};

use super::{Algorithm, Labels, RunResult};
use crate::graph::Csr;
use crate::par;
use crate::VId;

/// Sequential Rem's algorithm with splicing.
#[derive(Clone, Debug, Default)]
pub struct RemSequential;

impl RemSequential {
    fn unite(p: &mut [VId], u: VId, v: VId) {
        let (mut rx, mut ry) = (u, v);
        while p[rx as usize] != p[ry as usize] {
            // Work on the side with the larger parent (we link to smaller).
            if p[rx as usize] < p[ry as usize] {
                std::mem::swap(&mut rx, &mut ry);
            }
            if rx == p[rx as usize] {
                // rx is a root: link it below the smaller parent. Done.
                p[rx as usize] = p[ry as usize];
                return;
            }
            // Splice: redirect rx's parent pointer to the smaller parent
            // and climb. (Path-compressing as a side effect.)
            let z = p[rx as usize];
            p[rx as usize] = p[ry as usize];
            rx = z;
        }
    }
}

impl Algorithm for RemSequential {
    fn name(&self) -> String {
        "Rem-seq".into()
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let mut p: Labels = (0..g.n as VId).collect();
        for (u, v) in g.edges() {
            Self::unite(&mut p, u, v);
        }
        // Flatten to stars.
        for v in 0..g.n {
            let mut r = p[v];
            while p[r as usize] != r {
                r = p[r as usize];
            }
            p[v] = r;
        }
        RunResult::new(p, 1)
    }
}

/// Lock-free concurrent Rem's with CAS splicing (ConnectIt's
/// `unite_rem_cas` strategy) — the "ConnectIt" line in our figures.
#[derive(Clone, Debug, Default)]
pub struct RemConcurrent {
    pub threads: usize,
}

impl RemConcurrent {
    pub fn new() -> Self {
        Self { threads: 0 }
    }

    pub fn with_threads(mut self, t: usize) -> Self {
        self.threads = t;
        self
    }

    #[inline]
    pub(crate) fn unite(p: &[AtomicU32], u: VId, v: VId) {
        let (mut rx, mut ry) = (u, v);
        loop {
            let px = p[rx as usize].load(Ordering::Relaxed);
            let py = p[ry as usize].load(Ordering::Relaxed);
            if px == py {
                return;
            }
            if px < py {
                std::mem::swap(&mut rx, &mut ry);
                continue; // reload through the swapped roles
            }
            // px > py. Try to swing p[rx] from px down to py.
            if rx == px {
                // rx is (was) a root: CAS-link it under py.
                if p[rx as usize]
                    .compare_exchange(px, py, Ordering::Relaxed, Ordering::Relaxed)
                    .is_ok()
                {
                    return;
                }
                // Lost a race; retry from the same pair.
            } else {
                // Splice: swing and climb regardless of CAS success
                // (failure means someone lowered p[rx] — also progress).
                let _ = p[rx as usize].compare_exchange(
                    px,
                    py,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                );
                rx = px;
            }
        }
    }
}

impl Algorithm for RemConcurrent {
    fn name(&self) -> String {
        "ConnectIt".into()
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let n = g.n;
        let p: Vec<AtomicU32> = (0..n as u32).map(AtomicU32::new).collect();
        let src = &g.src;
        let dst = &g.dst;
        let pr = &p;
        par::par_for(g.m(), self.threads, par::AUTO_GRAIN, |range| {
            for e in range {
                Self::unite(pr, src[e], dst[e]);
            }
        });
        // Parallel flatten: pointer-jump every vertex to its root.
        par::par_for(n, self.threads, par::AUTO_GRAIN, |range| {
            for v in range {
                let mut r = pr[v].load(Ordering::Relaxed);
                loop {
                    let rr = pr[r as usize].load(Ordering::Relaxed);
                    if rr == r {
                        break;
                    }
                    r = rr;
                }
                pr[v].store(r, Ordering::Relaxed);
            }
        });
        RunResult::new(p.into_iter().map(|x| x.into_inner()).collect(), 1)
    }
}

/// Textbook union-by-rank with path halving (sanity baseline).
#[derive(Clone, Debug, Default)]
pub struct RankUnionFind;

impl Algorithm for RankUnionFind {
    fn name(&self) -> String {
        "UF-rank".into()
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let n = g.n;
        let mut p: Vec<VId> = (0..n as VId).collect();
        let mut rank = vec![0u8; n];
        let mut find = |p: &mut Vec<VId>, mut x: VId| -> VId {
            while p[x as usize] != x {
                p[x as usize] = p[p[x as usize] as usize]; // halving
                x = p[x as usize];
            }
            x
        };
        for (u, v) in g.edges() {
            let ru = find(&mut p, u);
            let rv = find(&mut p, v);
            if ru == rv {
                continue;
            }
            match rank[ru as usize].cmp(&rank[rv as usize]) {
                std::cmp::Ordering::Less => p[ru as usize] = rv,
                std::cmp::Ordering::Greater => p[rv as usize] = ru,
                std::cmp::Ordering::Equal => {
                    p[rv as usize] = ru;
                    rank[ru as usize] += 1;
                }
            }
        }
        let mut labels = vec![0 as VId; n];
        for v in 0..n {
            labels[v] = find(&mut p, v as VId);
        }
        // Rank-based roots are arbitrary; canonicalize to min-id form.
        RunResult::new(super::canonicalize(&labels), 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{ground_truth, Algorithm};
    use crate::graph::gen;

    fn suite() -> Vec<crate::graph::Csr> {
        vec![
            gen::path(500).into_csr(),
            gen::star(100).into_csr(),
            gen::component_soup(10, 30, 5).into_csr(),
            gen::erdos_renyi(1000, 1500, 6).into_csr(),
            gen::rmat(11, 8000, gen::RmatKind::Graph500, 7).into_csr(),
            gen::delaunay(600, 8).into_csr(),
        ]
    }

    #[test]
    fn rem_sequential_correct() {
        for g in suite() {
            assert_eq!(RemSequential.run(&g), ground_truth(&g));
        }
    }

    #[test]
    fn rem_concurrent_correct_across_threads() {
        for g in suite() {
            let want = ground_truth(&g);
            for t in [1, 2, 8] {
                assert_eq!(RemConcurrent::new().with_threads(t).run(&g), want, "t={t}");
            }
        }
    }

    #[test]
    fn rank_uf_correct() {
        for g in suite() {
            assert_eq!(RankUnionFind.run(&g), ground_truth(&g));
        }
    }

    #[test]
    fn reports_single_iteration() {
        let g = gen::path(64).into_csr();
        assert_eq!(RemSequential.run_with_stats(&g).iterations, 1);
        assert_eq!(RemConcurrent::new().run_with_stats(&g).iterations, 1);
    }

    /// Stress the lock-free unite under heavy contention: many threads,
    /// one component, star-shaped so every unite hits vertex 0.
    #[test]
    fn concurrent_contention_stress() {
        let g = gen::star(20_000).into_csr();
        for seed in 0..3 {
            let got = RemConcurrent::new().with_threads(8).run(&g);
            assert!(got.iter().all(|&l| l == 0), "seed {seed}");
        }
    }
}
