//! Afforest (Sutton, Ben-Nun & Barak, IPDPS 2018) — subgraph-sampling
//! connectivity, the related-work extension the paper cites (§V):
//! union a few neighbors of every vertex first, detect the emerging
//! giant component by sampling, then only process the remaining edges of
//! vertices outside it.

use std::sync::atomic::{AtomicU32, Ordering};

use super::{unionfind::RemConcurrent, Algorithm, RunResult};
use crate::graph::Csr;
use crate::par;
use crate::util::Xoshiro256;
use crate::VId;

#[derive(Clone, Debug)]
pub struct Afforest {
    /// Neighbor rounds in the sampling phase (paper default: 2).
    pub sample_rounds: usize,
    /// Vertices sampled to guess the giant component (paper: 1024).
    pub sample_size: usize,
    pub threads: usize,
    pub seed: u64,
}

impl Default for Afforest {
    fn default() -> Self {
        Self { sample_rounds: 2, sample_size: 1024, threads: 0, seed: 0xAFF0 }
    }
}

impl Afforest {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    fn find(p: &[AtomicU32], mut x: VId) -> VId {
        loop {
            let px = p[x as usize].load(Ordering::Relaxed);
            if px == x {
                return x;
            }
            // Path halving.
            let ppx = p[px as usize].load(Ordering::Relaxed);
            let _ = p[x as usize].compare_exchange(px, ppx, Ordering::Relaxed, Ordering::Relaxed);
            x = px;
        }
    }
}

impl Algorithm for Afforest {
    fn name(&self) -> String {
        "Afforest".into()
    }

    fn run_with_stats(&self, g: &Csr) -> RunResult {
        let n = g.n;
        let t = self.threads;
        let p: Vec<AtomicU32> = (0..n as u32).map(AtomicU32::new).collect();
        let pr = &p;
        // Phase 1: union each vertex with its first `sample_rounds`
        // neighbors (covers most of the giant component cheaply).
        for r in 0..self.sample_rounds {
            par::par_for(n, t, par::AUTO_GRAIN, |range| {
                for v in range {
                    let nb = g.neighbors(v as VId);
                    if let Some(&w) = nb.get(r) {
                        RemConcurrent::unite(pr, v as VId, w);
                    }
                }
            });
        }
        // Phase 2: sample to find the most frequent (giant) root.
        let mut rng = Xoshiro256::new(self.seed);
        let mut counts = std::collections::HashMap::<VId, usize>::new();
        for _ in 0..self.sample_size.min(n.max(1)) {
            let v = rng.below(n.max(1) as u64) as VId;
            *counts.entry(Self::find(pr, v)).or_insert(0) += 1;
        }
        let giant = counts.into_iter().max_by_key(|&(_, c)| c).map(|(r, _)| r);
        // Phase 3: finish the remaining adjacency of non-giant vertices.
        par::par_for(n, t, par::AUTO_GRAIN, |range| {
            for v in range {
                if Some(Self::find(pr, v as VId)) == giant {
                    continue; // already in the giant component
                }
                for (i, &w) in g.neighbors(v as VId).iter().enumerate() {
                    if i < self.sample_rounds {
                        continue; // done in phase 1
                    }
                    RemConcurrent::unite(pr, v as VId, w);
                }
            }
        });
        // Flatten.
        par::par_for(n, t, par::AUTO_GRAIN, |range| {
            for v in range {
                let r = Self::find(pr, v as VId);
                pr[v].store(r, Ordering::Relaxed);
            }
        });
        RunResult::new(p.into_iter().map(|x| x.into_inner()).collect(), 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc::{ground_truth, Algorithm};
    use crate::graph::gen;

    #[test]
    fn correct_on_suite() {
        for e in [
            gen::path(300),
            gen::star(128),
            gen::component_soup(7, 40, 3),
            gen::erdos_renyi(1000, 2000, 4),
            gen::rmat(11, 10_000, gen::RmatKind::Graph500, 5),
            gen::delaunay(500, 6),
        ] {
            let g = e.into_csr();
            assert_eq!(Afforest::new().run(&g), ground_truth(&g), "n={}", g.n);
        }
    }

    #[test]
    fn giant_component_skip_does_not_skip_merges() {
        // Two equal halves: the "giant" guess covers only one; the other
        // must still be completed by phase 3.
        let mut e = gen::path(100);
        e.n = 200;
        for i in 101..200 {
            e.push((i - 1) as VId, i as VId);
        }
        let g = e.into_csr();
        assert_eq!(Afforest::new().run(&g), ground_truth(&g));
    }

    #[test]
    fn across_thread_counts() {
        let g = gen::barabasi_albert(3000, 3, 8).into_csr();
        let want = ground_truth(&g);
        for t in [1, 4, 8] {
            let alg = Afforest { threads: t, ..Default::default() };
            assert_eq!(alg.run(&g), want, "t={t}");
        }
    }
}
