//! Minimal CLI argument parser (the image has no `clap`): positional
//! subcommand plus `--key value` / `--flag` options.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (first is usually the subcommand).
    pub positional: Vec<String>,
    /// `--key value` pairs; bare `--flag` maps to "true".
    pub options: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of tokens (e.g. `std::env::args().skip(1)`).
    /// A `--key` followed by another `--...` or end-of-args is a boolean
    /// flag; otherwise it consumes the next token as its value.
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = tokens.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(key) = tok.strip_prefix("--") {
                if key.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| n.starts_with("--")).unwrap_or(true) {
                    out.options.insert(key.to_string(), "true".to_string());
                } else {
                    out.options.insert(key.to_string(), it.next().unwrap());
                }
            } else {
                out.positional.push(tok);
            }
        }
        Ok(out)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got {v:?}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("bench fig1 --threads 8 --out results");
        assert_eq!(a.subcommand(), Some("bench"));
        assert_eq!(a.positional, vec!["bench", "fig1"]);
        assert_eq!(a.get("threads"), Some("8"));
        assert_eq!(a.get_usize("threads", 1).unwrap(), 8);
        assert_eq!(a.get_or("out", "x"), "results");
    }

    #[test]
    fn boolean_flags_and_equals() {
        let a = parse("run --quick --alg=C-2 --verbose --n 10");
        assert!(a.flag("quick"));
        assert!(a.flag("verbose"));
        assert_eq!(a.get("alg"), Some("C-2"));
        assert_eq!(a.get_usize("n", 0).unwrap(), 10);
        assert!(!a.flag("absent"));
    }

    #[test]
    fn errors() {
        assert!(Args::parse(["--".to_string()]).is_err());
        let a = parse("x --n ten");
        assert!(a.get_usize("n", 1).is_err());
    }
}
