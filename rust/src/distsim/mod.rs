//! Distributed-memory simulator for §IV-G.
//!
//! The paper's distributed results come from a 32-node InfiniBand
//! cluster running Chapel; §IV-G reports only *relative trends*. This
//! module reproduces those trends with an explicit cost model instead of
//! real hardware (DESIGN.md §5):
//!
//! * vertices are block-partitioned across `p` nodes (label ownership);
//! * edges are block-partitioned (work ownership);
//! * one BSP superstep = every node sweeps its edge shard with MM^h,
//!   counting **remote label reads** (a gather of `L[x]` whose owner is
//!   another node — exactly the GET traffic a PGAS/Chapel program pays)
//!   and **remote conditional writes**;
//! * superstep time = max-shard compute (measured) + α·(messages) +
//!   β·(bytes), α/β defaulting to InfiniBand-class constants.
//!
//! The §IV-G claims this exposes: C-1 touches only `L[w], L[v]` per edge
//! (1 potential remote read per endpoint) so its per-iteration
//! communication is minimal; higher orders chase pointers across nodes
//! (more gets per edge, fewer supersteps); ConnectIt-style union-find
//! pays fine-grained remote CAS traffic.

use crate::graph::Csr;
use crate::util::Timer;
use crate::VId;

/// Network cost model (seconds). Defaults approximate FDR InfiniBand:
/// ~2 µs per message batch, ~10 GB/s effective bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Per-byte transfer cost (seconds).
    pub beta: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        Self { alpha: 2e-6, beta: 1.0 / 10e9 }
    }
}

/// Per-run communication + time accounting.
#[derive(Clone, Debug, Default)]
pub struct DistReport {
    pub nodes: usize,
    pub supersteps: usize,
    /// Remote label reads (aggregated over all nodes and supersteps).
    pub remote_reads: u64,
    /// Remote conditional-assignment writes.
    pub remote_writes: u64,
    /// Total modeled bytes moved.
    pub bytes: u64,
    /// Measured local compute, max over shards, summed over supersteps.
    pub compute_secs: f64,
    /// Modeled communication time.
    pub comm_secs: f64,
}

impl DistReport {
    pub fn modeled_total(&self) -> f64 {
        self.compute_secs + self.comm_secs
    }
}

/// Which distributed algorithm to simulate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DistAlgorithm {
    /// Synchronous distributed Contour with operator order h.
    Contour { hops: usize },
    /// Distributed FastSV (hook + shortcut, replicated gf gathers).
    FastSv,
    /// Union-find with remote CAS per cross-shard edge (ConnectIt-style).
    UnionFind,
}

impl DistAlgorithm {
    pub fn name(&self) -> String {
        match self {
            DistAlgorithm::Contour { hops: 1 } => "C-1".into(),
            DistAlgorithm::Contour { hops: 2 } => "C-2".into(),
            DistAlgorithm::Contour { hops } => format!("C-m({hops})"),
            DistAlgorithm::FastSv => "FastSV".into(),
            DistAlgorithm::UnionFind => "ConnectIt".into(),
        }
    }
}

/// Block vertex partition: owner(v) = v / ceil(n/p).
#[inline]
fn owner(v: VId, block: usize) -> usize {
    v as usize / block
}

/// Simulate `alg` on `g` over `p` nodes. Runs the actual algorithm
/// (synchronous variants) while accounting remote traffic per the model.
pub fn simulate(g: &Csr, p: usize, alg: DistAlgorithm, cost: CostModel) -> DistReport {
    assert!(p >= 1);
    let n = g.n;
    let block = n.div_ceil(p).max(1);
    let mut report = DistReport { nodes: p, ..Default::default() };
    match alg {
        DistAlgorithm::Contour { hops } => simulate_contour(g, p, block, hops, cost, &mut report),
        DistAlgorithm::FastSv => simulate_fastsv(g, p, block, cost, &mut report),
        DistAlgorithm::UnionFind => simulate_unionfind(g, p, block, cost, &mut report),
    }
    report
}

/// Account one superstep's comm into the report: every node exchanges its
/// remote requests in one batched message round (PGAS aggregation).
fn account_superstep(
    report: &mut DistReport,
    cost: CostModel,
    p: usize,
    reads: u64,
    writes: u64,
    compute: f64,
) {
    report.supersteps += 1;
    report.remote_reads += reads;
    report.remote_writes += writes;
    // A read moves 8 B request + 4 B reply; a write moves 8 B + 4 B value.
    let bytes = reads * 12 + writes * 12;
    report.bytes += bytes;
    // One batched all-to-all per superstep: p·(p−1) messages.
    report.comm_secs += cost.alpha * (p.saturating_sub(1) * p) as f64 + cost.beta * bytes as f64;
    report.compute_secs += compute;
}

fn simulate_contour(
    g: &Csr,
    p: usize,
    block: usize,
    hops: usize,
    cost: CostModel,
    report: &mut DistReport,
) {
    let n = g.n;
    let m = g.m();
    let mut labels: Vec<VId> = (0..n as VId).collect();
    let shard = m.div_ceil(p).max(1);
    loop {
        let mut next = labels.clone();
        let mut reads = 0u64;
        let mut writes = 0u64;
        let mut max_compute = 0.0f64;
        let mut changed = false;
        for node in 0..p {
            let t = Timer::start();
            let lo = node * shard;
            let hi = ((node + 1) * shard).min(m);
            for e in lo..hi {
                let (w, v) = (g.src[e], g.dst[e]);
                // Chase with remote-read accounting: the first hop reads
                // L[w]; every further hop reads L[cur].
                let mut chase = |mut cur: VId, reads: &mut u64| {
                    if owner(cur, block) != node {
                        *reads += 1;
                    }
                    let mut val = labels[cur as usize];
                    for _ in 1..hops {
                        if val == cur {
                            break;
                        }
                        cur = val;
                        if owner(cur, block) != node {
                            *reads += 1;
                        }
                        val = labels[cur as usize];
                    }
                    val
                };
                let zw = chase(w, &mut reads);
                let zv = chase(v, &mut reads);
                let z = zw.min(zv);
                for mut x in [w, v] {
                    for _ in 0..hops {
                        let nxt = labels[x as usize];
                        if next[x as usize] > z {
                            next[x as usize] = z;
                            changed = true;
                            if owner(x, block) != node {
                                writes += 1;
                            }
                        }
                        if nxt == x {
                            break;
                        }
                        x = nxt;
                    }
                }
            }
            max_compute = max_compute.max(t.secs());
        }
        account_superstep(report, cost, p, reads, writes, max_compute);
        labels = next;
        if !changed {
            break;
        }
    }
}

fn simulate_fastsv(g: &Csr, p: usize, block: usize, cost: CostModel, report: &mut DistReport) {
    let n = g.n;
    let m = g.m();
    let mut f: Vec<VId> = (0..n as VId).collect();
    let shard = m.div_ceil(p).max(1);
    loop {
        // gf gather: every node needs f[f[v]] for its shard's endpoints.
        let gf: Vec<VId> = f.iter().map(|&x| f[x as usize]).collect();
        let mut fnext = f.clone();
        let mut reads = 0u64;
        let mut writes = 0u64;
        let mut max_compute = 0.0f64;
        for node in 0..p {
            let t = Timer::start();
            let lo = node * shard;
            let hi = ((node + 1) * shard).min(m);
            for e in lo..hi {
                let (u, v) = (g.src[e], g.dst[e]);
                // f[u], f[v] reads + gf indirections (two hops each).
                for &x in &[u, v] {
                    if owner(x, block) != node {
                        reads += 1;
                    }
                    if owner(f[x as usize], block) != node {
                        reads += 1;
                    }
                }
                let mut hook = |target: VId, val: VId| {
                    if fnext[target as usize] > val {
                        fnext[target as usize] = val;
                        if owner(target, block) != node {
                            writes += 1;
                        }
                    }
                };
                hook(f[u as usize], gf[v as usize]);
                hook(f[v as usize], gf[u as usize]);
                hook(u, gf[v as usize]);
                hook(v, gf[u as usize]);
            }
            // Shortcut over owned vertices (local).
            let vlo = node * block;
            let vhi = ((node + 1) * block).min(n);
            for x in vlo..vhi {
                if fnext[x] > gf[x] {
                    fnext[x] = gf[x];
                }
            }
            max_compute = max_compute.max(t.secs());
        }
        let changed = f != fnext;
        account_superstep(report, cost, p, reads, writes, max_compute);
        f = fnext;
        if !changed {
            break;
        }
    }
}

fn simulate_unionfind(g: &Csr, p: usize, block: usize, cost: CostModel, report: &mut DistReport) {
    // Union-find completes in "one iteration" but every find chases
    // parent pointers across node boundaries with fine-grained gets, and
    // every cross-boundary link is a remote CAS.
    let n = g.n;
    let m = g.m();
    let mut parent: Vec<VId> = (0..n as VId).collect();
    let shard = m.div_ceil(p).max(1);
    let mut reads = 0u64;
    let mut writes = 0u64;
    let mut max_compute = 0.0f64;
    for node in 0..p {
        let t = Timer::start();
        let lo = node * shard;
        let hi = ((node + 1) * shard).min(m);
        for e in lo..hi {
            let (u, v) = (g.src[e], g.dst[e]);
            // Rem's splicing loop with remote accounting.
            let (mut rx, mut ry) = (u, v);
            loop {
                for r in [rx, ry] {
                    if owner(r, block) != node {
                        reads += 1;
                    }
                }
                let (px, py) = (parent[rx as usize], parent[ry as usize]);
                if px == py {
                    break;
                }
                if px < py {
                    std::mem::swap(&mut rx, &mut ry);
                    continue;
                }
                if rx == px {
                    parent[rx as usize] = py;
                    if owner(rx, block) != node {
                        writes += 1;
                    }
                    break;
                }
                let z = parent[rx as usize];
                parent[rx as usize] = py;
                if owner(rx, block) != node {
                    writes += 1;
                }
                rx = z;
            }
        }
        max_compute = max_compute.max(t.secs());
    }
    account_superstep(report, cost, p, reads, writes, max_compute);
    // Final flatten (local pointer jumping, negligible comm modeled).
    for v in 0..n {
        let mut r = parent[v];
        while parent[r as usize] != r {
            r = parent[r as usize];
        }
        parent[v] = r;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn single_node_has_no_remote_traffic() {
        let g = gen::erdos_renyi(500, 1000, 1).into_csr();
        let r = simulate(&g, 1, DistAlgorithm::Contour { hops: 2 }, CostModel::default());
        assert_eq!(r.remote_reads, 0);
        assert_eq!(r.remote_writes, 0);
        assert!(r.supersteps >= 1);
    }

    #[test]
    fn more_nodes_more_traffic() {
        let g = gen::rmat(11, 10_000, gen::RmatKind::Graph500, 2).into_csr();
        let r2 = simulate(&g, 2, DistAlgorithm::Contour { hops: 2 }, CostModel::default());
        let r8 = simulate(&g, 8, DistAlgorithm::Contour { hops: 2 }, CostModel::default());
        assert!(r8.remote_reads > r2.remote_reads);
    }

    #[test]
    fn c1_fewer_remote_reads_per_superstep_than_c2() {
        // §IV-G: C-1's locality => less communication per iteration.
        let g = gen::delaunay(2000, 3).into_csr().shuffled_edges(1);
        let r1 = simulate(&g, 4, DistAlgorithm::Contour { hops: 1 }, CostModel::default());
        let r2 = simulate(&g, 4, DistAlgorithm::Contour { hops: 2 }, CostModel::default());
        let per1 = r1.remote_reads as f64 / r1.supersteps as f64;
        let per2 = r2.remote_reads as f64 / r2.supersteps as f64;
        assert!(per1 < per2, "C-1 {per1:.0}/step vs C-2 {per2:.0}/step");
        // ...but C-2 takes fewer supersteps.
        assert!(r2.supersteps <= r1.supersteps);
    }

    #[test]
    fn unionfind_single_superstep() {
        let g = gen::erdos_renyi(400, 900, 5).into_csr();
        let r = simulate(&g, 4, DistAlgorithm::UnionFind, CostModel::default());
        assert_eq!(r.supersteps, 1);
        assert!(r.remote_reads > 0);
    }

    #[test]
    fn fastsv_converges_with_traffic() {
        let g = gen::path(600).into_csr().shuffled_edges(2);
        let r = simulate(&g, 4, DistAlgorithm::FastSv, CostModel::default());
        assert!(r.supersteps >= 5, "supersteps {}", r.supersteps);
        assert!(r.bytes > 0);
        assert!(r.modeled_total() > 0.0);
    }
}
