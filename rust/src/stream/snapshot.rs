//! Immutable per-epoch label snapshots and their binary on-disk format.
//!
//! A [`Snapshot`] is what the streaming service publishes at each epoch
//! seal: the canonical min-vertex-id labelling produced by the
//! re-contour compaction, plus the derived component-size table. Once
//! built it is never mutated — readers hold it through an `Arc` and
//! answer `SAME_COMP` / `COMP_SIZE` / `NUM_COMPS` without touching the
//! ingestion path.
//!
//! Disk layout (little-endian):
//!
//! ```text
//!   "CONTRSS1"  epoch: u64  edges_ingested: u64  n: u64  labels: u32 × n
//! ```

use std::collections::HashMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::cc::Labels;
use crate::VId;

const SNAP_MAGIC: &[u8; 8] = b"CONTRSS1";

/// One epoch's immutable connectivity view.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Epoch number (0 is the empty pre-ingestion epoch).
    pub epoch: u64,
    /// Edge insertions acknowledged up to the seal (duplicates counted).
    pub edges_ingested: usize,
    /// Canonical labelling: `labels[v]` = min vertex id in v's component.
    pub labels: Labels,
    pub num_components: usize,
    sizes: HashMap<VId, u32>,
}

impl Snapshot {
    /// Build from a canonical min-id labelling (O(n): derives the
    /// component-size table and count).
    pub fn from_labels(epoch: u64, edges_ingested: usize, labels: Labels) -> Self {
        let mut sizes: HashMap<VId, u32> = HashMap::new();
        for &l in &labels {
            *sizes.entry(l).or_insert(0) += 1;
        }
        let num_components = sizes.len();
        Self { epoch, edges_ingested, labels, num_components, sizes }
    }

    pub fn n(&self) -> usize {
        self.labels.len()
    }

    fn check(&self, v: VId) -> Result<()> {
        ensure!((v as usize) < self.labels.len(), "vertex {v} out of range (n = {})", self.n());
        Ok(())
    }

    /// Component label (= min vertex id of the component) of `v`.
    pub fn label(&self, v: VId) -> Result<VId> {
        self.check(v)?;
        Ok(self.labels[v as usize])
    }

    /// Are `u` and `v` in the same component at this epoch?
    pub fn same_comp(&self, u: VId, v: VId) -> Result<bool> {
        Ok(self.label(u)? == self.label(v)?)
    }

    /// Size of `v`'s component at this epoch.
    pub fn comp_size(&self, v: VId) -> Result<usize> {
        let l = self.label(v)?;
        Ok(self.sizes[&l] as usize)
    }

    /// Write the snapshot to `path` (fsynced).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create snapshot dir {}", dir.display()))?;
            }
        }
        let f = File::create(path)
            .with_context(|| format!("create snapshot {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(SNAP_MAGIC)?;
        w.write_all(&self.epoch.to_le_bytes())?;
        w.write_all(&(self.edges_ingested as u64).to_le_bytes())?;
        w.write_all(&(self.labels.len() as u64).to_le_bytes())?;
        for &l in &self.labels {
            w.write_all(&l.to_le_bytes())?;
        }
        w.flush()?;
        w.get_ref().sync_all()?;
        Ok(())
    }

    /// Load and validate a snapshot written by [`Snapshot::save`].
    pub fn load(path: &Path) -> Result<Snapshot> {
        let data =
            std::fs::read(path).with_context(|| format!("read snapshot {}", path.display()))?;
        ensure!(
            data.len() >= 32 && &data[..8] == SNAP_MAGIC,
            "{}: not a contour snapshot",
            path.display()
        );
        let epoch = u64::from_le_bytes(data[8..16].try_into().unwrap());
        let edges = u64::from_le_bytes(data[16..24].try_into().unwrap()) as usize;
        let n = u64::from_le_bytes(data[24..32].try_into().unwrap()) as usize;
        ensure!(
            data.len() == 32 + 4 * n,
            "{}: truncated snapshot (declares n = {n})",
            path.display()
        );
        let labels: Labels = data[32..]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        for (v, &l) in labels.iter().enumerate() {
            ensure!(
                (l as usize) <= v && labels[l as usize] == l,
                "{}: label table not canonical at vertex {v}",
                path.display()
            );
        }
        Ok(Snapshot::from_labels(epoch, edges, labels))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("contour_snapshot_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn query_api_over_a_labelling() {
        // Components {0,1,2}, {3}, {4,5}.
        let s = Snapshot::from_labels(3, 9, vec![0, 0, 0, 3, 4, 4]);
        assert_eq!(s.epoch, 3);
        assert_eq!(s.num_components, 3);
        assert!(s.same_comp(1, 2).unwrap());
        assert!(!s.same_comp(2, 3).unwrap());
        assert_eq!(s.comp_size(1).unwrap(), 3);
        assert_eq!(s.comp_size(3).unwrap(), 1);
        assert_eq!(s.label(5).unwrap(), 4);
        assert!(s.label(6).is_err());
        assert!(s.same_comp(0, 99).is_err());
    }

    #[test]
    fn save_load_round_trip() {
        let p = temp("round_trip.snap");
        let s = Snapshot::from_labels(7, 42, vec![0, 0, 2, 2, 2, 5]);
        s.save(&p).unwrap();
        let back = Snapshot::load(&p).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.edges_ingested, 42);
        assert_eq!(back.labels, s.labels);
        assert_eq!(back.num_components, 3);
        assert_eq!(back.comp_size(4).unwrap(), 3);
    }

    #[test]
    fn load_rejects_garbage_and_non_canonical_tables() {
        let p = temp("garbage.snap");
        std::fs::write(&p, b"not a snapshot at all........").unwrap();
        assert!(Snapshot::load(&p).is_err());

        // Valid header, non-canonical labels (vertex 1 labelled above itself).
        let q = temp("non_canonical.snap");
        let s = Snapshot::from_labels(1, 1, vec![0, 0, 2]);
        s.save(&q).unwrap();
        let mut data = std::fs::read(&q).unwrap();
        data[32 + 4..32 + 8].copy_from_slice(&2u32.to_le_bytes()); // labels[1] = 2
        std::fs::write(&q, &data).unwrap();
        assert!(Snapshot::load(&q).is_err());

        // Truncated payload.
        let r = temp("truncated.snap");
        s.save(&r).unwrap();
        let data = std::fs::read(&r).unwrap();
        std::fs::write(&r, &data[..data.len() - 2]).unwrap();
        assert!(Snapshot::load(&r).is_err());
    }
}
