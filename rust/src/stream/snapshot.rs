//! Immutable per-epoch label snapshots and their binary on-disk format.
//!
//! A [`Snapshot`] is what the streaming service publishes at each epoch
//! seal: the canonical min-vertex-id labelling produced by the
//! re-contour compaction, plus the derived component-size table. Once
//! built it is never mutated — readers hold it through an `Arc` and
//! answer `SAME_COMP` / `COMP_SIZE` / `NUM_COMPS` without touching the
//! ingestion path.
//!
//! Disk layout (little-endian), three versions:
//!
//! ```text
//!   v1:  "CONTRSS1"  epoch: u64  edges_ingested: u64  n: u64  labels: u32 × n
//!   v2:  "CONTRSS2"  ── same fields ──                        crc: u32
//!        (CRC-32/IEEE over every byte before the trailer)
//!   v3:  "CONTRSS3"  epoch: u64  edges_ingested: u64  edges_live: u64
//!                    n: u64  labels: u32 × n  crc: u32
//! ```
//!
//! v3 adds the live-edge count (insertions minus accepted deletions) so
//! a recovered stream reports honest occupancy. New snapshots are
//! written as v3 and crash-safely: the bytes go to a `<path>.tmp`
//! sibling which is fsynced, atomically renamed over `path`, and the
//! parent directory fsynced — a crash mid-save can never leave a
//! half-written snapshot under the real name, and the rename itself is
//! durable. v1/v2 files remain loadable (their live count defaults to
//! the ingested count — those formats predate deletions).

use std::collections::HashMap;
use std::fs::File;
use std::io::Write;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::cc::Labels;
use crate::util::{crc, faults};
use crate::VId;

const SNAP_MAGIC_V1: &[u8; 8] = b"CONTRSS1";
const SNAP_MAGIC_V2: &[u8; 8] = b"CONTRSS2";
const SNAP_MAGIC_V3: &[u8; 8] = b"CONTRSS3";

/// One epoch's immutable connectivity view.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Epoch number (0 is the empty pre-ingestion epoch).
    pub epoch: u64,
    /// Edge insertions accepted up to the seal (parallel edges counted,
    /// self-loops never admitted).
    pub edges_ingested: usize,
    /// Edges live at the seal: `edges_ingested` minus accepted
    /// deletions. Equal to `edges_ingested` on insert-only streams.
    pub edges_live: usize,
    /// Canonical labelling: `labels[v]` = min vertex id in v's component.
    pub labels: Labels,
    pub num_components: usize,
    sizes: HashMap<VId, u32>,
}

impl Snapshot {
    /// Build from a canonical min-id labelling (O(n): derives the
    /// component-size table and count). The live-edge count defaults to
    /// `edges_ingested`; delete-capable callers set it with
    /// [`Snapshot::with_edges_live`].
    pub fn from_labels(epoch: u64, edges_ingested: usize, labels: Labels) -> Self {
        let mut sizes: HashMap<VId, u32> = HashMap::new();
        for &l in &labels {
            *sizes.entry(l).or_insert(0) += 1;
        }
        let num_components = sizes.len();
        Self { epoch, edges_ingested, edges_live: edges_ingested, labels, num_components, sizes }
    }

    /// Set the live-edge count (insertions minus accepted deletions).
    pub fn with_edges_live(mut self, live: usize) -> Self {
        self.edges_live = live;
        self
    }

    pub fn n(&self) -> usize {
        self.labels.len()
    }

    fn check(&self, v: VId) -> Result<()> {
        ensure!((v as usize) < self.labels.len(), "vertex {v} out of range (n = {})", self.n());
        Ok(())
    }

    /// Component label (= min vertex id of the component) of `v`.
    pub fn label(&self, v: VId) -> Result<VId> {
        self.check(v)?;
        Ok(self.labels[v as usize])
    }

    /// Are `u` and `v` in the same component at this epoch?
    pub fn same_comp(&self, u: VId, v: VId) -> Result<bool> {
        Ok(self.label(u)? == self.label(v)?)
    }

    /// Size of `v`'s component at this epoch.
    pub fn comp_size(&self, v: VId) -> Result<usize> {
        let l = self.label(v)?;
        Ok(self.sizes[&l] as usize)
    }

    /// Write the snapshot to `path` crash-safely: checksummed v3 bytes to
    /// `<path>.tmp` (fsynced), then atomic rename over `path`, then fsync
    /// of the parent directory so the new name survives a crash.
    ///
    /// Failpoint `snap.save`: `err` fails after the tmp write but before
    /// the rename — the previous snapshot under `path` is untouched.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create snapshot dir {}", dir.display()))?;
            }
        }
        let mut data = Vec::with_capacity(40 + 4 * self.labels.len() + 4);
        data.extend_from_slice(SNAP_MAGIC_V3);
        data.extend_from_slice(&self.epoch.to_le_bytes());
        data.extend_from_slice(&(self.edges_ingested as u64).to_le_bytes());
        data.extend_from_slice(&(self.edges_live as u64).to_le_bytes());
        data.extend_from_slice(&(self.labels.len() as u64).to_le_bytes());
        for &l in &self.labels {
            data.extend_from_slice(&l.to_le_bytes());
        }
        let crc = crc::crc32(&data);
        data.extend_from_slice(&crc.to_le_bytes());

        let tmp = tmp_path(path);
        {
            let mut f = File::create(&tmp)
                .with_context(|| format!("create snapshot tmp {}", tmp.display()))?;
            f.write_all(&data)?;
            f.sync_all()?;
        }
        if faults::hit("snap.save")? {
            return Ok(()); // drop: simulate a crash between write and rename
        }
        std::fs::rename(&tmp, path).with_context(|| {
            format!("rename snapshot {} -> {}", tmp.display(), path.display())
        })?;
        sync_parent_dir(path)?;
        Ok(())
    }

    /// Load and validate a snapshot written by [`Snapshot::save`] (either
    /// on-disk version). A v2 checksum mismatch fails loudly.
    pub fn load(path: &Path) -> Result<Snapshot> {
        let mut data =
            std::fs::read(path).with_context(|| format!("read snapshot {}", path.display()))?;
        ensure!(data.len() >= 32, "{}: not a contour snapshot", path.display());
        let ver: u8 = match &data[..8] {
            m if m == SNAP_MAGIC_V3 => 3,
            m if m == SNAP_MAGIC_V2 => 2,
            m if m == SNAP_MAGIC_V1 => 1,
            _ => anyhow::bail!("{}: not a contour snapshot", path.display()),
        };
        let head = if ver >= 3 { 40usize } else { 32 };
        if ver >= 2 {
            ensure!(data.len() >= head + 4, "{}: truncated snapshot", path.display());
            let at = data.len() - 4;
            let stored = u32::from_le_bytes(data[at..].try_into().unwrap());
            let actual = crc::crc32(&data[..at]);
            ensure!(
                stored == actual,
                "{}: snapshot checksum mismatch (stored {stored:#010x}, computed {actual:#010x})",
                path.display()
            );
            data.truncate(at);
        }
        ensure!(data.len() >= head, "{}: truncated snapshot", path.display());
        let epoch = u64::from_le_bytes(data[8..16].try_into().unwrap());
        let edges = u64::from_le_bytes(data[16..24].try_into().unwrap()) as usize;
        // v3 inserts the live-edge count before n; older formats predate
        // deletions, so everything ingested is live.
        let (live, npos) = if ver >= 3 {
            (u64::from_le_bytes(data[24..32].try_into().unwrap()) as usize, 32)
        } else {
            (edges, 24)
        };
        let n = u64::from_le_bytes(data[npos..npos + 8].try_into().unwrap()) as usize;
        ensure!(
            data.len() == head + 4 * n,
            "{}: truncated snapshot (declares n = {n})",
            path.display()
        );
        let labels: Labels = data[head..]
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        for (v, &l) in labels.iter().enumerate() {
            ensure!(
                (l as usize) <= v && labels[l as usize] == l,
                "{}: label table not canonical at vertex {v}",
                path.display()
            );
        }
        Ok(Snapshot::from_labels(epoch, edges, labels).with_edges_live(live))
    }
}

fn tmp_path(path: &Path) -> std::path::PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// fsync the directory containing `path` so a just-renamed entry is
/// durable (directory metadata is not covered by the file's own fsync).
fn sync_parent_dir(path: &Path) -> Result<()> {
    let dir = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d,
        _ => Path::new("."),
    };
    File::open(dir)
        .and_then(|f| f.sync_all())
        .with_context(|| format!("fsync snapshot dir {}", dir.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("contour_snapshot_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Hand-build a v1 snapshot (no checksum trailer) to pin compat.
    fn write_v1(path: &Path, epoch: u64, edges: u64, labels: &[u32]) {
        let mut data = Vec::new();
        data.extend_from_slice(SNAP_MAGIC_V1);
        data.extend_from_slice(&epoch.to_le_bytes());
        data.extend_from_slice(&edges.to_le_bytes());
        data.extend_from_slice(&(labels.len() as u64).to_le_bytes());
        for &l in labels {
            data.extend_from_slice(&l.to_le_bytes());
        }
        std::fs::write(path, data).unwrap();
    }

    #[test]
    fn query_api_over_a_labelling() {
        // Components {0,1,2}, {3}, {4,5}.
        let s = Snapshot::from_labels(3, 9, vec![0, 0, 0, 3, 4, 4]);
        assert_eq!(s.epoch, 3);
        assert_eq!(s.num_components, 3);
        assert!(s.same_comp(1, 2).unwrap());
        assert!(!s.same_comp(2, 3).unwrap());
        assert_eq!(s.comp_size(1).unwrap(), 3);
        assert_eq!(s.comp_size(3).unwrap(), 1);
        assert_eq!(s.label(5).unwrap(), 4);
        assert!(s.label(6).is_err());
        assert!(s.same_comp(0, 99).is_err());
    }

    #[test]
    fn save_load_round_trip() {
        let p = temp("round_trip.snap");
        let s = Snapshot::from_labels(7, 42, vec![0, 0, 2, 2, 2, 5]).with_edges_live(37);
        s.save(&p).unwrap();
        let back = Snapshot::load(&p).unwrap();
        assert_eq!(back.epoch, 7);
        assert_eq!(back.edges_ingested, 42);
        assert_eq!(back.edges_live, 37);
        assert_eq!(back.labels, s.labels);
        assert_eq!(back.num_components, 3);
        assert_eq!(back.comp_size(4).unwrap(), 3);
        // The tmp sibling is gone after a successful save.
        assert!(!tmp_path(&p).exists());
    }

    #[test]
    fn v1_snapshots_still_load() {
        let p = temp("compat_v1.snap");
        write_v1(&p, 5, 17, &[0, 0, 2, 2]);
        let s = Snapshot::load(&p).unwrap();
        assert_eq!(s.epoch, 5);
        assert_eq!(s.edges_ingested, 17);
        assert_eq!(s.edges_live, 17, "pre-deletion formats: everything ingested is live");
        assert_eq!(s.labels, vec![0, 0, 2, 2]);
    }

    #[test]
    fn v2_snapshots_still_load() {
        // Hand-build a v2 file (pre-deletion layout, CRC trailer).
        let p = temp("compat_v2.snap");
        let mut data = Vec::new();
        data.extend_from_slice(SNAP_MAGIC_V2);
        data.extend_from_slice(&9u64.to_le_bytes());
        data.extend_from_slice(&23u64.to_le_bytes());
        data.extend_from_slice(&4u64.to_le_bytes());
        for l in [0u32, 0, 2, 2] {
            data.extend_from_slice(&l.to_le_bytes());
        }
        let crc = crate::util::crc::crc32(&data);
        data.extend_from_slice(&crc.to_le_bytes());
        std::fs::write(&p, data).unwrap();
        let s = Snapshot::load(&p).unwrap();
        assert_eq!(s.epoch, 9);
        assert_eq!(s.edges_ingested, 23);
        assert_eq!(s.edges_live, 23);
        assert_eq!(s.labels, vec![0, 0, 2, 2]);
    }

    #[test]
    fn bit_flip_is_detected_by_checksum() {
        let p = temp("bit_flip.snap");
        let s = Snapshot::from_labels(2, 8, vec![0, 0, 0, 0]);
        s.save(&p).unwrap();
        let mut data = std::fs::read(&p).unwrap();
        data[33] ^= 0x01; // corrupt a label byte, keep length intact
        std::fs::write(&p, &data).unwrap();
        let err = Snapshot::load(&p).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
    }

    #[test]
    fn failed_save_leaves_previous_snapshot_intact() {
        let _g = crate::util::faults::test_lock();
        let p = temp("crash_mid_save.snap");
        Snapshot::from_labels(1, 3, vec![0, 0]).save(&p).unwrap();
        crate::util::faults::configure("snap.save=err@1").unwrap();
        let err = Snapshot::from_labels(2, 6, vec![0, 0]).save(&p).unwrap_err().to_string();
        crate::util::faults::clear();
        assert!(err.contains("injected fault at snap.save"), "{err}");
        // The old snapshot under the real name is untouched and valid.
        let back = Snapshot::load(&p).unwrap();
        assert_eq!(back.epoch, 1);
    }

    #[test]
    fn load_rejects_garbage_and_non_canonical_tables() {
        let p = temp("garbage.snap");
        std::fs::write(&p, b"not a snapshot at all........").unwrap();
        assert!(Snapshot::load(&p).is_err());

        // Valid v1 header (no checksum to trip first), non-canonical
        // labels: vertex 1 labelled above itself.
        let q = temp("non_canonical.snap");
        write_v1(&q, 1, 1, &[0, 2, 2]);
        assert!(Snapshot::load(&q).is_err());

        // Truncated payload.
        let r = temp("truncated.snap");
        Snapshot::from_labels(1, 1, vec![0, 0, 2]).save(&r).unwrap();
        let data = std::fs::read(&r).unwrap();
        std::fs::write(&r, &data[..data.len() - 2]).unwrap();
        assert!(Snapshot::load(&r).is_err());
    }
}
