//! Streaming connectivity — the epoch-based online service.
//!
//! The paper positions Contour inside an *interactive* Arkouda/Arachne
//! server, and ConnectIt (PAPERS.md) frames connectivity as both a
//! static and an incremental problem where insertions interleave with
//! queries. This module is that service for our stack:
//!
//! * **Ingestion** — [`StreamingCc::add_edges`] applies whole batches to
//!   the lock-free Rem-CAS union-find ([`crate::cc::incremental`])
//!   FastSV-style: the batch is one grouped parallel edge sweep, not m
//!   serialized inserts. Edges are WAL-logged *before* they are applied.
//! * **Re-contour compaction** — [`StreamingCc::seal_epoch`] snapshots
//!   the union-find forest and runs the paper's Contour operator (C-2)
//!   over it, re-canonicalizing every label to min-vertex-id form. The
//!   forest has ≤ n−1 edges, so compaction costs O(n) regardless of how
//!   many edges streamed in — and the published labels are bit-identical
//!   to what static [`crate::cc::contour::Contour::c2`] computes on the
//!   same graph.
//! * **Online queries** — each seal publishes an immutable
//!   [`Snapshot`] behind an `Arc` swap. `SAME_COMP` / `COMP_SIZE` /
//!   `NUM_COMPS` resolve against a snapshot (current or any retained
//!   past epoch) and never block on in-flight ingestion batches: the
//!   only lock a query touches is a read-lock on the snapshot table,
//!   whose writers hold it for a single O(1) pointer push.
//! * **Durability** — a write-ahead edge log ([`wal`]) plus a binary
//!   snapshot format ([`snapshot`]). [`StreamingCc::recover`] seeds the
//!   union-find from the latest snapshot, replays the WAL suffix past
//!   the snapshot's seal marker (full replay if the marker is gone —
//!   edge re-insertion is idempotent), and seals a fresh epoch so the
//!   recovered state is immediately queryable.
//!
//! Consistency model: a sealed epoch is a *consistent cut*. An
//! ingestion gate (reader side: `add_edges`; writer side: the seal's
//! forest capture) guarantees the captured forest contains exactly the
//! batches acknowledged before the capture began — and the WAL seal
//! marker is written inside the same critical section, so recovery
//! skips exactly the edges a snapshot already covers. The gate pauses
//! ingestion only for the O(n) capture and the buffered seal-marker
//! append — the WAL fsync and the Contour compaction both run off the
//! gate; queries touch neither lock and keep answering from the
//! published snapshots throughout.

pub mod snapshot;
pub mod wal;

use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, ensure, Result};

use crate::cc::contour::Contour;
use crate::cc::incremental::IncrementalCc;
use crate::cc::{Algorithm, Labels};
use crate::graph::EdgeList;
use crate::par;
use crate::util::{mlock, rlock, wlock};
use crate::VId;

pub use snapshot::Snapshot;
pub use wal::{RepairStats, Wal, WalRecord};

/// What [`StreamingCc::recover`] (and recovery-on-open) found: surfaced
/// on `SLOAD` replies and logged on open so operators can see how much
/// of the log was replayed and whether a torn tail was dropped.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryInfo {
    /// Epoch of the snapshot recovery seeded from, if any.
    pub snapshot_epoch: Option<u64>,
    /// Complete frames found in the WAL (edges batches + seal markers).
    pub wal_frames: usize,
    /// Frames replayed past the snapshot's cut (the WAL suffix).
    pub frames_replayed: usize,
    /// Individual edges re-applied from the replayed frames.
    pub edges_replayed: usize,
    /// Bytes of torn WAL tail truncated away (crash mid-append).
    pub truncated_bytes: u64,
}

impl RecoveryInfo {
    /// One-line summary for replies and logs.
    pub fn summary(&self) -> String {
        let snap = match self.snapshot_epoch {
            Some(e) => format!("{e}"),
            None => "-".to_string(),
        };
        format!(
            "snapshot={snap} frames={} replayed={} edges={} truncated={}B",
            self.wal_frames, self.frames_replayed, self.edges_replayed, self.truncated_bytes
        )
    }
}

/// Epoch snapshots retained for time-travel queries before the oldest
/// is evicted. Each snapshot holds a full O(n) label array, so the
/// default stays small; raise per stream via
/// [`StreamingCc::with_max_history`] (or the server's `STREAM ... HIST`
/// argument) when deeper time travel is worth the memory.
pub const DEFAULT_MAX_HISTORY: usize = 64;

/// The streaming connectivity service over a fixed vertex universe.
pub struct StreamingCc {
    inc: IncrementalCc,
    threads: usize,
    wal: Option<Mutex<Wal>>,
    /// Where the WAL lives, when attached — exposed so owners (e.g. the
    /// server) can refuse to attach a second appender to the same file.
    wal_path: Option<std::path::PathBuf>,
    /// Published snapshots, ascending by epoch. Non-empty from
    /// construction on; the last entry is the current epoch.
    history: RwLock<Vec<Arc<Snapshot>>>,
    last_epoch: AtomicU64,
    edges_ingested: AtomicUsize,
    /// Serializes compactions (ingestion and queries never take it).
    seal: Mutex<()>,
    /// Ingestion gate: `add_edges` holds the read side while logging and
    /// applying a batch; the seal's forest capture takes the write side
    /// so each epoch is a consistent cut of acknowledged batches.
    gate: RwLock<()>,
    max_history: usize,
    /// Duration of the most recent seal-time WAL fsync, in nanoseconds
    /// (0 until the first durable seal). A health signal: a climbing
    /// fsync lag means the disk is falling behind ingestion.
    last_fsync_ns: AtomicU64,
    /// Set when this service was built by recovery (SLOAD or
    /// recovery-on-open); `None` for a fresh stream.
    recovery: Option<RecoveryInfo>,
}

impl StreamingCc {
    /// In-memory service (no durability) over `n` vertices.
    pub fn new(n: usize, threads: usize) -> Self {
        let identity: Labels = (0..n as VId).collect();
        Self {
            inc: IncrementalCc::new(n),
            threads,
            wal: None,
            wal_path: None,
            history: RwLock::new(vec![Arc::new(Snapshot::from_labels(0, 0, identity))]),
            last_epoch: AtomicU64::new(0),
            edges_ingested: AtomicUsize::new(0),
            seal: Mutex::new(()),
            gate: RwLock::new(()),
            max_history: DEFAULT_MAX_HISTORY,
            last_fsync_ns: AtomicU64::new(0),
            recovery: None,
        }
    }

    /// Durable open: attach a WAL at `wal`, recovering from it if the
    /// file already exists (recovery-on-open) and creating it fresh
    /// otherwise. `wal = None` degrades to [`StreamingCc::new`].
    pub fn open(n: usize, threads: usize, wal: Option<&Path>) -> Result<Self> {
        match wal {
            None => Ok(Self::new(n, threads)),
            Some(p) if p.exists() => {
                // Validate the header before recovery: recovering seals
                // a fresh epoch (a WAL write), which must not happen for
                // a mismatched universe.
                let wn = Wal::universe(p)?;
                ensure!(
                    wn == n,
                    "WAL {} holds a universe of n={wn} but n={n} was requested",
                    p.display()
                );
                Self::recover(None, Some(p), threads)
            }
            Some(p) => {
                let mut s = Self::new(n, threads);
                s.wal = Some(Mutex::new(Wal::create(p, n)?));
                s.wal_path = Some(p.to_path_buf());
                Ok(s)
            }
        }
    }

    /// Rebuild a service from durable state: an optional snapshot file
    /// and/or an optional WAL (at least one required). Ends by sealing a
    /// fresh epoch covering everything recovered, and re-attaches the
    /// WAL for continued appends.
    pub fn recover(snapshot: Option<&Path>, wal: Option<&Path>, threads: usize) -> Result<Self> {
        ensure!(
            snapshot.is_some() || wal.is_some(),
            "recover needs a snapshot file and/or a WAL"
        );
        let snap = snapshot.map(Snapshot::load).transpose()?;
        let mut records = Vec::new();
        let mut wal_n = None;
        let mut repair = RepairStats::default();
        if let Some(p) = wal {
            // replay_and_repair truncates a torn tail frame (crash
            // mid-append) so the appender re-attached below starts at a
            // clean frame boundary.
            let (n, recs, stats) = Wal::replay_and_repair(p)?;
            wal_n = Some(n);
            records = recs;
            repair = stats;
        }
        let (inc, base_epoch, base_edges) = match &snap {
            Some(s) => {
                if let Some(wn) = wal_n {
                    ensure!(
                        wn == s.n(),
                        "snapshot holds n={} but the WAL holds n={wn}",
                        s.n()
                    );
                }
                (IncrementalCc::from_labels(&s.labels), s.epoch, s.edges_ingested)
            }
            None => (IncrementalCc::new(wal_n.expect("ensured above")), 0, 0),
        };
        // Skip WAL records already folded into the snapshot: everything
        // up to and including the seal marker for its epoch. If that
        // marker is absent (older snapshot, rotated log), replay the
        // whole log — re-inserting known edges is idempotent.
        let start = match &snap {
            Some(s) => records
                .iter()
                .position(|r| matches!(r, WalRecord::EpochSeal(e) if *e == s.epoch))
                .map(|i| i + 1)
                .unwrap_or(0),
            None => 0,
        };
        let mut last_epoch = base_epoch;
        let mut replayed = 0usize;
        for rec in &records[start..] {
            match rec {
                WalRecord::Edges(batch) => {
                    for &(u, v) in batch {
                        inc.add_edge(u, v);
                    }
                    replayed += batch.len();
                }
                WalRecord::EpochSeal(e) => last_epoch = last_epoch.max(*e),
            }
        }
        let info = RecoveryInfo {
            snapshot_epoch: snap.as_ref().map(|s| s.epoch),
            wal_frames: repair.frames,
            frames_replayed: records.len() - start,
            edges_replayed: replayed,
            truncated_bytes: repair.truncated_bytes,
        };
        crate::info!("stream recovery: {}", info.summary());
        let s = Self {
            inc,
            threads,
            wal: wal
                .map(|p| Wal::append_to(p).map(|(w, _)| Mutex::new(w)))
                .transpose()?,
            wal_path: wal.map(|p| p.to_path_buf()),
            history: RwLock::new(snap.into_iter().map(Arc::new).collect()),
            last_epoch: AtomicU64::new(last_epoch),
            edges_ingested: AtomicUsize::new(base_edges + replayed),
            seal: Mutex::new(()),
            gate: RwLock::new(()),
            max_history: DEFAULT_MAX_HISTORY,
            last_fsync_ns: AtomicU64::new(0),
            recovery: Some(info),
        };
        s.seal_epoch()?;
        Ok(s)
    }

    /// Recovery stats, when this service was rebuilt from durable state.
    pub fn recovery(&self) -> Option<RecoveryInfo> {
        self.recovery
    }

    /// Cap the number of retained epoch snapshots.
    pub fn with_max_history(mut self, cap: usize) -> Self {
        self.max_history = cap.max(1);
        self
    }

    pub fn n(&self) -> usize {
        self.inc.n()
    }

    /// Current (latest sealed) epoch number.
    pub fn epoch(&self) -> u64 {
        self.last_epoch.load(Ordering::Relaxed)
    }

    /// Edge insertions acknowledged so far (duplicates counted).
    pub fn edges_ingested(&self) -> usize {
        self.edges_ingested.load(Ordering::Relaxed)
    }

    /// Nanoseconds the most recent seal-time WAL fsync took (0 with no
    /// WAL attached, or before the first durable seal).
    pub fn last_fsync_ns(&self) -> u64 {
        self.last_fsync_ns.load(Ordering::Relaxed)
    }

    /// The attached WAL's path, if durable. A WAL file must back at
    /// most one live service — a second appender would interleave
    /// frames and corrupt the log.
    pub fn wal_path(&self) -> Option<&Path> {
        self.wal_path.as_deref()
    }

    /// Ingest one batch: WAL-log it, then apply it to the union-find as
    /// a grouped parallel sweep. Returns the number of edges accepted.
    /// Safe to call from many threads at once.
    pub fn add_edges(&self, edges: &[(VId, VId)]) -> Result<usize> {
        let n = self.n();
        for &(u, v) in edges {
            ensure!(
                (u as usize) < n && (v as usize) < n,
                "edge ({u}, {v}) out of range (n = {n})"
            );
        }
        // Hold the ingestion gate (read side, so batches still run in
        // parallel with each other) across log + apply + acknowledge:
        // a seal either sees this whole batch or none of it.
        let _ingest = rlock(&self.gate);
        if let Some(w) = &self.wal {
            mlock(w).append_edges(edges)?;
        }
        let inc = &self.inc;
        par::par_for(edges.len(), self.threads, par::AUTO_GRAIN, |range| {
            for e in range {
                inc.add_edge(edges[e].0, edges[e].1);
            }
        });
        self.edges_ingested.fetch_add(edges.len(), Ordering::Relaxed);
        Ok(edges.len())
    }

    /// Live (pre-seal) connectivity probe against the union-find —
    /// sees edges the next epoch will publish.
    pub fn connected_live(&self, u: VId, v: VId) -> Result<bool> {
        let n = self.n();
        ensure!((u as usize) < n && (v as usize) < n, "vertex out of range (n = {n})");
        Ok(self.inc.connected(u, v))
    }

    /// Seal the current epoch: run the re-contour compaction over the
    /// union-find forest, publish the resulting snapshot, and append a
    /// seal marker to the WAL (fsynced). Returns the new snapshot.
    pub fn seal_epoch(&self) -> Result<Arc<Snapshot>> {
        let _guard = mlock(&self.seal);
        let epoch = self.last_epoch.load(Ordering::Relaxed) + 1;
        // Consistent cut: with the gate held exclusively, no batch is
        // mid-application, so the forest is exactly the acknowledged
        // state, and the WAL seal marker written inside the same
        // critical section cleanly partitions the log at this epoch.
        let (edges, forest) = {
            let _cut = wlock(&self.gate);
            let edges = self.edges_ingested.load(Ordering::Relaxed);
            let forest = self.inc.forest_edges(self.threads);
            if let Some(w) = &self.wal {
                // Buffered marker append only — it fixes the log order.
                mlock(w).seal_epoch(epoch)?;
            }
            (edges, forest)
        };
        // Durability fsync off the gate: ingestion resumes while the
        // disk syncs (frames appended meanwhile simply ride along).
        if let Some(w) = &self.wal {
            let t = std::time::Instant::now();
            mlock(w).sync()?;
            let ns = t.elapsed().as_nanos().min(u64::MAX as u128) as u64;
            self.last_fsync_ns.store(ns, Ordering::Relaxed);
        }
        // Re-contour compaction, off the gate so ingestion resumes while
        // labels are recanonicalized: the forest is itself a graph with
        // the same components, so the paper's operator over it yields
        // the canonical min-id labelling of everything ingested so far.
        let g = EdgeList::from_pairs(self.n(), &forest).into_csr();
        let labels = Contour::c2().with_threads(self.threads).run(&g);
        let snap = Arc::new(Snapshot::from_labels(epoch, edges, labels));
        {
            let mut h = wlock(&self.history);
            h.push(Arc::clone(&snap));
            if h.len() > self.max_history {
                h.remove(0);
            }
        }
        self.last_epoch.store(epoch, Ordering::Relaxed);
        Ok(snap)
    }

    /// The current epoch's snapshot (wait-free for practical purposes:
    /// the read-lock's writers hold it only for an O(1) push).
    pub fn current(&self) -> Arc<Snapshot> {
        let h = rlock(&self.history);
        Arc::clone(h.last().expect("history is never empty"))
    }

    /// The snapshot sealed as `epoch`, if still retained.
    pub fn at_epoch(&self, epoch: u64) -> Option<Arc<Snapshot>> {
        let h = rlock(&self.history);
        h.binary_search_by_key(&epoch, |s| s.epoch).ok().map(|i| Arc::clone(&h[i]))
    }

    /// Resolve a query target: `None` = current epoch, `Some(e)` = that
    /// sealed epoch (error if never sealed or already evicted).
    pub fn snapshot_at(&self, epoch: Option<u64>) -> Result<Arc<Snapshot>> {
        match epoch {
            None => Ok(self.current()),
            Some(e) => self.at_epoch(e).ok_or_else(|| {
                let h = rlock(&self.history);
                let span = match (h.first(), h.last()) {
                    (Some(a), Some(b)) => format!("{}..={}", a.epoch, b.epoch),
                    _ => "∅".to_string(),
                };
                anyhow!("epoch {e} not retained (history spans {span})")
            }),
        }
    }

    /// Persist the current snapshot to `path`; returns its epoch.
    pub fn save_snapshot(&self, path: &Path) -> Result<u64> {
        let snap = self.current();
        snap.save(path)?;
        Ok(snap.epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc;
    use crate::graph::gen;

    #[test]
    fn epochs_publish_min_id_labels() {
        // Universe of 6; edges arrive in two epochs.
        let s = StreamingCc::new(6, 1);
        assert_eq!(s.epoch(), 0);
        assert_eq!(s.current().labels, vec![0, 1, 2, 3, 4, 5]);

        s.add_edges(&[(0, 1), (2, 3)]).unwrap();
        let e1 = s.seal_epoch().unwrap();
        assert_eq!(e1.epoch, 1);
        assert_eq!(e1.labels, vec![0, 0, 2, 2, 4, 5]);
        assert_eq!(e1.num_components, 4);
        assert_eq!(e1.edges_ingested, 2);

        s.add_edges(&[(1, 2), (4, 5)]).unwrap();
        let e2 = s.seal_epoch().unwrap();
        assert_eq!(e2.labels, vec![0, 0, 0, 0, 4, 4]);
        assert_eq!(e2.num_components, 2);

        // Past epochs stay queryable and immutable.
        let back = s.at_epoch(1).unwrap();
        assert_eq!(back.labels, vec![0, 0, 2, 2, 4, 5]);
        assert!(!back.same_comp(0, 3).unwrap());
        assert!(s.snapshot_at(Some(2)).unwrap().same_comp(0, 3).unwrap());
        assert!(s.snapshot_at(Some(9)).is_err());
        assert!(s.at_epoch(9).is_none());
    }

    #[test]
    fn streamed_equals_static_contour() {
        let g = gen::rmat(10, 3_000, gen::RmatKind::Graph500, 5).into_csr();
        let s = StreamingCc::new(g.n, 0);
        let edges: Vec<(VId, VId)> = g.edges().collect();
        for chunk in edges.chunks(137) {
            s.add_edges(chunk).unwrap();
        }
        let fin = s.seal_epoch().unwrap();
        let want = Contour::c2().run(&g);
        assert_eq!(fin.labels, want);
        assert_eq!(fin.labels, cc::ground_truth(&g));
        assert_eq!(s.edges_ingested(), edges.len());
    }

    #[test]
    fn live_probe_sees_unsealed_edges() {
        let s = StreamingCc::new(4, 1);
        s.add_edges(&[(0, 3)]).unwrap();
        assert!(s.connected_live(0, 3).unwrap());
        // The published snapshot (epoch 0) predates the edge.
        assert!(!s.current().same_comp(0, 3).unwrap());
        assert!(s.connected_live(0, 9).is_err());
    }

    #[test]
    fn rejects_out_of_range_edges() {
        let s = StreamingCc::new(3, 1);
        assert!(s.add_edges(&[(0, 1), (1, 7)]).is_err());
        // The bad batch must not have been partially counted.
        assert_eq!(s.edges_ingested(), 0);
    }

    #[test]
    fn history_eviction_keeps_the_newest() {
        let s = StreamingCc::new(8, 1).with_max_history(3);
        for i in 0..6u32 {
            s.add_edges(&[(i % 7, i % 7 + 1)]).unwrap();
            s.seal_epoch().unwrap();
        }
        assert_eq!(s.epoch(), 6);
        assert!(s.at_epoch(2).is_none(), "old epochs evicted");
        assert!(s.at_epoch(4).is_some());
        assert!(s.at_epoch(6).is_some());
    }

    #[test]
    fn concurrent_ingestion_and_sealing() {
        let n = 30_000usize;
        let s = StreamingCc::new(n, 1);
        std::thread::scope(|sc| {
            for t in 0..4usize {
                let s = &s;
                sc.spawn(move || {
                    let edges: Vec<(VId, VId)> = (t..n - 1)
                        .step_by(4)
                        .map(|i| (i as VId, (i + 1) as VId))
                        .collect();
                    for chunk in edges.chunks(256) {
                        s.add_edges(chunk).unwrap();
                    }
                });
            }
            let s = &s;
            sc.spawn(move || {
                for _ in 0..5 {
                    s.seal_epoch().unwrap();
                }
            });
        });
        let fin = s.seal_epoch().unwrap();
        assert_eq!(fin.num_components, 1);
        assert!(fin.labels.iter().all(|&l| l == 0));
        // Components can only merge over epochs.
        let h: Vec<usize> = (1..=s.epoch())
            .filter_map(|e| s.at_epoch(e))
            .map(|snap| snap.num_components)
            .collect();
        assert!(h.windows(2).all(|w| w[1] <= w[0]), "components must be non-increasing: {h:?}");
    }
}
