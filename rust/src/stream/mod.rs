//! Streaming connectivity — the epoch-based online service.
//!
//! The paper positions Contour inside an *interactive* Arkouda/Arachne
//! server, and ConnectIt (PAPERS.md) frames connectivity as both a
//! static and an incremental problem where insertions interleave with
//! queries. This module is that service for our stack:
//!
//! * **Ingestion** — [`StreamingCc::add_edges`] applies whole batches to
//!   the lock-free Rem-CAS union-find ([`crate::cc::incremental`])
//!   FastSV-style: the batch is one grouped parallel edge sweep, not m
//!   serialized inserts. Edges are WAL-logged *before* they are applied.
//! * **Re-contour compaction** — [`StreamingCc::seal_epoch`] snapshots
//!   the union-find forest and runs the paper's Contour operator (C-2)
//!   over it, re-canonicalizing every label to min-vertex-id form. The
//!   forest has ≤ n−1 edges, so compaction costs O(n) regardless of how
//!   many edges streamed in — and the published labels are bit-identical
//!   to what static [`crate::cc::contour::Contour::c2`] computes on the
//!   same graph.
//! * **Online queries** — each seal publishes an immutable
//!   [`Snapshot`] behind an `Arc` swap. `SAME_COMP` / `COMP_SIZE` /
//!   `NUM_COMPS` resolve against a snapshot (current or any retained
//!   past epoch) and never block on in-flight ingestion batches: the
//!   only lock a query touches is a read-lock on the snapshot table,
//!   whose writers hold it for a single O(1) pointer push.
//! * **Deletions** — [`StreamingCc::delete_edges`] removes previously
//!   ingested edges. A union-find can only merge, so deletions are the
//!   part it cannot express: a compact live-edge multiset (normalized
//!   pair → multiplicity) rides alongside, deletions are WAL-logged
//!   (v3 delete frames) and decrement it, and the next
//!   [`StreamingCc::seal_epoch`] repairs the labelling by re-running
//!   Contour over only the *affected components* — the pre-delete
//!   labels (a coarsening of the truth: every merge not justified by a
//!   surviving edge came from a deleted one) identify exactly which
//!   components the deletions touched; untouched components carry
//!   their labels forward verbatim. When the affected mass passes half
//!   the universe the seal falls back to one full re-contour. Either
//!   way the repaired labels are stored straight back into the
//!   union-find ([`IncrementalCc::store_labels`]), so insertions keep
//!   the lock-free path. Parallel edges are a multiset: each accepted
//!   delete removes one multiplicity, and connectivity only changes
//!   when the last one goes.
//! * **Durability** — a write-ahead edge log ([`wal`]) plus a binary
//!   snapshot format ([`snapshot`]). [`StreamingCc::recover`] seeds the
//!   union-find from the latest snapshot, replays the WAL suffix past
//!   the snapshot's seal marker (full replay if the marker is gone —
//!   edge re-insertion is idempotent), and seals a fresh epoch so the
//!   recovered state is immediately queryable. A log holding delete
//!   frames voids the snapshot's labels as a seed (a deleted edge baked
//!   into them could never be backed out): recovery then rebuilds from
//!   the surviving multiset of the full log instead.
//!
//! Consistency model: a sealed epoch is a *consistent cut*. An
//! ingestion gate (reader side: `add_edges` / `delete_edges`; writer
//! side: the seal's forest capture) guarantees the captured forest
//! contains exactly the batches acknowledged before the capture began —
//! and the WAL seal marker is written inside the same critical section,
//! so recovery skips exactly the edges a snapshot already covers. For
//! insert-only epochs the gate pauses ingestion only for the O(n)
//! capture and the buffered seal-marker append — the WAL fsync and the
//! Contour compaction both run off the gate; a delete epoch holds the
//! gate for its re-contour too (the union-find fixup must land before
//! ingestion resumes). Queries touch neither lock and keep answering
//! from the published snapshots throughout. Deletions take effect in
//! the *published labelling* at the next seal; until then
//! [`StreamingCc::connected_live`] may still answer `true` for a
//! severed pair (the live union-find cannot un-merge).

pub mod snapshot;
pub mod wal;

use std::collections::{HashMap, VecDeque};
use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail, ensure, Result};

use crate::cc::contour::Contour;
use crate::cc::incremental::IncrementalCc;
use crate::cc::{Algorithm, Labels};
use crate::graph::EdgeList;
use crate::par;
use crate::util::{mlock, rlock, wlock};
use crate::VId;

pub use snapshot::Snapshot;
pub use wal::{RepairStats, Wal, WalRecord};

/// What [`StreamingCc::recover`] (and recovery-on-open) found: surfaced
/// on `SLOAD` replies and logged on open so operators can see how much
/// of the log was replayed and whether a torn tail was dropped.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RecoveryInfo {
    /// Epoch of the snapshot recovery seeded from, if any.
    pub snapshot_epoch: Option<u64>,
    /// Complete frames found in the WAL (edges batches + seal markers).
    pub wal_frames: usize,
    /// Frames replayed past the snapshot's cut (the WAL suffix).
    pub frames_replayed: usize,
    /// Individual edges re-applied from the replayed frames.
    pub edges_replayed: usize,
    /// Individual deletions replayed from the log (0 for insert-only
    /// logs — every v1/v2 log, and v3 logs that never saw a delete).
    pub deletes_replayed: usize,
    /// Bytes of torn WAL tail truncated away (crash mid-append).
    pub truncated_bytes: u64,
}

impl RecoveryInfo {
    /// One-line summary for replies and logs. The deletes field only
    /// appears when deletions were replayed, so insert-only recoveries
    /// keep their historical wire shape.
    pub fn summary(&self) -> String {
        let snap = match self.snapshot_epoch {
            Some(e) => format!("{e}"),
            None => "-".to_string(),
        };
        let deletes = match self.deletes_replayed {
            0 => String::new(),
            d => format!(" deletes={d}"),
        };
        format!(
            "snapshot={snap} frames={} replayed={} edges={}{deletes} truncated={}B",
            self.wal_frames, self.frames_replayed, self.edges_replayed, self.truncated_bytes
        )
    }
}

/// Epoch snapshots retained for time-travel queries before the oldest
/// is evicted. Each snapshot holds a full O(n) label array, so the
/// default stays small; raise per stream via
/// [`StreamingCc::with_max_history`] (or the server's `STREAM ... HIST`
/// argument) when deeper time travel is worth the memory.
pub const DEFAULT_MAX_HISTORY: usize = 64;

/// Fraction of the vertex universe (numerator / denominator) up to
/// which a delete epoch re-contours only the affected components;
/// past it, the bookkeeping buys nothing over one full re-contour.
const SCOPED_MAX_NUM: usize = 1;
const SCOPED_MAX_DEN: usize = 2;

/// Normalized multiset key for an undirected edge.
#[inline]
fn norm(u: VId, v: VId) -> (VId, VId) {
    if u <= v {
        (u, v)
    } else {
        (v, u)
    }
}

/// The streaming connectivity service over a fixed vertex universe.
pub struct StreamingCc {
    inc: IncrementalCc,
    threads: usize,
    wal: Option<Mutex<Wal>>,
    /// Where the WAL lives, when attached — exposed so owners (e.g. the
    /// server) can refuse to attach a second appender to the same file.
    wal_path: Option<std::path::PathBuf>,
    /// Live-edge multiset: normalized `(min, max)` pair → multiplicity.
    /// The union-find cannot express removal, so this is the ground
    /// truth deletions validate against and delete epochs rebuild from.
    multiset: Mutex<HashMap<(VId, VId), u32>>,
    /// Deletions accepted since the last seal — the endpoints scope the
    /// next seal's re-contour to the components they touch.
    pending_deletes: Mutex<Vec<(VId, VId)>>,
    /// Published snapshots, ascending by epoch. Non-empty from
    /// construction on; the back entry is the current epoch. A deque:
    /// retention pressure evicts from the front in O(1), where a `Vec`
    /// would shift the whole window per seal.
    history: RwLock<VecDeque<Arc<Snapshot>>>,
    last_epoch: AtomicU64,
    edges_ingested: AtomicUsize,
    edges_live: AtomicUsize,
    edges_deleted: AtomicUsize,
    /// Delete-epoch seals that re-contoured only the affected
    /// components / that fell back to a full pass.
    scoped_recontours: AtomicUsize,
    full_recontours: AtomicUsize,
    /// Serializes compactions (ingestion and queries never take it).
    seal: Mutex<()>,
    /// Ingestion gate: `add_edges` / `delete_edges` hold the read side
    /// while logging and applying a batch; the seal's forest capture
    /// takes the write side so each epoch is a consistent cut of
    /// acknowledged batches.
    gate: RwLock<()>,
    max_history: usize,
    /// Duration of the most recent seal-time WAL fsync, in nanoseconds
    /// (0 until the first durable seal). A health signal: a climbing
    /// fsync lag means the disk is falling behind ingestion.
    last_fsync_ns: AtomicU64,
    /// Set when this service was built by recovery (SLOAD or
    /// recovery-on-open); `None` for a fresh stream.
    recovery: Option<RecoveryInfo>,
}

impl StreamingCc {
    /// In-memory service (no durability) over `n` vertices.
    pub fn new(n: usize, threads: usize) -> Self {
        let identity: Labels = (0..n as VId).collect();
        Self {
            inc: IncrementalCc::new(n),
            threads,
            wal: None,
            wal_path: None,
            multiset: Mutex::new(HashMap::new()),
            pending_deletes: Mutex::new(Vec::new()),
            history: RwLock::new(VecDeque::from([Arc::new(Snapshot::from_labels(
                0, 0, identity,
            ))])),
            last_epoch: AtomicU64::new(0),
            edges_ingested: AtomicUsize::new(0),
            edges_live: AtomicUsize::new(0),
            edges_deleted: AtomicUsize::new(0),
            scoped_recontours: AtomicUsize::new(0),
            full_recontours: AtomicUsize::new(0),
            seal: Mutex::new(()),
            gate: RwLock::new(()),
            max_history: DEFAULT_MAX_HISTORY,
            last_fsync_ns: AtomicU64::new(0),
            recovery: None,
        }
    }

    /// Durable open: attach a WAL at `wal`, recovering from it if the
    /// file already exists (recovery-on-open) and creating it fresh
    /// otherwise. `wal = None` degrades to [`StreamingCc::new`].
    pub fn open(n: usize, threads: usize, wal: Option<&Path>) -> Result<Self> {
        match wal {
            None => Ok(Self::new(n, threads)),
            Some(p) if p.exists() => {
                // Validate the header before recovery: recovering seals
                // a fresh epoch (a WAL write), which must not happen for
                // a mismatched universe.
                let wn = Wal::universe(p)?;
                ensure!(
                    wn == n,
                    "WAL {} holds a universe of n={wn} but n={n} was requested",
                    p.display()
                );
                Self::recover(None, Some(p), threads)
            }
            Some(p) => {
                let mut s = Self::new(n, threads);
                s.wal = Some(Mutex::new(Wal::create(p, n)?));
                s.wal_path = Some(p.to_path_buf());
                Ok(s)
            }
        }
    }

    /// Rebuild a service from durable state: an optional snapshot file
    /// and/or an optional WAL (at least one required). Ends by sealing a
    /// fresh epoch covering everything recovered, and re-attaches the
    /// WAL for continued appends.
    ///
    /// The live-edge multiset is rebuilt from the *full* log (the WAL is
    /// the complete insert/delete history — it is never rotated), so
    /// recovered streams validate future deletions against exactly what
    /// survived. Snapshot-only recovery has no log to rebuild from: the
    /// multiset starts empty and deletions of pre-snapshot edges are
    /// rejected — the documented limit of snapshot-only durability.
    pub fn recover(snapshot: Option<&Path>, wal: Option<&Path>, threads: usize) -> Result<Self> {
        ensure!(
            snapshot.is_some() || wal.is_some(),
            "recover needs a snapshot file and/or a WAL"
        );
        let snap = snapshot.map(Snapshot::load).transpose()?;
        let mut records = Vec::new();
        let mut wal_n = None;
        let mut repair = RepairStats::default();
        if let Some(p) = wal {
            // replay_and_repair truncates a torn tail frame (crash
            // mid-append) so the appender re-attached below starts at a
            // clean frame boundary.
            let (n, recs, stats) = Wal::replay_and_repair(p)?;
            wal_n = Some(n);
            records = recs;
            repair = stats;
        }
        if let (Some(s), Some(wn)) = (&snap, wal_n) {
            ensure!(wn == s.n(), "snapshot holds n={} but the WAL holds n={wn}", s.n());
        }
        // One pass over the full log: the surviving multiset plus honest
        // accepted-insert / accepted-delete counts. Self-loops in legacy
        // logs (written before ingestion dropped them) are skipped — they
        // never affected connectivity. A delete with no live insert
        // cannot come from any legal execution (deletions are only
        // accepted, and logged, after the insert that made them
        // deletable): corruption, loudly.
        let mut multiset: HashMap<(VId, VId), u32> = HashMap::new();
        let mut ingested = 0usize;
        let mut deleted = 0usize;
        let mut has_deletes = false;
        for (i, rec) in records.iter().enumerate() {
            match rec {
                WalRecord::Edges(batch) => {
                    for &(u, v) in batch {
                        if u == v {
                            continue;
                        }
                        *multiset.entry(norm(u, v)).or_insert(0) += 1;
                        ingested += 1;
                    }
                }
                WalRecord::Deletes(batch) => {
                    has_deletes = true;
                    for &(u, v) in batch {
                        match multiset.get_mut(&norm(u, v)) {
                            Some(c) if *c > 1 => *c -= 1,
                            Some(_) => {
                                multiset.remove(&norm(u, v));
                            }
                            None => bail!(
                                "WAL record {i}: delete of ({u}, {v}) without a live insert — \
                                 log corrupt"
                            ),
                        }
                        deleted += 1;
                    }
                }
                WalRecord::EpochSeal(_) => {}
            }
        }
        let live = multiset.values().map(|&c| c as usize).sum::<usize>();
        let mut last_epoch = snap.as_ref().map(|s| s.epoch).unwrap_or(0);
        for rec in &records {
            if let WalRecord::EpochSeal(e) = rec {
                last_epoch = last_epoch.max(*e);
            }
        }
        let (inc, frames_replayed, edges_replayed) = if has_deletes {
            // Deletions void the snapshot's labels as a seed — the
            // union-find can only merge, so a deleted edge baked into
            // them could never be backed out. Rebuild from the surviving
            // multiset instead: one insert per distinct live pair.
            let inc = IncrementalCc::new(wal_n.expect("deletes imply a WAL"));
            for &(u, v) in multiset.keys() {
                inc.add_edge(u, v);
            }
            (inc, records.len(), multiset.len())
        } else {
            // Insert-only log: seed from the snapshot's labels and
            // replay only the suffix past its seal marker. If that
            // marker is absent (older snapshot), replay the whole log —
            // re-inserting known edges is idempotent.
            let inc = match &snap {
                Some(s) => IncrementalCc::from_labels(&s.labels),
                None => IncrementalCc::new(wal_n.expect("ensured above")),
            };
            let start = match &snap {
                Some(s) => records
                    .iter()
                    .position(|r| matches!(r, WalRecord::EpochSeal(e) if *e == s.epoch))
                    .map(|i| i + 1)
                    .unwrap_or(0),
                None => 0,
            };
            let mut replayed = 0usize;
            for rec in &records[start..] {
                if let WalRecord::Edges(batch) = rec {
                    for &(u, v) in batch {
                        if u == v {
                            continue;
                        }
                        inc.add_edge(u, v);
                        replayed += 1;
                    }
                }
            }
            (inc, records.len() - start, replayed)
        };
        // Counters: the full log is authoritative when attached; a
        // snapshot alone carries its own totals forward.
        let (ingested, live) = match (&snap, wal.is_some()) {
            (_, true) => (ingested, live),
            (Some(s), false) => (s.edges_ingested, s.edges_live),
            (None, false) => unreachable!("ensured above"),
        };
        let info = RecoveryInfo {
            snapshot_epoch: snap.as_ref().map(|s| s.epoch),
            wal_frames: repair.frames,
            frames_replayed,
            edges_replayed,
            deletes_replayed: deleted,
            truncated_bytes: repair.truncated_bytes,
        };
        crate::info!("stream recovery: {}", info.summary());
        let s = Self {
            inc,
            threads,
            wal: wal
                .map(|p| Wal::append_to(p).map(|(w, _)| Mutex::new(w)))
                .transpose()?,
            wal_path: wal.map(|p| p.to_path_buf()),
            multiset: Mutex::new(multiset),
            pending_deletes: Mutex::new(Vec::new()),
            history: RwLock::new(snap.into_iter().map(Arc::new).collect()),
            last_epoch: AtomicU64::new(last_epoch),
            edges_ingested: AtomicUsize::new(ingested),
            edges_live: AtomicUsize::new(live),
            edges_deleted: AtomicUsize::new(ingested - live),
            scoped_recontours: AtomicUsize::new(0),
            full_recontours: AtomicUsize::new(0),
            seal: Mutex::new(()),
            gate: RwLock::new(()),
            max_history: DEFAULT_MAX_HISTORY,
            last_fsync_ns: AtomicU64::new(0),
            recovery: Some(info),
        };
        s.seal_epoch()?;
        Ok(s)
    }

    /// Recovery stats, when this service was rebuilt from durable state.
    pub fn recovery(&self) -> Option<RecoveryInfo> {
        self.recovery
    }

    /// Cap the number of retained epoch snapshots.
    pub fn with_max_history(mut self, cap: usize) -> Self {
        self.max_history = cap.max(1);
        self
    }

    pub fn n(&self) -> usize {
        self.inc.n()
    }

    /// Current (latest sealed) epoch number.
    pub fn epoch(&self) -> u64 {
        self.last_epoch.load(Ordering::Relaxed)
    }

    /// Edge insertions accepted so far (parallel edges counted;
    /// self-loops are dropped at ingestion and never counted).
    pub fn edges_ingested(&self) -> usize {
        self.edges_ingested.load(Ordering::Relaxed)
    }

    /// Edges currently live: accepted insertions minus accepted
    /// deletions.
    pub fn edges_live(&self) -> usize {
        self.edges_live.load(Ordering::Relaxed)
    }

    /// Deletions accepted so far.
    pub fn edges_deleted(&self) -> usize {
        self.edges_deleted.load(Ordering::Relaxed)
    }

    /// Delete-epoch seals that re-contoured only the affected
    /// components.
    pub fn scoped_recontours(&self) -> usize {
        self.scoped_recontours.load(Ordering::Relaxed)
    }

    /// Delete-epoch seals that fell back to a full re-contour (affected
    /// mass above the scoped threshold).
    pub fn full_recontours(&self) -> usize {
        self.full_recontours.load(Ordering::Relaxed)
    }

    /// Nanoseconds the most recent seal-time WAL fsync took (0 with no
    /// WAL attached, or before the first durable seal).
    pub fn last_fsync_ns(&self) -> u64 {
        self.last_fsync_ns.load(Ordering::Relaxed)
    }

    /// The attached WAL's path, if durable. A WAL file must back at
    /// most one live service — a second appender would interleave
    /// frames and corrupt the log.
    pub fn wal_path(&self) -> Option<&Path> {
        self.wal_path.as_deref()
    }

    /// Ingest one batch: WAL-log it, then apply it to the union-find as
    /// a grouped parallel sweep. Self-loops are dropped — they never
    /// affect connectivity, and admitting them would corrupt the
    /// accounting deletions rely on (`edges_ingested` must count exactly
    /// the edges that can later be deleted). Returns the number of edges
    /// accepted. Safe to call from many threads at once.
    pub fn add_edges(&self, edges: &[(VId, VId)]) -> Result<usize> {
        let n = self.n();
        for &(u, v) in edges {
            ensure!(
                (u as usize) < n && (v as usize) < n,
                "edge ({u}, {v}) out of range (n = {n})"
            );
        }
        let accepted: Vec<(VId, VId)> =
            edges.iter().copied().filter(|&(u, v)| u != v).collect();
        if accepted.is_empty() {
            return Ok(0);
        }
        // Hold the ingestion gate (read side, so batches still run in
        // parallel with each other) across log + apply + acknowledge:
        // a seal either sees this whole batch or none of it.
        let _ingest = rlock(&self.gate);
        if let Some(w) = &self.wal {
            mlock(w).append_edges(&accepted)?;
        }
        let inc = &self.inc;
        par::par_for(accepted.len(), self.threads, par::AUTO_GRAIN, |range| {
            for e in range {
                inc.add_edge(accepted[e].0, accepted[e].1);
            }
        });
        // The multiset increment comes *after* the WAL append: a delete
        // only accepts an edge it can see here, so the matching insert
        // frame always precedes the delete frame in the log, and replay
        // can never underflow.
        {
            let mut ms = mlock(&self.multiset);
            for &(u, v) in &accepted {
                *ms.entry(norm(u, v)).or_insert(0) += 1;
            }
        }
        self.edges_ingested.fetch_add(accepted.len(), Ordering::Relaxed);
        self.edges_live.fetch_add(accepted.len(), Ordering::Relaxed);
        Ok(accepted.len())
    }

    /// Remove a batch of previously ingested edges. Parallel edges form
    /// a multiset: each accepted delete removes one multiplicity, and
    /// connectivity only changes when the last one goes. A pair that is
    /// not currently live — never inserted, already fully deleted, or a
    /// self-loop (never admitted) — fails the whole batch before
    /// anything is logged or applied, so a caller retrying after an
    /// error never half-applies a batch. Returns the number of
    /// deletions accepted (the full batch size on success).
    ///
    /// Deletions are durably logged before they are applied, like
    /// inserts, and take effect in the *published labelling* at the next
    /// [`StreamingCc::seal_epoch`]: the live union-find cannot un-merge,
    /// so [`StreamingCc::connected_live`] may keep answering `true` for
    /// a severed pair until the seal re-contours the affected
    /// components.
    pub fn delete_edges(&self, edges: &[(VId, VId)]) -> Result<usize> {
        let n = self.n();
        for &(u, v) in edges {
            ensure!(
                (u as usize) < n && (v as usize) < n,
                "edge ({u}, {v}) out of range (n = {n})"
            );
        }
        let _ingest = rlock(&self.gate);
        // The multiset lock spans accept-check, WAL append and decrement
        // so two racing deletes cannot both claim an edge's last
        // multiplicity. (Inserts never hold the WAL and multiset locks
        // at once, so this multiset→WAL order cannot deadlock against
        // their WAL→multiset sequence.)
        let mut ms = mlock(&self.multiset);
        let mut taken: HashMap<(VId, VId), u32> = HashMap::new();
        let mut accepted: Vec<(VId, VId)> = Vec::new();
        for &(u, v) in edges {
            ensure!(u != v, "edge ({u}, {v}) is a self-loop (never live, delete rejected)");
            let k = norm(u, v);
            let have = ms.get(&k).copied().unwrap_or(0);
            let t = taken.entry(k).or_insert(0);
            ensure!(
                *t < have,
                "edge ({u}, {v}) is not live (delete rejected, batch unapplied)"
            );
            *t += 1;
            accepted.push(k);
        }
        if accepted.is_empty() {
            return Ok(0);
        }
        // Log before apply: a failed append leaves the whole batch
        // unapplied and unacknowledged.
        if let Some(w) = &self.wal {
            mlock(w).append_deletes(&accepted)?;
        }
        for &k in &accepted {
            match ms.get_mut(&k) {
                Some(c) if *c > 1 => *c -= 1,
                _ => {
                    ms.remove(&k);
                }
            }
        }
        drop(ms);
        mlock(&self.pending_deletes).extend_from_slice(&accepted);
        self.edges_live.fetch_sub(accepted.len(), Ordering::Relaxed);
        self.edges_deleted.fetch_add(accepted.len(), Ordering::Relaxed);
        Ok(accepted.len())
    }

    /// Live (pre-seal) connectivity probe against the union-find — sees
    /// edges the next epoch will publish. After a delete, the probe may
    /// still answer `true` for a severed pair until the next seal
    /// repairs the union-find (merges cannot be undone in place).
    pub fn connected_live(&self, u: VId, v: VId) -> Result<bool> {
        let n = self.n();
        ensure!((u as usize) < n && (v as usize) < n, "vertex out of range (n = {n})");
        Ok(self.inc.connected(u, v))
    }

    /// Flush and fsync the WAL, recording the fsync duration as the
    /// health signal.
    fn wal_sync_timed(&self) -> Result<()> {
        if let Some(w) = &self.wal {
            let t = std::time::Instant::now();
            mlock(w).sync()?;
            let ns = t.elapsed().as_nanos().min(u64::MAX as u128) as u64;
            self.last_fsync_ns.store(ns, Ordering::Relaxed);
        }
        Ok(())
    }

    /// Rebuild the labelling after an epoch with deletions — the
    /// paper's re-contour operator scoped to the damage. The pre-delete
    /// union-find partition is a coarsening of the truth (every merge
    /// not justified by a surviving edge came from a deleted one, whose
    /// endpoints are in `deletes`), so its labels identify exactly the
    /// components the deletions touched: unaffected components carry
    /// their labels forward verbatim, affected ones are re-contoured
    /// from their surviving edges. When the affected mass passes
    /// [`SCOPED_MAX_NUM`]/[`SCOPED_MAX_DEN`] of the universe, one full
    /// re-contour over the surviving multiset is cheaper than the
    /// bookkeeping. Runs under the ingestion gate's write side.
    fn recontour_deletes(&self, deletes: &[(VId, VId)]) -> Labels {
        let n = self.n();
        let uf = self.inc.labels(self.threads);
        let mut affected = vec![false; n];
        for &(u, v) in deletes {
            affected[uf[u as usize] as usize] = true;
            affected[uf[v as usize] as usize] = true;
        }
        let mass = uf.iter().filter(|&&l| affected[l as usize]).count();
        let scoped = mass * SCOPED_MAX_DEN <= n * SCOPED_MAX_NUM;
        let sub: Vec<(VId, VId)> = {
            let ms = mlock(&self.multiset);
            if scoped {
                // A surviving edge's endpoints share a union-find
                // component (the edge is part of its closure), so one
                // endpoint decides membership.
                ms.keys().copied().filter(|&(u, _)| affected[uf[u as usize] as usize]).collect()
            } else {
                ms.keys().copied().collect()
            }
        };
        let g = EdgeList::from_pairs(n, &sub).into_csr();
        let fresh = Contour::c2().with_threads(self.threads).run(&g);
        if !scoped {
            self.full_recontours.fetch_add(1, Ordering::Relaxed);
            return fresh;
        }
        self.scoped_recontours.fetch_add(1, Ordering::Relaxed);
        // Merge: a true component never spans affected and unaffected
        // union-find components (it refines them), so affected vertices
        // take the re-contoured labels — their entire component is in
        // the scoped subgraph, making its min-id the global one — and
        // everything else keeps its carried label.
        let mut out = uf;
        for v in 0..n {
            if affected[out[v] as usize] {
                out[v] = fresh[v];
            }
        }
        out
    }

    /// Seal the current epoch: run the re-contour compaction, publish
    /// the resulting snapshot, and append a seal marker to the WAL
    /// (fsynced). Insert-only epochs re-contour the union-find forest
    /// off the ingestion gate; epochs with deletions rebuild the
    /// affected components under it (see [`StreamingCc::delete_edges`]).
    /// Returns the new snapshot.
    pub fn seal_epoch(&self) -> Result<Arc<Snapshot>> {
        let _guard = mlock(&self.seal);
        let epoch = self.last_epoch.load(Ordering::Relaxed) + 1;
        // Consistent cut: with the gate held exclusively, no batch is
        // mid-application, so union-find and multiset are exactly the
        // acknowledged state, and the WAL seal marker written inside the
        // same critical section cleanly partitions the log at this
        // epoch.
        let cut = wlock(&self.gate);
        let edges = self.edges_ingested.load(Ordering::Relaxed);
        let live = self.edges_live.load(Ordering::Relaxed);
        let deletes: Vec<(VId, VId)> = std::mem::take(&mut *mlock(&self.pending_deletes));
        let labels = if deletes.is_empty() {
            let forest = self.inc.forest_edges(self.threads);
            if let Some(w) = &self.wal {
                // Buffered marker append only — it fixes the log order.
                mlock(w).seal_epoch(epoch)?;
            }
            // Durability fsync and re-contour compaction off the gate:
            // ingestion resumes while the disk syncs and labels are
            // recanonicalized. The forest is itself a graph with the
            // same components, so the paper's operator over it yields
            // the canonical min-id labelling of everything live.
            drop(cut);
            self.wal_sync_timed()?;
            let g = EdgeList::from_pairs(self.n(), &forest).into_csr();
            Contour::c2().with_threads(self.threads).run(&g)
        } else {
            if let Some(w) = &self.wal {
                mlock(w).seal_epoch(epoch)?;
            }
            // Delete epoch: the union-find can only merge, so the seal
            // must repair it before ingestion resumes — the re-contour
            // and the label store-back stay under the gate. Deletions
            // are the rare, expensive direction; inserts keep the
            // lock-free path above.
            let labels = self.recontour_deletes(&deletes);
            self.inc.store_labels(&labels, self.threads);
            drop(cut);
            self.wal_sync_timed()?;
            labels
        };
        let snap = Arc::new(Snapshot::from_labels(epoch, edges, labels).with_edges_live(live));
        {
            let mut h = wlock(&self.history);
            h.push_back(Arc::clone(&snap));
            if h.len() > self.max_history {
                h.pop_front();
            }
        }
        self.last_epoch.store(epoch, Ordering::Relaxed);
        Ok(snap)
    }

    /// The current epoch's snapshot (wait-free for practical purposes:
    /// the read-lock's writers hold it only for an O(1) push).
    pub fn current(&self) -> Arc<Snapshot> {
        let h = rlock(&self.history);
        Arc::clone(h.back().expect("history is never empty"))
    }

    /// The snapshot sealed as `epoch`, if still retained.
    pub fn at_epoch(&self, epoch: u64) -> Option<Arc<Snapshot>> {
        let h = rlock(&self.history);
        h.binary_search_by_key(&epoch, |s| s.epoch).ok().map(|i| Arc::clone(&h[i]))
    }

    /// Resolve a query target: `None` = current epoch, `Some(e)` = that
    /// sealed epoch (error if never sealed or already evicted).
    pub fn snapshot_at(&self, epoch: Option<u64>) -> Result<Arc<Snapshot>> {
        match epoch {
            None => Ok(self.current()),
            Some(e) => self.at_epoch(e).ok_or_else(|| {
                let h = rlock(&self.history);
                let span = match (h.front(), h.back()) {
                    (Some(a), Some(b)) => format!("{}..={}", a.epoch, b.epoch),
                    _ => "∅".to_string(),
                };
                anyhow!("epoch {e} not retained (history spans {span})")
            }),
        }
    }

    /// Persist the current snapshot to `path`; returns its epoch.
    pub fn save_snapshot(&self, path: &Path) -> Result<u64> {
        let snap = self.current();
        snap.save(path)?;
        Ok(snap.epoch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cc;
    use crate::graph::gen;

    #[test]
    fn epochs_publish_min_id_labels() {
        // Universe of 6; edges arrive in two epochs.
        let s = StreamingCc::new(6, 1);
        assert_eq!(s.epoch(), 0);
        assert_eq!(s.current().labels, vec![0, 1, 2, 3, 4, 5]);

        s.add_edges(&[(0, 1), (2, 3)]).unwrap();
        let e1 = s.seal_epoch().unwrap();
        assert_eq!(e1.epoch, 1);
        assert_eq!(e1.labels, vec![0, 0, 2, 2, 4, 5]);
        assert_eq!(e1.num_components, 4);
        assert_eq!(e1.edges_ingested, 2);

        s.add_edges(&[(1, 2), (4, 5)]).unwrap();
        let e2 = s.seal_epoch().unwrap();
        assert_eq!(e2.labels, vec![0, 0, 0, 0, 4, 4]);
        assert_eq!(e2.num_components, 2);

        // Past epochs stay queryable and immutable.
        let back = s.at_epoch(1).unwrap();
        assert_eq!(back.labels, vec![0, 0, 2, 2, 4, 5]);
        assert!(!back.same_comp(0, 3).unwrap());
        assert!(s.snapshot_at(Some(2)).unwrap().same_comp(0, 3).unwrap());
        assert!(s.snapshot_at(Some(9)).is_err());
        assert!(s.at_epoch(9).is_none());
    }

    #[test]
    fn streamed_equals_static_contour() {
        let g = gen::rmat(10, 3_000, gen::RmatKind::Graph500, 5).into_csr();
        let s = StreamingCc::new(g.n, 0);
        let edges: Vec<(VId, VId)> = g.edges().collect();
        for chunk in edges.chunks(137) {
            s.add_edges(chunk).unwrap();
        }
        let fin = s.seal_epoch().unwrap();
        let want = Contour::c2().run(&g);
        assert_eq!(fin.labels, want);
        assert_eq!(fin.labels, cc::ground_truth(&g));
        assert_eq!(s.edges_ingested(), edges.len());
    }

    #[test]
    fn live_probe_sees_unsealed_edges() {
        let s = StreamingCc::new(4, 1);
        s.add_edges(&[(0, 3)]).unwrap();
        assert!(s.connected_live(0, 3).unwrap());
        // The published snapshot (epoch 0) predates the edge.
        assert!(!s.current().same_comp(0, 3).unwrap());
        assert!(s.connected_live(0, 9).is_err());
    }

    #[test]
    fn rejects_out_of_range_edges() {
        let s = StreamingCc::new(3, 1);
        assert!(s.add_edges(&[(0, 1), (1, 7)]).is_err());
        // The bad batch must not have been partially counted.
        assert_eq!(s.edges_ingested(), 0);
    }

    #[test]
    fn history_eviction_keeps_the_newest() {
        let s = StreamingCc::new(8, 1).with_max_history(3);
        for i in 0..6u32 {
            s.add_edges(&[(i % 7, i % 7 + 1)]).unwrap();
            s.seal_epoch().unwrap();
        }
        assert_eq!(s.epoch(), 6);
        assert!(s.at_epoch(2).is_none(), "old epochs evicted");
        assert!(s.at_epoch(4).is_some());
        assert!(s.at_epoch(6).is_some());
    }

    #[test]
    fn deletions_split_components_at_the_seal() {
        let s = StreamingCc::new(6, 1);
        s.add_edges(&[(0, 1), (1, 2), (3, 4)]).unwrap();
        s.seal_epoch().unwrap();
        assert_eq!(s.current().labels, vec![0, 0, 0, 3, 3, 5]);
        assert_eq!(s.delete_edges(&[(1, 2)]).unwrap(), 1);
        // Deletes publish at the next seal: the current snapshot (and
        // possibly the live probe) still see the severed pair merged.
        assert!(s.current().same_comp(0, 2).unwrap());
        let e = s.seal_epoch().unwrap();
        assert_eq!(e.labels, vec![0, 0, 2, 3, 3, 5]);
        assert_eq!(e.edges_ingested, 3);
        assert_eq!(e.edges_live, 2);
        assert_eq!(s.edges_deleted(), 1);
        assert!(!s.connected_live(0, 2).unwrap(), "seal repaired the union-find");
        // Deleting a pair that is not live fails the whole batch: the
        // live edge riding along with a dead one stays untouched.
        assert!(s.delete_edges(&[(1, 2)]).is_err());
        assert!(s.delete_edges(&[(0, 5)]).is_err());
        assert!(s.delete_edges(&[(0, 1), (1, 2)]).is_err());
        assert_eq!(s.edges_deleted(), 1);
        assert_eq!(s.edges_live(), 2, "rejected batches apply nothing");
        // Out-of-range deletes error like out-of-range inserts.
        assert!(s.delete_edges(&[(0, 9)]).is_err());
    }

    #[test]
    fn parallel_edges_are_a_multiset() {
        let s = StreamingCc::new(3, 1);
        s.add_edges(&[(0, 1), (1, 0), (1, 2)]).unwrap(); // (0,1) twice
        assert_eq!(s.edges_live(), 3);
        assert_eq!(s.delete_edges(&[(0, 1)]).unwrap(), 1);
        let e = s.seal_epoch().unwrap();
        assert!(e.same_comp(0, 1).unwrap(), "one multiplicity survives");
        assert_eq!(s.delete_edges(&[(1, 0)]).unwrap(), 1, "orientation is normalized");
        let e = s.seal_epoch().unwrap();
        assert!(!e.same_comp(0, 1).unwrap(), "last multiplicity severs the pair");
        assert!(e.same_comp(1, 2).unwrap());
        // A batch claiming more multiplicity than is live is rejected
        // whole — not partially applied.
        s.add_edges(&[(0, 1)]).unwrap();
        assert!(s.delete_edges(&[(0, 1), (0, 1)]).is_err());
        assert_eq!(s.delete_edges(&[(0, 1)]).unwrap(), 1);
    }

    #[test]
    fn self_loops_are_dropped_and_uncounted() {
        // Regression: self-loops used to inflate `edges_ingested`.
        let s = StreamingCc::new(4, 1);
        assert_eq!(s.add_edges(&[(1, 1), (0, 1), (2, 2)]).unwrap(), 1);
        assert_eq!(s.edges_ingested(), 1);
        assert_eq!(s.edges_live(), 1);
        assert_eq!(s.add_edges(&[(3, 3)]).unwrap(), 0);
        assert_eq!(s.edges_ingested(), 1);
        let e = s.seal_epoch().unwrap();
        assert_eq!(e.edges_ingested, 1);
        assert!(s.delete_edges(&[(1, 1)]).is_err(), "self-loops are never live");
    }

    #[test]
    fn scoped_recontour_matches_full_recompute() {
        // Two far-apart paths; a delete inside one must not touch the
        // other's labels, via the scoped path.
        let n = 100usize;
        let s = StreamingCc::new(n, 1);
        let mut edges: Vec<(VId, VId)> = Vec::new();
        for v in 0..40u32 {
            edges.push((v, v + 1)); // path over 0..=40 (41 vertices)
        }
        for v in 60..99u32 {
            edges.push((v, v + 1)); // path over 60..=99 (40 vertices)
        }
        s.add_edges(&edges).unwrap();
        s.seal_epoch().unwrap();
        assert_eq!(s.delete_edges(&[(20, 21)]).unwrap(), 1);
        let e = s.seal_epoch().unwrap();
        assert_eq!(s.scoped_recontours(), 1, "affected mass 41 of 100 stays scoped");
        assert_eq!(s.full_recontours(), 0);
        let survivors: Vec<(VId, VId)> =
            edges.iter().copied().filter(|&p| p != (20, 21)).collect();
        let g = EdgeList::from_pairs(n, &survivors).into_csr();
        assert_eq!(e.labels, Contour::c2().run(&g));
        // Join both halves, then cut the bridge: the affected component
        // now covers more than half the universe → full re-contour.
        s.add_edges(&[(40, 60)]).unwrap();
        s.seal_epoch().unwrap();
        assert_eq!(s.delete_edges(&[(40, 60)]).unwrap(), 1);
        let e = s.seal_epoch().unwrap();
        assert_eq!(s.full_recontours(), 1, "affected mass 81 of 100 goes full");
        let g = EdgeList::from_pairs(n, &survivors).into_csr();
        assert_eq!(e.labels, Contour::c2().run(&g));
        assert_eq!(e.edges_live, survivors.len());
    }

    #[test]
    fn insert_delete_epochs_match_static_contour() {
        // Churny differential check: interleave insert, delete and seal
        // against a mirror multiset; every sealed epoch must equal a
        // from-scratch static Contour over the surviving edges.
        let g = gen::erdos_renyi(400, 900, 13).into_csr();
        let edges: Vec<(VId, VId)> = g.edges().collect();
        let s = StreamingCc::new(g.n, 1);
        let mut live: Vec<(VId, VId)> = Vec::new();
        for (i, chunk) in edges.chunks(64).enumerate() {
            s.add_edges(chunk).unwrap();
            live.extend_from_slice(chunk);
            // Delete every third previously inserted edge of this chunk.
            let doomed: Vec<(VId, VId)> = chunk.iter().copied().step_by(3).collect();
            assert_eq!(s.delete_edges(&doomed).unwrap(), doomed.len());
            live.retain(|p| !doomed.contains(p));
            if i % 2 == 0 {
                let snap = s.seal_epoch().unwrap();
                let want =
                    Contour::c2().run(&EdgeList::from_pairs(g.n, &live).into_csr());
                assert_eq!(snap.labels, want, "epoch {}", snap.epoch);
                assert_eq!(snap.edges_live, live.len());
            }
        }
        let snap = s.seal_epoch().unwrap();
        let want = Contour::c2().run(&EdgeList::from_pairs(g.n, &live).into_csr());
        assert_eq!(snap.labels, want);
    }

    #[test]
    fn queries_across_an_eviction_boundary() {
        let s = StreamingCc::new(8, 1).with_max_history(3);
        for i in 0..7u32 {
            s.add_edges(&[(i, i + 1)]).unwrap();
            s.seal_epoch().unwrap();
        }
        // History holds epochs 5..=7; the binary search must stay
        // correct after front evictions wrapped the deque's ring.
        assert!(s.at_epoch(4).is_none());
        for e in 5..=7u64 {
            let snap = s.at_epoch(e).unwrap();
            assert_eq!(snap.epoch, e);
            assert_eq!(snap.edges_ingested, e as usize);
        }
        assert_eq!(s.current().epoch, 7);
        let err = s.snapshot_at(Some(2)).unwrap_err().to_string();
        assert!(err.contains("history spans 5..=7"), "{err}");
    }

    #[test]
    fn concurrent_ingestion_and_sealing() {
        let n = 30_000usize;
        let s = StreamingCc::new(n, 1);
        std::thread::scope(|sc| {
            for t in 0..4usize {
                let s = &s;
                sc.spawn(move || {
                    let edges: Vec<(VId, VId)> = (t..n - 1)
                        .step_by(4)
                        .map(|i| (i as VId, (i + 1) as VId))
                        .collect();
                    for chunk in edges.chunks(256) {
                        s.add_edges(chunk).unwrap();
                    }
                });
            }
            let s = &s;
            sc.spawn(move || {
                for _ in 0..5 {
                    s.seal_epoch().unwrap();
                }
            });
        });
        let fin = s.seal_epoch().unwrap();
        assert_eq!(fin.num_components, 1);
        assert!(fin.labels.iter().all(|&l| l == 0));
        // Components can only merge over epochs.
        let h: Vec<usize> = (1..=s.epoch())
            .filter_map(|e| s.at_epoch(e))
            .map(|snap| snap.num_components)
            .collect();
        assert!(h.windows(2).all(|w| w[1] <= w[0]), "components must be non-increasing: {h:?}");
    }
}
