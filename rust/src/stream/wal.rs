//! Write-ahead edge log for the streaming connectivity subsystem.
//!
//! Append-only binary file, three on-disk versions:
//!
//! ```text
//!   v1 header:  "CONTRWAL"  n: u64 LE        (vertex universe size)
//!   v1 frames:  0x01  count: u32 LE  count × (u: u32 LE, v: u32 LE)
//!               0x02  epoch: u64 LE          (epoch seal marker)
//!
//!   v2 header:  "CONTRWL2"  n: u64 LE
//!   v2 frames:  as v1, each followed by crc: u32 LE
//!               (CRC-32/IEEE over the frame bytes: tag + payload)
//!
//!   v3 header:  "CONTRWL3"  n: u64 LE
//!   v3 frames:  as v2, plus
//!               0x03  count: u32 LE  count × (u: u32 LE, v: u32 LE)
//!                                            (delete batch, CRC'd)
//! ```
//!
//! New logs are written as v3; v1/v2 logs remain readable and appendable
//! in their own format. A delete frame in a v1/v2 log is corruption (the
//! format cannot hold one), and [`Wal::append_deletes`] refuses to write
//! it there. Edges and deletions are logged *before* they are applied,
//! so a crash can lose at most work that was never acknowledged. Replay
//! is tolerant of a torn final frame (the crash-mid-append case):
//! parsing stops at the first incomplete frame and everything before it
//! is recovered. A frame with an unknown tag, an out-of-range vertex, or
//! a checksum mismatch is corruption, not truncation, and fails loudly
//! with the byte offset of the bad frame.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::util::{crc, faults};
use crate::VId;

const WAL_MAGIC_V1: &[u8; 8] = b"CONTRWAL";
const WAL_MAGIC_V2: &[u8; 8] = b"CONTRWL2";
const WAL_MAGIC_V3: &[u8; 8] = b"CONTRWL3";
const FRAME_EDGES: u8 = 0x01;
const FRAME_SEAL: u8 = 0x02;
const FRAME_DELETE: u8 = 0x03;

/// One recovered WAL entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// A batch of inserted edges.
    Edges(Vec<(VId, VId)>),
    /// An epoch was sealed after everything logged before this marker.
    EpochSeal(u64),
    /// A batch of deleted edges (one multiplicity each; v3 logs only).
    Deletes(Vec<(VId, VId)>),
}

/// What [`Wal::replay_and_repair`] found and fixed.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RepairStats {
    /// Complete frames recovered from the log.
    pub frames: usize,
    /// Bytes of torn tail truncated away (0 for a clean log).
    pub truncated_bytes: u64,
}

/// An open WAL, positioned for appending.
///
/// Every append is flushed to the OS (one frame per `write` syscall
/// burst); [`Wal::sync`] additionally fsyncs, and epoch seals are the
/// natural place callers do that.
pub struct Wal {
    w: BufWriter<File>,
    /// Frame format version of the underlying file (1, 2 or 3);
    /// appends must match it.
    ver: u8,
}

impl Wal {
    /// Create a fresh WAL at `path` (truncating any existing file) for a
    /// universe of `n` vertices. New logs use the checksummed v3 format
    /// (delete frames allowed).
    pub fn create(path: &Path, n: usize) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create WAL dir {}", dir.display()))?;
            }
        }
        let f = File::create(path).with_context(|| format!("create WAL {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(WAL_MAGIC_V3)?;
        w.write_all(&(n as u64).to_le_bytes())?;
        w.flush()?;
        Ok(Self { w, ver: 3 })
    }

    /// Read just the header of an existing WAL: the vertex universe size
    /// and the frame format version. Cheap (16 bytes) — lets callers
    /// validate before replaying or mutating the log.
    fn header(path: &Path) -> Result<(usize, u8)> {
        let mut head = [0u8; 16];
        File::open(path)
            .and_then(|mut f| f.read_exact(&mut head))
            .with_context(|| format!("read WAL header {}", path.display()))?;
        let ver = match &head[..8] {
            m if m == WAL_MAGIC_V3 => 3,
            m if m == WAL_MAGIC_V2 => 2,
            m if m == WAL_MAGIC_V1 => 1,
            _ => bail!("{}: not a contour WAL", path.display()),
        };
        Ok((u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize, ver))
    }

    /// The vertex universe size recorded in an existing WAL's header.
    pub fn universe(path: &Path) -> Result<usize> {
        Ok(Self::header(path)?.0)
    }

    /// Open an existing WAL for appending; returns the log and the
    /// vertex universe size recorded in its header. Appends continue in
    /// the file's own frame format (v1 stays v1).
    pub fn append_to(path: &Path) -> Result<(Self, usize)> {
        let (n, ver) = Self::header(path)?;
        let f = OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("open WAL {} for append", path.display()))?;
        Ok((Self { w: BufWriter::new(f), ver }, n))
    }

    /// Append one pair-list frame (insert or delete batch).
    fn append_pairs(&mut self, tag: u8, edges: &[(VId, VId)]) -> Result<()> {
        if edges.is_empty() {
            return Ok(());
        }
        if faults::hit("wal.append")? {
            return Ok(());
        }
        let mut buf = Vec::with_capacity(5 + 8 * edges.len() + 4);
        buf.push(tag);
        buf.extend_from_slice(&(edges.len() as u32).to_le_bytes());
        for &(u, v) in edges {
            buf.extend_from_slice(&u.to_le_bytes());
            buf.extend_from_slice(&v.to_le_bytes());
        }
        if self.ver >= 2 {
            let crc = crc::crc32(&buf);
            buf.extend_from_slice(&crc.to_le_bytes());
        }
        self.w.write_all(&buf)?;
        self.w.flush()?;
        Ok(())
    }

    /// Append one edge batch (no-op for an empty batch).
    ///
    /// Failpoint `wal.append`: `err` fails the append before any bytes
    /// are written (the batch is never acknowledged, so recovery stays
    /// consistent); `drop` silently loses the frame (simulates a lost
    /// write that the next replay must tolerate as a missing suffix).
    pub fn append_edges(&mut self, edges: &[(VId, VId)]) -> Result<()> {
        self.append_pairs(FRAME_EDGES, edges)
    }

    /// Append one delete batch (no-op for an empty batch). Only v3 logs
    /// can hold delete frames — appending to an older format fails
    /// cleanly *before* any bytes are written, so the caller's batch
    /// stays entirely unapplied. The `wal.append` failpoint applies.
    pub fn append_deletes(&mut self, edges: &[(VId, VId)]) -> Result<()> {
        ensure!(
            self.ver >= 3,
            "WAL format v{} cannot hold delete frames (v3 required — recreate the log)",
            self.ver
        );
        self.append_pairs(FRAME_DELETE, edges)
    }

    /// Append an epoch seal marker (failpoint `wal.append` applies).
    pub fn seal_epoch(&mut self, epoch: u64) -> Result<()> {
        if faults::hit("wal.append")? {
            return Ok(());
        }
        let mut buf = [0u8; 13];
        buf[0] = FRAME_SEAL;
        buf[1..9].copy_from_slice(&epoch.to_le_bytes());
        let len = if self.ver >= 2 {
            let crc = crc::crc32(&buf[..9]);
            buf[9..].copy_from_slice(&crc.to_le_bytes());
            13
        } else {
            9
        };
        self.w.write_all(&buf[..len])?;
        self.w.flush()?;
        Ok(())
    }

    /// Flush and fsync (failpoint `wal.fsync`: `err` fails the fsync).
    pub fn sync(&mut self) -> Result<()> {
        if faults::hit("wal.fsync")? {
            return Ok(());
        }
        self.w.flush()?;
        self.w.get_ref().sync_all()?;
        Ok(())
    }

    /// Scan a WAL from disk: returns the vertex universe size and every
    /// complete record, stopping silently at a torn tail frame.
    pub fn replay(path: &Path) -> Result<(usize, Vec<WalRecord>)> {
        let (n, records, _) = Self::scan(path)?;
        Ok((n, records))
    }

    /// [`Wal::replay`] plus repair: if the log ends in a torn frame
    /// (crash mid-append), truncate it away so subsequent appends start
    /// at a clean frame boundary — appending after torn bytes would make
    /// the next replay misparse or silently drop everything after them.
    /// Call before re-attaching an appender (recovery does). Returns the
    /// records plus [`RepairStats`] for recovery reporting.
    pub fn replay_and_repair(path: &Path) -> Result<(usize, Vec<WalRecord>, RepairStats)> {
        let (n, records, valid_end) = Self::scan(path)?;
        let len = std::fs::metadata(path)?.len();
        let mut stats = RepairStats { frames: records.len(), truncated_bytes: 0 };
        if valid_end < len {
            let f = OpenOptions::new()
                .write(true)
                .open(path)
                .with_context(|| format!("open WAL {} for repair", path.display()))?;
            f.set_len(valid_end)?;
            f.sync_all()?;
            stats.truncated_bytes = len - valid_end;
        }
        Ok((n, records, stats))
    }

    /// Parse the log, returning (universe, records, end offset of the
    /// last complete frame).
    fn scan(path: &Path) -> Result<(usize, Vec<WalRecord>, u64)> {
        let data =
            std::fs::read(path).with_context(|| format!("read WAL {}", path.display()))?;
        ensure!(data.len() >= 16, "{}: not a contour WAL", path.display());
        let ver: u8 = match &data[..8] {
            m if m == WAL_MAGIC_V3 => 3,
            m if m == WAL_MAGIC_V2 => 2,
            m if m == WAL_MAGIC_V1 => 1,
            _ => bail!("{}: not a contour WAL", path.display()),
        };
        let n = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
        let crc_len = if ver >= 2 { 4usize } else { 0 };
        let mut records = Vec::new();
        let mut off = 16usize;
        while off < data.len() {
            match data[off] {
                tag @ (FRAME_EDGES | FRAME_DELETE) => {
                    // A delete frame inside a pre-v3 log cannot have
                    // been written by any appender — corruption, not a
                    // format quirk.
                    ensure!(
                        tag == FRAME_EDGES || ver >= 3,
                        "{}: delete frame in a v{ver} WAL at byte {off} (v3 required)",
                        path.display()
                    );
                    let Some(count) = read_u32(&data, off + 1) else { break };
                    let body_end = off + 5 + 8 * count as usize;
                    let end = body_end + crc_len;
                    if end > data.len() {
                        break; // torn frame: crash mid-append
                    }
                    check_crc(&data, off, body_end, ver >= 2, path)?;
                    let mut edges = Vec::with_capacity(count as usize);
                    let mut p = off + 5;
                    while p < body_end {
                        let u = read_u32(&data, p).unwrap();
                        let v = read_u32(&data, p + 4).unwrap();
                        ensure!(
                            (u as usize) < n && (v as usize) < n,
                            "{}: edge ({u}, {v}) out of range (n = {n}) at byte {off}",
                            path.display()
                        );
                        edges.push((u, v));
                        p += 8;
                    }
                    records.push(if tag == FRAME_EDGES {
                        WalRecord::Edges(edges)
                    } else {
                        WalRecord::Deletes(edges)
                    });
                    off = end;
                }
                FRAME_SEAL => {
                    let body_end = off + 9;
                    let end = body_end + crc_len;
                    if end > data.len() {
                        break; // torn seal
                    }
                    check_crc(&data, off, body_end, ver >= 2, path)?;
                    let epoch = u64::from_le_bytes(data[off + 1..off + 9].try_into().unwrap());
                    records.push(WalRecord::EpochSeal(epoch));
                    off = end;
                }
                other => {
                    bail!("{}: corrupt WAL frame tag {other:#04x} at byte {off}", path.display())
                }
            }
        }
        Ok((n, records, off as u64))
    }
}

/// Verify a checksummed frame's trailing CRC (no-op for v1). The frame
/// spans `data[off..body_end]` with the stored CRC directly after it;
/// callers have already bounds-checked `body_end + 4`.
fn check_crc(data: &[u8], off: usize, body_end: usize, v2: bool, path: &Path) -> Result<()> {
    if !v2 {
        return Ok(());
    }
    let stored = read_u32(data, body_end).unwrap();
    let actual = crc::crc32(&data[off..body_end]);
    ensure!(
        stored == actual,
        "{}: WAL checksum mismatch at byte {off} (stored {stored:#010x}, computed {actual:#010x})",
        path.display()
    );
    Ok(())
}

fn read_u32(data: &[u8], off: usize) -> Option<u32> {
    data.get(off..off + 4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("contour_wal_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Hand-build a v1 or v2 log to pin compat (v1: no per-frame CRCs;
    /// v2: CRC'd frames, but no delete frames exist in either).
    fn write_legacy(path: &Path, ver: u8, n: u64, frames: &[WalRecord]) {
        assert!(ver == 1 || ver == 2);
        let mut data = Vec::new();
        data.extend_from_slice(if ver == 1 { WAL_MAGIC_V1 } else { WAL_MAGIC_V2 });
        data.extend_from_slice(&n.to_le_bytes());
        for rec in frames {
            let mut frame = Vec::new();
            match rec {
                WalRecord::Edges(edges) => {
                    frame.push(FRAME_EDGES);
                    frame.extend_from_slice(&(edges.len() as u32).to_le_bytes());
                    for &(u, v) in edges {
                        frame.extend_from_slice(&u.to_le_bytes());
                        frame.extend_from_slice(&v.to_le_bytes());
                    }
                }
                WalRecord::EpochSeal(e) => {
                    frame.push(FRAME_SEAL);
                    frame.extend_from_slice(&e.to_le_bytes());
                }
                WalRecord::Deletes(_) => panic!("legacy formats hold no delete frames"),
            }
            if ver == 2 {
                let crc = crate::util::crc::crc32(&frame);
                frame.extend_from_slice(&crc.to_le_bytes());
            }
            data.extend_from_slice(&frame);
        }
        std::fs::write(path, data).unwrap();
    }

    fn write_v1(path: &Path, n: u64, frames: &[WalRecord]) {
        write_legacy(path, 1, n, frames);
    }

    #[test]
    fn round_trip_batches_and_seals() {
        let p = temp("round_trip.wal");
        {
            let mut w = Wal::create(&p, 100).unwrap();
            w.append_edges(&[(0, 1), (2, 3)]).unwrap();
            w.seal_epoch(1).unwrap();
            w.append_edges(&[(4, 5)]).unwrap();
            w.append_edges(&[]).unwrap(); // no-op, no frame
            w.sync().unwrap();
        }
        let (n, recs) = Wal::replay(&p).unwrap();
        assert_eq!(n, 100);
        assert_eq!(
            recs,
            vec![
                WalRecord::Edges(vec![(0, 1), (2, 3)]),
                WalRecord::EpochSeal(1),
                WalRecord::Edges(vec![(4, 5)]),
            ]
        );
    }

    #[test]
    fn append_to_continues_an_existing_log() {
        let p = temp("append_to.wal");
        {
            let mut w = Wal::create(&p, 64).unwrap();
            w.append_edges(&[(1, 2)]).unwrap();
        }
        {
            let (mut w, n) = Wal::append_to(&p).unwrap();
            assert_eq!(n, 64);
            w.append_edges(&[(3, 4)]).unwrap();
        }
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1], WalRecord::Edges(vec![(3, 4)]));
    }

    #[test]
    fn v1_logs_replay_and_append_in_their_own_format() {
        let p = temp("compat_v1.wal");
        let frames =
            vec![WalRecord::Edges(vec![(0, 1), (2, 3)]), WalRecord::EpochSeal(1)];
        write_v1(&p, 50, &frames);
        let (n, recs) = Wal::replay(&p).unwrap();
        assert_eq!(n, 50);
        assert_eq!(recs, frames);
        // Appending to a v1 log keeps writing v1 frames (no CRC), and the
        // whole file still replays.
        let (mut w, n) = Wal::append_to(&p).unwrap();
        assert_eq!(n, 50);
        w.append_edges(&[(4, 5)]).unwrap();
        w.seal_epoch(2).unwrap();
        drop(w);
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(recs.len(), 4);
        assert_eq!(recs[2], WalRecord::Edges(vec![(4, 5)]));
        assert_eq!(recs[3], WalRecord::EpochSeal(2));
    }

    #[test]
    fn torn_tail_is_tolerated_corruption_is_not() {
        let p = temp("torn.wal");
        {
            let mut w = Wal::create(&p, 10).unwrap();
            w.append_edges(&[(0, 1)]).unwrap();
            w.append_edges(&[(2, 3), (4, 5)]).unwrap();
        }
        // Tear 3 bytes off the final frame: only the first batch survives.
        let len = std::fs::metadata(&p).unwrap().len();
        let f = OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(len - 3).unwrap();
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(recs, vec![WalRecord::Edges(vec![(0, 1)])]);

        // A bogus frame tag is corruption and must fail loudly.
        let r = temp("bad_tag.wal");
        let mut w = Wal::create(&r, 10).unwrap();
        w.append_edges(&[(0, 1)]).unwrap();
        drop(w);
        let mut data = std::fs::read(&r).unwrap();
        data.push(0x7F);
        std::fs::write(&r, &data).unwrap();
        assert!(Wal::replay(&r).is_err());

        // So is an edge outside the declared universe.
        let q = temp("bad_vertex.wal");
        write_v1(&q, 4, &[WalRecord::Edges(vec![(0, 9)])]);
        assert!(Wal::replay(&q).is_err());
    }

    #[test]
    fn bit_flip_fails_with_byte_offset() {
        let p = temp("bit_flip.wal");
        {
            let mut w = Wal::create(&p, 10).unwrap();
            w.append_edges(&[(0, 1)]).unwrap(); // frame at byte 16
            w.append_edges(&[(2, 3)]).unwrap(); // frame at byte 33
        }
        let mut data = std::fs::read(&p).unwrap();
        data[40] ^= 0x04; // flip a vertex-id bit inside the second frame
        std::fs::write(&p, &data).unwrap();
        let err = Wal::replay(&p).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch at byte 33"), "{err}");
        // v1 logs have no CRC: the same flip there goes undetected unless
        // it breaks framing — that asymmetry is exactly why v2 exists.
    }

    #[test]
    fn torn_crc_is_truncation_not_corruption() {
        let p = temp("torn_crc.wal");
        {
            let mut w = Wal::create(&p, 10).unwrap();
            w.append_edges(&[(0, 1)]).unwrap();
            w.append_edges(&[(2, 3)]).unwrap();
        }
        // Cut inside the second frame's trailing CRC: the frame body is
        // complete but unverifiable — treated as torn, not corrupt.
        let len = std::fs::metadata(&p).unwrap().len();
        let f = OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(len - 2).unwrap();
        drop(f);
        let (_, recs, stats) = Wal::replay_and_repair(&p).unwrap();
        assert_eq!(recs, vec![WalRecord::Edges(vec![(0, 1)])]);
        assert_eq!(stats.frames, 1);
        assert_eq!(stats.truncated_bytes, 11); // 1 + 4 + 8 + 4 - 2 torn bytes
    }

    #[test]
    fn repair_truncates_torn_tail_before_reappending() {
        let p = temp("repair.wal");
        {
            let mut w = Wal::create(&p, 10).unwrap();
            w.append_edges(&[(0, 1)]).unwrap();
            w.append_edges(&[(2, 3), (4, 5)]).unwrap();
        }
        let len = std::fs::metadata(&p).unwrap().len();
        let f = OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(len - 3).unwrap(); // tear the last frame
        drop(f);
        // Repair drops the torn frame and truncates the file...
        let (_, recs, stats) = Wal::replay_and_repair(&p).unwrap();
        assert_eq!(recs, vec![WalRecord::Edges(vec![(0, 1)])]);
        assert_eq!(stats.frames, 1);
        assert!(stats.truncated_bytes > 0);
        // ...so appending resumes at a clean boundary: without the
        // truncate, these bytes would land after the torn frame and the
        // next replay would misparse or drop them.
        let (mut w, _) = Wal::append_to(&p).unwrap();
        w.append_edges(&[(6, 7)]).unwrap();
        w.seal_epoch(1).unwrap();
        drop(w);
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(
            recs,
            vec![
                WalRecord::Edges(vec![(0, 1)]),
                WalRecord::Edges(vec![(6, 7)]),
                WalRecord::EpochSeal(1),
            ]
        );
    }

    #[test]
    fn injected_append_error_leaves_log_replayable() {
        let _g = crate::util::faults::test_lock();
        crate::util::faults::configure("wal.append=err@2").unwrap();
        let p = temp("fault_append.wal");
        let mut w = Wal::create(&p, 10).unwrap();
        w.append_edges(&[(0, 1)]).unwrap();
        let err = w.append_edges(&[(2, 3)]).unwrap_err().to_string();
        assert!(err.contains("injected fault at wal.append"), "{err}");
        crate::util::faults::clear();
        w.append_edges(&[(4, 5)]).unwrap();
        drop(w);
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(
            recs,
            vec![WalRecord::Edges(vec![(0, 1)]), WalRecord::Edges(vec![(4, 5)])]
        );
    }

    #[test]
    fn rejects_non_wal_files() {
        let p = temp("not_a.wal");
        std::fs::write(&p, b"hello world, definitely a wal").unwrap();
        assert!(Wal::replay(&p).is_err());
        assert!(Wal::append_to(&p).is_err());
    }

    #[test]
    fn delete_frames_round_trip() {
        let p = temp("deletes.wal");
        {
            let mut w = Wal::create(&p, 10).unwrap();
            w.append_edges(&[(0, 1), (2, 3)]).unwrap();
            w.append_deletes(&[(0, 1)]).unwrap();
            w.append_deletes(&[]).unwrap(); // no-op, no frame
            w.seal_epoch(1).unwrap();
            w.sync().unwrap();
        }
        let (n, recs) = Wal::replay(&p).unwrap();
        assert_eq!(n, 10);
        assert_eq!(
            recs,
            vec![
                WalRecord::Edges(vec![(0, 1), (2, 3)]),
                WalRecord::Deletes(vec![(0, 1)]),
                WalRecord::EpochSeal(1),
            ]
        );
    }

    #[test]
    fn legacy_logs_refuse_delete_appends() {
        // v2: replays fine, appends stay v2, deletes refused cleanly.
        let p = temp("compat_v2.wal");
        let frames = vec![WalRecord::Edges(vec![(0, 1)]), WalRecord::EpochSeal(1)];
        write_legacy(&p, 2, 20, &frames);
        let (n, recs) = Wal::replay(&p).unwrap();
        assert_eq!((n, recs), (20, frames));
        let (mut w, _) = Wal::append_to(&p).unwrap();
        w.append_edges(&[(2, 3)]).unwrap();
        let err = w.append_deletes(&[(0, 1)]).unwrap_err().to_string();
        assert!(err.contains("v2 cannot hold delete frames"), "{err}");
        drop(w);
        // The refused append wrote nothing: the log is still clean.
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(recs.len(), 3);
        assert_eq!(recs[2], WalRecord::Edges(vec![(2, 3)]));
        // v1: same refusal.
        let q = temp("compat_v1_del.wal");
        write_v1(&q, 20, &[WalRecord::Edges(vec![(4, 5)])]);
        let (mut w, _) = Wal::append_to(&q).unwrap();
        assert!(w.append_deletes(&[(4, 5)]).is_err());
    }

    #[test]
    fn delete_tag_in_a_legacy_log_is_corruption() {
        let p = temp("v2_delete_tag.wal");
        write_legacy(&p, 2, 10, &[WalRecord::Edges(vec![(0, 1)])]);
        let mut data = std::fs::read(&p).unwrap();
        // Hand-forge a CRC-valid delete frame: the version check must
        // reject it anyway — no v2 appender can have written it.
        let mut frame = vec![FRAME_DELETE];
        frame.extend_from_slice(&1u32.to_le_bytes());
        frame.extend_from_slice(&0u32.to_le_bytes());
        frame.extend_from_slice(&1u32.to_le_bytes());
        let crc = crate::util::crc::crc32(&frame);
        frame.extend_from_slice(&crc.to_le_bytes());
        data.extend_from_slice(&frame);
        std::fs::write(&p, &data).unwrap();
        let err = Wal::replay(&p).unwrap_err().to_string();
        assert!(err.contains("delete frame in a v2 WAL at byte 33"), "{err}");
    }

    #[test]
    fn torn_delete_tail_truncates_corrupt_delete_frame_fails() {
        let p = temp("torn_delete.wal");
        {
            let mut w = Wal::create(&p, 10).unwrap();
            w.append_edges(&[(0, 1), (2, 3)]).unwrap();
            w.append_deletes(&[(0, 1), (2, 3)]).unwrap();
        }
        // Tear mid-delete-frame: the insert batch survives, the torn
        // delete is truncated away, and appends resume cleanly.
        let len = std::fs::metadata(&p).unwrap().len();
        let f = OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(len - 5).unwrap();
        drop(f);
        let (_, recs, stats) = Wal::replay_and_repair(&p).unwrap();
        assert_eq!(recs, vec![WalRecord::Edges(vec![(0, 1), (2, 3)])]);
        assert!(stats.truncated_bytes > 0);
        let (mut w, _) = Wal::append_to(&p).unwrap();
        w.append_deletes(&[(0, 1)]).unwrap();
        drop(w);
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(recs[1], WalRecord::Deletes(vec![(0, 1)]));

        // Interior bit flip inside a delete frame: loud, with offset.
        let q = temp("corrupt_delete.wal");
        {
            let mut w = Wal::create(&q, 10).unwrap();
            w.append_edges(&[(0, 1)]).unwrap(); // frame at byte 16
            w.append_deletes(&[(0, 1)]).unwrap(); // frame at byte 33
        }
        let mut data = std::fs::read(&q).unwrap();
        data[40] ^= 0x02; // flip a vertex-id bit inside the delete frame
        std::fs::write(&q, &data).unwrap();
        let err = Wal::replay(&q).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch at byte 33"), "{err}");
    }
}
