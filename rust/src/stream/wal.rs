//! Write-ahead edge log for the streaming connectivity subsystem.
//!
//! Append-only binary file. Layout:
//!
//! ```text
//!   header:  "CONTRWAL"  n: u64 LE          (vertex universe size)
//!   frames:  0x01  count: u32 LE  count × (u: u32 LE, v: u32 LE)
//!            0x02  epoch: u64 LE            (epoch seal marker)
//! ```
//!
//! Edges are logged *before* they are applied to the union-find, so a
//! crash can lose at most work that was never acknowledged. Replay is
//! tolerant of a torn final frame (the crash-mid-append case): parsing
//! stops at the first incomplete frame and everything before it is
//! recovered. A frame with an unknown tag or an out-of-range vertex is
//! corruption, not truncation, and fails loudly.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::VId;

const WAL_MAGIC: &[u8; 8] = b"CONTRWAL";
const FRAME_EDGES: u8 = 0x01;
const FRAME_SEAL: u8 = 0x02;

/// One recovered WAL entry.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// A batch of inserted edges.
    Edges(Vec<(VId, VId)>),
    /// An epoch was sealed after everything logged before this marker.
    EpochSeal(u64),
}

/// An open WAL, positioned for appending.
///
/// Every append is flushed to the OS (one frame per `write` syscall
/// burst); [`Wal::sync`] additionally fsyncs, and epoch seals are the
/// natural place callers do that.
pub struct Wal {
    w: BufWriter<File>,
}

impl Wal {
    /// Create a fresh WAL at `path` (truncating any existing file) for a
    /// universe of `n` vertices.
    pub fn create(path: &Path, n: usize) -> Result<Self> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("create WAL dir {}", dir.display()))?;
            }
        }
        let f = File::create(path).with_context(|| format!("create WAL {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(WAL_MAGIC)?;
        w.write_all(&(n as u64).to_le_bytes())?;
        w.flush()?;
        Ok(Self { w })
    }

    /// Read just the header of an existing WAL: the vertex universe
    /// size. Cheap (16 bytes) — lets callers validate before replaying
    /// or mutating the log.
    pub fn universe(path: &Path) -> Result<usize> {
        let mut head = [0u8; 16];
        File::open(path)
            .and_then(|mut f| f.read_exact(&mut head))
            .with_context(|| format!("read WAL header {}", path.display()))?;
        ensure!(&head[..8] == WAL_MAGIC, "{}: not a contour WAL", path.display());
        Ok(u64::from_le_bytes(head[8..16].try_into().unwrap()) as usize)
    }

    /// Open an existing WAL for appending; returns the log and the
    /// vertex universe size recorded in its header.
    pub fn append_to(path: &Path) -> Result<(Self, usize)> {
        let n = Self::universe(path)?;
        let f = OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("open WAL {} for append", path.display()))?;
        Ok((Self { w: BufWriter::new(f) }, n))
    }

    /// Append one edge batch (no-op for an empty batch).
    pub fn append_edges(&mut self, edges: &[(VId, VId)]) -> Result<()> {
        if edges.is_empty() {
            return Ok(());
        }
        let mut buf = Vec::with_capacity(5 + 8 * edges.len());
        buf.push(FRAME_EDGES);
        buf.extend_from_slice(&(edges.len() as u32).to_le_bytes());
        for &(u, v) in edges {
            buf.extend_from_slice(&u.to_le_bytes());
            buf.extend_from_slice(&v.to_le_bytes());
        }
        self.w.write_all(&buf)?;
        self.w.flush()?;
        Ok(())
    }

    /// Append an epoch seal marker.
    pub fn seal_epoch(&mut self, epoch: u64) -> Result<()> {
        let mut buf = [0u8; 9];
        buf[0] = FRAME_SEAL;
        buf[1..].copy_from_slice(&epoch.to_le_bytes());
        self.w.write_all(&buf)?;
        self.w.flush()?;
        Ok(())
    }

    /// Flush and fsync.
    pub fn sync(&mut self) -> Result<()> {
        self.w.flush()?;
        self.w.get_ref().sync_all()?;
        Ok(())
    }

    /// Scan a WAL from disk: returns the vertex universe size and every
    /// complete record, stopping silently at a torn tail frame.
    pub fn replay(path: &Path) -> Result<(usize, Vec<WalRecord>)> {
        let (n, records, _) = Self::scan(path)?;
        Ok((n, records))
    }

    /// [`Wal::replay`] plus repair: if the log ends in a torn frame
    /// (crash mid-append), truncate it away so subsequent appends start
    /// at a clean frame boundary — appending after torn bytes would make
    /// the next replay misparse or silently drop everything after them.
    /// Call before re-attaching an appender (recovery does).
    pub fn replay_and_repair(path: &Path) -> Result<(usize, Vec<WalRecord>)> {
        let (n, records, valid_end) = Self::scan(path)?;
        let len = std::fs::metadata(path)?.len();
        if valid_end < len {
            let f = OpenOptions::new()
                .write(true)
                .open(path)
                .with_context(|| format!("open WAL {} for repair", path.display()))?;
            f.set_len(valid_end)?;
            f.sync_all()?;
        }
        Ok((n, records))
    }

    /// Parse the log, returning (universe, records, end offset of the
    /// last complete frame).
    fn scan(path: &Path) -> Result<(usize, Vec<WalRecord>, u64)> {
        let data =
            std::fs::read(path).with_context(|| format!("read WAL {}", path.display()))?;
        ensure!(
            data.len() >= 16 && &data[..8] == WAL_MAGIC,
            "{}: not a contour WAL",
            path.display()
        );
        let n = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
        let mut records = Vec::new();
        let mut off = 16usize;
        while off < data.len() {
            match data[off] {
                FRAME_EDGES => {
                    let Some(count) = read_u32(&data, off + 1) else { break };
                    let end = off + 5 + 8 * count as usize;
                    if end > data.len() {
                        break; // torn frame: crash mid-append
                    }
                    let mut edges = Vec::with_capacity(count as usize);
                    let mut p = off + 5;
                    while p < end {
                        let u = read_u32(&data, p).unwrap();
                        let v = read_u32(&data, p + 4).unwrap();
                        ensure!(
                            (u as usize) < n && (v as usize) < n,
                            "{}: edge ({u}, {v}) out of range (n = {n})",
                            path.display()
                        );
                        edges.push((u, v));
                        p += 8;
                    }
                    records.push(WalRecord::Edges(edges));
                    off = end;
                }
                FRAME_SEAL => {
                    if off + 9 > data.len() {
                        break; // torn seal
                    }
                    let epoch = u64::from_le_bytes(data[off + 1..off + 9].try_into().unwrap());
                    records.push(WalRecord::EpochSeal(epoch));
                    off += 9;
                }
                other => {
                    bail!("{}: corrupt WAL frame tag {other:#04x} at byte {off}", path.display())
                }
            }
        }
        Ok((n, records, off as u64))
    }
}

fn read_u32(data: &[u8], off: usize) -> Option<u32> {
    data.get(off..off + 4).map(|b| u32::from_le_bytes(b.try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("contour_wal_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn round_trip_batches_and_seals() {
        let p = temp("round_trip.wal");
        {
            let mut w = Wal::create(&p, 100).unwrap();
            w.append_edges(&[(0, 1), (2, 3)]).unwrap();
            w.seal_epoch(1).unwrap();
            w.append_edges(&[(4, 5)]).unwrap();
            w.append_edges(&[]).unwrap(); // no-op, no frame
            w.sync().unwrap();
        }
        let (n, recs) = Wal::replay(&p).unwrap();
        assert_eq!(n, 100);
        assert_eq!(
            recs,
            vec![
                WalRecord::Edges(vec![(0, 1), (2, 3)]),
                WalRecord::EpochSeal(1),
                WalRecord::Edges(vec![(4, 5)]),
            ]
        );
    }

    #[test]
    fn append_to_continues_an_existing_log() {
        let p = temp("append_to.wal");
        {
            let mut w = Wal::create(&p, 64).unwrap();
            w.append_edges(&[(1, 2)]).unwrap();
        }
        {
            let (mut w, n) = Wal::append_to(&p).unwrap();
            assert_eq!(n, 64);
            w.append_edges(&[(3, 4)]).unwrap();
        }
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(recs.len(), 2);
        assert_eq!(recs[1], WalRecord::Edges(vec![(3, 4)]));
    }

    #[test]
    fn torn_tail_is_tolerated_corruption_is_not() {
        let p = temp("torn.wal");
        {
            let mut w = Wal::create(&p, 10).unwrap();
            w.append_edges(&[(0, 1)]).unwrap();
            w.append_edges(&[(2, 3), (4, 5)]).unwrap();
        }
        // Tear 3 bytes off the final frame: only the first batch survives.
        let len = std::fs::metadata(&p).unwrap().len();
        let f = OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(len - 3).unwrap();
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(recs, vec![WalRecord::Edges(vec![(0, 1)])]);

        // A bogus frame tag is corruption and must fail loudly.
        let r = temp("bad_tag.wal");
        let mut w = Wal::create(&r, 10).unwrap();
        w.append_edges(&[(0, 1)]).unwrap();
        drop(w);
        let mut data = std::fs::read(&r).unwrap();
        data.push(0x7F);
        std::fs::write(&r, &data).unwrap();
        assert!(Wal::replay(&r).is_err());

        // So is an edge outside the declared universe.
        let q = temp("bad_vertex.wal");
        let mut w = Wal::create(&q, 4).unwrap();
        w.append_edges(&[(0, 3)]).unwrap();
        drop(w);
        let mut data = std::fs::read(&q).unwrap();
        let at = data.len() - 4;
        data[at..].copy_from_slice(&9u32.to_le_bytes());
        std::fs::write(&q, &data).unwrap();
        assert!(Wal::replay(&q).is_err());
    }

    #[test]
    fn repair_truncates_torn_tail_before_reappending() {
        let p = temp("repair.wal");
        {
            let mut w = Wal::create(&p, 10).unwrap();
            w.append_edges(&[(0, 1)]).unwrap();
            w.append_edges(&[(2, 3), (4, 5)]).unwrap();
        }
        let len = std::fs::metadata(&p).unwrap().len();
        let f = OpenOptions::new().write(true).open(&p).unwrap();
        f.set_len(len - 3).unwrap(); // tear the last frame
        drop(f);
        // Repair drops the torn frame and truncates the file...
        let (_, recs) = Wal::replay_and_repair(&p).unwrap();
        assert_eq!(recs, vec![WalRecord::Edges(vec![(0, 1)])]);
        // ...so appending resumes at a clean boundary: without the
        // truncate, these bytes would land after the torn frame and the
        // next replay would misparse or drop them.
        let (mut w, _) = Wal::append_to(&p).unwrap();
        w.append_edges(&[(6, 7)]).unwrap();
        w.seal_epoch(1).unwrap();
        drop(w);
        let (_, recs) = Wal::replay(&p).unwrap();
        assert_eq!(
            recs,
            vec![
                WalRecord::Edges(vec![(0, 1)]),
                WalRecord::Edges(vec![(6, 7)]),
                WalRecord::EpochSeal(1),
            ]
        );
    }

    #[test]
    fn rejects_non_wal_files() {
        let p = temp("not_a.wal");
        std::fs::write(&p, b"hello world, definitely a wal").unwrap();
        assert!(Wal::replay(&p).is_err());
        assert!(Wal::append_to(&p).is_err());
    }
}
