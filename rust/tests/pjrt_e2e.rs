//! PJRT end-to-end tests: the AOT artifacts (L1 Pallas kernel inside the
//! L2 JAX iteration) executed from the L3 runtime must match the native
//! engine exactly. Requires `make artifacts`; tests skip when absent so
//! pure-Rust CI stays green.

use contour::cc::{self, contour::Contour, Algorithm};
use contour::coordinator::{PjrtContour, PjrtMode};
use contour::graph::gen;
use contour::runtime::{PaddedGraph, Runtime};

fn runtime() -> Option<Runtime> {
    match Runtime::from_env() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping PJRT tests: {e}");
            None
        }
    }
}

#[test]
fn parity_across_graph_families() {
    let Some(rt) = runtime() else { return };
    let graphs = vec![
        ("path", gen::path(900).into_csr().shuffled_edges(1)),
        ("star", gen::star(1_000).into_csr()),
        ("soup", gen::component_soup(9, 100, 2).into_csr()),
        ("rmat", gen::rmat(13, 50_000, gen::RmatKind::Graph500, 3).into_csr()),
        ("delaunay", gen::delaunay(9_000, 4).into_csr().shuffled_edges(5)),
    ];
    for (name, g) in graphs {
        // The fused artifact caps at 64 on-device iterations; synchronous
        // MM^1 needs diameter-many, so fused h=1 is only sound on
        // low-diameter graphs (Theorem 1 covers h >= 2 with log d).
        let low_diameter = matches!(name, "star" | "rmat");
        let want = Contour::c2().run(&g);
        for mode in [PjrtMode::PerIteration, PjrtMode::FusedRun] {
            for hops in [1usize, 2] {
                if hops == 1 && mode == PjrtMode::FusedRun && !low_diameter {
                    continue;
                }
                let eng = PjrtContour::new(&rt, hops, mode);
                let r = eng.try_run(&g).expect("pjrt run");
                assert_eq!(r.labels, want, "{} h={hops} {mode:?}", name);
            }
        }
    }
}

#[test]
fn per_iteration_counts_match_sync_semantics() {
    let Some(rt) = runtime() else { return };
    // The HLO iteration is the synchronous MM^2; its Rust-driven loop
    // must take the same iterations as native C-Syn (minus detection
    // accounting differences of at most one).
    let g = gen::path(800).into_csr().shuffled_edges(9);
    let pjrt = PjrtContour::new(&rt, 2, PjrtMode::PerIteration).try_run(&g).unwrap();
    // Full-sweep engine pinned: the HLO loop sweeps every edge every
    // iteration, so that is the engine whose count it must match.
    let sync = Contour::csyn()
        .with_early_check(false)
        .with_frontier_mode(contour::cc::contour::FrontierMode::Off)
        .run_with_stats(&g);
    assert!(
        pjrt.iterations.abs_diff(sync.iterations) <= 1,
        "pjrt {} vs native sync {}",
        pjrt.iterations,
        sync.iterations
    );
}

#[test]
fn fused_run_reports_on_device_iterations() {
    let Some(rt) = runtime() else { return };
    let g = gen::star(2_000).into_csr();
    let r = PjrtContour::new(&rt, 2, PjrtMode::FusedRun).try_run(&g).unwrap();
    assert!(r.iterations <= 3, "star must converge almost immediately, got {}", r.iterations);
    assert_eq!(cc::num_components(&r.labels), 1);
}

#[test]
fn fastsv_artifact_matches_native() {
    let Some(rt) = runtime() else { return };
    let g = gen::erdos_renyi(2_000, 4_000, 7).into_csr();
    let art = rt.registry().select("fastsv_iter", g.n, g.m()).expect("bucket");
    let p = PaddedGraph::new(&g, art.n, art.m).unwrap();
    let mut labels = p.labels.clone();
    for _ in 0..64 {
        let out = rt.exec_i32(art, &[labels, p.src.clone(), p.dst.clone()]).unwrap();
        let changed = out[1][0] != 0;
        labels = out.into_iter().next().unwrap();
        if !changed {
            break;
        }
    }
    let got = p.unpad(&labels);
    let want = cc::fastsv::FastSv::new().run(&g);
    assert!(cc::same_partition(&got, &want));
}

#[test]
fn compress_and_count_artifacts() {
    let Some(rt) = runtime() else { return };
    // A pointer chain: compress must flatten it to stars; count must
    // report the star count including padding singletons.
    let n_real = 600usize;
    let art = rt.registry().select("compress", n_real, 0).expect("bucket");
    let mut labels: Vec<i32> = (0..art.n as i32).collect();
    for v in 1..n_real {
        labels[v] = (v - 1) as i32; // chain v -> v-1
    }
    let out = rt.exec_i32(art, &[labels]).unwrap();
    assert!(out[0][..n_real].iter().all(|&l| l == 0), "chain must flatten to root 0");
    let rounds = out[1][0];
    assert!(rounds >= 1 && rounds <= 12, "log-rounds compression, got {rounds}");

    let cart = rt.registry().select("count_components", n_real, 0).expect("bucket");
    let cout = rt.exec_i32(cart, &[out[0].clone()]).unwrap();
    let stars = cout[0][0] as usize;
    assert_eq!(stars, 1 + (cart.n - n_real), "1 real star + padding singletons");
}

#[test]
fn bucket_overflow_is_a_clean_error() {
    let Some(rt) = runtime() else { return };
    // Larger than the largest bucket (n = 2^18 buckets ship by default).
    let huge = 1usize << 22;
    assert!(rt.registry().select("contour_iter_h2", huge, 1).is_none());
}
