//! Serving-path integration tests: the dispatcher extraction (every
//! verb through `dispatch()` with no transport attached), binary
//! protocol v2 pipelining over real TCP cross-checked against
//! sequential line-protocol answers, admission-control backpressure
//! (BUSY frames / `ERR busy`), error-path metering, and LABELS paging
//! bounds hardening.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use contour::server::dispatch::{self, Body};
use contour::server::{protocol, serve_listener, ServerState, Session};
use contour::VId;

fn no_body() -> anyhow::Result<String> {
    anyhow::bail!("no extra payload expected")
}

fn ask(state: &ServerState, line: &str) -> String {
    Session::new(state).handle(line, no_body).unwrap_or_else(|| "BYE".into())
}

// ------------------------------------------------- dispatcher core

/// Satellite: every verb in the protocol table runs through the shared
/// `dispatch()` core directly — no TCP, no Session — and the coverage
/// set is pinned to `protocol::OPCODES`, so adding a verb without
/// extending this table fails the build's tests.
#[test]
fn every_verb_through_dispatch_directly() {
    let state = ServerState::new(1);
    let dir = std::env::temp_dir().join(format!("contour-serving-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let edge_file = dir.join("edges.txt");
    std::fs::write(&edge_file, "0 1\n1 2\n2 3\n").unwrap();
    let snap = dir.join("stream.snap");

    let run = |line: &str| -> Option<String> {
        let mut fields = line.split_whitespace();
        let verb = fields.next().unwrap();
        let rest: Vec<&str> = fields.collect();
        dispatch::render_line(&dispatch::dispatch(&state, verb, &rest, Body::None))
    };

    // (request line, expected reply prefix) — order matters: later rows
    // read state earlier rows created.
    let table: Vec<(String, &str)> = vec![
        ("PING".into(), "PONG"),
        ("HELLO 2".into(), "OK v2"),
        ("GEN g path:6".into(), "OK 6 5"),
        (format!("LOAD f {}", edge_file.display()), "OK 4 3"),
        ("CC g C-2".into(), "OK 1 "),
        ("QUERY g 3 C-2".into(), "OK 0"),
        ("LABELS g C-2 0 3".into(), "OK 6 0 0 0"),
        ("STATS g".into(), "OK n=6 m=5"),
        ("SHARD g 2".into(), "OK "),
        ("PCC g C-2".into(), "OK 1 "),
        ("SHARDSTATS g".into(), "OK "),
        ("TRACE g".into(), "OK "),
        ("STREAM s 4".into(), "OK "),
        ("SADD s 0 1".into(), "OK "),
        ("SADD s 2 3".into(), "OK 1 "),
        ("SDEL s 2 3".into(), "OK 1 "),
        ("SEPOCH s".into(), "OK 1 "),
        ("SQUERY s SAME 0 1".into(), "OK "),
        // Satellite: the SQUERY usage string is one string on every
        // error path — arity errors and bad ops used to disagree.
        ("SQUERY s NOPE 1".into(), "ERR usage: SQUERY name SAME u v [epoch]"),
        ("SQUERY s".into(), "ERR usage: SQUERY name SAME u v [epoch]"),
        (format!("SSAVE s {}", snap.display()), "OK "),
        ("DROP s".into(), "OK"),
        (format!("SLOAD s2 {}", snap.display()), "OK "),
        ("LIST".into(), "OK "),
        // Sorted-key render: the first key is alphabetical, not
        // requests= — the exact ordering is pinned in tests/telemetry.rs.
        ("METRICS".into(), "OK "),
        ("PROM".into(), "OK "),
        ("HEALTH".into(), "OK "),
        // WATCH through bare dispatch() renders the header only; the
        // tick streaming lives in the transports (tests/telemetry.rs).
        ("WATCH 3 10".into(), "OK 3 10"),
        ("RECENT".into(), "OK "),
        // FAULTS is boot-gated: without CONTOUR_FAULTS[_VERB] it must
        // refuse, not silently no-op. The enabled path is in tests/chaos.rs.
        ("FAULTS".into(), "ERR FAULTS is disabled"),
    ];
    let mut covered: HashSet<&'static str> = HashSet::new();
    for (line, want) in &table {
        let verb = line.split_whitespace().next().unwrap().to_ascii_uppercase();
        let got = run(line).unwrap_or_else(|| panic!("{line:?} closed the session"));
        assert!(got.starts_with(want), "{line:?} -> {got:?}, wanted prefix {want:?}");
        covered.insert(
            protocol::OPCODES.iter().find(|(_, v)| *v == verb).map(|(_, v)| *v).unwrap(),
        );
    }

    // UPLOAD: the line body (announced edge lines) and the binary body
    // (a decoded edge array) must produce identical replies — one
    // dispatch core, two transports.
    let mut lines = vec!["1 2".to_string(), "0 1".to_string()];
    let via_lines = Session::new(&state)
        .handle("UPLOAD u1 2", move || Ok(lines.pop().expect("two edge lines")))
        .unwrap();
    let edges: Vec<(VId, VId)> = vec![(0, 1), (1, 2)];
    let via_edges = dispatch::render_line(&dispatch::dispatch(
        &state,
        "UPLOAD",
        &["u2", "2"],
        Body::Edges(&edges),
    ))
    .unwrap();
    assert!(via_lines.starts_with("OK "), "{via_lines}");
    assert_eq!(via_lines, via_edges, "line vs binary UPLOAD bodies disagree");
    covered.insert("UPLOAD");

    // BQUERY: ids in the arg list (line) and ids in the frame payload
    // (binary) answer identically from the same cached labelling.
    let via_args = run("BQUERY g C-2 0 2 5").unwrap();
    let ids: Vec<VId> = vec![0, 2, 5];
    let via_payload = dispatch::render_line(&dispatch::dispatch(
        &state,
        "BQUERY",
        &["g", "C-2"],
        Body::Ids(&ids),
    ))
    .unwrap();
    assert_eq!(via_args, "OK 3 0 0 0");
    assert_eq!(via_args, via_payload, "line vs binary BQUERY ids disagree");
    covered.insert("BQUERY");

    // SDEL: id pairs in the arg list (line) and in the frame payload
    // (binary) delete identically. Two parallel inserts of the same
    // edge, one retired each way — multiset semantics on both paths.
    assert!(run("SADD s2 2 3").unwrap().starts_with("OK 1 "));
    assert!(run("SADD s2 2 3").unwrap().starts_with("OK 1 "));
    let via_args = run("SDEL s2 2 3").unwrap();
    let pair: Vec<VId> = vec![2, 3];
    let via_payload = dispatch::render_line(&dispatch::dispatch(
        &state,
        "SDEL",
        &["s2"],
        Body::Ids(&pair),
    ))
    .unwrap();
    assert!(via_args.starts_with("OK 1 "), "{via_args}");
    assert_eq!(via_args, via_payload, "line vs binary SDEL ids disagree");
    covered.insert("SDEL");

    // Deterministic read verbs render identically through the Session
    // line adapter and through dispatch() directly.
    for line in ["PING", "QUERY g 3 C-2", "LABELS g C-2 0 3", "STATS g", "LIST"] {
        assert_eq!(run(line), Some(ask(&state, line)), "{line:?} drifted between adapters");
    }

    // QUIT ends the session (render_line -> None)...
    assert!(run("QUIT").is_none());
    covered.insert("QUIT");
    // ...an unknown verb is a clean ERR...
    assert!(run("NOPE").unwrap().starts_with("ERR "));
    // ...and the table covered the entire opcode set.
    let all: HashSet<&'static str> = protocol::OPCODES.iter().map(|(_, v)| *v).collect();
    let missing: Vec<_> = all.difference(&covered).collect();
    assert!(missing.is_empty(), "verbs not exercised through dispatch(): {missing:?}");
}

// ----------------------------------------------------- TCP helpers

fn spawn_server(state: Arc<ServerState>) -> (String, Arc<AtomicBool>, std::thread::JoinHandle<anyhow::Result<()>>) {
    let shutdown = Arc::new(AtomicBool::new(false));
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = listener.local_addr().expect("local_addr").to_string();
    let sd = Arc::clone(&shutdown);
    let handle = std::thread::spawn(move || serve_listener(listener, state, sd));
    (addr, shutdown, handle)
}

struct LineWire {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

impl LineWire {
    fn connect(addr: &str) -> Self {
        let s = TcpStream::connect(addr).expect("connect");
        Self { r: BufReader::new(s.try_clone().unwrap()), w: BufWriter::new(s) }
    }

    fn ask(&mut self, msg: &str) -> String {
        self.w.write_all(msg.as_bytes()).unwrap();
        self.w.write_all(b"\n").unwrap();
        self.w.flush().unwrap();
        let mut reply = String::new();
        self.r.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    }
}

struct BinWire {
    r: BufReader<TcpStream>,
    w: BufWriter<TcpStream>,
}

impl BinWire {
    /// Connect and upgrade: line `HELLO 2`, expect `OK v2`, then frames.
    fn connect(addr: &str) -> Self {
        let s = TcpStream::connect(addr).expect("connect");
        let mut r = BufReader::new(s.try_clone().unwrap());
        let mut w = BufWriter::new(s);
        w.write_all(b"HELLO 2\n").unwrap();
        w.flush().unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "OK v2", "HELLO 2 negotiation failed");
        Self { r, w }
    }

    fn send(&mut self, id: u32, verb: &str, args: &str, extra: &[VId]) {
        let b = protocol::encode_request(id, verb, args, extra).unwrap();
        self.w.write_all(&b).unwrap();
    }

    fn recv(&mut self) -> protocol::ReplyFrame {
        protocol::read_reply(&mut self.r).unwrap().expect("server closed mid-stream")
    }
}

// --------------------------------------------- pipelined binary path

/// Acceptance: N≥8 in-flight BQUERY frames on one upgraded connection
/// come back request-id-matched and equal to sequential line-protocol
/// QUERY answers; QUIT drains the pipeline and BYE is the last frame.
#[test]
fn pipelined_bquery_matches_sequential_query() {
    let state = Arc::new(ServerState::new(1));
    let (addr, shutdown, handle) = spawn_server(Arc::clone(&state));

    let mut line = LineWire::connect(&addr);
    assert!(line.ask("GEN g er:2000:3500").starts_with("OK 2000 "));
    assert!(line.ask("CC g C-2").starts_with("OK "));

    // Ground truth, one vertex at a time over the line protocol.
    let ids: Vec<VId> = (0..96).map(|i| (i * 131) % 2000).collect();
    let mut expected: Vec<VId> = Vec::new();
    for &v in &ids {
        let reply = line.ask(&format!("QUERY g {v} C-2"));
        let label =
            reply.split_whitespace().nth(1).and_then(|t| t.parse().ok()).unwrap_or_else(|| {
                panic!("QUERY g {v} -> {reply:?}");
            });
        expected.push(label);
    }

    // 12 BQUERY frames in flight before a single reply is read.
    let mut bin = BinWire::connect(&addr);
    let chunks: Vec<&[VId]> = ids.chunks(8).collect();
    assert!(chunks.len() >= 8, "need >=8 in-flight requests");
    for (i, chunk) in chunks.iter().enumerate() {
        bin.send(100 + i as u32, "BQUERY", "g C-2", chunk);
    }
    bin.w.flush().unwrap();

    let mut got: HashMap<u32, Vec<VId>> = HashMap::new();
    for _ in 0..chunks.len() {
        let f = bin.recv();
        assert_eq!(f.status, protocol::STATUS_OK, "BQUERY -> {}", f.text());
        assert!(got.insert(f.id, f.batch_labels().unwrap()).is_none(), "duplicate id {}", f.id);
    }
    for (i, chunk) in chunks.iter().enumerate() {
        let labels = &got[&(100 + i as u32)];
        assert_eq!(labels.len(), chunk.len());
        for (k, &v) in chunk.iter().enumerate() {
            let want = expected[i * 8 + k];
            assert_eq!(labels[k], want, "vertex {v}: pipelined label != sequential QUERY");
        }
    }

    // A light verb and a QUERY ride the same framed connection.
    bin.send(7, "PING", "", &[]);
    bin.w.flush().unwrap();
    let f = bin.recv();
    assert_eq!((f.id, f.status), (7, protocol::STATUS_OK));
    assert_eq!(f.text(), "PONG");
    bin.send(8, "QUERY", &format!("g {} C-2", ids[0]), &[]);
    bin.w.flush().unwrap();
    let f = bin.recv();
    assert_eq!((f.id, f.status), (8, protocol::STATUS_OK));
    assert_eq!(f.text(), expected[0].to_string());

    // QUIT: BYE is the last frame, then EOF.
    bin.send(9, "QUIT", "", &[]);
    bin.w.flush().unwrap();
    let f = bin.recv();
    assert_eq!((f.id, f.status), (9, protocol::STATUS_BYE));
    assert!(protocol::read_reply(&mut bin.r).unwrap().is_none(), "frames after BYE");

    // The upgrade and the batch path showed up in the metrics.
    let m = line.ask("METRICS");
    assert!(m.contains("hello_upgrades=1"), "{m}");
    assert!(m.contains(&format!("batch_queries={}", chunks.len())), "{m}");
    assert_eq!(line.ask("QUIT"), "BYE");

    shutdown.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

/// Acceptance: under an over-window pipelined load the server answers
/// BUSY frames immediately instead of queueing without bound, and every
/// request id still gets exactly one reply.
#[test]
fn over_window_pipelining_sees_busy() {
    // Window of 1: any second in-flight pipelined request is over the
    // window. Heavy cap stays high so admission control's *global*
    // gate does not fire here — this test isolates the per-connection
    // window.
    let state = Arc::new(ServerState::new(1).with_admission(1, 64));
    let (addr, shutdown, handle) = spawn_server(Arc::clone(&state));

    let mut line = LineWire::connect(&addr);
    assert!(line.ask("GEN g path:64").starts_with("OK 64 "));
    assert!(line.ask("CC g C-2").starts_with("OK "));

    let mut bin = BinWire::connect(&addr);
    // A slow pipelined request occupies the window...
    bin.send(1, "GEN", "big rmat:14:8", &[]);
    // ...and a burst of reads behind it overflows it.
    let burst = 32u32;
    for i in 0..burst {
        bin.send(10 + i, "BQUERY", "g C-2", &[(i % 64) as VId]);
    }
    bin.w.flush().unwrap();

    let mut seen: HashMap<u32, u8> = HashMap::new();
    for _ in 0..(burst + 1) {
        let f = bin.recv();
        assert!(seen.insert(f.id, f.status).is_none(), "duplicate reply id {}", f.id);
    }
    assert_eq!(seen.len() as u32, burst + 1, "every request answered exactly once");
    assert_eq!(seen[&1], protocol::STATUS_OK, "the in-window request succeeded");
    let busy = seen.values().filter(|&&s| s == protocol::STATUS_BUSY).count();
    assert!(busy >= 1, "no BUSY under an over-window load");
    for (&id, &status) in &seen {
        assert!(
            status == protocol::STATUS_OK || status == protocol::STATUS_BUSY,
            "request {id} -> unexpected status {status}"
        );
    }

    let m = line.ask("METRICS");
    let busy_total: u64 = m
        .split_whitespace()
        .find_map(|t| t.strip_prefix("busy="))
        .and_then(|v| v.parse().ok())
        .expect("busy= counter missing");
    assert!(busy_total >= busy as u64, "{m}");
    assert_eq!(line.ask("QUIT"), "BYE");
    drop(bin);

    shutdown.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

/// The global heavy-verb semaphore: with zero heavy slots every heavy
/// verb is `ERR busy` on the line protocol (frame-level BUSY is the
/// binary rendering of the same reply), while light verbs still serve.
#[test]
fn heavy_cap_zero_turns_heavy_verbs_busy() {
    let state = ServerState::new(1).with_admission(8, 0);
    let r = ask(&state, "GEN g path:10");
    assert!(r.starts_with("ERR busy:"), "{r}");
    assert_eq!(ask(&state, "PING"), "PONG");
    let m = ask(&state, "METRICS");
    assert!(m.contains("busy=1"), "{m}");
    assert!(m.contains("errors=0"), "busy rejections are not errors: {m}");
    assert!(m.contains("err/GEN=1"), "{m}");
}

// ------------------------------------------------- error metering

/// Satellite bugfix, over the real wire: an ERR reply records both
/// `lat/<verb>` and the new `err/<verb>` counter.
#[test]
fn error_replies_are_metered_on_the_wire() {
    let state = Arc::new(ServerState::new(1));
    let (addr, shutdown, handle) = spawn_server(Arc::clone(&state));
    let mut line = LineWire::connect(&addr);

    let before = line.ask("METRICS");
    assert!(!before.contains("err/CC="), "{before}");
    assert!(line.ask("CC nosuch C-2").starts_with("ERR "));
    let m = line.ask("METRICS");
    assert!(m.contains("err/CC=1"), "{m}");
    // The latency histogram saw the failed request: count is the first
    // field of `lat/CC=count:p50:p95:p99`.
    let lat = m
        .split_whitespace()
        .find_map(|t| t.strip_prefix("lat/CC="))
        .expect("lat/CC missing after an ERR reply");
    let count: u64 = lat.split(':').next().unwrap().parse().unwrap();
    assert_eq!(count, 1, "{lat}");
    // Errors on one verb don't invent counters for others.
    assert!(!m.contains("err/PING="), "{m}");
    assert_eq!(line.ask("QUIT"), "BYE");

    shutdown.store(true, Ordering::Relaxed);
    handle.join().unwrap().unwrap();
}

// --------------------------------------------------- LABELS bounds

/// Satellite: LABELS paging never panics or wraps — huge and
/// overflowing offsets/counts are clean ERRs or clamped pages, and the
/// page boundaries are exact.
#[test]
fn labels_paging_is_bounds_hardened() {
    let state = ServerState::new(1);
    assert!(ask(&state, "GEN g path:50").starts_with("OK 50 "));

    // 2^64 does not fit usize: a clean ERR, not a wrap.
    let r = ask(&state, "LABELS g 18446744073709551616");
    assert!(r.starts_with("ERR ") && r.contains("out of range"), "{r}");
    let r = ask(&state, "LABELS g 0 18446744073709551616");
    assert!(r.starts_with("ERR ") && r.contains("out of range"), "{r}");

    // usize::MAX is in range and clamps: offset 49 + MAX saturates to
    // the end, one label left.
    assert_eq!(ask(&state, "LABELS g 49 18446744073709551615"), "OK 50 0");
    // offset == total and offset > total: empty page, total still told.
    assert_eq!(ask(&state, "LABELS g 50"), "OK 50");
    assert_eq!(ask(&state, "LABELS g 1000 5"), "OK 50");
    // Exact page boundaries.
    assert_eq!(ask(&state, "LABELS g 48 2"), "OK 50 0 0");
    assert_eq!(ask(&state, "LABELS g 0 0"), "OK 50");
    let full = ask(&state, "LABELS g");
    assert_eq!(full.split_whitespace().count(), 2 + 50, "default page covers path:50");
}
